"""Quickstart: build an Einsum Network, train it with stochastic EM, and run
the tractable-inference queries the paper is about -- in ~30 seconds on CPU.

PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import EiNet, Normal, random_binary_trees
from repro.core.em import EMConfig, stochastic_em_update

# 1. structure: a RAT region graph (paper §4.1), 32 variables
graph = random_binary_trees(num_vars=32, depth=3, num_repetitions=4, seed=0)
net = EiNet(graph, num_sums=8, exponential_family=Normal())
params = net.init(jax.random.PRNGKey(0))
print(f"EiNet: {net.leaf_spec.num_leaves} leaves, "
      f"{len(net.pair_specs)} einsum layers, "
      f"{net.num_params(params):,} parameters")

# 2. data: two Gaussian clusters
rng = np.random.RandomState(0)
centers = rng.randn(2, 32) * 2
data = jnp.asarray(
    centers[rng.randint(2, size=2048)] + rng.randn(2048, 32) * 0.5,
    jnp.float32,
)

# 3. train: autodiff-EM (one jax.grad per E-step -- paper §3.5)
step = jax.jit(lambda p, b: stochastic_em_update(net, p, b, EMConfig(step_size=0.5)))
for epoch in range(5):
    for i in range(0, 2048, 256):
        params, ll = step(params, data[i: i + 256])
    print(f"epoch {epoch}: batch mean log-likelihood {float(ll):8.3f}")

# 4. exact inference (the point of tractable models):
x = data[:4]
print("\nlog p(x):", np.round(np.asarray(net.log_likelihood(params, x)), 2))

marg = jnp.zeros((4, 32), bool).at[:, :16].set(True)  # marginalize vars 16..31
print("log p(x_0..15):", np.round(np.asarray(net.log_likelihood(params, x, marg)), 2))

q = jnp.zeros((4, 32), bool).at[:, 16:].set(True)
print("log p(x_16.. | x_0..15):",
      np.round(np.asarray(net.conditional_log_likelihood(params, x, q, marg)), 2))

samples = net.sample(params, jax.random.PRNGKey(1), 3)
print("\n3 samples, first 6 dims:\n", np.round(np.asarray(samples[:, :6]), 2))

inpaint = net.conditional_sample(params, jax.random.PRNGKey(2), x, marg)
print("inpainted (vars 16.. resampled | vars 0..15 observed), first row:",
      np.round(np.asarray(inpaint[0, 14:20]), 2))
