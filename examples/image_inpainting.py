"""Fig. 4 workflow: PD-structure EiNet as a generative image model with
tractable inpainting (conditional sampling given arbitrary evidence masks).

PYTHONPATH=src python examples/image_inpainting.py

Writes artifacts/example_inpainting/{originals,masked,inpainted,samples}.npy
and prints reconstruction metrics for three different mask patterns --
the "multi-purpose predictor" property (paper Eq. 1): ONE model answers all
conditionals exactly, no retraining per mask.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import EiNet, Normal, poon_domingos
from repro.core.em import EMConfig, stochastic_em_update
from repro.data.synthetic import gaussian_mixture_images

H = W = 16
C = 3
OUT = "artifacts/example_inpainting"


def main():
    data = gaussian_mixture_images(4096 + 32, H, W, C, seed=0)
    train, test = data[:4096], data[4096:]
    graph = poon_domingos(H, W, delta=4, num_channels=C, axes=("w",))
    net = EiNet(graph, num_sums=12,
                exponential_family=Normal(min_var=1e-6, max_var=1e-2))
    params = net.init(jax.random.PRNGKey(0))
    step = jax.jit(lambda p, b: stochastic_em_update(
        net, p, b, EMConfig(step_size=0.5)))
    for epoch in range(6):
        for i in range(0, 4096, 256):
            params, ll = step(params, jnp.asarray(train[i: i + 256]))
        print(f"epoch {epoch}: LL {float(ll):9.2f}")

    xt = jnp.asarray(test)
    masks = {
        "left_half": np.tile(
            (np.arange(W) < W // 2)[None, :, None], (H, 1, C)),
        "top_half": np.tile(
            (np.arange(H) < H // 2)[:, None, None], (1, W, C)),
        "sparse_25pct": np.random.RandomState(0).rand(H, W, C) < 0.25,
    }
    os.makedirs(OUT, exist_ok=True)
    np.save(f"{OUT}/originals.npy", np.asarray(xt).reshape(-1, H, W, C))
    mean_img = train.mean(0)
    for name, m in masks.items():
        ev = jnp.asarray(np.tile(m.reshape(1, -1), (len(test), 1)))
        recon = np.asarray(net.conditional_sample(
            params, jax.random.PRNGKey(1), xt, ev, mode="argmax"))
        missing = ~np.asarray(ev)
        mse = np.mean((recon - np.asarray(xt))[missing] ** 2)
        base = np.mean((np.tile(mean_img, (len(test), 1)) -
                        np.asarray(xt))[missing] ** 2)
        print(f"{name:14s}: inpaint MSE {mse:.4f} vs mean-fill {base:.4f} "
              f"({'better' if mse < base else 'WORSE'})")
        np.save(f"{OUT}/inpainted_{name}.npy", recon.reshape(-1, H, W, C))
    samples = np.asarray(net.sample(params, jax.random.PRNGKey(2), 16))
    np.save(f"{OUT}/samples.npy", samples.reshape(-1, H, W, C))
    print(f"wrote arrays to {OUT}/")


if __name__ == "__main__":
    main()
