"""End-to-end training driver: a multi-million-parameter EiNet density model
trained for a few hundred stochastic-EM steps with the full production stack
-- sharded data pipeline, fault-tolerant loop, atomic async checkpoints,
restart-and-continue.

PYTHONPATH=src python examples/train_density.py [--steps 200] [--kill-at 120]

``--kill-at`` injects a simulated node failure mid-run to demonstrate the
checkpoint/restart path (the loop restores and the final LL matches an
uninterrupted run).
"""

import argparse
import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core import EiNet, Normal, random_binary_trees
from repro.core.em import EMConfig
from repro.data import datasets as ds_lib
from repro.data.pipeline import ShardedLoader
from repro.data.synthetic import gaussian_mixture_images
from repro.dist import fault_tolerance as ft
from repro.train import TrainConfig, make_em_step


def resolve_data(args) -> np.ndarray:
    """(N, D) float32 training rows for --dataset (real data falls back to
    the deterministic procedural generator on offline hosts)."""
    if args.dataset == "synthetic":
        return gaussian_mixture_images(8192, 16, 16, 3, seed=1)
    try:
        ds = ds_lib.load_image_dataset(args.dataset)
    except ds_lib.DatasetUnavailable as e:
        print(f"{e}; using the procedural fallback")
        ds = ds_lib.load_image_dataset(args.dataset, source="procedural")
    print(f"dataset {args.dataset} ({ds.source}): {len(ds.train_x)} rows")
    data, _ = ds_lib.to_domain(ds.train_x, "normal")
    return data


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--num-sums", type=int, default=16)
    ap.add_argument("--dataset", choices=("synthetic", "mnist", "svhn"),
                    default="synthetic")
    ap.add_argument("--kill-at", type=int, default=None)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    data = resolve_data(args)
    d = data.shape[1]
    graph = random_binary_trees(d, depth=5, num_repetitions=8, seed=0)
    net = EiNet(graph, num_sums=args.num_sums,
                exponential_family=Normal(min_var=1e-6, max_var=1e-2))
    params = net.init(jax.random.PRNGKey(0))
    print(f"model: {net.num_params(params):,} parameters, "
          f"{len(net.pair_specs)} einsum layers")

    def make_batch(step, shard, n):
        idx = (np.arange(n) + step * n + shard * 10_007) % len(data)
        return {"x": data[idx]}

    loader = ShardedLoader(make_batch, global_batch=args.batch)

    emcfg = EMConfig(step_size=0.3)
    # one compiled program per step (repro.train).  donate=False: the
    # fault-tolerant loop may replay from the initial params after a
    # pre-first-checkpoint failure (--kill-at demonstrates exactly that).
    step_fn_jit = make_em_step(net, TrainConfig(em=emcfg, donate=False))
    lls = []

    def step_fn(state, batch):
        p, ll = step_fn_jit(state["params"], jnp.asarray(batch["x"]))
        lls.append(float(ll))
        return {"params": p, "step": state["step"] + 1}

    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="einet_ckpt_")
    mgr = CheckpointManager(ckpt_dir, keep=2)
    killed = set()

    def injector(step):
        if args.kill_at is not None and step == args.kill_at \
                and step not in killed:
            killed.add(step)
            raise RuntimeError("simulated preemption")

    t0 = time.time()
    state, stats = ft.run_training(
        step_fn,
        {"params": params, "step": jnp.zeros((), jnp.int32)},
        loader.batch_at,
        mgr,
        num_steps=args.steps,
        cfg=ft.LoopConfig(checkpoint_every=50),
        fail_injector=injector,
    )
    dt = time.time() - t0
    print(f"trained {args.steps} steps in {dt:.1f}s "
          f"({dt/args.steps*1e3:.0f} ms/step), restarts={stats['restarts']}")
    print(f"LL: first10 {np.mean(lls[:10]):8.2f} -> last10 {np.mean(lls[-10:]):8.2f}")
    test = jnp.asarray(data[:512])
    print(f"final mean test LL: "
          f"{float(jnp.mean(net.log_likelihood(state['params'], test))):.2f}")
    print(f"checkpoints in {ckpt_dir}: steps {mgr.all_steps()}")


if __name__ == "__main__":
    main()
