"""LM substrate demo: train a reduced assigned-architecture config for a few
steps on CPU, with the AdamW optimizer, sharded loader, checkpointing --
then serve it (prefill + a few decode steps).

PYTHONPATH=src python examples/train_lm.py [--arch qwen1.5-0.5b] [--steps 30]

(Architectures are selectable exactly as in the dry-run; the smoke_variant
reduction keeps the family/block-pattern/MoE layout but shrinks the dims so
the demo runs in ~a minute on one CPU core.)
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_variant
from repro.data.pipeline import lm_loader
from repro.configs.base import ShapeSpec
from repro.models import lm
from repro.optim import adamw


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()

    cfg = smoke_variant(get_config(args.arch))
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    n = sum(int(np.prod(p.shape)) for p in jax.tree_util.tree_leaves(params))
    print(f"{cfg.name}: {n:,} params, pattern {cfg.block_pattern}")

    ocfg = adamw.AdamWConfig(learning_rate=3e-3, warmup_steps=10,
                             decay_steps=args.steps * 2)
    opt = adamw.init_state(ocfg, params)
    shape = ShapeSpec("demo", "train", args.seq, args.batch)
    loader = lm_loader(cfg, shape)

    step = jax.jit(lambda p, o, b: lm.train_step(cfg, ocfg, p, o, b))
    t0 = time.time()
    for i, batch in zip(range(args.steps), loader):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt, m = step(params, opt, batch)
        if i % 5 == 0 or i == args.steps - 1:
            print(f"step {i:3d}: loss {float(m['loss']):6.3f} "
                  f"gnorm {float(m['grad_norm']):8.2f}")
    print(f"{args.steps} steps in {time.time()-t0:.1f}s")

    # serve the trained model
    if cfg.embedding_input:
        prompt = {"inputs_embeds": jax.random.normal(
            jax.random.PRNGKey(1), (1, 8, cfg.d_model)) * 0.1}
        nxt = {"inputs_embeds": jax.random.normal(
            jax.random.PRNGKey(2), (1, 1, cfg.d_model)) * 0.1}
        logits, cache, pos = lm.prefill(cfg, params, prompt, max_len=16)
        for _ in range(4):
            logits, cache = lm.decode_step(cfg, params, nxt, cache, pos)
            pos = pos + 1
        print("decoded (embedding-input arch): final logits shape",
              logits.shape)
    else:
        prompt = {"tokens": jnp.asarray([[1, 5, 2, 7, 1, 5, 2, 7]])}
        logits, cache, pos = lm.prefill(cfg, params, prompt, max_len=16)
        out = []
        tok = jnp.argmax(logits[:, -1:], -1)
        for _ in range(6):
            logits, cache = lm.decode_step(cfg, params, {"tokens": tok},
                                           cache, pos)
            pos = pos + 1
            tok = jnp.argmax(logits[:, -1:], -1)
            out.append(int(tok[0, 0]))
        print("greedy continuation of [1 5 2 7 1 5 2 7]:", out)


if __name__ == "__main__":
    main()
