"""Roofline analysis from the dry-run artifacts (deliverable g).

Per (arch x shape x mesh) cell, derive the three roofline terms on TPU v5e:

  compute    = FLOPs_per_device            / 197e12  bf16 FLOP/s
  memory     = HBM_bytes_per_device        / 819e9   B/s
  collective = collective_bytes_per_device / 50e9    B/s (per ICI link)

FLOPs come from the scan-aware HLO analyzer (dot ops x loop trip counts; see
launch/hlo_analysis.py).  HBM bytes use the analyzer's bytes_written (every
materialized buffer, x trips) as the traffic model, floored by the parameter
bytes that must stream from HBM each step.  Collective bytes are summed
result-buffer bytes of all collective ops, x trips.

Also reported per cell: the dominant term and a one-line mitigation note.
(EiNet EM steps have no tokens-x-active-params useful-work model, so the
MODEL_FLOPS floor columns report "-" and the HLO flops stand alone.)

Usage:  PYTHONPATH=src python -m benchmarks.roofline [--dir artifacts/dryrun]
writes a markdown table to stdout (EXPERIMENTS.md §Roofline embeds it).
"""

from __future__ import annotations

import argparse
import json
import os
from typing import Dict, Optional

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s
LINK_BW = 50e9  # B/s per ICI link

def model_flops_per_device(rec: Dict) -> Optional[float]:
    """Useful-work floor, per device.

    EiNet EM steps have no tokens-x-active-params flop model (the useful
    work IS the circuit evaluation the HLO analyzer already counts), so
    there is no separate floor: every cell reports None and the roofline
    uses the HLO flops directly."""
    return None


def analyze_record(rec: Dict) -> Dict:
    n_dev = rec["num_devices"]
    mf = model_flops_per_device(rec)
    hlo_flops = rec["flops_per_device"]
    flops = max(hlo_flops, mf or 0.0)  # matvec-fused decode cells: use model
    param_bytes = (rec.get("param_count") or 0) * 2 / n_dev  # bf16 stream floor
    mem_bytes = max(rec["bytes_written_per_device"], param_bytes)
    terms = {
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": mem_bytes / HBM_BW,
        "collective_s": rec["collective_bytes_per_device"] / LINK_BW,
    }
    dominant = max(terms, key=terms.get)
    total = max(terms.values())
    out = dict(rec)
    out.update(terms)
    out["dominant"] = dominant.replace("_s", "")
    out["model_flops_per_device"] = mf
    out["useful_ratio"] = (mf / hlo_flops) if (mf and hlo_flops) else None
    # roofline fraction: useful compute time / bottleneck time
    useful_s = (mf or flops) / PEAK_FLOPS
    out["roofline_fraction"] = useful_s / total if total > 0 else None
    return out


_NOTES = {
    "compute": "compute-bound: raise MXU utilization (bf16 everywhere, "
               "larger per-device tiles) or shrink remat recompute",
    "memory": "memory-bound: fuse or shrink materialized scan-body buffers; "
              "cast f32 temporaries to bf16; larger tiles per HBM pass",
    "collective": "collective-bound: reshard to cut all-gathers (FSDP "
                  "prefetch, SP<->TP transitions), overlap via async "
                  "collectives / collective matmul",
}


def build_table(art_dir: str, mesh: Optional[str] = "16x16"):
    rows = []
    for f in sorted(os.listdir(art_dir)):
        if not f.endswith(".json"):
            continue
        with open(os.path.join(art_dir, f)) as fh:
            rec = json.load(fh)
        if mesh and rec.get("mesh") != mesh and "skipped" not in rec:
            continue
        if "error" in rec:
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "skipped": "ERROR: " + rec["error"][:60]})
            continue
        if "skipped" in rec:
            if mesh and rec.get("mesh") not in (None, mesh):
                continue
            rows.append(rec)
            continue
        rows.append(analyze_record(rec))
    return rows


def to_markdown(rows) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant | "
           "MODEL/HLO flops | roofline frac | note |")
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for r in rows:
        if "skipped" in r:
            lines.append(
                f"| {r['arch']} | {r.get('shape','-')} | - | - | - | skipped | "
                f"- | - | {r['skipped']} |"
            )
            continue
        ur = f"{r['useful_ratio']:.2f}" if r["useful_ratio"] else "-"
        rf = f"{r['roofline_fraction']:.3f}" if r["roofline_fraction"] else "-"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3f} | "
            f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | {r['dominant']} | "
            f"{ur} | {rf} | {_NOTES[r['dominant']][:58]} |"
        )
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()
    rows = build_table(args.dir, args.mesh)
    if args.json:
        print(json.dumps(rows, indent=1, default=str))
    else:
        print(to_markdown(rows))


if __name__ == "__main__":
    main()
