"""Serving-engine throughput benchmark -> BENCH_serve.json.

Measures the batched exact-inference engine (``repro.serve``) against the
direct one-call-at-a-time path on a mixed query stream and writes a JSON
record so the perf trajectory has data across PRs:

  PYTHONPATH=src python benchmarks/bench_serve.py --smoke     # CI-sized
  PYTHONPATH=src python benchmarks/bench_serve.py             # einet_rat

Schema (one flat dict): see ``repro.serve.benchmark.run_benchmark`` plus
{"arch", "num_vars", "num_sums", "timestamp"}.
"""

from __future__ import annotations

import argparse
import datetime
import json

import jax

from repro.configs import EinetConfig, get_config
from repro.launch.cells import build_einet
from repro.serve import format_report, mixed_requests, run_benchmark

SMOKE_CONFIG = EinetConfig(
    name="einet-rat-serve-smoke",
    structure="rat",
    num_vars=16,
    depth=2,
    num_repetitions=2,
    num_sums=4,
    batch_size=64,
)


def main(
    smoke: bool = False,
    arch: str = "einet_rat",
    requests: int = 64,
    max_batch: int = 0,
    reps: int = 3,
    out: str = "BENCH_serve.json",
) -> dict:
    cfg = SMOKE_CONFIG if smoke else get_config(arch)
    if smoke:
        requests = min(requests, 24)
    model = build_einet(cfg)
    params = model.init(jax.random.PRNGKey(0))
    reqs = mixed_requests(model.num_vars, requests, seed=0)
    report = run_benchmark(model, params, reqs, max_batch=max_batch, reps=reps)
    ok = report["parity_max_abs_diff"] <= 1e-5
    report.update(
        arch=cfg.name,
        num_vars=model.num_vars,
        num_sums=model.K,
        smoke=smoke,
        parity_ok=ok,
        timestamp=datetime.datetime.now(datetime.timezone.utc).isoformat(),
    )
    print(format_report(report))
    if not ok:
        print(f"PARITY FAILURE: {report['parity_max_abs_diff']:.2e} > 1e-5")
    if out:
        with open(out, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
        print(f"wrote {out}")
    return report if ok else {}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny model + short stream (CI profile)")
    ap.add_argument("--arch", default="einet_rat")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--max-batch", type=int, default=0)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args()
    result = main(smoke=args.smoke, arch=args.arch, requests=args.requests,
                  max_batch=args.max_batch, reps=args.reps, out=args.out)
    raise SystemExit(0 if result else 1)
