"""Serving-engine throughput benchmark -> BENCH_serve.json.

Measures the batched exact-inference engine (``repro.serve``) against the
direct one-call-at-a-time path on a mixed query stream and writes a JSON
record so the perf trajectory has data across PRs:

  PYTHONPATH=src python benchmarks/bench_serve.py --smoke     # CI-sized
  PYTHONPATH=src python benchmarks/bench_serve.py             # einet_rat

Schema (one flat dict): see ``repro.serve.benchmark.run_benchmark`` plus
{"arch", "num_vars", "num_sums", "timestamp"}.
"""

from __future__ import annotations

import argparse
import datetime
import json

import jax

from repro.configs import EinetConfig, get_config
from repro.launch.cells import build_einet
from repro.obs import slo as slo_lib
from repro.serve import format_report, mixed_requests, run_benchmark

SMOKE_CONFIG = EinetConfig(
    name="einet-rat-serve-smoke",
    structure="rat",
    # 32 vars: the smallest RAT shape whose scopes don't collide across
    # repetitions, so the whole circuit depth-groups and the smoke run
    # exercises the grouped execution path (see bench_train.SMOKE_CONFIG)
    num_vars=32,
    depth=2,
    num_repetitions=2,
    num_sums=4,
    batch_size=64,
)

PD_SMOKE_CONFIG = EinetConfig(
    name="einet-pd-serve-smoke",
    structure="pd",
    # 32 vars as a 4x8 image, delta=2 on both axes: the interior PD pairs
    # compile to one gather-grouped segment, so the smoke run serves
    # through the gather kernels (see bench_train.PD_SMOKE_CONFIG)
    height=4,
    width=8,
    num_channels=1,
    delta=2,
    pd_axes=("h", "w"),
    num_sums=4,
    batch_size=64,
)


def _bench_one(cfg, requests: int, max_batch: int, reps: int,
               smoke: bool) -> dict:
    model = build_einet(cfg)
    params = model.init(jax.random.PRNGKey(0))
    reqs = mixed_requests(model.num_vars, requests, seed=0)
    report = run_benchmark(model, params, reqs, max_batch=max_batch, reps=reps)
    parity_ok = report["parity_max_abs_diff"] <= 1e-5
    # LL serving must run the grouped plan -- RAT through fused (canonical)
    # segments, PD through gather segments (sampling keeps the per-layer
    # cache path by design).  The historical PD structural exemption is
    # gone: gather fusion covers it now.
    grouped_ok = model.grouped_active
    report.update(
        arch=cfg.name,
        num_vars=model.num_vars,
        num_sums=model.K,
        smoke=smoke,
        parity_ok=parity_ok,
        grouped_ok=grouped_ok,
        # kernel launches per forward: per-layer loop vs grouped plan
        # (includes the effective vmem_budget the planner resolved)
        grouping=model.grouping_summary(),
        timestamp=datetime.datetime.now(datetime.timezone.utc).isoformat(),
    )
    print(format_report(report))
    g = report["grouping"]
    print(f"grouping  : launches {g['launches_per_layer']} -> "
          f"{g['launches_grouped']} ({g['fused_groups']} fused + "
          f"{g['gather_groups']} gather group(s) over "
          f"{g['fused_pairs']}/{g['num_pairs']} pairs)")
    if not parity_ok:
        print(f"PARITY FAILURE: {report['parity_max_abs_diff']:.2e} > 1e-5")
    if not grouped_ok:
        print("GROUPED-EXECUTION FAILURE: arch expected to depth-group fell "
              "back to the per-layer path")
    return report


def main(
    smoke: bool = False,
    arch: str = "einet_rat",
    requests: int = 64,
    max_batch: int = 0,
    reps: int = 3,
    out: str = "BENCH_serve.json",
) -> dict:
    cfg = SMOKE_CONFIG if smoke else get_config(arch)
    if smoke:
        requests = min(requests, 24)
    report = _bench_one(cfg, requests, max_batch, reps, smoke)
    ok = report["parity_ok"] and report["grouped_ok"]
    if smoke:
        # the gather-topology twin: CI serves through the PD gather kernels
        pd_report = _bench_one(PD_SMOKE_CONFIG, requests, max_batch, reps,
                               smoke)
        report["pd_smoke"] = pd_report
        ok = ok and pd_report["parity_ok"] and pd_report["grouped_ok"]
    if out:
        with open(out, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
        print(f"wrote {out}")
        print(f"history -> {slo_lib.append_history('serve', report)}")
    return report if ok else {}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny model + short stream (CI profile)")
    ap.add_argument("--arch", default="einet_rat")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--max-batch", type=int, default=0)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args()
    result = main(smoke=args.smoke, arch=args.arch, requests=args.requests,
                  max_batch=args.max_batch, reps=args.reps, out=args.out)
    raise SystemExit(0 if result else 1)
