"""Fig. 6 (supplementary): inference time per sample, EiNet vs naive,
sweeping K / D / R.  Same protocol as bench_fig3 but timing
``log_likelihood`` on a 100-sample test batch (the paper's setup).

CSV: impl,param,value,inference_us_per_sample
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import EiNet, NaiveEiNet, Normal, random_binary_trees

DVARS, NTEST = 128, 100
DEFAULTS = dict(depth=3, reps=4, k=8)


def one(impl: str, depth: int, reps: int, k: int) -> float:
    g = random_binary_trees(DVARS, depth, reps, seed=0)
    cls = NaiveEiNet if impl == "naive" else EiNet
    net = cls(g, num_sums=k, exponential_family=Normal())
    params = net.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (NTEST, DVARS))
    f = jax.jit(net.log_likelihood)
    jax.block_until_ready(f(params, x))  # compile
    t0 = time.time()
    for _ in range(5):
        out = f(params, x)
    jax.block_until_ready(out)
    return (time.time() - t0) / 5 / NTEST * 1e6


def run(quick: bool = False):
    rows = []
    ks = [4, 16] if quick else [2, 4, 8, 16, 24]
    depths = [2, 4] if quick else [1, 2, 3, 4, 5]
    reps = [2, 8] if quick else [1, 4, 8, 16]
    for impl in ("einet", "naive"):
        for k in ks:
            rows.append((impl, "K", k, one(impl, DEFAULTS["depth"], DEFAULTS["reps"], k)))
        for d in depths:
            rows.append((impl, "D", d, one(impl, d, DEFAULTS["reps"], DEFAULTS["k"])))
        for r in reps:
            rows.append((impl, "R", r, one(impl, DEFAULTS["depth"], r, DEFAULTS["k"])))
    return rows


def main(quick: bool = False):
    rows = run(quick)
    print("impl,param,value,inference_us_per_sample")
    for r in rows:
        print(f"{r[0]},{r[1]},{r[2]},{r[3]:.2f}")
    return rows


if __name__ == "__main__":
    main()
