"""Fig. 3: training time + peak memory, EiNet vs naive implementation,
sweeping the structural hyper-parameters K (densities per sum/leaf),
D (split depth), R (replica).

The paper's measurement on a RTX 2080 Ti shows 1-2 orders of magnitude;
this container is a single CPU core, so magnitudes differ but the *scaling
claim* (EiNet time/memory grows gracefully in K while the naive
K^3-exp/materialized-product implementation blows up) is measurable.

Memory proxy (no GPU allocator here): peak live buffer bytes from the jitted
step's compiled memory_analysis (temp + output), which is exactly the
materialized-products effect the paper plots.

CSV: impl,param,value,train_s_per_epoch,peak_temp_mb
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import EiNet, NaiveEiNet, Normal, em_update, random_binary_trees

N, DVARS = 512, 128  # paper: 2000 x 512 (scaled to CPU)
DEFAULTS = dict(depth=3, reps=4, k=8)


def one(impl: str, depth: int, reps: int, k: int):
    g = random_binary_trees(DVARS, depth, reps, seed=0)
    cls = NaiveEiNet if impl == "naive" else EiNet
    net = cls(g, num_sums=k, exponential_family=Normal())
    params = net.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (N, DVARS))
    step = jax.jit(lambda p, b: em_update(net, p, b))
    lowered = step.lower(params, x)
    ma = lowered.compile().memory_analysis()
    peak_mb = (ma.temp_size_in_bytes + ma.output_size_in_bytes) / 1e6
    p, _ = step(params, x)  # compile+warm
    t0 = time.time()
    reps_t = 3
    for _ in range(reps_t):
        p, ll = step(p, x)
    jax.block_until_ready(ll)
    return (time.time() - t0) / reps_t, peak_mb


def run(quick: bool = False):
    rows = []
    ks = [4, 8, 16] if quick else [2, 4, 8, 16, 24]
    depths = [2, 4] if quick else [1, 2, 3, 4, 5]
    reps = [2, 8] if quick else [1, 4, 8, 16]
    for impl in ("einet", "naive"):
        for k in ks:
            t, m = one(impl, DEFAULTS["depth"], DEFAULTS["reps"], k)
            rows.append((impl, "K", k, t, m))
        for d in depths:
            t, m = one(impl, d, DEFAULTS["reps"], DEFAULTS["k"])
            rows.append((impl, "D", d, t, m))
        for r in reps:
            t, m = one(impl, DEFAULTS["depth"], r, DEFAULTS["k"])
            rows.append((impl, "R", r, t, m))
    return rows


def main(quick: bool = False):
    rows = run(quick)
    print("impl,param,value,train_s_per_epoch,peak_temp_mb")
    for r in rows:
        print(f"{r[0]},{r[1]},{r[2]},{r[3]:.4f},{r[4]:.2f}")
    # derived: speedup + memory ratio at the largest K
    kmax = max(r[2] for r in rows if r[1] == "K")
    te = [r for r in rows if r[0] == "einet" and r[1] == "K" and r[2] == kmax][0]
    tn = [r for r in rows if r[0] == "naive" and r[1] == "K" and r[2] == kmax][0]
    print(f"# K={kmax}: naive/einet time {tn[3]/te[3]:.1f}x, "
          f"memory {tn[4]/max(te[4],1e-9):.1f}x")
    return rows


if __name__ == "__main__":
    main()
