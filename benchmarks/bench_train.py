"""Training-pipeline benchmark -> BENCH_train.json.

Measures the compiled EM step (``repro.train``: scan-accumulated microbatch
statistics + M-step + blend as ONE donated-buffer XLA program, E-step grads
through the fused backward Pallas kernel on TPU) against the seed's per-step
path (per-microbatch jitted E-step dispatches, Python-loop statistic
accumulation, separately-jitted M-step), and reports Pallas-vs-XLA gradient
parity alongside, so the training perf trajectory has data across PRs:

  PYTHONPATH=src python benchmarks/bench_train.py --smoke     # CI-sized
  PYTHONPATH=src python benchmarks/bench_train.py             # 3-arch sweep

The default sweep covers einet_rat / einet_rat_large / einet_pd at
CPU-feasible batch sizes (full paper batches need TPU; shapes are recorded in
the JSON so numbers are comparable across hosts).  Exit status gates grad
parity (1e-4), the per-row speedup floor (>= 1.0 or an explicit
SPEEDUP_WAIVERS entry), and grouped execution being active on archs that
support it; --smoke skips the timing gate (timer noise) but keeps the
parity and grouped-execution gates.
"""

from __future__ import annotations

import argparse
import datetime
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs import EinetConfig, get_config
from repro.core.em import (
    EMConfig,
    accumulate_statistics,
    blend_params,
    em_statistics,
    m_step,
    zeros_like_statistics,
)
from repro.kernels import ops
from repro.kernels.ref import log_einsum_exp_ref
from repro.launch.cells import build_einet
from repro.obs import slo as slo_lib
from repro.train import TrainConfig, make_em_step

SMOKE_CONFIG = EinetConfig(
    name="einet-rat-train-smoke",
    structure="rat",
    # 32 vars (not fewer): small var counts collide region scopes across
    # repetitions, which breaks canonical layout and would silently drop the
    # smoke run to the per-layer path -- 32/2/2 is the smallest RAT shape
    # whose whole circuit depth-groups, so CI exercises the grouped kernels
    num_vars=32,
    depth=2,
    num_repetitions=2,
    num_sums=4,
    batch_size=64,
)

PD_SMOKE_CONFIG = EinetConfig(
    name="einet-pd-train-smoke",
    structure="pd",
    # 32 vars as a 4x8 image with delta=2 cuts on both axes: a 4-pair PD
    # circuit whose 3 interior pairs compile to ONE gather-grouped segment
    # (launches 7 -> 3), so CI exercises the gather kernels end to end
    height=4,
    width=8,
    num_channels=1,
    delta=2,
    pd_axes=("h", "w"),
    num_sums=4,
    batch_size=64,
)

# (arch id, benchmark batch, microbatches, timed steps) -- batches are sized
# for the CPU container; pass --batch/--steps to override, or run on TPU for
# the paper-scale shapes recorded in the configs.
DEFAULT_CELLS = (
    ("einet_rat", 256, 4, 3),
    ("einet_rat_large", 16, 2, 2),
    ("einet_pd", 32, 2, 2),
)

PARITY_TOL = 1e-4

# Every non-smoke results[] row must show speedup >= 1.0 (compiled step at
# least as fast as the seed per-step path) OR carry an explicit waiver here:
# arch id -> reason string, recorded verbatim in the row's
# ``speedup_waiver`` field.  Empty since the depth-grouped execution plan
# fixed the einet_rat 0.814 regression (root cause: the seed's gather-based
# per-layer forward dominating the scan body at small arch, not the scan
# itself -- see SCAN_UNROLL_MAX in repro.train.pipeline for the
# measurements).  Add entries ONLY with a root-cause note.
SPEEDUP_WAIVERS: dict = {}


def _grad_parity(model) -> float:
    """Max abs diff, fused-backward Pallas VJP vs XLA autodiff, on the
    model's widest einsum layer (its real (L, K_out, K) shapes)."""
    spec = max(model.pair_specs, key=lambda s: s.num_partitions)
    l, k, ko = min(spec.num_partitions, 8), spec.k_in, spec.k_out
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    w = jax.nn.softmax(
        jax.random.normal(k1, (l, ko, k, k)).reshape(l, ko, -1), -1
    ).reshape(l, ko, k, k)
    lnl = -jnp.abs(jax.random.normal(k2, (16, l, k))) * 10.0
    lnr = -jnp.abs(jax.random.normal(k3, (16, l, k))) * 10.0
    gk = jax.grad(lambda *a: ops.log_einsum_exp(*a).mean(), argnums=(0, 1, 2))(
        w, lnl, lnr
    )
    gr = jax.grad(
        lambda *a: log_einsum_exp_ref(*a).mean(), argnums=(0, 1, 2)
    )(w, lnl, lnr)
    return max(
        float(np.max(np.abs(np.asarray(a) - np.asarray(b))))
        for a, b in zip(gk, gr)
    )


def _time_steps(step_fn, params, x, steps: int, reps: int) -> float:
    """Best-of-reps mean seconds per step, with a chained warm-up step so the
    steady-state (params-in == params-out aval) program is what gets timed."""
    p, _ = step_fn(params, x)
    p, _ = step_fn(p, x)
    jax.block_until_ready(jax.tree_util.tree_leaves(p)[0])
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        p = params
        for _ in range(steps):
            p, ll = step_fn(p, x)
        jax.block_until_ready(jax.tree_util.tree_leaves(p)[0])
        best = min(best, (time.perf_counter() - t0) / steps)
    return best


def leaf_scatter_timing(arch: str = "einet_pd", batch: int = 32,
                        reps: int = 3) -> dict:
    """The ROADMAP "fuse or not" question, measured: how much of an
    ``em_statistics`` call is the leaf-statistic fan-out scatter (the
    unique-index ``.at[flat].set`` into (D, K, R, |T|) -- the one E-step op
    still pure XLA after the fused backward kernels)?

    Times the full jitted E-step against a jitted program of the REAL
    production op (``core.em.leaf_scatter``, shared with the mixture
    E-step) at realistic operand shapes.
    """
    from repro.core.em import leaf_scatter

    cfg = get_config(arch)
    model = build_einet(cfg)
    params = model.init(jax.random.PRNGKey(0))
    d_vars = model.num_vars
    x = jnp.asarray(
        np.random.RandomState(0).randn(batch, d_vars).astype(np.float32)
    )
    stats_jit = jax.jit(lambda p, xb: em_statistics(model, p, xb))

    ls = model.leaf_spec
    d, k, r = params["phi"].shape[:3]
    t_dim = model.ef.num_stats
    p_len = len(ls.pair_var)

    scatter_jit = jax.jit(
        lambda sp, sd: leaf_scatter(model, sp, sd)
    )
    rng = np.random.RandomState(1)
    sp = jnp.asarray(rng.rand(p_len, k, t_dim).astype(np.float32))
    sd = jnp.asarray(rng.rand(p_len, k).astype(np.float32))

    def time_fn(fn, *args):
        out = fn(*args)  # compile + warm
        jax.block_until_ready(jax.tree_util.tree_leaves(out)[0])
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            out = fn(*args)
            jax.block_until_ready(jax.tree_util.tree_leaves(out)[0])
            best = min(best, time.perf_counter() - t0)
        return best

    full_s = time_fn(stats_jit, params, x)
    scatter_s = time_fn(scatter_jit, sp, sd)
    return {
        "arch": cfg.name,
        "arch_id": arch,
        "batch": batch,
        "num_pairs": int(p_len),
        "scatter_out_shape": [int(d), int(k), int(r), int(t_dim)],
        "em_statistics_ms": round(full_s * 1e3, 3),
        "leaf_scatter_ms": round(scatter_s * 1e3, 3),
        "scatter_fraction": round(scatter_s / max(full_s, 1e-12), 4),
    }


def segment_breakdown(model, params, x) -> dict:
    """Per-segment time breakdown of one forward pass, measured eagerly.

    The ``plan.segment`` spans in ``EiNet._forward_planned*`` normally fire
    at trace time (the walk runs under jit); to charge real device time to
    each segment, this enables obs tracing, installs
    ``jax.block_until_ready`` as the obs sync hook (each span then blocks
    on its own segment's output before closing) and runs one forward under
    ``jax.disable_jit()``.  Returns {segment kind: {launches, eager_ms}} --
    eager op dispatch inflates the absolute numbers vs the compiled step,
    but the RELATIVE per-kind split is what the breakdown is for.
    """
    if not model.grouped_active:
        return {}
    mark = obs.num_events()
    was_enabled = obs.enabled()
    obs.configure(trace=True)
    obs.set_sync(jax.block_until_ready)
    try:
        with jax.disable_jit():
            jax.block_until_ready(model.log_likelihood(params, x))
    finally:
        obs.set_sync(None)
        obs.configure(trace=was_enabled)
    out: dict = {}
    for e in obs.trace_events()[mark:]:
        if e["name"] != "plan.segment":
            continue
        d = out.setdefault(e["args"]["kind"], {"launches": 0, "eager_ms": 0.0})
        d["launches"] += 1
        d["eager_ms"] += e["dur"] / 1e3
    for d in out.values():
        d["eager_ms"] = round(d["eager_ms"], 3)
    return out


def _per_step_path(model, em_cfg: EMConfig, num_microbatches: int):
    """The seed's training path: one jitted dispatch PER microbatch, host
    Python-loop accumulation, separately-jitted M-step + blend."""
    stats_jit = jax.jit(lambda p, xb: em_statistics(model, p, xb))
    acc_jit = jax.jit(accumulate_statistics)

    def finish(p, st):
        mini = m_step(model, st, em_cfg)
        return (
            blend_params(model, p, mini, em_cfg.step_size),
            st["ll"] / st["count"],
        )

    finish_jit = jax.jit(finish)

    def step(params, x):
        mb = x.shape[0] // num_microbatches
        acc = zeros_like_statistics(model, params)
        for i in range(num_microbatches):
            acc = acc_jit(acc, stats_jit(params, x[i * mb:(i + 1) * mb]))
        return finish_jit(params, acc)

    return step


def bench_cell(arch: str, cfg: EinetConfig, batch: int, microbatches: int,
               steps: int, reps: int) -> dict:
    model = build_einet(cfg)
    params = model.init(jax.random.PRNGKey(0))
    d = model.num_vars
    x = jnp.asarray(
        np.random.RandomState(0).randn(batch, d).astype(np.float32)
    )
    em_cfg = EMConfig()

    # donate=False: the benchmark re-feeds the SAME params pytree to both
    # paths and across timing reps; donation would delete the buffers after
    # the first fused call on TPU/GPU
    fused = make_em_step(
        model,
        TrainConfig(em=em_cfg, num_microbatches=microbatches, donate=False),
    )
    per_step = _per_step_path(model, em_cfg, microbatches)

    # warm-up both paths (compile), checking they agree while we're at it
    t0 = time.perf_counter()
    pf, ll_f = fused(params, x)
    jax.block_until_ready(jax.tree_util.tree_leaves(pf)[0])
    compile_fused_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    pp, ll_p = per_step(params, x)
    jax.block_until_ready(jax.tree_util.tree_leaves(pp)[0])
    compile_per_step_s = time.perf_counter() - t0
    step_parity = float(
        max(
            np.max(np.abs(np.asarray(a) - np.asarray(b)))
            for a, b in zip(
                jax.tree_util.tree_leaves(pf), jax.tree_util.tree_leaves(pp)
            )
            if np.asarray(a).size  # unmixed layers carry (0, 0, K) stubs
        )
    )

    fused_s = _time_steps(fused, params, x, steps, reps)
    per_step_s = _time_steps(per_step, params, x, steps, reps)
    parity = _grad_parity(model)
    segments = segment_breakdown(model, params, x)
    waiver = SPEEDUP_WAIVERS.get(arch)
    speedup = per_step_s / fused_s
    return {
        "arch": cfg.name,
        "arch_id": arch,
        "num_vars": d,
        "num_sums": model.K,
        "num_params_m": round(model.num_params(params) / 1e6, 3),
        "batch": batch,
        "microbatches": microbatches,
        "steps_timed": steps,
        "fused_ms_per_step": round(fused_s * 1e3, 2),
        "per_step_ms_per_step": round(per_step_s * 1e3, 2),
        "fused_steps_per_s": round(1.0 / fused_s, 3),
        "per_step_steps_per_s": round(1.0 / per_step_s, 3),
        "speedup": round(speedup, 3),
        "speedup_ok": speedup >= 1.0 or waiver is not None,
        "speedup_waiver": waiver,
        # kernel launches per forward: per-layer loop vs depth-grouped plan
        "grouping": model.grouping_summary(),
        # eager per-segment forward split (obs plan.segment spans)
        "segment_breakdown": segments,
        "compile_fused_s": round(compile_fused_s, 2),
        "compile_per_step_s": round(compile_per_step_s, 2),
        "update_parity_max_abs_diff": step_parity,
        "grad_parity_max_abs_diff": parity,
        "grad_parity_ok": parity <= PARITY_TOL,
    }


def main(smoke: bool = False, archs=None, batch: int = 0, steps: int = 0,
         reps: int = 2, out: str = "BENCH_train.json") -> dict:
    if smoke:
        cells = [
            ("smoke", SMOKE_CONFIG, SMOKE_CONFIG.batch_size, 4, 3),
            ("smoke-pd", PD_SMOKE_CONFIG, PD_SMOKE_CONFIG.batch_size, 4, 3),
        ]
        reps = 1
    else:
        cells = [
            (a, get_config(a), batch or b, m, steps or s)
            for a, b, m, s in DEFAULT_CELLS
            if archs is None or a in archs
        ]
    results = []
    for arch, cfg, b, m, s in cells:
        print(f"[bench_train] {cfg.name}: batch={b} microbatches={m} ...")
        r = bench_cell(arch, cfg, b, m, s, reps)
        g = r["grouping"]
        print(
            f"  fused {r['fused_ms_per_step']:.1f} ms/step vs per-step "
            f"{r['per_step_ms_per_step']:.1f} ms/step "
            f"(x{r['speedup']:.2f}); launches "
            f"{g['launches_per_layer']}->{g['launches_grouped']}; "
            f"grad parity {r['grad_parity_max_abs_diff']:.2e}"
        )
        if r["segment_breakdown"]:
            split = ", ".join(
                f"{k}: {v['launches']} launch(es) {v['eager_ms']:.1f} ms"
                for k, v in sorted(r["segment_breakdown"].items())
            )
            print(f"  segments (eager forward): {split}")
        results.append(r)
    parity_ok = all(r["grad_parity_ok"] for r in results)
    # speedup gate: every row >= 1.0 or an explicit waiver (ISSUE: no silent
    # regressions).  Smoke timings are too small/noisy to gate on, but the
    # smoke run DOES gate that the grouped path is actually exercised.
    speedup_ok = smoke or all(r["speedup_ok"] for r in results)
    # grouped-execution gate: EVERY arch must run grouped -- RAT via fused
    # (canonical) segments, PD via gather segments.  The historical einet_pd
    # exemption is gone: a PD-family arch reporting per-layer fallback fails
    # unless it carries an explicit SPEEDUP_WAIVERS entry.
    grouped_ok = all(
        r["grouping"]["fused_groups"] >= 1
        or r["grouping"]["gather_groups"] >= 1
        or r["arch_id"] in SPEEDUP_WAIVERS
        for r in results
    )
    for r in results:
        if not r["speedup_ok"]:
            print(f"SPEEDUP REGRESSION (unwaived): {r['arch_id']} "
                  f"x{r['speedup']:.3f} < 1.0")
    # the leaf-statistic fan-out microbenchmark (ROADMAP "fuse or not"):
    # cheap, so it runs at einet_pd scale even when --arch narrowed the
    # sweep; skipped entirely under --smoke (the question needs production
    # scale, and CI only gates parity), leaving leaf_scatter = null
    leaf_scatter = leaf_scatter_timing("einet_pd") if not smoke else None
    if leaf_scatter:
        print(
            f"[bench_train] leaf scatter ({leaf_scatter['arch']}): "
            f"{leaf_scatter['leaf_scatter_ms']:.2f} ms of "
            f"{leaf_scatter['em_statistics_ms']:.2f} ms em_statistics "
            f"({100 * leaf_scatter['scatter_fraction']:.1f}%)"
        )
    report = {
        "results": results,
        "leaf_scatter": leaf_scatter,
        "smoke": smoke,
        "backend": jax.default_backend(),
        "parity_ok": parity_ok,
        "speedup_ok": speedup_ok,
        "grouped_ok": grouped_ok,
        "timestamp": datetime.datetime.now(datetime.timezone.utc).isoformat(),
    }
    if not parity_ok:
        print(f"GRAD PARITY FAILURE (> {PARITY_TOL})")
    if not grouped_ok:
        print("GROUPED-EXECUTION FAILURE: an arch expected to depth-group "
              "fell back to the per-layer path")
    if out:
        with open(out, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
        print(f"wrote {out}")
        print(f"history -> {slo_lib.append_history('train', report)}")
    return report if (parity_ok and speedup_ok and grouped_ok) else {}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny model, parity-gated only (CI profile)")
    ap.add_argument("--arch", action="append", default=None,
                    help="restrict to this arch id (repeatable)")
    ap.add_argument("--batch", type=int, default=0,
                    help="override the per-cell benchmark batch")
    ap.add_argument("--steps", type=int, default=0)
    ap.add_argument("--reps", type=int, default=2)
    ap.add_argument("--out", default="BENCH_train.json")
    args = ap.parse_args()
    result = main(smoke=args.smoke, archs=args.arch, batch=args.batch,
                  steps=args.steps, reps=args.reps, out=args.out)
    raise SystemExit(0 if result else 1)
