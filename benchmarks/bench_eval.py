"""Evaluation-throughput benchmark -> BENCH_eval.json.

Measures the engine-streamed held-out-LL path (``repro.eval.metrics``)
against the engine-free dense baseline (fixed-size jitted ``EiNet.query``
chunks) on the same test rows, so EXPERIMENTS.md records what serving the
benchmark through the production engine costs (or saves) versus a bespoke
eval loop -- plus the inpainting harness throughput, parity-gated:

  PYTHONPATH=src python benchmarks/bench_eval.py --smoke    # CI profile
  PYTHONPATH=src python benchmarks/bench_eval.py            # 16x16x3 PD net
"""

from __future__ import annotations

import argparse
import datetime
import json
import time

import jax
import numpy as np

from repro.data import datasets as ds_lib
from repro.eval.inpainting import run_inpainting
from repro.eval.metrics import direct_log_likelihoods, engine_log_likelihoods
from repro.eval.workbench import EvalConfig, pd_config_for
from repro.launch.cells import build_einet
from repro.obs import slo as slo_lib
from repro.serve import ServeEngine


def main(smoke: bool = False, rows: int = 512, inpaint_rows: int = 8,
         max_batch: int = 64, out: str = "BENCH_eval.json") -> dict:
    cfg = EvalConfig(dataset="synthetic", smoke=smoke)
    if smoke:
        rows, inpaint_rows, max_batch = 96, 4, 16
    dataset = (
        ds_lib.synthetic_image_dataset(8, 8, 1, num_train=256, num_test=rows)
        if smoke else
        ds_lib.synthetic_image_dataset(16, 16, 3, num_train=256, num_test=rows)
    )
    spec = dataset.spec
    model = build_einet(pd_config_for(cfg, spec))
    params = model.init(jax.random.PRNGKey(0))
    test_x, _ = ds_lib.to_domain(dataset.test_x, "normal")
    x = test_x[:rows]

    engine = ServeEngine(model, params, max_batch=max_batch)
    res = engine_log_likelihoods(
        model, params, x, engine=engine, parity_rows=min(64, rows)
    )

    # dense baseline: compile once on the chunk shape, then measure
    direct_log_likelihoods(model, params, x[: max_batch * 2], chunk=max_batch)
    t0 = time.perf_counter()
    ll_direct = direct_log_likelihoods(model, params, x, chunk=max_batch)
    direct_s = time.perf_counter() - t0

    inp = run_inpainting(
        model, params, x[:inpaint_rows], spec.height, spec.width,
        spec.channels, engine=engine, parity_rows=None,
    )

    mismatches = res.parity_mismatches + inp.metrics["parity_mismatches"]
    report = {
        "arch": f"einet-pd-{spec.name}-eval",
        "num_vars": model.num_vars,
        "num_sums": model.K,
        "smoke": smoke,
        "rows": rows,
        "engine_rows_per_s": res.rows_per_second,
        "engine_seconds": res.engine_seconds,
        "engine_warmup_s": res.warmup_seconds,
        "direct_rows_per_s": rows / max(direct_s, 1e-9),
        "direct_seconds": direct_s,
        "engine_vs_direct": (rows / max(res.engine_seconds, 1e-9))
        / (rows / max(direct_s, 1e-9)),
        "ll_max_abs_diff_engine_vs_direct": float(
            np.max(np.abs(res.ll - ll_direct))
        ),
        "inpaint_requests_per_s": inp.metrics["requests_per_s"],
        "inpaint_requests": inp.metrics["num_requests"],
        "parity_mismatches": int(mismatches),
        "parity_ok": mismatches == 0,
        "timestamp": datetime.datetime.now(datetime.timezone.utc).isoformat(),
    }
    print(f"{report['arch']}: engine {report['engine_rows_per_s']:.0f} rows/s "
          f"vs dense {report['direct_rows_per_s']:.0f} rows/s "
          f"(x{report['engine_vs_direct']:.2f}); inpainting "
          f"{report['inpaint_requests_per_s']:.0f} req/s; "
          f"parity mismatches {mismatches}")
    if out:
        with open(out, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
        print(f"wrote {out}")
        print(f"history -> {slo_lib.append_history('eval', report)}")
    return report if mismatches == 0 else {}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--rows", type=int, default=512)
    ap.add_argument("--inpaint-rows", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=64)
    ap.add_argument("--out", default="BENCH_eval.json")
    args = ap.parse_args()
    result = main(smoke=args.smoke, rows=args.rows,
                  inpaint_rows=args.inpaint_rows, max_batch=args.max_batch,
                  out=args.out)
    raise SystemExit(0 if result else 1)
