"""Mixture-of-EiNets training benchmark -> BENCH_mixture.json.

The mixture subsystem's headline claim: training C architecturally-identical
components is better executed as ONE vmapped, jitted EM step over a stacked
``(C, B, D)`` batch than as a Python loop of C single-model steps -- the
batched-circuit-execution observation of "Scaling Tractable Probabilistic
Circuits: A Systems Perspective" (PyJuice) applied to whole models.  Both
paths compute the identical update (per-cluster hard EM, ``repro.mixture``),
so per-component parameter parity after a step is the benchmark's gate and
the wall-clock ratio is the result:

  PYTHONPATH=src python benchmarks/bench_mixture.py --smoke   # CI, parity-gated
  PYTHONPATH=src python benchmarks/bench_mixture.py           # C in {4, 8}

Exit status is the parity gate (the timing is recorded, not gated, so CI
stays robust to timer noise).
"""

from __future__ import annotations

import argparse
import datetime
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import EinetConfig
from repro.launch.cells import build_einet
from repro.mixture import EiNetMixture, MixtureTrainConfig, make_mixture_em_step
from repro.obs import slo as slo_lib
from repro.train import TrainConfig, make_em_step

# one CPU-feasible component in the dispatch-bound regime the mixture step
# targets: many components, each small enough that the Python loop's
# per-component dispatch is a real fraction of its step.  (At container-CPU
# scale a LARGE component turns compute-bound and XLA-CPU threads the looped
# steps to parity -- recorded in EXPERIMENTS.md §Perf; on TPU the vmapped
# step additionally saves C-1 program launches per update.)  Paper-scale
# components need TPU; shapes are in the JSON so numbers are comparable.
COMPONENT_CONFIG = EinetConfig(
    name="einet-rat-mixture-bench",
    structure="rat",
    num_vars=32,
    depth=2,
    num_repetitions=2,
    num_sums=4,
    batch_size=32,
)

SMOKE_CONFIG = EinetConfig(
    name="einet-rat-mixture-smoke",
    structure="rat",
    num_vars=16,
    depth=2,
    num_repetitions=2,
    num_sums=4,
    batch_size=32,
)

# (cell id, num components, per-component batch, timed steps); C spans the
# paper's clusters-of-images regime (§4.2 uses on the order of tens of
# clusters)
DEFAULT_CELLS = (
    ("mixture_c4", 4, 32, 4),
    ("mixture_c16", 16, 32, 4),
    ("mixture_c32", 32, 32, 4),
)

PARITY_TOL = 1e-6  # vmap-vs-loop reassociates reductions; ~1e-9 in practice


def _component(params, c):
    return jax.tree_util.tree_map(lambda a: a[c], params["components"])


def _block(tree):
    jax.block_until_ready(jax.tree_util.tree_leaves(tree)[0])


def bench_cell(cell: str, cfg: EinetConfig, num_components: int, batch: int,
               steps: int, reps: int) -> dict:
    base = build_einet(cfg)
    mix = EiNetMixture(base, num_components)
    params = mix.init(jax.random.PRNGKey(0))
    d = base.num_vars
    x = jnp.asarray(
        np.random.RandomState(0)
        .randn(num_components, batch, d).astype(np.float32)
    )

    # donate=False: both paths re-feed the same params across timing reps
    vstep = make_mixture_em_step(mix, MixtureTrainConfig(donate=False))
    sstep = make_em_step(base, TrainConfig(donate=False))

    # -- parity: one vmapped step vs the loop, from identical init ---------
    pv, ll_v = vstep(params, x)
    _block(pv)
    looped = [_component(params, c) for c in range(num_components)]
    looped = [sstep(p, x[c])[0] for c, p in enumerate(looped)]
    _block(looped)
    parity = 0.0
    for c in range(num_components):
        a_leaves = jax.tree_util.tree_leaves(_component(pv, c))
        b_leaves = jax.tree_util.tree_leaves(looped[c])
        for a, b in zip(a_leaves, b_leaves):
            if np.asarray(a).size:
                parity = max(parity, float(
                    np.max(np.abs(np.asarray(a) - np.asarray(b)))
                ))

    # -- timing ------------------------------------------------------------
    def run_vmapped():
        p = params
        for _ in range(steps):
            p, _ll = vstep(p, x)
        _block(p)

    def run_looped():
        comps = [_component(params, c) for c in range(num_components)]
        for _ in range(steps):
            for c in range(num_components):
                comps[c], _ll = sstep(comps[c], x[c])
        _block(comps)

    run_vmapped()  # steady-state warm-up for both programs
    run_looped()
    best_v = best_l = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        run_vmapped()
        best_v = min(best_v, (time.perf_counter() - t0) / steps)
        t0 = time.perf_counter()
        run_looped()
        best_l = min(best_l, (time.perf_counter() - t0) / steps)

    return {
        "cell": cell,
        "component_arch": cfg.name,
        "num_components": num_components,
        "num_vars": d,
        "num_sums": base.K,
        "num_params_m": round(mix.num_params(params) / 1e6, 3),
        "per_component_batch": batch,
        "steps_timed": steps,
        "vmapped_ms_per_step": round(best_v * 1e3, 2),
        "looped_ms_per_step": round(best_l * 1e3, 2),
        "speedup": round(best_l / best_v, 3),
        "param_parity_max_abs_diff": parity,
        "param_parity_ok": parity <= PARITY_TOL,
    }


def main(smoke: bool = False, components: int = 0, batch: int = 0,
         steps: int = 0, reps: int = 2,
         out: str = "BENCH_mixture.json") -> dict:
    if smoke:
        cells = [("smoke", SMOKE_CONFIG, components or 4, batch or 32, 2)]
        reps = 1
    else:
        cells = [
            (cell, COMPONENT_CONFIG, components or c, batch or b, steps or s)
            for cell, c, b, s in DEFAULT_CELLS
        ]
    results = []
    for cell, cfg, c, b, s in cells:
        print(f"[bench_mixture] {cell}: C={c} batch={b}/component ...")
        r = bench_cell(cell, cfg, c, b, s, reps)
        print(
            f"  vmapped {r['vmapped_ms_per_step']:.1f} ms/step vs looped "
            f"{r['looped_ms_per_step']:.1f} ms/step (x{r['speedup']:.2f}); "
            f"param parity {r['param_parity_max_abs_diff']:.2e}"
        )
        results.append(r)
    parity_ok = all(r["param_parity_ok"] for r in results)
    report = {
        "results": results,
        "smoke": smoke,
        "backend": jax.default_backend(),
        "parity_ok": parity_ok,
        "timestamp": datetime.datetime.now(datetime.timezone.utc).isoformat(),
    }
    if not parity_ok:
        print(f"PARAM PARITY FAILURE (> {PARITY_TOL})")
    if out:
        with open(out, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
        print(f"wrote {out}")
        print(f"history -> {slo_lib.append_history('mixture', report)}")
    return report if parity_ok else {}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny component, parity-gated only (CI profile)")
    ap.add_argument("--components", type=int, default=0,
                    help="override C for every cell")
    ap.add_argument("--batch", type=int, default=0,
                    help="override the per-component batch")
    ap.add_argument("--steps", type=int, default=0)
    ap.add_argument("--reps", type=int, default=2)
    ap.add_argument("--out", default="BENCH_mixture.json")
    args = ap.parse_args()
    result = main(smoke=args.smoke, components=args.components,
                  batch=args.batch, steps=args.steps, reps=args.reps,
                  out=args.out)
    raise SystemExit(0 if result else 1)
