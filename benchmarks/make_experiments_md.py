"""Assemble EXPERIMENTS.md from the dry-run artifacts + benchmark JSONs +
the hand-written §Perf iteration log (kept in benchmarks/perf_log.md).

Degrades gracefully: sections whose artifacts have not been generated on
this host (the dry-run sweep needs the 512-device subprocess run) render a
placeholder instead of crashing, so the §Perf log that module docstrings
cite is always available.

PYTHONPATH=src:. python -m benchmarks.make_experiments_md
"""

import json
import os

from benchmarks import roofline

_MISSING = ("_not yet generated on this host — run "
            "`python -m repro.launch.dryrun` first._")


def dryrun_summary(art_dir: str, mesh: str) -> str:
    if not os.path.isdir(art_dir):
        return _MISSING
    rows = []
    ok = skip = 0
    for f in sorted(os.listdir(art_dir)):
        if not f.endswith(".json"):
            continue
        rec = json.load(open(os.path.join(art_dir, f)))
        if rec.get("mesh") not in (mesh, None) and "skipped" not in rec:
            continue
        if "skipped" in rec:
            skip += 1
            continue
        if "error" in rec:
            rows.append(f"| {rec['arch']} | {rec.get('shape')} | ERROR |")
            continue
        ok += 1
        mem = rec["memory"]
        rows.append(
            f"| {rec['arch']} | {rec['shape']} | {rec['kind']} | "
            f"{rec['flops_per_device']:.2e} | "
            f"{rec['collective_bytes_per_device']:.2e} | "
            f"{(mem['argument_bytes'])/1e9:.1f} | "
            f"{(mem['temp_bytes'])/1e9:.1f} | {rec['compile_s']:.0f} |"
        )
    hdr = ("| arch | shape | kind | FLOPs/dev | coll B/dev | args GB | "
           "temp GB | compile s |\n|" + "---|" * 8)
    return (f"{ok} cells compiled, {skip} documented skips.\n\n" + hdr + "\n"
            + "\n".join(rows))


def roofline_summary(art_dir: str, mesh: str) -> str:
    if not os.path.isdir(art_dir):
        return _MISSING
    return roofline.to_markdown(roofline.build_table(art_dir, mesh))


def bench_summary() -> str:
    """One row per benchmark JSON snapshot present at the repo root."""
    parts = []
    if os.path.isfile("BENCH_serve.json"):
        r = json.load(open("BENCH_serve.json"))
        parts.append(
            f"**Serving** (`BENCH_serve.json`, {r.get('arch')}): engine "
            f"{r.get('engine_qps', 0):.1f} req/s — "
            f"x{r.get('speedup', 0):.1f} vs the pre-engine per-request path, "
            f"x{r.get('speedup_vs_jitted', 0):.1f} vs a fully-jitted "
            f"per-request baseline; parity {r.get('parity_max_abs_diff')}."
        )
    if os.path.isfile("BENCH_train.json"):
        r = json.load(open("BENCH_train.json"))
        rows = ["| arch | batch (microbatches) | compiled ms/step | "
                "per-step ms/step | speedup | grad parity |",
                "|" + "---|" * 6]
        for c in r.get("results", []):
            rows.append(
                f"| {c['arch']} | {c['batch']} ({c['microbatches']}) | "
                f"{c['fused_ms_per_step']} | {c['per_step_ms_per_step']} | "
                f"x{c['speedup']} | {c['grad_parity_max_abs_diff']:.1e} |"
            )
        parts.append(
            "**Training** (`BENCH_train.json`, backend "
            f"{r.get('backend')}): compiled EM step vs the seed's per-step "
            "path.\n\n" + "\n".join(rows)
        )
    return "\n\n".join(parts) if parts else _MISSING


def main():
    base = roofline_summary("artifacts/dryrun_baseline", "16x16")
    opt_dir = "artifacts/dryrun_opt" if os.path.isdir("artifacts/dryrun_opt") \
        else "artifacts/dryrun"
    opt = roofline_summary(opt_dir, "16x16")
    single = dryrun_summary(opt_dir, "16x16")
    multi = dryrun_summary("artifacts/dryrun", "2x16x16")
    perf = open("benchmarks/perf_log.md").read()
    header = open("benchmarks/experiments_header.md").read()
    out = header
    out = out.replace("{{DRYRUN_SINGLE}}", single)
    out = out.replace("{{DRYRUN_MULTI}}", multi)
    out = out.replace("{{ROOFLINE_BASELINE}}", base)
    out = out.replace("{{ROOFLINE_OPT}}", opt)
    out = out.replace("{{BENCHES}}", bench_summary())
    out = out.replace("{{PERF_LOG}}", perf)
    with open("EXPERIMENTS.md", "w") as f:
        f.write(out)
    print("wrote EXPERIMENTS.md", len(out), "bytes")


if __name__ == "__main__":
    main()
