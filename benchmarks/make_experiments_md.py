"""Assemble EXPERIMENTS.md from the dry-run artifacts + benchmark JSONs +
the hand-written §Perf iteration log (kept in benchmarks/perf_log.md).

Degrades gracefully: sections whose artifacts have not been generated on
this host (the dry-run sweep needs the 512-device subprocess run) render a
placeholder instead of crashing, so the §Perf log that module docstrings
cite is always available.

PYTHONPATH=src:. python -m benchmarks.make_experiments_md
"""

import json
import os

from benchmarks import roofline

_MISSING = ("_not yet generated on this host — run "
            "`python -m repro.launch.dryrun` first._")


def dryrun_summary(art_dir: str, mesh: str) -> str:
    if not os.path.isdir(art_dir):
        return _MISSING
    rows = []
    ok = skip = 0
    for f in sorted(os.listdir(art_dir)):
        if not f.endswith(".json"):
            continue
        rec = json.load(open(os.path.join(art_dir, f)))
        if rec.get("mesh") not in (mesh, None) and "skipped" not in rec:
            continue
        if "skipped" in rec:
            skip += 1
            continue
        if "error" in rec:
            rows.append(f"| {rec['arch']} | {rec.get('shape')} | ERROR |")
            continue
        ok += 1
        mem = rec["memory"]
        rows.append(
            f"| {rec['arch']} | {rec['shape']} | {rec['kind']} | "
            f"{rec['flops_per_device']:.2e} | "
            f"{rec['collective_bytes_per_device']:.2e} | "
            f"{(mem['argument_bytes'])/1e9:.1f} | "
            f"{(mem['temp_bytes'])/1e9:.1f} | {rec['compile_s']:.0f} |"
        )
    hdr = ("| arch | shape | kind | FLOPs/dev | coll B/dev | args GB | "
           "temp GB | compile s |\n|" + "---|" * 8)
    return (f"{ok} cells compiled, {skip} documented skips.\n\n" + hdr + "\n"
            + "\n".join(rows))


def roofline_summary(art_dir: str, mesh: str) -> str:
    if not os.path.isdir(art_dir):
        return _MISSING
    return roofline.to_markdown(roofline.build_table(art_dir, mesh))


def bench_summary() -> str:
    """One row per benchmark JSON snapshot present at the repo root."""
    parts = []
    if os.path.isfile("BENCH_serve.json"):
        r = json.load(open("BENCH_serve.json"))
        pc = r.get("program_cache") or {}
        cache_s = (f" Program cache: {pc.get('hits', 0)} hits / "
                   f"{pc.get('misses', 0)} misses "
                   f"({pc.get('registry_compiles', 0)} registry compiles)."
                   if pc else "")
        parts.append(
            f"**Serving** (`BENCH_serve.json`, {r.get('arch')}): engine "
            f"{r.get('engine_qps', 0):.1f} req/s — "
            f"x{r.get('speedup', 0):.1f} vs the pre-engine per-request path, "
            f"x{r.get('speedup_vs_jitted', 0):.1f} vs a fully-jitted "
            f"per-request baseline; parity {r.get('parity_max_abs_diff')}."
            + cache_s
        )
        lat = r.get("latency_ms") or {}
        if lat:
            rows = ["| kind | p50 ms | p95 ms | p99 ms |",
                    "|" + "---|" * 4]
            for kind, lm in sorted(lat.items()):
                rows.append(
                    f"| {kind} | {lm.get('p50', 0):.3f} | "
                    f"{lm.get('p95', 0):.3f} | {lm.get('p99', 0):.3f} |"
                )
            parts.append(
                "Steady-state per-request latency (enqueue → complete, "
                "from the engine's `serve.request.seconds` histograms; "
                "warm-up excluded):\n\n" + "\n".join(rows)
            )
    if os.path.isfile("BENCH_eval.json"):
        r = json.load(open("BENCH_eval.json"))
        parity = ("0 mismatches" if r.get("parity_ok")
                  else f"{r.get('parity_mismatches')} MISMATCHES")
        parts.append(
            f"**Evaluation** (`BENCH_eval.json`, {r.get('arch')}): held-out "
            f"LL through the serving engine at "
            f"{r.get('engine_rows_per_s', 0):.0f} rows/s vs "
            f"{r.get('direct_rows_per_s', 0):.0f} rows/s for the dense "
            f"engine-free loop (x{r.get('engine_vs_direct', 0):.2f}); "
            f"inpainting {r.get('inpaint_requests_per_s', 0):.0f} req/s; "
            f"engine-vs-direct parity {parity}."
        )
    if os.path.isfile("BENCH_train.json"):
        r = json.load(open("BENCH_train.json"))
        rows = ["| arch | batch (microbatches) | compiled ms/step | "
                "per-step ms/step | speedup | launches | segment split "
                "(eager) | grad parity |",
                "|" + "---|" * 8]
        for c in r.get("results", []):
            g = c.get("grouping") or {}
            launches = (f"{g['launches_per_layer']} -> {g['launches_grouped']}"
                        if g else "—")
            seg = c.get("segment_breakdown") or {}
            seg_s = ", ".join(
                f"{k}: {v['launches']}× {v['eager_ms']:.1f} ms"
                for k, v in sorted(seg.items())
            ) or "—"
            rows.append(
                f"| {c['arch']} | {c['batch']} ({c['microbatches']}) | "
                f"{c['fused_ms_per_step']} | {c['per_step_ms_per_step']} | "
                f"x{c['speedup']} | {launches} | {seg_s} | "
                f"{c['grad_parity_max_abs_diff']:.1e} |"
            )
        parts.append(
            "**Training** (`BENCH_train.json`, backend "
            f"{r.get('backend')}): compiled EM step vs the seed's per-step "
            "path; the segment split is one eager forward per arch timed "
            "through the obs `plan.segment` spans (relative per-kind cost, "
            "not compiled absolute time).\n\n" + "\n".join(rows)
        )
        sc = r.get("leaf_scatter")
        if sc:
            parts.append(
                f"**Leaf EM fan-out** (`BENCH_train.json`, {sc.get('arch')} "
                f"at batch {sc.get('batch')}): the leaf-statistic scatter "
                f"(unique-index `.at[flat].set` into (D, K, R, |T|)) costs "
                f"{sc.get('leaf_scatter_ms')} ms of the "
                f"{sc.get('em_statistics_ms')} ms `em_statistics` call "
                f"({100 * sc.get('scatter_fraction', 0):.1f}%) — the ROADMAP "
                "\"fuse or not\" answer: not worth a fused kernel at this "
                "scale."
            )
    if os.path.isfile("BENCH_mixture.json"):
        r = json.load(open("BENCH_mixture.json"))
        cells = r.get("results") or []
        rows = ["| cell | C | batch/component | vmapped ms/step | "
                "looped ms/step | speedup | param parity |",
                "|" + "---|" * 7]
        for c in cells:
            rows.append(
                f"| {c['cell']} | {c['num_components']} | "
                f"{c['per_component_batch']} | {c['vmapped_ms_per_step']} | "
                f"{c['looped_ms_per_step']} | x{c['speedup']} | "
                f"{c['param_parity_max_abs_diff']:.1e} |"
            )
        comp_arch = cells[0].get("component_arch") if cells else "?"
        parts.append(
            "**Mixture training** (`BENCH_mixture.json`, backend "
            f"{r.get('backend')}, component {comp_arch}"
            "): ONE vmapped C-component EM step vs a Python loop of C "
            "single-model steps (identical update; parity is bitwise).\n\n"
            + "\n".join(rows)
        )
    return "\n\n".join(parts) if parts else _MISSING


def _eval_records(root: str):
    """Per-run metrics JSONs under ``root``.  Deliberately NOT imported from
    repro.eval.grids: that would pull jax + the serve/train stack into this
    dependency-light generator, breaking its degrade-gracefully contract on
    hosts without them."""
    records = []
    if not os.path.isdir(root):
        return records
    for run in sorted(os.listdir(root)):
        p = os.path.join(root, run, "metrics.json")
        if os.path.isfile(p):
            records.append(json.load(open(p)))
    return records


def eval_summary(root: str = "artifacts/eval") -> str:
    """The Fig. 4 section: one block per eval-workbench run
    (``repro.launch.eval`` writes ``artifacts/eval/<run>/metrics.json``)."""
    records = _eval_records(root)
    if not records:
        return ("_no eval runs on this host — run "
                "`PYTHONPATH=src python -m repro.launch.eval "
                "--dataset synthetic --smoke` first._")
    parts = []
    for r in records:
        bj = r.get("bpd_joint", {})
        bm = r.get("bpd_marginal", {})
        rows = ["| mask | sample MSE | MPE MSE | mean-fill MSE |",
                "|" + "---|" * 4]
        for mk, m in r.get("inpainting", {}).get("per_mask", {}).items():
            mf = m.get("mean_fill_mse")
            rows.append(
                f"| {mk} | {m.get('conditional_sample_mse', 0):.4f} | "
                f"{m.get('mpe_mse', 0):.4f} | "
                f"{'—' if mf is None else f'{mf:.4f}'} |"
            )
        mix_s = ""
        if r.get("mixture_components"):
            mix_s = (f", mixture of {r['mixture_components']} EiNets over "
                     f"k-means clusters {r.get('cluster_sizes')}")
        parts.append(
            f"**{r.get('run_name')}** — {r.get('dataset')} "
            f"({r.get('dataset_source')}), "
            f"{r.get('height')}x{r.get('width')}x{r.get('channels')}, "
            f"{r.get('num_params', 0):,} params, {r.get('train_steps')} EM "
            f"steps{mix_s}; test bpd {bj.get('bpd', 0):.4f} "
            f"({bj.get('num_rows')} rows at "
            f"{bj.get('engine_rows_per_s', 0):.0f} rows/s through the "
            f"engine), marginal bpd ({bm.get('mask')}) "
            f"{bm.get('bpd', 0):.4f}; engine-vs-direct parity mismatches "
            f"{r.get('parity_mismatches_total')}.\n\n" + "\n".join(rows)
        )
    return "\n\n".join(parts)


def health_summary(root: str = "artifacts/health") -> str:
    """One row per arch from the dry-run numerical-health probe
    (``repro.launch.dryrun --verify`` writes ``artifacts/health/*.json``)."""
    if not os.path.isdir(root):
        return ("_no health probe records on this host — run "
                "`PYTHONPATH=src python -m repro.launch.dryrun --verify` "
                "first._")
    rows = ["| arch | params | probe LL mean | LL min | non-finite | "
            "leaf sat | segment sat (max) |",
            "|" + "---|" * 7]
    for f in sorted(os.listdir(root)):
        if not f.endswith(".json"):
            continue
        rec = json.load(open(os.path.join(root, f)))
        if rec.get("skipped"):
            reason = rec.get("reason", "?")
            rows.append(f"| {rec.get('arch')} | — | — | — | — | — | "
                        f"skipped: {reason} |")
            continue
        seg = rec.get("segment_sat_frac") or [0.0]
        rows.append(
            f"| {rec['arch']} | {rec.get('num_params', 0):,} | "
            f"{rec['ll_mean']:.2f} | {rec['ll_min']:.2f} | "
            f"{rec['ll_nonfinite']} | {rec['leaf_sat_frac']:.3f} | "
            f"{max(seg):.3f} over {len(seg)} segment(s) |"
        )
    return "\n".join(rows)


def bench_history_summary(root: str = "artifacts/bench_history",
                          last: int = 5) -> str:
    """Recent commit-stamped rows per bench kind from the JSONL history
    (``repro.obs.slo.append_history``; read directly so this generator
    stays import-free)."""
    if not os.path.isdir(root):
        return ("_no bench history on this host — any "
                "`python -m benchmarks.bench_*` run appends to it._")
    parts = []
    for fname in sorted(os.listdir(root)):
        if not fname.endswith(".jsonl"):
            continue
        rows = []
        with open(os.path.join(root, fname)) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    rows.append(json.loads(line))
                except json.JSONDecodeError:
                    continue
        if not rows:
            continue
        kind = fname[: -len(".jsonl")]
        md = [f"**{kind}** ({len(rows)} run(s) recorded):", "",
              "| commit | when (UTC) | profile | headline |",
              "|" + "---|" * 4]
        for r in rows[-last:]:
            if kind == "serve":
                head = (f"x{r.get('speedup_vs_jitted', 0):.2f} vs jitted, "
                        f"{r.get('engine_qps', 0):.0f} req/s")
            elif kind == "train":
                cells = r.get("cells") or {}
                head = ", ".join(
                    f"{a}: {c.get('fused_ms') or 0:.1f} ms"
                    for a, c in sorted(cells.items())) or "—"
            elif kind == "mixture":
                cells = r.get("cells") or {}
                head = ", ".join(f"{c}: x{s:.2f}"
                                 for c, s in sorted(cells.items())) or "—"
            else:
                head = f"engine/direct x{r.get('engine_vs_direct') or 0:.2f}"
            md.append(
                f"| {r.get('commit', '?')} | "
                f"{str(r.get('ts', '?'))[:16]} | "
                f"{'smoke' if r.get('smoke') else 'full'} | {head} |")
        parts.append("\n".join(md))
    return "\n\n".join(parts) if parts else (
        "_no bench history on this host — any "
        "`python -m benchmarks.bench_*` run appends to it._")


def verify_summary() -> str:
    """Verifier-coverage row per registered arch.  Needs jax (the circuit
    is built to be verified); degrades to a placeholder without it."""
    try:
        from repro.analysis.verify import verify_config
        from repro.configs import REGISTRY as configs
    except Exception:  # noqa: BLE001 -- dependency-light contract
        return ("_verifier unavailable on this host (requires jax) — run "
                "`PYTHONPATH=src python -m repro.launch.dryrun --verify`._")
    rows = ["| arch | pairs | plan | invariants checked | findings | status |",
            "|" + "---|" * 6]
    for name in sorted(configs):
        try:
            from repro.launch.cells import build_einet

            model = build_einet(configs[name])
            report = verify_config(configs[name])
            s = model.plan.summary()
            plan = (f"{s['fused_groups']} fused + {s['gather_groups']} "
                    f"gather / {s['num_pairs']} pairs")
            rows.append(
                f"| {report.name} | {len(model.pair_specs)} | {plan} | "
                f"{len(report.invariants)} | {len(report.findings)} | "
                f"{'ok' if report.ok else 'FAILED'} |")
        except Exception as e:  # noqa: BLE001 -- a failed build is a row
            rows.append(f"| {name} | — | — | — | — | ERROR: {e!r} |")
    return "\n".join(rows)


def main():
    base = roofline_summary("artifacts/dryrun_baseline", "16x16")
    opt_dir = "artifacts/dryrun_opt" if os.path.isdir("artifacts/dryrun_opt") \
        else "artifacts/dryrun"
    opt = roofline_summary(opt_dir, "16x16")
    single = dryrun_summary(opt_dir, "16x16")
    multi = dryrun_summary("artifacts/dryrun", "2x16x16")
    perf = open("benchmarks/perf_log.md").read()
    header = open("benchmarks/experiments_header.md").read()
    out = header
    out = out.replace("{{VERIFY}}", verify_summary())
    out = out.replace("{{DRYRUN_SINGLE}}", single)
    out = out.replace("{{DRYRUN_MULTI}}", multi)
    out = out.replace("{{ROOFLINE_BASELINE}}", base)
    out = out.replace("{{ROOFLINE_OPT}}", opt)
    out = out.replace("{{HEALTH}}", health_summary())
    out = out.replace("{{BENCHES}}", bench_summary())
    out = out.replace("{{BENCH_HISTORY}}", bench_history_summary())
    out = out.replace("{{EVAL}}", eval_summary())
    out = out.replace("{{PERF_LOG}}", perf)
    with open("EXPERIMENTS.md", "w") as f:
        f.write(out)
    print("wrote EXPERIMENTS.md", len(out), "bytes")


if __name__ == "__main__":
    main()
