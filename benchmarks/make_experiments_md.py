"""Assemble EXPERIMENTS.md from the dry-run artifacts + the hand-written
§Perf iteration log (kept in benchmarks/perf_log.md).

PYTHONPATH=src:. python -m benchmarks.make_experiments_md
"""

import json
import os

from benchmarks import roofline


def dryrun_summary(art_dir: str, mesh: str) -> str:
    rows = []
    ok = skip = 0
    for f in sorted(os.listdir(art_dir)):
        if not f.endswith(".json"):
            continue
        rec = json.load(open(os.path.join(art_dir, f)))
        if rec.get("mesh") not in (mesh, None) and "skipped" not in rec:
            continue
        if "skipped" in rec:
            skip += 1
            continue
        if "error" in rec:
            rows.append(f"| {rec['arch']} | {rec.get('shape')} | ERROR |")
            continue
        ok += 1
        mem = rec["memory"]
        rows.append(
            f"| {rec['arch']} | {rec['shape']} | {rec['kind']} | "
            f"{rec['flops_per_device']:.2e} | "
            f"{rec['collective_bytes_per_device']:.2e} | "
            f"{(mem['argument_bytes'])/1e9:.1f} | "
            f"{(mem['temp_bytes'])/1e9:.1f} | {rec['compile_s']:.0f} |"
        )
    hdr = ("| arch | shape | kind | FLOPs/dev | coll B/dev | args GB | "
           "temp GB | compile s |\n|" + "---|" * 8)
    return (f"{ok} cells compiled, {skip} documented skips.\n\n" + hdr + "\n"
            + "\n".join(rows))


def main():
    base = roofline.to_markdown(roofline.build_table("artifacts/dryrun_baseline", "16x16"))
    opt_dir = "artifacts/dryrun_opt" if os.path.isdir("artifacts/dryrun_opt") \
        else "artifacts/dryrun"
    opt = roofline.to_markdown(roofline.build_table(opt_dir, "16x16"))
    single = dryrun_summary(opt_dir, "16x16")
    multi = dryrun_summary("artifacts/dryrun", "2x16x16") if any(
        "2x16x16" in f or True for f in os.listdir("artifacts/dryrun")) else ""
    multi = dryrun_summary("artifacts/dryrun", "2x16x16")
    perf = open("benchmarks/perf_log.md").read()
    header = open("benchmarks/experiments_header.md").read()
    out = header
    out = out.replace("{{DRYRUN_SINGLE}}", single)
    out = out.replace("{{DRYRUN_MULTI}}", multi)
    out = out.replace("{{ROOFLINE_BASELINE}}", base)
    out = out.replace("{{ROOFLINE_OPT}}", opt)
    out = out.replace("{{PERF_LOG}}", perf)
    with open("EXPERIMENTS.md", "w") as f:
        f.write(out)
    print("wrote EXPERIMENTS.md", len(out), "bytes")


if __name__ == "__main__":
    main()
