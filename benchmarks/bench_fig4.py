"""Fig. 4: EiNets as generative image models + tractable inpainting.

SVHN/CelebA are not downloadable (DESIGN.md §6); a structured Gaussian-mixture
image proxy of the same shape (32x32 RGB by default) stands in.  The protocol
follows §4.2: PD structure with vertical splits (Delta splits), factorized
Gaussian leaves over channels, stochastic EM (lambda=0.5), variance projected
to [1e-6, 1e-2] via the EF's project_phi.

Outputs (artifacts/fig4/):
  samples.npy        -- unconditional samples
  inpainted.npy      -- left-half evidence, right half sampled from p(.|e)
  originals.npy
CSV to stdout: metric,value -- train LL trajectory + inpainting MSE vs a
mean-imputation baseline (the tractability payoff must beat it).
"""

from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import EiNet, Normal, poon_domingos
from repro.core.em import EMConfig, stochastic_em_update
from repro.data.synthetic import gaussian_mixture_images


def run(quick: bool = False, out_dir: str = "artifacts/fig4"):
    h = w = 12 if quick else 24
    c = 3
    n_train = 600 if quick else 3000
    epochs = 3 if quick else 8
    data = gaussian_mixture_images(n_train + 64, h, w, c, seed=0)
    train, test = data[:n_train], data[n_train:]
    g = poon_domingos(h, w, delta=max(2, h // 4), num_channels=c, axes=("w",))
    net = EiNet(g, num_sums=8 if quick else 16,
                exponential_family=Normal(min_var=1e-6, max_var=1e-2))
    params = net.init(jax.random.PRNGKey(0))
    step = jax.jit(lambda p, b: stochastic_em_update(
        net, p, b, EMConfig(step_size=0.5)))
    bs = 128
    lls = []
    t0 = time.time()
    for ep in range(epochs):
        perm = np.random.RandomState(ep).permutation(n_train)
        for i in range(0, n_train - bs + 1, bs):
            batch = jnp.asarray(train[perm[i: i + bs]])
            params, ll = step(params, batch)
        lls.append(float(ll))
    train_time = time.time() - t0

    # unconditional samples
    samples = np.asarray(net.sample(params, jax.random.PRNGKey(1), 16))
    # inpainting: observe the left half, sample the right half
    xt = jnp.asarray(test[:16])
    mask = np.zeros((16, h, w, c), bool)
    mask[:, :, : w // 2, :] = True
    mask = jnp.asarray(mask.reshape(16, -1))
    inpainted = np.asarray(
        net.conditional_sample(params, jax.random.PRNGKey(2), xt, mask)
    )
    # MSE metric uses the MPE-style argmax decode (a sample adds the model's
    # own output variance, which is not an error of the conditional)
    recon = np.asarray(
        net.conditional_sample(params, jax.random.PRNGKey(3), xt, mask,
                               mode="argmax")
    )
    # baseline: fill missing with the training mean
    mean_fill = np.where(np.asarray(mask), np.asarray(xt),
                         train.mean(0, keepdims=True))
    m = ~np.asarray(mask)
    mse_einet = float(np.mean((recon - np.asarray(xt))[m] ** 2))
    mse_mean = float(np.mean((mean_fill - np.asarray(xt))[m] ** 2))

    os.makedirs(out_dir, exist_ok=True)
    np.save(os.path.join(out_dir, "samples.npy"), samples.reshape(16, h, w, c))
    np.save(os.path.join(out_dir, "inpainted.npy"),
            inpainted.reshape(16, h, w, c))
    np.save(os.path.join(out_dir, "originals.npy"),
            np.asarray(xt).reshape(16, h, w, c))
    return {
        "ll_first_epoch": lls[0],
        "ll_last_epoch": lls[-1],
        "train_s": train_time,
        "inpaint_mse": mse_einet,
        "meanfill_mse": mse_mean,
        "samples_finite": bool(np.isfinite(samples).all()),
    }


def main(quick: bool = False):
    r = run(quick)
    print("metric,value")
    for k, v in r.items():
        print(f"{k},{v}")
    ok = r["ll_last_epoch"] > r["ll_first_epoch"] and \
        r["inpaint_mse"] < r["meanfill_mse"]
    print(f"# EM learns + inpainting beats mean-fill: {ok}")
    return r


if __name__ == "__main__":
    main()
