"""Benchmark harness: one benchmark per paper table/figure (+ the roofline).

``PYTHONPATH=src python -m benchmarks.run [--full]``

Defaults to the quick profile (CPU-friendly); --full runs the paper-sized
sweeps.  Output: CSV blocks per benchmark, identical schema either way.
"""

from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="table1|fig3|fig6|fig4|roofline")
    args = ap.parse_args()
    quick = not args.full
    t0 = time.time()

    def banner(name):
        print(f"\n===== {name} =====", flush=True)

    ok = True
    if args.only in (None, "table1"):
        banner("Table 1: LL parity einsum vs naive + EM improvement")
        from benchmarks import bench_table1

        ok &= bool(bench_table1.main(quick=quick))
    if args.only in (None, "fig3"):
        banner("Fig 3: train time / peak memory vs K, D, R")
        from benchmarks import bench_fig3

        bench_fig3.main(quick=quick)
    if args.only in (None, "fig6"):
        banner("Fig 6: inference time vs K, D, R")
        from benchmarks import bench_fig6

        bench_fig6.main(quick=quick)
    if args.only in (None, "fig4"):
        banner("Fig 4: generative image model + inpainting")
        from benchmarks import bench_fig4

        bench_fig4.main(quick=quick)
    if args.only in (None, "roofline"):
        banner("Roofline table (from dry-run artifacts, 16x16 mesh)")
        import os

        from benchmarks import roofline

        if os.path.isdir("artifacts/dryrun"):
            rows = roofline.build_table("artifacts/dryrun", "16x16")
            print(roofline.to_markdown(rows))
        else:
            print("no artifacts/dryrun: run repro.launch.dryrun first")
    print(f"\n# benchmarks done in {time.time()-t0:.1f}s; all-ok={ok}")
    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    main()
