"""Table 1: EiNet vs the naive (LibSPN/SPFlow-style) implementation.

The paper's Table 1 shows EiNets reproduce RAT-SPN test log-likelihoods on
the 20 binary datasets.  The datasets are not downloadable here (DESIGN.md
§6), so this benchmark checks the *implementation claim* on identically-sized
synthetic proxies:

  1. LL parity: the einsum layers and the naive log-sum-exp layers compute the
     same circuit -- max |dLL| must be float-level on every dataset;
  2. EM trains: test LL after 10 EM epochs beats the epoch-0 model on every
     dataset.

CSV: name,num_vars,ll_einsum,ll_naive,max_abs_diff,ll_after_em
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    Bernoulli,
    EiNet,
    NaiveEiNet,
    em_update,
    random_binary_trees,
)
from repro.data.synthetic import TWENTY_DATASETS, binary_dataset

# keep CPU runtime bounded: every dataset, subsampled var-count cap
MAX_VARS = 200
N_TRAIN, N_TEST = 400, 200


def run(quick: bool = False):
    rows = []
    datasets = TWENTY_DATASETS[:6] if quick else TWENTY_DATASETS
    for name, dims in datasets:
        d = min(dims, MAX_VARS)
        data = binary_dataset(name, N_TRAIN + N_TEST)[:, :d]
        train = jnp.asarray(data[:N_TRAIN])
        test = jnp.asarray(data[N_TRAIN:])
        depth = min(3, int(np.log2(d)))
        g = random_binary_trees(d, depth, 4, seed=0)
        net = EiNet(g, num_sums=8, exponential_family=Bernoulli())
        naive = NaiveEiNet(g, num_sums=8, exponential_family=Bernoulli())
        params = net.init(jax.random.PRNGKey(0))
        ll_e = np.asarray(net.log_likelihood(params, test))
        ll_n = np.asarray(naive.log_likelihood(params, test))
        diff = float(np.max(np.abs(ll_e - ll_n)))
        p = params
        for _ in range(3 if quick else 10):
            p, _ = em_update(net, p, train)
        ll_after = float(np.mean(np.asarray(net.log_likelihood(p, test))))
        rows.append((name, d, float(ll_e.mean()), float(ll_n.mean()), diff,
                     ll_after))
    return rows


def main(quick: bool = False):
    t0 = time.time()
    rows = run(quick)
    print("name,num_vars,ll_einsum,ll_naive,max_abs_diff,ll_after_em")
    ok = True
    for r in rows:
        print(",".join(str(x) for x in r))
        ok &= r[4] < 1e-3 and r[5] > r[2]
    print(f"# parity+improvement on all datasets: {ok}; {time.time()-t0:.1f}s")
    return ok


if __name__ == "__main__":
    main()
