"""Mixture-of-Experts layer: top-k routing with two dispatch strategies.

``gather`` (default): sort-based dropless-style dispatch.  Token->expert
assignments are sorted, ranked within expert via a cumulative count, and
scattered into a per-group (E, C, D) buffer; expert FFNs run as one batched
einsum over the expert axis (MXU-friendly, EP-shardable); results gather back
with the router weights.  No (T x E x C) one-hot tensor is ever materialized
-- at kimi-k2 scale (E=384) the classic GShard dispatch einsum would cost
O(T^2 * topk * d) redundant FLOPs and a ~10^13-element dispatch tensor, which
is why the gather path is the baseline here (recorded in DESIGN.md).

``dense`` (reference): the GShard/Switch one-hot dispatch-einsum formulation,
kept for small expert counts as a cross-check oracle and for the §Perf
comparison.

Capacity: C = ceil(T * topk / E * capacity_factor); overflow tokens are
dropped (classic capacity-style MoE).  An auxiliary load-balancing loss
(Switch-style) is returned alongside.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import constraint


def router(x: jax.Array, w_router: jax.Array, top_k: int):
    """x: (T, D); w_router: (D, E).  Returns (weights (T,k), experts (T,k), aux)."""
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), w_router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    weights, experts = jax.lax.top_k(probs, top_k)  # (T, k)
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
    # Switch-style load balance loss: E * sum_e f_e * p_e
    e = w_router.shape[1]
    me = jnp.mean(probs, axis=0)  # (E,)
    ce = jnp.mean(
        jax.nn.one_hot(experts[:, 0], e, dtype=jnp.float32), axis=0
    )
    aux = e * jnp.sum(me * ce)
    return weights, experts, aux


def moe_ffn_gather(
    x: jax.Array,
    w_router: jax.Array,
    w_gate: jax.Array,
    w_up: jax.Array,
    w_down: jax.Array,
    top_k: int,
    capacity_factor: float = 1.25,
) -> Tuple[jax.Array, jax.Array]:
    """Sort-based MoE.  x: (T, D); expert weights (E, D, F) / (E, F, D).

    Returns (out (T, D), aux_loss scalar).
    """
    t, d = x.shape
    e, _, f = w_gate.shape
    weights, experts, aux = router(x, w_router, top_k)
    capacity = int(max(1, -(-t * top_k // e) * capacity_factor))
    stok, sw, se, rank, keep = _expert_slots(
        experts, weights, t, top_k, e, capacity
    )
    slot = se * capacity + jnp.where(keep, rank, 0)  # dropped -> slot 0 w/ 0 weight
    buf_idx = jnp.where(keep, slot, e * capacity)  # trash row

    # dispatch: (E*C+1, D) scatter of token activations
    xb = jnp.zeros((e * capacity + 1, d), x.dtype).at[buf_idx].set(x[stok])
    xb = xb[:-1].reshape(e, capacity, d)
    # pin the buffer to the EP layout *here*: the scatter from token space to
    # expert space is the all-to-all; without this constraint GSPMD leaves E
    # replicated and moves group-sized buffers instead (§Perf iteration log)
    xb = constraint(xb, ("expert", None, None))
    # expert FFN (swiglu), batched over E -- MXU einsum, EP-shardable
    g = jnp.einsum("ecd,edf->ecf", xb, w_gate)
    u = jnp.einsum("ecd,edf->ecf", xb, w_up)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    h = constraint(h, ("expert", None, "expert_mlp"))
    yb = jnp.einsum("ecf,efd->ecd", h, w_down)
    yb = constraint(yb, ("expert", None, None)).reshape(e * capacity, d)
    yb = jnp.concatenate([yb, jnp.zeros((1, d), yb.dtype)], axis=0)
    # combine: weighted gather-scatter back to tokens
    contrib = yb[buf_idx] * jnp.where(keep, sw, 0.0)[:, None].astype(yb.dtype)
    out = jnp.zeros((t, d), x.dtype).at[stok].add(contrib)
    return out, aux


def _expert_slots(experts, weights, t, top_k, e, capacity):
    """Shared sort-based slot assignment.  Returns (stok, sw, se, rank, keep)
    sorted by expert id; rank is the position within the expert's capacity."""
    flat_e = experts.reshape(-1)
    flat_w = weights.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(t), top_k)
    order = jnp.argsort(flat_e)
    se, stok, sw = flat_e[order], flat_tok[order], flat_w[order]
    counts = jnp.zeros((e,), jnp.int32).at[flat_e].add(1)
    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(t * top_k, dtype=jnp.int32) - offsets[se]
    keep = rank < capacity
    return stok, sw, se, rank, keep


def moe_ffn_shard_map(
    x: jax.Array,
    w_router: jax.Array,
    w_gate: jax.Array,
    w_up: jax.Array,
    w_down: jax.Array,
    top_k: int,
    capacity_factor: float,
    dp_axes,
    ep_axis: str,
    fsdp_axes,
) -> Tuple[jax.Array, jax.Array]:
    """Expert parallelism with explicit all-to-alls (the production path).

    GSPMD cannot partition a data-dependent scatter across the expert axis --
    it falls back to replicating group-sized buffers (measured: 18.8 GB
    all-gathers per MoE layer at kimi-k2 scale, EXPERIMENTS.md §Perf).  This
    path hand-rolls the canonical EP schedule inside shard_map:

      local routing -> local scatter into per-destination send buffer
      -> all_to_all(model) -> local expert FFN (weights all-gathered over the
      fsdp axis if sharded there) -> all_to_all(model) back -> local combine.

    Per-device exchanged bytes are the true MoE volume
    T_local * topk * cf * d_model * 2 per direction -- ~30x less than what the
    scatter lowering moved.

    x: (B, S, D) GLOBAL array (inside jit); weights as in moe_ffn_gather.

    Token partitioning: the sequence dim is sharded over the EP (model) axis
    whenever it divides -- each of the dp x ep shards routes its own
    B/dp x S/ep token slab (this also lines up with the SP residual layout,
    so no resharding on entry).  When S doesn't divide (decode steps), tokens
    are replicated over EP and the dispatch is redundant ep-fold -- harmless
    for 1-token steps, and recorded in the roofline notes.
    """
    e = w_gate.shape[0]
    f = w_gate.shape[2]
    d = x.shape[-1]
    mesh = jax.sharding.get_abstract_mesh()
    ep_size = dict(mesh.shape)[ep_axis]
    shard_seq = x.shape[1] % ep_size == 0 and x.shape[1] >= ep_size

    def body(x_l, r_l, wg_l, wu_l, wd_l):
        e_loc = wg_l.shape[0]
        ep = e // e_loc
        if fsdp_axes and wg_l.shape[1] != d:
            wg_l = jax.lax.all_gather(wg_l, fsdp_axes, axis=1, tiled=True)
            wu_l = jax.lax.all_gather(wu_l, fsdp_axes, axis=1, tiled=True)
            wd_l = jax.lax.all_gather(wd_l, fsdp_axes, axis=2, tiled=True)
        b_l, s_l = x_l.shape[0], x_l.shape[1]
        t = b_l * s_l
        xt = x_l.reshape(t, d)
        weights, experts, aux = router(xt, r_l, top_k)
        cap = int(max(1, -(-t * top_k // e) * capacity_factor))
        stok, sw, se, rank, keep = _expert_slots(experts, weights, t, top_k, e, cap)
        dst = se // e_loc
        slot = (se % e_loc) * cap + rank  # slot within the destination shard
        c_dst = e_loc * cap
        buf_idx = jnp.where(keep, dst * c_dst + slot, ep * c_dst)
        send = jnp.zeros((ep * c_dst + 1, d), x_l.dtype).at[buf_idx].set(xt[stok])
        send = send[:-1].reshape(ep, c_dst, d)
        recv = jax.lax.all_to_all(send, ep_axis, split_axis=0, concat_axis=0,
                                  tiled=False)
        # (ep_src, e_loc, cap, D) -> (e_loc, ep_src * cap, D)
        xb = recv.reshape(ep, e_loc, cap, d).swapaxes(0, 1).reshape(
            e_loc, ep * cap, d)
        g = jnp.einsum("ecd,edf->ecf", xb, wg_l)
        u = jnp.einsum("ecd,edf->ecf", xb, wu_l)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x_l.dtype) * u
        yb = jnp.einsum("ecf,efd->ecd", h, wd_l)
        back = yb.reshape(e_loc, ep, cap, d).swapaxes(0, 1).reshape(ep, c_dst, d)
        ret = jax.lax.all_to_all(back, ep_axis, split_axis=0, concat_axis=0,
                                 tiled=False)
        ret = jnp.concatenate(
            [ret.reshape(ep * c_dst, d), jnp.zeros((1, d), ret.dtype)], 0)
        contrib = ret[buf_idx] * jnp.where(keep, sw, 0.0)[:, None].astype(ret.dtype)
        out = jnp.zeros((t, d), x_l.dtype).at[stok].add(contrib)
        # aux is a local mean over this dp shard's tokens; average over dp
        if dp_axes:
            aux = jax.lax.pmean(aux, dp_axes)
        aux = jax.lax.pmean(aux, ep_axis)
        return out.reshape(b_l, s_l, d), aux

    from jax.sharding import PartitionSpec as P

    dp = dp_axes if dp_axes else None
    w_fsdp = fsdp_axes if fsdp_axes else None
    seq = ep_axis if shard_seq else None
    in_specs = (
        P(dp, seq, None),               # x: batch over dp, seq over ep (SP)
        P(None, None),                  # router: replicated
        P(ep_axis, w_fsdp, None),       # wg (E, D, F)
        P(ep_axis, w_fsdp, None),       # wu
        P(ep_axis, None, w_fsdp),       # wd (E, F, D)
    )
    out_specs = (P(dp, seq, None), P())
    fn = jax.shard_map(body, in_specs=in_specs, out_specs=out_specs,
                       check_vma=False)
    return fn(x, w_router, w_gate, w_up, w_down)


def moe_ffn_dense(
    x: jax.Array,
    w_router: jax.Array,
    w_gate: jax.Array,
    w_up: jax.Array,
    w_down: jax.Array,
    top_k: int,
    capacity_factor: float = 1.25,
) -> Tuple[jax.Array, jax.Array]:
    """GShard-style one-hot dispatch einsum (reference; small E only)."""
    t, d = x.shape
    e, _, f = w_gate.shape
    weights, experts, aux = router(x, w_router, top_k)
    capacity = int(max(1, -(-t * top_k // e) * capacity_factor))
    oh = jax.nn.one_hot(experts, e, dtype=jnp.int32)  # (T, k, E)
    pos = jnp.cumsum(oh.reshape(t * top_k, e), axis=0).reshape(t, top_k, e) - 1
    pos = jnp.sum(pos * oh, axis=-1)  # (T, k) position in expert
    keep = pos < capacity
    disp = (
        jax.nn.one_hot(experts, e, dtype=x.dtype)[:, :, :, None]
        * jax.nn.one_hot(jnp.where(keep, pos, 0), capacity, dtype=x.dtype)[:, :, None, :]
        * keep[:, :, None, None]
    )  # (T, k, E, C)
    comb = disp * weights[:, :, None, None].astype(x.dtype)
    xb = jnp.einsum("tkec,td->ecd", disp, x)
    g = jnp.einsum("ecd,edf->ecf", xb, w_gate)
    u = jnp.einsum("ecd,edf->ecf", xb, w_up)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    yb = jnp.einsum("ecf,efd->ecd", h, w_down)
    out = jnp.einsum("tkec,ecd->td", comb, yb)
    return out, aux
