"""LM substrate: block-pattern decoder models (attn/mamba/mLSTM/sLSTM x
dense/MoE) with train / prefill / decode entry points."""

from repro.models import attention, common, lm, mamba, moe, xlstm

__all__ = ["attention", "common", "lm", "mamba", "moe", "xlstm"]
