"""xLSTM cells: mLSTM (matrix memory) and sLSTM (scalar memory).

Both use the stabilized exponential gating of Beck et al. (2024):

    m_t = max(logf_t + m_{t-1}, logi_t)
    f'  = exp(logf + m_{t-1} - m_t),   i' = exp(logi - m_t)

mLSTM state: per-head matrix C (dv x dk) + normalizer n (dk) -- a gated
linear-attention recurrence, O(1) per decode token.  sLSTM state: scalar
cells with block-diagonal (per-head) recurrent connections -- strictly
sequential by construction (the paper's point: it cannot be parallelized, so
we lower it as a chunked lax.scan and accept the serial latency; see
DESIGN.md §8 for the production note).

Chunking: outer scan over sequence chunks with a rematerialized inner scan,
so the backward pass stores only per-chunk carries (required at 4k train /
500k decode shapes).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def scan_chunked(step_fn, carry, xs, chunk: int, length: int):
    """lax.scan over time in rematerialized chunks.  xs pytree: (L, ...).

    Length is padded up to a chunk multiple; padded steps are masked so they
    neither touch the carry nor appear in the outputs.
    """
    chunk = min(chunk, length)
    pad = (-length) % chunk
    valid = jnp.arange(length + pad) < length
    if pad:
        xs = jax.tree_util.tree_map(
            lambda a: jnp.pad(a, ((0, pad),) + ((0, 0),) * (a.ndim - 1)), xs
        )
    nchunks = (length + pad) // chunk
    xs_c = jax.tree_util.tree_map(
        lambda a: a.reshape((nchunks, chunk) + a.shape[1:]), xs
    )
    valid_c = valid.reshape(nchunks, chunk)

    def masked_step(c, x_and_valid):
        x, ok = x_and_valid
        c_new, y = step_fn(c, x)
        c_out = jax.tree_util.tree_map(
            lambda new, old: jnp.where(ok, new, old), c_new, c
        )
        return c_out, y

    @jax.checkpoint
    def chunk_body(c, args):
        return jax.lax.scan(masked_step, c, args)

    carry, ys = jax.lax.scan(chunk_body, carry, (xs_c, valid_c))
    ys = jax.tree_util.tree_map(
        lambda a: a.reshape((nchunks * chunk,) + a.shape[2:])[:length], ys
    )
    return carry, ys


# ---------------------------------------------------------------- mLSTM cell
def mlstm_step(carry, inp):
    """carry: (C (B,H,dv,dk), n (B,H,dk), m (B,H)).
    inp: dict q, k, v (B,H,dh), li, lf (B,H) log-gates."""
    c, n, m = carry
    q, k, v, li, lf = inp["q"], inp["k"], inp["v"], inp["li"], inp["lf"]
    m_new = jnp.maximum(lf + m, li)
    fp = jnp.exp(lf + m - m_new)[..., None]  # (B,H,1)
    ip = jnp.exp(li - m_new)[..., None]
    c_new = fp[..., None] * c + ip[..., None] * jnp.einsum("bhv,bhk->bhvk", v, k)
    n_new = fp * n + ip * k
    num = jnp.einsum("bhvk,bhk->bhv", c_new, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n_new, q)), 1.0)
    h = num / den[..., None]
    return (c_new, n_new, m_new), h


def mlstm_sequence(q, k, v, li, lf, carry=None, chunk: int = 64):
    """q,k,v: (B, L, H, dh) f32; li, lf: (B, L, H).  Returns (y (B,L,H,dh), carry)."""
    bsz, l, h, dh = q.shape
    if carry is None:
        carry = (
            jnp.zeros((bsz, h, dh, dh), jnp.float32),
            jnp.zeros((bsz, h, dh), jnp.float32),
            jnp.full((bsz, h), -1e30, jnp.float32),
        )
    xs = {
        "q": q.swapaxes(0, 1),
        "k": k.swapaxes(0, 1),
        "v": v.swapaxes(0, 1),
        "li": li.swapaxes(0, 1),
        "lf": lf.swapaxes(0, 1),
    }
    carry, ys = scan_chunked(mlstm_step, carry, xs, chunk, l)
    return ys.swapaxes(0, 1), carry


def mlstm_sequence_chunked(q, k, v, li, lf, chunk: int = 128):
    """Chunkwise-parallel mLSTM (GLA/SSD-style), exact same function as the
    recurrent form but O(S/c) state materializations instead of O(S).

    Derivation: with b_t = sum_{r<=t} log f_r (cumulative log-forget) and the
    running stabilizer m_t = max_{s<=t}(log i_s + b_t - b_s),

        C_t = sum_s exp(log i_s + b_t - b_s - m_t) v_s k_s^T
        n_t = sum_s exp(log i_s + b_t - b_s - m_t) k_s

    so within a chunk the contribution splits into an intra-chunk masked
    (c x c) score matrix (an MXU matmul) plus one inter-chunk term through the
    stabilized boundary state (S = C~ exp(-m_state), n~, m_state).  The state
    is updated ONCE per chunk -- this removes the 100+TB/device HBM traffic of
    the per-step matrix-state writes (EXPERIMENTS.md §Perf, xlstm hillclimb).

    q,k,v: (B, L, H, dh) f32; li, lf: (B, L, H) log-gates.
    Returns (y (B, L, H, dh), carry (C, n, m)) -- carry matches mlstm_step's.
    """
    bsz, l, h, dh = q.shape
    chunk = min(chunk, l)
    pad = (-l) % chunk
    if pad:
        padt = lambda a: jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))
        q, k, v = padt(q), padt(k), padt(v)
        li = jnp.pad(li, ((0, 0), (0, pad), (0, 0)),
                     constant_values=-1e30)  # i=0
        lf = jnp.pad(lf, ((0, 0), (0, pad), (0, 0)))  # f=1: carry intact
    lp = q.shape[1]
    nc = lp // chunk

    def to_chunks(a):
        return a.reshape(bsz, nc, chunk, *a.shape[2:]).swapaxes(0, 1)

    qc, kc, vc = to_chunks(q), to_chunks(k), to_chunks(v)
    lic, lfc = to_chunks(li), to_chunks(lf)

    def chunk_step(carry, args):
        s_state, n_state, m_state = carry  # (B,H,dh,dh), (B,H,dh), (B,H)
        qb, kb, vb, lib, lfb = args  # (B, c, H, ...)
        b_cum = jnp.cumsum(lfb, axis=1)  # (B, c, H)
        # intra-chunk scores a[t,u] = li_u + b_t - b_u   (u <= t)
        a = (
            lib[:, None, :, :] + b_cum[:, :, None, :] - b_cum[:, None, :, :]
        )  # (B, t, u, H)
        tril = jnp.tril(jnp.ones((chunk, chunk), bool))
        a = jnp.where(tril[None, :, :, None], a, -1e30)
        # stabilizer: m_t = max(m_state + b_t, max_u a[t,u])
        m_t = jnp.maximum(m_state[:, None] + b_cum, jnp.max(a, axis=2))
        m_t = jnp.maximum(m_t, -1e30)  # guard all -inf rows
        gates = jnp.exp(a - m_t[:, :, None, :])  # (B, t, u, H)
        inter = jnp.exp(b_cum + m_state[:, None] - m_t)  # (B, t, H)
        qk = jnp.einsum("bthd,buhd->btuh", qb, kb)  # (B, t, u, H)
        num = jnp.einsum("btuh,buhd->bthd", gates * qk, vb)
        num = num + inter[..., None] * jnp.einsum("bhvk,bthk->bthv", s_state, qb)
        den = jnp.einsum("btuh,buhd->bthd", gates, kb)
        den = den + inter[..., None] * n_state[:, None]
        dq = jnp.einsum("bthd,bthd->bth", den, qb)
        y = num / jnp.maximum(jnp.abs(dq), 1.0)[..., None]
        # boundary state update (once per chunk)
        b_end = b_cum[:, -1]  # (B, H)
        m_new = m_t[:, -1]
        w_state = jnp.exp(b_end + m_state - m_new)  # (B, H)
        w_in = jnp.exp(
            lib + b_end[:, None] - b_cum - m_new[:, None]
        )  # (B, c, H)
        s_new = (
            w_state[:, :, None, None] * s_state
            + jnp.einsum("buh,buhv,buhk->bhvk", w_in, vb, kb)
        )
        n_new = w_state[..., None] * n_state + jnp.einsum(
            "buh,buhk->bhk", w_in, kb
        )
        return (s_new, n_new, m_new), y

    carry0 = (
        jnp.zeros((bsz, h, dh, dh), jnp.float32),
        jnp.zeros((bsz, h, dh), jnp.float32),
        jnp.full((bsz, h), -1e30, jnp.float32),
    )
    chunk_step = jax.checkpoint(chunk_step)
    carry, ys = jax.lax.scan(chunk_step, carry0, (qc, kc, vc, lic, lfc))
    y = ys.swapaxes(0, 1).reshape(bsz, lp, h, dh)[:, :l]
    return y, carry


# ---------------------------------------------------------------- sLSTM cell
def slstm_step_factory(r_blocks):
    """r_blocks: dict of (H, dh, dh) recurrent mats for gates i, f, z, o."""

    def step(carry, inp):
        c, n, m, h = carry  # each (B, H, dh) except m (B, H, dh)
        def rec(name):
            return inp[name] + jnp.einsum("bhd,hde->bhe", h, r_blocks[name])

        li = rec("i")
        lf = rec("f")
        z = jnp.tanh(rec("z"))
        o = jax.nn.sigmoid(rec("o"))
        m_new = jnp.maximum(lf + m, li)
        fp = jnp.exp(lf + m - m_new)
        ip = jnp.exp(li - m_new)
        c_new = fp * c + ip * z
        n_new = fp * n + ip
        h_new = o * c_new / jnp.maximum(n_new, 1e-6)
        return (c_new, n_new, m_new, h_new), h_new

    return step


def slstm_sequence(wx, r_blocks, carry=None, chunk: int = 64):
    """wx: dict i/f/z/o -> (B, L, H, dh) input projections (W x + b).

    Returns (y (B, L, H, dh), carry)."""
    bsz, l, h, dh = wx["i"].shape
    if carry is None:
        carry = tuple(
            jnp.zeros((bsz, h, dh), jnp.float32) if i != 2
            else jnp.full((bsz, h, dh), -1e30, jnp.float32)
            for i in range(4)
        )
    xs = {k: v.swapaxes(0, 1) for k, v in wx.items()}
    step = slstm_step_factory(r_blocks)
    carry, ys = scan_chunked(step, carry, xs, chunk, l)
    return ys.swapaxes(0, 1), carry
