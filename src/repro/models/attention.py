"""GQA attention with chunked (flash-style) XLA lowering + Pallas TPU path.

Training / prefill use ``chunked_attention``: a two-level lax scan over query
and key/value tiles with the online-softmax recurrence, so peak activation
memory is O(S * tile) instead of O(S^2) -- required for the 32k-prefill dry-run
cells to fit HBM.  On TPU the same tiles are served by the fused Pallas kernel
(``repro.kernels.flash_attention``); both paths share the ``ref.mha_ref``
oracle.

Sharding note (found via the dry-run iteration log, EXPERIMENTS.md §Perf):
keeping a separate (kv_heads, group) split makes GSPMD reshard through
{kv x group} tilings that don't divide the model axis, triggering involuntary
full rematerialization (replication!) inside the scan body.  The baseline
therefore *repeats* K/V to the full query-head count -- every attention tensor
then carries the (batch, heads, ...) layout whose heads dim shards cleanly
over the model axis.  The repeat costs group x more KV activation bytes but
zero extra HBM-resident cache (the cache stays at kv_heads; the repeat happens
tile-by-tile inside the scan and fuses).

Decode uses a single-query path against a preallocated KV cache with length
masking (one dynamic_update_slice per step).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.dist.sharding import constraint

NEG_INF = -1e30


def chunked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
    kv_len: Optional[jax.Array] = None,
) -> jax.Array:
    """Online-softmax attention over tiles.

    q: (B, Hq, Sq, Dh), k/v: (B, Hkv, Sk, Dh); GQA KV heads are repeated to
    Hq (see module docstring).  Returns (B, Hq, Sq, Dh) in q.dtype.
    """
    b, hq, sq, dh = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    group = hq // hkv
    scale = dh**-0.5
    if group > 1:
        k = jnp.repeat(k, group, axis=1)
        v = jnp.repeat(v, group, axis=1)
    q_offset = sk - sq

    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, sk)
    pad_q = (-sq) % q_chunk
    pad_kv = (-sk) % kv_chunk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_kv), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_kv), (0, 0)))
    sqp, skp = q.shape[2], k.shape[2]
    nq, nk = sqp // q_chunk, skp // kv_chunk
    if kv_len is None:
        kv_len = jnp.asarray(sk, jnp.int32)

    # (nq, B, H, qc, Dh) / (nk, B, H, kc, Dh): scan-major tiles, pinned to the
    # (dp, tp) layout so the loop slices never leave their shards
    tile_spec = (None, "batch", "heads", None, None)
    qt = constraint(jnp.moveaxis(q.reshape(b, hq, nq, q_chunk, dh), 2, 0), tile_spec)
    kt = constraint(jnp.moveaxis(k.reshape(b, hq, nk, kv_chunk, dh), 2, 0), tile_spec)
    vt = constraint(jnp.moveaxis(v.reshape(b, hq, nk, kv_chunk, dh), 2, 0), tile_spec)

    def q_block(args):
        qi, qc = args  # qc: (B, H, q_chunk, Dh)
        rows = qi * q_chunk + jnp.arange(q_chunk)[:, None] + q_offset

        def kv_step(carry, args2):
            m, l, acc = carry
            ki, kc, vc = args2
            cols = ki * kv_chunk + jnp.arange(kv_chunk)[None, :]
            # bf16 inputs, f32 accumulation: full MXU rate, f32-safe softmax
            s = jnp.einsum(
                "bhqd,bhkd->bhqk", qc, kc,
                preferred_element_type=jnp.float32,
            ) * scale
            valid = cols < kv_len
            if causal:
                valid = valid & (cols <= rows)
            s = jnp.where(valid[None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
            p = jnp.exp(s - m_new)
            alpha = jnp.exp(m - m_new)
            l_new = alpha * l + jnp.sum(p, axis=-1, keepdims=True)
            acc_new = acc * alpha + jnp.einsum(
                "bhqk,bhkd->bhqd", p.astype(vc.dtype), vc,
                preferred_element_type=jnp.float32,
            )
            return (m_new, l_new, acc_new), None

        # constrain the online-softmax carries: unconstrained scan carries
        # propagate as REPLICATED, which made GSPMD all-gather every f32
        # score tile (0.5 GB x q-blocks x kv-blocks x layers x fwd/remat/bwd
        # -- the dominant collective in every attention cell, §Perf it.2)
        spec = ("batch", "heads", None, None)
        m0 = constraint(jnp.full((b, hq, q_chunk, 1), NEG_INF, jnp.float32), spec)
        l0 = constraint(jnp.zeros((b, hq, q_chunk, 1), jnp.float32), spec)
        a0 = constraint(jnp.zeros((b, hq, q_chunk, dh), jnp.float32), spec)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (jnp.arange(nk), kt, vt)
        )
        return acc / jnp.maximum(l, 1e-30)

    out = jax.lax.map(q_block, (jnp.arange(nq), qt))  # (nq, B, H, qc, Dh)
    out = jnp.moveaxis(out, 0, 2).reshape(b, hq, sqp, dh)
    out = out[:, :, :sq]
    return out.astype(q.dtype)


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    kv_len: jax.Array,
) -> jax.Array:
    """Single-step decode: q (B, Hq, 1, Dh) vs cache (B, Hkv, S, Dh).

    One masked softmax over the cache -- O(S) memory in the scores, which is
    the roofline-optimal shape for decode (memory-bound on cache reads).
    The GQA group dim is folded into the *query rows* of a single (G, S)
    matmul per kv head, so no repeated-KV materialization ever happens.
    """
    b, hq, _, dh = q.shape
    hkv, s = k_cache.shape[1], k_cache.shape[2]
    group = hq // hkv
    scale = dh**-0.5
    qg = q.reshape(b, hkv, group, dh)
    scores = jnp.einsum(
        "bhgd,bhsd->bhgs", qg, k_cache, preferred_element_type=jnp.float32
    ) * scale
    mask = jnp.arange(s)[None, None, None, :] < kv_len
    scores = jnp.where(mask, scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum(
        "bhgs,bhsd->bhgd", p.astype(v_cache.dtype), v_cache,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(b, hq, 1, dh).astype(q.dtype)
