"""Decoder LM assembly: block-pattern models (attn / mamba / mLSTM / sLSTM
mixers, dense or MoE FFNs) with a scanned layer stack.

Layers are grouped into *periods* (``cfg.block_pattern``): parameters are
stacked over periods and the stack is ``lax.scan``-ned, so the HLO contains
one period body regardless of depth -- essential for compiling the 61-layer /
1T-param dry-run cells in bounded time, and the idiomatic JAX equivalent of
the paper's "one monolithic op per topological layer" philosophy applied to
transformers.

Three entry points per architecture (the dry-run lowers all three):
  * ``train_step``   -- loss/grad/AdamW update (train_4k cells)
  * ``prefill``      -- full-sequence forward building the KV/state cache
  * ``decode_step``  -- one token against the cache (decode_32k / long_500k)
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist import sharding as sharding_lib
from repro.dist.sharding import constraint
from repro.models import attention as attn_lib
from repro.models import mamba as mamba_lib
from repro.models import moe as moe_lib
from repro.models import xlstm as xlstm_lib
from repro.models.common import cross_entropy_loss, dense_init, rms_norm, apply_rope
from repro.optim import adamw


def _dt(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ===========================================================================
# parameter construction
# ===========================================================================
def _init_attn(cfg: ModelConfig, key, np_, dtype) -> Dict[str, Any]:
    d, hq, hkv, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 8)
    p = {
        "wq": dense_init(ks[0], (np_, d, hq * dh), dtype),
        "wk": dense_init(ks[1], (np_, d, hkv * dh), dtype),
        "wv": dense_init(ks[2], (np_, d, hkv * dh), dtype),
        "wo": dense_init(ks[3], (np_, hq * dh, d), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((np_, hq * dh), dtype)
        p["bk"] = jnp.zeros((np_, hkv * dh), dtype)
        p["bv"] = jnp.zeros((np_, hkv * dh), dtype)
    return p


def _init_ffn(cfg: ModelConfig, key, np_, is_moe, dtype) -> Dict[str, Any]:
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    if is_moe:
        e, f = cfg.num_experts, cfg.d_ff_expert or cfg.d_ff
        return {
            "moe": {
                "router": dense_init(ks[0], (np_, d, e), jnp.float32),
                "wg": dense_init(ks[1], (np_, e, d, f), dtype),
                "wu": dense_init(ks[2], (np_, e, d, f), dtype),
                "wd": dense_init(ks[3], (np_, e, f, d), dtype),
            }
        }
    f = cfg.d_ff
    if cfg.activation == "swiglu":
        return {
            "mlp": {
                "wg": dense_init(ks[0], (np_, d, f), dtype),
                "wu": dense_init(ks[1], (np_, d, f), dtype),
                "wd": dense_init(ks[2], (np_, f, d), dtype),
            }
        }
    return {
        "mlp": {
            "wu": dense_init(ks[0], (np_, d, f), dtype),
            "wd": dense_init(ks[1], (np_, f, d), dtype),
        }
    }


def _init_mamba(cfg: ModelConfig, key, np_, dtype) -> Dict[str, Any]:
    d = cfg.d_model
    e = cfg.ssm_expand * d
    n = cfg.ssm_state_dim
    dtr = cfg.ssm_dt_rank or max(d // 16, 1)
    ks = jax.random.split(key, 6)
    return {
        "in_proj": dense_init(ks[0], (np_, d, 2 * e), dtype),
        "conv_w": dense_init(ks[1], (np_, cfg.ssm_conv_dim, e), dtype, scale=0.5),
        "x_proj": dense_init(ks[2], (np_, e, dtr + 2 * n), dtype),
        "dt_proj": dense_init(ks[3], (np_, dtr, e), dtype),
        "dt_bias": jnp.full((np_, e), -4.6, jnp.float32),  # softplus^-1(0.01)
        "a_log": jnp.tile(
            jnp.log(jnp.arange(1, n + 1, dtype=jnp.float32))[None, None],
            (np_, e, 1),
        ),
        "d_skip": jnp.ones((np_, e), jnp.float32),
        "out_proj": dense_init(ks[4], (np_, e, d), dtype),
    }


def _init_mlstm(cfg: ModelConfig, key, np_, dtype) -> Dict[str, Any]:
    d = cfg.d_model
    pf = cfg.lstm_proj_factor
    e = int(pf * d)
    h = cfg.num_heads
    ks = jax.random.split(key, 6)
    return {
        "up": dense_init(ks[0], (np_, d, 2 * e), dtype),
        "wq_l": dense_init(ks[1], (np_, e, e), dtype),
        "wk_l": dense_init(ks[2], (np_, e, e), dtype),
        "wi": dense_init(ks[3], (np_, e, h), jnp.float32),
        "wf": dense_init(ks[4], (np_, e, h), jnp.float32),
        "bi": jnp.zeros((np_, h), jnp.float32),
        "bf": jnp.full((np_, h), 3.0, jnp.float32),  # forget-gate bias >0
        "down": dense_init(ks[5], (np_, e, d), dtype),
    }


def _init_slstm(cfg: ModelConfig, key, np_, dtype) -> Dict[str, Any]:
    d = cfg.d_model
    h = cfg.num_heads
    dh = d // h
    ks = jax.random.split(key, 2)
    return {
        "wx": dense_init(ks[0], (np_, d, 4 * d), dtype),
        "bx": jnp.zeros((np_, 4 * d), jnp.float32),
        "r": dense_init(ks[1], (np_, 4, h, dh, dh), jnp.float32, scale=dh**-0.5),
    }


def init_params(cfg: ModelConfig, key: jax.Array) -> Dict[str, Any]:
    dtype = _dt(cfg)
    np_ = cfg.num_periods
    keys = jax.random.split(key, len(cfg.block_pattern) + 3)
    blocks = []
    for pos, kind in enumerate(cfg.block_pattern):
        k1, k2 = jax.random.split(keys[pos])
        p: Dict[str, Any] = {"ln1": jnp.ones((np_, cfg.d_model), jnp.float32)}
        if kind == "attn":
            p.update(_init_attn(cfg, k1, np_, dtype))
        elif kind == "mamba":
            p.update(_init_mamba(cfg, k1, np_, dtype))
        elif kind == "mlstm":
            p.update(_init_mlstm(cfg, k1, np_, dtype))
        elif kind == "slstm":
            p.update(_init_slstm(cfg, k1, np_, dtype))
        else:
            raise ValueError(kind)
        if cfg.has_ffn(pos):
            p["ln2"] = jnp.ones((np_, cfg.d_model), jnp.float32)
            p.update(_init_ffn(cfg, k2, np_, cfg.moe_pattern[pos], dtype))
        blocks.append(p)
    params: Dict[str, Any] = {"blocks": tuple(blocks)}
    vp = cfg.padded_vocab  # 128-aligned storage; see ModelConfig.padded_vocab
    if not cfg.embedding_input:
        params["embed"] = dense_init(keys[-3], (vp, cfg.d_model), dtype, scale=1.0)
    params["final_norm"] = jnp.ones((cfg.d_model,), jnp.float32)
    params["head"] = dense_init(keys[-2], (cfg.d_model, vp), dtype)
    return params


# ===========================================================================
# block application (shared by train / prefill / decode)
# ===========================================================================
def _attn_mixer(cfg, p, x, positions, cache, pos):
    b, s, d = x.shape
    hq, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"])
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"])
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, hq, dh)
    k = k.reshape(b, s, hkv, dh)
    v = v.reshape(b, s, hkv, dh)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    # only q carries a head constraint: Hq always divides the model axis;
    # K/V layouts follow from the repeat inside chunked_attention (Hkv may
    # not divide the mesh -- constraining it caused involuntary replication)
    q = constraint(q, ("batch", None, "heads", None))
    qh = q.swapaxes(1, 2)  # (B, Hq, S, dh)
    kh = k.swapaxes(1, 2)
    vh = v.swapaxes(1, 2)
    if cache is None:
        o = attn_lib.chunked_attention(
            qh, kh, vh, causal=True,
            q_chunk=cfg.attn_q_chunk, kv_chunk=cfg.attn_kv_chunk,
        )
        new_cache = {"k": kh.astype(_dt(cfg)), "v": vh.astype(_dt(cfg))}
    else:
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], kh.astype(cache["k"].dtype), pos, axis=2)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], vh.astype(cache["v"].dtype), pos, axis=2)
        o = attn_lib.decode_attention(qh, ck, cv, kv_len=pos + s)
        new_cache = {"k": ck, "v": cv}
    o = o.swapaxes(1, 2).reshape(b, s, hq * dh).astype(x.dtype)
    return jnp.einsum("bsh,hd->bsd", o, p["wo"]), new_cache


def _mamba_mixer(cfg, p, x, positions, cache, pos):
    b, s, d = x.shape
    e = cfg.ssm_expand * d
    n = cfg.ssm_state_dim
    dtr = cfg.ssm_dt_rank or max(d // 16, 1)
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    xi, z = jnp.split(xz, 2, axis=-1)
    xi = constraint(xi, ("batch", None, "mlp"))
    conv_state = None if cache is None else cache["conv"]
    xi, new_conv = mamba_lib.causal_conv1d(xi, p["conv_w"], conv_state)
    xi = jax.nn.silu(xi.astype(jnp.float32)).astype(x.dtype)
    proj = jnp.einsum("bse,ef->bsf", xi, p["x_proj"])
    dt_in, bmat, cmat = jnp.split(proj, [dtr, dtr + n], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,re->bse", dt_in, p["dt_proj"]).astype(jnp.float32)
        + p["dt_bias"]
    )
    if cache is None:
        y, h_final = mamba_lib.selective_scan(
            xi.astype(jnp.float32), dt, p["a_log"], bmat.astype(jnp.float32),
            cmat.astype(jnp.float32), p["d_skip"], chunk=cfg.ssm_chunk,
        )
        new_cache = {"h": h_final, "conv": new_conv}
    else:
        y, h_new = mamba_lib.selective_step(
            xi[:, 0].astype(jnp.float32), dt[:, 0], p["a_log"],
            bmat[:, 0].astype(jnp.float32), cmat[:, 0].astype(jnp.float32),
            p["d_skip"], cache["h"],
        )
        y = y[:, None]
        new_cache = {"h": h_new, "conv": new_conv}
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return jnp.einsum("bse,ed->bsd", y, p["out_proj"]), new_cache


def _mlstm_mixer(cfg, p, x, positions, cache, pos):
    b, s, d = x.shape
    e = int(cfg.lstm_proj_factor * d)
    h = cfg.num_heads
    dh = e // h
    up = jnp.einsum("bsd,de->bse", x, p["up"])
    xi, z = jnp.split(up, 2, axis=-1)
    q = jnp.einsum("bse,ef->bsf", xi, p["wq_l"]).reshape(b, s, h, dh).astype(jnp.float32)
    k = jnp.einsum("bse,ef->bsf", xi, p["wk_l"]).reshape(b, s, h, dh).astype(jnp.float32) * dh**-0.5
    v = xi.reshape(b, s, h, dh).astype(jnp.float32)
    li = jnp.einsum("bse,eh->bsh", xi.astype(jnp.float32), p["wi"]) + p["bi"]
    lf = jax.nn.log_sigmoid(
        jnp.einsum("bse,eh->bsh", xi.astype(jnp.float32), p["wf"]) + p["bf"]
    )
    carry = None if cache is None else (cache["c"], cache["n"], cache["m"])
    if cache is None and s > 1:
        # chunkwise-parallel form: per-chunk (c x c) MXU matmuls + one state
        # materialization per chunk (vs per step) -- see xlstm.py docstring
        y, carry = xlstm_lib.mlstm_sequence_chunked(
            q, k, v, li, lf, chunk=cfg.ssm_chunk
        )
    else:
        carry = carry or (
            jnp.zeros((b, h, dh, dh), jnp.float32),
            jnp.zeros((b, h, dh), jnp.float32),
            jnp.full((b, h), -1e30, jnp.float32),
        )
        carry, y = xlstm_lib.mlstm_step(
            carry, {"q": q[:, 0], "k": k[:, 0], "v": v[:, 0],
                    "li": li[:, 0], "lf": lf[:, 0]}
        )
        y = y[:, None]
    new_cache = {"c": carry[0], "n": carry[1], "m": carry[2]}
    y = y.reshape(b, s, e)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return jnp.einsum("bse,ed->bsd", y, p["down"]), new_cache


def _slstm_mixer(cfg, p, x, positions, cache, pos):
    b, s, d = x.shape
    h = cfg.num_heads
    dh = d // h
    wx = jnp.einsum("bsd,df->bsf", x, p["wx"]).astype(jnp.float32) + p["bx"]
    parts = jnp.split(wx, 4, axis=-1)
    names = ("i", "f", "z", "o")
    wxd = {n: t.reshape(b, s, h, dh) for n, t in zip(names, parts)}
    r_blocks = {n: p["r"][idx] for idx, n in enumerate(names)}
    carry = None if cache is None else (cache["c"], cache["n"], cache["m"], cache["h"])
    if cache is None and s > 1:
        y, carry = xlstm_lib.slstm_sequence(wxd, r_blocks, chunk=cfg.ssm_chunk)
    else:
        carry = carry or tuple(
            jnp.zeros((b, h, dh), jnp.float32) if i != 2
            else jnp.full((b, h, dh), -1e30, jnp.float32)
            for i in range(4)
        )
        step = xlstm_lib.slstm_step_factory(r_blocks)
        carry, y = step(carry, {n: wxd[n][:, 0] for n in names})
        y = y[:, None]
    new_cache = {"c": carry[0], "n": carry[1], "m": carry[2], "h": carry[3]}
    return y.reshape(b, s, d).astype(x.dtype), new_cache


_MIXERS = {
    "attn": _attn_mixer,
    "mamba": _mamba_mixer,
    "mlstm": _mlstm_mixer,
    "slstm": _slstm_mixer,
}


def _ffn(cfg: ModelConfig, p: Dict[str, Any], x: jax.Array, is_moe: bool):
    b, s, d = x.shape
    if is_moe:
        m = p["moe"]
        rules = sharding_lib.get_rules()
        if cfg.moe_impl == "shard_map" and rules is not None:
            return moe_lib.moe_ffn_shard_map(
                x, m["router"], m["wg"], m["wu"], m["wd"],
                top_k=cfg.num_experts_per_tok,
                capacity_factor=cfg.capacity_factor,
                dp_axes=rules["batch"], ep_axis=rules["expert"],
                fsdp_axes=rules["fsdp"],
            )
        fn = (moe_lib.moe_ffn_dense if cfg.moe_impl == "dense"
              else moe_lib.moe_ffn_gather)
        fn = functools.partial(
            fn, top_k=cfg.num_experts_per_tok,
            capacity_factor=cfg.capacity_factor,
        )
        g = min(cfg.moe_groups, b * s)
        if g > 1:
            # routing groups aligned with the DP sharding of the batch dim:
            # capacity is per-group, the (G, E, C, D) buffer shards (dp, ep)
            xg = x.reshape(g, b * s // g, d)
            xg = constraint(xg, ("batch", None, None))
            out, aux = jax.vmap(fn, in_axes=(0, None, None, None, None))(
                xg, m["router"], m["wg"], m["wu"], m["wd"]
            )
            return out.reshape(b, s, d), jnp.mean(aux)
        out, aux = fn(x.reshape(b * s, d), m["router"], m["wg"], m["wu"], m["wd"])
        return out.reshape(b, s, d), aux
    m = p["mlp"]
    if cfg.activation == "swiglu":
        g = jnp.einsum("bsd,df->bsf", x, m["wg"])
        u = jnp.einsum("bsd,df->bsf", x, m["wu"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    else:
        u = jnp.einsum("bsd,df->bsf", x, m["wu"])
        if cfg.activation == "squared_relu":
            h = jnp.square(jax.nn.relu(u.astype(jnp.float32))).astype(x.dtype)
        else:
            h = jax.nn.gelu(u.astype(jnp.float32)).astype(x.dtype)
    h = constraint(h, ("batch", None, "mlp"))
    return jnp.einsum("bsf,fd->bsd", h, m["wd"]), jnp.zeros((), jnp.float32)


def block_apply(cfg, kind, pos_idx, p, h, positions, cache, pos):
    """One block: mixer + optional FFN, pre-norm residual."""
    x = rms_norm(h, p["ln1"], cfg.norm_eps)
    mixer_out, new_cache = _MIXERS[kind](cfg, p, x, positions, cache, pos)
    h = h + mixer_out
    aux = jnp.zeros((), jnp.float32)
    if cfg.has_ffn(pos_idx):
        x = rms_norm(h, p["ln2"], cfg.norm_eps)
        out, aux = _ffn(cfg, p, x, cfg.moe_pattern[pos_idx])
        h = h + out
    h = constraint(h, ("batch", None, None))
    return h, new_cache, aux


# ===========================================================================
# full model
# ===========================================================================
def _embed_in(cfg, params, batch):
    if cfg.embedding_input:
        return batch["inputs_embeds"].astype(_dt(cfg))
    return params["embed"][batch["tokens"]]


def backbone(cfg: ModelConfig, params, batch, remat: bool = True):
    """Scanned layer stack.  Returns (h (B,S,D) post-final-norm, aux_loss)."""
    h = _embed_in(cfg, params, batch)
    h = constraint(h, ("batch", "seq", None))
    s = h.shape[1]
    positions = jnp.arange(s, dtype=jnp.int32)

    def period(carry, layer_params):
        h, aux = carry
        for pos_idx, kind in enumerate(cfg.block_pattern):
            fn = functools.partial(
                block_apply, cfg, kind, pos_idx,
            )
            if remat:
                fn = jax.checkpoint(
                    lambda p_, h_, fn=fn: fn(p_, h_, positions, None, None)
                )
                h, _, a = fn(layer_params[pos_idx], h)
            else:
                h, _, a = fn(layer_params[pos_idx], h, positions, None, None)
            aux = aux + a
        # SP: the residual carry is stored seq-sharded across scan steps,
        # keeping the per-device activation footprint flat in depth
        h = constraint(h, ("batch", "seq", None))
        return (h, aux), None

    (h, aux), _ = jax.lax.scan(
        period, (h, jnp.zeros((), jnp.float32)), params["blocks"]
    )
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    return h, aux


def forward(cfg: ModelConfig, params, batch, remat: bool = True):
    """Eval forward with full logits (small models / unit tests only)."""
    h, aux = backbone(cfg, params, batch, remat=remat)
    logits = jnp.einsum("bsd,dv->bsv", h, params["head"])
    logits = constraint(logits, ("batch", None, "vocab"))
    return logits[..., : cfg.vocab_size], aux


def chunked_cross_entropy(cfg, h, head, labels):
    """Vocab-parallel CE without materializing (B, S, V) logits.

    Scans sequence chunks; each chunk's logits are (B, chunk, V/tp) and are
    rematerialized in the backward pass (jax.checkpoint), so peak memory is
    one chunk of logits instead of the full 10^11-element tensor the 1T-vocab
    cells would otherwise allocate.
    """
    b, s, d = h.shape
    chunk = min(cfg.loss_chunk, s)
    pad = (-s) % chunk
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    nch = h.shape[1] // chunk
    hs = jnp.moveaxis(h.reshape(b, nch, chunk, d), 1, 0)
    ls = jnp.moveaxis(labels.reshape(b, nch, chunk), 1, 0)

    @jax.checkpoint
    def body(carry, xs):
        nll_sum, cnt = carry
        hc, lc = xs
        logits = jnp.einsum("bsd,dv->bsv", hc, head).astype(jnp.float32)
        logits = constraint(logits, ("batch", None, "vocab"))
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(lc, 0)[..., None], axis=-1
        )[..., 0]
        mask = (lc != -1).astype(jnp.float32)
        return (nll_sum + jnp.sum((logz - gold) * mask), cnt + jnp.sum(mask)), None

    (nll, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)), (hs, ls)
    )
    return nll / jnp.maximum(cnt, 1.0), cnt


def loss_fn(cfg, params, batch, remat: bool = True):
    h, aux = backbone(cfg, params, batch, remat=remat)
    loss, denom = chunked_cross_entropy(cfg, h, params["head"], batch["labels"])
    total = loss + cfg.moe_aux_weight * aux
    return total, {"loss": loss, "aux": aux, "tokens": denom}


def train_step(cfg: ModelConfig, opt_cfg: adamw.AdamWConfig, params, opt_state,
               batch):
    """One optimization step (the train_4k dry-run entry point)."""
    (total, metrics), grads = jax.value_and_grad(
        lambda p: loss_fn(cfg, p, batch), has_aux=True
    )(params)
    # pin grads to the parameter layout BEFORE the update: turns the grad
    # realignment into a reduce-scatter instead of a replicating all-gather
    grads = sharding_lib.constrain_like_params(grads)
    new_params, new_state, gnorm = adamw.apply_updates(
        opt_cfg, params, grads, opt_state
    )
    metrics = dict(metrics, total=total, grad_norm=gnorm)
    return new_params, new_state, metrics


# ----------------------------------------------------------------- serving
def init_cache(cfg: ModelConfig, batch_size: int, max_len: int):
    """Preallocated cache pytree, stacked over periods per position."""
    np_, dtype = cfg.num_periods, _dt(cfg)
    b = batch_size
    caches = []
    for kind in cfg.block_pattern:
        if kind == "attn":
            shape = (np_, b, cfg.num_kv_heads, max_len, cfg.head_dim)
            caches.append({"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)})
        elif kind == "mamba":
            e = cfg.ssm_expand * cfg.d_model
            caches.append({
                "h": jnp.zeros((np_, b, e, cfg.ssm_state_dim), jnp.float32),
                "conv": jnp.zeros((np_, b, cfg.ssm_conv_dim - 1, e), dtype),
            })
        elif kind == "mlstm":
            e = int(cfg.lstm_proj_factor * cfg.d_model)
            h, dh = cfg.num_heads, int(cfg.lstm_proj_factor * cfg.d_model) // cfg.num_heads
            caches.append({
                "c": jnp.zeros((np_, b, h, dh, dh), jnp.float32),
                "n": jnp.zeros((np_, b, h, dh), jnp.float32),
                "m": jnp.full((np_, b, h), -1e30, jnp.float32),
            })
        elif kind == "slstm":
            h, dh = cfg.num_heads, cfg.d_model // cfg.num_heads
            caches.append({
                "c": jnp.zeros((np_, b, h, dh), jnp.float32),
                "n": jnp.zeros((np_, b, h, dh), jnp.float32),
                "m": jnp.full((np_, b, h, dh), -1e30, jnp.float32),
                "h": jnp.zeros((np_, b, h, dh), jnp.float32),
            })
    return tuple(caches)


def _serve_pass(cfg, params, h, positions, cache, pos):
    def period(carry, xs):
        h = carry
        layer_params, layer_cache = xs
        new_caches = []
        for pos_idx, kind in enumerate(cfg.block_pattern):
            h, nc, _ = block_apply(
                cfg, kind, pos_idx, layer_params[pos_idx], h, positions,
                layer_cache[pos_idx], pos,
            )
            new_caches.append(nc)
        return h, tuple(new_caches)

    h, new_cache = jax.lax.scan(period, h, (params["blocks"], cache))
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", h, params["head"]).astype(jnp.float32)
    return logits[..., : cfg.vocab_size], new_cache


def prefill(cfg: ModelConfig, params, batch, max_len: Optional[int] = None):
    """Process the prompt, build the cache.  Returns (last_logits, cache, pos).

    The attention cache comes back sized to the prompt (padded to ``max_len``
    if given); recurrent states are O(1) regardless of prompt length.
    """
    h = _embed_in(cfg, params, batch)
    h = constraint(h, ("batch", "seq", None))
    b, s = h.shape[0], h.shape[1]
    positions = jnp.arange(s, dtype=jnp.int32)

    # training-style pass that also emits caches
    def period(carry, layer_params):
        h = carry
        new_caches = []
        for pos_idx, kind in enumerate(cfg.block_pattern):
            h, nc, _ = block_apply(
                cfg, kind, pos_idx, layer_params[pos_idx], h, positions, None, None
            )
            new_caches.append(nc)
        return h, tuple(new_caches)

    h, cache = jax.lax.scan(period, h, params["blocks"])
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    last = h[:, -1:]
    logits = jnp.einsum("bsd,dv->bsv", last, params["head"]).astype(jnp.float32)
    logits = logits[..., : cfg.vocab_size]
    if max_len is not None and max_len > s:
        def pad_kv(c):
            if "k" in c:
                padw = ((0, 0), (0, 0), (0, 0), (0, max_len - s), (0, 0))
                return dict(c, k=jnp.pad(c["k"], padw), v=jnp.pad(c["v"], padw))
            return c
        cache = tuple(pad_kv(c) for c in cache)
    return logits, cache, jnp.asarray(s, jnp.int32)


def decode_step(cfg: ModelConfig, params, batch, cache, pos):
    """One new token against the cache (decode dry-run entry point).

    batch: {"tokens": (B, 1)} or {"inputs_embeds": (B, 1, D)}; pos: scalar.
    Returns (logits (B, 1, V), new_cache).
    """
    h = _embed_in(cfg, params, batch)
    h = constraint(h, ("batch", None, None))
    positions = jnp.full((h.shape[0], 1), pos, jnp.int32)
    logits, new_cache = _serve_pass(cfg, params, h, positions, cache, pos)
    return logits, new_cache
