"""Shared building blocks for the LM substrate: norms, RoPE, activations."""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return out.astype(dtype)


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    """(head_dim // 2,) inverse frequencies."""
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary position embedding.

    x: (B, S, H, Dh); positions: (B, S) or (S,) int32.
    """
    dh = x.shape[-1]
    inv = rope_frequencies(dh, theta)  # (dh/2,)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * inv  # (B, S, dh/2)
    cos = jnp.cos(ang)[:, :, None, :]  # (B, S, 1, dh/2)
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def activation(name: str):
    if name == "swiglu":
        raise ValueError("swiglu is handled structurally in the MLP")
    if name == "squared_relu":
        return lambda x: jnp.square(jax.nn.relu(x))
    if name == "gelu":
        return jax.nn.gelu
    if name == "silu":
        return jax.nn.silu
    raise ValueError(f"unknown activation {name}")


def dense_init(key: jax.Array, shape: Tuple[int, ...], dtype,
               scale: float | None = None) -> jax.Array:
    fan_in = shape[0] if len(shape) >= 2 else 1
    if scale is None:
        scale = fan_in**-0.5
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def cross_entropy_loss(
    logits: jax.Array, labels: jax.Array, ignore_id: int = -1
) -> Tuple[jax.Array, jax.Array]:
    """Mean token cross entropy in f32.  logits (B, S, V); labels (B, S)."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1
    )[..., 0]
    mask = (labels != ignore_id).astype(jnp.float32)
    nll = (logz - gold) * mask
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.sum(nll) / denom, denom
