"""Mamba (S6) selective state-space block, chunked for TPU memory limits.

The selective scan  h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t,  y_t = C_t h_t
+ D x_t  is evaluated as an outer ``lax.scan`` over sequence chunks carrying
the (B, E, N) state, with an inner ``associative_scan`` inside each chunk.
The (B, chunk, E, N) discretized tensors therefore exist only per-chunk
(E = expand * d_model is the TP-sharded axis), keeping activation memory flat
for the 500k-token long-context cells.

Decode is the O(1) recurrent update on the cached state.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def _discretize(x, dt, a_log, b, c):
    """x: (B, L, E); dt: (B, L, E); a_log: (E, N); b, c: (B, L, N)."""
    a = -jnp.exp(a_log.astype(jnp.float32))  # (E, N), negative-definite
    a_bar = jnp.exp(dt[..., None] * a)  # (B, L, E, N)
    bx = (dt * x)[..., None] * b[:, :, None, :]  # (B, L, E, N)
    return a_bar, bx


def selective_scan(
    x: jax.Array,
    dt: jax.Array,
    a_log: jax.Array,
    b: jax.Array,
    c: jax.Array,
    d_skip: jax.Array,
    h0: jax.Array | None = None,
    chunk: int = 128,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (B, L, E), h_final (B, E, N))."""
    bsz, l, e = x.shape
    n = a_log.shape[1]
    chunk = min(chunk, l)
    pad = (-l) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    lp = x.shape[1]
    nchunks = lp // chunk
    xs = x.reshape(bsz, nchunks, chunk, e).swapaxes(0, 1)
    dts = dt.reshape(bsz, nchunks, chunk, e).swapaxes(0, 1)
    bs = b.reshape(bsz, nchunks, chunk, n).swapaxes(0, 1)
    cs = c.reshape(bsz, nchunks, chunk, n).swapaxes(0, 1)
    if h0 is None:
        h0 = jnp.zeros((bsz, e, n), jnp.float32)

    @jax.checkpoint  # recompute the (B, chunk, E, N) discretized tensors in
    def chunk_step(h, args):  # the bwd pass instead of storing them per chunk
        xc, dtc, bc, cc = args  # (B, chunk, ...)
        a_bar, bx = _discretize(xc, dtc, a_log, bc, cc)  # (B, chunk, E, N)

        def combine(p, q):
            a1, b1 = p
            a2, b2 = q
            return a1 * a2, a2 * b1 + b2

        cum_a, cum_b = jax.lax.associative_scan(
            combine, (a_bar, bx.astype(jnp.float32)), axis=1
        )
        hs = cum_b + cum_a * h[:, None]  # (B, chunk, E, N)
        y = jnp.einsum("blen,bln->ble", hs, cc.astype(jnp.float32))
        return hs[:, -1], y

    h_final, ys = jax.lax.scan(chunk_step, h0, (xs, dts, bs, cs))
    y = ys.swapaxes(0, 1).reshape(bsz, lp, e)[:, :l]
    y = y + d_skip.astype(jnp.float32) * x[:, :l].astype(jnp.float32)
    return y, h_final


def selective_step(
    x: jax.Array,
    dt: jax.Array,
    a_log: jax.Array,
    b: jax.Array,
    c: jax.Array,
    d_skip: jax.Array,
    h: jax.Array,
) -> Tuple[jax.Array, jax.Array]:
    """Single-token decode.  x, dt: (B, E); b, c: (B, N); h: (B, E, N)."""
    a = -jnp.exp(a_log.astype(jnp.float32))
    a_bar = jnp.exp(dt[..., None] * a)  # (B, E, N)
    bx = (dt * x)[..., None] * b[:, None, :]
    h_new = a_bar * h + bx.astype(jnp.float32)
    y = jnp.einsum("ben,bn->be", h_new, c.astype(jnp.float32))
    y = y + d_skip.astype(jnp.float32) * x.astype(jnp.float32)
    return y, h_new


def causal_conv1d(x: jax.Array, w: jax.Array, state: jax.Array | None = None):
    """Depthwise causal conv over the sequence.

    x: (B, L, E); w: (K, E).  Returns (y (B, L, E), new_state (B, K-1, E)).
    """
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)  # (B, K-1+L, E)
    y = sum(xp[:, i : i + x.shape[1]] * w[i][None, None, :] for i in range(k))
    new_state = xp[:, -(k - 1) :] if k > 1 else state
    return y, new_state
