"""jamba-v0.1-52b [hybrid]: Mamba+attention 1:7 interleave, MoE every 2 layers.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536, MoE 16e top-2
[arXiv:2403.19887; hf].  Period of 8 blocks: attention at position 4 (1:7
attn:mamba), MoE on odd positions (every second layer).
"""
from repro.configs.base import ModelConfig

_PATTERN = ("mamba", "mamba", "mamba", "mamba", "attn", "mamba", "mamba", "mamba")
_MOE = (False, True, False, True, False, True, False, True)

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    block_pattern=_PATTERN,
    moe_pattern=_MOE,
    num_experts=16,
    num_experts_per_tok=2,
    d_ff_expert=14336,
    ssm_state_dim=16,
    ssm_conv_dim=4,
    ssm_expand=2,
)
