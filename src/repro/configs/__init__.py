"""Architecture registry: --arch <id> resolves here."""

from repro.configs.base import (
    SHAPES,
    SHAPES_BY_NAME,
    EinetConfig,
    ModelConfig,
    ShapeSpec,
    applicable,
    smoke_variant,
)

from repro.configs import (
    einet_celeba,
    einet_pd,
    einet_pd_mnist,
    einet_rat,
    einet_rat_large,
    granite_8b,
    internvl2_26b,
    jamba_v0_1_52b,
    kimi_k2_1t_a32b,
    llama3_2_3b,
    moonshot_v1_16b_a3b,
    musicgen_medium,
    nemotron_4_15b,
    qwen1_5_0_5b,
    xlstm_350m,
)

REGISTRY = {
    m.CONFIG.name: m.CONFIG
    for m in (
        musicgen_medium,
        jamba_v0_1_52b,
        xlstm_350m,
        kimi_k2_1t_a32b,
        moonshot_v1_16b_a3b,
        granite_8b,
        llama3_2_3b,
        nemotron_4_15b,
        qwen1_5_0_5b,
        internvl2_26b,
        einet_celeba,
        einet_pd,
        einet_pd_mnist,
        einet_rat,
        einet_rat_large,
    )
}

# stable short ids for --arch flags / file names
ALIASES = {
    "musicgen-medium": "musicgen-medium",
    "jamba-v0.1-52b": "jamba-v0.1-52b",
    "xlstm-350m": "xlstm-350m",
    "kimi-k2-1t-a32b": "kimi-k2-1t-a32b",
    "moonshot-v1-16b-a3b": "moonshot-v1-16b-a3b",
    "granite-8b": "granite-8b",
    "llama3.2-3b": "llama3.2-3b",
    "nemotron-4-15b": "nemotron-4-15b",
    "qwen1.5-0.5b": "qwen1.5-0.5b",
    "internvl2-26b": "internvl2-26b",
    "einet_celeba": "einet-pd-celeba",
    "einet_pd": "einet-pd-svhn",
    "einet_pd_mnist": "einet-pd-mnist",
    "einet_rat": "einet-rat",
    "einet_rat_large": "einet-rat-large",
}

LM_ARCHS = tuple(
    n for n, c in REGISTRY.items() if isinstance(c, ModelConfig)
)


def get_config(name: str):
    name = ALIASES.get(name, name)
    if name not in REGISTRY:
        raise KeyError(
            f"unknown arch {name!r}; available: {sorted(REGISTRY)}"
        )
    return REGISTRY[name]


__all__ = [
    "REGISTRY", "ALIASES", "LM_ARCHS", "get_config", "ModelConfig",
    "EinetConfig", "ShapeSpec", "SHAPES", "SHAPES_BY_NAME", "applicable",
    "smoke_variant",
]
