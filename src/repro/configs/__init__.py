"""Architecture registry: --arch <id> resolves here.

EiNet-only: every registered config is an ``EinetConfig``.  The template LM
architectures (transformer/SSM/MoE configs and their model code) that rode
in with the repo scaffold were removed -- they were never part of the
paper's system and kept leaking into --arch listings, packaging, and test
collection.
"""

from repro.configs.base import EinetConfig

from repro.configs import (
    einet_celeba,
    einet_pd,
    einet_pd_mnist,
    einet_rat,
    einet_rat_large,
)

REGISTRY = {
    m.CONFIG.name: m.CONFIG
    for m in (
        einet_celeba,
        einet_pd,
        einet_pd_mnist,
        einet_rat,
        einet_rat_large,
    )
}

# stable short ids for --arch flags / file names
ALIASES = {
    "einet_celeba": "einet-pd-celeba",
    "einet_pd": "einet-pd-svhn",
    "einet_pd_mnist": "einet-pd-mnist",
    "einet_rat": "einet-rat",
    "einet_rat_large": "einet-rat-large",
}


def get_config(name: str) -> EinetConfig:
    name = ALIASES.get(name, name)
    if name not in REGISTRY:
        raise KeyError(
            f"unknown arch {name!r}; available: {sorted(REGISTRY)}"
        )
    return REGISTRY[name]


__all__ = ["REGISTRY", "ALIASES", "get_config", "EinetConfig"]
