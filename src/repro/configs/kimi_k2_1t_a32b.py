"""kimi-k2-1t-a32b [moe]: trillion-parameter MoE (paper-table numbers).

61L d_model=7168 64H (GQA kv=8) d_ff=2048 (expert) vocab=163840,
MoE 384e top-8  [arXiv:2501.kimi2; unverified].  All layers MoE per the
assignment table; d_ff is the per-expert hidden dim.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    d_ff=0,
    vocab_size=163840,
    block_pattern=("attn",),
    moe_pattern=(True,),
    num_experts=384,
    num_experts_per_tok=8,
    d_ff_expert=2048,
    head_dim_override=112,
)
