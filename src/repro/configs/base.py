"""Config system: architecture configs, input-shape specs, applicability.

Every assigned architecture is one ``ModelConfig`` in ``repro/configs/<id>.py``
(exact numbers from the assignment table) plus a ``smoke()`` reduction of the
same family that runs a real forward/train step on CPU.  The paper's own
model is an ``EinetConfig`` and flows through the same launcher/dry-run
machinery (``--arch einet_pd``).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    # block layout: cycled pattern of mixers + which positions carry MoE
    block_pattern: Tuple[str, ...] = ("attn",)
    moe_pattern: Tuple[bool, ...] = (False,)
    # MoE
    num_experts: int = 0
    num_experts_per_tok: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    # shard_map: explicit EP all-to-alls when a mesh is active (production);
    # gather: sort-based pjit path (single-host / oracle); dense: GShard ref
    moe_impl: str = "shard_map"  # shard_map | gather | dense
    moe_aux_weight: float = 0.01
    # attention details
    head_dim_override: Optional[int] = None
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    attn_q_chunk: int = 512
    attn_kv_chunk: int = 1024
    # SSM / xLSTM details
    ssm_state_dim: int = 16
    ssm_conv_dim: int = 4
    ssm_expand: int = 2
    ssm_dt_rank: Optional[int] = None
    ssm_chunk: int = 128
    lstm_proj_factor: float = 2.0
    # distribution-facing knobs (set per mesh by the launcher / dry-run)
    moe_groups: int = 1  # routing groups == DP shards; bounds expert capacity
    loss_chunk: int = 512  # sequence chunk for the vocab-parallel CE loss
    # misc
    activation: str = "swiglu"  # swiglu | squared_relu | gelu
    norm_eps: float = 1e-5
    embedding_input: bool = False  # audio/vlm: frontend stub feeds embeddings
    dtype: str = "bfloat16"

    def __post_init__(self):
        assert self.num_layers % len(self.block_pattern) == 0, (
            f"{self.name}: num_layers {self.num_layers} must be a multiple of "
            f"the block pattern length {len(self.block_pattern)}"
        )
        assert len(self.moe_pattern) == len(self.block_pattern)

    @property
    def head_dim(self) -> int:
        return self.head_dim_override or self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a 128 multiple: MXU lane alignment + TP
        divisibility for embedding/head storage (logits over the padded
        columns stay in the softmax, exactly like production frameworks;
        ``forward`` slices them off for the eval API)."""
        return -(-self.vocab_size // 128) * 128

    @property
    def num_periods(self) -> int:
        return self.num_layers // len(self.block_pattern)

    def has_ffn(self, pos: int) -> bool:
        return bool(self.moe_pattern[pos]) or self.d_ff > 0

    def param_count(self) -> int:
        """Analytic parameter count (for MODEL_FLOPS in the roofline)."""
        d, dh = self.d_model, self.head_dim
        total = 0 if self.embedding_input else self.vocab_size * d
        total += d * self.vocab_size  # head
        for pos, kind in enumerate(self.block_pattern):
            n = self.num_periods
            if kind == "attn":
                total += n * d * dh * (self.num_heads * 2 + self.num_kv_heads * 2)
            elif kind == "mamba":
                e = self.ssm_expand * d
                dtr = self.ssm_dt_rank or max(d // 16, 1)
                total += n * (
                    d * 2 * e + e * (dtr + 2 * self.ssm_state_dim)
                    + dtr * e + e * self.ssm_state_dim + e * d
                )
            elif kind == "mlstm":
                e = int(self.lstm_proj_factor * d)
                total += n * (d * 2 * e + 2 * e * e + e * d)
            elif kind == "slstm":
                total += n * (d * 4 * d + 4 * d * (d // self.num_heads))
            if self.moe_pattern[pos]:
                f = self.d_ff_expert or self.d_ff
                total += n * self.num_experts * 3 * d * f
            elif self.d_ff > 0:
                mult = 3 if self.activation == "swiglu" else 2
                total += n * mult * d * self.d_ff
        return total

    def active_param_count(self) -> int:
        """Per-token active parameters (MoE: top-k of the experts)."""
        if self.num_experts == 0:
            return self.param_count()
        d = self.d_model
        f = self.d_ff_expert or self.d_ff
        n_moe = sum(
            self.num_periods for pos in range(len(self.block_pattern))
            if self.moe_pattern[pos]
        )
        inactive = n_moe * (self.num_experts - self.num_experts_per_tok) * 3 * d * f
        return self.param_count() - inactive


@dataclasses.dataclass(frozen=True)
class EinetConfig:
    """The paper's own architecture as a peer config (``--arch einet_*``)."""

    name: str
    family: str = "einet"
    structure: str = "pd"  # pd | rat
    # pd
    height: int = 32
    width: int = 32
    num_channels: int = 3
    delta: int = 8
    pd_axes: Tuple[str, ...] = ("w",)
    # rat
    num_vars: int = 512
    depth: int = 4
    num_repetitions: int = 10
    # shared
    num_sums: int = 40
    num_classes: int = 1
    exponential_family: str = "normal"  # normal | binomial | categorical
    # normal-leaf variance clamp; the paper uses [1e-6, 1e-2] for images
    min_var: float = 1e-6
    max_var: float = 10.0
    batch_size: int = 512


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Tuple[ShapeSpec, ...] = (
    ShapeSpec("train_4k", "train", 4_096, 256),
    ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    ShapeSpec("decode_32k", "decode", 32_768, 128),
    ShapeSpec("long_500k", "decode", 524_288, 1),
)

SHAPES_BY_NAME: Dict[str, ShapeSpec] = {s.name: s for s in SHAPES}

# families whose per-token state is O(1)-ish: long-context decode is runnable
_SUBQUADRATIC = ("ssm", "hybrid")


def applicable(cfg, shape: ShapeSpec) -> Tuple[bool, str]:
    """Whether a (config, shape) cell runs; reason when skipped (DESIGN.md §5)."""
    if isinstance(cfg, EinetConfig):
        # the EiNet has no KV cache / decode loop: train + single query shapes
        if shape.kind == "train":
            return True, ""
        return False, "EiNet: no autoregressive decode; LL queries only"
    if shape.name == "long_500k" and cfg.family not in _SUBQUADRATIC:
        return False, "pure full-attention arch: 500k decode skipped (DESIGN.md §5)"
    return True, ""


def smoke_variant(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    pat = cfg.block_pattern
    heads = max(2, min(cfg.num_heads, 4))
    kv = heads if cfg.num_kv_heads == cfg.num_heads else max(1, heads // 2)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        num_layers=len(pat) * 2,
        d_model=64,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim_override=64 // heads,
        d_ff=128 if cfg.d_ff > 0 else 0,
        d_ff_expert=96 if cfg.num_experts else 0,
        vocab_size=128,
        num_experts=min(cfg.num_experts, 4),
        num_experts_per_tok=min(cfg.num_experts_per_tok, 2),
        ssm_state_dim=8,
        ssm_dt_rank=8,
        ssm_chunk=16,
        attn_q_chunk=16,
        attn_kv_chunk=16,
        dtype="float32",
    )
