"""Config system: the paper's EiNet architectures as frozen dataclasses.

Each registered architecture is one ``EinetConfig`` in
``repro/configs/<id>.py`` with exact numbers from the paper's experiments
(§4); ``repro.launch.cells.build_einet`` turns a config into a live model.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class EinetConfig:
    """One EiNet experiment cell (``--arch einet_*``)."""

    name: str
    family: str = "einet"
    structure: str = "pd"  # pd | rat
    # pd
    height: int = 32
    width: int = 32
    num_channels: int = 3
    delta: int = 8
    pd_axes: Tuple[str, ...] = ("w",)
    # rat
    num_vars: int = 512
    depth: int = 4
    num_repetitions: int = 10
    # shared
    num_sums: int = 40
    num_classes: int = 1
    exponential_family: str = "normal"  # normal | binomial | categorical
    # normal-leaf variance clamp; the paper uses [1e-6, 1e-2] for images
    min_var: float = 1e-6
    max_var: float = 10.0
    batch_size: int = 512
