"""The paper's efficiency-study architecture: RAT random binary trees
(Fig. 3/6 defaults D=4, R=10, K=10 at 512 variables)."""
from repro.configs.base import EinetConfig

CONFIG = EinetConfig(
    name="einet-rat",
    structure="rat",
    num_vars=512,
    depth=4,
    num_repetitions=10,
    num_sums=10,
    exponential_family="normal",
    batch_size=2048,
)
