"""The paper's §4.2 CelebA architecture: one mixture COMPONENT of the
mixture-of-EiNets model -- a PD-structure EiNet over center-cropped CelebA
downsampled to 32x32 RGB (Delta=8, vertical splits, K=40, factorized
Gaussians over channels, the image-leaf variance clamp).

The full CelebA model is ``--mixture C`` of these, trained over k-means
image clusters (``repro.mixture``); each component flows through the same
launcher / serving machinery as any single EiNet.
"""
from repro.configs.base import EinetConfig

CONFIG = EinetConfig(
    name="einet-pd-celeba",
    structure="pd",
    height=32,
    width=32,
    num_channels=3,
    delta=8,
    pd_axes=("w",),
    num_sums=40,
    exponential_family="normal",
    min_var=1e-6,
    max_var=1e-2,
    batch_size=512,
)
