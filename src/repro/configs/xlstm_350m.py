"""xlstm-350m [ssm]: sLSTM + mLSTM blocks.

24L d_model=1024 4H (GQA kv=4) d_ff=0 vocab=50304  [arXiv:2405.04517;
unverified].  d_ff=0: the cells carry their own up/down projections
(mLSTM proj factor 2).  Pattern: 3 mLSTM blocks then 1 sLSTM block (the
paper's sparse-sLSTM placements).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    block_pattern=("mlstm", "mlstm", "mlstm", "slstm"),
    moe_pattern=(False, False, False, False),
    lstm_proj_factor=2.0,
)
