"""Production-scale EiNet: the paper's RAT structure scaled to a 256-chip
pod (the §Perf "most representative of the paper" hillclimb cell).

1024 variables, depth 7, 16 replica, K=64 -> ~0.5B sum-weights; every einsum
layer's node count L is a multiple of 16 so the layer-node axis shards
exactly over the model axis (DESIGN.md §4: EiNet TP = shard L).
"""
from repro.configs.base import EinetConfig

CONFIG = EinetConfig(
    name="einet-rat-large",
    structure="rat",
    num_vars=1024,
    depth=7,
    num_repetitions=16,
    num_sums=64,
    exponential_family="normal",
    batch_size=65536,  # 256 samples/chip: amortizes the step-constant EM-stat reduction
)
