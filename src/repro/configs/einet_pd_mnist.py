"""PD-structure EiNet for 28x28 grayscale images (the paper's MNIST-family
configuration of §4.2: Delta=7 vertical cuts, K=32, Gaussian leaves with the
image variance clamp).  The 28x28 counterpart of ``einet_pd`` (32x32 SVHN),
giving ``--arch``/``--dataset mnist`` a registered image-grid config path."""
from repro.configs.base import EinetConfig

CONFIG = EinetConfig(
    name="einet-pd-mnist",
    structure="pd",
    height=28,
    width=28,
    num_channels=1,
    delta=7,
    pd_axes=("w",),
    num_sums=32,
    exponential_family="normal",
    min_var=1e-6,
    max_var=1e-2,
    batch_size=256,
)
