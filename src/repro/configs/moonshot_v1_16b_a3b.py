"""moonshot-v1-16b-a3b [moe]: kimi/moonlight, 64 experts top-6.

48L d_model=2048 16H (GQA kv=16) d_ff=1408 (expert) vocab=163840, MoE 64e
top-6  [hf:moonshotai/Moonlight-16B-A3B; hf].
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=0,
    vocab_size=163840,
    block_pattern=("attn",),
    moe_pattern=(True,),
    num_experts=64,
    num_experts_per_tok=6,
    d_ff_expert=1408,
)
