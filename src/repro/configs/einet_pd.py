"""The paper's own architecture: PD-structure EiNet for 32x32 RGB images
(the SVHN configuration of §4.2: Delta=8, vertical splits, K=40, factorized
Gaussians over channels)."""
from repro.configs.base import EinetConfig

CONFIG = EinetConfig(
    name="einet-pd-svhn",
    structure="pd",
    height=32,
    width=32,
    num_channels=3,
    delta=8,
    pd_axes=("w",),
    num_sums=40,
    exponential_family="normal",
    batch_size=512,
)
