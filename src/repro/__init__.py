"""repro: Einsum Networks (Peharz et al., ICML 2020) as a production
multi-pod JAX framework.

Subpackages:
  core        the paper's contribution (einsum-layer PCs, autodiff-EM)
  kernels     Pallas TPU kernels + jnp oracles
  models      LM substrate (the 10 assigned architectures)
  configs     architecture registry (--arch <id>)
  data        synthetic datasets + sharded pipeline
  optim       AdamW (quantizable state), gradient compression
  checkpoint  atomic async checkpoints
  dist        sharding rules, fault tolerance, elasticity
  launch      production mesh, dry-run, train/serve drivers
"""

from repro import _jax_compat as _jax_compat_lib

_jax_compat_lib.install()  # uniform mesh API across the supported jax range

__version__ = "1.0.0"
