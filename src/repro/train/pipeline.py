"""Compiled EM training pipeline: the training-side twin of ``repro.serve``.

The paper's EM step is two phases -- an E-step that is one ``jax.grad`` call
(§3.5) and a closed-form M-step -- but the *seed* hot path still ran them as
separate dispatches, accumulated microbatch statistics in a Python loop, and
never donated the old parameter buffers.  This module makes the whole update
one compiled, donated-buffer XLA program:

  * ``microbatched_em_statistics`` folds ``accumulate_statistics`` over the
    microbatch axis with ``lax.scan`` (one compiled body, no per-microbatch
    dispatch, no host round-trips) -- full-batch EM on datasets larger than
    one device batch is a single program.
  * ``em_update_microbatched`` / ``stochastic_em_update_microbatched`` fuse
    scan-E-step + M-step (+ Sato blend) into one jittable function.
  * ``make_em_step`` returns the jitted update with the parameter pytree
    donated (the M-step writes a fresh pytree of identical shape, so the old
    buffers are dead the moment statistics are read -- donation halves peak
    parameter memory on TPU/GPU).

With ``EiNet(impl="pallas")`` the E-step grad flows through the fused
backward Pallas kernel (``repro.kernels``), making the entire update --
forward, backward, accumulate, M-step -- a single fused program: the
"compiled EM step" row of EXPERIMENTS.md §Perf, benchmarked by
``benchmarks/bench_train.py``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro import compile as compile_lib
from repro import obs
from repro.core.einet import EiNet
from repro.core.em import (
    EMConfig,
    accumulate_statistics,
    blend_params,
    em_statistics,
    m_step,
    zeros_like_statistics,
)
from repro.obs import health as health_lib

# At or below this many microbatches the accumulation loop is UNROLLED into
# the jitted program instead of lowered as ``lax.scan``.  This threshold is
# MEASURED, not assumed -- and the measurement says the scan wins at every
# (arch, microbatch) cell on the CPU container (unroll 1.03-2.02x the scan
# time at microbatches in {2,4,8} on the smoke arch and einet_rat: XLA
# optimizes one scan body better than N fused copies), so the threshold is
# 1: only the microbatches == 1 case skips the scan, via the direct
# ``em_statistics`` fast path below.  The einet_rat speedup-below-1.0
# BENCH_train.json regression this was suspected of causing was actually the
# seed's gather-based per-layer forward dominating the scan body at small
# arch; with depth-grouped (static-slice) execution the scan-accumulated
# step beats the per-dispatch path (x1.10 at einet_rat, batch 256, mb 4).
# Both lowerings add identical terms in identical order; totals agree to
# float32 roundoff.  ``TrainConfig.scan_microbatches`` overrides per step.
SCAN_UNROLL_MAX = 1


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    """Configuration for one compiled EM update step.

    mode: "stochastic" (Sato online EM, the paper's minibatch training) or
      "full" (exact M-step from the whole batch -- full-batch EM when the
      batch is the dataset).
    num_microbatches: split the batch into this many scan steps; bounds
      activation memory at batch/num_microbatches rows while keeping the
      statistics exact (they are sums over data).
    donate: donate the old parameter buffers to the update.  None means
      "donate where the backend implements it" (TPU/GPU); CPU donation is a
      no-op that only produces warnings.  Donation deletes the input
      buffers -- callers that re-feed the same params pytree (benchmarks
      timing both paths, fault-tolerant loops that replay from the initial
      state) must pass donate=False.
    axis_names: mesh axes to psum statistics over (distributed E-step).
    """

    em: EMConfig = EMConfig()
    mode: str = "stochastic"  # "stochastic" | "full"
    num_microbatches: int = 1
    donate: Optional[bool] = None
    axis_names: Optional[Sequence[str]] = None
    scan_microbatches: Optional[bool] = None
    """None: scan only above ``SCAN_UNROLL_MAX`` microbatches (measured
    small-arch crossover); True/False force the lowering either way."""
    health: Optional[bool] = None
    """Emit the device-side health vector (``repro.obs.health``) as a third
    step output.  None defers to the model's ``health`` knob (which itself
    defers to ``REPRO_HEALTH``); the resolved flag is part of the compiled
    step's registry key, so toggling it selects a different cached program
    instead of recompiling an existing one."""


def _split_microbatches(x: jax.Array, num_microbatches: int) -> jax.Array:
    b = x.shape[0]
    if b % num_microbatches:
        raise ValueError(
            f"batch {b} not divisible into {num_microbatches} microbatches"
        )
    return x.reshape((num_microbatches, b // num_microbatches) + x.shape[1:])


def _resolve_scan(scan: Optional[bool], num_microbatches: int) -> bool:
    if scan is None:
        return num_microbatches > SCAN_UNROLL_MAX
    return bool(scan)


def microbatched_em_statistics(
    model: EiNet,
    params: Dict[str, Any],
    x: jax.Array,
    num_microbatches: int = 1,
    axis_names: Optional[Sequence[str]] = None,
    scan: Optional[bool] = None,
) -> Dict[str, Any]:
    """E-step statistics for ``x``, accumulated over microbatches in ONE
    compiled program.

    Same totals as the Python-loop ``accumulate_statistics`` pattern
    (statistics are sums over data).  The accumulation lowers as a
    ``lax.scan`` -- body (leaf pass, forward, backward, statistic add)
    compiled once, running accumulator kept on-device -- except at
    ``num_microbatches <= SCAN_UNROLL_MAX`` (measured crossover; see its
    comment) where the loop is unrolled into the program.  ``scan``
    overrides the threshold when not None.  Both lowerings add identical
    terms in identical order; totals agree to float32 roundoff.
    """
    if num_microbatches == 1:
        return em_statistics(model, params, x, axis_names)
    xm = _split_microbatches(x, num_microbatches)

    def body(acc, xb):
        # accumulate locally; the cross-shard psum runs ONCE on the totals
        # below, not once per microbatch (statistics are plain sums, so the
        # result is identical at 1/num_microbatches the collective traffic)
        new = em_statistics(model, params, xb, axis_names=None)
        return accumulate_statistics(acc, new), None

    if _resolve_scan(scan, num_microbatches):
        acc, _ = jax.lax.scan(body, zeros_like_statistics(model, params), xm)
    else:
        acc = zeros_like_statistics(model, params)
        for i in range(num_microbatches):
            acc, _ = body(acc, xm[i])
    if axis_names:
        acc = jax.tree_util.tree_map(
            lambda a: jax.lax.psum(a, axis_names), acc
        )
    return acc


def _probe_slice(x: jax.Array, num_microbatches: int) -> jax.Array:
    """The (static) subbatch the dedicated health forward runs on: the full
    batch at one microbatch (XLA CSE merges the probe with the E-step's
    primal forward -- the scan body can't leak intermediates, so at more
    microbatches the probe re-runs one bounded forward instead)."""
    return x[: x.shape[0] // max(num_microbatches, 1)]


def em_update_microbatched(
    model: EiNet,
    params: Dict[str, Any],
    x: jax.Array,
    cfg: EMConfig = EMConfig(),
    num_microbatches: int = 1,
    axis_names: Optional[Sequence[str]] = None,
    scan: Optional[bool] = None,
    health: bool = False,
):
    """One full EM update (monotone on the batch), microbatch-accumulated.

    Returns (new_params, mean log-likelihood), plus the packed health vector
    (``repro.obs.health``) as a third element when ``health``.
    """
    stats = microbatched_em_statistics(
        model, params, x, num_microbatches, axis_names, scan
    )
    new = m_step(model, stats, cfg)
    ll = stats["ll"] / stats["count"]
    if not health:
        return new, ll
    hv = health_lib.health_vector(
        model, params, _probe_slice(x, num_microbatches), stats, new
    )
    return new, ll, hv


def stochastic_em_update_microbatched(
    model: EiNet,
    params: Dict[str, Any],
    x: jax.Array,
    cfg: EMConfig = EMConfig(),
    num_microbatches: int = 1,
    axis_names: Optional[Sequence[str]] = None,
    scan: Optional[bool] = None,
    health: bool = False,
):
    """Sato online EM (Eqs. 8/9) with microbatch-accumulated statistics."""
    stats = microbatched_em_statistics(
        model, params, x, num_microbatches, axis_names, scan
    )
    mini = m_step(model, stats, cfg)
    new = blend_params(model, params, mini, cfg.step_size)
    ll = stats["ll"] / stats["count"]
    if not health:
        return new, ll
    # entropy/clamp slots monitor the params the NEXT step will run on,
    # i.e. the blended ones
    hv = health_lib.health_vector(
        model, params, _probe_slice(x, num_microbatches), stats, new
    )
    return new, ll, hv


def _resolve_donate(donate: Optional[bool]) -> bool:
    if donate is None:
        return jax.default_backend() in ("tpu", "gpu")
    return bool(donate)


def _step_key(cfg: TrainConfig, donate: bool, tag: str,
              health: bool = False) -> tuple:
    """Registry key for one jitted training step: the step kind + every
    config field that changes the compiled program."""
    return (
        tag, cfg.mode, cfg.num_microbatches,
        _resolve_scan(cfg.scan_microbatches, cfg.num_microbatches),
        tuple(cfg.axis_names) if cfg.axis_names else None,
        cfg.em, donate, health,
    )


def _resolve_step_health(model: EiNet, cfg: TrainConfig) -> bool:
    return model.health if cfg.health is None else bool(cfg.health)


def make_em_step(
    model: EiNet,
    cfg: TrainConfig = TrainConfig(),
    registry: Optional[compile_lib.ProgramRegistry] = None,
) -> Callable[[Dict[str, Any], jax.Array], Tuple[Dict[str, Any], jax.Array]]:
    """Build the jitted, donated-buffer EM update: (params, x) -> (params, ll).

    The returned callable is the training hot path: one XLA program per
    (param, batch) shape, old parameter buffers donated to the new ones.
    Steps are cached in the shared compiled-program registry
    (``repro.compile``) keyed by (model, mode/microbatches/EM config), so
    repeat calls with the same (model, cfg) return the SAME compiled callable
    -- the serve/train unification: one registry holds serving's AOT bucket
    programs and training's donated steps.

    With health telemetry resolved on (``TrainConfig.health``, else the
    model's knob) the step returns (params, ll, health_vector) instead --
    the extra output is computed inside the same compiled program.
    """
    if cfg.mode not in ("stochastic", "full"):
        raise ValueError(f"unknown mode {cfg.mode!r}; 'stochastic' or 'full'")
    update = (
        stochastic_em_update_microbatched
        if cfg.mode == "stochastic"
        else em_update_microbatched
    )
    health_on = _resolve_step_health(model, cfg)

    def step(params, x):
        return update(
            model, params, x, cfg.em, cfg.num_microbatches, cfg.axis_names,
            cfg.scan_microbatches, health=health_on,
        )

    donate_flag = _resolve_donate(cfg.donate)
    donate = (0,) if donate_flag else ()
    reg = registry if registry is not None else compile_lib.REGISTRY
    return reg.jit(
        model, _step_key(cfg, donate_flag, "em_step", health_on), step,
        donate_argnums=donate,
    )


def make_sharded_em_step(
    model: EiNet,
    cfg: TrainConfig,
    mesh,
) -> Callable[[Dict[str, Any], jax.Array], Tuple[Dict[str, Any], jax.Array]]:
    """The multi-host form of :func:`make_em_step`: shard_map over the data
    axes with the cross-shard statistics reduction made EXPLICIT.

    The batch is split over the mesh's data axes (``cfg.axis_names``,
    defaulting to every DP axis present); each shard computes its local
    scan-accumulated E-step statistics, ``psum``s the totals over
    ``axis_names`` (one collective on the statistics, not the activations --
    structurally a gradient all-reduce, per DESIGN.md §2), and every shard
    then runs the identical M-step/blend on identical totals, so the
    returned params are replicated by construction.

    Inside the manually-partitioned body the logical-axis rule table is
    disabled (``use_rules({})``): GSPMD constraints don't apply to manual
    axes, and the psum already fixes the only layout decision that matters.

    Health telemetry is NOT supported on this path (the vector would need
    its own replication spec for no operational win -- the single-shard
    probe in ``launch.train`` covers the same failure modes); the sharded
    step always returns the 2-tuple.
    """
    if cfg.mode not in ("stochastic", "full"):
        raise ValueError(f"unknown mode {cfg.mode!r}; 'stochastic' or 'full'")
    axes = tuple(cfg.axis_names) if cfg.axis_names else tuple(
        a for a in ("pod", "data") if a in mesh.shape
    )
    if not axes:
        raise ValueError(
            f"mesh {dict(mesh.shape)} has no data axis to shard the EM "
            "batch over; use make_em_step for single-shard training"
        )
    update = (
        stochastic_em_update_microbatched
        if cfg.mode == "stochastic"
        else em_update_microbatched
    )
    from jax.sharding import PartitionSpec as P

    from repro.dist import sharding as shlib

    def local(params, x):
        with shlib.use_rules({}):
            return update(
                model, params, x, cfg.em, cfg.num_microbatches, axes,
                cfg.scan_microbatches,
            )

    sharded = jax.shard_map(
        local,
        mesh=mesh,
        in_specs=(P(), P(axes if len(axes) > 1 else axes[0])),
        out_specs=(P(), P()),
        # psum'd statistics make the outputs replicated; rep-tracking can't
        # see through the update's tree_map, so assert it ourselves (tests)
        check_rep=False,
    )
    donate_flag = _resolve_donate(cfg.donate)
    donate = (0,) if donate_flag else ()
    return compile_lib.REGISTRY.jit(
        model, _step_key(cfg, donate_flag, "sharded_em_step") + (mesh,),
        sharded, donate_argnums=donate,
    )


def fit(
    model: EiNet,
    params: Dict[str, Any],
    batches: Any,
    cfg: TrainConfig = TrainConfig(),
    num_steps: Optional[int] = None,
    on_step: Optional[Callable[[int, float], None]] = None,
    health_policy: Optional[health_lib.HealthPolicy] = None,
) -> Tuple[Dict[str, Any], list]:
    """Convenience driver: run the compiled step over an iterable of batches.

    ``batches`` yields (B, D) arrays (or dicts with an "x" key).  Returns
    (final_params, per-step mean-LL list).  For the production loop with
    checkpoint-restart and sharded loaders, use ``repro.launch.train``.

    With health telemetry resolved on, every step's health vector feeds the
    ``train.health.*`` gauges and a :class:`repro.obs.health.HealthWatcher`
    (``health_policy`` configures it): a divergence dumps an incident bundle
    and -- under the default "abort" policy -- raises
    :class:`repro.obs.health.DivergenceError`.
    """
    step_fn = make_em_step(model, cfg)
    health_on = _resolve_step_health(model, cfg)
    watcher = (
        health_lib.HealthWatcher(model, health_policy) if health_on else None
    )
    lls: list = []
    for i, batch in enumerate(batches):
        if num_steps is not None and i >= num_steps:
            break
        x = batch["x"] if isinstance(batch, dict) else batch
        x = jnp.asarray(x)
        # float(ll) blocks on the device, so the timed region covers the
        # full step (dispatch + compute), not just dispatch
        with obs.timed("train.step", metric="train.step.seconds"):
            if health_on:
                params, ll, hv = step_fn(params, x)
            else:
                params, ll = step_fn(params, x)
                hv = None
            lls.append(float(ll))
        obs.METRICS.counter("train.examples.count").inc(int(x.shape[0]))
        obs.METRICS.gauge("train.ll.last").set(lls[-1])
        if watcher is not None:
            health_lib.publish(model.health_spec, hv)
            watcher.observe(i, hv, params)
        if on_step is not None:
            on_step(i, lls[-1])
    return params, lls
