"""Compiled EM training pipeline (the training-side twin of ``repro.serve``)."""

from repro.train.pipeline import (
    TrainConfig,
    em_update_microbatched,
    fit,
    make_em_step,
    make_sharded_em_step,
    microbatched_em_statistics,
    stochastic_em_update_microbatched,
)

__all__ = [
    "TrainConfig",
    "em_update_microbatched",
    "fit",
    "make_em_step",
    "make_sharded_em_step",
    "microbatched_em_statistics",
    "stochastic_em_update_microbatched",
]
