import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^^ MUST precede any jax import: jax locks the device count on first init.
"""Multi-pod dry-run: lower + compile every EiNet architecture's EM-step cell
on the production meshes, and extract the roofline inputs.

For each cell this produces artifacts/dryrun/<arch>__em_step__<mesh>.json with:
  * cost_analysis flops / bytes accessed       (compute & memory terms)
  * memory_analysis argument/output/temp bytes (fits-in-HBM evidence)
  * per-collective byte counts parsed from the post-SPMD HLO
    (all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute)
  * lowering/compile wall times

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch einet_rat --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""

import argparse
import json
import traceback
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro import obs
from repro.analysis.verify import VerifyError, verify_config, verify_einet
from repro.configs import REGISTRY, get_config
from repro.core import plan as plan_lib
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import make_production_mesh

from repro.launch.cells import lower_einet_cell  # noqa: E402


def run_cell(arch: str, mesh_kind: str, out_dir: str,
             skip_existing: bool = True) -> Optional[Dict[str, Any]]:
    cfg = get_config(arch)
    multi_pod = mesh_kind == "multi"
    tag = f"{arch}__em_step__{'2x16x16' if multi_pod else '16x16'}"
    path = os.path.join(out_dir, tag.replace("/", "_") + ".json")
    if skip_existing and os.path.exists(path):
        print(f"[skip-cached] {tag}")
        with open(path) as f:
            return json.load(f)

    mesh = make_production_mesh(multi_pod=multi_pod)
    print(f"[lower] {tag} ...", flush=True)
    try:
        with jax.set_mesh(mesh):
            lowered, t_lower, model = lower_einet_cell(cfg, mesh, multi_pod)
            print(f"[plan] {arch}: "
                  f"{plan_lib.format_summary(model.grouping_summary())}",
                  flush=True)
            report = verify_einet(model, name=arch)
            print(f"[verify] {arch}: {report.summary()}", flush=True)
            if not report.ok:
                raise VerifyError(report)
            with obs.timed("compile.cell", arch=arch) as t:
                compiled = lowered.compile()
            t_compile = t.seconds
        cost = compiled.cost_analysis()
        ma = compiled.memory_analysis()
        hlo = compiled.as_text()
        # scan-aware per-device totals (XLA cost_analysis counts while bodies
        # once; analyze_hlo multiplies by known_trip_count -- see hlo_analysis)
        corr = analyze_hlo(hlo)
        rec = {
            "arch": arch,
            "shape": "em_step",
            "mesh": "2x16x16" if multi_pod else "16x16",
            "num_devices": int(np.prod(list(mesh.shape.values()))),
            "kind": "train",
            # raw XLA aggregate (loop bodies counted once) -- kept for reference
            "xla_flops_raw": float(cost.get("flops", -1)),
            "xla_bytes_raw": float(cost.get("bytes accessed", -1)),
            # corrected per-device totals
            "flops_per_device": corr["flops"],
            "bytes_written_per_device": corr["bytes_written"],
            "collectives": corr["collectives"],
            "collective_bytes_per_device": corr["collective_bytes"],
            "memory": {
                "argument_bytes": ma.argument_size_in_bytes,
                "output_bytes": ma.output_size_in_bytes,
                "temp_bytes": ma.temp_size_in_bytes,
                "alias_bytes": ma.alias_size_in_bytes,
            },
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "param_count": None,
            "active_param_count": None,
            "grouping": model.grouping_summary(),
            "hlo_bytes": len(hlo),
        }
        os.makedirs(out_dir, exist_ok=True)
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        print(f"[ok] {tag}: {rec['flops_per_device']:.3e} flops/dev, "
              f"{rec['collective_bytes_per_device']:.3e} coll B/dev, "
              f"compile {t_compile:.1f}s", flush=True)
        return rec
    except Exception as e:  # noqa: BLE001 -- a failed cell is a bug; record it
        rec = {"arch": arch, "shape": "em_step", "mesh": mesh_kind,
               "error": repr(e), "traceback": traceback.format_exc()}
        os.makedirs(out_dir, exist_ok=True)
        with open(path + ".err", "w") as f:
            json.dump(rec, f, indent=1)
        print(f"[FAIL] {tag}: {e}", flush=True)
        return rec


def run_verify(archs) -> int:
    """Static circuit/plan verification per arch (no lowering, no mesh):
    the ``--verify`` CI gate.  Returns the number of failing archs."""
    failures = 0
    for arch in archs:
        report = verify_config(get_config(arch))
        print(f"[verify] {arch}: {report.summary()}", flush=True)
        for finding in report.findings:
            print(f"  - {finding}", flush=True)
        failures += 0 if report.ok else 1
    return failures


# archs whose parameter pytree exceeds this many floats skip the numerical
# probe (an eager forward on einet_rat_large's 530M params is a dry-run
# budget, not a smoke test)
PROBE_PARAM_FLOOR = 80_000_000
PROBE_BATCH = 8


def _probe_data(model, batch: int) -> np.ndarray:
    """A batch in the arch's EF data domain (lgamma/one-hot blow up on
    out-of-domain floats, which would make the probe report false alarms)."""
    rng = np.random.RandomState(0)
    name = model.ef.name
    if name == "binomial":
        hi = model.ef.n_trials
        return rng.randint(0, hi + 1, (batch, model.num_vars)).astype(
            np.float32)
    if name == "categorical":
        hi = model.ef.num_categories
        return rng.randint(0, hi, (batch, model.num_vars)).astype(np.float32)
    if name == "bernoulli":
        return rng.randint(0, 2, (batch, model.num_vars)).astype(np.float32)
    return rng.randn(batch, model.num_vars).astype(np.float32)


def run_health_probe(archs, out_dir: str = "artifacts/health") -> int:
    """Numerical-health probe per arch: one eager forward at init params
    through the tap sites (``repro.obs.health``), recording per-segment
    saturation and batch-LL health to ``artifacts/health/<arch>.json``.

    Catches init-time numerical rot (a config whose leaves saturate on
    in-domain data before training even starts) that static verification
    can't see.  Probe *errors* warn and are recorded but do not fail the
    gate -- only a non-finite LL on in-domain data counts as a failure.
    Returns the number of failing archs.
    """
    import jax.numpy as jnp

    from repro.launch.cells import build_einet
    from repro.obs import health as health_lib

    failures = 0
    os.makedirs(out_dir, exist_ok=True)
    for arch in archs:
        path = os.path.join(out_dir, arch.replace("/", "_") + ".json")
        try:
            model = build_einet(get_config(arch))
            shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
            n_params = sum(
                int(np.prod(s.shape))
                for s in jax.tree_util.tree_leaves(shapes)
            )
            if n_params > PROBE_PARAM_FLOOR:
                rec = {"arch": arch, "skipped": True,
                       "num_params": n_params,
                       "reason": f"param count {n_params} > probe floor "
                                 f"{PROBE_PARAM_FLOOR}"}
                print(f"[health] {arch}: skipped ({n_params/1e6:.0f}M "
                      "params)", flush=True)
            else:
                params = model.init(jax.random.PRNGKey(0))
                x = jnp.asarray(_probe_data(model, PROBE_BATCH))
                e = model.leaf_log_prob(params, x, None)
                leaf_rows = model._leaf_rows(e)
                with health_lib.collect() as taps:
                    root = model.forward_from_e(
                        params["einsum"], params["mixing"], None,
                        leaf_rows=leaf_rows,
                    )
                ll = jax.scipy.special.logsumexp(
                    root + jnp.log(params["class_prior"])[None, :], axis=-1
                )
                ll = np.asarray(ll)
                rec = {
                    "arch": arch,
                    "skipped": False,
                    "num_params": n_params,
                    "probe_batch": PROBE_BATCH,
                    "ll_mean": float(np.mean(ll)),
                    "ll_min": float(np.min(ll)),
                    "ll_nonfinite": int(np.sum(~np.isfinite(ll))),
                    "leaf_sat_frac": float(
                        health_lib.saturation_fraction(leaf_rows)),
                    "segment_sat_frac": [float(t) for t in taps],
                }
                ok = rec["ll_nonfinite"] == 0
                failures += 0 if ok else 1
                print(f"[health] {arch}: ll mean {rec['ll_mean']:.2f} "
                      f"min {rec['ll_min']:.2f}, leaf sat "
                      f"{rec['leaf_sat_frac']:.3f}, "
                      f"{len(taps)} segment(s)"
                      + ("" if ok else "  <-- NON-FINITE"), flush=True)
        except Exception as e:  # noqa: BLE001 -- probe breakage must not
            # mask the verify gate; record and move on
            rec = {"arch": arch, "skipped": True, "reason": repr(e)}
            print(f"[health] {arch}: probe error (not fatal): {e}",
                  flush=True)
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
    return failures


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--mesh", choices=("single", "multi", "both"), default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--verify", action="store_true",
                    help="run the static circuit/plan verifier over the "
                         "selected archs and exit (non-zero on any failed "
                         "invariant); no lowering or compilation")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="collect obs tracing spans and export a "
                         "Chrome-trace JSON to this path at exit")
    args = ap.parse_args()
    obs.cli_begin(args.trace)

    meshes = {"single": ["single"], "multi": ["multi"],
              "both": ["single", "multi"]}[args.mesh]
    if args.all or args.arch is None:
        archs = sorted(REGISTRY)
    else:
        archs = [args.arch]

    if args.verify:
        failures = run_verify(archs)
        failures += run_health_probe(archs)
        if failures:
            raise SystemExit(f"{failures} arch(s) failed verification")
        print(f"verification complete: {len(archs)} arch(s) clean")
        obs.cli_end(args.trace)
        return

    failures = 0
    for mesh_kind in meshes:
        for arch in archs:
            rec = run_cell(arch, mesh_kind, args.out,
                           skip_existing=not args.force)
            if rec and "error" in rec:
                failures += 1
    if failures:
        raise SystemExit(f"{failures} cells failed")
    print("dry-run complete")
    obs.cli_end(args.trace)


if __name__ == "__main__":
    main()
