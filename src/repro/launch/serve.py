"""Serving driver: the batched exact-inference engine (``repro.serve``).

A mixed stream of joint/marginal/conditional LL, sampling and MPE requests
is coalesced into padded per-kind micro-batches and executed through the
compiled-program cache; warm-up (compilation) and steady-state throughput
are reported separately, against the direct one-call-at-a-time baseline.

  PYTHONPATH=src python -m repro.launch.serve --arch einet_rat --requests 64
"""

from __future__ import annotations

import argparse

import jax

from repro import serve as serve_lib
from repro.configs import get_config
from repro.launch import cells as dr


def serve_einet(cfg, args):
    model = dr.build_einet(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n = args.requests
    reqs = serve_lib.mixed_requests(model.num_vars, n, seed=0)
    report = serve_lib.run_benchmark(
        model, params, reqs, max_batch=args.max_batch, reps=args.reps
    )
    print(serve_lib.format_report(report))
    if report["parity_max_abs_diff"] > 1e-5:
        raise SystemExit(
            f"engine/direct parity violated: {report['parity_max_abs_diff']:.2e}"
        )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-batch", type=int, default=0,
                    help="engine micro-batch cap (0 = min(32, requests))")
    ap.add_argument("--reps", type=int, default=3,
                    help="steady-state measurement repetitions")
    args = ap.parse_args()
    serve_einet(get_config(args.arch), args)


if __name__ == "__main__":
    main()
