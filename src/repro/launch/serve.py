"""Serving driver: batched prefill + decode against the KV/state cache, with
continuous-batching-style slot management.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --smoke \
      --requests 6 --max-new 12

The EiNet path (``--arch einet_rat``) drives the batched exact-inference
engine (``repro.serve``): a mixed stream of joint/marginal/conditional LL,
sampling and MPE requests is coalesced into padded per-kind micro-batches
and executed through the compiled-program cache; warm-up (compilation) and
steady-state throughput are reported separately, against the direct
one-call-at-a-time baseline.

  PYTHONPATH=src python -m repro.launch.serve --arch einet_rat --requests 64
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import serve as serve_lib
from repro.configs import EinetConfig, get_config, smoke_variant
from repro.launch import cells as dr
from repro.models import lm


def serve_lm(cfg, args):
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    max_len = args.prompt_len + args.max_new
    prefill = jax.jit(lambda p, b: lm.prefill(cfg, p, b, max_len=max_len))
    decode = jax.jit(lm.decode_step, static_argnums=0)

    # batch of requests (continuous batching: one shared cache, slot = row)
    if cfg.embedding_input:
        prompts = {"inputs_embeds": jnp.asarray(
            rng.randn(args.requests, args.prompt_len, cfg.d_model), jnp.float32) * 0.1}
    else:
        prompts = {"tokens": jnp.asarray(
            rng.randint(0, cfg.vocab_size, (args.requests, args.prompt_len)))}
    t0 = time.time()
    logits, cache, pos = prefill(params, prompts)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0
    tok = jnp.argmax(logits[:, -1:], axis=-1)
    out = [np.asarray(tok)[:, 0]]
    t0 = time.time()
    for _ in range(args.max_new - 1):
        if cfg.embedding_input:
            step_in = {"inputs_embeds": jnp.asarray(
                rng.randn(args.requests, 1, cfg.d_model), jnp.float32) * 0.1}
        else:
            step_in = {"tokens": tok}
        logits, cache = decode(cfg, params, step_in, cache, pos)
        pos = pos + 1
        tok = jnp.argmax(logits[:, -1:], axis=-1)
        out.append(np.asarray(tok)[:, 0])
    jax.block_until_ready(logits)
    t_decode = time.time() - t0
    gen = np.stack(out, 1)
    print(f"prefill: {args.requests} x {args.prompt_len} tokens in "
          f"{t_prefill*1e3:.0f} ms")
    print(f"decode:  {args.max_new-1} steps x {args.requests} seqs in "
          f"{t_decode*1e3:.0f} ms "
          f"({t_decode/(args.max_new-1)*1e3:.1f} ms/step)")
    print("generations (greedy):")
    for i, row in enumerate(gen[: min(4, len(gen))]):
        print(f"  req{i}: {row.tolist()}")


def serve_einet(cfg, args):
    model = dr.build_einet(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n = args.requests
    reqs = serve_lib.mixed_requests(model.num_vars, n, seed=0)
    report = serve_lib.run_benchmark(
        model, params, reqs, max_batch=args.max_batch, reps=args.reps
    )
    print(serve_lib.format_report(report))
    if report["parity_max_abs_diff"] > 1e-5:
        raise SystemExit(
            f"engine/direct parity violated: {report['parity_max_abs_diff']:.2e}"
        )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=0,
                    help="einet: engine micro-batch cap (0 = min(32, requests))")
    ap.add_argument("--reps", type=int, default=3,
                    help="einet: steady-state measurement repetitions")
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    cfg = get_config(args.arch)
    if isinstance(cfg, EinetConfig):
        serve_einet(cfg, args)
    else:
        if args.smoke:
            cfg = smoke_variant(cfg)
        serve_lm(cfg, args)


if __name__ == "__main__":
    main()
