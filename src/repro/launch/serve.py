"""Serving driver: the batched exact-inference engine (``repro.serve``).

A mixed stream of joint/marginal/conditional LL, sampling and MPE requests
is coalesced into padded per-kind micro-batches and executed through the
compiled-program cache; warm-up (compilation) and steady-state throughput
are reported separately, against the direct one-call-at-a-time baseline.

  PYTHONPATH=src python -m repro.launch.serve --arch einet_rat --requests 64
  PYTHONPATH=src python -m repro.launch.serve --smoke --trace /tmp/trace.json
"""

from __future__ import annotations

import argparse

import jax

from repro import obs
from repro import serve as serve_lib
from repro.configs import EinetConfig, get_config
from repro.launch import cells as dr

# CI trace-smoke profile: the same tiny all-grouping RAT shape as
# benchmarks/bench_serve.py (32 vars = the smallest RAT whose scopes don't
# collide across repetitions, so the smoke serves the grouped plan); kept
# local because the launch CLIs only see src/ on PYTHONPATH
SMOKE_CONFIG = EinetConfig(
    name="einet-rat-serve-smoke",
    structure="rat",
    num_vars=32,
    depth=2,
    num_repetitions=2,
    num_sums=4,
    batch_size=64,
)


def serve_einet(cfg, args):
    model = dr.build_einet(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n = args.requests
    reqs = serve_lib.mixed_requests(model.num_vars, n, seed=0)
    report = serve_lib.run_benchmark(
        model, params, reqs, max_batch=args.max_batch, reps=args.reps
    )
    print(serve_lib.format_report(report))
    if report["parity_max_abs_diff"] > 1e-5:
        raise SystemExit(
            f"engine/direct parity violated: {report['parity_max_abs_diff']:.2e}"
        )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny built-in arch + short stream (the CI "
                         "trace-smoke profile); --arch not required")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-batch", type=int, default=0,
                    help="engine micro-batch cap (0 = min(32, requests))")
    ap.add_argument("--reps", type=int, default=3,
                    help="steady-state measurement repetitions")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="collect obs tracing spans and export a "
                         "Chrome-trace JSON to this path at exit")
    ap.add_argument("--metrics", default=None, metavar="OUT.json",
                    help="write the METRICS.snapshot() JSON to this path "
                         "at exit")
    args = ap.parse_args()
    if not args.smoke and args.arch is None:
        ap.error("--arch is required (or pass --smoke)")
    obs.cli_begin(args.trace)
    cfg = SMOKE_CONFIG if args.smoke else get_config(args.arch)
    serve_einet(cfg, args)
    obs.cli_end(args.trace, args.metrics)


if __name__ == "__main__":
    main()
