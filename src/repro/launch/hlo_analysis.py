"""Scan-aware HLO cost analyzer for the dry-run roofline.

``compiled.cost_analysis()`` counts each ``while`` body ONCE, but our layer
stacks / attention tiles / CE chunks are all ``lax.scan`` loops -- the real
FLOPs are body x trip_count.  XLA records ``known_trip_count`` in each while
op's backend_config after loop simplification, so we reconstruct the true
per-device totals from the post-SPMD HLO text:

  * matmul FLOPs: every ``dot`` op contributes
    2 * prod(result_dims) * prod(lhs_contracting_dims)
  * collective bytes: result-buffer bytes of every all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute
  * bytes_written: result-buffer bytes of every non-tuple op (an HBM-traffic
    proxy: every materialized buffer is written once and read >= once; fusion
    internals correctly stay invisible)

each multiplied by the product of enclosing loop trip counts (computed
bottom-up over the computation call graph).  Validated against analytic
FLOP counts in tests/test_roofline.py.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}
_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)
_SHAPE_RE = re.compile(r"([a-z]\w*)\[([\d,]*)\]")
_TRIP_RE = re.compile(r'known_trip_count\\?":{\\?"n\\?":\\?"(\d+)\\?"')
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")


def _shape_bytes(text: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _split_type_and_rest(rhs: str) -> Tuple[str, str]:
    """Split '<type expr> opcode(...)' -> (type_expr, remainder)."""
    rhs = rhs.lstrip()
    if rhs.startswith("("):
        depth = 0
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    return rhs[: i + 1], rhs[i + 1:]
        return rhs, ""
    m = re.match(r"([a-z]\w*\[[\d,]*\](?:\{[^}]*\})?)", rhs)
    if m:
        return m.group(1), rhs[m.end():]
    return "", rhs


_OPCODE_RE = re.compile(r"^\s*([\w\-]+)\(")


@dataclasses.dataclass
class CompStats:
    flops: float = 0.0
    bytes_written: float = 0.0
    coll: Optional[Dict[str, Dict[str, float]]] = None
    children: Optional[List[Tuple[str, float]]] = None  # (callee, multiplier)

    def __post_init__(self):
        if self.coll is None:
            self.coll = {c: {"count": 0.0, "bytes": 0.0} for c in _COLLECTIVES}
        if self.children is None:
            self.children = []


def _dot_contract(rest: str, symbols: Dict[str, List[int]]) -> float:
    """Product of contracted-dim sizes for a dot op.

    Operands are name references (`dot(%a, %b)`); shapes come from the
    per-computation symbol table.  Falls back to inline shapes if present.
    """
    inner_start = rest.find("(")
    depth, i = 0, inner_start
    while i < len(rest):
        if rest[i] == "(":
            depth += 1
        elif rest[i] == ")":
            depth -= 1
            if depth == 0:
                break
        i += 1
    args = rest[inner_start + 1: i]
    attrs = rest[i + 1:]
    shapes = _SHAPE_RE.findall(args)
    if shapes:
        lhs_dims = [int(d) for d in shapes[0][1].split(",") if d]
    else:
        m = re.match(r"\s*%?([\w\.\-]+)", args)
        lhs_dims = symbols.get(m.group(1), []) if m else []
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", attrs)
    contract = 1.0
    if m and lhs_dims:
        for idx in m.group(1).split(","):
            if idx:
                contract *= lhs_dims[int(idx)]
    elif not lhs_dims:
        return 0.0
    return contract


def analyze_hlo(text: str) -> Dict[str, object]:
    """Returns dict with corrected per-device flops / bytes / collectives."""
    comps: Dict[str, CompStats] = {}
    symbols: Dict[str, List[int]] = {}
    current: Optional[str] = None
    entry: Optional[str] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        if not stripped:
            continue
        # computation header?
        if line.endswith("{") and ("->" in line or stripped.startswith("ENTRY")):
            m = re.match(r"\s*(ENTRY\s+)?%?([\w\.\-]+)\s*\(", line)
            if m:
                current = m.group(2)
                comps[current] = CompStats()
                symbols = {}
                if m.group(1):
                    entry = current
            continue
        if stripped == "}":
            continue
        if current is None:
            continue
        m = re.match(r"(ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$", stripped)
        if not m:
            continue
        result_name = m.group(2)
        rhs = m.group(3)
        type_expr, rest = _split_type_and_rest(rhs)
        opm = _OPCODE_RE.match(rest)
        if not opm:
            continue
        op = opm.group(1)
        cs = comps[current]
        result_bytes = _shape_bytes(type_expr)
        # record result shape (non-tuple ops) for dot operand lookups
        shp = _SHAPE_RE.findall(type_expr)
        if len(shp) == 1 and not type_expr.lstrip().startswith("("):
            symbols[result_name] = [int(d) for d in shp[0][1].split(",") if d]
        if op not in ("tuple", "get-tuple-element", "parameter", "constant"):
            cs.bytes_written += result_bytes
        if op == "dot":
            elems = 0.0
            for dt, dims in _SHAPE_RE.findall(type_expr):
                n = 1
                for d in dims.split(","):
                    if d:
                        n *= int(d)
                elems += n
            cs.flops += 2.0 * elems * _dot_contract(rest, symbols)
        elif op in _COLLECTIVES:
            cs.coll[op]["count"] += 1
            cs.coll[op]["bytes"] += result_bytes
        elif op == "while":
            body = _BODY_RE.search(rest)
            trip = _TRIP_RE.search(rest)
            n = float(trip.group(1)) if trip else 1.0
            if body:
                cs.children.append((body.group(1), n))
        elif op in ("call", "fusion", "conditional", "async-start"):
            cm = re.search(r"(?:calls|to_apply)=%?([\w\.\-]+)", rest)
            if cm and op == "call":
                cs.children.append((cm.group(1), 1.0))
            # fusions: bodies are element-wise; their cost is the result
            # buffer already counted above.  (CPU keeps dots un-fused.)

    # bottom-up totals with memoization
    memo: Dict[str, Tuple[float, float, Dict[str, Dict[str, float]]]] = {}

    def total(name: str, stack=()) -> Tuple[float, float, Dict]:
        if name in memo:
            return memo[name]
        if name in stack or name not in comps:
            return 0.0, 0.0, {c: {"count": 0, "bytes": 0.0} for c in _COLLECTIVES}
        cs = comps[name]
        f, b = cs.flops, cs.bytes_written
        coll = {c: dict(v) for c, v in cs.coll.items()}
        for child, mult in cs.children:
            cf, cb, cc = total(child, stack + (name,))
            f += mult * cf
            b += mult * cb
            for c in _COLLECTIVES:
                coll[c]["count"] += mult * cc[c]["count"]
                coll[c]["bytes"] += mult * cc[c]["bytes"]
        memo[name] = (f, b, coll)
        return memo[name]

    if entry is None:
        # fall back: the computation with the largest own cost
        entry = max(comps, key=lambda n: comps[n].flops + comps[n].bytes_written)
    f, b, coll = total(entry)
    return {
        "flops": f,
        "bytes_written": b,
        "collectives": coll,
        "collective_bytes": sum(c["bytes"] for c in coll.values()),
        "entry": entry,
        "num_computations": len(comps),
    }
