"""Production training driver: ``--arch`` selects any registered config
(LM or EiNet), builds the mesh, installs sharding rules, and runs the
fault-tolerant loop with sharded data, checkpointing, and restart.

On real hardware this runs under ``jax.distributed.initialize()`` with one
process per host; on this container it runs the same code path on however
many devices exist (``--devices`` lets CI exercise the multi-device path via
XLA_FLAGS).

  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
      --smoke --steps 20
  PYTHONPATH=src python -m repro.launch.train --arch einet_rat --steps 50
"""

from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import EinetConfig, get_config, smoke_variant
from repro.configs.base import ShapeSpec
from repro.core.em import EMConfig, stochastic_em_update
from repro.data import synthetic
from repro.data.pipeline import ShardedLoader, lm_loader
from repro.dist import fault_tolerance as ft
from repro.dist import sharding as shlib
from repro.launch import cells as dr
from repro.launch.mesh import dp_shards, make_mesh_for
from repro.models import lm
from repro.optim import adamw


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU-friendly)")
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="artifacts/ckpt")
    ap.add_argument("--checkpoint-every", type=int, default=25)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    mesh = make_mesh_for(model_parallel=args.model_parallel)
    rules = shlib.default_rules(multi_pod=False, fsdp=False)
    mgr = CheckpointManager(
        os.path.join(args.ckpt_dir, args.arch.replace("/", "_"))
    )

    with shlib.use_rules(rules), jax.set_mesh(mesh):
        if isinstance(cfg, EinetConfig):
            model = dr.build_einet(cfg)
            params = model.init(jax.random.PRNGKey(0))
            d = model.num_vars
            data = synthetic.gaussian_mixture_images(
                4096, 16, max(d // 48, 1), 3, seed=0
            )[:, :d] if cfg.structure == "pd" else np.random.RandomState(0).randn(
                4096, d).astype(np.float32)
            loader = ShardedLoader(
                lambda s, sh, n: {"x": data[(np.arange(n) + s * n) % len(data)]},
                global_batch=args.batch * 32,
            )
            step_jit = jax.jit(lambda p, b: stochastic_em_update(
                model, p, b, EMConfig()))

            def step_fn(state, batch):
                p, ll = step_jit(state["params"], jnp.asarray(batch["x"]))
                state["last_ll"] = float(ll)
                return {"params": p, "step": state["step"] + 1,
                        "last_ll": state["last_ll"]}

            init_state = {"params": params, "step": jnp.zeros((), jnp.int32),
                          "last_ll": 0.0}
        else:
            if args.smoke:
                cfg = smoke_variant(cfg)
            params = lm.init_params(cfg, jax.random.PRNGKey(0))
            ocfg = adamw.AdamWConfig(warmup_steps=10, decay_steps=args.steps * 2)
            opt = adamw.init_state(ocfg, params)
            shape = ShapeSpec("cli", "train", args.seq, args.batch)
            loader = lm_loader(cfg, shape, num_shards=1, shard_id=0)
            step_jit = jax.jit(lambda p, o, b: lm.train_step(cfg, ocfg, p, o, b))

            def step_fn(state, batch):
                b = {k: jnp.asarray(v) for k, v in batch.items()}
                p, o, m = step_jit(state["params"], state["opt"], b)
                state["last_ll"] = -float(m["loss"])
                return {"params": p, "opt": o, "step": state["step"] + 1,
                        "last_ll": state["last_ll"]}

            init_state = {"params": params, "opt": opt,
                          "step": jnp.zeros((), jnp.int32), "last_ll": 0.0}

        t0 = time.time()
        lls = []
        state, stats = ft.run_training(
            step_fn, init_state, loader.batch_at, mgr, args.steps,
            ft.LoopConfig(checkpoint_every=args.checkpoint_every),
            on_step=lambda s, st: lls.append(st["last_ll"]),
        )
    dt = time.time() - t0
    print(f"{args.arch}: {args.steps} steps, {dt/max(args.steps,1)*1e3:.0f} "
          f"ms/step, dp_shards={dp_shards(mesh)}, restarts={stats['restarts']}")
    print(f"objective: first {np.mean(lls[:5]):.3f} -> last {np.mean(lls[-5:]):.3f}")


if __name__ == "__main__":
    main()
