"""Production training driver: ``--arch`` selects a registered EiNet config,
builds the mesh, installs sharding rules, and runs the fault-tolerant loop
with sharded data, checkpointing, and restart.

On real hardware this runs under ``jax.distributed.initialize()`` with one
process per host; on this container it runs the same code path on however
many devices exist (``--devices`` lets CI exercise the multi-device path via
XLA_FLAGS).

  PYTHONPATH=src python -m repro.launch.train --arch einet_rat --steps 50
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.checkpoint import CheckpointManager
from repro.configs import EinetConfig, get_config
from repro.core import plan as plan_lib
from repro.data import datasets as ds_lib
from repro.data import synthetic
from repro.data.pipeline import ShardedLoader
from repro.dist import fault_tolerance as ft
from repro.dist import sharding as shlib
from repro.launch import cells as dr
from repro.launch.mesh import dp_shards, make_mesh_for
from repro.obs import health as health_lib
from repro.train import TrainConfig, make_em_step, make_sharded_em_step

# --smoke: the CI trace-smoke profile -- a RAT shape small enough to train
# in seconds on CPU but deep enough to depth-group, with health telemetry
# forced on so the trace/metrics gates see train.health.* populated
SMOKE_CONFIG = EinetConfig(
    name="einet-rat-train-launch-smoke",
    structure="rat",
    num_vars=32,
    depth=2,
    num_repetitions=2,
    num_sums=4,
    batch_size=64,
)


def einet_loader(
    data: np.ndarray,
    global_batch: int,
    num_shards: int = 1,
    shard_id: int = 0,
    start_step: int = 0,
) -> ShardedLoader:
    """Deterministic EiNet loader: shard ``sh`` of step ``s`` reads the
    contiguous row block ``[(s * num_shards + sh) * n, ...)`` (mod data), so
    shards within a step are DISJOINT and steps tile the dataset.

    Delegates to ``repro.data.datasets.array_loader`` (the scheme moved there
    with the image datasets); this name stays as the launch-facing alias the
    disjointness regression test pins (tests/test_train.py -- the pre-PR-3
    inline lambda ignored its shard argument, silently shrinking the
    effective batch num_shards-fold).
    """

    return ds_lib.array_loader(
        data, global_batch, num_shards=num_shards, shard_id=shard_id,
        start_step=start_step,
    )


def einet_train_data(cfg: EinetConfig, dataset: str, data_dir: str) -> np.ndarray:
    """Resolve the EiNet training array for ``--dataset``.

    "synthetic" keeps the pre-image-workbench behaviour (mixture images for
    PD structures, white noise for RAT).  "mnist"/"svhn" load the real
    dataset (npz cache -> download), falling back to the deterministic
    procedural generator on offline hosts so the driver always runs; the
    chosen source is printed so logs record what was actually trained on.
    """
    d = (cfg.height * cfg.width * cfg.num_channels
         if cfg.structure == "pd" else cfg.num_vars)
    if dataset == "synthetic":
        if cfg.structure == "pd":
            # round the proxy width UP so the slice always covers d (the
            # old floor-division under-generated for d not divisible by 48,
            # e.g. einet_pd_mnist's 784 -> 768-dim batches -> shape error)
            return synthetic.gaussian_mixture_images(
                4096, 16, -(-d // 48), 3, seed=0
            )[:, :d]
        return np.random.RandomState(0).randn(4096, d).astype(np.float32)
    try:
        ds = ds_lib.load_image_dataset(dataset, data_dir=data_dir)
    except ds_lib.DatasetUnavailable as e:
        print(f"[train] {e}; using the procedural fallback")
        ds = ds_lib.load_image_dataset(dataset, data_dir=data_dir,
                                       source="procedural")
    print(f"[train] dataset {dataset} ({ds.source}): "
          f"{len(ds.train_x)} train rows")
    data, _ = ds_lib.to_domain(ds.train_x, cfg.exponential_family)
    if data.shape[1] != d:
        raise SystemExit(
            f"--dataset {dataset} has {data.shape[1]} dims but --arch "
            f"{cfg.name} models {d}; pick the matching PD config "
            "(einet_pd_mnist for mnist, einet_pd for svhn, einet_celeba "
            "for celeba)"
        )
    return data


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="registered EiNet config (required unless --smoke)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny built-in arch, few steps, health telemetry "
                         "on (CI trace-smoke profile)")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="artifacts/ckpt")
    ap.add_argument("--checkpoint-every", type=int, default=25)
    ap.add_argument("--microbatches", type=int, default=1,
                    help="EiNet: scan-accumulate E-step statistics over this "
                         "many microbatches inside the compiled step")
    ap.add_argument("--em-mode", choices=("stochastic", "full"),
                    default="stochastic")
    ap.add_argument("--dataset",
                    choices=("synthetic", "mnist", "svhn", "celeba"),
                    default="synthetic",
                    help="EiNet training data (real datasets cache under "
                         "--data-dir; offline hosts fall back to the "
                         "procedural generator)")
    ap.add_argument("--data-dir", default=ds_lib.DEFAULT_DATA_DIR)
    ap.add_argument("--mixture", type=int, default=0,
                    help="EiNet: train a mixture of this many components "
                         "over k-means data clusters (§4.2 CelebA protocol) "
                         "with one vmapped lockstep EM update; 0 = single "
                         "model")
    ap.add_argument("--mixture-assign", choices=("hard", "soft"),
                    default="hard",
                    help="mixture E-step: hard per-cluster EM on stacked "
                         "batches, or soft responsibility-weighted EM on a "
                         "shared batch")
    ap.add_argument("--dist-em", action="store_true",
                    help="EiNet: use the shard_map psum-EM step over the "
                         "mesh's data axes (implied by multi-process runs)")
    ap.add_argument("--health", action="store_true",
                    help="force device-side health telemetry on (defaults "
                         "to the model knob / REPRO_HEALTH; implied by "
                         "--smoke; unsupported with --dist-em)")
    ap.add_argument("--on-divergence", choices=("abort", "continue"),
                    default="abort",
                    help="flight-recorder policy when the health vector "
                         "trips: dump an incident bundle then abort (raise) "
                         "or keep training")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="collect obs tracing spans and export a "
                         "Chrome-trace JSON to this path at exit")
    ap.add_argument("--metrics", default=None, metavar="OUT.json",
                    help="write the METRICS.snapshot() JSON (including "
                         "train.health.* gauges) to this path at exit")
    args = ap.parse_args()
    if args.arch is None and not args.smoke:
        ap.error("--arch is required (or pass --smoke)")
    if args.steps is None:
        args.steps = 8 if args.smoke else 50
    obs.cli_begin(args.trace)

    if args.smoke:
        cfg = SMOKE_CONFIG
        args.arch = args.arch or cfg.name
    else:
        cfg = get_config(args.arch)
    mesh = make_mesh_for(model_parallel=args.model_parallel)
    rules = shlib.default_rules(multi_pod=False, fsdp=False)
    mgr = CheckpointManager(
        os.path.join(args.ckpt_dir, args.arch.replace("/", "_"))
    )

    with shlib.use_rules(rules), jax.set_mesh(mesh):
        if args.mixture >= 2:
            # §4.2 mixture-of-EiNets: k-means the data, stack C components,
            # advance them all with ONE vmapped jitted EM step.  (Mixture
            # training is single-process for now -- the stacked component
            # axis is not in the dist rule table yet.)
            if jax.process_count() > 1 or args.dist_em:
                raise SystemExit(
                    "--mixture does not compose with --dist-em / "
                    "multi-process yet; run single-process"
                )
            from repro import mixture as mx

            base = dr.build_einet(cfg)
            print(f"[plan] {args.arch}: "
                  f"{plan_lib.format_summary(base.grouping_summary())}")
            model = mx.EiNetMixture(base, args.mixture)
            data = einet_train_data(cfg, args.dataset, args.data_dir)
            mcfg = mx.MixtureTrainConfig(
                assign=args.mixture_assign, mode=args.em_mode,
                num_microbatches=args.microbatches, donate=False,
            )
            if args.mixture_assign == "hard":
                params, loader, km = mx.prepare_mixture_training(
                    model, data, seed=0, global_batch=args.batch * 32,
                )
                print(f"[train] k-means clusters: {km.counts.tolist()} "
                      f"(inertia {km.inertia:.4f})")
            else:
                params = model.init(jax.random.PRNGKey(0))
                loader = einet_loader(data, args.batch * 32)
            step_jit = mx.make_mixture_em_step(model, mcfg)

            def step_fn(state, batch):
                x = jnp.asarray(batch["x"])
                with obs.timed("train.step", metric="train.step.seconds"):
                    p, ll = step_jit(state["params"], x)
                    state["last_ll"] = float(ll)
                obs.METRICS.counter("train.examples.count").inc(
                    int(x.shape[0]))
                obs.METRICS.gauge("train.ll.last").set(state["last_ll"])
                return {"params": p, "step": state["step"] + 1,
                        "last_ll": state["last_ll"]}

            init_state = {"params": params, "step": jnp.zeros((), jnp.int32),
                          "last_ll": 0.0}
        else:
            model = dr.build_einet(cfg)
            print(f"[plan] {args.arch}: "
                  f"{plan_lib.format_summary(model.grouping_summary())}")
            params = model.init(jax.random.PRNGKey(0))
            data = einet_train_data(cfg, args.dataset, args.data_dir)
            loader = einet_loader(
                data, args.batch * 32,
                num_shards=jax.process_count(), shard_id=jax.process_index(),
            )
            # the whole EM update -- scan-accumulated E-step, M-step, blend --
            # is ONE compiled program.  donate=False: ft.run_training's
            # replay-from-init recovery path re-feeds the initial params when
            # a failure precedes the first committed checkpoint, so the step
            # must not consume them.
            # health telemetry: --smoke/--health force it on, otherwise the
            # model knob (REPRO_HEALTH) decides; the sharded psum-EM step
            # does not support the extra output, so --dist-em keeps it off
            dist = args.dist_em or jax.process_count() > 1
            health_knob = (
                False if dist
                else (True if (args.smoke or args.health) else None)
            )
            tcfg = TrainConfig(
                mode=args.em_mode, num_microbatches=args.microbatches,
                donate=False, health=health_knob)
            health_on = (
                model.health if tcfg.health is None else bool(tcfg.health)
            )
            watcher = None
            if health_on:
                watcher = health_lib.HealthWatcher(
                    model, health_lib.HealthPolicy(
                        on_incident=args.on_divergence)
                )
            if dist:
                # multi-process (or explicitly requested): disjoint
                # per-process shards REQUIRE the cross-shard statistics
                # psum inside the step -- the shard_map form makes it
                # explicit over the mesh's data axes.  (Closes the ROADMAP
                # "Distributed compiled EM" item; the loud guard PR 3 left
                # here is gone.)
                step_jit = make_sharded_em_step(model, tcfg, mesh)
            else:
                step_jit = make_em_step(model, tcfg)
            if jax.process_count() > 1:
                # each process's loader yields only its own disjoint rows;
                # the global-mesh step needs them assembled into one global
                # array sharded over the data axis (a host-local np array
                # is not addressable across processes)
                from jax.sharding import NamedSharding, PartitionSpec as P

                x_sh = NamedSharding(mesh, P("data"))

                def to_device(x):
                    return jax.make_array_from_process_local_data(
                        x_sh, np.asarray(x, np.float32)
                    )
            else:
                to_device = jnp.asarray

            def step_fn(state, batch):
                x = to_device(batch["x"])
                with obs.timed("train.step", metric="train.step.seconds"):
                    if health_on:
                        p, ll, hv = step_jit(state["params"], x)
                    else:
                        p, ll = step_jit(state["params"], x)
                        hv = None
                    state["last_ll"] = float(ll)
                obs.METRICS.counter("train.examples.count").inc(
                    int(np.asarray(batch["x"]).shape[0]))
                obs.METRICS.gauge("train.ll.last").set(state["last_ll"])
                if watcher is not None:
                    health_lib.publish(model.health_spec, hv)
                    watcher.observe(int(state["step"]), hv, p)
                return {"params": p, "step": state["step"] + 1,
                        "last_ll": state["last_ll"]}

            init_state = {"params": params, "step": jnp.zeros((), jnp.int32),
                          "last_ll": 0.0}

        lls = []
        with obs.timed("train.run") as t_run:
            state, stats = ft.run_training(
                step_fn, init_state, loader.batch_at, mgr, args.steps,
                ft.LoopConfig(checkpoint_every=args.checkpoint_every),
                on_step=lambda s, st: lls.append(st["last_ll"]),
            )
    dt = t_run.seconds
    print(f"{args.arch}: {args.steps} steps, {dt/max(args.steps,1)*1e3:.0f} "
          f"ms/step, dp_shards={dp_shards(mesh)}, restarts={stats['restarts']}")
    print(f"objective: first {np.mean(lls[:5]):.3f} -> last {np.mean(lls[-5:]):.3f}")
    obs.cli_end(args.trace, args.metrics)


if __name__ == "__main__":
    main()
