"""Cell construction shared by the dry-run, train and serve drivers:
input specs, lowering per (config, shape, mesh), cache shardings.

Importable WITHOUT touching jax device state (unlike launch.dryrun, whose
first lines force 512 host devices -- that module is only for the dry-run
process itself).
"""

import dataclasses
import time
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import EinetConfig, ModelConfig
from repro.core import EiNet, Normal, poon_domingos, random_binary_trees
from repro.core.exponential_family import make_exponential_family
from repro.core.em import EMConfig, stochastic_em_update
from repro.dist import sharding as shlib
from repro.launch.mesh import dp_shards
from repro.models import lm
from repro.optim import adamw


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg, shape_spec) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of a cell."""
    if isinstance(cfg, EinetConfig):
        d = (cfg.height * cfg.width * cfg.num_channels
             if cfg.structure == "pd" else cfg.num_vars)
        return {"x": _sds((cfg.batch_size, d), jnp.float32)}
    b, s = shape_spec.global_batch, shape_spec.seq_len
    kind = shape_spec.kind
    if kind == "train":
        if cfg.embedding_input:
            return {
                "inputs_embeds": _sds((b, s, cfg.d_model), jnp.bfloat16),
                "labels": _sds((b, s), jnp.int32),
            }
        return {
            "tokens": _sds((b, s), jnp.int32),
            "labels": _sds((b, s), jnp.int32),
        }
    if kind == "prefill":
        if cfg.embedding_input:
            return {"inputs_embeds": _sds((b, s, cfg.d_model), jnp.bfloat16)}
        return {"tokens": _sds((b, s), jnp.int32)}
    # decode: one new token against a seq_len cache
    if cfg.embedding_input:
        return {"inputs_embeds": _sds((b, 1, cfg.d_model), jnp.bfloat16)}
    return {"tokens": _sds((b, 1), jnp.int32)}


def _use_fsdp(cfg, kind: str) -> bool:
    if isinstance(cfg, EinetConfig):
        return False
    if kind == "train":
        return cfg.param_count() > 4e9
    return cfg.param_count() > 100e9  # serve: only the 1T cells need it


def cache_shardings(cfg: ModelConfig, mesh, cache_struct, global_batch: int):
    """KV/state cache shardings: batch over DP when divisible, else the
    sequence axis (context parallelism) for the batch-1 long-context cells."""
    dp_axes = tuple(n for n in ("pod", "data") if n in mesh.shape)
    dp_n = dp_shards(mesh)
    shard_batch = global_batch % dp_n == 0 and global_batch >= dp_n
    dp = dp_axes if shard_batch else None

    def leaf(path, x):
        p = shlib._path_str(path)
        nd = len(x.shape)
        if p.endswith("/k") or p.endswith("/v"):  # (np, B, Hkv, S, dh)
            # seq (not kv-heads) carries the model axis: Hkv can be smaller
            # than the mesh, the 32k cache seq dim never is
            if shard_batch:
                return NamedSharding(mesh, P(None, dp, None, "model", None))
            return NamedSharding(
                mesh, P(None, None, None, dp_axes + ("model",), None)
            )
        if "/conv" in p:  # (np, B, K-1, E)
            return NamedSharding(mesh, P(None, dp if shard_batch else None,
                                         None, "model"))
        if p.endswith("/h") and nd == 4 and x.shape[-1] == cfg.ssm_state_dim:
            # mamba state (np, B, E, N)
            return NamedSharding(mesh, P(None, dp if shard_batch else None,
                                         "model", None))
        if shard_batch and nd >= 2:
            return NamedSharding(mesh, P(None, dp) + (None,) * (nd - 2))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map_with_path(leaf, cache_struct)


def lower_lm_cell(cfg: ModelConfig, shape_spec, mesh, multi_pod: bool):
    dp_n = dp_shards(mesh)
    kind = shape_spec.kind
    fsdp = _use_fsdp(cfg, kind)
    rules = shlib.default_rules(multi_pod, fsdp=fsdp)
    if kind == "decode":
        rules["seq"] = None  # no SP for single-token steps
    b = shape_spec.global_batch
    if b % dp_n:  # batch-1 long-context: replicate batch, CP the cache
        rules["batch"] = None
    cfg = dataclasses.replace(cfg, moe_groups=dp_n if b % dp_n == 0 else 1)

    with shlib.use_rules(rules):
        params_struct = jax.eval_shape(
            lambda: lm.init_params(cfg, jax.random.PRNGKey(0))
        )
        param_sh = shlib.tree_shardings(mesh, params_struct)
        batch_struct = input_specs(cfg, shape_spec)
        batch_sh = shlib.batch_shardings(mesh, batch_struct) if b % dp_n == 0 \
            else jax.tree_util.tree_map(
                lambda x: NamedSharding(mesh, P()), batch_struct)
        if kind == "train":
            ocfg = adamw.AdamWConfig(
                state_dtype="bfloat16" if cfg.param_count() > 50e9 else "float32"
            )
            opt_struct = jax.eval_shape(
                lambda p: adamw.init_state(ocfg, p), params_struct
            )
            opt_sh = shlib.tree_shardings(mesh, opt_struct)

            def fn(p, o, batch):
                return lm.train_step(cfg, ocfg, p, o, batch)

            jitted = jax.jit(
                fn,
                in_shardings=(param_sh, opt_sh, batch_sh),
                out_shardings=(param_sh, opt_sh, None),
            )
            args = (params_struct, opt_struct, batch_struct)
        elif kind == "prefill":
            def fn(p, batch):
                return lm.prefill(cfg, p, batch)

            jitted = jax.jit(fn, in_shardings=(param_sh, batch_sh))
            args = (params_struct, batch_struct)
        else:  # decode
            cache_struct = jax.eval_shape(
                lambda: lm.init_cache(cfg, b, shape_spec.seq_len)
            )
            cache_sh = cache_shardings(cfg, mesh, cache_struct, b)
            pos_struct = _sds((), jnp.int32)

            def fn(p, batch, cache, pos):
                return lm.decode_step(cfg, p, batch, cache, pos)

            jitted = jax.jit(
                fn,
                in_shardings=(param_sh, batch_sh, cache_sh,
                              NamedSharding(mesh, P())),
                out_shardings=(None, cache_sh),
            )
            args = (params_struct, batch_struct, cache_struct, pos_struct)
        t0 = time.time()
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        return lowered, t_lower


def build_einet(cfg: EinetConfig) -> EiNet:
    if cfg.structure == "pd":
        graph = poon_domingos(
            cfg.height, cfg.width, cfg.delta, cfg.num_channels, cfg.pd_axes
        )
    else:
        graph = random_binary_trees(cfg.num_vars, cfg.depth, cfg.num_repetitions)
    if cfg.exponential_family == "normal":
        ef = Normal(min_var=cfg.min_var, max_var=cfg.max_var)
    elif cfg.exponential_family == "binomial":
        # 8-bit image data modelled as counts, the paper's MNIST treatment
        ef = make_exponential_family("binomial", n_trials=255)
    elif cfg.exponential_family == "categorical":
        ef = make_exponential_family("categorical", num_categories=256)
    else:
        raise ValueError(
            f"{cfg.name}: unsupported leaf family {cfg.exponential_family!r}"
        )
    return EiNet(graph, num_sums=cfg.num_sums, num_classes=cfg.num_classes,
                 exponential_family=ef)


def lower_einet_cell(cfg: EinetConfig, mesh, multi_pod: bool):
    rules = shlib.default_rules(multi_pod, fsdp=False)
    model = build_einet(cfg)
    with shlib.use_rules(rules):
        params_struct = jax.eval_shape(
            lambda: model.init(jax.random.PRNGKey(0))
        )
        param_sh = shlib.tree_shardings(mesh, params_struct)
        batch_struct = input_specs(cfg, None)
        batch_sh = shlib.batch_shardings(mesh, batch_struct)

        def fn(p, batch):
            # one distributed stochastic-EM step: E-step statistics are summed
            # over the DP axes by XLA (they are grads of the summed batch LL)
            return stochastic_em_update(model, p, batch["x"], EMConfig())

        jitted = jax.jit(
            fn, in_shardings=(param_sh, batch_sh), out_shardings=(param_sh, None)
        )
        t0 = time.time()
        lowered = jitted.lower(params_struct, batch_struct)
        return lowered, time.time() - t0, model


