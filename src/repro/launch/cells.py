"""Cell construction shared by the dry-run, train and serve drivers:
input specs, model build, lowering per (config, mesh).

Importable WITHOUT touching jax device state (unlike launch.dryrun, whose
first lines force 512 host devices -- that module is only for the dry-run
process itself).
"""

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro import obs
from repro.compile import REGISTRY
from repro.configs import EinetConfig
from repro.core import EiNet, Normal, poon_domingos, random_binary_trees
from repro.core.exponential_family import make_exponential_family
from repro.core.em import EMConfig, stochastic_em_update
from repro.dist import sharding as shlib


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: EinetConfig, shape_spec=None) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of a cell."""
    d = (cfg.height * cfg.width * cfg.num_channels
         if cfg.structure == "pd" else cfg.num_vars)
    return {"x": _sds((cfg.batch_size, d), jnp.float32)}


def build_einet(cfg: EinetConfig) -> EiNet:
    if cfg.structure == "pd":
        graph = poon_domingos(
            cfg.height, cfg.width, cfg.delta, cfg.num_channels, cfg.pd_axes
        )
    else:
        graph = random_binary_trees(cfg.num_vars, cfg.depth, cfg.num_repetitions)
    if cfg.exponential_family == "normal":
        ef = Normal(min_var=cfg.min_var, max_var=cfg.max_var)
    elif cfg.exponential_family == "binomial":
        # 8-bit image data modelled as counts, the paper's MNIST treatment
        ef = make_exponential_family("binomial", n_trials=255)
    elif cfg.exponential_family == "categorical":
        ef = make_exponential_family("categorical", num_categories=256)
    else:
        raise ValueError(
            f"{cfg.name}: unsupported leaf family {cfg.exponential_family!r}"
        )
    return EiNet(graph, num_sums=cfg.num_sums, num_classes=cfg.num_classes,
                 exponential_family=ef)


def lower_einet_cell(cfg: EinetConfig, mesh, multi_pod: bool):
    rules = shlib.default_rules(multi_pod, fsdp=False)
    model = build_einet(cfg)
    with shlib.use_rules(rules):
        params_struct = jax.eval_shape(
            lambda: model.init(jax.random.PRNGKey(0))
        )
        param_sh = shlib.tree_shardings(mesh, params_struct)
        batch_struct = input_specs(cfg)
        batch_sh = shlib.batch_shardings(mesh, batch_struct)

        def fn(p, batch):
            # one distributed stochastic-EM step: E-step statistics are summed
            # over the DP axes by XLA (they are grads of the summed batch LL)
            return stochastic_em_update(model, p, batch["x"], EMConfig())

        jitted = REGISTRY.jit(
            model,
            ("lowered_cell", cfg.name, multi_pod),
            fn,
            jit_kwargs={
                "in_shardings": (param_sh, batch_sh),
                "out_shardings": (param_sh, None),
            },
        )
        with obs.timed("compile.lower", arch=cfg.name) as t:
            lowered = jitted.lower(params_struct, batch_struct)
        return lowered, t.seconds, model
