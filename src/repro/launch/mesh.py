"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import and then calls this.
"""

from __future__ import annotations

import math
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 single-pod (256 chips) or 2x16x16 two-pod (512 chips) mesh.

    Axes: ("data", "model") resp. ("pod", "data", "model").  The "pod" axis
    is the slow DCN axis -- only DP gradient/EM-statistic reductions cross it.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = math.prod(shape)
    devs = jax.devices()
    if len(devs) < need:
        raise RuntimeError(
            f"need {need} devices for mesh {shape}, found {len(devs)}; "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "BEFORE importing jax (see launch/dryrun.py)"
        )
    return jax.make_mesh(
        shape,
        axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
        devices=devs[:need],
    )


def make_mesh_for(devices: Optional[Sequence] = None,
                  model_parallel: int = 16) -> Mesh:
    """Elastic variant: (data, model) mesh over whatever devices are alive."""
    devices = list(devices if devices is not None else jax.devices())
    data = len(devices) // model_parallel
    if data < 1:
        data, model_parallel = 1, len(devices)
    devices = devices[: data * model_parallel]
    return jax.make_mesh(
        (data, model_parallel),
        ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
        devices=devices,
    )


def dp_shards(mesh: Mesh) -> int:
    """Number of data-parallel shards (pod x data)."""
    n = 1
    for name in ("pod", "data"):
        if name in mesh.shape:
            n *= mesh.shape[name]
    return n
