"""Image-evaluation driver: train (or reuse) a PD EiNet and measure it as a
generative image model -- bits-per-dim, Fig. 4 inpainting, sample grids --
with every query served through the batched engine and parity-audited
against direct ``EiNet.query`` calls.

  # offline end-to-end smoke (tiny PD net, procedural data, CI profile)
  PYTHONPATH=src python -m repro.launch.eval --dataset synthetic --smoke

  # the paper's protocol on real data (downloads + caches under
  # artifacts/datasets/ on first use; --source procedural never needs net)
  PYTHONPATH=src python -m repro.launch.eval --dataset mnist --steps 200
  PYTHONPATH=src python -m repro.launch.eval --dataset svhn --family normal

  # §4.2 mixture-of-EiNets: k-means clusters + C components trained by one
  # vmapped EM step, served through the mixture_* engine kinds
  PYTHONPATH=src python -m repro.launch.eval --dataset celeba --mixture 8
  PYTHONPATH=src python -m repro.launch.eval --dataset celeba --mixture 4 --smoke

Exit status is the acceptance gate: non-zero when any engine result is not
bit-identical to the direct call (``parity_mismatches_total != 0``).
"""

from __future__ import annotations

import argparse

from repro import obs
from repro.data.datasets import DEFAULT_DATA_DIR
from repro.eval.masks import MASK_KINDS
from repro.eval.workbench import EVAL_DATASETS, EvalConfig, run_eval


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", choices=EVAL_DATASETS, default="synthetic")
    ap.add_argument("--family", choices=("normal", "binomial", "categorical"),
                    default="normal", help="leaf exponential family")
    ap.add_argument("--smoke", action="store_true",
                    help="CI profile: tiny net, procedural data, few steps")
    ap.add_argument("--steps", type=int, default=80)
    ap.add_argument("--batch", type=int, default=128)
    ap.add_argument("--num-sums", type=int, default=16)
    ap.add_argument("--delta", type=int, default=None,
                    help="PD cut spacing (default: per-dataset)")
    ap.add_argument("--source", choices=("auto", "download", "procedural"),
                    default="auto", help="dataset source resolution")
    ap.add_argument("--data-dir", default=DEFAULT_DATA_DIR)
    ap.add_argument("--out-dir", default="artifacts/eval")
    ap.add_argument("--run-name", default=None)
    ap.add_argument("--max-batch", type=int, default=32)
    ap.add_argument("--eval-rows", type=int, default=256)
    ap.add_argument("--inpaint-rows", type=int, default=8)
    ap.add_argument("--num-samples", type=int, default=16)
    ap.add_argument("--masks", nargs="+", default=list(MASK_KINDS),
                    choices=list(MASK_KINDS))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mixture", type=int, default=0,
                    help="train/eval a mixture of this many EiNets over "
                         "k-means image clusters (§4.2); 0 = single EiNet")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="collect obs tracing spans and export a "
                         "Chrome-trace JSON to this path at exit")
    args = ap.parse_args(argv)
    obs.cli_begin(args.trace)

    cfg = EvalConfig(
        dataset=args.dataset,
        family=args.family,
        smoke=args.smoke,
        steps=args.steps,
        batch=args.batch,
        num_sums=args.num_sums,
        delta=args.delta,
        data_dir=args.data_dir,
        source=args.source,
        out_dir=args.out_dir,
        run_name=args.run_name,
        max_batch=args.max_batch,
        eval_rows=args.eval_rows,
        inpaint_rows=args.inpaint_rows,
        num_samples=args.num_samples,
        mask_kinds=tuple(args.masks),
        seed=args.seed,
        mixture=args.mixture,
    )
    rec = run_eval(cfg)

    bj = rec["bpd_joint"]
    mix_s = (f", mixture of {rec['mixture_components']} "
             f"(clusters {rec['cluster_sizes']})"
             if rec.get("mixture_components") else "")
    print(f"{rec['run_name']}: {rec['dataset']} ({rec['dataset_source']}), "
          f"{rec['height']}x{rec['width']}x{rec['channels']}, "
          f"{rec['num_params']:,} params, {rec['train_steps']} EM steps"
          f"{mix_s}")
    if rec["train_ll_first"] is not None:
        print(f"train LL: {rec['train_ll_first']:9.2f} -> "
              f"{rec['train_ll_last']:9.2f}")
    print(f"test bpd (joint):    {bj['bpd']:.4f}  "
          f"({bj['num_rows']} rows, {bj['engine_rows_per_s']:.0f} rows/s "
          f"through the engine)")
    print(f"test bpd (marginal, {rec['bpd_marginal']['mask']}): "
          f"{rec['bpd_marginal']['bpd']:.4f}")
    for mk, m in rec["inpainting"]["per_mask"].items():
        base = m.get("mean_fill_mse")
        base_s = f" vs mean-fill {base:.4f}" if base is not None else ""
        print(f"inpaint {mk:14s}: sample MSE {m['conditional_sample_mse']:.4f}"
              f", mpe MSE {m['mpe_mse']:.4f}{base_s}")
    print(f"artifacts: {', '.join(sorted(rec['artifacts'].values()))}")
    print(f"engine: {rec['engine_programs']} compiled programs, "
          f"parity mismatches {rec['parity_mismatches_total']}")
    obs.cli_end(args.trace)
    if rec["parity_mismatches_total"]:
        raise SystemExit(
            f"PARITY FAILURE: {rec['parity_mismatches_total']} engine results "
            "differ from direct EiNet.query calls"
        )
    return rec


if __name__ == "__main__":
    main()
