"""Deterministic minibatch k-means for mixture-of-EiNets training (§4.2).

The paper's CelebA model is a *mixture* of EiNets trained over image
clusters; this module produces those clusters.  Two contracts matter more
than clustering quality:

  * **Cross-process determinism.**  Seeding follows the datasets module's
    crc32 idiom (``zlib.crc32``, NOT ``hash()``, whose str salt varies per
    process via PYTHONHASHSEED): a restarted trainer, a different host, or a
    train-then-eval pair must derive the SAME partition of the data, because
    cluster identity is baked into the per-component parameters.
  * **Device-friendly iterations.**  Initialization (k-means++) runs on host
    in numpy; the Lloyd / minibatch iterations are one jitted JAX step each
    (assign = one argmin over squared distances, update = one segment-sum),
    so clustering paper-scale data is a handful of XLA programs, not a
    Python loop over rows.

Minibatches are *contiguous deterministic blocks* (``[(i * b) % N, ...)``,
the same mod-N tiling as ``repro.data.datasets.array_loader``) rather than
random subsamples -- no RNG in the iteration path at all.
"""

from __future__ import annotations

import dataclasses
import zlib
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.compile import REGISTRY

_SEED_SALT = zlib.crc32(b"repro.mixture.kmeans")


class _Anchor:
    """Module-lifetime anchor for the k-means step programs in the shared
    compile registry (the registry holds anchors by weakref, so plain
    module-level jit objects would need their own cache -- this keeps
    k-means accountable to the same ProgramRegistry as everything else)."""


_KMEANS_ANCHOR = _Anchor()


def _jitted(name: str, fn):
    return REGISTRY.jit(_KMEANS_ANCHOR, ("kmeans", name), fn)


@dataclasses.dataclass
class KMeansResult:
    """Cluster assignment of a dataset.

    centers:      (C, D) float32 cluster centroids.
    assignments:  (N,) int32 cluster id per row.
    counts:       (C,) int64 rows per cluster.
    inertia:      mean squared distance of rows to their centroid.
    """

    centers: np.ndarray
    assignments: np.ndarray
    counts: np.ndarray
    inertia: float

    @property
    def num_clusters(self) -> int:
        return len(self.centers)

    def weights(self, alpha: float = 0.0) -> np.ndarray:
        """Cluster proportions (the mixture's initial component weights),
        optionally Laplace-smoothed so empty clusters keep nonzero mass."""
        c = self.counts.astype(np.float64) + alpha
        return (c / c.sum()).astype(np.float32)


def _rng(seed: int) -> np.random.RandomState:
    return np.random.RandomState((_SEED_SALT + seed * 7919) % 2**31)


def _plusplus_init(
    data: np.ndarray, num_clusters: int, rng: np.random.RandomState,
    sample_cap: int = 16_384,
) -> np.ndarray:
    """k-means++ seeding on a deterministic row subsample (host, numpy)."""
    n = len(data)
    sub = data if n <= sample_cap else data[:: max(n // sample_cap, 1)]
    sub = np.asarray(sub, np.float64)
    centers = [sub[rng.randint(len(sub))]]
    d2 = np.sum((sub - centers[0]) ** 2, axis=1)
    for _ in range(num_clusters - 1):
        total = d2.sum()
        if total <= 0:  # degenerate data: duplicate rows are fine
            centers.append(sub[rng.randint(len(sub))])
            continue
        r = rng.rand() * total
        idx = int(np.searchsorted(np.cumsum(d2), r))
        idx = min(idx, len(sub) - 1)
        centers.append(sub[idx])
        d2 = np.minimum(d2, np.sum((sub - centers[-1]) ** 2, axis=1))
    return np.stack(centers).astype(np.float32)


def _assign(data: jax.Array, centers: jax.Array) -> jax.Array:
    """Nearest-centroid assignment: (N,) int32.  ||x - c||^2 expanded so the
    N x C distance matrix is one matmul (no (N, C, D) intermediate)."""
    x2 = jnp.sum(data * data, axis=1, keepdims=True)  # (N, 1)
    c2 = jnp.sum(centers * centers, axis=1)[None, :]  # (1, C)
    d2 = x2 + c2 - 2.0 * data @ centers.T
    return jnp.argmin(d2, axis=1).astype(jnp.int32)


def _update(data, centers, assign):
    """One Lloyd update: segment-mean of the rows per cluster; empty
    clusters keep their previous centroid."""
    c = centers.shape[0]
    sums = jax.ops.segment_sum(data, assign, num_segments=c)
    counts = jax.ops.segment_sum(
        jnp.ones((data.shape[0],), data.dtype), assign, num_segments=c
    )
    safe = jnp.maximum(counts, 1.0)[:, None]
    return jnp.where(counts[:, None] > 0, sums / safe, centers), counts


def kmeans(
    data: np.ndarray,
    num_clusters: int,
    num_iters: int = 25,
    batch: Optional[int] = None,
    seed: int = 0,
    tol: float = 1e-6,
) -> KMeansResult:
    """Deterministic (minibatch) k-means.

    Args:
      data: (N, D) rows (any float dtype; clustered in float32).
      num_clusters: C.
      num_iters: Lloyd / minibatch iterations (early exit on center
        movement < ``tol``).
      batch: rows per iteration.  None = full-batch Lloyd; otherwise each
        iteration i uses the contiguous block ``[(i * batch) % N, ...)``
        (deterministic, RNG-free) and applies the standard minibatch k-means
        per-center running-count update (Sculley, 2010).
      seed: initialization seed (crc32-salted; process-independent).

    Returns:
      :class:`KMeansResult` with final centers and FULL-data assignments.
    """
    data = np.ascontiguousarray(np.asarray(data, np.float32))
    n = len(data)
    if not 1 <= num_clusters <= n:
        raise ValueError(
            f"num_clusters must be in [1, {n} rows]; got {num_clusters}"
        )
    centers = _plusplus_init(data, num_clusters, _rng(seed))
    data_j = jnp.asarray(data)
    centers_j = jnp.asarray(centers)
    assign_step = _jitted("assign", _assign)
    update_step = _jitted("update", _update)
    if batch is None or batch >= n:
        for _ in range(num_iters):
            assign = assign_step(data_j, centers_j)
            new_centers, _ = update_step(data_j, centers_j, assign)
            moved = float(jnp.max(jnp.abs(new_centers - centers_j)))
            centers_j = new_centers
            if moved < tol:
                break
    else:
        # minibatch: per-center running counts weight each step (a new
        # center moves fast, a mature one is stable)
        run_counts = jnp.zeros((num_clusters,), jnp.float32)
        for i in range(num_iters):
            base = (i * batch) % n
            rows = (np.arange(batch) + base) % n
            xb = data_j[jnp.asarray(rows)]
            assign = assign_step(xb, centers_j)
            sums = jax.ops.segment_sum(xb, assign, num_segments=num_clusters)
            cnt = jax.ops.segment_sum(
                jnp.ones((batch,), jnp.float32), assign,
                num_segments=num_clusters,
            )
            run_counts = run_counts + cnt
            lr = cnt / jnp.maximum(run_counts, 1.0)
            target = sums / jnp.maximum(cnt, 1.0)[:, None]
            centers_j = jnp.where(
                cnt[:, None] > 0,
                centers_j + lr[:, None] * (target - centers_j),
                centers_j,
            )
    final_assign = np.asarray(assign_step(data_j, centers_j))
    counts = np.bincount(final_assign, minlength=num_clusters).astype(np.int64)
    d = data - np.asarray(centers_j)[final_assign]
    inertia = float(np.mean(np.sum(d * d, axis=1)))
    return KMeansResult(
        centers=np.asarray(centers_j),
        assignments=final_assign,
        counts=counts,
        inertia=inertia,
    )


def cluster_order(
    assignments: np.ndarray, num_clusters: int
) -> Tuple[np.ndarray, np.ndarray]:
    """Row indices grouped by cluster: (order, offsets) where
    ``order[offsets[c]:offsets[c+1]]`` are cluster c's rows in dataset
    order.  Deterministic (stable sort)."""
    order = np.argsort(assignments, kind="stable").astype(np.int64)
    counts = np.bincount(assignments, minlength=num_clusters)
    offsets = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
    return order, offsets
