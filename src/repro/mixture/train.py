"""Vmapped multi-component EM: train C EiNets in lockstep as ONE program.

Training a mixture of EiNets is embarrassingly parallel over the component
axis -- C architecturally-identical components differ only in parameter
values, which :class:`~repro.mixture.model.EiNetMixture` stacks on a leading
axis.  This module advances all C components with a single jitted, donated
EM step (``vmap`` over the stack), in two regimes:

  * **hard** (the paper's CelebA protocol): the data is pre-partitioned by
    k-means (``repro.mixture.cluster``); each component runs the standard
    single-model EM update on ITS cluster's batch.  The step is
    ``vmap(em_update)`` over ``(params_c, x_c)`` with a stacked ``(C, B, D)``
    batch -- bitwise the same math as a Python loop of C single-model steps,
    executed as one XLA program (``benchmarks/bench_mixture.py`` measures the
    gap; the per-component parity is the benchmark's gate).
  * **soft**: full-mixture responsibility-weighted EM.  Because the mixture's
    top level routes through ``log_mix_exp`` (one mixing cell), the paper's
    EM-via-autodiff observation extends verbatim: ONE ``jax.grad`` of the
    summed mixture log-likelihood yields every component's statistics already
    weighted by its responsibilities r[b, c] = p(c | x_b), plus
    ``w * dL/dw = sum_b r[b, c]`` for the mixture weights.  No explicit
    E-step posterior pass exists anywhere.

Both regimes reuse ``repro.train``'s machinery -- scan-accumulated microbatch
statistics, the shared M-step/blend, donated buffers, and the shared
compiled-program registry (``repro.compile``) for the jitted step.

Unlike ``core.em.em_statistics`` the soft path does not pin statistics to the
weight sharding (``constrain_like_params``): the stacked component axis is
not in the rule table yet.  Mixture training is single-host for now; the
constraint is a no-op there anyway.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import compile as compile_lib
from repro.core.em import (
    EMConfig,
    accumulate_statistics,
    blend_params,
    leaf_scatter,
    m_step,
)
from repro.data.pipeline import ShardedLoader
from repro.mixture.cluster import cluster_order
from repro.mixture.model import EiNetMixture, _W_FLOOR
from repro.train.pipeline import (
    _resolve_donate,
    _split_microbatches,
    em_update_microbatched,
    stochastic_em_update_microbatched,
)


@dataclasses.dataclass(frozen=True)
class MixtureTrainConfig:
    """One compiled mixture EM step.

    assign: "hard" (per-cluster EM on a stacked (C, B, D) batch) or "soft"
      (responsibility-weighted full-mixture EM on a shared (B, D) batch).
    mode: "stochastic" (Sato blend, Eqs. 8/9) or "full" (exact M-step --
      monotone on the batch in soft mode).
    weight_alpha: Laplace smoothing on the mixture-weight statistics (soft
      mode; hard mode keeps the k-means cluster proportions fixed).
    donate / num_microbatches: as in ``repro.train.TrainConfig``.
    """

    em: EMConfig = EMConfig()
    assign: str = "hard"  # hard | soft
    mode: str = "stochastic"  # stochastic | full
    num_microbatches: int = 1
    weight_alpha: float = 1e-4
    donate: Optional[bool] = None


# ---------------------------------------------------------------- soft E-step
def mixture_em_statistics(
    mix: EiNetMixture, params: Dict[str, Any], x: jax.Array
) -> Dict[str, Any]:
    """Responsibility-weighted E-step statistics for every component, via one
    grad call on the MIXTURE log-likelihood.

    Returns the single-model statistics dict with a leading component axis on
    every tensor, plus ``n_weight`` (C,) = sum_b r[b, c].
    """
    model = mix.component
    comp = params["components"]
    weights = params["mixture_weights"]

    def leaf_rows_one(p):
        e = model.leaf_log_prob(p, x, None)
        return model._leaf_rows(e)

    leaf_rows = jax.vmap(leaf_rows_one)(comp)  # (C, B, num_leaves, K)
    logprior = jnp.log(comp["class_prior"])  # (C, num_classes)

    def batch_ll(einsum_s, mixing_s, lr_s, logprior_s, w):
        def root_one(ew, mv, lrc, lp):
            root = model.forward_from_e(ew, mv, None, leaf_rows=lrc)
            return jax.scipy.special.logsumexp(root + lp[None, :], axis=-1)

        cll = jax.vmap(root_one, out_axes=1)(
            einsum_s, mixing_s, lr_s, logprior_s
        )  # (B, C)
        return jnp.sum(mix.mix_log_likelihoods(w, cll))

    val, grads = jax.value_and_grad(batch_ll, argnums=(0, 1, 2, 3, 4))(
        comp["einsum"], comp["mixing"], leaf_rows, logprior, weights
    )
    g_einsum, g_mixing, g_leaf, g_prior, g_w = grads

    # sum-node statistics, responsibility-weighted by construction:
    # dL/dW of the routed mixture LL carries the r[b, c] factor that the
    # top-level log_mix_exp VJP distributes to each component's cotangent
    n_einsum = [w_ * g for w_, g in zip(comp["einsum"], g_einsum)]
    n_mixing = [v * g for v, g in zip(comp["mixing"], g_mixing)]

    # leaf statistics: the single-model unique-index fan-out
    # (core.em.leaf_scatter, the one shared definition), vmapped over C
    ls = model.leaf_spec
    t = model.ef.sufficient_statistics(x)  # (B, D, |T|), shared across comps
    t_pairs = t[:, ls.pair_var, :]

    def leaf_stats_one(g_leaf_c):
        g_pairs = g_leaf_c[:, ls.pair_leaf, :]  # (B, P, K)
        s_phi_pairs = jnp.einsum("bpk,bpt->pkt", g_pairs, t_pairs)
        s_den_pairs = jnp.sum(g_pairs, axis=0)
        return leaf_scatter(model, s_phi_pairs, s_den_pairs)

    s_phi, s_den = jax.vmap(leaf_stats_one)(g_leaf)
    return {
        "n_einsum": n_einsum,
        "n_mixing": n_mixing,
        "s_phi": s_phi,  # (C, D, K, R, |T|)
        "s_den": s_den,  # (C, D, K, R)
        "n_class": g_prior,  # (C, num_classes)
        "n_weight": weights * g_w,  # (C,) = sum_b r[b, c]
        "ll": val,
        "count": jnp.asarray(x.shape[0], jnp.float32),
    }


def zeros_like_mixture_statistics(
    mix: EiNetMixture, params: Dict[str, Any]
) -> Dict[str, Any]:
    comp = params["components"]
    c = mix.num_components
    d, k, r = comp["phi"].shape[1:4]
    tdim = mix.component.ef.num_stats
    return {
        "n_einsum": [jnp.zeros_like(w) for w in comp["einsum"]],
        "n_mixing": [jnp.zeros_like(v) for v in comp["mixing"]],
        "s_phi": jnp.zeros((c, d, k, r, tdim)),
        "s_den": jnp.zeros((c, d, k, r)),
        "n_class": jnp.zeros_like(comp["class_prior"]),
        "n_weight": jnp.zeros((c,)),
        "ll": jnp.zeros(()),
        "count": jnp.zeros(()),
    }


def microbatched_mixture_em_statistics(
    mix: EiNetMixture,
    params: Dict[str, Any],
    x: jax.Array,
    num_microbatches: int = 1,
) -> Dict[str, Any]:
    """Scan-accumulated soft statistics (sums over data, so microbatching is
    exact -- same contract as ``repro.train.microbatched_em_statistics``)."""
    if num_microbatches == 1:
        return mixture_em_statistics(mix, params, x)
    xm = _split_microbatches(x, num_microbatches)

    def body(acc, xb):
        new = mixture_em_statistics(mix, params, xb)
        return accumulate_statistics(acc, new), None

    acc, _ = jax.lax.scan(body, zeros_like_mixture_statistics(mix, params), xm)
    return acc


def mixture_m_step(
    mix: EiNetMixture,
    stats: Dict[str, Any],
    cfg: EMConfig,
    weight_alpha: float = 1e-4,
) -> Dict[str, Any]:
    """Per-component exact M-step (vmapped) + mixture-weight renormalize."""
    per_comp = {
        key: stats[key]
        for key in ("n_einsum", "n_mixing", "s_phi", "s_den", "n_class")
    }
    new_comp = jax.vmap(lambda st: m_step(mix.component, st, cfg))(per_comp)
    nw = stats["n_weight"] + weight_alpha
    return {"components": new_comp, "mixture_weights": nw / jnp.sum(nw)}


def mixture_em_update(
    mix: EiNetMixture,
    params: Dict[str, Any],
    x: jax.Array,
    cfg: MixtureTrainConfig = MixtureTrainConfig(assign="soft", mode="full"),
) -> Tuple[Dict[str, Any], jax.Array]:
    """One full soft-EM update (monotone on the batch).  Returns
    (new_params, mean mixture log-likelihood)."""
    stats = microbatched_mixture_em_statistics(
        mix, params, x, cfg.num_microbatches
    )
    new = mixture_m_step(mix, stats, cfg.em, cfg.weight_alpha)
    return new, stats["ll"] / stats["count"]


def stochastic_mixture_em_update(
    mix: EiNetMixture,
    params: Dict[str, Any],
    x: jax.Array,
    cfg: MixtureTrainConfig = MixtureTrainConfig(assign="soft"),
) -> Tuple[Dict[str, Any], jax.Array]:
    """Sato online soft EM: per-component blend + linear weight blend."""
    mini, ll = mixture_em_update(mix, params, x, cfg)
    lam = cfg.em.step_size
    comps = jax.vmap(
        lambda o, n: blend_params(mix.component, o, n, lam)
    )(params["components"], mini["components"])
    w = (1.0 - lam) * params["mixture_weights"] \
        + lam * mini["mixture_weights"]
    return {"components": comps, "mixture_weights": w}, ll


# ---------------------------------------------------------------- hard E-step
def hard_mixture_em_update(
    mix: EiNetMixture,
    params: Dict[str, Any],
    x_stacked: jax.Array,
    cfg: MixtureTrainConfig = MixtureTrainConfig(),
) -> Tuple[Dict[str, Any], jax.Array]:
    """Per-cluster EM: component c updates on its own batch ``x_stacked[c]``.

    ``vmap`` of the single-model update over (params_c, x_c): identical math
    to a Python loop of C ``em_update`` calls, one XLA program.  Mixture
    weights stay fixed (they are the k-means cluster proportions -- the
    stacked equal-size batches carry no size signal).  Returns
    (new_params, weight-averaged per-cluster mean LL).
    """
    if x_stacked.ndim != 3 or x_stacked.shape[0] != mix.num_components:
        raise ValueError(
            f"hard mixture EM needs a (C={mix.num_components}, B, D) stacked "
            f"batch; got {x_stacked.shape}"
        )
    update = (
        stochastic_em_update_microbatched
        if cfg.mode == "stochastic"
        else em_update_microbatched
    )

    def one(p, xc):
        return update(mix.component, p, xc, cfg.em, cfg.num_microbatches, None)

    new_comp, ll = jax.vmap(one)(params["components"], x_stacked)  # ll: (C,)
    w = params["mixture_weights"]
    return (
        {"components": new_comp, "mixture_weights": w},
        jnp.sum(w * ll) / jnp.maximum(jnp.sum(w), _W_FLOOR),
    )


# ------------------------------------------------------------- compiled step
def make_mixture_em_step(
    mix: EiNetMixture,
    cfg: MixtureTrainConfig = MixtureTrainConfig(),
    registry: Optional[compile_lib.ProgramRegistry] = None,
) -> Callable[[Dict[str, Any], jax.Array], Tuple[Dict[str, Any], jax.Array]]:
    """The jitted, donated mixture EM step: (params, x) -> (params, ll).

    ``assign="hard"`` expects a stacked (C, B, D) batch
    (:func:`stacked_cluster_loader`); ``assign="soft"`` a shared (B, D)
    batch.  Cached in the shared compiled-program registry keyed by the
    config, like ``repro.train.make_em_step``.
    """
    if cfg.assign not in ("hard", "soft"):
        raise ValueError(f"unknown assign {cfg.assign!r}; 'hard' or 'soft'")
    if cfg.mode not in ("stochastic", "full"):
        raise ValueError(f"unknown mode {cfg.mode!r}; 'stochastic' or 'full'")

    if cfg.assign == "hard":
        def step(params, x):
            return hard_mixture_em_update(mix, params, x, cfg)
    elif cfg.mode == "stochastic":
        def step(params, x):
            return stochastic_mixture_em_update(mix, params, x, cfg)
    else:
        def step(params, x):
            return mixture_em_update(mix, params, x, cfg)

    donate_flag = _resolve_donate(cfg.donate)
    reg = registry if registry is not None else compile_lib.REGISTRY
    return reg.jit(
        mix, ("mixture_em_step", cfg, donate_flag), step,
        donate_argnums=(0,) if donate_flag else (),
    )


# -------------------------------------------------------------------- loaders
def stacked_cluster_loader(
    data: np.ndarray,
    assignments: np.ndarray,
    num_clusters: int,
    per_component_batch: int,
    num_shards: int = 1,
    shard_id: int = 0,
    start_step: int = 0,
) -> ShardedLoader:
    """``ShardedLoader`` of stacked per-cluster batches {"x": (C, B, D)}.

    Component c's rows tile ITS cluster with the same contiguous
    block-mod-N scheme as ``repro.data.datasets.array_loader`` (shards
    within a step are disjoint per cluster, steps tile each cluster).
    Empty clusters fall back to tiling the whole dataset -- their mixture
    weight is ~0, so the rows only keep shapes static.
    """
    order, offsets = cluster_order(assignments, num_clusters)
    idx = [
        order[offsets[c]: offsets[c + 1]] for c in range(num_clusters)
    ]
    idx = [i if len(i) else np.arange(len(data)) for i in idx]

    def make(step: int, shard: int, n: int) -> Dict[str, np.ndarray]:
        out = np.empty(
            (num_clusters, n) + data.shape[1:], dtype=np.float32
        )
        base = (step * num_shards + shard) * n
        for c in range(num_clusters):
            rows = idx[c][(np.arange(n) + base) % len(idx[c])]
            out[c] = data[rows]
        return {"x": out}

    return ShardedLoader(
        make, per_component_batch * num_shards, num_shards=num_shards,
        shard_id=shard_id, start_step=start_step,
    )


# full-batch Lloyd below this many rows; deterministic contiguous-block
# minibatches above it (one threshold for every §4.2 entry point)
KMEANS_MINIBATCH_THRESHOLD = 8192


def prepare_mixture_training(
    mix: EiNetMixture,
    data: np.ndarray,
    seed: int = 0,
    global_batch: int = 512,
    kmeans_iters: int = 25,
) -> Tuple[Dict[str, Any], ShardedLoader, Any]:
    """THE §4.2 hard-EM setup, shared by ``launch/train.py`` and the eval
    workbench so both run the identical protocol: k-means the data
    (minibatched past :data:`KMEANS_MINIBATCH_THRESHOLD` rows), seed the
    mixture weights with the Laplace-smoothed cluster proportions, and build
    the stacked per-cluster loader with per-component batch
    ``max(min(global_batch, N) // C, 4)``.

    Returns (params, loader, KMeansResult).
    """
    from repro.mixture.cluster import kmeans

    c = mix.num_components
    km = kmeans(
        data, c, num_iters=kmeans_iters,
        batch=None if len(data) <= KMEANS_MINIBATCH_THRESHOLD
        else KMEANS_MINIBATCH_THRESHOLD,
        seed=seed,
    )
    params = mix.init(jax.random.PRNGKey(seed))
    # alpha=1.0: an empty cluster keeps (negligible) mass, so the log-domain
    # weight routing never sees an exact zero
    params["mixture_weights"] = jnp.asarray(km.weights(alpha=1.0))
    per_comp = max(min(global_batch, len(data)) // c, 4)
    loader = stacked_cluster_loader(data, km.assignments, c, per_comp)
    return params, loader, km


def fit_mixture(
    mix: EiNetMixture,
    params: Dict[str, Any],
    batches: Any,
    cfg: MixtureTrainConfig = MixtureTrainConfig(),
    num_steps: Optional[int] = None,
    on_step: Optional[Callable[[int, float], None]] = None,
) -> Tuple[Dict[str, Any], list]:
    """Run the compiled mixture step over an iterable of batches (dicts with
    an "x" key, or raw arrays).  Returns (final_params, per-step LL list)."""
    step_fn = make_mixture_em_step(mix, cfg)
    lls: list = []
    for i, batch in enumerate(batches):
        if num_steps is not None and i >= num_steps:
            break
        x = batch["x"] if isinstance(batch, dict) else batch
        params, ll = step_fn(params, jnp.asarray(x))
        lls.append(float(ll))
        if on_step is not None:
            on_step(i, lls[-1])
    return params, lls
