"""Mixtures of EiNets: the paper's §4.2 CelebA model as a first-class citizen.

An :class:`EiNetMixture` is C architecturally-identical EiNet components plus
linear-domain mixture weights:

    log p(x) = log sum_c  w_c  p_c(x)

The C components share ONE compiled structure (one ``EiNet`` instance, i.e.
one set of static gather tables) and stack their parameters along a leading
component axis -- every per-component computation is a ``vmap`` over that
axis, so the whole mixture runs as batched dense ops instead of C separate
model dispatches (the PyJuice observation: batched circuit execution beats
sparse per-model dispatch).

The top-level mixture IS a mixing layer, so ``log p`` routes through the
same fused ``log_mix_exp`` kernel (custom VJP) as every in-circuit mixing
layer: one (M=1, C, K=1) cell.  That gives the mixture EM the identical
EM-via-autodiff treatment -- ``w * d(logP)/dw`` of the routed forward is
exactly the summed responsibilities (``repro.mixture.train``).

Query surface: the ``mixture_*`` kinds mirror EiNet's six kinds at the
mixture level, plus component responsibilities and component-pinned
sampling/decoding/LL (the ``component_kinds``, which the serving engine
folds into its program key).
"""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.einet import EiNet
from repro.core.layers import NEG_INF, log_mix_exp

# mixture-level analogues of EiNet.QUERY_KINDS + responsibilities
MIXTURE_QUERY_KINDS = (
    "mixture_joint_ll",
    "mixture_marginal_ll",
    "mixture_conditional_ll",
    "mixture_sample",
    "mixture_conditional_sample",
    "mixture_mpe",
    "mixture_responsibility",
    # component-pinned kinds (Request.component required; the engine bakes
    # the index into the compiled program)
    "mixture_component_ll",
    "mixture_component_sample",
    "mixture_component_mpe",
)
MIXTURE_COMPONENT_KINDS = (
    "mixture_component_ll",
    "mixture_component_sample",
    "mixture_component_mpe",
)

_W_FLOOR = 1e-38  # log-domain guard for mixture weights (matches layers.py)


class EiNetMixture:
    """C EiNet components with stacked parameters + mixture weights.

    Static structure lives on the shared ``component`` EiNet; learnable
    state is the pytree ``{"components": <stacked component params>,
    "mixture_weights": (C,)}`` produced by :meth:`init`.  Every method is a
    pure function of (params, inputs), so the mixture composes with
    jit / grad / vmap exactly like a single EiNet.
    """

    query_kinds = MIXTURE_QUERY_KINDS
    component_kinds = MIXTURE_COMPONENT_KINDS

    def __init__(self, component: EiNet, num_components: int):
        if num_components < 1:
            raise ValueError(f"need >= 1 component, got {num_components}")
        self.component = component
        self.num_components = int(num_components)
        self.num_vars = component.num_vars

    # ------------------------------------------------------------- parameters
    def init(self, key: jax.Array) -> Dict[str, Any]:
        """Stacked init: component c's params are exactly
        ``component.init(fold(key, c))``, stacked on a leading C axis."""
        keys = jax.random.split(key, self.num_components)
        components = jax.vmap(self.component.init)(keys)
        weights = jnp.full(
            (self.num_components,), 1.0 / self.num_components, jnp.float32
        )
        return {"components": components, "mixture_weights": weights}

    def component_params(self, params: Dict[str, Any], c) -> Dict[str, Any]:
        """Component c's (unstacked) parameter pytree; ``c`` may be traced."""
        return jax.tree_util.tree_map(lambda a: a[c], params["components"])

    def num_params(self, params: Dict[str, Any]) -> int:
        return sum(
            int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(params)
        )

    def project_params(self, params: Dict[str, Any]) -> Dict[str, Any]:
        comps = jax.vmap(self.component.project_params)(params["components"])
        w = jnp.maximum(params["mixture_weights"], 1e-12)
        return {"components": comps, "mixture_weights": w / jnp.sum(w)}

    # ---------------------------------------------------------------- forward
    def component_log_likelihoods(
        self,
        params: Dict[str, Any],
        x: jax.Array,
        marg_mask: Optional[jax.Array] = None,
    ) -> jax.Array:
        """Per-component log-densities: (B, C)."""
        def one(p):
            return self.component.log_likelihood(p, x, marg_mask)

        return jax.vmap(one, out_axes=1)(params["components"])

    def mix_log_likelihoods(
        self, weights: jax.Array, comp_ll: jax.Array
    ) -> jax.Array:
        """(C,) linear weights + (B, C) component LLs -> (B,) mixture LL,
        through the fused ``log_mix_exp`` kernel (the mixture is one
        (M=1, C, K=1) mixing cell, so its EM gradient ``w * dL/dw`` is the
        summed responsibilities -- same custom VJP as in-circuit mixing)."""
        b, c = comp_ll.shape
        v = weights.reshape(1, c, 1)
        ln = comp_ll.reshape(b, 1, c, 1)
        mask = jnp.ones((1, c), jnp.float32)
        return log_mix_exp(v, ln, mask)[:, 0, 0]

    def log_likelihood(
        self,
        params: Dict[str, Any],
        x: jax.Array,
        marg_mask: Optional[jax.Array] = None,
    ) -> jax.Array:
        """log p(x) = log sum_c w_c p_c(x)  (marginals via ``marg_mask``)."""
        comp_ll = self.component_log_likelihoods(params, x, marg_mask)
        return self.mix_log_likelihoods(params["mixture_weights"], comp_ll)

    def conditional_log_likelihood(
        self,
        params: Dict[str, Any],
        x: jax.Array,
        query_mask: jax.Array,
        evidence_mask: jax.Array,
    ) -> jax.Array:
        joint = self.log_likelihood(params, x, query_mask | evidence_mask)
        ev = self.log_likelihood(params, x, evidence_mask)
        return joint - ev

    def responsibilities(
        self,
        params: Dict[str, Any],
        x: jax.Array,
        marg_mask: Optional[jax.Array] = None,
    ) -> jax.Array:
        """Posterior over components r[b, c] = p(c | x_b), rows sum to 1.

        Saturation-safe: logits are clamped to the NEG_INF convention first,
        so rows whose every component underflows to -inf / NEG_INF resolve
        to the uniform posterior instead of NaN (0/0 softmax).
        """
        comp_ll = self.component_log_likelihoods(params, x, marg_mask)
        logits = (
            jnp.log(jnp.maximum(params["mixture_weights"], _W_FLOOR))[None, :]
            + comp_ll
        )
        logits = jnp.maximum(logits, NEG_INF)
        return jax.nn.softmax(logits, axis=-1)

    # --------------------------------------------------------------- sampling
    def conditional_sample_per_key(
        self,
        params: Dict[str, Any],
        keys: jax.Array,
        x: jax.Array,
        evidence_mask: jax.Array,
        mode: str = "sample",
    ) -> jax.Array:
        """Row-independent mixture sampling: one PRNG key per batch row.

        Ancestral in the mixture too: first draw (or argmax, for MPE) the
        component from its evidence posterior p(c | x_e), then run that
        component's induced-tree top-down pass.  Each row is a pure function
        of its own (key, x, evidence) -- the serving engine's micro-batch
        invariance contract, inherited from the single-EiNet path.
        """
        log_w = jnp.log(jnp.maximum(params["mixture_weights"], _W_FLOOR))

        def one(k, xi, ei):
            k_comp, k_draw = jax.random.split(k)
            cll = self.component_log_likelihoods(
                params, xi[None], ei[None]
            )[0]  # (C,)
            logits = jnp.maximum(log_w + cll, NEG_INF)
            if mode == "argmax":
                c = jnp.argmax(logits)
            else:
                c = jax.random.categorical(k_comp, logits)
            p_c = self.component_params(params, c)
            return self.component.conditional_sample(
                p_c, k_draw, xi[None], ei[None], mode=mode
            )[0]

        return jax.vmap(one)(keys, x, evidence_mask)

    def sample_per_key(
        self, params: Dict[str, Any], keys: jax.Array, num_vars_zeros: jax.Array
    ) -> jax.Array:
        """Unconditional per-key sampling.  With no evidence every
        component's evidence marginal is exactly 1 (normalized circuits), so
        the component posterior IS the mixture weights -- draw the component
        from them directly instead of paying C full forward passes per row.
        Bit-identical to the conditional path on empty evidence: the logits
        there reduce to ``log_w + 0``.
        """
        log_w = jnp.maximum(
            jnp.log(jnp.maximum(params["mixture_weights"], _W_FLOOR)), NEG_INF
        )
        ev = jnp.zeros_like(num_vars_zeros, dtype=bool)

        def one(k, xi, ei):
            k_comp, k_draw = jax.random.split(k)
            c = jax.random.categorical(k_comp, log_w)
            p_c = self.component_params(params, c)
            return self.component.conditional_sample(
                p_c, k_draw, xi[None], ei[None]
            )[0]

        return jax.vmap(one)(keys, num_vars_zeros, ev)

    def component_conditional_sample_per_key(
        self,
        params: Dict[str, Any],
        keys: jax.Array,
        x: jax.Array,
        evidence_mask: jax.Array,
        component: int,
        mode: str = "sample",
    ) -> jax.Array:
        """Sampling pinned to one component (a static index: the serving
        engine compiles one program per component)."""
        p_c = self.component_params(params, int(component))
        return self.component.conditional_sample_per_key(
            p_c, keys, x, evidence_mask, mode=mode
        )

    # ----------------------------------------------------------------- query
    def query(
        self,
        params: Dict[str, Any],
        batch: Dict[str, Any],
        kind: str,
        component: Optional[int] = None,
    ) -> jax.Array:
        """Uniform exact-inference entry point (the serving-engine surface).

        Same input signature as ``EiNet.query`` -- "x", "evidence_mask",
        "query_mask", "keys" -- so mixture programs share the engine's
        assembly/bucketing path unchanged.  ``component`` is a STATIC index,
        required by the ``mixture_component_*`` kinds and rejected
        otherwise.
        """
        if kind in MIXTURE_COMPONENT_KINDS:
            if component is None:
                raise ValueError(f"kind {kind!r} requires a component index")
        elif component is not None:
            raise ValueError(f"kind {kind!r} does not take a component")
        x = batch["x"]
        if kind == "mixture_joint_ll":
            return self.log_likelihood(params, x)
        if kind == "mixture_marginal_ll":
            return self.log_likelihood(params, x, batch["evidence_mask"])
        if kind == "mixture_conditional_ll":
            return self.conditional_log_likelihood(
                params, x, batch["query_mask"], batch["evidence_mask"]
            )
        if kind == "mixture_responsibility":
            return self.responsibilities(params, x)
        if kind == "mixture_sample":
            return self.sample_per_key(
                params, batch["keys"], jnp.zeros_like(x)
            )
        if kind == "mixture_conditional_sample":
            return self.conditional_sample_per_key(
                params, batch["keys"], x, batch["evidence_mask"]
            )
        if kind == "mixture_mpe":
            return self.conditional_sample_per_key(
                params, batch["keys"], x, batch["evidence_mask"],
                mode="argmax",
            )
        if kind == "mixture_component_ll":
            p_c = self.component_params(params, int(component))
            return self.component.log_likelihood(p_c, x)
        if kind == "mixture_component_sample":
            return self.component_conditional_sample_per_key(
                params, batch["keys"], x, batch["evidence_mask"], component
            )
        if kind == "mixture_component_mpe":
            return self.component_conditional_sample_per_key(
                params, batch["keys"], x, batch["evidence_mask"], component,
                mode="argmax",
            )
        raise ValueError(
            f"unknown query kind {kind!r}; one of {MIXTURE_QUERY_KINDS}"
        )
