"""Mixtures of EiNets (paper §4.2): k-means clustering, stacked-parameter
mixture model, and vmapped multi-component EM.

The paper's flagship CelebA result is a mixture of EiNets trained over image
clusters.  This package makes that a first-class subsystem: deterministic
minibatch k-means partitions the data (``cluster``), ``EiNetMixture`` stacks
C architecturally-identical components on a leading parameter axis and
routes ``log p`` through the fused ``log_mix_exp`` kernel (``model``), and a
single jitted vmapped EM step advances every component in lockstep
(``train``) -- hard per-cluster EM or soft responsibility-weighted EM, both
via the EM-as-autodiff trick of §3.5.
"""

from repro.mixture.cluster import KMeansResult, cluster_order, kmeans
from repro.mixture.model import (
    MIXTURE_COMPONENT_KINDS,
    MIXTURE_QUERY_KINDS,
    EiNetMixture,
)
from repro.mixture.train import (
    MixtureTrainConfig,
    fit_mixture,
    hard_mixture_em_update,
    make_mixture_em_step,
    microbatched_mixture_em_statistics,
    mixture_em_statistics,
    mixture_em_update,
    prepare_mixture_training,
    stacked_cluster_loader,
    stochastic_mixture_em_update,
)

__all__ = [
    "KMeansResult",
    "cluster_order",
    "kmeans",
    "EiNetMixture",
    "MIXTURE_QUERY_KINDS",
    "MIXTURE_COMPONENT_KINDS",
    "MixtureTrainConfig",
    "fit_mixture",
    "hard_mixture_em_update",
    "make_mixture_em_step",
    "microbatched_mixture_em_statistics",
    "mixture_em_statistics",
    "mixture_em_update",
    "prepare_mixture_training",
    "stacked_cluster_loader",
    "stochastic_mixture_em_update",
]
