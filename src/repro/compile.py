"""Shared compiled-program registry for serving and training.

Before this module, the repo had two parallel compile caches: the serving
engine's AOT program dict (``serve/engine.py``, keyed ``(kind, bucket)``) and
training's convention that callers hold on to the ``jax.jit`` object returned
by ``make_em_step`` (``train/pipeline.py``) -- duplicated bookkeeping, and no
sharing when a process both trains and serves the same model (the eval
workbench, the mixture pipeline).  This registry is the one place compiled
programs live:

  * **AOT programs** (:meth:`ProgramRegistry.aot`): ``fn.lower(...).compile()``
    under an optional sharding-rule table -- the serving engine's padded
    bucket programs, keyed by ``(kind, bucket[, component])``.
  * **Jitted steps** (:meth:`ProgramRegistry.jit`): donated-buffer training
    steps, keyed by the step kind + config -- two ``make_em_step`` calls with
    the same (model, config) now return the SAME compiled callable instead of
    two jit objects that each retrace.

Keys are ``(anchor, key)`` where ``anchor`` is the model (or any long-lived
object) held via ``weakref`` so dead models do not pin their programs, and
``key`` is a hashable tuple of (fn-kind, bucket/shape/config) -- the
"(fn, kind, bucket/shape)" contract.  Compile wall-clock and hit counts are
tracked per registry; the engine surfaces them as ``engine.stats``.

A module-level :data:`REGISTRY` is the default used by ``repro.train`` and
``repro.serve`` (and by ``repro.mixture`` from day one); passing an explicit
registry isolates cache statistics (benchmarks, tests).
"""

from __future__ import annotations

import weakref
from typing import Any, Callable, Dict, Hashable, Optional, Sequence, Tuple

from repro import obs


class ProgramRegistry:
    """One cache of compiled XLA programs, shared across serve and train."""

    def __init__(self):
        # anchor (weak) -> {key: program}; anchors are models/engines whose
        # death must release their programs
        self._tables: "weakref.WeakKeyDictionary[Any, Dict[Hashable, Any]]" = (
            weakref.WeakKeyDictionary()
        )
        self.stats = {"compiles": 0, "compile_s": 0.0, "hits": 0}

    # ------------------------------------------------------------- inspection
    def table(self, anchor: Any) -> Dict[Hashable, Any]:
        """The (mutable) key -> program table anchored to ``anchor``."""
        tab = self._tables.get(anchor)
        if tab is None:
            tab = {}
            self._tables[anchor] = tab
        return tab

    def num_programs(self, anchor: Optional[Any] = None) -> int:
        if anchor is not None:
            return len(self._tables.get(anchor, ()))
        return sum(len(t) for t in self._tables.values())

    def clear(self) -> None:
        self._tables = weakref.WeakKeyDictionary()
        self.stats = {"compiles": 0, "compile_s": 0.0, "hits": 0}

    # -------------------------------------------------------------- AOT path
    def aot(
        self,
        anchor: Any,
        key: Hashable,
        fn: Callable,
        abstract_args: Tuple[Any, ...],
        rules: Optional[Any] = None,
    ):
        """Ahead-of-time compile ``fn`` for ``abstract_args`` (pytrees of
        arrays / ShapeDtypeStructs), cached under ``(anchor, key)``.

        ``rules``: optional ``repro.dist.sharding`` rule table the lowering
        runs under (the serve-rules path); per the dist degradation contract
        this is a no-op without a multi-device mesh.
        """
        table = self.table(anchor)
        prog = table.get(key)
        if prog is not None:
            self.stats["hits"] += 1
            obs.cache_event("aot", hit=True)
            return prog
        import jax

        jitted = jax.jit(fn)
        with obs.timed("compile.aot", key=repr(key)) as t:
            if rules is not None:
                from repro.dist import sharding as shlib

                with shlib.use_rules(rules):
                    prog = jitted.lower(*abstract_args).compile()
            else:
                prog = jitted.lower(*abstract_args).compile()
        self.stats["compile_s"] += t.seconds
        self.stats["compiles"] += 1
        obs.compile_event("aot", key, t.seconds)
        table[key] = prog
        return prog

    # ----------------------------------------------------------- jitted path
    def jit(
        self,
        anchor: Any,
        key: Hashable,
        fn: Callable,
        donate_argnums: Sequence[int] = (),
        static_argnames: Sequence[str] = (),
        jit_kwargs: Optional[Dict[str, Any]] = None,
    ) -> Callable:
        """Cached ``jax.jit(fn, donate_argnums=...)`` under ``(anchor, key)``.

        Unlike :meth:`aot` this compiles lazily per input shape (jax's own
        per-shape cache), but the registry guarantees one jit object per
        (anchor, key) -- repeat ``make_em_step`` calls stop paying a retrace.

        ``static_argnames`` / ``jit_kwargs`` (e.g. in/out shardings) pass
        through to ``jax.jit``; they are NOT part of the cache key, so the
        caller's ``key`` must distinguish variants.
        """
        table = self.table(anchor)
        jitted = table.get(key)
        if jitted is not None:
            self.stats["hits"] += 1
            obs.cache_event("jit", hit=True)
            return jitted
        import jax

        jitted = jax.jit(
            fn,
            donate_argnums=tuple(donate_argnums),
            static_argnames=tuple(static_argnames),
            **(jit_kwargs or {}),
        )
        self.stats["compiles"] += 1
        obs.compile_event("jit", key, 0.0)
        # the lazy jit path has no compile wall-clock to span; an instant
        # marker keeps "compile." visible in traces of train-only runs
        obs.event("compile.jit", key=repr(key))
        table[key] = jitted
        return jitted


# The process-wide default registry: train steps and serve programs share it
# unless a caller passes its own (benchmarks and tests that count compiles).
REGISTRY = ProgramRegistry()
