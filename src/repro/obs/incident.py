"""Divergence flight recorder: self-contained incident bundles.

When :class:`repro.obs.health.HealthWatcher` trips (non-finite LL,
exploding statistic norms, saturation spike), :func:`dump_incident` writes
everything needed to debug the divergence *after the fact* into
``artifacts/incidents/<ts>/``:

  * ``incident.json``        -- reason, step, policy-visible trigger values,
    the health-slot layout;
  * ``metrics.json``         -- a full ``METRICS.snapshot()`` at the moment
    of the incident;
  * ``trace.json``           -- a Chrome-trace export of the buffered spans
    plus one synthesized ``train.incident`` marker (so the document is a
    schema-valid trace even when tracing was off);
  * ``health_history.json``  -- the watcher's recent per-step health rows;
  * ``params.npz`` + ``params_tree.txt`` -- the offending step's parameter
    checkpoint (flattened pytree leaves, loadable with ``numpy.load``).

Time reads live here legally (this file is under ``repro/obs/``, the one
place the ``timing-outside-obs`` lint rule allows them).  numpy/jax are
imported lazily so the module itself stays importable anywhere.
"""

from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Dict, List, Optional

from repro.obs import trace as trace_mod


def _synth_marker(reason: str, step: int) -> Dict[str, Any]:
    """One instant event on the shared trace clock marking the incident."""
    return {
        "ph": "i",
        "s": "t",
        "name": "train.incident",
        "ts": (time.perf_counter_ns() - trace_mod._T0_NS) / 1e3,
        "pid": os.getpid(),
        "tid": threading.get_ident(),
        "args": {"reason": reason, "step": step},
    }


def _bundle_dir(root: str) -> str:
    ts = time.strftime("%Y%m%dT%H%M%S", time.gmtime())
    path = os.path.join(root, ts)
    n = 0
    while os.path.exists(path):  # same-second incidents get a suffix
        n += 1
        path = os.path.join(root, f"{ts}.{n}")
    os.makedirs(path)
    return path


def dump_incident(
    root: str,
    reason: str,
    step: int,
    history: List[Dict[str, float]],
    params: Any = None,
    spec: Any = None,
) -> str:
    """Write one incident bundle; returns its directory path."""
    from repro.obs.metrics import METRICS

    path = _bundle_dir(root)
    with open(os.path.join(path, "incident.json"), "w") as f:
        json.dump(
            {
                "reason": reason,
                "step": step,
                "time_utc": time.strftime(
                    "%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
                "health_slots": list(spec.names) if spec is not None else [],
                "last_health": history[-1] if history else {},
            },
            f, indent=1,
        )
    with open(os.path.join(path, "metrics.json"), "w") as f:
        json.dump(METRICS.snapshot(), f, indent=1)
    events = trace_mod.trace_events()
    events.append(_synth_marker(reason, step))
    with open(os.path.join(path, "trace.json"), "w") as f:
        json.dump(
            {
                "traceEvents": events,
                "displayTimeUnit": "ms",
                "otherData": {
                    "producer": "repro.obs.incident",
                    "dropped_events": trace_mod.dropped_events(),
                },
            },
            f,
        )
    with open(os.path.join(path, "health_history.json"), "w") as f:
        json.dump(history, f, indent=1)
    if params is not None:
        _dump_params(path, params)
    return path


def _dump_params(path: str, params: Any) -> None:
    import numpy as np

    try:
        import jax

        leaves, treedef = jax.tree_util.tree_flatten(params)
        tree_repr = str(treedef)
    except Exception:  # params already a flat list / dict of arrays
        leaves = list(params.values()) if isinstance(params, dict) else [params]
        tree_repr = repr(type(params))
    np.savez(
        os.path.join(path, "params.npz"),
        **{f"leaf_{i}": np.asarray(leaf) for i, leaf in enumerate(leaves)},
    )
    with open(os.path.join(path, "params_tree.txt"), "w") as f:
        f.write(tree_repr + "\n")
