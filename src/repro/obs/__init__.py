"""``repro.obs``: zero-dependency tracing + metrics for every hot path.

Three parts (see the submodule docstrings):

  * :mod:`repro.obs.trace`   -- nestable ``span(...)`` context managers,
    Chrome/Perfetto ``trace_event`` export, the ``REPRO_TRACE`` switch;
  * :mod:`repro.obs.metrics` -- counters / gauges / log-bucket histograms
    with ``percentile(q)``, snapshot-able to plain JSON;
  * :mod:`repro.obs.events`  -- the shared compile-event hook fed by
    ``repro.compile.ProgramRegistry`` (single source of truth for compile
    counts; ``analysis.sentry`` subscribes here).

Instrumented subsystems tag spans/metrics as ``subsystem.verb.unit``:
``serve.request.seconds{kind,bucket}``, ``compile.cache.misses{kind}``,
``plan.segment`` (trace-time, per execution-plan segment), ``train.step.
seconds``, ``eval.inpaint.seconds{mask}``.  The launch CLIs accept
``--trace out.json`` and print one ``[obs]`` summary line at exit
(:func:`format_summary`).

Import discipline: stdlib only.  Everything in ``repro`` (including
``repro.compile`` before jax loads) may import ``repro.obs`` freely.
"""

from repro.obs.events import (
    cache_event,
    compile_event,
    on_compile,
    remove_compile_listener,
)
from repro.obs.metrics import (
    METRICS,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    percentile_from_counts,
)
from repro.obs.trace import (
    Span,
    Timed,
    configure,
    dropped_events,
    enabled,
    event,
    export_trace,
    now,
    num_events,
    reset,
    set_sync,
    span,
    sync,
    timed,
    trace_events,
)

__all__ = [
    "METRICS", "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "Span", "Timed", "cache_event", "compile_event", "configure",
    "dropped_events", "enabled", "event", "export_trace", "format_summary",
    "now", "num_events", "on_compile", "percentile_from_counts",
    "remove_compile_listener", "reset", "set_sync", "span", "summary",
    "sync", "timed", "trace_events",
]


def summary() -> dict:
    """Compact cross-subsystem rollup of the metrics registry (the data
    behind the ``[obs]`` exit line)."""
    out: dict = {}
    compiles = sum(
        m.value for _, m in METRICS.find("compile.cache.misses")
    )
    if compiles:
        out["compiles"] = int(compiles)
        out["compile_seconds"] = round(sum(
            m.value for _, m in METRICS.find("compile.programs.seconds")
        ), 3)
        hits = sum(m.value for _, m in METRICS.find("compile.cache.hits"))
        out["cache_hits"] = int(hits)
    req = METRICS.sum_histogram("serve.request.seconds")
    n_req = sum(req)
    if n_req:
        out["serve_requests"] = n_req
        out["serve_latency_ms"] = {
            f"p{q}": round(percentile_from_counts(req, q) * 1e3, 3)
            for q in (50, 95, 99)
        }
    steps = METRICS.sum_histogram("train.step.seconds")
    n_steps = sum(steps)
    if n_steps:
        out["train_steps"] = n_steps
        out["train_step_ms_p50"] = round(
            percentile_from_counts(steps, 50) * 1e3, 1)
        ex = METRICS.value("train.examples.count")
        if ex:
            out["train_examples"] = int(ex)
    seg = [(d.get("kind"), int(m.value))
           for d, m in METRICS.find("plan.segment.traces")]
    if seg:
        out["plan_segment_traces"] = dict(sorted(seg))
    if num_events():
        out["trace_events"] = num_events()
    if dropped_events():
        out["trace_dropped"] = dropped_events()
    return out


def format_summary() -> str:
    """The ``[obs]`` exit line: human-readable one-liner of :func:`summary`."""
    s = summary()
    parts = []
    if "compiles" in s:
        parts.append(
            f"compile: {s['compiles']} programs "
            f"({s['compile_seconds']:.2f} s, {s['cache_hits']} cache hits)"
        )
    if "serve_requests" in s:
        lm = s["serve_latency_ms"]
        parts.append(
            f"serve: {s['serve_requests']} req, p50 {lm['p50']:.2f} ms, "
            f"p95 {lm['p95']:.2f} ms, p99 {lm['p99']:.2f} ms"
        )
    if "train_steps" in s:
        ex = f", {s['train_examples']} examples" if "train_examples" in s \
            else ""
        parts.append(
            f"train: {s['train_steps']} steps, "
            f"p50 {s['train_step_ms_p50']:.0f} ms/step{ex}"
        )
    if "plan_segment_traces" in s:
        seg = ", ".join(f"{k}={v}" for k, v in
                        s["plan_segment_traces"].items())
        parts.append(f"plan traces: {seg}")
    if "trace_events" in s:
        t = f"trace: {s['trace_events']} events"
        if "trace_dropped" in s:
            t += f" ({s['trace_dropped']} dropped, buffer cap hit)"
        parts.append(t)
    return " | ".join(parts) if parts else "no activity recorded"


def cli_begin(trace_path=None) -> None:
    """Launch-CLI prologue: ``--trace out.json`` enables collection."""
    if trace_path:
        configure(trace=True)


def cli_end(trace_path=None, metrics_path=None) -> None:
    """Launch-CLI epilogue: print the ``[obs]`` line; export the trace and
    (with ``--metrics out.json``) the metrics snapshot."""
    print(f"[obs] {format_summary()}")
    if trace_path:
        path = export_trace(trace_path)
        print(f"[obs] trace: {num_events()} events -> {path}")
    if metrics_path:
        import json
        import os

        d = os.path.dirname(metrics_path)
        if d:
            os.makedirs(d, exist_ok=True)
        snap = METRICS.snapshot()
        with open(metrics_path, "w") as f:
            json.dump(snap, f, indent=1)
        print(f"[obs] metrics: {len(snap)} series -> {metrics_path}")
