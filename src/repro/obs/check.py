"""Chrome-trace schema checker: the CI gate behind the trace-smoke step.

Validates that an exported trace is structurally a Chrome ``trace_event``
JSON document -- loads through ``json.loads``, ``traceEvents`` is a list,
every event carries ``ph``/``ts``/``name``/``args`` (and ``dur`` for
complete events) with sane types -- and optionally that spans from required
subsystems are present (``--require serve.`` asserts at least one event
whose name starts with that prefix).

  PYTHONPATH=src python -m repro.obs.check /tmp/trace.json \
      --require serve. --require plan. --require compile.

Exit status 0 = valid, 1 = problems (each printed).  stdlib-only, like the
rest of ``repro.obs``.
"""

from __future__ import annotations

import json
import sys
from typing import Any, Dict, List, Sequence

_REQUIRED_FIELDS = ("ph", "ts", "name", "args")


def validate_events(doc: Any,
                    require_prefixes: Sequence[str] = ()) -> List[str]:
    """Problems found in a parsed trace document (empty list = valid)."""
    problems: List[str] = []
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        return ["document is not an object with a 'traceEvents' key"]
    events = doc["traceEvents"]
    if not isinstance(events, list):
        return ["'traceEvents' is not a list"]
    if not events:
        problems.append("trace contains no events")
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            problems.append(f"event {i} is not an object")
            continue
        missing = [f for f in _REQUIRED_FIELDS if f not in ev]
        if missing:
            problems.append(f"event {i} ({ev.get('name')!r}) missing "
                            f"field(s) {missing}")
            continue
        if not isinstance(ev["name"], str) or not ev["name"]:
            problems.append(f"event {i}: 'name' must be a non-empty string")
        if not isinstance(ev["ts"], (int, float)) or ev["ts"] < 0:
            problems.append(f"event {i} ({ev['name']!r}): bad ts {ev['ts']!r}")
        if not isinstance(ev["args"], dict):
            problems.append(f"event {i} ({ev['name']!r}): 'args' must be "
                            f"an object")
        if ev["ph"] == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                problems.append(
                    f"event {i} ({ev['name']!r}): complete event needs a "
                    f"non-negative 'dur', got {dur!r}")
    names = [ev.get("name", "") for ev in events if isinstance(ev, dict)]
    for prefix in require_prefixes:
        if not any(isinstance(n, str) and n.startswith(prefix)
                   for n in names):
            problems.append(
                f"no span from required subsystem {prefix!r} "
                f"(have: {sorted(set(names))[:12]})")
    return problems


def validate_trace(path: str,
                   require_prefixes: Sequence[str] = ()) -> List[str]:
    """Load + validate one exported trace file."""
    try:
        with open(path) as f:
            doc: Dict[str, Any] = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"cannot load {path}: {e}"]
    return validate_events(doc, require_prefixes)


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.check", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("path", help="exported Chrome-trace JSON file")
    ap.add_argument("--require", action="append", default=[],
                    metavar="PREFIX",
                    help="assert at least one event name starts with this "
                         "prefix (repeatable)")
    args = ap.parse_args(argv)
    problems = validate_trace(args.path, args.require)
    for p in problems:
        print(f"trace check: {p}")
    if not problems:
        with open(args.path) as f:
            n = len(json.load(f)["traceEvents"])
        print(f"trace check: {args.path} valid ({n} events)")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
