"""Chrome-trace schema checker: the CI gate behind the trace-smoke step.

Validates that an exported trace is structurally a Chrome ``trace_event``
JSON document -- loads through ``json.loads``, ``traceEvents`` is a list,
every event carries ``ph``/``ts``/``name``/``args`` (and ``dur`` for
complete events) with sane types -- and optionally that spans from required
subsystems are present (``--require serve.`` asserts at least one event
whose name starts with that prefix).

  PYTHONPATH=src python -m repro.obs.check /tmp/trace.json \
      --require serve. --require plan. --require compile.

Exit status 0 = valid, 1 = problems (each printed).  stdlib-only, like the
rest of ``repro.obs``.
"""

from __future__ import annotations

import json
import math
import re
import sys
from typing import Any, Dict, List, Sequence

_REQUIRED_FIELDS = ("ph", "ts", "name", "args")

# metric names follow ``subsystem.verb.unit`` (>= 3 dotted segments) with
# optional ``{label=value,...}`` -- e.g. ``serve.request.seconds{kind=mpe,
# bucket=4}``; see repro/obs/metrics.py
_METRIC_NAME_RE = re.compile(
    r"^[a-z][a-z0-9_]*(\.[a-z0-9_]+){2,}(\{[^{}]+\})?$"
)


def validate_events(doc: Any,
                    require_prefixes: Sequence[str] = ()) -> List[str]:
    """Problems found in a parsed trace document (empty list = valid)."""
    problems: List[str] = []
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        return ["document is not an object with a 'traceEvents' key"]
    events = doc["traceEvents"]
    if not isinstance(events, list):
        return ["'traceEvents' is not a list"]
    if not events:
        problems.append("trace contains no events")
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            problems.append(f"event {i} is not an object")
            continue
        missing = [f for f in _REQUIRED_FIELDS if f not in ev]
        if missing:
            problems.append(f"event {i} ({ev.get('name')!r}) missing "
                            f"field(s) {missing}")
            continue
        if not isinstance(ev["name"], str) or not ev["name"]:
            problems.append(f"event {i}: 'name' must be a non-empty string")
        if not isinstance(ev["ts"], (int, float)) or ev["ts"] < 0:
            problems.append(f"event {i} ({ev['name']!r}): bad ts {ev['ts']!r}")
        if not isinstance(ev["args"], dict):
            problems.append(f"event {i} ({ev['name']!r}): 'args' must be "
                            f"an object")
        if ev["ph"] == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                problems.append(
                    f"event {i} ({ev['name']!r}): complete event needs a "
                    f"non-negative 'dur', got {dur!r}")
    names = [ev.get("name", "") for ev in events if isinstance(ev, dict)]
    for prefix in require_prefixes:
        if not any(isinstance(n, str) and n.startswith(prefix)
                   for n in names):
            problems.append(
                f"no span from required subsystem {prefix!r} "
                f"(have: {sorted(set(names))[:12]})")
    return problems


def validate_trace(path: str,
                   require_prefixes: Sequence[str] = ()) -> List[str]:
    """Load + validate one exported trace file."""
    try:
        with open(path) as f:
            doc: Dict[str, Any] = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"cannot load {path}: {e}"]
    return validate_events(doc, require_prefixes)


def _finite_number(v: Any) -> bool:
    return (isinstance(v, (int, float)) and not isinstance(v, bool)
            and math.isfinite(v))


def validate_metrics(snap: Any) -> List[str]:
    """Problems in a ``METRICS.snapshot()`` document (empty list = valid).

    Schema: a flat non-empty JSON object whose keys follow
    ``subsystem.verb.unit{labels}`` and whose values are finite numbers
    (counters, legacy scalar gauges) or flat objects of finite numbers
    (histogram summaries, gauge value/max pairs).
    """
    if not isinstance(snap, dict) or not snap:
        return ["metrics snapshot is not a non-empty object"]
    problems: List[str] = []
    for name, value in snap.items():
        if not isinstance(name, str) or not _METRIC_NAME_RE.match(name):
            problems.append(
                f"metric {name!r}: name does not follow "
                "subsystem.verb.unit{labels}")
        if _finite_number(value):
            continue
        if isinstance(value, dict) and value:
            for k, v in value.items():
                if not _finite_number(v):
                    problems.append(
                        f"metric {name!r}: field {k!r} is not a finite "
                        f"number ({v!r})")
            continue
        problems.append(
            f"metric {name!r}: value must be a finite number or a flat "
            f"object of finite numbers, got {value!r}")
    return problems


def validate_metrics_file(path: str) -> List[str]:
    try:
        with open(path) as f:
            snap = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"cannot load {path}: {e}"]
    return validate_metrics(snap)


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.check", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("path", nargs="?", default=None,
                    help="exported Chrome-trace JSON file")
    ap.add_argument("--require", action="append", default=[],
                    metavar="PREFIX",
                    help="assert at least one event name starts with this "
                         "prefix (repeatable)")
    ap.add_argument("--metrics", default=None, metavar="SNAPSHOT.json",
                    help="also validate a METRICS.snapshot() JSON file "
                         "(name format subsystem.verb.unit{labels}, finite "
                         "values)")
    args = ap.parse_args(argv)
    if args.path is None and args.metrics is None:
        ap.error("nothing to check: pass a trace path and/or --metrics")
    problems: List[str] = []
    if args.path is not None:
        trace_problems = validate_trace(args.path, args.require)
        for p in trace_problems:
            print(f"trace check: {p}")
        if not trace_problems:
            with open(args.path) as f:
                n = len(json.load(f)["traceEvents"])
            print(f"trace check: {args.path} valid ({n} events)")
        problems += trace_problems
    if args.metrics is not None:
        metric_problems = validate_metrics_file(args.metrics)
        for p in metric_problems:
            print(f"metrics check: {p}")
        if not metric_problems:
            with open(args.metrics) as f:
                n = len(json.load(f))
            print(f"metrics check: {args.metrics} valid ({n} series)")
        problems += metric_problems
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
