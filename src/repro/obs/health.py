"""Device-side numerical-health telemetry for the compiled EM step.

EiNet failure modes live *inside* the compiled programs -- rows pinned at
``NEG_INF`` after a saturated ``log_einsum_exp``, EF parameters stuck at
their clamp bounds, exploding E-step statistics -- where host-side tracing
(:mod:`repro.obs.trace`) cannot see.  This module computes a fixed-shape
**health vector** as an extra output of the already-compiled training
program: every slot is a scalar reduction over intermediates XLA is already
materializing (no host callbacks, no Pallas changes), so enabling it adds
zero recompiles per step and disabling it leaves the program untouched.

Layout (:class:`HealthSpec`): a stable tuple of named slots --

  * ``ll.mean`` / ``ll.min`` / ``ll.nonfinite``  -- batch log-likelihood
    health (mean over the full batch from the E-step statistics; min and
    non-finite count over the probe microbatch);
  * ``leaf.sat_frac``    -- fraction of leaf-region rows pinned at NEG_INF;
  * ``leaf.clamp_frac``  -- fraction of EF parameters at their clamp bounds
    (:meth:`ExponentialFamily.clamp_fraction`);
  * ``weight.entropy``   -- mean sum-weight entropy (collapse detector);
  * ``stat.norm.max`` / ``stat.norm.mean`` / ``stat.nonfinite`` -- E-step
    statistic block norms and non-finite count;
  * ``seg{i}.sat_frac``  -- per execution-plan segment, the saturated-row
    fraction of that segment's ``log_einsum_exp`` output.

The per-segment slots come from **taps**: ``core/einet.py``'s plan walk
calls :func:`tap_segment` after each segment.  A tap is one thread-local
attribute read when no collector is active (the permanent cost of the
instrumentation); under :func:`collect` -- active only while the dedicated
health forward of ``train/pipeline.py`` is being traced -- it appends the
segment's saturation fraction to the health vector under construction.
The gradient/scan forwards never run under a collector, so their graphs
are byte-identical with health on or off.

Gating: the ``EiNet(health=...)`` ctor knob (``None`` defers to the
``REPRO_HEALTH`` env var), overridable per step via
``TrainConfig(health=...)``.  The fetched vector feeds ``train.health.*``
gauges (:func:`publish`) and the divergence flight recorder
(:class:`HealthWatcher` -> :mod:`repro.obs.incident`).

Import discipline: this submodule imports jax and is NOT re-exported by
``repro.obs`` (whose package root stays stdlib-only); jax-land callers
import ``repro.obs.health`` directly.
"""

from __future__ import annotations

import collections
import dataclasses
import os
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.layers import NEG_INF

# a log-space row is "saturated" when it has collapsed to the NEG_INF
# sentinel (halved so float roundoff in the stabilized frame can't unpin it)
SAT_THRESHOLD = 0.5 * NEG_INF

BASE_SLOTS: Tuple[str, ...] = (
    "ll.mean",
    "ll.min",
    "ll.nonfinite",
    "leaf.sat_frac",
    "leaf.clamp_frac",
    "weight.entropy",
    "stat.norm.max",
    "stat.norm.mean",
    "stat.nonfinite",
)


def resolve_health(value: Optional[bool]) -> bool:
    """Ctor-knob resolution: an explicit value wins, else ``REPRO_HEALTH``."""
    if value is not None:
        return bool(value)
    env = os.environ.get("REPRO_HEALTH", "").strip().lower()
    return env not in ("", "0", "false", "off", "no")


@dataclasses.dataclass(frozen=True)
class HealthSpec:
    """The fixed slot layout of one model's health vector.

    Deterministic per model (base slots + one saturation slot per execution
    segment, in plan order), so the packed vector's shape -- and therefore
    the compiled step's output signature -- never changes across steps.
    """

    names: Tuple[str, ...]

    @property
    def size(self) -> int:
        return len(self.names)

    @property
    def num_segments(self) -> int:
        return len(self.names) - len(BASE_SLOTS)

    def index(self, name: str) -> int:
        return self.names.index(name)

    def to_dict(self, vec) -> Dict[str, float]:
        return {n: float(v) for n, v in zip(self.names, vec)}


def num_segments(model) -> int:
    """Tap count of one forward pass: plan segments when the grouped walk is
    active, else one per (einsum, mixing) pair of the per-layer loop."""
    if model.grouped_active:
        return len(model.exec_plan)
    return len(model.pair_specs)


def spec_for(model) -> HealthSpec:
    return HealthSpec(BASE_SLOTS + tuple(
        f"seg{i}.sat_frac" for i in range(num_segments(model))
    ))


# ------------------------------------------------------------------- taps
_TAP = threading.local()


class _Collector:
    """Context manager arming the tap sites for one traced forward."""

    __slots__ = ("items", "_prev")

    def __init__(self):
        self.items: List[jax.Array] = []
        self._prev = None

    def __enter__(self) -> List[jax.Array]:
        self._prev = getattr(_TAP, "items", None)
        _TAP.items = self.items
        return self.items

    def __exit__(self, *exc) -> bool:
        _TAP.items = self._prev
        return False


def collect() -> _Collector:
    """Arm :func:`tap_segment` for the ``with`` body (one health forward)."""
    return _Collector()


def tap_segment(value: jax.Array) -> None:
    """Per-segment tap site (called by the ``core/einet.py`` plan walks).

    No collector active -- one thread-local attribute read, nothing added
    to the traced graph.  Collector active -- appends this segment's
    saturated-row fraction (entries pinned at NEG_INF) to the health
    vector under construction.
    """
    items = getattr(_TAP, "items", None)
    if items is None:
        return
    items.append(jnp.mean((value <= SAT_THRESHOLD).astype(jnp.float32)))


# --------------------------------------------------------- vector assembly
def saturation_fraction(value: jax.Array) -> jax.Array:
    return jnp.mean((value <= SAT_THRESHOLD).astype(jnp.float32))


def _f32(v) -> jax.Array:
    # strong float32: a weak-typed slot would change the step's output aval
    # and silently recompile (the PR 3 class_prior bug class)
    return jnp.asarray(v, jnp.float32)


def _nonfinite_count(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return sum(jnp.sum(~jnp.isfinite(leaf)) for leaf in leaves)


def _weight_entropy(einsum_w: List[jax.Array]) -> jax.Array:
    """Mean entropy of the (K x K) child distribution of every sum node --
    near-zero entropy means the circuit has collapsed onto single children."""
    ents = []
    for w in einsum_w:
        p = w / jnp.maximum(jnp.sum(w, axis=(-2, -1), keepdims=True), 1e-38)
        ents.append(jnp.mean(
            -jnp.sum(p * jnp.log(jnp.maximum(p, 1e-38)), axis=(-2, -1))
        ))
    return jnp.mean(jnp.stack(ents))


def health_vector(
    model,
    params: Dict[str, Any],
    probe_x: jax.Array,
    stats: Dict[str, Any],
    new_params: Dict[str, Any],
) -> jax.Array:
    """Assemble the health vector inside the compiled EM update.

    ``probe_x`` is the (sub)batch the dedicated health forward runs on --
    the full batch at one microbatch (where XLA CSE merges it with the
    E-step's primal forward), the first microbatch otherwise (the scan body
    cannot leak intermediates, so the probe re-runs one bounded forward).
    ``stats`` are the E-step statistics (full batch, exact), ``new_params``
    the post-update parameters whose entropy/clamp state we monitor.
    """
    spec = model.health_spec
    # -- dedicated health forward, tap sites armed
    e = model.leaf_log_prob(params, probe_x, None)
    leaf_rows = model._leaf_rows(e)
    with collect() as taps:
        root = model.forward_from_e(
            params["einsum"], params["mixing"], None, leaf_rows=leaf_rows
        )
    if len(taps) != spec.num_segments:
        raise AssertionError(
            f"health taps out of sync with the plan: got {len(taps)} "
            f"segments, spec has {spec.num_segments}"
        )
    ll_rows = jax.scipy.special.logsumexp(
        root + jnp.log(params["class_prior"])[None, :], axis=-1
    )
    # -- statistic block norms (einsum blocks + the leaf moment tensor)
    norms = jnp.stack(
        [jnp.sqrt(jnp.sum(jnp.square(n))) for n in stats["n_einsum"]]
        + [jnp.sqrt(jnp.sum(jnp.square(stats["s_phi"])))]
    )
    base = {
        "ll.mean": stats["ll"] / stats["count"],
        "ll.min": jnp.min(ll_rows),
        "ll.nonfinite": jnp.sum(~jnp.isfinite(ll_rows)),
        "leaf.sat_frac": saturation_fraction(leaf_rows),
        "leaf.clamp_frac": model.ef.clamp_fraction(new_params["phi"]),
        "weight.entropy": _weight_entropy(new_params["einsum"]),
        "stat.norm.max": jnp.max(norms),
        "stat.norm.mean": jnp.mean(norms),
        "stat.nonfinite": _nonfinite_count(stats),
    }
    return jnp.stack(
        [_f32(base[n]) for n in BASE_SLOTS] + [_f32(t) for t in taps]
    )


def publish(spec: HealthSpec, vec) -> None:
    """Feed a fetched health vector into the ``train.health.*`` gauges."""
    from repro.obs.metrics import METRICS

    import numpy as np

    for name, value in zip(spec.names, np.asarray(vec)):
        METRICS.gauge(f"train.health.{name}").set(float(value))


# ------------------------------------------------- divergence flight recorder
class DivergenceError(RuntimeError):
    """Training diverged; ``bundle`` is the incident-bundle directory."""

    def __init__(self, reason: str, bundle: Optional[str]):
        super().__init__(
            f"training diverged: {reason}"
            + (f" (incident bundle: {bundle})" if bundle else "")
        )
        self.reason = reason
        self.bundle = bundle


@dataclasses.dataclass(frozen=True)
class HealthPolicy:
    """What the flight recorder does when the health vector trips.

    on_incident: "abort" raises :class:`DivergenceError` after dumping the
      bundle; "continue" dumps and keeps training.
    max_incidents: bundles dumped per run -- a persistently-NaN run under
      "continue" records ONE bundle, not one per step.
    stat_norm_factor: trip when ``stat.norm.max`` exceeds this multiple of
      its running median (needs >= ``min_history`` observations).
    sat_spike: trip when any segment's saturation fraction exceeds its
      running median by this much.
    """

    on_incident: str = "abort"  # "abort" | "continue"
    max_incidents: int = 1
    stat_norm_factor: float = 50.0
    sat_spike: float = 0.25
    min_history: int = 3
    window: int = 64
    incident_dir: str = "artifacts/incidents"


class HealthWatcher:
    """Watches the per-step health vector and dumps incident bundles.

    Host-side and cheap: one ``spec.size``-float readback per step (the
    vector was fetched anyway for the gauges).  Triggers:

      * non-finite log-likelihood or E-step statistics (immediate);
      * ``stat.norm.max`` exploding past ``stat_norm_factor`` x its running
        median;
      * any segment saturation fraction spiking ``sat_spike`` above its
        running median.

    The relative triggers compare against the run's own recent history
    (``window`` steps), so a model that *starts* saturated does not trip --
    only a step that suddenly degrades does.
    """

    def __init__(self, model, policy: Optional[HealthPolicy] = None):
        self.spec: HealthSpec = model.health_spec
        self.policy = policy or HealthPolicy()
        if self.policy.on_incident not in ("abort", "continue"):
            raise ValueError(
                f"on_incident={self.policy.on_incident!r}; "
                "'abort' or 'continue'"
            )
        self.history: "collections.deque" = collections.deque(
            maxlen=self.policy.window
        )
        self.incidents: List[str] = []
        self._sat_names = [n for n in self.spec.names
                           if n.endswith(".sat_frac")]

    def _median(self, name: str) -> Optional[float]:
        import math

        vals = sorted(h[name] for h in self.history
                      if math.isfinite(h[name]))
        if len(vals) < self.policy.min_history:
            return None
        mid = len(vals) // 2
        return (vals[mid] if len(vals) % 2
                else 0.5 * (vals[mid - 1] + vals[mid]))

    def _check(self, vals: Dict[str, float]) -> Optional[str]:
        import math

        if (vals["ll.nonfinite"] > 0 or not math.isfinite(vals["ll.mean"])
                or vals["stat.nonfinite"] > 0):
            return (
                f"non-finite values: ll.mean={vals['ll.mean']}, "
                f"ll.nonfinite={vals['ll.nonfinite']:.0f}, "
                f"stat.nonfinite={vals['stat.nonfinite']:.0f}"
            )
        med = self._median("stat.norm.max")
        if med is not None and med > 0.0 and (
                vals["stat.norm.max"] > self.policy.stat_norm_factor * med):
            return (
                f"statistic norm exploded: stat.norm.max="
                f"{vals['stat.norm.max']:.3e} vs running median {med:.3e}"
            )
        for name in self._sat_names:
            med = self._median(name)
            if med is not None and (
                    vals[name] > med + self.policy.sat_spike):
                return (
                    f"saturation spike: {name}={vals[name]:.3f} vs "
                    f"running median {med:.3f}"
                )
        return None

    def observe(self, step: int, vec, params=None) -> Optional[str]:
        """Record one step's health vector; returns the bundle path when an
        incident fired this step (and raises under the "abort" policy)."""
        import numpy as np

        vals = self.spec.to_dict(np.asarray(vec))
        reason = self._check(vals)
        self.history.append({"step": int(step), **vals})
        if reason is None:
            return None
        bundle = None
        if len(self.incidents) < self.policy.max_incidents:
            from repro.obs import incident as incident_lib

            bundle = incident_lib.dump_incident(
                self.policy.incident_dir, reason=reason, step=int(step),
                history=list(self.history), params=params, spec=self.spec,
            )
            self.incidents.append(bundle)
            print(f"[health] incident at step {step}: {reason} -> {bundle}")
        if self.policy.on_incident == "abort":
            raise DivergenceError(reason, bundle)
        return bundle
