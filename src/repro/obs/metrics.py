"""Metrics registry: counters, gauges, and log-scale histograms.

The always-on half of ``repro.obs`` (tracing is opt-in; metrics are cheap
enough to leave running).  Metric names follow ``subsystem.verb.unit``
(``serve.request.seconds``, ``compile.cache.misses``) with optional labels
(``kind="joint_ll"``, ``bucket=8``); one (name, labels) pair is one metric
instance.  ``METRICS.snapshot()`` renders the whole registry as a plain
JSON-able dict for BENCH files and the ``[obs]`` exit summary.

Histograms use fixed log-scale buckets (``_PER_DECADE`` buckets per decade
of dynamic range, geometric midpoint readout), so ``percentile(q)`` is
accurate to about half a bucket ratio (~5% relative) at any load --
bounded memory, no sample retention, mergeable across label values by
summing the bucket count vectors (:meth:`MetricsRegistry.sum_histogram`).
``Histogram.counts()`` snapshots are subtractable, which is how the serve
benchmark reads *steady-state-only* percentiles: mark before the timed
passes, diff after (:func:`percentile_from_counts`).

Thread safety: every mutation takes the owning metric's lock (concurrent
engine threads incrementing one counter must never lose updates -- pinned
by tests/test_obs.py).
"""

from __future__ import annotations

import math
import threading
from typing import Any, Dict, List, Optional, Sequence, Tuple

# histogram bucket layout (class-wide so count vectors are always mergeable):
# values below _LO land in the underflow bucket, above _HI in overflow;
# 24 buckets/decade -> ratio 10^(1/24) ~ 1.10, midpoint error < 5%
_LO = 1e-7
_HI = 1e4
_PER_DECADE = 24
_DECADES = int(round(math.log10(_HI / _LO)))
NUM_BUCKETS = _DECADES * _PER_DECADE + 2  # + underflow + overflow
_LOG_LO = math.log10(_LO)


def _bucket_index(value: float) -> int:
    if value < _LO:
        return 0
    if value >= _HI:
        return NUM_BUCKETS - 1
    return 1 + int((math.log10(value) - _LOG_LO) * _PER_DECADE)


def _bucket_mid(index: int) -> float:
    """Geometric midpoint of bucket ``index`` (clamped for under/overflow)."""
    if index <= 0:
        return _LO
    if index >= NUM_BUCKETS - 1:
        return _HI
    lo = 10.0 ** (_LOG_LO + (index - 1) / _PER_DECADE)
    return lo * 10.0 ** (0.5 / _PER_DECADE)


def percentile_from_counts(counts: Sequence[int], q: float) -> float:
    """The q-th percentile (0..100) from a bucket count vector (e.g. the
    difference of two :meth:`Histogram.counts` snapshots)."""
    total = sum(counts)
    if total <= 0:
        return 0.0
    rank = (q / 100.0) * (total - 1)
    cum = 0
    for i, c in enumerate(counts):
        cum += c
        if cum > rank:
            return _bucket_mid(i)
    return _bucket_mid(NUM_BUCKETS - 1)


class Counter:
    """Monotonic counter; ``inc`` accepts floats (seconds accumulators)."""

    __slots__ = ("_lock", "_value")

    def __init__(self):
        self._lock = threading.Lock()
        self._value = 0.0

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        return self._value

    def snapshot(self) -> float:
        v = self._value
        return int(v) if float(v).is_integer() else v


class Gauge:
    """Last-write-wins instantaneous value (queue depth, last LL).

    Also keeps a high-watermark: last-write-wins alone made bursty gauges
    like ``serve.queue.depth`` always read ~0 in end-of-run snapshots (the
    queue drains before anyone looks), so :attr:`max` records the largest
    value ever set and the snapshot carries both.
    """

    __slots__ = ("_lock", "_value", "_max")

    def __init__(self):
        self._lock = threading.Lock()
        self._value = 0.0
        self._max = -math.inf

    def set(self, value: float) -> None:
        with self._lock:
            self._value = float(value)
            if self._value > self._max:
                self._max = self._value

    @property
    def value(self) -> float:
        return self._value

    @property
    def max(self) -> float:
        """High-watermark of every ``set`` (0.0 before the first)."""
        return self._max if self._max != -math.inf else 0.0

    def snapshot(self) -> Dict[str, float]:
        return {"value": self._value, "max": self.max}


class Histogram:
    """Fixed-bucket log-scale histogram with percentile readout."""

    __slots__ = ("_lock", "_counts", "count", "total", "vmin", "vmax")

    def __init__(self):
        self._lock = threading.Lock()
        self._counts = [0] * NUM_BUCKETS
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf

    def record(self, value: float) -> None:
        value = float(value)
        idx = _bucket_index(value) if value > 0 else 0
        with self._lock:
            self._counts[idx] += 1
            self.count += 1
            self.total += value
            if value < self.vmin:
                self.vmin = value
            if value > self.vmax:
                self.vmax = value

    def counts(self) -> List[int]:
        """Snapshot of the bucket counts (subtract two snapshots to read
        percentiles over just the interval between them)."""
        with self._lock:
            return list(self._counts)

    def percentile(self, q: float,
                   baseline: Optional[Sequence[int]] = None) -> float:
        """q-th percentile (0..100); ``baseline`` subtracts an earlier
        :meth:`counts` snapshot first.  Clamped to the observed [min, max]
        when no baseline is given (bucket midpoints can overshoot)."""
        counts = self.counts()
        if baseline is not None:
            counts = [c - b for c, b in zip(counts, baseline)]
            return percentile_from_counts(counts, q)
        v = percentile_from_counts(counts, q)
        if self.count:
            v = min(max(v, self.vmin), self.vmax)
        return v

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def snapshot(self) -> Dict[str, float]:
        return {
            "count": self.count,
            "sum": self.total,
            "min": self.vmin if self.count else 0.0,
            "max": self.vmax if self.count else 0.0,
            "mean": self.mean,
            "p50": self.percentile(50),
            "p95": self.percentile(95),
            "p99": self.percentile(99),
        }


_LabelKey = Tuple[str, Tuple[Tuple[str, Any], ...]]


def _key(name: str, labels: Dict[str, Any]) -> _LabelKey:
    return (name, tuple(sorted(labels.items())))


def _fullname(key: _LabelKey) -> str:
    name, labels = key
    if not labels:
        return name
    inner = ",".join(f"{k}={v}" for k, v in labels)
    return f"{name}{{{inner}}}"


class MetricsRegistry:
    """All metrics of one process; module-level :data:`METRICS` is the
    default everything instruments into."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[_LabelKey, Any] = {}

    def _get(self, name: str, labels: Dict[str, Any], cls):
        key = _key(name, labels)
        m = self._metrics.get(key)
        if m is None:
            with self._lock:
                m = self._metrics.get(key)
                if m is None:
                    m = cls()
                    self._metrics[key] = m
        if not isinstance(m, cls):
            raise TypeError(
                f"metric {_fullname(key)} already registered as "
                f"{type(m).__name__}, requested {cls.__name__}"
            )
        return m

    def counter(self, name: str, **labels: Any) -> Counter:
        return self._get(name, labels, Counter)

    def gauge(self, name: str, **labels: Any) -> Gauge:
        return self._get(name, labels, Gauge)

    def histogram(self, name: str, **labels: Any) -> Histogram:
        return self._get(name, labels, Histogram)

    def find(self, name: str, **match: Any) -> List[Tuple[Dict[str, Any], Any]]:
        """Every (labels, metric) registered under ``name`` whose labels
        include ``match``."""
        out = []
        with self._lock:
            items = list(self._metrics.items())
        for (n, labels), metric in items:
            if n != name:
                continue
            d = dict(labels)
            if all(d.get(k) == v for k, v in match.items()):
                out.append((d, metric))
        return out

    def sum_histogram(self, name: str, **match: Any) -> List[int]:
        """Merged bucket counts over every histogram labeled under ``name``
        matching ``match`` (histograms merge by summing count vectors)."""
        total = [0] * NUM_BUCKETS
        for _, h in self.find(name, **match):
            if isinstance(h, Histogram):
                for i, c in enumerate(h.counts()):
                    total[i] += c
        return total

    def value(self, name: str, default: float = 0.0, **labels: Any) -> float:
        m = self._metrics.get(_key(name, labels))
        return m.value if m is not None else default

    def snapshot(self) -> Dict[str, Any]:
        """The whole registry as one flat JSON-able dict keyed by
        ``name{label=value,...}``."""
        with self._lock:
            items = list(self._metrics.items())
        return {_fullname(k): m.snapshot() for k, m in sorted(
            items, key=lambda kv: _fullname(kv[0]))}

    def reset(self) -> None:
        with self._lock:
            self._metrics = {}


METRICS = MetricsRegistry()
