"""The shared compile-event hook: ONE source of truth for compile counts.

``repro.compile.ProgramRegistry`` is the only place programs get compiled,
so it is the only emitter: cache hits call :func:`cache_event`, cache
misses call :func:`compile_event` (which counts the miss, accumulates
compile seconds, and fans the event out to subscribers).  Consumers --
``analysis.sentry.CompileSentry`` (per-scope attribution), the ``[obs]``
exit summary, and the BENCH JSONs -- all read these counters or subscribe
to the stream; nobody else increments them, so nothing double counts.
"""

from __future__ import annotations

import threading
from typing import Any, Callable, Dict, List

from repro.obs.metrics import METRICS

CompileListener = Callable[[Dict[str, Any]], None]

_LOCK = threading.Lock()
_LISTENERS: List[CompileListener] = []


def on_compile(listener: CompileListener) -> CompileListener:
    """Subscribe to compile events; returns ``listener`` (the unsubscribe
    token for :func:`remove_compile_listener`)."""
    with _LOCK:
        if listener not in _LISTENERS:
            _LISTENERS.append(listener)
    return listener


def remove_compile_listener(listener: CompileListener) -> None:
    with _LOCK:
        if listener in _LISTENERS:
            _LISTENERS.remove(listener)


def cache_event(kind: str, hit: bool) -> None:
    """One program-cache lookup in the registry: ``kind`` is the cache path
    ("aot" | "jit").  Misses are counted by :func:`compile_event` (a miss IS
    a compile), so this only counts hits."""
    if hit:
        METRICS.counter("compile.cache.hits", kind=kind).inc()


def compile_event(kind: str, key: Any, seconds: float) -> None:
    """One compile (= cache miss) in the registry.  ``seconds`` is the
    measured compile wall-clock (0.0 for the lazy ``jit`` path, which
    compiles on first call inside jax)."""
    METRICS.counter("compile.cache.misses", kind=kind).inc()
    METRICS.counter("compile.programs.seconds", kind=kind).inc(seconds)
    with _LOCK:
        listeners = list(_LISTENERS)
    if not listeners:
        return
    ev = {"kind": kind, "key": repr(key), "seconds": seconds}
    for fn in listeners:
        fn(ev)
