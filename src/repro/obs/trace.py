"""Tracing spans: nestable context managers -> Chrome/Perfetto trace JSON.

The tracing half of ``repro.obs``: ``span("serve.execute", kind=..., ...)``
wraps a region of host code and, when tracing is enabled, appends one Chrome
``trace_event`` *complete* event (``ph: "X"`` with ``ts``/``dur`` in
microseconds) to a thread-safe in-process buffer that ``export_trace(path)``
writes as a JSON file loadable by ``chrome://tracing`` / ui.perfetto.dev.
Nesting needs no bookkeeping -- the viewer reconstructs the stack from
``ts``/``dur`` containment per thread.

Enable switches (the disabled path must cost ~nothing -- ``span()`` returns
a shared no-op singleton, one attribute read + one ``if``):

  * ``REPRO_TRACE`` env var: any truthy value enables collection; a value
    that looks like a path (contains ``/`` or ends in ``.json``) also
    registers an atexit export to that path.
  * ``configure(trace=True/False)``: programmatic override (the launch
    CLIs' ``--trace out.json`` flag).

Two flavours of timed region:

  * :func:`span` -- trace-only; a no-op when tracing is off.  For hot paths
    where even a clock read per call would be waste.
  * :func:`timed` -- ALWAYS measures (exposes ``.seconds`` after exit) and
    optionally records into a metrics histogram; emits the trace event only
    when tracing is on.  This is the migration target for the repo's former
    ad-hoc ``time.perf_counter()`` bookkeeping.

jax-free and numpy-free by design: ``repro.obs`` must be importable from
every layer (including ``repro.compile`` before jax loads) without cycles.
"""

from __future__ import annotations

import atexit
import json
import os
import threading
import time
from typing import Any, Callable, Dict, List, Optional

# trace-time clock origin: event ts are microseconds since process start
_T0_NS = time.perf_counter_ns()

# buffer hard cap -- a runaway instrumented loop must not eat the host;
# events past the cap are counted, not stored
_MAX_EVENTS = 1_000_000


def _env_truthy(value: str) -> bool:
    return value.strip().lower() not in ("", "0", "false", "off", "no")


def _env_path(value: str) -> Optional[str]:
    v = value.strip()
    if "/" in v or v.endswith(".json"):
        return v
    return None


class _TraceState:
    __slots__ = ("enabled", "sync_fn", "lock", "events", "dropped",
                 "export_path", "_atexit_armed")

    def __init__(self):
        env = os.environ.get("REPRO_TRACE", "")
        self.enabled = _env_truthy(env)
        self.sync_fn: Optional[Callable[[Any], Any]] = None
        self.lock = threading.Lock()
        self.events: List[Dict[str, Any]] = []
        self.dropped = 0
        self.export_path = _env_path(env)
        self._atexit_armed = False
        if self.export_path:
            self._arm_atexit()

    def _arm_atexit(self):
        if not self._atexit_armed:
            self._atexit_armed = True
            atexit.register(_atexit_export)


_STATE = _TraceState()


def _atexit_export():
    if _STATE.export_path and _STATE.events:
        export_trace(_STATE.export_path)


def configure(trace: Optional[bool] = None,
              export_path: Optional[str] = None) -> None:
    """Process-wide switch: ``configure(trace=True)`` starts collecting,
    ``configure(trace=False)`` stops (buffered events are kept -- call
    :func:`reset` to drop them).  ``export_path`` arms an atexit export."""
    if trace is not None:
        _STATE.enabled = bool(trace)
    if export_path is not None:
        _STATE.export_path = export_path
        _STATE._arm_atexit()


def enabled() -> bool:
    return _STATE.enabled


def now() -> float:
    """The obs clock (monotonic seconds).  All repo timing flows through
    here -- the ``timing-outside-obs`` lint rule forbids raw
    ``time.perf_counter`` / ``time.time`` outside ``repro/obs/``."""
    return time.perf_counter()


def set_sync(fn: Optional[Callable[[Any], Any]]) -> None:
    """Install a synchronization callback for :func:`sync` (e.g.
    ``jax.block_until_ready`` while timing an eager plan walk).  ``None``
    (the default) makes :func:`sync` a no-op, so instrumented library code
    pays nothing in production."""
    _STATE.sync_fn = fn


def sync(value: Any) -> Any:
    """Synchronize ``value`` through the installed callback (no-op by
    default).  Instrumented compute sites call this just before their span
    closes so an eager-mode profiler can charge device time to the right
    span."""
    fn = _STATE.sync_fn
    if fn is not None:
        fn(value)
    return value


def _append(event: Dict[str, Any]) -> None:
    with _STATE.lock:
        if len(_STATE.events) >= _MAX_EVENTS:
            _STATE.dropped += 1
            return
        _STATE.events.append(event)


class Span:
    """One traced region; use via ``with span("name", key=val): ...``."""

    __slots__ = ("name", "args", "_t0")

    def __init__(self, name: str, args: Dict[str, Any]):
        self.name = name
        self.args = args
        self._t0 = 0

    def __enter__(self) -> "Span":
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc) -> bool:
        t1 = time.perf_counter_ns()
        _append({
            "ph": "X",
            "name": self.name,
            "ts": (self._t0 - _T0_NS) / 1e3,  # microseconds
            "dur": (t1 - self._t0) / 1e3,
            "pid": os.getpid(),
            "tid": threading.get_ident(),
            "args": self.args,
        })
        return False


class _NullSpan:
    """The disabled path: a shared singleton whose enter/exit do nothing."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False


_NULL_SPAN = _NullSpan()


def span(name: str, **args: Any):
    """Nestable traced region.  Disabled -> returns a no-op singleton
    (no clock read, no allocation beyond the kwargs dict)."""
    if not _STATE.enabled:
        return _NULL_SPAN
    return Span(name, args)


class Timed:
    """Always-measuring timed region: ``.seconds`` is valid after exit.

    With ``metric=`` the duration is recorded into that metrics histogram
    (labels = the span args), so one ``with obs.timed(...)`` both feeds the
    trace (when enabled) and the always-on metrics registry.
    """

    __slots__ = ("name", "args", "metric", "seconds", "_t0")

    def __init__(self, name: str, metric: Optional[str] = None,
                 **args: Any):
        self.name = name
        self.args = args
        self.metric = metric
        self.seconds = 0.0
        self._t0 = 0

    def __enter__(self) -> "Timed":
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc) -> bool:
        t1 = time.perf_counter_ns()
        self.seconds = (t1 - self._t0) / 1e9
        if self.metric is not None:
            from repro.obs.metrics import METRICS

            METRICS.histogram(self.metric, **self.args).record(self.seconds)
        if _STATE.enabled:
            _append({
                "ph": "X",
                "name": self.name,
                "ts": (self._t0 - _T0_NS) / 1e3,
                "dur": (t1 - self._t0) / 1e3,
                "pid": os.getpid(),
                "tid": threading.get_ident(),
                "args": self.args,
            })
        return False


def timed(name: str, metric: Optional[str] = None, **args: Any) -> Timed:
    return Timed(name, metric=metric, **args)


def event(name: str, **args: Any) -> None:
    """Instant event (``ph: "i"``) -- a point marker in the trace."""
    if not _STATE.enabled:
        return
    _append({
        "ph": "i",
        "s": "t",
        "name": name,
        "ts": (time.perf_counter_ns() - _T0_NS) / 1e3,
        "pid": os.getpid(),
        "tid": threading.get_ident(),
        "args": args,
    })


def trace_events() -> List[Dict[str, Any]]:
    """Snapshot of the buffered events (a shallow copy)."""
    with _STATE.lock:
        return list(_STATE.events)


def num_events() -> int:
    with _STATE.lock:
        return len(_STATE.events)


def dropped_events() -> int:
    """Events discarded past the buffer cap (surfaced by the ``[obs]`` exit
    summary so a truncated trace is never silent)."""
    with _STATE.lock:
        return _STATE.dropped


def reset() -> None:
    """Drop every buffered event (tests, repeated benchmark passes)."""
    with _STATE.lock:
        _STATE.events = []
        _STATE.dropped = 0


def export_trace(path: str) -> str:
    """Write the buffer as Chrome ``trace_event`` JSON; returns ``path``."""
    with _STATE.lock:
        events = list(_STATE.events)
        dropped = _STATE.dropped
    doc = {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "otherData": {"producer": "repro.obs", "dropped_events": dropped},
    }
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(doc, f)
    return path
