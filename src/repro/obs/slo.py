"""SLO budgets and commit-stamped bench history: perf as a CI contract.

Two halves:

  * **Budgets** -- the committed ``slo.json`` at the repo root declares what
    the benchmarks are *allowed* to report: per-kind serve p99 latency,
    per-arch train step time, minimum speedups, parity bounds, plus one
    ``tolerance`` knob that widens every timing budget by its declared noise
    fraction (timing gates on shared CI runners are worthless without one).
    ``python -m repro.obs.slo --check`` validates every fresh
    ``BENCH_*.json`` against the budgets and exits non-zero on any breach --
    the CI perf gate.  Smoke-profile reports (``"smoke": true``) carry no
    meaningful wall-clock, so only their correctness flags (parity, grouped
    execution) are checked; full-profile reports get the timing budgets too.

  * **History** -- every benchmark run appends one compact, commit-stamped
    row to ``artifacts/bench_history/<bench>.jsonl`` (:func:`append_history`,
    called by ``benchmarks/bench_*.py`` right after writing the BENCH file).
    The rows accumulate across commits, so a perf regression is visible as
    a trend, not just a budget breach; ``benchmarks/make_experiments_md.py``
    renders the recent rows into EXPERIMENTS.md.

stdlib-only, like the rest of ``repro.obs``.
"""

from __future__ import annotations

import datetime
import json
import os
import subprocess
import sys
from typing import Any, Dict, List, Optional

DEFAULT_SLO_PATH = "slo.json"
HISTORY_DIR = "artifacts/bench_history"

# bench kind -> the BENCH file it writes (the --check discovery set)
BENCH_FILES = {
    "serve": "BENCH_serve.json",
    "train": "BENCH_train.json",
    "mixture": "BENCH_mixture.json",
    "eval": "BENCH_eval.json",
}


def load_slo(path: str = DEFAULT_SLO_PATH) -> Dict[str, Any]:
    with open(path) as f:
        return json.load(f)


def _tol(slo: Dict[str, Any]) -> float:
    return float(slo.get("tolerance", 0.0))


def _flag(report: Dict[str, Any], name: str, problems: List[str],
          where: str) -> None:
    if not report.get(name, False):
        problems.append(f"{where}: {name} is not true")


# ------------------------------------------------------------ per-kind checks
def _check_serve(report: Dict[str, Any], slo: Dict[str, Any]) -> List[str]:
    budget = slo.get("serve", {})
    tol = _tol(slo)
    problems: List[str] = []
    reports = [("serve", report)]
    if isinstance(report.get("pd_smoke"), dict):
        reports.append(("serve.pd_smoke", report["pd_smoke"]))
    for where, r in reports:
        _flag(r, "parity_ok", problems, where)
        _flag(r, "grouped_ok", problems, where)
        max_parity = budget.get("max_parity_abs_diff")
        if max_parity is not None and (
                r.get("parity_max_abs_diff", 0.0) > max_parity):
            problems.append(
                f"{where}: parity_max_abs_diff "
                f"{r['parity_max_abs_diff']:.3e} > {max_parity:.0e}")
    if report.get("smoke"):
        return problems
    for kind, p99_budget in budget.get("p99_ms", {}).items():
        lat = report.get("latency_ms", {}).get(kind)
        if lat is None:
            problems.append(f"serve: no latency for kind {kind!r}")
            continue
        limit = p99_budget * (1.0 + tol)
        if lat["p99"] > limit:
            problems.append(
                f"serve: {kind} p99 {lat['p99']:.2f} ms > budget "
                f"{p99_budget} ms (+{tol:.0%} tolerance = {limit:.2f})")
    min_sv = budget.get("min_speedup_vs_jitted")
    if min_sv is not None:
        floor = min_sv * (1.0 - tol)
        if report.get("speedup_vs_jitted", 0.0) < floor:
            problems.append(
                f"serve: speedup_vs_jitted "
                f"{report.get('speedup_vs_jitted', 0.0):.2f} < floor "
                f"{floor:.2f} (budget {min_sv}, -{tol:.0%} tolerance)")
    return problems


def _check_train(report: Dict[str, Any], slo: Dict[str, Any]) -> List[str]:
    budget = slo.get("train", {})
    tol = _tol(slo)
    problems: List[str] = []
    _flag(report, "parity_ok", problems, "train")
    _flag(report, "grouped_ok", problems, "train")
    for row in report.get("results", []):
        arch = row.get("arch_id", row.get("arch", "?"))
        if not row.get("grad_parity_ok", True):
            problems.append(f"train[{arch}]: grad_parity_ok is not true")
    if report.get("smoke"):
        return problems
    max_ms = budget.get("max_step_ms", {})
    min_speedup = budget.get("min_speedup")
    for row in report.get("results", []):
        arch = row.get("arch_id", row.get("arch", "?"))
        ms_budget = max_ms.get(arch)
        if ms_budget is not None:
            limit = ms_budget * (1.0 + tol)
            if row.get("fused_ms_per_step", 0.0) > limit:
                problems.append(
                    f"train[{arch}]: fused step "
                    f"{row['fused_ms_per_step']:.2f} ms > budget "
                    f"{ms_budget} ms (+{tol:.0%} tolerance = {limit:.2f})")
        if min_speedup is not None and row.get("speedup_waiver") is None:
            floor = min_speedup * (1.0 - tol)
            if row.get("speedup", 0.0) < floor:
                problems.append(
                    f"train[{arch}]: speedup {row.get('speedup', 0.0):.3f} "
                    f"< floor {floor:.3f} (budget {min_speedup}, "
                    f"-{tol:.0%} tolerance)")
    return problems


def _check_mixture(report: Dict[str, Any], slo: Dict[str, Any]) -> List[str]:
    budget = slo.get("mixture", {})
    tol = _tol(slo)
    problems: List[str] = []
    _flag(report, "parity_ok", problems, "mixture")
    if report.get("smoke"):
        return problems
    min_speedup = budget.get("min_speedup")
    if min_speedup is not None:
        floor = min_speedup * (1.0 - tol)
        for row in report.get("results", []):
            cell = row.get("cell", "?")
            if row.get("speedup", 0.0) < floor:
                problems.append(
                    f"mixture[{cell}]: speedup "
                    f"{row.get('speedup', 0.0):.3f} < floor {floor:.3f} "
                    f"(budget {min_speedup}, -{tol:.0%} tolerance)")
    return problems


def _check_eval(report: Dict[str, Any], slo: Dict[str, Any]) -> List[str]:
    budget = slo.get("eval", {})
    tol = _tol(slo)
    problems: List[str] = []
    _flag(report, "parity_ok", problems, "eval")
    if report.get("smoke"):
        return problems
    min_ratio = budget.get("min_engine_vs_direct")
    if min_ratio is not None:
        floor = min_ratio * (1.0 - tol)
        if report.get("engine_vs_direct", 0.0) < floor:
            problems.append(
                f"eval: engine_vs_direct "
                f"{report.get('engine_vs_direct', 0.0):.3f} < floor "
                f"{floor:.3f} (budget {min_ratio}, -{tol:.0%} tolerance)")
    return problems


_CHECKS = {
    "serve": _check_serve,
    "train": _check_train,
    "mixture": _check_mixture,
    "eval": _check_eval,
}


def check_report(kind: str, report: Dict[str, Any],
                 slo: Dict[str, Any]) -> List[str]:
    """Budget breaches of one bench report (empty list = within SLO)."""
    if kind not in _CHECKS:
        return [f"unknown bench kind {kind!r}; one of {sorted(_CHECKS)}"]
    return _CHECKS[kind](report, slo)


def check_all(bench_dir: str = ".",
              slo: Optional[Dict[str, Any]] = None,
              slo_path: str = DEFAULT_SLO_PATH) -> Dict[str, List[str]]:
    """Check every ``BENCH_*.json`` present in ``bench_dir``; kind -> its
    problem list.  Having NO bench file at all is itself a problem entry
    (the gate must not pass vacuously)."""
    if slo is None:
        slo = load_slo(slo_path)
    out: Dict[str, List[str]] = {}
    found = 0
    for kind, fname in BENCH_FILES.items():
        path = os.path.join(bench_dir, fname)
        if not os.path.exists(path):
            continue
        found += 1
        try:
            with open(path) as f:
                report = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            out[kind] = [f"cannot load {path}: {e}"]
            continue
        out[kind] = check_report(kind, report, slo)
    if not found:
        out["(none)"] = [f"no BENCH_*.json found in {bench_dir!r}"]
    return out


# ---------------------------------------------------------------- history
def git_commit(repo_dir: str = ".") -> str:
    """Short commit hash of ``repo_dir``, or "unknown" outside a checkout."""
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], cwd=repo_dir,
            capture_output=True, text=True, timeout=10, check=True,
        ).stdout.strip() or "unknown"
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def _summarize(kind: str, report: Dict[str, Any]) -> Dict[str, Any]:
    """The compact per-run payload of one history row (trend-worthy scalars
    only -- the full report lives in the BENCH file, not the history)."""
    if kind == "serve":
        lat = report.get("latency_ms", {})
        return {
            "speedup": report.get("speedup"),
            "speedup_vs_jitted": report.get("speedup_vs_jitted"),
            "engine_qps": report.get("engine_qps"),
            "p99_ms": {k: v.get("p99") for k, v in lat.items()},
            "parity_ok": report.get("parity_ok"),
        }
    if kind == "train":
        return {
            "cells": {
                row.get("arch_id", row.get("arch", "?")): {
                    "fused_ms": row.get("fused_ms_per_step"),
                    "speedup": row.get("speedup"),
                }
                for row in report.get("results", [])
            },
            "parity_ok": report.get("parity_ok"),
        }
    if kind == "mixture":
        return {
            "cells": {
                row.get("cell", "?"): row.get("speedup")
                for row in report.get("results", [])
            },
            "parity_ok": report.get("parity_ok"),
        }
    if kind == "eval":
        return {
            "engine_vs_direct": report.get("engine_vs_direct"),
            "engine_rows_per_s": report.get("engine_rows_per_s"),
            "parity_ok": report.get("parity_ok"),
        }
    return {}


def history_row(kind: str, report: Dict[str, Any]) -> Dict[str, Any]:
    ts = report.get("timestamp") or datetime.datetime.now(
        datetime.timezone.utc).isoformat()
    return {
        "bench": kind,
        "ts": ts,
        "commit": git_commit(),
        "smoke": bool(report.get("smoke", False)),
        **_summarize(kind, report),
    }


def append_history(kind: str, report: Dict[str, Any],
                   root: str = HISTORY_DIR) -> str:
    """Append one commit-stamped row to ``<root>/<kind>.jsonl``; returns the
    file path.  Called by every bench run (smoke and full), so the history
    is an unbroken per-commit record."""
    os.makedirs(root, exist_ok=True)
    path = os.path.join(root, f"{kind}.jsonl")
    with open(path, "a") as f:
        f.write(json.dumps(history_row(kind, report), sort_keys=True) + "\n")
    return path


def load_history(root: str = HISTORY_DIR) -> Dict[str, List[Dict[str, Any]]]:
    """bench kind -> its history rows, oldest first (malformed lines are
    skipped, not fatal -- history must never break a build)."""
    out: Dict[str, List[Dict[str, Any]]] = {}
    if not os.path.isdir(root):
        return out
    for fname in sorted(os.listdir(root)):
        if not fname.endswith(".jsonl"):
            continue
        rows = []
        with open(os.path.join(root, fname)) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    rows.append(json.loads(line))
                except json.JSONDecodeError:
                    continue
        out[fname[:-len(".jsonl")]] = rows
    return out


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.slo", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--check", action="store_true",
                    help="validate BENCH_*.json against the SLO budgets")
    ap.add_argument("--dir", default=".",
                    help="directory holding the BENCH_*.json files")
    ap.add_argument("--slo", default=DEFAULT_SLO_PATH,
                    help="budget file (default: ./slo.json)")
    ap.add_argument("--history", action="store_true",
                    help="print the bench history (rows per bench kind)")
    args = ap.parse_args(argv)
    if not args.check and not args.history:
        ap.error("nothing to do: pass --check and/or --history")
    status = 0
    if args.check:
        results = check_all(bench_dir=args.dir, slo_path=args.slo)
        for kind in sorted(results):
            problems = results[kind]
            if problems:
                status = 1
                for p in problems:
                    print(f"slo check: {kind}: {p}")
            else:
                print(f"slo check: {kind}: within budget")
    if args.history:
        for kind, rows in sorted(load_history().items()):
            print(f"{kind}: {len(rows)} rows")
            for row in rows[-5:]:
                print(f"  {json.dumps(row, sort_keys=True)}")
    return status


if __name__ == "__main__":
    sys.exit(main())
