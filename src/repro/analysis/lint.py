"""AST-based repo lint: the conventions this codebase's bug history bought.

Six rules, each pinned to a past defect or a contract the rest of the
stack relies on:

  * ``neg-inf-literal``     -- no NEG_INF-scale numeric literals (|v| >=
    1e20) outside ``core/layers.py``: PR 2 shipped a hard-coded ``-1e30``
    that silently disagreed with the shared log-domain floor.  Import
    ``NEG_INF`` instead.
  * ``interpret-default``   -- kernel entry points take ``interpret=None``
    and defer to ``kernels.dispatch.resolve_interpret`` (PR 3 shipped
    ``interpret=True`` public defaults that pinned CPU interpret mode on
    TPU).  Outside ``repro/kernels/`` the knob must not appear at all.
  * ``pallas-contract``     -- ``pl.pallas_call`` and the raw ``*_pallas``
    kernels are reachable only through the ``repro.kernels.ops`` wrappers,
    which own the ``pad_to_lanes`` / ``pad_group_for_lanes`` padding
    contract; a direct call from outside ``repro/kernels/`` bypasses the
    lane contract the launch shapes assume.
  * ``bare-jit``            -- no ``jax.jit`` / ``jax.pmap`` outside
    ``repro/compile.py`` (the registry), ``repro/train/`` (step builders
    route through the registry) and ``repro/kernels/`` (jitted kernel ABI
    wrappers with static tiling args).  Stray jit objects each carry their
    own compile cache: duplicated compiles, no shared accounting, and the
    recompile sentry cannot see them.
  * ``donated-read``        -- a step built by ``make_em_step`` /
    ``make_sharded_em_step`` / ``make_mixture_em_step`` donates its first
    argument; reading that buffer after the call (without rebinding it from
    the result) is undefined behaviour jax only warns about at runtime.
  * ``timing-outside-obs``  -- no raw ``time.time`` / ``time.perf_counter``
    (or their ``_ns``/monotonic/process_time cousins) outside ``repro/obs/``
    and ``benchmarks/``: ad-hoc clocks re-grow the duplicated warm-up-vs-
    steady-state bookkeeping ``repro.obs`` replaced, and their measurements
    never reach the metrics registry or the trace.  Use ``obs.timed`` /
    ``obs.span`` / ``obs.now``.

CLI (a CI fast-job gate)::

    python -m repro.analysis.lint            # scan src/repro, exit 0/1
    python -m repro.analysis.lint PATH ...   # explicit roots/files

Waivers live in ``analysis/lint_waivers.json`` -- a machine-readable list
of ``{"rule", "path", "line", "reason"}`` entries (line optional).  The
file starts (and per ISSUE 8 ships) EMPTY: the tree lints clean.  A waiver
is for the rare deliberate exception, and every entry carries its reason.
"""

from __future__ import annotations

import ast
import dataclasses
import json
import pathlib
import sys
from typing import Iterable, Iterator, List, Optional, Sequence, Tuple

# |literal| at or above this is "NEG_INF scale" (threshold spelled as an
# expression so the lint does not flag its own definition)
_NEG_INF_SCALE = 10.0 ** 20

RULES = {
    "neg-inf-literal": (
        "NEG_INF-scale literal; import NEG_INF from repro.core.layers"
    ),
    "interpret-default": (
        "interpret= must default to None (kernels.dispatch decides) and "
        "must not appear outside repro/kernels/"
    ),
    "pallas-contract": (
        "pl.pallas_call / *_pallas kernels are private to repro/kernels/; "
        "call the repro.kernels.ops wrappers (they own pad_to_lanes)"
    ),
    "bare-jit": (
        "bare jax.jit/jax.pmap; route through repro.compile.REGISTRY "
        "(ProgramRegistry.jit/aot)"
    ),
    "donated-read": (
        "donated buffer read after the donating step call; rebind it from "
        "the step's result"
    ),
    "timing-outside-obs": (
        "raw time.time/time.perf_counter outside repro/obs/ and "
        "benchmarks/; use obs.timed / obs.span / obs.now"
    ),
}

# rule -> path prefixes (repo-module style, see _relpath) where it is OFF;
# a prefix matches at the start of the rel path or at any "/" boundary
# (so "benchmarks/" covers the repo-root benchmark scripts, which have no
# src/ component to normalize from)
_ALLOW = {
    "neg-inf-literal": ("repro/core/layers.py",),
    "bare-jit": ("repro/compile.py", "repro/train/", "repro/kernels/"),
    "pallas-contract": ("repro/kernels/",),
    "timing-outside-obs": ("repro/obs/", "benchmarks/"),
}

# the wall-clock readers the timing rule forbids outside repro/obs/ --
# time.sleep and datetime formatting are fine; only *measurement* clocks
# must flow through obs so their readings reach the metrics/trace layer
_TIME_ATTRS = {
    "time", "time_ns", "perf_counter", "perf_counter_ns",
    "monotonic", "monotonic_ns", "process_time", "process_time_ns",
}

_STEP_MAKERS = {"make_em_step", "make_sharded_em_step", "make_mixture_em_step"}


@dataclasses.dataclass(frozen=True)
class Violation:
    rule: str
    path: str  # repo-module style (see _relpath)
    line: int
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: {self.rule}: {self.message}"


def _relpath(path: str) -> str:
    """Normalize to the module-ish form rules match on: the posix path
    from the last ``src/`` component (``repro/kernels/ops.py``)."""
    parts = pathlib.PurePath(path).as_posix().split("/")
    if "src" in parts:
        parts = parts[len(parts) - parts[::-1].index("src"):]
    return "/".join(p for p in parts if p not in (".", ""))


def _allowed(rule: str, rel: str) -> bool:
    probe = "/" + rel
    return any(
        probe.startswith("/" + p) or "/" + p in probe
        or rel == p.rstrip("/")
        for p in _ALLOW.get(rule, ())
    )


def _terminal_name(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        return node.attr
    return None


# ------------------------------------------------------------------- rules
def _check_neg_inf(tree: ast.AST, rel: str) -> Iterator[Violation]:
    for node in ast.walk(tree):
        if isinstance(node, ast.Constant) and isinstance(
            node.value, (int, float)
        ) and not isinstance(node.value, bool):
            if abs(node.value) >= _NEG_INF_SCALE:
                yield Violation(
                    "neg-inf-literal", rel, node.lineno,
                    f"literal {node.value!r} is NEG_INF-scale; import "
                    f"NEG_INF from repro.core.layers")


def _check_interpret(tree: ast.AST, rel: str) -> Iterator[Violation]:
    in_kernels = rel.startswith("repro/kernels/")
    for node in ast.walk(tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        a = node.args
        params = list(a.posonlyargs) + list(a.args)
        # defaults align to the TAIL of (posonly + args)
        defaults: List[Optional[ast.expr]] = (
            [None] * (len(params) - len(a.defaults)) + list(a.defaults)
        )
        params += list(a.kwonlyargs)
        defaults += list(a.kw_defaults)
        for arg, default in zip(params, defaults):
            if arg.arg != "interpret":
                continue
            if not in_kernels:
                yield Violation(
                    "interpret-default", rel, node.lineno,
                    f"function {node.name!r} exposes an interpret= knob "
                    f"outside repro/kernels/ (dispatch decides)")
            elif default is not None and not (
                isinstance(default, ast.Constant) and default.value is None
            ):
                # a no-default interpret (resolve_interpret itself) is fine:
                # it forces the caller to decide explicitly
                yield Violation(
                    "interpret-default", rel, node.lineno,
                    f"function {node.name!r}: interpret must default to "
                    f"None (kernels.dispatch.resolve_interpret decides)")


def _check_pallas(tree: ast.AST, rel: str) -> Iterator[Violation]:
    for node in ast.walk(tree):
        if isinstance(node, ast.Attribute) and node.attr == "pallas_call":
            yield Violation(
                "pallas-contract", rel, node.lineno,
                "direct pl.pallas_call outside repro/kernels/ bypasses "
                "the pad_to_lanes launch contract")
        elif isinstance(node, ast.Call):
            name = _terminal_name(node.func)
            if name and name.endswith("_pallas"):
                yield Violation(
                    "pallas-contract", rel, node.lineno,
                    f"direct call to raw kernel {name!r}; use the "
                    f"repro.kernels.ops wrapper (it owns the padding)")


def _check_bare_jit(tree: ast.AST, rel: str) -> Iterator[Violation]:
    for node in ast.walk(tree):
        if isinstance(node, ast.Attribute) and isinstance(
            node.value, ast.Name
        ) and node.value.id == "jax" and node.attr in ("jit", "pmap"):
            yield Violation(
                "bare-jit", rel, node.lineno,
                f"bare jax.{node.attr}; route through "
                f"repro.compile.REGISTRY so programs share one cache and "
                f"the recompile sentry can account for them")


class _DonatedReads(ast.NodeVisitor):
    """Linear over-approximate scan: names returned by the step makers
    donate their first positional arg at every call; a Load of a donated
    name before a rebinding Store is a violation.  Reads, donations and
    stores inside ONE statement apply in that order, so the canonical
    ``params, ll = step(params, x)`` is clean."""

    def __init__(self, rel: str):
        self.rel = rel
        self.violations: List[Violation] = []

    def visit_FunctionDef(self, node):  # noqa: N802
        self._scan(node.body, set(), set())

    visit_AsyncFunctionDef = visit_FunctionDef

    def _scan(self, body, step_vars: set, donated: set) -> None:
        for stmt in body:
            nested = []
            for attr in ("body", "orelse", "finalbody"):
                nested.extend(getattr(stmt, attr, []) or [])
            head = stmt
            if nested:  # compound: analyze the header expr, then recurse
                for field in ("test", "iter"):
                    expr = getattr(stmt, field, None)
                    if expr is not None:
                        self._stmt(expr, step_vars, donated)
                self._scan(nested, step_vars, donated)
                continue
            self._stmt(head, step_vars, donated)

    def _stmt(self, stmt, step_vars: set, donated: set) -> None:
        reads, stores, new_steps, donations = set(), set(), set(), []
        for node in ast.walk(stmt):
            if isinstance(node, ast.Name):
                (reads if isinstance(node.ctx, ast.Load) else stores).add(
                    node.id)
            if isinstance(node, ast.Call):
                fname = _terminal_name(node.func)
                if fname in _STEP_MAKERS:
                    parent_targets = getattr(stmt, "targets", None)
                    if parent_targets and isinstance(
                        parent_targets[0], ast.Name
                    ):
                        new_steps.add(parent_targets[0].id)
                if isinstance(node.func, ast.Name) and (
                    node.func.id in step_vars
                ) and node.args and isinstance(node.args[0], ast.Name):
                    donations.append((node.args[0].id, node.lineno))
        for name in sorted(reads & donated):
            self.violations.append(Violation(
                "donated-read", self.rel, stmt.lineno,
                f"{name!r} was donated to a compiled EM step and is read "
                f"before being rebound from the step's result"))
        for name, _ in donations:
            donated.add(name)
        donated -= stores
        step_vars |= new_steps


def _check_donated(tree: ast.AST, rel: str) -> Iterator[Violation]:
    checker = _DonatedReads(rel)
    checker.visit(tree)
    yield from checker.violations


def _check_timing(tree: ast.AST, rel: str) -> Iterator[Violation]:
    for node in ast.walk(tree):
        if isinstance(node, ast.Attribute) and isinstance(
            node.value, ast.Name
        ) and node.value.id == "time" and node.attr in _TIME_ATTRS:
            yield Violation(
                "timing-outside-obs", rel, node.lineno,
                f"raw time.{node.attr}; use obs.timed / obs.span / obs.now "
                f"so the measurement reaches the metrics registry")
        elif isinstance(node, ast.ImportFrom) and node.module == "time":
            for alias in node.names:
                if alias.name in _TIME_ATTRS:
                    yield Violation(
                        "timing-outside-obs", rel, node.lineno,
                        f"from time import {alias.name}; use obs.timed / "
                        f"obs.span / obs.now instead")


_CHECKS = (
    _check_neg_inf,
    _check_interpret,
    _check_pallas,
    _check_bare_jit,
    _check_donated,
    _check_timing,
)


# ------------------------------------------------------------------ driver
def lint_source(src: str, path: str = "<snippet>") -> List[Violation]:
    """Lint one source string (the negative-test entry point)."""
    rel = _relpath(path)
    tree = ast.parse(src)
    out: List[Violation] = []
    for check in _CHECKS:
        out.extend(v for v in check(tree, rel) if not _allowed(v.rule, rel))
    return sorted(out, key=lambda v: (v.path, v.line, v.rule))


def _iter_py_files(paths: Sequence[str]) -> Iterator[pathlib.Path]:
    for p in paths:
        path = pathlib.Path(p)
        if path.is_dir():
            yield from sorted(path.rglob("*.py"))
        else:
            yield path


def load_waivers(path: Optional[str] = None) -> List[dict]:
    wpath = pathlib.Path(path) if path else (
        pathlib.Path(__file__).parent / "lint_waivers.json"
    )
    if not wpath.exists():
        return []
    waivers = json.loads(wpath.read_text())
    for w in waivers:
        missing = {"rule", "path", "reason"} - set(w)
        if missing:
            raise ValueError(
                f"waiver {w!r} is missing required field(s) {sorted(missing)}"
            )
    return waivers


def _waived(v: Violation, waivers: Iterable[dict]) -> bool:
    return any(
        w["rule"] == v.rule
        and (v.path == w["path"] or v.path.endswith("/" + w["path"]))
        and ("line" not in w or int(w["line"]) == v.line)
        for w in waivers
    )


def run_lint(
    paths: Sequence[str], waivers_path: Optional[str] = None
) -> Tuple[List[Violation], List[Violation]]:
    """Lint files/trees -> (violations, waived)."""
    waivers = load_waivers(waivers_path)
    violations: List[Violation] = []
    waived: List[Violation] = []
    for f in _iter_py_files(paths):
        found = lint_source(f.read_text(), str(f))
        for v in found:
            (waived if _waived(v, waivers) else violations).append(v)
    return violations, waived


def main(argv: Optional[Sequence[str]] = None) -> int:
    import argparse

    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    parser.add_argument(
        "paths", nargs="*",
        default=[str(pathlib.Path(__file__).resolve().parents[1])],
        help="files or trees to lint (default: src/repro)")
    parser.add_argument("--waivers", default=None,
                        help="waiver JSON (default: analysis/lint_waivers.json)")
    parser.add_argument("--list-rules", action="store_true")
    args = parser.parse_args(argv)
    if args.list_rules:
        for rule, desc in RULES.items():
            print(f"{rule}: {desc}")
        return 0
    violations, waived = run_lint(args.paths, args.waivers)
    for v in violations:
        print(v)
    for v in waived:
        print(f"{v}  [waived]")
    n_files = sum(1 for _ in _iter_py_files(args.paths))
    print(
        f"lint: {n_files} file(s), {len(violations)} violation(s), "
        f"{len(waived)} waived, {len(RULES)} rule(s)")
    return 1 if violations else 0


if __name__ == "__main__":
    sys.exit(main())
