"""Recompile sentry: make "one compile per (kind, bucket)" assertable.

The PR 3 bug class: a weak-typed parameter (``jnp.full(shape, py_float)``
with no dtype) changes abstract value after the first EM update, so every
jitted training step silently retraces -- numerically invisible, 10-100x
slow.  Nothing in jax surfaces this; ``jax.monitoring`` compile events are
noisy (service-side lowerings fire too).  This sentry instead counts what
jit itself keys on -- the *abstract signature* of each call (shape, dtype,
weak_type per leaf) -- and cross-checks against the jitted object's own
cache size where jax exposes it (``pjit._cache_size``), plus the
``ProgramRegistry`` compile counter for the AOT/serve path.

Usage (also available as the ``compile_sentry`` pytest fixture)::

    with CompileSentry() as sentry:
        step = sentry.wrap(make_em_step(model), name="em_step")
        for _ in range(3):
            params, ll = step(params, x)
    sentry.assert_max_compiles(1, name="em_step")
    assert not sentry.findings   # no weak-type / promotion leaks

For serving, wrap nothing and use the registry delta::

    with CompileSentry(registry=engine.registry) as sentry:
        engine.submit(stream)
    assert sentry.registry_compiles() <= kinds * buckets
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro import obs

Signature = Tuple[Tuple[Any, ...], ...]


@dataclasses.dataclass(frozen=True)
class SentryFinding:
    """One detected compile-hygiene leak."""

    kind: str  # "weak-type-arg" | "weak-type-leak" | "dtype-promotion-leak"
    fn: str  # wrapped-function name
    message: str

    def __str__(self) -> str:
        return f"{self.kind} in {self.fn}: {self.message}"


def _leaf_aval(leaf: Any) -> Tuple[Any, ...]:
    """(shape, dtype, weak_type) of one argument leaf -- exactly the triple
    jit's dispatch cache keys on.  Non-array statics hash by repr."""
    import jax

    try:
        aval = jax.core.get_aval(leaf)
    except TypeError:
        return ("static", repr(leaf), False)
    return (
        tuple(getattr(aval, "shape", ())),
        str(getattr(aval, "dtype", type(leaf).__name__)),
        bool(getattr(aval, "weak_type", False)),
    )


def _signature(args: tuple, kwargs: dict) -> Signature:
    import jax

    leaves = jax.tree_util.tree_leaves((args, kwargs))
    return tuple(_leaf_aval(leaf) for leaf in leaves)


class CompileSentry:
    """Context manager counting compile-cache misses by abstract signature.

    ``wrap(fn, name)`` returns ``fn`` instrumented to record each call's
    abstract signature; the number of *distinct* signatures is the number
    of compiles jit must perform (its cache key), and pairs of signatures
    that differ only in ``weak_type`` or only in dtype are flagged as
    leaks -- the silent-retrace bug class.  When the wrapped object exposes
    ``_cache_size()`` (jitted functions do), the sentry cross-checks the
    observed cache growth against the signature count.
    """

    def __init__(self, registry: Optional[Any] = None):
        self.registry = registry
        self._reg_compiles0 = 0
        self._sigs: Dict[str, List[Signature]] = {}
        self._calls: Dict[str, int] = {}
        self._cache0: Dict[str, Optional[int]] = {}
        self._fns: Dict[str, Any] = {}
        self.findings: List[SentryFinding] = []
        # registry compile events observed while active, via the shared
        # obs hook (repro.compile emits; obs.events counts the metrics;
        # the sentry only *listens* -- nothing double counts)
        self.compile_events: List[Dict[str, Any]] = []
        self._listener: Optional[Callable] = None
        self.active = False

    # ------------------------------------------------------------- lifecycle
    def __enter__(self) -> "CompileSentry":
        self.active = True
        if self.registry is not None:
            self._reg_compiles0 = int(self.registry.stats["compiles"])
        self._listener = obs.on_compile(self.compile_events.append)
        return self

    def __exit__(self, *exc) -> None:
        self.active = False
        if self._listener is not None:
            obs.remove_compile_listener(self._listener)
            self._listener = None

    # --------------------------------------------------------------- wrapping
    def wrap(self, fn: Callable, name: Optional[str] = None) -> Callable:
        """Instrument ``fn``: every call records its abstract signature."""
        label = name or getattr(fn, "__name__", None) or repr(fn)
        self._sigs.setdefault(label, [])
        self._calls.setdefault(label, 0)
        self._fns[label] = fn
        if label not in self._cache0:
            size = getattr(fn, "_cache_size", None)
            self._cache0[label] = int(size()) if callable(size) else None

        def wrapped(*args, **kwargs):
            self._record(label, args, kwargs)
            return fn(*args, **kwargs)

        wrapped.__name__ = f"sentry[{label}]"
        return wrapped

    def _record(self, label: str, args: tuple, kwargs: dict) -> None:
        sig = _signature(args, kwargs)
        self._calls[label] += 1
        seen = self._sigs[label]
        if sig in seen:
            return
        for leaf in sig:
            shape, dtype, weak = leaf
            if weak and shape != () and shape != ("static",):
                self._report(SentryFinding(
                    "weak-type-arg", label,
                    f"weak-typed array argument {shape} {dtype}: its aval "
                    f"changes once an op touches it, forcing a retrace "
                    f"(give it an explicit dtype)"))
        for prev in seen:
            self._diff(label, prev, sig)
        seen.append(sig)

    def _diff(self, label: str, a: Signature, b: Signature) -> None:
        """Flag signature pairs that differ ONLY in weak_type / dtype --
        same shapes, so the caller almost certainly meant them to hit one
        compiled program."""
        if len(a) != len(b):
            return
        if any(la[0] != lb[0] for la, lb in zip(a, b)):
            return  # genuine shape polymorphism (bucketing) -- not a leak
        weak_only = all(la[:2] == lb[:2] for la, lb in zip(a, b))
        if weak_only:
            self._report(SentryFinding(
                "weak-type-leak", label,
                "two calls share every shape and dtype but differ in "
                "weak_type -- a weak-typed input is splitting the jit "
                "cache (the PR 3 class_prior bug class)"))
            return
        dtype_only = all(la[0] == lb[0] for la, lb in zip(a, b))
        if dtype_only:
            diffs = [
                f"{la[1]}->{lb[1]}"
                for la, lb in zip(a, b) if la[1] != lb[1]
            ]
            self._report(SentryFinding(
                "dtype-promotion-leak", label,
                f"two calls share every shape but differ in dtype "
                f"({', '.join(sorted(set(diffs))[:4])}) -- an implicit "
                f"promotion is splitting the jit cache"))

    def _report(self, finding: SentryFinding) -> None:
        if all(str(finding) != str(f) for f in self.findings):
            self.findings.append(finding)

    # ------------------------------------------------------------- accounting
    def signatures(self, name: str) -> Tuple[Signature, ...]:
        return tuple(self._sigs.get(name, ()))

    def compiles(self, name: Optional[str] = None) -> int:
        """Compiles attributable to the wrapped function(s): the jit cache
        growth when the object exposes it, else the distinct-signature
        count (identical by construction of jit's cache key)."""
        names = [name] if name is not None else list(self._sigs)
        total = 0
        for label in names:
            fn = self._fns.get(label)
            size = getattr(fn, "_cache_size", None)
            base = self._cache0.get(label)
            if callable(size) and base is not None:
                total += int(size()) - base
            else:
                total += len(self._sigs.get(label, ()))
        return total

    def registry_compiles(self) -> int:
        """ProgramRegistry compiles since ``__enter__`` (the AOT path)."""
        if self.registry is None:
            raise ValueError("CompileSentry was built without a registry")
        return int(self.registry.stats["compiles"]) - self._reg_compiles0

    # ------------------------------------------------------------- assertions
    def assert_max_compiles(self, limit: int, name: Optional[str] = None):
        got = self.compiles(name)
        if got > limit:
            raise AssertionError(
                f"recompile sentry: {got} compiles for "
                f"{name or 'all wrapped fns'} (limit {limit})\n"
                + self.report()
            )

    def assert_no_leaks(self) -> None:
        if self.findings:
            raise AssertionError(
                "recompile sentry found compile-hygiene leaks:\n"
                + "\n".join(f"  - {f}" for f in self.findings)
            )

    def report(self) -> str:
        lines = []
        for label, sigs in self._sigs.items():
            lines.append(
                f"  {label}: {self._calls[label]} call(s), "
                f"{len(sigs)} distinct signature(s), "
                f"{self.compiles(label)} compile(s)")
            for i, sig in enumerate(sigs):
                lines.append(f"    sig {i}: {sig}")
        for f in self.findings:
            lines.append(f"  finding: {f}")
        return "\n".join(lines) or "  (nothing wrapped)"
