"""Circuit / plan verifier: statically prove the invariants everything rests on.

Three layers of checks, each pure host-side python/numpy over static
structure (no jax arrays, no tracing -- the same domain as ``core.plan``):

  * **Region graph** (:func:`verify_region_graph`): smoothness and
    decomposability in the paper's Definition 1 sense -- every partition's
    child scopes are nonempty, disjoint, and cover the parent scope; the
    root region covers all variables.
  * **Compiled circuit** (:func:`verify_circuit`): the same two properties
    re-proved over the *built* artifact (``EiNet.pair_specs`` + the leaf
    layer) instead of the graph it came from, by recomputing every buffer
    row's scope bottom-up: gather rows must reference already-allocated
    rows, einsum children must have disjoint scopes, mixing children must
    share one scope (smoothness at the tensorized level), allocation must
    be contiguous in build order, the K chain must match the model, and the
    root row must cover every variable.  A graph that validates can still
    compile into a corrupt circuit (a canonicalization bug, a permuted
    gather row); this layer catches that independently.
  * **Execution plan** (:func:`verify_plan`): every ``CircuitPlan`` the
    planner emits -- segments partition the pair list exactly; mix masks
    cover exactly the mixing layers; fused segments are genuine canonical
    halving chains with in-budget VMEM working sets and valid tilings;
    gather segments carry ``GatherTables`` whose rows are in-range
    permutations consistent with the pair specs' child scopes; every
    planned launch shape satisfies the ``pad_to_lanes`` lane contract.

``verify_einet`` runs all three and returns a typed :class:`VerifyReport`.
Wired into ``EiNet(verify=...)`` / the ``REPRO_VERIFY`` env var and
``python -m repro.launch.dryrun --verify`` (the CI gate); negative tests in
``tests/test_analysis_verify.py`` corrupt tables/scopes/plans and assert
every corruption is caught by the invariant named here.
"""

from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import plan as plan_lib

# Every invariant the verifier can check, by id.  ``VerifyReport`` reports
# this set as its coverage; tests pin that each id has a negative test.
INVARIANTS = (
    # region graph (Definition 1)
    "graph/nonempty-scope",
    "graph/decomposability",
    "graph/smoothness",
    "graph/root-scope",
    # built circuit (pair specs + leaf layer)
    "circuit/row-range",
    "circuit/scope-decomposability",
    "circuit/scope-smoothness",
    "circuit/allocation-order",
    "circuit/k-chain",
    "circuit/mix-tables",
    "circuit/root-coverage",
    # execution plan (CircuitPlan)
    "plan/coverage",
    "plan/mix-flags",
    "plan/segment-kind",
    "plan/fused-structure",
    "plan/fused-tiling",
    "plan/gather-tables",
    "plan/gather-row-range",
    "plan/vmem-budget",
    "plan/lanes-contract",
)


@dataclasses.dataclass(frozen=True)
class Finding:
    """One violated invariant."""

    invariant: str  # id from INVARIANTS
    where: str  # location, e.g. "pair 3" / "segment gather[0,2) depth 1"
    message: str

    def __str__(self) -> str:
        return f"{self.invariant} @ {self.where}: {self.message}"


@dataclasses.dataclass(frozen=True)
class VerifyReport:
    """Typed verification outcome for one model / config."""

    name: str
    invariants: Tuple[str, ...]  # the ids that were checked
    findings: Tuple[Finding, ...]

    @property
    def ok(self) -> bool:
        return not self.findings

    def summary(self) -> str:
        """One startup log line (``[verify]`` in launch/dryrun.py)."""
        if self.ok:
            return (
                f"ok ({len(self.invariants)} invariants over "
                f"graph+circuit+plan)"
            )
        head = "; ".join(str(f) for f in self.findings[:3])
        more = len(self.findings) - 3
        return (
            f"FAILED {len(self.findings)} invariant(s): {head}"
            + (f"; +{more} more" if more > 0 else "")
        )

    def format_report(self) -> str:
        lines = [f"verify {self.name}: {self.summary()}"]
        lines += [f"  - {f}" for f in self.findings]
        return "\n".join(lines)


class VerifyError(RuntimeError):
    """Raised by ``EiNet(verify='raise')`` when verification fails."""

    def __init__(self, report: VerifyReport):
        super().__init__(report.format_report())
        self.report = report


# ------------------------------------------------------------ region graph
def verify_region_graph(graph) -> List[Finding]:
    """Definition 1, structurally: smooth + decomposable region graph."""
    out: List[Finding] = []
    num_vars = graph.num_vars
    all_vars = frozenset(range(num_vars))
    for rid, scope in enumerate(graph.regions):
        s = set(scope)
        if not s:
            out.append(Finding(
                "graph/nonempty-scope", f"region {rid}", "empty scope"))
        if not s <= all_vars:
            out.append(Finding(
                "graph/nonempty-scope", f"region {rid}",
                f"scope {sorted(s - all_vars)} outside [0, {num_vars})"))
    for pid, (parent, left, right) in enumerate(graph.partitions):
        sl = set(graph.regions[left])
        sr = set(graph.regions[right])
        sp = set(graph.regions[parent])
        if not sl or not sr:
            out.append(Finding(
                "graph/nonempty-scope", f"partition {pid}",
                "empty child scope"))
        if sl & sr:
            out.append(Finding(
                "graph/decomposability", f"partition {pid}",
                f"child scopes overlap on {sorted(sl & sr)[:8]}"))
        if sl | sr != sp:
            out.append(Finding(
                "graph/smoothness", f"partition {pid}",
                f"children cover {len(sl | sr)} vars, parent scope has "
                f"{len(sp)}"))
    if set(graph.regions[graph.root]) != all_vars:
        out.append(Finding(
            "graph/root-scope", f"region {graph.root}",
            f"root scope has {len(graph.regions[graph.root])} of "
            f"{num_vars} variables"))
    return out


# ---------------------------------------------------------- built circuit
def verify_circuit(model) -> List[Finding]:
    """Re-prove smoothness/decomposability over the BUILT circuit by
    recomputing every buffer row's scope bottom-up from the leaf layer."""
    out: List[Finding] = []
    ls = model.leaf_spec
    all_vars = frozenset(range(model.num_vars))
    row_scopes: List[frozenset] = [frozenset(s) for s in ls.leaf_scopes]
    root_scope: Optional[frozenset] = None
    for t, spec in enumerate(model.pair_specs):
        where = f"pair {t}"
        avail = len(row_scopes)
        if int(spec.einsum_global[0]) != avail or not np.array_equal(
            spec.einsum_global,
            np.arange(avail, avail + spec.num_partitions),
        ):
            out.append(Finding(
                "circuit/allocation-order", where,
                f"einsum rows {spec.einsum_global[:3].tolist()}... do not "
                f"continue the build allocation at row {avail}"))
        if spec.k_in != model.K:
            out.append(Finding(
                "circuit/k-chain", where,
                f"k_in {spec.k_in} != model K {model.K}"))
        want_k_out = model.num_classes if spec.is_final else model.K
        if spec.k_out != want_k_out:
            out.append(Finding(
                "circuit/k-chain", where,
                f"k_out {spec.k_out} != {want_k_out} "
                f"({'final' if spec.is_final else 'interior'} pair)"))
        pair_scopes: List[frozenset] = []
        for i in range(spec.num_partitions):
            li, ri = int(spec.left[i]), int(spec.right[i])
            if not (0 <= li < avail and 0 <= ri < avail):
                out.append(Finding(
                    "circuit/row-range", f"{where} partition {i}",
                    f"child rows ({li}, {ri}) outside the {avail} rows "
                    f"allocated below"))
                pair_scopes.append(frozenset())
                continue
            sl, sr = row_scopes[li], row_scopes[ri]
            if sl & sr:
                out.append(Finding(
                    "circuit/scope-decomposability", f"{where} partition {i}",
                    f"child rows {li} and {ri} share scope vars "
                    f"{sorted(sl & sr)[:8]}"))
            pair_scopes.append(sl | sr)
        row_scopes.extend(pair_scopes)
        if spec.mix_global is not None:
            mix_avail = len(row_scopes)
            if int(spec.mix_global[0]) != mix_avail or not np.array_equal(
                spec.mix_global,
                np.arange(mix_avail, mix_avail + spec.num_mixed),
            ):
                out.append(Finding(
                    "circuit/allocation-order", where,
                    "mixing rows do not continue the build allocation"))
            for m in range(spec.num_mixed):
                mask = np.asarray(spec.mix_mask[m])
                kids = np.asarray(spec.mix_child_local[m])
                if not np.all((mask == 0) | (mask == 1)) or mask.sum() < 1:
                    out.append(Finding(
                        "circuit/mix-tables", f"{where} mix row {m}",
                        f"mask must be 0/1 with >= 1 child, got "
                        f"{mask.tolist()}"))
                active = [int(k) for k, mk in zip(kids, mask) if mk > 0]
                if any(not 0 <= k < spec.num_partitions for k in active):
                    out.append(Finding(
                        "circuit/mix-tables", f"{where} mix row {m}",
                        f"child indices {active} outside "
                        f"[0, {spec.num_partitions})"))
                    row_scopes.append(frozenset())
                    continue
                kid_scopes = {pair_scopes[k] for k in active}
                if len(kid_scopes) > 1:
                    out.append(Finding(
                        "circuit/scope-smoothness", f"{where} mix row {m}",
                        "mixing children have differing scopes (sum node "
                        "over non-identical scopes is not smooth)"))
                row_scopes.append(next(iter(kid_scopes)) if kid_scopes
                                  else frozenset())
        if spec.is_final:
            if t != len(model.pair_specs) - 1:
                out.append(Finding(
                    "circuit/k-chain", where,
                    "is_final set on a non-terminal pair"))
            root_scope = (
                row_scopes[int(spec.mix_global[0])]
                if spec.mix_global is not None and spec.num_mixed
                else (pair_scopes[0] if pair_scopes else frozenset())
            )
    if root_scope is None or root_scope != all_vars:
        got = 0 if root_scope is None else len(root_scope)
        out.append(Finding(
            "circuit/root-coverage", "root row",
            f"root scope covers {got} of {model.num_vars} variables"))
    return out


# -------------------------------------------------------------------- plan
def _rows_available(specs: Sequence, t: int) -> int:
    """Rows allocated strictly below pair ``t`` (the build order)."""
    return int(specs[t].einsum_global[0])


def _check_fused_segment(specs, seg, budget, out: List[Finding]) -> None:
    where = f"segment fused[{seg.start},{seg.stop})"
    g = seg.stop - seg.start
    run = [specs[t] for t in range(seg.start, seg.stop)]
    if any(not sp.canonical for sp in run):
        out.append(Finding(
            "plan/fused-structure", where,
            "fused segment contains a non-canonical pair"))
        return
    if any(sp.mix_global is not None for sp in run[:-1]):
        out.append(Finding(
            "plan/fused-structure", where,
            "interior pair has a mixing layer (mixing may only terminate "
            "a fused run)"))
    l_out = run[-1].num_partitions
    for d, sp in enumerate(run):
        if sp.num_partitions != l_out * 2 ** (g - 1 - d):
            out.append(Finding(
                "plan/fused-structure", f"{where} depth {d}",
                f"{sp.num_partitions} partitions breaks the exact halving "
                f"chain to {l_out}"))
        if d < g - 1 and sp.k_out != run[d + 1].k_in:
            out.append(Finding(
                "plan/fused-structure", f"{where} depth {d}",
                f"k_out {sp.k_out} != next depth k_in {run[d + 1].k_in}"))
    if seg.out_block < 1 or l_out % max(seg.out_block, 1):
        out.append(Finding(
            "plan/fused-tiling", where,
            f"out_block {seg.out_block} does not tile L_out {l_out}"))
        return
    _check_lanes(seg, run[0].k_in, out, where)
    cost = plan_lib.fused_cost_bytes(
        specs, seg.start, seg.stop, seg.out_block, seg.block_b)
    if cost > budget:
        out.append(Finding(
            "plan/vmem-budget", where,
            f"working set {cost} B exceeds the effective budget {budget} B"))


def _check_gather_segment(specs, seg, budget, out: List[Finding]) -> None:
    where = f"segment gather[{seg.start},{seg.stop})"
    run = [specs[t] for t in range(seg.start, seg.stop)]
    if any(sp.is_final for sp in run):
        out.append(Finding(
            "plan/segment-kind", where,
            "gather segment covers the final (root) pair"))
    k = run[0].k_in
    if any(sp.k_in != k or sp.k_out != k for sp in run):
        out.append(Finding(
            "plan/gather-tables", where,
            f"non-uniform K across the run (expected k_in == k_out == {k})"))
    tb = seg.tables
    if tb is None:
        out.append(Finding(
            "plan/gather-tables", where, "gather segment carries no tables"))
        return
    if tb.num_depths != len(run):
        out.append(Finding(
            "plan/gather-tables", where,
            f"tables cover {tb.num_depths} depths, segment spans "
            f"{len(run)}"))
        return
    if tb.num_in_rows != _rows_available(specs, seg.start):
        out.append(Finding(
            "plan/gather-tables", where,
            f"tables.num_in_rows {tb.num_in_rows} != rows below the "
            f"segment {_rows_available(specs, seg.start)}"))
    if tb.k != k:
        out.append(Finding(
            "plan/gather-tables", where,
            f"tables.k {tb.k} != run K {k}"))
    avail = tb.num_in_rows
    for d, sp in enumerate(run):
        dw = f"{where} depth {d}"
        left = tuple(int(v) for v in sp.left)
        right = tuple(int(v) for v in sp.right)
        if tb.left[d] != left or tb.right[d] != right:
            out.append(Finding(
                "plan/gather-tables", dw,
                "frozen left/right rows disagree with the pair spec's "
                "child rows (table is not the spec's permutation)"))
        for side, rows in (("left", tb.left[d]), ("right", tb.right[d])):
            bad = [r for r in rows if not 0 <= int(r) < avail]
            if bad:
                out.append(Finding(
                    "plan/gather-row-range", dw,
                    f"{side} rows {bad[:4]} outside the {avail} buffer "
                    f"rows available at this depth"))
        avail += sp.num_partitions
        has_mix = sp.mix_global is not None
        if (tb.mix_child[d] is not None) != has_mix:
            out.append(Finding(
                "plan/mix-flags", dw,
                "tables' mixing entry does not match the pair's mixing "
                "layer (mix tables must cover exactly the mixing depths)"))
        elif has_mix:
            want_child = tuple(
                tuple(int(c) for c in row) for row in sp.mix_child_local)
            want_mask = tuple(
                tuple(int(m) for m in row) for row in sp.mix_mask)
            if tb.mix_child[d] != want_child or tb.mix_mask[d] != want_mask:
                out.append(Finding(
                    "plan/gather-tables", dw,
                    "frozen mixing tables disagree with the pair spec"))
            for m, mask_row in enumerate(tb.mix_mask[d] or ()):
                if sum(mask_row) < 1 or any(v not in (0, 1)
                                            for v in mask_row):
                    out.append(Finding(
                        "plan/mix-flags", f"{dw} mix row {m}",
                        f"mask row {mask_row} is not 0/1 with >= 1 child"))
            avail += sp.num_mixed
    _check_lanes(seg, k, out, where)
    cost = plan_lib.gather_cost_bytes(specs, seg.start, seg.stop, seg.block_b)
    if cost > budget:
        out.append(Finding(
            "plan/vmem-budget", where,
            f"working set {cost} B exceeds the effective budget {budget} B"))


def _check_lanes(seg, k: int, out: List[Finding], where: str) -> None:
    """The ``pad_to_lanes`` launch contract: the batch tile must be a
    positive multiple of 8 sublanes (the planner only emits the candidates
    in ``_GROUP_BLOCK_B``), and the padded K lane (K rounded to 16) must
    make the flattened K^2 product axis a whole number of 128 lanes."""
    if seg.block_b < 1 or seg.block_b % 8:
        out.append(Finding(
            "plan/lanes-contract", where,
            f"batch tile {seg.block_b} is not a positive multiple of 8"))
    if seg.block_b not in plan_lib._GROUP_BLOCK_B:
        out.append(Finding(
            "plan/lanes-contract", where,
            f"batch tile {seg.block_b} is not a planner candidate "
            f"{plan_lib._GROUP_BLOCK_B}"))
    k_p = -(-k // 16) * 16
    if (k_p * k_p) % 128:
        out.append(Finding(
            "plan/lanes-contract", where,
            f"padded K {k_p} leaves the K^2 axis off the 128 lane"))


def verify_plan(model) -> List[Finding]:
    """Validate ``model.plan`` (a ``core.plan.CircuitPlan``) against
    ``model.pair_specs``."""
    out: List[Finding] = []
    specs = model.pair_specs
    plan: plan_lib.CircuitPlan = model.plan
    n = len(specs)
    if plan.num_pairs != n:
        out.append(Finding(
            "plan/coverage", "plan",
            f"plan.num_pairs {plan.num_pairs} != {n} built pairs"))
    pos = 0
    for seg in plan.segments:
        if seg.start != pos or seg.stop <= seg.start:
            out.append(Finding(
                "plan/coverage", f"segment {seg.kind}[{seg.start},{seg.stop})",
                f"segments must tile the pair list in order; expected "
                f"start {pos}"))
            pos = max(pos, seg.stop)
            continue
        pos = seg.stop
    if pos != n:
        out.append(Finding(
            "plan/coverage", "plan",
            f"segments cover [0, {pos}) of {n} pairs"))
    want_flags = tuple(sp.mix_global is not None for sp in specs)
    if plan.mix_flags != want_flags:
        out.append(Finding(
            "plan/mix-flags", "plan",
            "plan.mix_flags does not mark exactly the mixing layers"))
    needs_buffer = any(not sp.canonical for sp in specs)
    budget = plan.vmem_budget
    if budget < 1:
        out.append(Finding(
            "plan/vmem-budget", "plan",
            f"effective VMEM budget {budget} B is not positive"))
    for seg in plan.segments:
        if seg.stop > n or seg.start >= n:
            continue  # already reported by plan/coverage
        if seg.kind == "fused":
            if needs_buffer:
                out.append(Finding(
                    "plan/segment-kind",
                    f"segment fused[{seg.start},{seg.stop})",
                    "fused (slice-tiled) segments are forbidden in "
                    "row-buffer mode: they skip materializing interior "
                    "rows and would leave holes in the buffer"))
            _check_fused_segment(specs, seg, budget, out)
        elif seg.kind == "gather":
            _check_gather_segment(specs, seg, budget, out)
        elif seg.kind == "layer":
            if seg.stop - seg.start != 1:
                out.append(Finding(
                    "plan/segment-kind",
                    f"segment layer[{seg.start},{seg.stop})",
                    "layer segments cover exactly one pair"))
        else:
            out.append(Finding(
                "plan/segment-kind",
                f"segment {seg.kind}[{seg.start},{seg.stop})",
                f"unknown segment kind {seg.kind!r}"))
    return out


# ----------------------------------------------------------------- reports
def verify_einet(model, name: Optional[str] = None) -> VerifyReport:
    """Run every check over a built ``EiNet`` (or ``EiNetMixture.component``)
    and return the typed report."""
    findings = (
        verify_region_graph(model.graph)
        + verify_circuit(model)
        + verify_plan(model)
    )
    return VerifyReport(
        name=name or f"einet[{model.num_vars} vars, K={model.K}]",
        invariants=INVARIANTS,
        findings=tuple(findings),
    )


def verify_config(cfg: Any, grouped: bool = True) -> VerifyReport:
    """Build the registered arch (``launch.cells.build_einet``) and verify
    it -- the ``dryrun --verify`` / CI path."""
    from repro.launch.cells import build_einet

    model = build_einet(cfg)
    if not grouped:
        model = type(model)(
            model.graph, num_sums=model.K, num_classes=model.num_classes,
            exponential_family=model.ef, grouped=False,
        )
    return verify_einet(model, name=cfg.name)
