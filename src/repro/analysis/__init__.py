"""Static analysis for the EiNet stack: verifier, sentry, repo lint.

Everything tractable about an EiNet rests on structural invariants --
smoothness and decomposability of the region graph (paper §2) -- and
everything fast about this repo rests on compile-time artifacts: the
``CircuitPlan`` segment schedule, frozen ``GatherTables`` permutation rows,
the ``pad_to_lanes`` padding contract, the shared NEG_INF convention.  None
of those are checked by running the model: a corrupted gather table still
produces finite numbers, a weak-typed parameter still trains (it just
silently recompiles every step), an ``interpret=True`` default still passes
CPU tests.  This package is the static layer that catches that defect class
before a TPU run does:

  * :mod:`repro.analysis.verify`  -- prove smoothness/decomposability of a
    region graph and the circuit built over it, and validate every
    ``CircuitPlan`` (gather-table permutation consistency, VMEM accounting,
    lane/padding contract) into a typed :class:`~repro.analysis.verify.VerifyReport`.
    Wired into ``EiNet(verify=...)`` / ``REPRO_VERIFY`` and
    ``python -m repro.launch.dryrun --verify`` (a CI gate).
  * :mod:`repro.analysis.sentry`  -- a recompile sentry: wrap jitted entry
    points, count compile-cache misses by abstract signature, and flag
    weak-type / dtype-promotion leaks, so "one compile per (kind, bucket)"
    is an assertable invariant for serve, train and the mixture step.
  * :mod:`repro.analysis.lint`    -- AST-based repo-specific rules
    (``python -m repro.analysis.lint``, a CI gate): NEG_INF-scale literals,
    ``interpret=`` defaults, unpadded Pallas call sites, bare ``jax.jit``
    outside the compile registry, donated buffers read after donation.
"""

from repro.analysis.verify import (  # noqa: F401
    Finding,
    VerifyError,
    VerifyReport,
    verify_config,
    verify_einet,
    verify_plan,
    verify_region_graph,
)
from repro.analysis.sentry import CompileSentry  # noqa: F401

__all__ = [
    "Finding",
    "VerifyError",
    "VerifyReport",
    "verify_config",
    "verify_einet",
    "verify_plan",
    "verify_region_graph",
    "CompileSentry",
]
