"""Backend dispatch for the Pallas kernels.

This is the single place that decides whether a kernel runs compiled (TPU)
or in interpret mode (CPU validation / fallback).  Kernel entry points take
``interpret=None`` and resolve it here, so a direct caller on TPU gets the
compiled kernel without having to know about interpret mode at all; passing
an explicit bool remains possible for tests that pin interpret mode.
"""

from __future__ import annotations

from typing import Optional

import jax


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """None -> interpret everywhere except TPU; a bool is taken verbatim."""
    if interpret is None:
        return not on_tpu()
    return bool(interpret)
