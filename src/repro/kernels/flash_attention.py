"""FlashAttention-style fused attention Pallas TPU kernel.

Used by the LM substrate (``repro.models.attention``) as the TPU-target
implementation of the O(S) -memory attention needed for the 32k prefill
shapes.  Online-softmax recurrence over KV tiles; the (S_q x S_k) score matrix
is never materialized in HBM.

Grid = (batch*heads, S_q / block_q, S_k / block_k) with the KV dimension
innermost: TPU grids execute sequentially over the last axis, so VMEM scratch
(m, l, acc) carries the running max / normalizer / weighted sum across KV
tiles (the standard Pallas TPU accumulation pattern).  Causal masking supports
a query-offset so the same kernel serves training (Sq == Sk) and incremental
decode (Sq == 1 against a long KV cache).

Validated against ``ref.mha_ref`` in interpret mode; GQA head-repetition is
handled by the wrapper in ``ops.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.layers import NEG_INF
from repro.kernels.dispatch import resolve_interpret


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *, scale,
            causal, block_q, block_k, q_offset, kv_len, num_k_blocks):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)  # (block_q, dh)
    k = k_ref[0].astype(jnp.float32)  # (block_k, dh)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    rows = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    cols = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    valid = cols < kv_len  # mask kv padding columns
    if causal:
        valid = valid & (cols <= rows + q_offset)
    s = jnp.where(valid, s, NEG_INF)
    m_prev = m_scr[...]  # (block_q, 1)
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)  # (block_q, block_k)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
    v = v_ref[0].astype(jnp.float32)
    acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == num_k_blocks - 1)
    def _done():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(
            o_ref.dtype
        )


@functools.partial(
    jax.jit,
    static_argnames=("causal", "scale", "block_q", "block_k", "interpret"),
)
def flash_attention_pallas(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """q: (BH, Sq, Dh); k, v: (BH, Sk, Dh) -- heads pre-folded into batch.

    ``interpret=None`` defers to backend dispatch (compiled on TPU,
    interpret elsewhere); an explicit bool pins the mode.

    Returns (BH, Sq, Dh) float32.
    """
    interpret = resolve_interpret(interpret)
    bh, sq, dh = q.shape
    sk = k.shape[1]
    if scale is None:
        scale = dh**-0.5
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    pad_q = (-sq) % block_q
    pad_k = (-sk) % block_k
    q_offset = sk - sq  # decode: queries sit at the end of the kv sequence
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0)))
    sqp, skp = q.shape[1], k.shape[1]
    num_k_blocks = skp // block_k
    kernel = functools.partial(
        _kernel,
        scale=scale,
        causal=causal,
        block_q=block_q,
        block_k=block_k,
        q_offset=q_offset,
        kv_len=sk,
        num_k_blocks=num_k_blocks,
    )
    out = pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((bh, sqp, dh), jnp.float32),
        grid=(bh, sqp // block_q, num_k_blocks),
        in_specs=[
            pl.BlockSpec((1, block_q, dh), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, block_k, dh), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, block_k, dh), lambda b, qi, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, dh), lambda b, qi, ki: (b, qi, 0)),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :sq] if pad_q else out
