"""Depth-grouped (whole-subcircuit) log-einsum-exp Pallas kernels.

``log_einsum_exp.py`` runs ONE (product, sum) pair per ``pallas_call``: every
depth of the circuit is a separate kernel launch and its log-activations make
a full HBM round-trip between launches.  This module fuses a RUN of
consecutive *canonical* pairs (left = rows [0, L), right = rows [L, 2L) of
the layer below -- the static-slice layout ``EiNet._canonicalize`` produces
for RAT-style structures) into a single kernel whose intermediate
activations never leave VMEM: the PyJuice-style "compile the DAG into a few
block-parallel kernels" execution model, restated for the TPU memory
hierarchy.

The key observation that makes deep fusion fit in VMEM is that a canonical
run is a forest of complete binary trees over the group's OUTPUT cells: the
set of depth-``g`` cells needed to produce output cells ``[t*s, (t+1)*s)``
is ``{c + m * L_out : c in [t*s, (t+1)*s), m < L_g / L_out}`` -- a regular
strided family.  Reshaping every operand from ``(L_g, ...)`` to
``(L_g / L_out, L_out, ...)`` turns that family into a rectangular block, so
a plain ``BlockSpec`` over the second axis tiles the whole subtree:

  * grid = (L_out / s, B / B_t): each program computes ``s`` output cells of
    the final depth for one batch tile, walking all ``G`` depths locally.
    In block coordinates every depth is still the canonical split -- inputs
    ``cur[:, :M/2]`` x ``cur[:, M/2:]`` -> outputs ``(B_t, M/2, s, K_out)``.
  * Each weight / input cell is read by EXACTLY ONE program (the trees are
    disjoint): fusion adds zero redundant HBM traffic, and shrinking ``s``
    shrinks the per-program working set proportionally, so the VMEM planner
    (``core.plan.plan_circuit``) can fuse arbitrarily wide depths by tiling
    the output cells instead of giving up.
  * Per cell the contraction is the SAME ``(B_t, K^2) @ (K^2, K_out)`` MXU
    dot as the per-layer kernel (identical operands, identical op), so the
    fused forward is bit-identical to the per-layer Pallas path wherever the
    padding contracts agree, and its gradients match autodiff of the chained
    reference to float32 roundoff.

Padding contract (``ops.pad_group_for_lanes``): K is rounded up to a
multiple of 16 exactly as in ``pad_for_lanes``; INTERIOR depths pad K_out to
the same padded K (their outputs are the next depth's inputs), and padded
weight rows are zero, so padded output lanes compute ``log(0) = -inf`` --
precisely the -inf padding the next depth's inputs require.  Only the final
depth pads K_out to a full 128 lane like the per-layer kernel.

The backward kernel follows the per-layer residual-recompute VJP contract:
it re-derives every depth's activations in VMEM from the (unpadded-then-
repadded) group inputs, walks the depths in reverse emitting ``dW`` (batch
tiles accumulate by revisiting the same block; batch is the innermost,
sequential grid axis) and the input cotangent, with the stabilized sum
recomputed by the forward's exact contraction.

GATHER-GROUPED kernels (``gather_grouped_log_einsum_exp_pallas`` + bwd)
extend the same fusion to ARBITRARY child topology -- Poon-Domingos pairs
whose children are cross-depth gathers, plus interior mixing layers -- via
static permutation tables (``core.plan.GatherTables``) compiled once on
host.  The tables are baked into the kernel as COMPILE-TIME CONSTANTS:
every gather unrolls into static row selects over an in-VMEM row list, so
irregular child access costs zero dynamic indexing inside the kernel (the
PyJuice block-sparse thesis).  We deliberately do NOT use
``PrefetchScalarGridSpec`` scalar-prefetch here: prefetch feeds BlockSpec
index maps, i.e. block-LEVEL indirection across the grid, while these
gathers select rows WITHIN the single resident buffer block -- a static
unroll is both simpler and exact.  The trade-off is one specialized program
per distinct table set (fine: one circuit has a handful of segments) and,
on real TPUs, constant-materialization of the tables (they are a few
hundred ints; revisit with scalar prefetch only if Mosaic constant pools
become a problem -- TPU validation is ROADMAP-gated).  Grid is batch-only:
the row buffer is irregular, so the segment is not cell-tiled; the planner
(``core.plan.gather_cost_bytes``) bounds run LENGTH instead of out_block.
Interior depths keep K_out == K and outputs stay on the 16-multiple K lane
(never widened to 128: all depths are non-final by construction).

Validated against autodiff of the chained XLA reference in interpret mode --
see ``tests/test_grouped.py`` and ``tests/test_gather_grouped.py``.  Forward
parity is bitwise; backward parity vs the per-layer path is bitwise on XLA
and float32-ulp-level through these kernels (per-layer ops pad every K_out
to 128 lanes while grouped interiors stay on the 16-pad, and gemm
reductions over different padded lengths associate partial sums
differently -- same values, different rounding; the per-depth math is
identical).
"""

from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.layers import NEG_INF
from repro.kernels.dispatch import resolve_interpret

# same stabilized-sum floor as the per-layer backward kernel (a NORMAL
# float32: XLA flushes subnormals, and g / 0 on saturated rows must not inf)
_S_FLOOR = 1e-30


def _depth_fwd(w, cur):
    """One canonical depth inside the kernel, in block coordinates.

    w:   (M/2, s, K_out, K, K) weight block.
    cur: (B_t, M, s, K) log-activations; left children are rows [0, M/2),
         right children rows [M/2, M) (the canonical split).
    Returns (B_t, M/2, s, K_out).
    """
    bb, m, s_, k = cur.shape
    h = m // 2
    ko = w.shape[2]
    lnl, lnr = cur[:, :h], cur[:, h:]
    # the per-layer kernel's exact stabilization, per (m, c) cell row
    a = jnp.maximum(jnp.max(lnl, axis=-1, keepdims=True), NEG_INF)
    ap = jnp.maximum(jnp.max(lnr, axis=-1, keepdims=True), NEG_INF)
    el = jnp.exp(lnl - a)
    er = jnp.exp(lnr - ap)
    cols = []
    for mi in range(h):
        row = []
        for ci in range(s_):
            # outer product in VMEM, then the per-layer kernel's exact
            # (B_t, K^2) @ (K^2, K_out) MXU contraction per cell
            prod = (el[:, mi, ci, :, None] * er[:, mi, ci, None, :]).reshape(
                bb, k * k
            )
            wmat = w[mi, ci].reshape(ko, k * k)
            s = jnp.dot(prod, wmat.T, preferred_element_type=jnp.float32)
            row.append(a[:, mi, ci] + ap[:, mi, ci] + jnp.log(s))
        cols.append(jnp.stack(row, axis=1))  # (B_t, s, K_out)
    return jnp.stack(cols, axis=1)  # (B_t, M/2, s, K_out)


def _depth_bwd(w, cur, gout):
    """Backward of one canonical depth, in block coordinates.

    gout: (B_t, M/2, s, K_out) cotangent of this depth's outputs.
    Returns (gw (M/2, s, K_out, K, K), gin (B_t, M, s, K)).
    """
    bb, m, s_, k = cur.shape
    h = m // 2
    ko = w.shape[2]
    lnl, lnr = cur[:, :h], cur[:, h:]
    a = jnp.maximum(jnp.max(lnl, axis=-1, keepdims=True), NEG_INF)
    ap = jnp.maximum(jnp.max(lnr, axis=-1, keepdims=True), NEG_INF)
    el = jnp.exp(lnl - a)
    er = jnp.exp(lnr - ap)
    gw_cols, gl_cols, gr_cols = [], [], []
    for mi in range(h):
        gw_row, gl_row, gr_row = [], [], []
        for ci in range(s_):
            eli, eri = el[:, mi, ci], er[:, mi, ci]  # (B_t, K)
            prod = (eli[:, :, None] * eri[:, None, :]).reshape(bb, k * k)
            wmat = w[mi, ci].reshape(ko, k * k)
            # forward's stabilized sum, recomputed with the forward's exact
            # contraction (same operands, same op -> bit-identical frame)
            s = jnp.dot(prod, wmat.T, preferred_element_type=jnp.float32)
            ginv = gout[:, mi, ci] / jnp.maximum(s, _S_FLOOR)  # (B_t, K_out)
            gw_row.append(
                jax.lax.dot_general(
                    ginv, prod, (((0,), (0,)), ((), ())),
                    preferred_element_type=jnp.float32,
                ).reshape(ko, k, k)
            )
            c = jnp.dot(ginv, wmat, preferred_element_type=jnp.float32)
            c = c.reshape(bb, k, k)
            gl_row.append(eli * jnp.sum(c * eri[:, None, :], axis=2))
            gr_row.append(eri * jnp.sum(c * eli[:, :, None], axis=1))
        gw_cols.append(jnp.stack(gw_row, axis=0))  # (s, K_out, K, K)
        gl_cols.append(jnp.stack(gl_row, axis=1))  # (B_t, s, K)
        gr_cols.append(jnp.stack(gr_row, axis=1))
    gw = jnp.stack(gw_cols, axis=0)  # (M/2, s, K_out, K, K)
    gin = jnp.concatenate(
        [jnp.stack(gl_cols, axis=1), jnp.stack(gr_cols, axis=1)], axis=1
    )  # (B_t, M, s, K)
    return gw, gin


def _make_fwd_kernel(num_depths: int):
    def kernel(*refs):
        w_refs, x_ref, o_ref = refs[:num_depths], refs[-2], refs[-1]
        cur = x_ref[...]  # (B_t, 2^G, s, K)
        for g in range(num_depths):
            cur = _depth_fwd(w_refs[g][...], cur)
        o_ref[...] = cur[:, 0].astype(o_ref.dtype)  # (B_t, s, K_out_final)

    return kernel


def _make_bwd_kernel(num_depths: int):
    def kernel(*refs):
        w_refs = refs[:num_depths]
        x_ref, g_ref = refs[num_depths], refs[num_depths + 1]
        gw_refs = refs[num_depths + 2: 2 * num_depths + 2]
        gx_ref = refs[-1]
        bi = pl.program_id(1)
        # recompute every depth's activations in VMEM (residual-recompute:
        # nothing but the group inputs was saved)
        acts = [x_ref[...]]
        for g in range(num_depths - 1):
            acts.append(_depth_fwd(w_refs[g][...], acts[-1]))
        gcur = g_ref[...][:, None]  # (B_t, 1, s, K_out_final)
        for g in reversed(range(num_depths)):
            gw_g, gcur = _depth_bwd(w_refs[g][...], acts[g], gcur)
            gw_ref = gw_refs[g]

            # batch tiles revisit the same dW block: init then accumulate
            # (batch is the innermost, sequential grid axis)
            @pl.when(bi == 0)
            def _init(gw_ref=gw_ref, gw_g=gw_g):
                gw_ref[...] = gw_g.astype(gw_ref.dtype)

            @pl.when(bi > 0)
            def _acc(gw_ref=gw_ref, gw_g=gw_g):
                gw_ref[...] += gw_g.astype(gw_ref.dtype)

        gx_ref[...] = gcur.astype(gx_ref.dtype)

    return kernel


def _pad_batch(block_b, *arrays):
    b = arrays[0].shape[0]
    pad_b = (-b) % block_b
    if not pad_b:
        return arrays
    return tuple(
        jnp.concatenate([x, jnp.zeros((pad_b,) + x.shape[1:], x.dtype)], 0)
        for x in arrays
    )


def _group_geometry(ws: Sequence[jax.Array], x: jax.Array):
    """Validate the canonical-run shapes and return (G, L_out, K, K_final)."""
    g = len(ws)
    b, rows, k = x.shape
    l_out = ws[-1].shape[0]
    if rows != l_out * 2 ** g:
        raise ValueError(
            f"group input has {rows} rows; a {g}-depth canonical run over "
            f"{l_out} output cells needs {l_out * 2 ** g}"
        )
    for d, w in enumerate(ws):
        if w.shape[0] != l_out * 2 ** (g - 1 - d):
            raise ValueError(
                f"depth {d} has {w.shape[0]} cells, expected "
                f"{l_out * 2 ** (g - 1 - d)} (canonical halving)"
            )
        if w.shape[-1] != k or w.shape[-2] != k:
            raise ValueError(f"depth {d} weight K {w.shape[-2:]} != input K {k}")
        if d < g - 1 and w.shape[1] != k:
            raise ValueError(
                f"interior depth {d} K_out {w.shape[1]} != K {k}; interior "
                "outputs feed the next depth so K_out must equal K"
            )
    return g, l_out, k, ws[-1].shape[1]


@functools.partial(
    jax.jit, static_argnames=("out_block", "block_b", "interpret")
)
def grouped_log_einsum_exp_pallas(
    ws: Tuple[jax.Array, ...],
    x: jax.Array,
    out_block: int = 1,
    block_b: int = 128,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Fused multi-depth forward: one kernel launch for a canonical run.

    Args:
      ws: per-depth linear-domain weights, input side first; depth ``d`` has
        shape (L_out * 2^(G-1-d), K_out_d, K, K) with K_out_d == K for every
        interior depth (padded per ``ops.pad_group_for_lanes``).
      x: (B, L_out * 2^G, K) log-domain inputs of the first depth (left
        children rows [0, L_0), right children rows [L_0, 2 L_0)).
      out_block: output cells per program (``s``); must divide L_out.  The
        VMEM knob: each program's working set is the s / L_out fraction of
        the whole group.
      block_b: batch tile.
      interpret: None defers to backend dispatch (compiled on TPU, interpret
        elsewhere); an explicit bool pins the mode.

    Returns: (B, L_out, K_out_final) float32.
    """
    interpret = resolve_interpret(interpret)
    g, l_out, k, k_final = _group_geometry(ws, x)
    if l_out % out_block:
        raise ValueError(f"out_block {out_block} does not divide L_out {l_out}")
    b = x.shape[0]
    block_b = min(block_b, b)
    (x,) = _pad_batch(block_b, x)
    bp = x.shape[0]
    s = out_block
    grid = (l_out // s, bp // block_b)
    x_r = x.reshape(bp, 2 ** g, l_out, k)
    w_r = [
        w.reshape(2 ** (g - 1 - d), l_out, w.shape[1], k, k)
        for d, w in enumerate(ws)
    ]
    in_specs = [
        pl.BlockSpec(
            (2 ** (g - 1 - d), s, w_r[d].shape[2], k, k),
            lambda ti, bi: (0, ti, 0, 0, 0),
        )
        for d in range(g)
    ] + [pl.BlockSpec((block_b, 2 ** g, s, k), lambda ti, bi: (bi, 0, ti, 0))]
    out = pl.pallas_call(
        _make_fwd_kernel(g),
        out_shape=jax.ShapeDtypeStruct((bp, l_out, k_final), jnp.float32),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec(
            (block_b, s, k_final), lambda ti, bi: (bi, ti, 0)
        ),
        interpret=interpret,
    )(*w_r, x_r)
    return out[:b] if bp != b else out


@functools.partial(
    jax.jit, static_argnames=("out_block", "block_b", "interpret")
)
def grouped_log_einsum_exp_bwd_pallas(
    ws: Tuple[jax.Array, ...],
    x: jax.Array,
    g_out: jax.Array,
    out_block: int = 1,
    block_b: int = 128,
    interpret: Optional[bool] = None,
):
    """Fused multi-depth backward: dW for every depth + the input cotangent,
    one kernel launch.

    Args:
      ws / x / out_block / block_b / interpret: as in the forward (residuals
        are the unpadded primals; the caller re-pads).
      g_out: (B, L_out, K_out_final) cotangent of the group output.

    Returns: (gws tuple matching ``ws`` shapes, gx (B, L_out * 2^G, K)).
    """
    interpret = resolve_interpret(interpret)
    g, l_out, k, k_final = _group_geometry(ws, x)
    if l_out % out_block:
        raise ValueError(f"out_block {out_block} does not divide L_out {l_out}")
    b = x.shape[0]
    block_b = min(block_b, b)
    x, g_out = _pad_batch(block_b, x, g_out)
    bp = x.shape[0]
    s = out_block
    grid = (l_out // s, bp // block_b)
    x_r = x.reshape(bp, 2 ** g, l_out, k)
    w_r = [
        w.reshape(2 ** (g - 1 - d), l_out, w.shape[1], k, k)
        for d, w in enumerate(ws)
    ]
    in_specs = [
        pl.BlockSpec(
            (2 ** (g - 1 - d), s, w_r[d].shape[2], k, k),
            lambda ti, bi: (0, ti, 0, 0, 0),
        )
        for d in range(g)
    ] + [
        pl.BlockSpec((block_b, 2 ** g, s, k), lambda ti, bi: (bi, 0, ti, 0)),
        pl.BlockSpec((block_b, s, k_final), lambda ti, bi: (bi, ti, 0)),
    ]
    # dW blocks are (M/2, s, K_out, K, K) in (m, c)-major layout: block
    # index depends on ti only, so batch tiles (innermost axis) revisit and
    # accumulate into the same block
    gw_shapes = tuple(
        jax.ShapeDtypeStruct(
            (2 ** (g - 1 - d), l_out, w_r[d].shape[2], k, k), jnp.float32
        )
        for d in range(g)
    )
    gw_specs = tuple(
        pl.BlockSpec(
            (2 ** (g - 1 - d), s, w_r[d].shape[2], k, k),
            lambda ti, bi: (0, ti, 0, 0, 0),
        )
        for d in range(g)
    )
    outs = pl.pallas_call(
        _make_bwd_kernel(g),
        out_shape=gw_shapes
        + (jax.ShapeDtypeStruct((bp, 2 ** g, l_out, k), jnp.float32),),
        grid=grid,
        in_specs=in_specs,
        out_specs=gw_specs
        + (pl.BlockSpec((block_b, 2 ** g, s, k), lambda ti, bi: (bi, 0, ti, 0)),),
        interpret=interpret,
    )(*w_r, x_r, g_out)
    gws = tuple(
        gw.reshape(w.shape[0], w.shape[1], k, k) for gw, w in zip(outs[:g], ws)
    )
    gx = outs[g].reshape(bp, l_out * 2 ** g, k)
    return gws, gx[:b] if bp != b else gx


# ---------------------------------------------------------------------------
# gather-grouped kernels: static-table topology (PD), mixing in-kernel
# ---------------------------------------------------------------------------
def _gather_depth_fwd(w, lnl, lnr):
    """One gather depth inside the kernel: flat per-cell operands.

    w:         (L, K_out, K, K) weight block.
    lnl / lnr: (B_t, L, K) gathered log-activations.
    Returns (B_t, L, K_out) -- the per-layer kernel's exact stabilization
    and (B_t, K^2) @ (K^2, K_out) MXU contraction, per cell.
    """
    bb, l, k = lnl.shape
    ko = w.shape[1]
    a = jnp.maximum(jnp.max(lnl, axis=-1, keepdims=True), NEG_INF)
    ap = jnp.maximum(jnp.max(lnr, axis=-1, keepdims=True), NEG_INF)
    el = jnp.exp(lnl - a)
    er = jnp.exp(lnr - ap)
    outs = []
    for li in range(l):
        prod = (el[:, li, :, None] * er[:, li, None, :]).reshape(bb, k * k)
        wmat = w[li].reshape(ko, k * k)
        s = jnp.dot(prod, wmat.T, preferred_element_type=jnp.float32)
        outs.append(a[:, li] + ap[:, li] + jnp.log(s))
    return jnp.stack(outs, axis=1)


def _gather_depth_bwd(w, lnl, lnr, gout):
    """Backward of one gather depth (the per-layer backward's exact math).

    gout: (B_t, L, K_out) cotangent of this depth's einsum outputs.
    Returns (gw (L, K_out, K, K), gl (B_t, L, K), gr (B_t, L, K)).
    """
    bb, l, k = lnl.shape
    ko = w.shape[1]
    a = jnp.maximum(jnp.max(lnl, axis=-1, keepdims=True), NEG_INF)
    ap = jnp.maximum(jnp.max(lnr, axis=-1, keepdims=True), NEG_INF)
    el = jnp.exp(lnl - a)
    er = jnp.exp(lnr - ap)
    gw_rows, gl_rows, gr_rows = [], [], []
    for li in range(l):
        eli, eri = el[:, li], er[:, li]  # (B_t, K)
        prod = (eli[:, :, None] * eri[:, None, :]).reshape(bb, k * k)
        wmat = w[li].reshape(ko, k * k)
        # forward's stabilized sum, recomputed bit-exactly
        s = jnp.dot(prod, wmat.T, preferred_element_type=jnp.float32)
        ginv = gout[:, li] / jnp.maximum(s, _S_FLOOR)  # (B_t, K_out)
        gw_rows.append(
            jax.lax.dot_general(
                ginv, prod, (((0,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            ).reshape(ko, k, k)
        )
        c = jnp.dot(ginv, wmat, preferred_element_type=jnp.float32)
        c = c.reshape(bb, k, k)
        gl_rows.append(eli * jnp.sum(c * eri[:, None, :], axis=2))
        gr_rows.append(eri * jnp.sum(c * eli[:, :, None], axis=1))
    return (
        jnp.stack(gw_rows, axis=0),
        jnp.stack(gl_rows, axis=1),
        jnp.stack(gr_rows, axis=1),
    )


def _gather_mix_frame(v, s, child, mask):
    """``core.layers._log_mix_exp_frame`` replicated in-kernel on statically
    gathered children: (masked ln, clamped max, exp'd inputs, stabilized
    sum).  The mask is applied by STATIC selection (padded children become
    NEG_INF rows at trace time -- Pallas kernels cannot capture array
    constants), which selects exactly the values ``jnp.where(mask > 0, ...)``
    selects; every traced op then matches the XLA frame expression for
    expression, so mixing rows are bitwise-identical.

    v: (M, C, K); s: (B_t, L, K) this depth's einsum rows; child / mask:
    STATIC (M, C) nested int tuples (local einsum indices, 0/1 flags).
    """
    bb, _, k = s.shape
    neg = jnp.full((bb, k), NEG_INF, dtype=s.dtype)
    lnm = jnp.stack(
        [
            jnp.stack(
                [
                    s[:, c, :] if mask[mi][ci] else neg
                    for ci, c in enumerate(row)
                ],
                axis=1,
            )
            for mi, row in enumerate(child)
        ],
        axis=1,
    )  # (B_t, M, C, K)
    a = jnp.maximum(jnp.max(lnm, axis=2, keepdims=True), NEG_INF)
    e = jnp.exp(lnm - a)
    ssum = jnp.sum(v[None] * e, axis=2)  # (B_t, M, K)
    return a, e, ssum


def _gather_fwd_sweep(tables, w_blocks, v_blocks, x):
    """The shared forward walk over an in-VMEM row list: returns
    (rows, new_rows, frames) where frames[t] = (lnl, lnr, s, e_base, m_base)
    for the backward's residual recompute."""
    r_in = tables.num_in_rows
    rows = [x[:, r, :] for r in range(r_in)]
    new_rows = []
    frames = []
    vi = 0
    for t in range(tables.num_depths):
        lnl = jnp.stack([rows[r] for r in tables.left[t]], axis=1)
        lnr = jnp.stack([rows[r] for r in tables.right[t]], axis=1)
        s = _gather_depth_fwd(w_blocks[t], lnl, lnr)  # (B_t, L, K)
        e_base = len(rows)
        for li in range(s.shape[1]):
            rows.append(s[:, li, :])
            new_rows.append(s[:, li, :])
        m_base = None
        if tables.mix_child[t] is not None:
            a, _, ssum = _gather_mix_frame(
                v_blocks[vi], s, tables.mix_child[t], tables.mix_mask[t]
            )
            vi += 1
            m = a[:, :, 0, :] + jnp.log(ssum)  # (B_t, M, K)
            m_base = len(rows)
            for mi in range(m.shape[1]):
                rows.append(m[:, mi, :])
                new_rows.append(m[:, mi, :])
        frames.append((lnl, lnr, s, e_base, m_base))
    return rows, new_rows, frames


def _make_gather_fwd_kernel(tables):
    d_total = tables.num_depths
    n_mix = tables.num_mix_depths

    def kernel(*refs):
        w_refs = refs[:d_total]
        v_refs = refs[d_total: d_total + n_mix]
        x_ref, o_ref = refs[-2], refs[-1]
        _, new_rows, _ = _gather_fwd_sweep(
            tables,
            [w[...] for w in w_refs],
            [v[...] for v in v_refs],
            x_ref[...],
        )
        o_ref[...] = jnp.stack(new_rows, axis=1).astype(o_ref.dtype)

    return kernel


def _make_gather_bwd_kernel(tables):
    d_total = tables.num_depths
    n_mix = tables.num_mix_depths
    r_in = tables.num_in_rows

    def kernel(*refs):
        w_refs = refs[:d_total]
        v_refs = refs[d_total: d_total + n_mix]
        x_ref = refs[d_total + n_mix]
        g_ref = refs[d_total + n_mix + 1]
        gw_refs = refs[d_total + n_mix + 2: 2 * d_total + n_mix + 2]
        gv_refs = refs[2 * d_total + n_mix + 2: 2 * d_total + 2 * n_mix + 2]
        gx_ref = refs[-1]
        bi = pl.program_id(0)

        w_blocks = [w[...] for w in w_refs]
        v_blocks = [v[...] for v in v_refs]
        g = g_ref[...]  # (B_t, r_new, K)
        # residual-recompute: re-derive every row + every depth's frame
        rows, _, frames = _gather_fwd_sweep(
            tables, w_blocks, v_blocks, x_ref[...]
        )
        zero = jnp.zeros_like(rows[0])
        cot = [zero] * r_in + [
            g[:, idx, :] for idx in range(len(rows) - r_in)
        ]
        vi = n_mix
        for t in reversed(range(d_total)):
            lnl, lnr, s, e_base, m_base = frames[t]
            # mixing backward FIRST: its gradient lands on this depth's
            # einsum rows before their own backward runs
            if tables.mix_child[t] is not None:
                vi -= 1
                v = v_blocks[vi]
                child = tables.mix_child[t]
                mask = tables.mix_mask[t]
                gm = jnp.stack(
                    [cot[m_base + mi] for mi in range(len(child))], axis=1
                )  # (B_t, M, K)
                _, e, ssum = _gather_mix_frame(v, s, child, mask)
                ginv = gm / jnp.maximum(ssum, _S_FLOOR)
                # static masking (see _gather_mix_frame): masked children
                # contribute exact zeros to dV and nothing to the scatter
                gv_rows = []
                for mi, row in enumerate(child):
                    gv_cols = []
                    for ci, c in enumerate(row):
                        if mask[mi][ci]:
                            ge = ginv[:, mi, :] * e[:, mi, ci, :]
                            gv_cols.append(jnp.sum(ge, axis=0))
                            cot[e_base + c] = (
                                cot[e_base + c] + ge * v[mi, ci][None]
                            )
                        else:
                            gv_cols.append(jnp.zeros_like(v[mi, ci]))
                    gv_rows.append(jnp.stack(gv_cols, axis=0))
                gv_t = jnp.stack(gv_rows, axis=0)  # (M, C, K)
                gv_ref = gv_refs[vi]

                @pl.when(bi == 0)
                def _init_v(gv_ref=gv_ref, gv_t=gv_t):
                    gv_ref[...] = gv_t.astype(gv_ref.dtype)

                @pl.when(bi > 0)
                def _acc_v(gv_ref=gv_ref, gv_t=gv_t):
                    gv_ref[...] += gv_t.astype(gv_ref.dtype)

            gs = jnp.stack(
                [cot[e_base + li] for li in range(len(tables.left[t]))],
                axis=1,
            )
            gw_t, gl, gr = _gather_depth_bwd(w_blocks[t], lnl, lnr, gs)
            # scatter order (right vs left) is numerically irrelevant: a
            # row hit by both sides accumulates two terms on top of its
            # existing cotangent, and measured diffs vs the per-layer path
            # are identical under either order -- the residual float32-ulp
            # gap comes from gemm reduction association under different
            # padded lane lengths (see gather_grouped docstring), not from
            # scatter ordering
            for li, r in enumerate(tables.right[t]):
                cot[r] = cot[r] + gr[:, li, :]
            for li, r in enumerate(tables.left[t]):
                cot[r] = cot[r] + gl[:, li, :]
            gw_ref = gw_refs[t]

            @pl.when(bi == 0)
            def _init_w(gw_ref=gw_ref, gw_t=gw_t):
                gw_ref[...] = gw_t.astype(gw_ref.dtype)

            @pl.when(bi > 0)
            def _acc_w(gw_ref=gw_ref, gw_t=gw_t):
                gw_ref[...] += gw_t.astype(gw_ref.dtype)

        gx_ref[...] = jnp.stack(cot[:r_in], axis=1).astype(gx_ref.dtype)

    return kernel


def _gather_geometry(tables, ws, vs, x):
    """Validate the table-carrying shapes; returns (r_new, K)."""
    b, r_in, k = x.shape
    if r_in != tables.num_in_rows:
        raise ValueError(
            f"gather input has {r_in} rows; tables expect "
            f"{tables.num_in_rows}"
        )
    if len(ws) != tables.num_depths:
        raise ValueError(
            f"{len(ws)} weight depths vs {tables.num_depths} table depths"
        )
    for t, w in enumerate(ws):
        l = len(tables.left[t])
        if w.shape != (l, k, k, k):
            raise ValueError(
                f"gather depth {t} weights {w.shape} != {(l, k, k, k)} "
                "(interior depths keep K_out == K)"
            )
    if len(vs) != tables.num_mix_depths:
        raise ValueError(
            f"{len(vs)} mixing depths vs {tables.num_mix_depths} in tables"
        )
    vi = 0
    for t in range((tables.num_depths)):
        if tables.mix_child[t] is None:
            continue
        m, c = len(tables.mix_child[t]), len(tables.mix_child[t][0])
        if vs[vi].shape != (m, c, k):
            raise ValueError(
                f"gather mix depth {t} weights {vs[vi].shape} != {(m, c, k)}"
            )
        vi += 1
    return tables.num_new_rows, k


@functools.partial(
    jax.jit, static_argnames=("tables", "block_b", "interpret")
)
def gather_grouped_log_einsum_exp_pallas(
    tables,
    ws: Tuple[jax.Array, ...],
    vs: Tuple[jax.Array, ...],
    x: jax.Array,
    block_b: int = 128,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Fused gather-topology forward: one launch for a table-driven run.

    Args:
      tables: ``core.plan.GatherTables`` (static; baked into the kernel).
      ws: per-depth linear-domain weights, (L_t, K, K, K) each (every depth
        is interior: K_out == K, padded per ``ops.pad_gather_for_lanes``).
      vs: mixing weights for the table's mixing depths, in depth order,
        (M_t, C_t, K) each.
      x: (B, r_in, K) log-domain row buffer below the segment.
      block_b: batch tile (grid is batch-only; the segment is not
        cell-tiled -- see the module docstring).
      interpret: None defers to backend dispatch.

    Returns: (B, r_new, K) float32 -- every new row (einsum rows then mixing
    rows, per depth, in emission order = global row order).
    """
    interpret = resolve_interpret(interpret)
    r_new, k = _gather_geometry(tables, ws, vs, x)
    b = x.shape[0]
    block_b = min(block_b, b)
    (x,) = _pad_batch(block_b, x)
    bp = x.shape[0]
    grid = (bp // block_b,)
    r_in = tables.num_in_rows
    in_specs = (
        [pl.BlockSpec(w.shape, lambda bi: (0, 0, 0, 0)) for w in ws]
        + [pl.BlockSpec(v.shape, lambda bi: (0, 0, 0)) for v in vs]
        + [pl.BlockSpec((block_b, r_in, k), lambda bi: (bi, 0, 0))]
    )
    out = pl.pallas_call(
        _make_gather_fwd_kernel(tables),
        out_shape=jax.ShapeDtypeStruct((bp, r_new, k), jnp.float32),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_b, r_new, k), lambda bi: (bi, 0, 0)),
        interpret=interpret,
    )(*ws, *vs, x)
    return out[:b] if bp != b else out


@functools.partial(
    jax.jit, static_argnames=("tables", "block_b", "interpret")
)
def gather_grouped_log_einsum_exp_bwd_pallas(
    tables,
    ws: Tuple[jax.Array, ...],
    vs: Tuple[jax.Array, ...],
    x: jax.Array,
    g_out: jax.Array,
    block_b: int = 128,
    interpret: Optional[bool] = None,
):
    """Fused gather-topology backward: dW per depth, dV per mixing depth and
    the input-buffer cotangent, one launch (residual-recompute: the forward
    rows and every stabilized frame are re-derived in VMEM from the primals;
    dW/dV accumulate across batch tiles via ``pl.when`` on the sequential
    batch grid axis).

    Returns: (gws tuple matching ``ws``, gvs tuple matching ``vs``,
    gx (B, r_in, K)).
    """
    interpret = resolve_interpret(interpret)
    r_new, k = _gather_geometry(tables, ws, vs, x)
    b = x.shape[0]
    block_b = min(block_b, b)
    x, g_out = _pad_batch(block_b, x, g_out)
    bp = x.shape[0]
    grid = (bp // block_b,)
    r_in = tables.num_in_rows
    d_total = tables.num_depths
    in_specs = (
        [pl.BlockSpec(w.shape, lambda bi: (0, 0, 0, 0)) for w in ws]
        + [pl.BlockSpec(v.shape, lambda bi: (0, 0, 0)) for v in vs]
        + [
            pl.BlockSpec((block_b, r_in, k), lambda bi: (bi, 0, 0)),
            pl.BlockSpec((block_b, r_new, k), lambda bi: (bi, 0, 0)),
        ]
    )
    # dW / dV blocks ignore the batch grid index: every batch tile revisits
    # the same block and accumulates (batch is the only -- hence innermost,
    # sequential -- grid axis)
    out_shape = (
        tuple(jax.ShapeDtypeStruct(w.shape, jnp.float32) for w in ws)
        + tuple(jax.ShapeDtypeStruct(v.shape, jnp.float32) for v in vs)
        + (jax.ShapeDtypeStruct((bp, r_in, k), jnp.float32),)
    )
    out_specs = (
        tuple(pl.BlockSpec(w.shape, lambda bi: (0, 0, 0, 0)) for w in ws)
        + tuple(pl.BlockSpec(v.shape, lambda bi: (0, 0, 0)) for v in vs)
        + (pl.BlockSpec((block_b, r_in, k), lambda bi: (bi, 0, 0)),)
    )
    outs = pl.pallas_call(
        _make_gather_bwd_kernel(tables),
        out_shape=out_shape,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        interpret=interpret,
    )(*ws, *vs, x, g_out)
    gws = tuple(outs[:d_total])
    gvs = tuple(outs[d_total: d_total + len(vs)])
    gx = outs[-1]
    return gws, gvs, gx[:b] if bp != b else gx
