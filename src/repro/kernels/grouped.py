"""Depth-grouped (whole-subcircuit) log-einsum-exp Pallas kernels.

``log_einsum_exp.py`` runs ONE (product, sum) pair per ``pallas_call``: every
depth of the circuit is a separate kernel launch and its log-activations make
a full HBM round-trip between launches.  This module fuses a RUN of
consecutive *canonical* pairs (left = rows [0, L), right = rows [L, 2L) of
the layer below -- the static-slice layout ``EiNet._canonicalize`` produces
for RAT-style structures) into a single kernel whose intermediate
activations never leave VMEM: the PyJuice-style "compile the DAG into a few
block-parallel kernels" execution model, restated for the TPU memory
hierarchy.

The key observation that makes deep fusion fit in VMEM is that a canonical
run is a forest of complete binary trees over the group's OUTPUT cells: the
set of depth-``g`` cells needed to produce output cells ``[t*s, (t+1)*s)``
is ``{c + m * L_out : c in [t*s, (t+1)*s), m < L_g / L_out}`` -- a regular
strided family.  Reshaping every operand from ``(L_g, ...)`` to
``(L_g / L_out, L_out, ...)`` turns that family into a rectangular block, so
a plain ``BlockSpec`` over the second axis tiles the whole subtree:

  * grid = (L_out / s, B / B_t): each program computes ``s`` output cells of
    the final depth for one batch tile, walking all ``G`` depths locally.
    In block coordinates every depth is still the canonical split -- inputs
    ``cur[:, :M/2]`` x ``cur[:, M/2:]`` -> outputs ``(B_t, M/2, s, K_out)``.
  * Each weight / input cell is read by EXACTLY ONE program (the trees are
    disjoint): fusion adds zero redundant HBM traffic, and shrinking ``s``
    shrinks the per-program working set proportionally, so the VMEM planner
    (``EiNet._plan_groups``) can fuse arbitrarily wide depths by tiling the
    output cells instead of giving up.
  * Per cell the contraction is the SAME ``(B_t, K^2) @ (K^2, K_out)`` MXU
    dot as the per-layer kernel (identical operands, identical op), so the
    fused forward is bit-identical to the per-layer Pallas path wherever the
    padding contracts agree, and its gradients match autodiff of the chained
    reference to float32 roundoff.

Padding contract (``ops.pad_group_for_lanes``): K is rounded up to a
multiple of 16 exactly as in ``pad_for_lanes``; INTERIOR depths pad K_out to
the same padded K (their outputs are the next depth's inputs), and padded
weight rows are zero, so padded output lanes compute ``log(0) = -inf`` --
precisely the -inf padding the next depth's inputs require.  Only the final
depth pads K_out to a full 128 lane like the per-layer kernel.

The backward kernel follows the per-layer residual-recompute VJP contract:
it re-derives every depth's activations in VMEM from the (unpadded-then-
repadded) group inputs, walks the depths in reverse emitting ``dW`` (batch
tiles accumulate by revisiting the same block; batch is the innermost,
sequential grid axis) and the input cotangent, with the stabilized sum
recomputed by the forward's exact contraction.

Validated against autodiff of the chained XLA reference in interpret mode --
see ``tests/test_grouped.py``.
"""

from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.layers import NEG_INF
from repro.kernels.dispatch import resolve_interpret

# same stabilized-sum floor as the per-layer backward kernel (a NORMAL
# float32: XLA flushes subnormals, and g / 0 on saturated rows must not inf)
_S_FLOOR = 1e-30


def _depth_fwd(w, cur):
    """One canonical depth inside the kernel, in block coordinates.

    w:   (M/2, s, K_out, K, K) weight block.
    cur: (B_t, M, s, K) log-activations; left children are rows [0, M/2),
         right children rows [M/2, M) (the canonical split).
    Returns (B_t, M/2, s, K_out).
    """
    bb, m, s_, k = cur.shape
    h = m // 2
    ko = w.shape[2]
    lnl, lnr = cur[:, :h], cur[:, h:]
    # the per-layer kernel's exact stabilization, per (m, c) cell row
    a = jnp.maximum(jnp.max(lnl, axis=-1, keepdims=True), NEG_INF)
    ap = jnp.maximum(jnp.max(lnr, axis=-1, keepdims=True), NEG_INF)
    el = jnp.exp(lnl - a)
    er = jnp.exp(lnr - ap)
    cols = []
    for mi in range(h):
        row = []
        for ci in range(s_):
            # outer product in VMEM, then the per-layer kernel's exact
            # (B_t, K^2) @ (K^2, K_out) MXU contraction per cell
            prod = (el[:, mi, ci, :, None] * er[:, mi, ci, None, :]).reshape(
                bb, k * k
            )
            wmat = w[mi, ci].reshape(ko, k * k)
            s = jnp.dot(prod, wmat.T, preferred_element_type=jnp.float32)
            row.append(a[:, mi, ci] + ap[:, mi, ci] + jnp.log(s))
        cols.append(jnp.stack(row, axis=1))  # (B_t, s, K_out)
    return jnp.stack(cols, axis=1)  # (B_t, M/2, s, K_out)


def _depth_bwd(w, cur, gout):
    """Backward of one canonical depth, in block coordinates.

    gout: (B_t, M/2, s, K_out) cotangent of this depth's outputs.
    Returns (gw (M/2, s, K_out, K, K), gin (B_t, M, s, K)).
    """
    bb, m, s_, k = cur.shape
    h = m // 2
    ko = w.shape[2]
    lnl, lnr = cur[:, :h], cur[:, h:]
    a = jnp.maximum(jnp.max(lnl, axis=-1, keepdims=True), NEG_INF)
    ap = jnp.maximum(jnp.max(lnr, axis=-1, keepdims=True), NEG_INF)
    el = jnp.exp(lnl - a)
    er = jnp.exp(lnr - ap)
    gw_cols, gl_cols, gr_cols = [], [], []
    for mi in range(h):
        gw_row, gl_row, gr_row = [], [], []
        for ci in range(s_):
            eli, eri = el[:, mi, ci], er[:, mi, ci]  # (B_t, K)
            prod = (eli[:, :, None] * eri[:, None, :]).reshape(bb, k * k)
            wmat = w[mi, ci].reshape(ko, k * k)
            # forward's stabilized sum, recomputed with the forward's exact
            # contraction (same operands, same op -> bit-identical frame)
            s = jnp.dot(prod, wmat.T, preferred_element_type=jnp.float32)
            ginv = gout[:, mi, ci] / jnp.maximum(s, _S_FLOOR)  # (B_t, K_out)
            gw_row.append(
                jax.lax.dot_general(
                    ginv, prod, (((0,), (0,)), ((), ())),
                    preferred_element_type=jnp.float32,
                ).reshape(ko, k, k)
            )
            c = jnp.dot(ginv, wmat, preferred_element_type=jnp.float32)
            c = c.reshape(bb, k, k)
            gl_row.append(eli * jnp.sum(c * eri[:, None, :], axis=2))
            gr_row.append(eri * jnp.sum(c * eli[:, :, None], axis=1))
        gw_cols.append(jnp.stack(gw_row, axis=0))  # (s, K_out, K, K)
        gl_cols.append(jnp.stack(gl_row, axis=1))  # (B_t, s, K)
        gr_cols.append(jnp.stack(gr_row, axis=1))
    gw = jnp.stack(gw_cols, axis=0)  # (M/2, s, K_out, K, K)
    gin = jnp.concatenate(
        [jnp.stack(gl_cols, axis=1), jnp.stack(gr_cols, axis=1)], axis=1
    )  # (B_t, M, s, K)
    return gw, gin


def _make_fwd_kernel(num_depths: int):
    def kernel(*refs):
        w_refs, x_ref, o_ref = refs[:num_depths], refs[-2], refs[-1]
        cur = x_ref[...]  # (B_t, 2^G, s, K)
        for g in range(num_depths):
            cur = _depth_fwd(w_refs[g][...], cur)
        o_ref[...] = cur[:, 0].astype(o_ref.dtype)  # (B_t, s, K_out_final)

    return kernel


def _make_bwd_kernel(num_depths: int):
    def kernel(*refs):
        w_refs = refs[:num_depths]
        x_ref, g_ref = refs[num_depths], refs[num_depths + 1]
        gw_refs = refs[num_depths + 2: 2 * num_depths + 2]
        gx_ref = refs[-1]
        bi = pl.program_id(1)
        # recompute every depth's activations in VMEM (residual-recompute:
        # nothing but the group inputs was saved)
        acts = [x_ref[...]]
        for g in range(num_depths - 1):
            acts.append(_depth_fwd(w_refs[g][...], acts[-1]))
        gcur = g_ref[...][:, None]  # (B_t, 1, s, K_out_final)
        for g in reversed(range(num_depths)):
            gw_g, gcur = _depth_bwd(w_refs[g][...], acts[g], gcur)
            gw_ref = gw_refs[g]

            # batch tiles revisit the same dW block: init then accumulate
            # (batch is the innermost, sequential grid axis)
            @pl.when(bi == 0)
            def _init(gw_ref=gw_ref, gw_g=gw_g):
                gw_ref[...] = gw_g.astype(gw_ref.dtype)

            @pl.when(bi > 0)
            def _acc(gw_ref=gw_ref, gw_g=gw_g):
                gw_ref[...] += gw_g.astype(gw_ref.dtype)

        gx_ref[...] = gcur.astype(gx_ref.dtype)

    return kernel


def _pad_batch(block_b, *arrays):
    b = arrays[0].shape[0]
    pad_b = (-b) % block_b
    if not pad_b:
        return arrays
    return tuple(
        jnp.concatenate([x, jnp.zeros((pad_b,) + x.shape[1:], x.dtype)], 0)
        for x in arrays
    )


def _group_geometry(ws: Sequence[jax.Array], x: jax.Array):
    """Validate the canonical-run shapes and return (G, L_out, K, K_final)."""
    g = len(ws)
    b, rows, k = x.shape
    l_out = ws[-1].shape[0]
    if rows != l_out * 2 ** g:
        raise ValueError(
            f"group input has {rows} rows; a {g}-depth canonical run over "
            f"{l_out} output cells needs {l_out * 2 ** g}"
        )
    for d, w in enumerate(ws):
        if w.shape[0] != l_out * 2 ** (g - 1 - d):
            raise ValueError(
                f"depth {d} has {w.shape[0]} cells, expected "
                f"{l_out * 2 ** (g - 1 - d)} (canonical halving)"
            )
        if w.shape[-1] != k or w.shape[-2] != k:
            raise ValueError(f"depth {d} weight K {w.shape[-2:]} != input K {k}")
        if d < g - 1 and w.shape[1] != k:
            raise ValueError(
                f"interior depth {d} K_out {w.shape[1]} != K {k}; interior "
                "outputs feed the next depth so K_out must equal K"
            )
    return g, l_out, k, ws[-1].shape[1]


@functools.partial(
    jax.jit, static_argnames=("out_block", "block_b", "interpret")
)
def grouped_log_einsum_exp_pallas(
    ws: Tuple[jax.Array, ...],
    x: jax.Array,
    out_block: int = 1,
    block_b: int = 128,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Fused multi-depth forward: one kernel launch for a canonical run.

    Args:
      ws: per-depth linear-domain weights, input side first; depth ``d`` has
        shape (L_out * 2^(G-1-d), K_out_d, K, K) with K_out_d == K for every
        interior depth (padded per ``ops.pad_group_for_lanes``).
      x: (B, L_out * 2^G, K) log-domain inputs of the first depth (left
        children rows [0, L_0), right children rows [L_0, 2 L_0)).
      out_block: output cells per program (``s``); must divide L_out.  The
        VMEM knob: each program's working set is the s / L_out fraction of
        the whole group.
      block_b: batch tile.
      interpret: None defers to backend dispatch (compiled on TPU, interpret
        elsewhere); an explicit bool pins the mode.

    Returns: (B, L_out, K_out_final) float32.
    """
    interpret = resolve_interpret(interpret)
    g, l_out, k, k_final = _group_geometry(ws, x)
    if l_out % out_block:
        raise ValueError(f"out_block {out_block} does not divide L_out {l_out}")
    b = x.shape[0]
    block_b = min(block_b, b)
    (x,) = _pad_batch(block_b, x)
    bp = x.shape[0]
    s = out_block
    grid = (l_out // s, bp // block_b)
    x_r = x.reshape(bp, 2 ** g, l_out, k)
    w_r = [
        w.reshape(2 ** (g - 1 - d), l_out, w.shape[1], k, k)
        for d, w in enumerate(ws)
    ]
    in_specs = [
        pl.BlockSpec(
            (2 ** (g - 1 - d), s, w_r[d].shape[2], k, k),
            lambda ti, bi: (0, ti, 0, 0, 0),
        )
        for d in range(g)
    ] + [pl.BlockSpec((block_b, 2 ** g, s, k), lambda ti, bi: (bi, 0, ti, 0))]
    out = pl.pallas_call(
        _make_fwd_kernel(g),
        out_shape=jax.ShapeDtypeStruct((bp, l_out, k_final), jnp.float32),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec(
            (block_b, s, k_final), lambda ti, bi: (bi, ti, 0)
        ),
        interpret=interpret,
    )(*w_r, x_r)
    return out[:b] if bp != b else out


@functools.partial(
    jax.jit, static_argnames=("out_block", "block_b", "interpret")
)
def grouped_log_einsum_exp_bwd_pallas(
    ws: Tuple[jax.Array, ...],
    x: jax.Array,
    g_out: jax.Array,
    out_block: int = 1,
    block_b: int = 128,
    interpret: Optional[bool] = None,
):
    """Fused multi-depth backward: dW for every depth + the input cotangent,
    one kernel launch.

    Args:
      ws / x / out_block / block_b / interpret: as in the forward (residuals
        are the unpadded primals; the caller re-pads).
      g_out: (B, L_out, K_out_final) cotangent of the group output.

    Returns: (gws tuple matching ``ws`` shapes, gx (B, L_out * 2^G, K)).
    """
    interpret = resolve_interpret(interpret)
    g, l_out, k, k_final = _group_geometry(ws, x)
    if l_out % out_block:
        raise ValueError(f"out_block {out_block} does not divide L_out {l_out}")
    b = x.shape[0]
    block_b = min(block_b, b)
    x, g_out = _pad_batch(block_b, x, g_out)
    bp = x.shape[0]
    s = out_block
    grid = (l_out // s, bp // block_b)
    x_r = x.reshape(bp, 2 ** g, l_out, k)
    w_r = [
        w.reshape(2 ** (g - 1 - d), l_out, w.shape[1], k, k)
        for d, w in enumerate(ws)
    ]
    in_specs = [
        pl.BlockSpec(
            (2 ** (g - 1 - d), s, w_r[d].shape[2], k, k),
            lambda ti, bi: (0, ti, 0, 0, 0),
        )
        for d in range(g)
    ] + [
        pl.BlockSpec((block_b, 2 ** g, s, k), lambda ti, bi: (bi, 0, ti, 0)),
        pl.BlockSpec((block_b, s, k_final), lambda ti, bi: (bi, ti, 0)),
    ]
    # dW blocks are (M/2, s, K_out, K, K) in (m, c)-major layout: block
    # index depends on ti only, so batch tiles (innermost axis) revisit and
    # accumulate into the same block
    gw_shapes = tuple(
        jax.ShapeDtypeStruct(
            (2 ** (g - 1 - d), l_out, w_r[d].shape[2], k, k), jnp.float32
        )
        for d in range(g)
    )
    gw_specs = tuple(
        pl.BlockSpec(
            (2 ** (g - 1 - d), s, w_r[d].shape[2], k, k),
            lambda ti, bi: (0, ti, 0, 0, 0),
        )
        for d in range(g)
    )
    outs = pl.pallas_call(
        _make_bwd_kernel(g),
        out_shape=gw_shapes
        + (jax.ShapeDtypeStruct((bp, 2 ** g, l_out, k), jnp.float32),),
        grid=grid,
        in_specs=in_specs,
        out_specs=gw_specs
        + (pl.BlockSpec((block_b, 2 ** g, s, k), lambda ti, bi: (bi, 0, ti, 0)),),
        interpret=interpret,
    )(*w_r, x_r, g_out)
    gws = tuple(
        gw.reshape(w.shape[0], w.shape[1], k, k) for gw, w in zip(outs[:g], ws)
    )
    gx = outs[g].reshape(bp, l_out * 2 ** g, k)
    return gws, gx[:b] if bp != b else gx
