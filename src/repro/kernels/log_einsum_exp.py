"""Fused log-einsum-exp Pallas TPU kernels: the paper's core op (Eq. 4/5),
forward and backward.

TPU adaptation of the paper's GPU einsum dispatch (DESIGN.md §2):

  * Per layer-node ``l``, the contraction ``W[l,k,i,j] el[b,i] er[b,j]`` is a
    ``(B_t, K^2) @ (K^2, K_out)`` matmul -- fed straight to the MXU.  The outer
    product ``el x er`` is formed in VMEM/registers and never written back to
    HBM: the paper's "products are never materialized", restated one level
    lower in the memory hierarchy.
  * The stabilization (per-row maxes, 2K exps, K logs -- the paper's op-count
    argument vs the naive K^3-exp implementation) runs on the VPU, fused into
    the same kernel, so the op makes exactly one pass over HBM: read
    ``ln_left``/``ln_right``/``W`` tiles, write the ``(B_t, K_out)`` output
    tile.
  * Grid = (L, B / B_t): layer-nodes are embarrassingly parallel; the batch is
    tiled so the working set  B_t*K^2 + K^2*K_out  floats stays within VMEM.
    For MXU efficiency K^2 and K_out must be padded to lane multiples of
    128; ``pad_for_lanes`` in ``ops.py`` handles padding/unpadding (K is
    rounded up to a multiple of 16 so K^2 lands on a 128 multiple, K_out to a
    full 128 lane; padded ln entries are -inf = log 0, padded weights 0, so
    the contraction is exact).

The backward kernel (``log_einsum_exp_bwd_pallas``) is the EM hot path: the
paper's E-step is one ``jax.grad`` over this op (§3.5), so training spends
most of its FLOPs here.  It re-derives the forward's stabilized frame from
the saved residuals -- the *same* NEG_INF clamp on the row maxes as the
forward (frame mismatch on saturated rows was a live bug, see tests), and
the stabilized sum ``s`` recomputed with the forward's own MXU contraction
so it is bit-identical to what the forward logged.  (Reconstructing
``s = exp(out - a - a')`` from the saved output is NOT exact: float32
swallows ``log s`` whenever ``|a + a'|`` is astronomically larger, e.g. on
fully-masked NEG_INF rows, skewing every gradient of that row.)  It then
emits all three gradients in one fused pass:

  dW[l,k,ij]    = sum_b  ginv[b,k] (el x er)[b,ij]   -- a (K_out, B_t) @
                  (B_t, K^2) MXU contraction, accumulated across batch tiles
                  by revisiting the same output block (batch is the innermost,
                  sequential grid axis);
  dln via  c[b,ij] = sum_k ginv[b,k] W[l,k,ij]       -- a (B_t, K_out) @
                  (K_out, K^2) MXU contraction, then VPU row/col reductions
                  of  c * (el x er)  give  dln_left / dln_right.

where ``ginv = g / s`` is the cotangent divided by the stabilized sum.  The
outer product appears once in VMEM and feeds all three contractions; nothing
K^2-sized ever touches HBM except dW itself.

Validated against autodiff of ``ref.log_einsum_exp_ref`` in interpret mode
(CPU) across shape/dtype sweeps -- see ``tests/test_kernels.py``.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.layers import NEG_INF
from repro.kernels.dispatch import resolve_interpret

# Floor for the stabilized sum when dividing the cotangent: s in (0, K^2] by
# construction, but fully-saturated rows can drive it to exactly 0.  Must be a
# NORMAL float32: XLA flushes subnormals to zero, so a 1e-38 floor becomes
# g / 0 = inf on saturated rows.  Any legitimate s is bounded below by the
# Laplace-floored weight of the row-argmax cell (>= 1e-12), far above this.
_S_FLOOR = 1e-30


def _stabilized_frame(ln_l, ln_r):
    """The forward's exact stabilization: clamped row maxes + exp'd inputs.

    The NEG_INF clamp is part of the op's definition (layers.py applies it in
    the XLA path too); forward and backward MUST share it so the backward's
    reconstructed ``s = exp(out - a - a')`` lives in the same frame the
    forward emitted ``out`` in.
    """
    a = jnp.maximum(jnp.max(ln_l, axis=-1, keepdims=True), NEG_INF)
    ap = jnp.maximum(jnp.max(ln_r, axis=-1, keepdims=True), NEG_INF)
    el = jnp.exp(ln_l - a)  # (B_t, K), VPU
    er = jnp.exp(ln_r - ap)
    return a, ap, el, er


def _fwd_kernel(w_ref, l_ref, r_ref, o_ref):
    ln_l = l_ref[:, 0, :]  # (B_t, K)
    ln_r = r_ref[:, 0, :]  # (B_t, K)
    a, ap, el, er = _stabilized_frame(ln_l, ln_r)
    bt, k = el.shape
    # outer product in VMEM: (B_t, K, K) -> (B_t, K^2); never leaves the chip
    prod = (el[:, :, None] * er[:, None, :]).reshape(bt, k * k)
    w = w_ref[0]  # (K_out, K, K)
    k_out = w.shape[0]
    wmat = w.reshape(k_out, k * k)
    s = jnp.dot(prod, wmat.T, preferred_element_type=jnp.float32)  # MXU
    o_ref[:, 0, :] = (a + ap + jnp.log(s)).astype(o_ref.dtype)


def _bwd_kernel(w_ref, l_ref, r_ref, g_ref, gw_ref, gl_ref, gr_ref):
    bi = pl.program_id(1)
    ln_l = l_ref[:, 0, :]  # (B_t, K)
    ln_r = r_ref[:, 0, :]
    a, ap, el, er = _stabilized_frame(ln_l, ln_r)
    g = g_ref[:, 0, :].astype(jnp.float32)
    bt, k = el.shape
    k_out = g.shape[-1]
    prod = (el[:, :, None] * er[:, None, :]).reshape(bt, k * k)
    wmat = w_ref[0].reshape(k_out, k * k)
    # the forward's stabilized sum, recomputed with the forward's exact
    # contraction (same operands, same MXU op -> bit-identical frame)
    s = jnp.dot(prod, wmat.T, preferred_element_type=jnp.float32)
    ginv = g / jnp.maximum(s, _S_FLOOR)  # (B_t, K_out)
    # dW: contract the batch tile away on the MXU -- (K_out, B_t) @ (B_t, K^2)
    gw_t = jax.lax.dot_general(
        ginv, prod, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).reshape(k_out, k, k)
    # dln: c = (B_t, K_out) @ (K_out, K^2) on the MXU, then VPU reductions
    c = jnp.dot(ginv, wmat, preferred_element_type=jnp.float32)
    c = c.reshape(bt, k, k)
    gl_ref[:, 0, :] = (el * jnp.sum(c * er[:, None, :], axis=2)).astype(
        gl_ref.dtype
    )
    gr_ref[:, 0, :] = (er * jnp.sum(c * el[:, :, None], axis=1)).astype(
        gr_ref.dtype
    )

    # batch tiles revisit the same (1, K_out, K, K) dW block: init then
    # accumulate (the batch axis is the innermost, sequential grid axis)
    @pl.when(bi == 0)
    def _init():
        gw_ref[0] = gw_t.astype(gw_ref.dtype)

    @pl.when(bi > 0)
    def _acc():
        gw_ref[0] += gw_t.astype(gw_ref.dtype)


def _pad_batch(block_b, *arrays):
    """Pad the leading batch axis of every array with zeros to a multiple of
    ``block_b``.  Zero rows are finite and harmless: the forward slices them
    off, and the backward sees zero cotangents there."""
    b = arrays[0].shape[0]
    pad_b = (-b) % block_b
    if not pad_b:
        return arrays
    return tuple(
        jnp.concatenate([x, jnp.zeros((pad_b,) + x.shape[1:], x.dtype)], 0)
        for x in arrays
    )


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def log_einsum_exp_pallas(
    w: jax.Array,
    ln_left: jax.Array,
    ln_right: jax.Array,
    block_b: int = 128,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Fused forward kernel entry point.

    Args:
      w:        (L, K_out, K, K) linear-domain weights.
      ln_left:  (B, L, K) log-domain inputs.
      ln_right: (B, L, K).
      block_b:  batch tile (the grid's inner parallel dim).
      interpret: None defers to backend dispatch (compiled on TPU, interpret
        elsewhere); an explicit bool pins the mode (CPU validation in tests).

    Returns: (B, L, K_out) float32.
    """
    interpret = resolve_interpret(interpret)
    b, l, k = ln_left.shape
    k_out = w.shape[1]
    block_b = min(block_b, b)
    ln_left, ln_right = _pad_batch(block_b, ln_left, ln_right)
    bp = ln_left.shape[0]
    grid = (l, bp // block_b)
    out = pl.pallas_call(
        _fwd_kernel,
        out_shape=jax.ShapeDtypeStruct((bp, l, k_out), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, k_out, k, k), lambda li, bi: (li, 0, 0, 0)),
            pl.BlockSpec((block_b, 1, k), lambda li, bi: (bi, li, 0)),
            pl.BlockSpec((block_b, 1, k), lambda li, bi: (bi, li, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, 1, k_out), lambda li, bi: (bi, li, 0)),
        interpret=interpret,
    )(w, ln_left, ln_right)
    return out[:b] if bp != b else out


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def log_einsum_exp_bwd_pallas(
    w: jax.Array,
    ln_left: jax.Array,
    ln_right: jax.Array,
    g: jax.Array,
    block_b: int = 128,
    interpret: Optional[bool] = None,
):
    """Fused backward kernel entry point (all three gradients in one pass).

    Args:
      w:        (L, K_out, K, K) linear-domain weights (forward residual).
      ln_left:  (B, L, K) log-domain inputs (forward residual).
      ln_right: (B, L, K).
      g:        (B, L, K_out) cotangent.
      block_b / interpret: as in the forward.

    Returns: (gw (L, K_out, K, K), gl (B, L, K), gr (B, L, K)), all float32.
    """
    interpret = resolve_interpret(interpret)
    b, l, k = ln_left.shape
    k_out = w.shape[1]
    block_b = min(block_b, b)
    ln_left, ln_right, g = _pad_batch(block_b, ln_left, ln_right, g)
    bp = ln_left.shape[0]
    grid = (l, bp // block_b)
    gw, gl, gr = pl.pallas_call(
        _bwd_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((l, k_out, k, k), jnp.float32),
            jax.ShapeDtypeStruct((bp, l, k), jnp.float32),
            jax.ShapeDtypeStruct((bp, l, k), jnp.float32),
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, k_out, k, k), lambda li, bi: (li, 0, 0, 0)),
            pl.BlockSpec((block_b, 1, k), lambda li, bi: (bi, li, 0)),
            pl.BlockSpec((block_b, 1, k), lambda li, bi: (bi, li, 0)),
            pl.BlockSpec((block_b, 1, k_out), lambda li, bi: (bi, li, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, k_out, k, k), lambda li, bi: (li, 0, 0, 0)),
            pl.BlockSpec((block_b, 1, k), lambda li, bi: (bi, li, 0)),
            pl.BlockSpec((block_b, 1, k), lambda li, bi: (bi, li, 0)),
        ),
        interpret=interpret,
    )(w, ln_left, ln_right, g)
    if bp != b:
        gl, gr = gl[:b], gr[:b]
    return gw, gl, gr
