"""Fused log-einsum-exp Pallas TPU kernel: the paper's core op (Eq. 4/5).

TPU adaptation of the paper's GPU einsum dispatch (DESIGN.md §2):

  * Per layer-node ``l``, the contraction ``W[l,k,i,j] el[b,i] er[b,j]`` is a
    ``(B_t, K^2) @ (K^2, K_out)`` matmul -- fed straight to the MXU.  The outer
    product ``el x er`` is formed in VMEM/registers and never written back to
    HBM: the paper's "products are never materialized", restated one level
    lower in the memory hierarchy.
  * The stabilization (per-row maxes, 2K exps, K logs -- the paper's op-count
    argument vs the naive K^3-exp implementation) runs on the VPU, fused into
    the same kernel, so the op makes exactly one pass over HBM: read
    ``ln_left``/``ln_right``/``W`` tiles, write the ``(B_t, K_out)`` output
    tile.
  * Grid = (L, B / B_t): layer-nodes are embarrassingly parallel; the batch is
    tiled so the working set  B_t*K^2 + K^2*K_out  floats stays within VMEM.
    For MXU efficiency K^2 and K_out must be padded to lane multiples of
    128; ``_pad_for_lanes`` in ``ops.py`` handles padding/unpadding (K is
    rounded up to a multiple of 16 so K^2 lands on a 128 multiple, K_out to a
    full 128 lane; padded ln entries are -inf = log 0, padded weights 0, so
    the contraction is exact).

Validated against ``ref.log_einsum_exp_ref`` in interpret mode (CPU) across
shape/dtype sweeps -- see ``tests/test_kernels.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.layers import NEG_INF


def _kernel(w_ref, l_ref, r_ref, o_ref):
    ln_l = l_ref[:, 0, :]  # (B_t, K)
    ln_r = r_ref[:, 0, :]  # (B_t, K)
    a = jnp.max(ln_l, axis=-1, keepdims=True)
    ap = jnp.max(ln_r, axis=-1, keepdims=True)
    a = jnp.maximum(a, NEG_INF)
    ap = jnp.maximum(ap, NEG_INF)
    el = jnp.exp(ln_l - a)  # (B_t, K), VPU
    er = jnp.exp(ln_r - ap)
    bt, k = el.shape
    # outer product in VMEM: (B_t, K, K) -> (B_t, K^2); never leaves the chip
    prod = (el[:, :, None] * er[:, None, :]).reshape(bt, k * k)
    w = w_ref[0]  # (K_out, K, K)
    k_out = w.shape[0]
    wmat = w.reshape(k_out, k * k)
    s = jnp.dot(prod, wmat.T, preferred_element_type=jnp.float32)  # MXU
    o_ref[:, 0, :] = (a + ap + jnp.log(s)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def log_einsum_exp_pallas(
    w: jax.Array,
    ln_left: jax.Array,
    ln_right: jax.Array,
    block_b: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Fused kernel entry point.

    Args:
      w:        (L, K_out, K, K) linear-domain weights.
      ln_left:  (B, L, K) log-domain inputs.
      ln_right: (B, L, K).
      block_b:  batch tile (the grid's inner parallel dim).
      interpret: run the kernel body in Python (CPU validation mode).

    Returns: (B, L, K_out) float32.
    """
    b, l, k = ln_left.shape
    k_out = w.shape[1]
    block_b = min(block_b, b)
    pad_b = (-b) % block_b
    if pad_b:
        # padded rows: ln = 0 everywhere is finite and harmless (sliced off)
        zeros = jnp.zeros((pad_b, l, k), ln_left.dtype)
        ln_left = jnp.concatenate([ln_left, zeros], 0)
        ln_right = jnp.concatenate([ln_right, zeros], 0)
    bp = ln_left.shape[0]
    grid = (l, bp // block_b)
    out = pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((bp, l, k_out), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, k_out, k, k), lambda li, bi: (li, 0, 0, 0)),
            pl.BlockSpec((block_b, 1, k), lambda li, bi: (bi, li, 0)),
            pl.BlockSpec((block_b, 1, k), lambda li, bi: (bi, li, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, 1, k_out), lambda li, bi: (bi, li, 0)),
        interpret=interpret,
    )(w, ln_left, ln_right)
    return out[:b] if pad_b else out
