"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantics the kernels must match (``tests/test_kernels.py``
asserts allclose against them across shape/dtype sweeps).  They are also the
XLA fallback paths used on non-TPU backends.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.layers import NEG_INF


def log_einsum_exp_ref(w: jax.Array, ln_left: jax.Array,
                       ln_right: jax.Array) -> jax.Array:
    """Paper Eq. (4)/(5): numerically-stable log einsum over (i, j).

    w: (L, K_out, K, K) linear-domain; ln_*: (B, L, K) log-domain.
    Returns (B, L, K_out).
    """
    a = jnp.maximum(jnp.max(ln_left, axis=-1, keepdims=True), NEG_INF)
    ap = jnp.maximum(jnp.max(ln_right, axis=-1, keepdims=True), NEG_INF)
    el = jnp.exp(ln_left - a)
    er = jnp.exp(ln_right - ap)
    s = jnp.einsum("lkij,bli,blj->blk", w, el, er)
    return a + ap + jnp.log(s)


def log_mix_exp_ref(v: jax.Array, ln: jax.Array, mask: jax.Array) -> jax.Array:
    """Mixing layer oracle: (M, C, K) x (B, M, C, K) -> (B, M, K)."""
    ln = jnp.where(mask[None, :, :, None] > 0, ln, NEG_INF)
    a = jnp.maximum(jnp.max(ln, axis=2, keepdims=True), NEG_INF)
    s = jnp.sum(v[None] * jnp.exp(ln - a), axis=2)
    return a[:, :, 0, :] + jnp.log(s)
