"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantics the kernels must match (``tests/test_kernels.py``
asserts allclose against them across shape/dtype sweeps).  They are also the
XLA fallback paths used on non-TPU backends.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.layers import NEG_INF


def log_einsum_exp_ref(w: jax.Array, ln_left: jax.Array,
                       ln_right: jax.Array) -> jax.Array:
    """Paper Eq. (4)/(5): numerically-stable log einsum over (i, j).

    w: (L, K_out, K, K) linear-domain; ln_*: (B, L, K) log-domain.
    Returns (B, L, K_out).
    """
    a = jnp.maximum(jnp.max(ln_left, axis=-1, keepdims=True), NEG_INF)
    ap = jnp.maximum(jnp.max(ln_right, axis=-1, keepdims=True), NEG_INF)
    el = jnp.exp(ln_left - a)
    er = jnp.exp(ln_right - ap)
    s = jnp.einsum("lkij,bli,blj->blk", w, el, er)
    return a + ap + jnp.log(s)


def mha_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    scale: Optional[float] = None,
) -> jax.Array:
    """Naive multi-head attention oracle.

    q: (B, Hq, Sq, Dh); k, v: (B, Hkv, Sk, Dh) with Hq % Hkv == 0 (GQA).
    Returns (B, Hq, Sq, Dh).
    """
    b, hq, sq, dh = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    if scale is None:
        scale = dh**-0.5
    group = hq // hkv
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    if causal:
        # decode-style offset: query block sits at the END of the kv sequence
        offset = sk - sq
        rows = jnp.arange(sq)[:, None] + offset
        cols = jnp.arange(sk)[None, :]
        s = jnp.where(cols <= rows, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v)


def log_mix_exp_ref(v: jax.Array, ln: jax.Array, mask: jax.Array) -> jax.Array:
    """Mixing layer oracle: (M, C, K) x (B, M, C, K) -> (B, M, K)."""
    ln = jnp.where(mask[None, :, :, None] > 0, ln, NEG_INF)
    a = jnp.maximum(jnp.max(ln, axis=2, keepdims=True), NEG_INF)
    s = jnp.sum(v[None] * jnp.exp(ln - a), axis=2)
    return a[:, :, 0, :] + jnp.log(s)
