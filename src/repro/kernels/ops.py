"""Jitted public wrappers for the Pallas kernels, with backend dispatch.

On TPU the Pallas kernels run compiled; on CPU (this container) they run in
interpret mode -- ``repro.kernels.dispatch`` is the single place that
decides, so kernel entry points never expose a CPU-validation default to
direct callers.  The pure-XLA reference paths in ``ref.py`` remain available
as the production fallback.

``log_einsum_exp`` carries a custom VJP wired to the fused backward kernel
in ``log_einsum_exp.py``: the forward saves (w, ln_left, ln_right) as
residuals, and the backward recomputes the forward's stabilized frame from
them bit-exactly (EXPERIMENTS.md §Perf, "EM via the fused backward").  Both
directions share one exact-padding contract (``pad_for_lanes``): K rounded
up to a multiple of 16, K_out to a 128 lane, padded ln entries -inf, padded
weights and cotangents 0, so padding changes no contraction bit-exactly and
gradients of padded lanes are identically zero.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.log_einsum_exp import (
    log_einsum_exp_bwd_pallas,
    log_einsum_exp_pallas,
)


# --------------------------------------------------------------------------
# log-einsum-exp: fused forward + fused backward (custom VJP)
# --------------------------------------------------------------------------
def pad_for_lanes(w, ln_left, ln_right, *kout_arrays):
    """Pad the contraction dims to MXU lane multiples of 128.

    The one padding contract shared by the forward and backward kernels:
    K is rounded up to a multiple of 16 (so the flattened K^2 product axis is
    a multiple of 256 >= one 128 lane), K_out to a full 128 lane.  Padded
    ``ln`` entries are -inf (= log 0, exp'd to exactly 0 inside the kernel)
    and padded weights are 0, so the padded contraction is bit-exact; callers
    slice the padding off the outputs (``unpad_lanes``).  Extra
    ``kout_arrays`` -- (B, L, K_out)-shaped tensors such as the saved forward
    output or the backward cotangent -- are zero-padded on the K_out lane
    (zeros are inert there: padded cotangent columns are zero, so the padded
    frame value never matters).
    """
    _, k_out, k, _ = w.shape
    k_p = -(-k // 16) * 16
    ko_p = -(-k_out // 128) * 128
    if (k_p, ko_p) == (k, k_out):
        return (w, ln_left, ln_right) + kout_arrays
    w = jnp.pad(w, ((0, 0), (0, ko_p - k_out), (0, k_p - k), (0, k_p - k)))
    lane = ((0, 0), (0, 0), (0, k_p - k))
    ln_left = jnp.pad(ln_left, lane, constant_values=-jnp.inf)
    ln_right = jnp.pad(ln_right, lane, constant_values=-jnp.inf)
    kout_lane = ((0, 0), (0, 0), (0, ko_p - k_out))
    padded = tuple(jnp.pad(x, kout_lane) for x in kout_arrays)
    return (w, ln_left, ln_right) + padded


@jax.custom_vjp
def log_einsum_exp(w: jax.Array, ln_left: jax.Array,
                   ln_right: jax.Array) -> jax.Array:
    k_out = w.shape[1]
    wp, lp, rp = pad_for_lanes(w, ln_left, ln_right)
    out = log_einsum_exp_pallas(wp, lp, rp)
    return out[..., :k_out]


def _lee_fwd(w, ln_left, ln_right):
    out = log_einsum_exp(w, ln_left, ln_right)
    # Residuals are the *unpadded* operands: the backward re-applies the
    # identical padding contract (cheap, fused into the same program) and
    # recomputes the stabilized frame bit-exactly, so nothing padded -- and
    # no forward output -- needs to live in residual memory.
    return out, (w, ln_left, ln_right)


def _lee_bwd(res, g):
    w, ln_l, ln_r = res
    _, k_out, k, _ = w.shape
    wp, lp, rp, gp = pad_for_lanes(w, ln_l, ln_r, g)
    gw, gl, gr = log_einsum_exp_bwd_pallas(wp, lp, rp, gp)
    return gw[:, :k_out, :k, :k], gl[..., :k], gr[..., :k]


log_einsum_exp.defvjp(_lee_fwd, _lee_bwd)


# --------------------------------------------------------------------------
# flash attention (GQA-aware wrapper)
# --------------------------------------------------------------------------
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
) -> jax.Array:
    """q: (B, Hq, Sq, Dh); k, v: (B, Hkv, Sk, Dh).  Returns (B, Hq, Sq, Dh)."""
    b, hq, sq, dh = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    if group > 1:
        k = jnp.repeat(k, group, axis=1)
        v = jnp.repeat(v, group, axis=1)
    qf = q.reshape(b * hq, sq, dh)
    kf = k.reshape(b * hq, -1, dh)
    vf = v.reshape(b * hq, -1, dh)
    out = flash_attention_pallas(
        qf, kf, vf, causal=causal, scale=scale, block_q=block_q,
        block_k=block_k,
    )
    return out.reshape(b, hq, sq, dh)


# re-export oracles for convenience
log_einsum_exp_ref = _ref.log_einsum_exp_ref
mha_ref = _ref.mha_ref
