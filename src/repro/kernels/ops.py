"""Jitted public wrappers for the Pallas kernels, with backend dispatch.

On TPU the Pallas kernels run compiled; on CPU (this container) they run in
interpret mode, and the pure-XLA reference paths in ``ref.py`` remain
available as the production fallback.  ``log_einsum_exp`` carries a custom
VJP so the kernelized forward still supports the paper's autodiff-EM (the
backward is expressed with plain einsums; a fused backward kernel is listed
as future work in EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.log_einsum_exp import log_einsum_exp_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# --------------------------------------------------------------------------
# log-einsum-exp: fused forward + einsum backward (custom VJP)
# --------------------------------------------------------------------------
def _pad_for_lanes(w, ln_left, ln_right):
    """Pad the contraction dims to MXU lane multiples of 128.

    K is rounded up to a multiple of 16 (so the flattened K^2 product axis is
    a multiple of 256 >= one 128 lane), K_out to a full 128 lane.  Padded
    ``ln`` entries are -inf (= log 0, exp'd to exactly 0 inside the kernel)
    and padded weights are 0, so the padded contraction is bit-exact; the
    caller slices the K_out padding off the output.
    """
    _, k_out, k, _ = w.shape
    k_p = -(-k // 16) * 16
    ko_p = -(-k_out // 128) * 128
    if (k_p, ko_p) == (k, k_out):
        return w, ln_left, ln_right
    w = jnp.pad(w, ((0, 0), (0, ko_p - k_out), (0, k_p - k), (0, k_p - k)))
    lane = ((0, 0), (0, 0), (0, k_p - k))
    ln_left = jnp.pad(ln_left, lane, constant_values=-jnp.inf)
    ln_right = jnp.pad(ln_right, lane, constant_values=-jnp.inf)
    return w, ln_left, ln_right


@jax.custom_vjp
def log_einsum_exp(w: jax.Array, ln_left: jax.Array,
                   ln_right: jax.Array) -> jax.Array:
    k_out = w.shape[1]
    wp, lp, rp = _pad_for_lanes(w, ln_left, ln_right)
    out = log_einsum_exp_pallas(wp, lp, rp, interpret=not _on_tpu())
    return out[..., :k_out]


def _lee_fwd(w, ln_left, ln_right):
    out = log_einsum_exp(w, ln_left, ln_right)
    return out, (w, ln_left, ln_right, out)


def _lee_bwd(res, g):
    w, ln_l, ln_r, out = res
    # d out[b,l,k] / d W[l,k,i,j]      = exp(ln_l_i + ln_r_j - out_k)
    # d out[b,l,k] / d ln_l[b,l,i]     = sum_j W[l,k,i,j] exp(ln_l_i + ln_r_j - out_k)
    # Work in the stabilized frame to avoid overflow (the maxes cancel exactly
    # in the analytic derivative, so this is just Eq. 4 re-applied backwards):
    a = jnp.max(ln_l, axis=-1, keepdims=True)
    ap = jnp.max(ln_r, axis=-1, keepdims=True)
    eln = jnp.exp(ln_l - a)
    ern = jnp.exp(ln_r - ap)
    # s[b,l,k] = exp(out - a - ap)
    s = jnp.exp(out - a - ap)
    ginv = g / jnp.maximum(s, 1e-38)  # (B, L, K_out)
    gw = jnp.einsum("blk,bli,blj->lkij", ginv, eln, ern)
    gl = jnp.einsum("blk,lkij,blj->bli", ginv, w, ern) * eln
    gr = jnp.einsum("blk,lkij,bli->blj", ginv, w, eln) * ern
    return gw, gl, gr


log_einsum_exp.defvjp(_lee_fwd, _lee_bwd)


# --------------------------------------------------------------------------
# flash attention (GQA-aware wrapper)
# --------------------------------------------------------------------------
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
) -> jax.Array:
    """q: (B, Hq, Sq, Dh); k, v: (B, Hkv, Sk, Dh).  Returns (B, Hq, Sq, Dh)."""
    b, hq, sq, dh = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    if group > 1:
        k = jnp.repeat(k, group, axis=1)
        v = jnp.repeat(v, group, axis=1)
    qf = q.reshape(b * hq, sq, dh)
    kf = k.reshape(b * hq, -1, dh)
    vf = v.reshape(b * hq, -1, dh)
    out = flash_attention_pallas(
        qf, kf, vf, causal=causal, scale=scale, block_q=block_q,
        block_k=block_k, interpret=not _on_tpu(),
    )
    return out.reshape(b, hq, sq, dh)


# re-export oracles for convenience
log_einsum_exp_ref = _ref.log_einsum_exp_ref
mha_ref = _ref.mha_ref
