"""Jitted public wrappers for the Pallas kernels, with backend dispatch.

On TPU the Pallas kernels run compiled; on CPU (this container) they run in
interpret mode -- ``repro.kernels.dispatch`` is the single place that
decides, so kernel entry points never expose a CPU-validation default to
direct callers.  The pure-XLA reference paths in ``ref.py`` remain available
as the production fallback.

``log_einsum_exp`` carries a custom VJP wired to the fused backward kernel
in ``log_einsum_exp.py``: the forward saves (w, ln_left, ln_right) as
residuals, and the backward recomputes the forward's stabilized frame from
them bit-exactly (EXPERIMENTS.md §Perf, "EM via the fused backward").  Both
directions share one exact-padding contract (``pad_for_lanes``): K rounded
up to a multiple of 16, K_out to a 128 lane, padded ln entries -inf, padded
weights and cotangents 0, so padding changes no contraction bit-exactly and
gradients of padded lanes are identically zero.

``grouped_log_einsum_exp`` is the whole-subcircuit form (``grouped.py``):
one custom-VJP op covering a RUN of consecutive canonical depths, with the
same residual-recompute contract extended group-wide
(``pad_group_for_lanes``); it is what ``EiNet`` dispatches fused execution
segments to when ``impl == "pallas"``.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.grouped import (
    grouped_log_einsum_exp_bwd_pallas,
    grouped_log_einsum_exp_pallas,
)
from repro.kernels.log_einsum_exp import (
    log_einsum_exp_bwd_pallas,
    log_einsum_exp_pallas,
)


# --------------------------------------------------------------------------
# log-einsum-exp: fused forward + fused backward (custom VJP)
# --------------------------------------------------------------------------
def pad_for_lanes(w, ln_left, ln_right, *kout_arrays):
    """Pad the contraction dims to MXU lane multiples of 128.

    The one padding contract shared by the forward and backward kernels:
    K is rounded up to a multiple of 16 (so the flattened K^2 product axis is
    a multiple of 256 >= one 128 lane), K_out to a full 128 lane.  Padded
    ``ln`` entries are -inf (= log 0, exp'd to exactly 0 inside the kernel)
    and padded weights are 0, so the padded contraction is bit-exact; callers
    slice the padding off the outputs (``unpad_lanes``).  Extra
    ``kout_arrays`` -- (B, L, K_out)-shaped tensors such as the saved forward
    output or the backward cotangent -- are zero-padded on the K_out lane
    (zeros are inert there: padded cotangent columns are zero, so the padded
    frame value never matters).
    """
    _, k_out, k, _ = w.shape
    k_p = -(-k // 16) * 16
    ko_p = -(-k_out // 128) * 128
    if (k_p, ko_p) == (k, k_out):
        return (w, ln_left, ln_right) + kout_arrays
    w = jnp.pad(w, ((0, 0), (0, ko_p - k_out), (0, k_p - k), (0, k_p - k)))
    lane = ((0, 0), (0, 0), (0, k_p - k))
    ln_left = jnp.pad(ln_left, lane, constant_values=-jnp.inf)
    ln_right = jnp.pad(ln_right, lane, constant_values=-jnp.inf)
    kout_lane = ((0, 0), (0, 0), (0, ko_p - k_out))
    padded = tuple(jnp.pad(x, kout_lane) for x in kout_arrays)
    return (w, ln_left, ln_right) + padded


@jax.custom_vjp
def log_einsum_exp(w: jax.Array, ln_left: jax.Array,
                   ln_right: jax.Array) -> jax.Array:
    k_out = w.shape[1]
    wp, lp, rp = pad_for_lanes(w, ln_left, ln_right)
    out = log_einsum_exp_pallas(wp, lp, rp)
    return out[..., :k_out]


def _lee_fwd(w, ln_left, ln_right):
    out = log_einsum_exp(w, ln_left, ln_right)
    # Residuals are the *unpadded* operands: the backward re-applies the
    # identical padding contract (cheap, fused into the same program) and
    # recomputes the stabilized frame bit-exactly, so nothing padded -- and
    # no forward output -- needs to live in residual memory.
    return out, (w, ln_left, ln_right)


def _lee_bwd(res, g):
    w, ln_l, ln_r = res
    _, k_out, k, _ = w.shape
    wp, lp, rp, gp = pad_for_lanes(w, ln_l, ln_r, g)
    gw, gl, gr = log_einsum_exp_bwd_pallas(wp, lp, rp, gp)
    return gw[:, :k_out, :k, :k], gl[..., :k], gr[..., :k]


log_einsum_exp.defvjp(_lee_fwd, _lee_bwd)


# --------------------------------------------------------------------------
# grouped log-einsum-exp: one op per fused execution segment (custom VJP)
# --------------------------------------------------------------------------
def pad_group_for_lanes(ws, x, g_out=None):
    """``pad_for_lanes`` extended to a canonical run of depths.

    K pads to a multiple of 16 with -inf input lanes / zero weights, exactly
    as in the per-layer contract.  INTERIOR depths pad K_out to the padded K
    (their outputs are the next depth's inputs): padded weight rows are
    zero, so padded output lanes evaluate ``a + a' + log(0) = -inf`` inside
    the kernel -- precisely the -inf padding the next depth's input lanes
    need, making group padding self-consistent with no per-depth fixups.
    Only the final depth pads K_out to a full 128 lane; ``g_out`` (the
    backward cotangent) zero-pads on that lane.
    """
    k = ws[0].shape[-1]
    k_p = -(-k // 16) * 16
    ws_p = []
    for d, w in enumerate(ws):
        ko = w.shape[1]
        ko_p = k_p if d < len(ws) - 1 else -(-ko // 128) * 128
        ws_p.append(
            jnp.pad(w, ((0, 0), (0, ko_p - ko), (0, k_p - k), (0, k_p - k)))
            if (ko_p, k_p) != (ko, k) else w
        )
    x_p = (
        jnp.pad(x, ((0, 0), (0, 0), (0, k_p - k)), constant_values=-jnp.inf)
        if k_p != k else x
    )
    if g_out is None:
        return tuple(ws_p), x_p
    ko = ws[-1].shape[1]
    ko_p = -(-ko // 128) * 128
    g_p = (
        jnp.pad(g_out, ((0, 0), (0, 0), (0, ko_p - ko)))
        if ko_p != ko else g_out
    )
    return tuple(ws_p), x_p, g_p


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def grouped_log_einsum_exp(
    out_block: int,
    block_b: int,
    ws: Tuple[jax.Array, ...],
    x: jax.Array,
) -> jax.Array:
    """Whole-subcircuit log-einsum-exp over a canonical depth run.

    Args:
      out_block / block_b: static tiling (chosen by ``EiNet._plan_groups``).
      ws: per-depth unpadded weights, input side first; depth ``d`` is
        (L_out * 2^(G-1-d), K_out_d, K, K), interior K_out_d == K.
      x: (B, L_out * 2^G, K) log-domain first-depth inputs.

    Returns: (B, L_out, K_out_final) log-domain outputs of the last depth.
    """
    k_final = ws[-1].shape[1]
    wp, xp = pad_group_for_lanes(ws, x)
    out = grouped_log_einsum_exp_pallas(
        wp, xp, out_block=out_block, block_b=block_b
    )
    return out[..., :k_final]


def _glee_fwd(out_block, block_b, ws, x):
    out = grouped_log_einsum_exp(out_block, block_b, ws, x)
    # same residual contract as the per-layer op: save the unpadded primals,
    # re-pad in the backward, recompute every depth's frame in VMEM
    return out, (tuple(ws), x)


def _glee_bwd(out_block, block_b, res, g):
    ws, x = res
    k = x.shape[-1]
    wp, xp, gp = pad_group_for_lanes(ws, x, g)
    gws, gx = grouped_log_einsum_exp_bwd_pallas(
        wp, xp, gp, out_block=out_block, block_b=block_b
    )
    gws = tuple(
        gw[:, : w.shape[1], :k, :k] for gw, w in zip(gws, ws)
    )
    return gws, gx[..., :k]


grouped_log_einsum_exp.defvjp(_glee_fwd, _glee_bwd)


# re-export the oracle for convenience
log_einsum_exp_ref = _ref.log_einsum_exp_ref
