"""Jitted public wrappers for the Pallas kernels, with backend dispatch.

On TPU the Pallas kernels run compiled; on CPU (this container) they run in
interpret mode -- ``repro.kernels.dispatch`` is the single place that
decides, so kernel entry points never expose a CPU-validation default to
direct callers.  The pure-XLA reference paths in ``ref.py`` remain available
as the production fallback.

``log_einsum_exp`` carries a custom VJP wired to the fused backward kernel
in ``log_einsum_exp.py``: the forward saves (w, ln_left, ln_right) as
residuals, and the backward recomputes the forward's stabilized frame from
them bit-exactly (EXPERIMENTS.md §Perf, "EM via the fused backward").  Both
directions share one exact-padding contract (``pad_for_lanes``): K rounded
up to a multiple of 16, K_out to a 128 lane, padded ln entries -inf, padded
weights and cotangents 0, so padding changes no contraction bit-exactly and
gradients of padded lanes are identically zero.

``grouped_log_einsum_exp`` is the whole-subcircuit form (``grouped.py``):
one custom-VJP op covering a RUN of consecutive canonical depths, with the
same residual-recompute contract extended group-wide; it is what ``EiNet``
dispatches canonical fused execution segments to when ``impl == "pallas"``.
``gather_grouped_log_einsum_exp`` is its gather-topology sibling: the op
additionally carries static ``core.plan.GatherTables`` (non-diff, baked
into the kernel) plus per-depth mixing weights, and returns every new row
of the run's global row buffer.

All three ops share ONE padding contract, ``pad_to_lanes``: K rounds up to
a multiple of 16, the terminal output lane to 128 when the run ends at a
root (``final=True``) and to the padded K when the run is all-interior
(gather runs -- their outputs feed later gathers at width K).  Log-domain
arrays pad with -inf, weights and cotangents with 0, so padding changes no
contraction and gradients of padded lanes are identically zero.  The
legacy entry points (``pad_for_lanes``, ``pad_group_for_lanes``) and the
gather form (``pad_gather_for_lanes``) are thin views of this contract.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.grouped import (
    gather_grouped_log_einsum_exp_bwd_pallas,
    gather_grouped_log_einsum_exp_pallas,
    grouped_log_einsum_exp_bwd_pallas,
    grouped_log_einsum_exp_pallas,
)
from repro.kernels.log_einsum_exp import (
    log_einsum_exp_bwd_pallas,
    log_einsum_exp_pallas,
)


# --------------------------------------------------------------------------
# the one lane-padding contract (shared by all kernel entry points)
# --------------------------------------------------------------------------
def pad_to_lanes(ws, logs=(), zeros=(), final=True):
    """Pad every kernel operand class to MXU lane multiples, one contract.

    K (the shared sum-node width, last dim of every weight) rounds up to a
    multiple of 16, so the flattened K^2 product axis is a multiple of
    256 >= one 128 lane.  The run's terminal output width -- K_out of the
    LAST depth -- rounds up to a full 128 lane when ``final=True`` (the run
    ends at a root whose outputs leave the kernel stack) and to the padded
    K when ``final=False`` (all-interior gather runs: outputs re-enter
    later depths at width K).  Interior depths always pad K_out to the
    padded K: their padded weight rows are zero, so padded output lanes
    evaluate ``a + a' + log(0) = -inf`` inside the kernel -- precisely the
    -inf padding the next depth's input lanes need, making run padding
    self-consistent with no per-depth fixups.

    Args:
      ws: per-depth weights, each (..., K_out_d, K, K); padded with zeros.
      logs: log-domain arrays (..., K); padded with -inf on the last dim
        (= log 0, exp'd to exactly 0 inside the kernel).
      zeros: linear-domain arrays padded with zeros on the last dim to the
        terminal output width -- saved outputs / backward cotangents
        (..., K_out) and gather mixing weights (M, C, K).  Zeros are inert:
        padded cotangent columns are zero, so the padded frame value never
        matters, and gradients of padded lanes are identically zero.

    Returns ``(ws_p, logs_p, zeros_p)`` as tuples; arrays already on lane
    boundaries are returned unchanged.
    """
    k = ws[0].shape[-1]
    k_p = -(-k // 16) * 16
    k_out = ws[-1].shape[1]
    out_p = -(-k_out // 128) * 128 if final else k_p
    ws_p = []
    for d, w in enumerate(ws):
        kd = w.shape[1]
        kd_p = out_p if d == len(ws) - 1 else k_p
        ws_p.append(
            jnp.pad(
                w,
                ((0, 0),) * (w.ndim - 3)
                + ((0, kd_p - kd), (0, k_p - k), (0, k_p - k)),
            )
            if (kd_p, k_p) != (kd, k) else w
        )
    logs_p = tuple(
        jnp.pad(
            a,
            ((0, 0),) * (a.ndim - 1) + ((0, k_p - a.shape[-1]),),
            constant_values=-jnp.inf,
        )
        if a.shape[-1] != k_p else a
        for a in logs
    )
    zeros_p = tuple(
        jnp.pad(a, ((0, 0),) * (a.ndim - 1) + ((0, out_p - a.shape[-1]),))
        if a.shape[-1] != out_p else a
        for a in zeros
    )
    return tuple(ws_p), logs_p, zeros_p


# --------------------------------------------------------------------------
# log-einsum-exp: fused forward + fused backward (custom VJP)
# --------------------------------------------------------------------------
def pad_for_lanes(w, ln_left, ln_right, *kout_arrays):
    """Per-layer view of ``pad_to_lanes``: one depth, root-width output.

    Extra ``kout_arrays`` -- (B, L, K_out)-shaped tensors such as the saved
    forward output or the backward cotangent -- are zero-padded on the
    K_out lane.
    """
    (w_p,), logs_p, zeros_p = pad_to_lanes(
        (w,), logs=(ln_left, ln_right), zeros=kout_arrays
    )
    return (w_p,) + logs_p + zeros_p


@jax.custom_vjp
def log_einsum_exp(w: jax.Array, ln_left: jax.Array,
                   ln_right: jax.Array) -> jax.Array:
    k_out = w.shape[1]
    wp, lp, rp = pad_for_lanes(w, ln_left, ln_right)
    out = log_einsum_exp_pallas(wp, lp, rp)
    return out[..., :k_out]


def _lee_fwd(w, ln_left, ln_right):
    out = log_einsum_exp(w, ln_left, ln_right)
    # Residuals are the *unpadded* operands: the backward re-applies the
    # identical padding contract (cheap, fused into the same program) and
    # recomputes the stabilized frame bit-exactly, so nothing padded -- and
    # no forward output -- needs to live in residual memory.
    return out, (w, ln_left, ln_right)


def _lee_bwd(res, g):
    w, ln_l, ln_r = res
    _, k_out, k, _ = w.shape
    wp, lp, rp, gp = pad_for_lanes(w, ln_l, ln_r, g)
    gw, gl, gr = log_einsum_exp_bwd_pallas(wp, lp, rp, gp)
    return gw[:, :k_out, :k, :k], gl[..., :k], gr[..., :k]


log_einsum_exp.defvjp(_lee_fwd, _lee_bwd)


# --------------------------------------------------------------------------
# grouped log-einsum-exp: one op per fused execution segment (custom VJP)
# --------------------------------------------------------------------------
def pad_group_for_lanes(ws, x, g_out=None):
    """Canonical-run view of ``pad_to_lanes``: interior depths keep the
    padded K, only the final depth widens to a 128 lane; ``g_out`` (the
    backward cotangent) zero-pads on that lane."""
    zeros = () if g_out is None else (g_out,)
    ws_p, (x_p,), zeros_p = pad_to_lanes(ws, logs=(x,), zeros=zeros)
    if g_out is None:
        return ws_p, x_p
    return ws_p, x_p, zeros_p[0]


def pad_gather_for_lanes(ws, vs, x, g_out=None):
    """Gather-run view of ``pad_to_lanes``: every depth is interior
    (``final=False``), so weights, mixing weights, the row buffer and the
    cotangent all stay on the padded-K lane."""
    zeros = tuple(vs) + (() if g_out is None else (g_out,))
    ws_p, (x_p,), zeros_p = pad_to_lanes(
        ws, logs=(x,), zeros=zeros, final=False
    )
    vs_p = zeros_p[: len(vs)]
    if g_out is None:
        return ws_p, vs_p, x_p
    return ws_p, vs_p, x_p, zeros_p[-1]


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def grouped_log_einsum_exp(
    out_block: int,
    block_b: int,
    ws: Tuple[jax.Array, ...],
    x: jax.Array,
) -> jax.Array:
    """Whole-subcircuit log-einsum-exp over a canonical depth run.

    Args:
      out_block / block_b: static tiling (chosen by ``core.plan``).
      ws: per-depth unpadded weights, input side first; depth ``d`` is
        (L_out * 2^(G-1-d), K_out_d, K, K), interior K_out_d == K.
      x: (B, L_out * 2^G, K) log-domain first-depth inputs.

    Returns: (B, L_out, K_out_final) log-domain outputs of the last depth.
    """
    k_final = ws[-1].shape[1]
    wp, xp = pad_group_for_lanes(ws, x)
    out = grouped_log_einsum_exp_pallas(
        wp, xp, out_block=out_block, block_b=block_b
    )
    return out[..., :k_final]


def _glee_fwd(out_block, block_b, ws, x):
    out = grouped_log_einsum_exp(out_block, block_b, ws, x)
    # same residual contract as the per-layer op: save the unpadded primals,
    # re-pad in the backward, recompute every depth's frame in VMEM
    return out, (tuple(ws), x)


def _glee_bwd(out_block, block_b, res, g):
    ws, x = res
    k = x.shape[-1]
    wp, xp, gp = pad_group_for_lanes(ws, x, g)
    gws, gx = grouped_log_einsum_exp_bwd_pallas(
        wp, xp, gp, out_block=out_block, block_b=block_b
    )
    gws = tuple(
        gw[:, : w.shape[1], :k, :k] for gw, w in zip(gws, ws)
    )
    return gws, gx[..., :k]


grouped_log_einsum_exp.defvjp(_glee_fwd, _glee_bwd)


# --------------------------------------------------------------------------
# gather-grouped log-einsum-exp: one op per gather segment (custom VJP)
# --------------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def gather_grouped_log_einsum_exp(
    tables,
    block_b: int,
    ws: Tuple[jax.Array, ...],
    vs: Tuple[jax.Array, ...],
    x: jax.Array,
) -> jax.Array:
    """Whole-subcircuit log-einsum-exp over a gather-topology depth run.

    Args:
      tables: static ``core.plan.GatherTables`` (per-depth child-row and
        mixing tables, baked into the kernel as compile-time constants).
      block_b: static batch tile (chosen by ``core.plan.plan_circuit``).
      ws: per-depth unpadded einsum weights, (L_t, K, K, K) each (every
        depth in a gather run is interior: K_out == K).
      vs: mixing weights for the run's mixing depths in depth order,
        (M_t, C_t, K) each.
      x: (B, r_in, K) log-domain global row buffer below the run.

    Returns: (B, r_new, K) -- every new buffer row the run emits (einsum
    rows then mixing rows per depth, in global row order).
    """
    k = x.shape[-1]
    wp, vp, xp = pad_gather_for_lanes(ws, vs, x)
    out = gather_grouped_log_einsum_exp_pallas(
        tables, wp, vp, xp, block_b=block_b
    )
    return out[..., :k]


def _gg_fwd(tables, block_b, ws, vs, x):
    out = gather_grouped_log_einsum_exp(tables, block_b, ws, vs, x)
    # same residual contract as the canonical ops: save the unpadded
    # primals, re-pad in the backward, recompute every depth's frame in VMEM
    return out, (tuple(ws), tuple(vs), x)


def _gg_bwd(tables, block_b, res, g):
    ws, vs, x = res
    k = x.shape[-1]
    wp, vp, xp, gp = pad_gather_for_lanes(ws, vs, x, g)
    gws, gvs, gx = gather_grouped_log_einsum_exp_bwd_pallas(
        tables, wp, vp, xp, gp, block_b=block_b
    )
    gws = tuple(gw[:, :k, :k, :k] for gw in gws)
    gvs = tuple(gv[..., :k] for gv in gvs)
    return gws, gvs, gx[..., :k]


gather_grouped_log_einsum_exp.defvjp(_gg_fwd, _gg_bwd)


# re-export the oracle for convenience
log_einsum_exp_ref = _ref.log_einsum_exp_ref
