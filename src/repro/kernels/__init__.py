"""Pallas TPU kernels for the EiNet hot-spots (+ jnp oracles).

  * ``log_einsum_exp`` -- the paper's core op (Eq. 4/5): fused
    max/exp/matmul/log, one (product, sum) pair per launch.
  * ``grouped_log_einsum_exp`` -- the whole-subcircuit form: a run of
    consecutive canonical pairs fused into ONE launch, intermediate
    log-activations resident in VMEM (``grouped.py``).

Kernels run compiled on TPU and in interpret mode on CPU; ``ref.py`` holds
the pure-jnp oracles that define their semantics.
"""

from repro.kernels import dispatch, grouped, ops, ref
from repro.kernels.ops import (
    grouped_log_einsum_exp,
    log_einsum_exp,
    pad_for_lanes,
    pad_group_for_lanes,
)

__all__ = [
    "dispatch", "grouped", "ops", "ref", "grouped_log_einsum_exp",
    "log_einsum_exp", "pad_for_lanes", "pad_group_for_lanes",
]
