"""Pallas TPU kernels for the performance hot-spots (+ jnp oracles).

  * ``log_einsum_exp`` -- the paper's core op (Eq. 4/5): fused max/exp/matmul/log.
  * ``flash_attention`` -- online-softmax attention for the LM substrate.

Kernels run compiled on TPU and in interpret mode on CPU; ``ref.py`` holds the
pure-jnp oracles that define their semantics.
"""

from repro.kernels import dispatch, ops, ref
from repro.kernels.ops import flash_attention, log_einsum_exp, pad_for_lanes

__all__ = [
    "dispatch", "ops", "ref", "flash_attention", "log_einsum_exp",
    "pad_for_lanes",
]
