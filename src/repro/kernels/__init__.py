"""Pallas TPU kernels for the EiNet hot-spots (+ jnp oracles).

  * ``log_einsum_exp`` -- the paper's core op (Eq. 4/5): fused
    max/exp/matmul/log, one (product, sum) pair per launch.
  * ``grouped_log_einsum_exp`` -- the whole-subcircuit form: a run of
    consecutive canonical pairs fused into ONE launch, intermediate
    log-activations resident in VMEM (``grouped.py``).
  * ``gather_grouped_log_einsum_exp`` -- the gather-topology form: a run
    of Poon-Domingos pairs whose child access goes through static
    ``core.plan.GatherTables``, mixing layers fused in-kernel.

Kernels run compiled on TPU and in interpret mode on CPU; ``ref.py`` holds
the pure-jnp oracles that define their semantics.
"""

from repro.kernels import dispatch, grouped, ops, ref
from repro.kernels.ops import (
    gather_grouped_log_einsum_exp,
    grouped_log_einsum_exp,
    log_einsum_exp,
    pad_for_lanes,
    pad_gather_for_lanes,
    pad_group_for_lanes,
    pad_to_lanes,
)

__all__ = [
    "dispatch", "grouped", "ops", "ref", "gather_grouped_log_einsum_exp",
    "grouped_log_einsum_exp", "log_einsum_exp", "pad_for_lanes",
    "pad_gather_for_lanes", "pad_group_for_lanes", "pad_to_lanes",
]
