"""Sharded, atomic, asynchronous checkpointing (no orbax in this container).

Layout:  <dir>/step_<N>/
            meta.json            -- treedef paths, shapes, dtypes, step
            shard_<p>.npz        -- this process's addressable array shards

Guarantees:
  * atomic commit: writes go to ``step_<N>.tmp`` and are renamed only after
    fsync -- a killed writer never corrupts the latest checkpoint.
  * restore picks the newest *committed* step (ignores .tmp debris).
  * optional async writer thread: the train loop donates a host copy and
    continues; ``wait()`` joins before the next save or at exit.
  * multi-host: each process saves only the shards it owns
    (``process_index`` in the shard filename); restore re-assembles per-host.
    On this single-process container that degenerates to one shard file.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree: Any):
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)
    flat, treedef = leaves_with_paths
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in p)
             for p, _ in flat]
    leaves = [l for _, l in flat]
    return paths, leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_write: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_write = async_write
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Any) -> None:
        self.wait()
        # snapshot to host memory synchronously (cheap), write async
        paths, leaves, _ = _flatten(tree)
        host = [np.asarray(jax.device_get(l)) for l in leaves]
        if self.async_write:
            self._thread = threading.Thread(
                target=self._write, args=(step, paths, host), daemon=True
            )
            self._thread.start()
        else:
            self._write(step, paths, host)

    def _write(self, step: int, paths: List[str], host: List[np.ndarray]) -> None:
        try:
            proc = jax.process_index()
            final = os.path.join(self.directory, f"step_{step:08d}")
            tmp = final + ".tmp"
            os.makedirs(tmp, exist_ok=True)
            meta = {
                "step": step,
                "paths": paths,
                "shapes": [list(a.shape) for a in host],
                "dtypes": [str(a.dtype) for a in host],
                "num_processes": jax.process_count(),
            }
            np.savez(os.path.join(tmp, f"shard_{proc}.npz"),
                     **{f"a{i}": a for i, a in enumerate(host)})
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta, f)
                f.flush()
                os.fsync(f.fileno())
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)  # atomic commit
            self._gc()
        except BaseException as e:  # surfaced on next wait()
            self._error = e

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.directory, name, "meta.json")):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, tree_like: Any, step: Optional[int] = None
                ) -> Tuple[int, Any]:
        """Restore into the structure of ``tree_like`` (values ignored)."""
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        d = os.path.join(self.directory, f"step_{step:08d}")
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)
        proc = jax.process_index()
        data = np.load(os.path.join(d, f"shard_{proc}.npz"))
        arrays = [data[f"a{i}"] for i in range(len(meta["paths"]))]
        paths, leaves, treedef = _flatten(tree_like)
        assert paths == meta["paths"], (
            "checkpoint tree mismatch:\n"
            f"  want {paths[:5]}...\n  have {meta['paths'][:5]}..."
        )
        restored = []
        for ref, arr in zip(leaves, arrays):
            arr = arr.astype(ref.dtype) if hasattr(ref, "dtype") else arr
            if hasattr(ref, "sharding"):
                arr = jax.device_put(arr, ref.sharding)
            restored.append(arr)
        return step, jax.tree_util.tree_unflatten(treedef, restored)
