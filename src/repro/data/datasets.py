"""Real-image datasets (MNIST, SVHN, CelebA) behind the ``ShardedLoader``
contract.

The paper's generative-image experiments (§4.2, Fig. 4) run on MNIST, SVHN
and CelebA; this module supplies those inputs to the training/serving stack
without changing any loader plumbing: every dataset resolves to plain numpy
arrays plus a ``make_batch(step, shard, per_host)`` function -- the same
stateless contract ``repro.data.pipeline.ShardedLoader`` already enforces for
synthetic data, so restart/straggler recovery and disjoint sharding come for
free.

Three sources, resolved in order by :func:`load_image_dataset`:

  1. **npz cache** (``<data_dir>/<name>.npz``) -- one file per dataset, raw
     uint8 + labels, written once after the first download.
  2. **download** -- urllib against the canonical mirrors (MNIST IDX files,
     SVHN .mat via ``scipy.io``; CelebA has no anonymous mirror, so its
     "download" builds the cache from a locally provided raw copy -- see
     ``_fetch_celeba``).  Never attempted when ``source="procedural"``.
  3. **procedural fallback** -- a deterministic generator with the *same
     shapes, dtypes, splits and API* as the real dataset (class-conditional
     bump templates + jitter, quantized to uint8), so tests, CI and the
     ``--smoke`` paths never need network and still exercise every byte of
     the image plumbing.

Leaf-family domain transforms (:func:`to_domain`) map raw uint8 to the input
domain each exponential family models, and carry the change-of-variables
offset that :func:`repro.eval.metrics.bits_per_dim` needs:

  * ``normal``      -- x / 255 in [0, 1]; bpd offset log2(256) = 8 bits/dim
                       (the paper's continuous treatment of 8-bit data).
  * ``binomial``    -- raw counts 0..255 (N=255 trials); discrete, offset 0.
  * ``categorical`` -- raw levels 0..255; discrete, offset 0.
"""

from __future__ import annotations

import dataclasses
import gzip
import os
import struct
import urllib.request
import zlib
from typing import Dict, Optional, Tuple

import numpy as np

from repro.data.pipeline import ShardedLoader

DEFAULT_DATA_DIR = "artifacts/datasets"

# fraction of the train split carved off (deterministically, from the end)
# as the validation split -- the paper's protocol of model selection on
# held-out data without touching the test set.
VALID_FRACTION = 0.1


@dataclasses.dataclass(frozen=True)
class ImageSpec:
    """Static description of one image dataset."""

    name: str
    height: int
    width: int
    channels: int
    num_classes: int
    train_size: int  # canonical sizes (procedural fallback matches them
    test_size: int   # scaled down via the ``size_cap`` argument)

    @property
    def num_dims(self) -> int:
        return self.height * self.width * self.channels


SPECS: Dict[str, ImageSpec] = {
    "mnist": ImageSpec("mnist", 28, 28, 1, 10, 60_000, 10_000),
    "svhn": ImageSpec("svhn", 32, 32, 3, 10, 73_257, 26_032),
    # §4.2's mixture-of-EiNets dataset, center-cropped + downsampled to a
    # 32x32 PD grid (aligned CelebA is 178x218; the paper downsamples too).
    # CelebA has no class label; num_classes=1 (the attribute table is not
    # part of the density-estimation protocol).  Sizes follow the standard
    # partition file (train 162,770 / valid 19,867 / test 19,962).
    "celeba": ImageSpec("celeba", 32, 32, 3, 1, 162_770, 19_962),
}

# canonical mirrors; MNIST IDX files are gzip'd, SVHN is a MATLAB .mat
_MNIST_BASE = "https://ossci-datasets.s3.amazonaws.com/mnist/"
_MNIST_FILES = {
    "train_x": "train-images-idx3-ubyte.gz",
    "train_y": "train-labels-idx1-ubyte.gz",
    "test_x": "t10k-images-idx3-ubyte.gz",
    "test_y": "t10k-labels-idx1-ubyte.gz",
}
_SVHN_BASE = "http://ufldl.stanford.edu/housenumbers/"
_SVHN_FILES = {"train": "train_32x32.mat", "test": "test_32x32.mat"}


class DatasetUnavailable(RuntimeError):
    """No cache and the download failed (offline host)."""


@dataclasses.dataclass
class ImageDataset:
    """Loaded dataset: raw uint8 images (N, H, W, C) + int labels per split."""

    spec: ImageSpec
    train_x: np.ndarray
    train_y: np.ndarray
    valid_x: np.ndarray
    valid_y: np.ndarray
    test_x: np.ndarray
    test_y: np.ndarray
    source: str  # "cache" | "download" | "procedural"

    def split(self, name: str) -> Tuple[np.ndarray, np.ndarray]:
        if name not in ("train", "valid", "test"):
            raise KeyError(f"unknown split {name!r}; train/valid/test")
        return getattr(self, f"{name}_x"), getattr(self, f"{name}_y")


# ---------------------------------------------------------------- transforms
def to_domain(x_uint8: np.ndarray, family: str) -> Tuple[np.ndarray, float]:
    """uint8 images -> (flattened float32 batch in the EF domain, bpd offset).

    The offset is the per-dimension change-of-variables term (in bits) that
    converts the model's log-density back to bits-per-dim of the original
    8-bit data: discrete families model the levels directly (offset 0);
    ``normal`` models x/255 on [0, 1], so each dim picks up log2(256) bits.
    """
    flat = x_uint8.reshape(len(x_uint8), -1).astype(np.float32)
    if family == "normal":
        return flat / 255.0, float(np.log2(256.0))
    if family in ("binomial", "categorical"):
        return flat, 0.0
    raise ValueError(
        f"no image domain transform for leaf family {family!r}"
    )


# ------------------------------------------------------------------- loaders
def array_loader(
    data: np.ndarray,
    global_batch: int,
    num_shards: int = 1,
    shard_id: int = 0,
    start_step: int = 0,
) -> ShardedLoader:
    """Deterministic array-backed loader: shard ``sh`` of step ``s`` reads the
    contiguous row block ``[(s * num_shards + sh) * n, ...)`` (mod data), so
    shards within a step are DISJOINT and steps tile the dataset.  (The same
    scheme ``launch/train.py``'s ``einet_loader`` pinned in PR 3; hoisted here
    so real datasets ride the identical contract.)
    """

    def make(step: int, shard: int, n: int) -> Dict[str, np.ndarray]:
        base = (step * num_shards + shard) * n
        return {"x": data[(np.arange(n) + base) % len(data)]}

    return ShardedLoader(
        make, global_batch, num_shards=num_shards, shard_id=shard_id,
        start_step=start_step,
    )


def image_loader(
    dataset: ImageDataset,
    split: str,
    global_batch: int,
    family: str = "normal",
    num_shards: int = 1,
    shard_id: int = 0,
    start_step: int = 0,
) -> ShardedLoader:
    """``ShardedLoader`` over one split, transformed to the leaf-EF domain."""
    x, _ = dataset.split(split)
    data, _ = to_domain(x, family)
    return array_loader(
        data, global_batch, num_shards=num_shards, shard_id=shard_id,
        start_step=start_step,
    )


# ----------------------------------------------------------------- downloads
def _download(url: str, path: str, timeout: float = 60.0) -> None:
    tmp = path + ".tmp"
    with urllib.request.urlopen(url, timeout=timeout) as r, open(tmp, "wb") as f:
        f.write(r.read())
    os.replace(tmp, path)


def _parse_idx(path: str) -> np.ndarray:
    """MNIST IDX format: big-endian magic + dims header, then raw uint8."""
    with gzip.open(path, "rb") as f:
        raw = f.read()
    _, _, dtype_code, ndim = struct.unpack(">BBBB", raw[:4])
    assert dtype_code == 0x08, f"expected uint8 IDX payload, got {dtype_code:#x}"
    dims = struct.unpack(">" + "I" * ndim, raw[4: 4 + 4 * ndim])
    return np.frombuffer(raw[4 + 4 * ndim:], dtype=np.uint8).reshape(dims)


def _fetch_mnist(data_dir: str, force: bool = False) -> Dict[str, np.ndarray]:
    out = {}
    for key, fname in _MNIST_FILES.items():
        path = os.path.join(data_dir, fname)
        if force or not os.path.isfile(path):
            _download(_MNIST_BASE + fname, path)
        out[key] = _parse_idx(path)
    return {
        "train_x": out["train_x"][..., None],  # (N, 28, 28, 1)
        "train_y": out["train_y"].astype(np.int32),
        "test_x": out["test_x"][..., None],
        "test_y": out["test_y"].astype(np.int32),
    }


def _fetch_svhn(data_dir: str, force: bool = False) -> Dict[str, np.ndarray]:
    from scipy import io as sio  # container ships scipy

    out = {}
    for split, fname in _SVHN_FILES.items():
        path = os.path.join(data_dir, fname)
        if force or not os.path.isfile(path):
            _download(_SVHN_BASE + fname, path)
        mat = sio.loadmat(path)
        # .mat layout is (H, W, C, N); label "10" means digit 0
        x = np.transpose(mat["X"], (3, 0, 1, 2)).astype(np.uint8)
        y = mat["y"].reshape(-1).astype(np.int32) % 10
        out[f"{split}_x"], out[f"{split}_y"] = x, y
    return out


def _fetch_celeba(data_dir: str, force: bool = False) -> Dict[str, np.ndarray]:
    """CelebA has NO anonymous direct-download mirror (the canonical copy
    sits behind Google-Drive auth), so "download" here means *build the npz
    cache from a locally provided raw copy*:

        <data_dir>/celeba_raw/img_align_celeba/*.jpg     (aligned 178x218)
        <data_dir>/celeba_raw/list_eval_partition.txt    (optional)

    Images are center-cropped to 178x178 and resized to the 32x32 spec with
    PIL; the partition file (0 train / 1 valid / 2 test) drives the split
    when present (0+1 fold into train -- ``_make_splits`` re-carves the
    validation tail), else the standard ordering does.  Raises when the raw
    directory is absent; offline callers use ``source="procedural"``.
    """
    from PIL import Image  # pillow ships with the test extra (PR 4)

    spec = SPECS["celeba"]
    raw = os.path.join(data_dir, "celeba_raw")
    img_dir = os.path.join(raw, "img_align_celeba")
    if not os.path.isdir(img_dir):
        raise FileNotFoundError(
            f"celeba: no raw copy at {img_dir}; CelebA is not anonymously "
            "downloadable -- place the aligned jpgs there (plus "
            "list_eval_partition.txt) or pass source='procedural'"
        )
    names = sorted(
        f for f in os.listdir(img_dir)
        if f.lower().endswith((".jpg", ".jpeg", ".png"))
    )
    part_path = os.path.join(raw, "list_eval_partition.txt")
    parts = {}
    if os.path.isfile(part_path):
        with open(part_path) as f:
            for line in f:
                cols = line.split()
                if len(cols) >= 2:
                    parts[cols[0]] = int(cols[1])
    train, test = [], []
    for name in names:
        with Image.open(os.path.join(img_dir, name)) as im:
            im = im.convert("RGB")
            side = min(im.size)
            left = (im.size[0] - side) // 2
            top = (im.size[1] - side) // 2
            im = im.crop((left, top, left + side, top + side)).resize(
                (spec.width, spec.height), Image.BILINEAR
            )
            arr = np.asarray(im, np.uint8)
        (test if parts.get(name, 0) == 2 else train).append(arr)
    if not train or not test:
        # no/partial partition table: deterministic 9:1 tail split
        both = train + test
        n_test = max(1, len(both) // 10)
        train, test = both[:-n_test], both[-n_test:]
    zeros = lambda n: np.zeros(n, np.int32)  # noqa: E731 -- unlabeled
    return {
        "train_x": np.stack(train),
        "train_y": zeros(len(train)),
        "test_x": np.stack(test),
        "test_y": zeros(len(test)),
    }


_FETCHERS = {"mnist": _fetch_mnist, "svhn": _fetch_svhn,
             "celeba": _fetch_celeba}


# -------------------------------------------------------- procedural fallback
def procedural_images(
    spec: ImageSpec, num: int, seed: int = 0
) -> Tuple[np.ndarray, np.ndarray]:
    """Deterministic stand-in with the real dataset's shapes/dtypes.

    Class-conditional templates (a fixed set of 2D Gaussian bumps per class,
    positions derived from the class id) plus per-sample geometric jitter and
    pixel noise, quantized to uint8 -- enough correlation structure that EM
    learns something and inpainting is visually checkable, with zero I/O.
    """
    h, w, c = spec.height, spec.width, spec.channels
    # crc32, NOT hash(): str hashes are salted per process (PYTHONHASHSEED),
    # and the fallback's whole point is cross-process reproducibility --
    # restart recovery and train-then-eval must see the same rows
    name_key = zlib.crc32(spec.name.encode())
    rng = np.random.RandomState((name_key + seed * 7919) % 2**31)
    yy, xx = np.mgrid[0:h, 0:w].astype(np.float32)
    templates = np.zeros((spec.num_classes, h, w, c), np.float32)
    for cls in range(spec.num_classes):
        trng = np.random.RandomState(1000 + cls)
        img = np.zeros((h, w, c), np.float32)
        for _ in range(3 + cls % 3):
            cy, cx = trng.rand(2) * [h * 0.8, w * 0.8] + [h * 0.1, w * 0.1]
            s = 1.5 + trng.rand() * (min(h, w) / 6.0)
            bump = np.exp(-(((yy - cy) ** 2 + (xx - cx) ** 2) / (2 * s * s)))
            img += bump[:, :, None] * (0.4 + 0.6 * trng.rand(c))
        templates[cls] = img / max(img.max(), 1e-6)
    labels = rng.randint(spec.num_classes, size=num).astype(np.int32)
    # per-sample sub-pixel shift via a small random translation of the grid
    dy = rng.randint(-2, 3, size=num)
    dx = rng.randint(-2, 3, size=num)
    base = templates[labels]  # (N, H, W, C)
    shifted = np.empty_like(base)
    for i in range(num):  # cheap: N is test/CI sized
        shifted[i] = np.roll(base[i], (dy[i], dx[i]), axis=(0, 1))
    noisy = shifted * (0.85 + 0.15 * rng.rand(num, 1, 1, 1)) \
        + rng.randn(num, h, w, c).astype(np.float32) * 0.04
    return (np.clip(noisy, 0.0, 1.0) * 255.0).astype(np.uint8), labels


# ------------------------------------------------------------------- loading
def _make_splits(
    spec: ImageSpec,
    train_x: np.ndarray,
    train_y: np.ndarray,
    test_x: np.ndarray,
    test_y: np.ndarray,
    source: str,
) -> ImageDataset:
    n_valid = max(1, int(len(train_x) * VALID_FRACTION))
    return ImageDataset(
        spec=spec,
        train_x=train_x[:-n_valid],
        train_y=train_y[:-n_valid],
        valid_x=train_x[-n_valid:],
        valid_y=train_y[-n_valid:],
        test_x=test_x,
        test_y=test_y,
        source=source,
    )


def load_image_dataset(
    name: str,
    data_dir: str = DEFAULT_DATA_DIR,
    source: str = "auto",
    size_cap: Optional[int] = None,
) -> ImageDataset:
    """Resolve a dataset: cache -> download -> error, or procedural.

    Args:
      name: "mnist" | "svhn" | "celeba".
      data_dir: on-disk cache root (one ``<name>.npz`` per dataset).
      source: "auto" (cache, then download), "download" (re-download the
        raw files even if present and rebuild the npz cache), or
        "procedural" (deterministic offline fallback -- never touches disk
        or network).
      size_cap: optionally cap the train/test sizes (procedural and cached
        reads both honour it; keeps CI memory bounded).

    Raises:
      DatasetUnavailable: source="auto"/"download" with no cache and no
        network -- callers that must run offline pass source="procedural".
    """
    spec = SPECS.get(name)
    if spec is None:
        raise KeyError(f"unknown image dataset {name!r}; one of {list(SPECS)}")

    if source == "procedural":
        n_train = min(spec.train_size, size_cap or 4096)
        n_test = min(spec.test_size, max((size_cap or 4096) // 4, 64))
        train_x, train_y = procedural_images(spec, n_train, seed=0)
        test_x, test_y = procedural_images(spec, n_test, seed=1)
        return _make_splits(spec, train_x, train_y, test_x, test_y,
                            "procedural")
    if source not in ("auto", "download"):
        raise ValueError(
            f"unknown source {source!r}; auto/download/procedural"
        )

    cache = os.path.join(data_dir, f"{name}.npz")
    if source == "auto" and os.path.isfile(cache):
        z = np.load(cache)
        arrays = {k: z[k] for k in ("train_x", "train_y", "test_x", "test_y")}
        src = "cache"
    else:
        os.makedirs(data_dir, exist_ok=True)
        try:
            arrays = _FETCHERS[name](data_dir, force=source == "download")
        except Exception as e:  # no network on this host
            raise DatasetUnavailable(
                f"{name}: no cache at {cache} and download failed ({e}); "
                "pass source='procedural' for the offline fallback"
            ) from e
        np.savez_compressed(cache + ".tmp.npz", **arrays)
        os.replace(cache + ".tmp.npz", cache)
        src = "download"
    if size_cap is not None:
        arrays = {
            "train_x": arrays["train_x"][:size_cap],
            "train_y": arrays["train_y"][:size_cap],
            "test_x": arrays["test_x"][: max(size_cap // 4, 64)],
            "test_y": arrays["test_y"][: max(size_cap // 4, 64)],
        }
    return _make_splits(
        spec, arrays["train_x"], arrays["train_y"], arrays["test_x"],
        arrays["test_y"], src,
    )


def synthetic_image_dataset(
    height: int = 16,
    width: int = 16,
    channels: int = 3,
    num_train: int = 4096,
    num_test: int = 512,
    seed: int = 0,
) -> ImageDataset:
    """The synthetic mixture images (``repro.data.synthetic``) wrapped in the
    ImageDataset API, so the eval workbench treats ``--dataset synthetic``
    exactly like a real dataset (uint8 storage, same splits/transforms)."""
    from repro.data.synthetic import gaussian_mixture_images

    spec = ImageSpec("synthetic", height, width, channels, 10,
                     num_train, num_test)
    data = gaussian_mixture_images(
        num_train + num_test, height, width, channels, seed=seed
    )
    imgs = (data.reshape(-1, height, width, channels) * 255.0).astype(np.uint8)
    labels = np.zeros(len(imgs), np.int32)
    return _make_splits(
        spec, imgs[:num_train], labels[:num_train], imgs[num_train:],
        labels[num_train:], "procedural",
    )
