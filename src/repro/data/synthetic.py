"""Synthetic data generators standing in for the paper's datasets.

The container has no network access, so the 20 binary density-estimation
datasets (Table 1), SVHN and CelebA (§4.2) are replaced by synthetic
generators of identical shape/dtype and *structured* distributions (latent
factor models / mixtures), so EM has real correlation structure to learn and
the implementation claims (speed, memory, LL parity, EM monotonicity) remain
checkable.  Documented in DESIGN.md §6.
"""

from __future__ import annotations

import zlib
from typing import Dict, Tuple

import numpy as np

# (name, num_vars) of the 20 binary datasets from Lowd & Davis / Van Haaren:
# used to size the Table-1 proxies identically to the paper.
TWENTY_DATASETS: Tuple[Tuple[str, int], ...] = (
    ("nltcs", 16), ("msnbc", 17), ("kdd-2k", 64), ("plants", 69),
    ("jester", 100), ("audio", 100), ("netflix", 100), ("accidents", 111),
    ("retail", 135), ("pumsb-star", 163), ("dna", 180), ("kosarek", 190),
    ("msweb", 294), ("book", 500), ("each-movie", 500), ("web-kb", 839),
    ("reuters-52", 889), ("20ng", 910), ("bbc", 1058), ("ad", 1556),
)


def binary_dataset(
    name: str, num_samples: int, seed: int = 0, num_factors: int = 8
) -> np.ndarray:
    """Correlated Bernoulli data from a random latent-factor model.

    z ~ Categorical(num_factors); x_d ~ Bernoulli(sigmoid(W[z, d])): a mixture
    with the per-dataset variable count of the real benchmark.
    """
    dims = dict(TWENTY_DATASETS)
    d = dims.get(name)
    if d is None:
        raise KeyError(f"unknown dataset {name}; one of {list(dims)}")
    # crc32, not hash(): str hashes are salted per process, and these rows
    # must be recomputable across restarts (the stateless-loader contract)
    rng = np.random.RandomState((zlib.crc32(name.encode()) + seed) % 2**31)
    w = rng.randn(num_factors, d) * 2.0
    z = rng.randint(num_factors, size=num_samples)
    p = 1.0 / (1.0 + np.exp(-w[z]))
    return (rng.rand(num_samples, d) < p).astype(np.float32)


def gaussian_mixture_images(
    num_samples: int,
    height: int = 32,
    width: int = 32,
    channels: int = 3,
    num_components: int = 10,
    seed: int = 0,
) -> np.ndarray:
    """Smooth mixture 'images' in [0, 1], (N, H*W*C): the SVHN/CelebA proxy."""
    rng = np.random.RandomState(seed)
    d = height * width * channels
    yy, xx = np.mgrid[0:height, 0:width].astype(np.float32)
    means = []
    for _ in range(num_components):
        # random smooth pattern: mixture of 2D gaussian bumps per channel
        img = np.zeros((height, width, channels), np.float32)
        for _ in range(4):
            cy, cx = rng.rand(2) * [height, width]
            s = 2.0 + rng.rand() * 6.0
            bump = np.exp(-(((yy - cy) ** 2 + (xx - cx) ** 2) / (2 * s * s)))
            img += bump[:, :, None] * rng.rand(channels)
        img = img / max(img.max(), 1e-6)
        means.append(img.reshape(-1))
    means = np.stack(means)  # (C, D)
    z = rng.randint(num_components, size=num_samples)
    x = means[z] + rng.randn(num_samples, d).astype(np.float32) * 0.08
    return np.clip(x, 0.0, 1.0)


def token_batch(
    step: int, shard: int, batch: int, seq_len: int, vocab: int, seed: int = 0
) -> Dict[str, np.ndarray]:
    """Stateless synthetic LM batch: derivable from (step, shard) alone.

    This statelessness is the restart/straggler story: any host can recompute
    any step's shard without coordination (DESIGN.md §4).
    """
    rng = np.random.RandomState((seed * 1_000_003 + step * 65_537 + shard) % 2**31)
    # Markov-ish stream so the loss actually decreases in the examples
    base = rng.randint(0, vocab, size=(batch, seq_len + 1))
    repeat = rng.rand(batch, seq_len + 1) < 0.3
    for t in range(1, seq_len + 1):
        base[:, t] = np.where(repeat[:, t], base[:, t - 1], base[:, t])
    return {
        "tokens": base[:, :-1].astype(np.int32),
        "labels": base[:, 1:].astype(np.int32),
    }
