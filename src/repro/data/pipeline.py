"""Sharded host data pipeline with deterministic skip-ahead.

Each host derives its shard of every global batch purely from
``(step, host_id)`` -- no pipeline state to checkpoint, no coordination on
restart, and a straggler's shard can be re-assigned by remapping host ids
(``repro.dist.fault_tolerance``).  A small background-thread prefetcher
overlaps host-side generation with device steps.
"""

from __future__ import annotations

import queue
import threading
from typing import Callable, Dict, Iterator, Optional

import numpy as np


class ShardedLoader:
    """Deterministic per-host loader.

    Args:
      make_batch: (step, shard, per_host_batch) -> dict of np arrays.
      global_batch: total batch across all hosts.
      num_shards / shard_id: data-parallel host grid.
      start_step: resume point (skip-ahead is O(1): nothing to replay).
    """

    def __init__(
        self,
        make_batch: Callable[[int, int, int], Dict[str, np.ndarray]],
        global_batch: int,
        num_shards: int = 1,
        shard_id: int = 0,
        start_step: int = 0,
        prefetch: int = 2,
    ):
        assert global_batch % num_shards == 0
        self.make_batch = make_batch
        self.per_host = global_batch // num_shards
        self.num_shards = num_shards
        self.shard_id = shard_id
        self.step = start_step
        self._q: Optional[queue.Queue] = None
        self._prefetch = prefetch
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # -- synchronous API ------------------------------------------------
    def batch_at(self, step: int, shard: Optional[int] = None) -> Dict[str, np.ndarray]:
        shard = self.shard_id if shard is None else shard
        return self.make_batch(step, shard, self.per_host)

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        b = self.batch_at(self.step)
        self.step += 1
        return b

    # -- prefetching ------------------------------------------------------
    def start_prefetch(self) -> "ShardedLoader":
        self._q = queue.Queue(maxsize=self._prefetch)
        self._stop.clear()

        def worker():
            step = self.step
            while not self._stop.is_set():
                try:
                    self._q.put((step, self.batch_at(step)), timeout=0.2)
                    step += 1
                except queue.Full:
                    continue

        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()
        return self

    def next_prefetched(self) -> Dict[str, np.ndarray]:
        assert self._q is not None, "call start_prefetch() first"
        step, batch = self._q.get()
        self.step = step + 1
        return batch

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2.0)
