"""Data substrate: synthetic dataset generators + sharded host pipeline."""

from repro.data import pipeline, synthetic

__all__ = ["pipeline", "synthetic"]
