"""Data substrate: synthetic generators, real-image datasets (MNIST/SVHN +
procedural offline fallback), and the sharded host pipeline."""

from repro.data import datasets, pipeline, synthetic

__all__ = ["datasets", "pipeline", "synthetic"]
