"""Synthetic mixed-kind request streams + the direct (pre-engine) call path.

Shared by the serve driver, ``benchmarks/bench_serve.py`` and the tests:
``mixed_requests`` builds a deterministic heterogeneous traffic sample, and
``direct_call`` is the one-call-at-a-time jitted path the engine is measured
against -- it doubles as the parity oracle, since the engine's contract is
bit-compatibility with direct model calls (per-request keys included).
"""

from __future__ import annotations

from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.compile import REGISTRY
from repro.core.einet import EiNet
from repro.serve.engine import Request, request_key

# default traffic mix: LL-heavy with a steady sampling/decode component
DEFAULT_MIX = (
    "joint_ll",
    "marginal_ll",
    "conditional_ll",
    "conditional_sample",
    "joint_ll",
    "sample",
    "marginal_ll",
    "mpe",
)


def mixed_requests(
    num_vars: int,
    n: int,
    seed: int = 0,
    mix: Sequence[str] = DEFAULT_MIX,
) -> list:
    """Deterministic stream of ``n`` heterogeneous requests over ``mix``."""
    rng = np.random.RandomState(seed)
    reqs = []
    for i in range(n):
        x = rng.randn(num_vars).astype(np.float32)
        ev = rng.rand(num_vars) < 0.5
        reqs.append(
            Request(
                req_id=i,
                kind=mix[i % len(mix)],
                x=x,
                evidence_mask=ev,
                query_mask=~ev,
                seed=1000 + i,
            )
        )
    return reqs


def _per_request_call(
    model: EiNet, params, jit_sampling: bool
) -> Callable[[Request], jax.Array]:
    # compiled through the shared registry (one jit object per model + kind,
    # visible to the recompile sentry) rather than ad-hoc jax.jit objects
    ll = REGISTRY.jit(
        model, ("direct", "log_likelihood"), model.log_likelihood
    )
    cll = REGISTRY.jit(
        model,
        ("direct", "conditional_log_likelihood"),
        model.conditional_log_likelihood,
    )
    cs = (
        REGISTRY.jit(
            model,
            ("direct", "conditional_sample"),
            model.conditional_sample,
            static_argnames=("mode",),
        )
        if jit_sampling
        else model.conditional_sample
    )

    def call(req: Request) -> jax.Array:
        x = jnp.asarray(req.x)[None]
        ev = jnp.asarray(req.evidence_mask)[None]
        key = request_key(req.seed)
        if req.kind == "joint_ll":
            return ll(params, x)[0]
        if req.kind == "marginal_ll":
            return ll(params, x, ev)[0]
        if req.kind == "conditional_ll":
            qm = jnp.asarray(req.query_mask)[None]
            return cll(params, x, qm, ev)[0]
        if req.kind == "sample":
            return cs(params, key, jnp.zeros_like(x), jnp.zeros_like(ev))[0]
        if req.kind == "conditional_sample":
            return cs(params, key, x, ev)[0]
        if req.kind == "mpe":
            return cs(params, key, x, ev, mode="argmax")[0]
        raise ValueError(f"unknown kind {req.kind!r}")

    return call


def legacy_call(model: EiNet, params) -> Callable[[Request], jax.Array]:
    """One-call-at-a-time serving with the pre-engine sampling bug intact:
    jitted log-likelihood calls, sampling dispatched eagerly (unjitted, as
    ``launch/serve.py:80`` shipped before this engine).  This is the
    "current one-call-at-a-time path" the engine's >= 5x bar is measured
    against.  (The old driver itself ran one fixed batched smoke loop, not
    per-request serving -- it could not serve a heterogeneous stream at all,
    so per-request dispatch is the closest meaningful baseline.)"""
    return _per_request_call(model, params, jit_sampling=False)


def direct_call(model: EiNet, params) -> Callable[[Request], jax.Array]:
    """Fully-jitted one-call-at-a-time path (batch size 1, no coalescing):
    the strong baseline, and the parity oracle -- sampling kinds use the
    same per-request key the engine derives, so outputs are directly
    comparable."""
    return _per_request_call(model, params, jit_sampling=True)
