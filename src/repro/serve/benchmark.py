"""Throughput / latency measurement: engine vs the one-call-at-a-time path.

One routine, shared by ``repro.launch.serve --arch einet_*`` and
``benchmarks/bench_serve.py``, so the driver's printed numbers and the
``BENCH_serve.json`` perf trajectory come from the same measurement:

  * warm-up (program compilation) is timed separately from steady state --
    compile cost is paid once per (kind, bucket), never per request;
  * steady state reruns the identical stream against the warm program cache;
  * latency is PER REQUEST, enqueue -> complete, read from the engine's
    ``serve.request.seconds`` histograms (the whole-stream wall clock hid
    the per-kind distribution -- a slow sampling request was invisible
    behind 63 fast LLs): steady-state-only percentiles come from marking
    the histogram counts before the timed passes and diffing after;
  * two baselines, both one-call-at-a-time: ``legacy_call`` is per-request
    serving with the pre-engine sampling bug intact (jitted LLs, *unjitted*
    sampling -- serve.py:80), the "current path" the >= 5x bar refers to;
    ``direct_call`` is the stronger fully-jitted per-request path, so the
    report also isolates pure batching/dispatch amortization from the jit
    fix;
  * every engine result is checked against the direct path (parity).
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from repro import obs
from repro.obs import METRICS, percentile_from_counts
from repro.serve.engine import Request, ServeEngine
from repro.serve.workload import direct_call, legacy_call


def _program_cache_counts() -> Dict[str, int]:
    """Process-wide program-cache counters (diff two snapshots to scope
    them to one benchmark): engine-dict fast-path hits/misses plus the
    shared registry's AOT compile count (a registry miss IS a compile)."""
    return {
        "hits": int(sum(
            m.value for _, m in METRICS.find("serve.program_cache.hits"))),
        "misses": int(sum(
            m.value for _, m in METRICS.find("serve.program_cache.misses"))),
        "registry_compiles": int(sum(
            m.value
            for _, m in METRICS.find("compile.cache.misses", kind="aot"))),
    }


def run_benchmark(
    model,
    params,
    requests: Sequence[Request],
    max_batch: int = 0,
    reps: int = 3,
    rules: Optional[Dict[str, Any]] = None,
) -> Dict[str, Any]:
    """``max_batch=0`` derives the micro-batch cap from the stream size
    (min(32, n)) -- the one defaulting rule both CLIs share."""
    n = len(requests)
    if n == 0:
        raise ValueError("run_benchmark needs at least one request")
    reps = max(1, int(reps))
    max_batch = max_batch or max(1, min(32, n))
    engine = ServeEngine(model, params, max_batch=max_batch, rules=rules)
    kinds = sorted({r.kind for r in requests})
    cache0 = _program_cache_counts()

    # -- warm-up pass: compiles the program cache on demand
    with obs.timed("serve.bench.warmup") as t_warm:
        results = engine.run(requests)

    warm_steps = engine.stats["steps"]
    warm_padded = engine.stats["padded_rows"]

    # -- steady state: identical stream, warm cache.  Mark the per-request
    # latency histograms here so the percentiles below cover ONLY the timed
    # passes (warm-up latencies include compiles; they must not pollute)
    marks: Dict[str, List[int]] = {
        k: METRICS.sum_histogram("serve.request.seconds", kind=k)
        for k in kinds
    }
    with obs.timed("serve.bench.steady", reps=reps) as t_st:
        for _ in range(reps):
            results = engine.run(requests)
    t_steady = t_st.seconds / reps
    latency_ms: Dict[str, Dict[str, float]] = {}
    for k in kinds:
        after = METRICS.sum_histogram("serve.request.seconds", kind=k)
        delta = [a - b for a, b in zip(after, marks[k])]
        latency_ms[k] = {
            f"p{q}": round(percentile_from_counts(delta, q) * 1e3, 4)
            for q in (50, 95, 99)
        }
    # per-stream scheduling stats (engine.stats accumulate across passes)
    steps_per_pass = (engine.stats["steps"] - warm_steps) // reps
    padded_per_pass = (engine.stats["padded_rows"] - warm_padded) // reps
    cache1 = _program_cache_counts()

    # -- strong baseline: fully-jitted one-call-at-a-time (warmed the same way)
    call = direct_call(model, params)
    with obs.timed("serve.bench.direct_warmup") as t_dw:
        direct = {r.req_id: np.asarray(call(r)) for r in requests}
    with obs.timed("serve.bench.direct") as t_d:
        direct = {r.req_id: np.asarray(call(r)) for r in requests}

    # -- acceptance baseline: the pre-engine path (unjitted sampling).
    # One warm pass primes the jitted LL programs + eager op caches so the
    # timed pass is its steady state too.
    legacy = legacy_call(model, params)
    for r in requests:
        np.asarray(legacy(r))
    with obs.timed("serve.bench.legacy") as t_l:
        for r in requests:
            np.asarray(legacy(r))
    t_legacy = t_l.seconds

    parity = max(
        float(np.max(np.abs(np.asarray(results[i].value) - direct[i])))
        for i in direct
    )
    return {
        "num_requests": n,
        "kinds": kinds,
        "max_batch": max_batch,
        "buckets": list(engine.buckets),
        "reps": reps,
        "warmup_s": t_warm.seconds,
        "compile_s": engine.stats["compile_s"],
        "direct_warmup_s": t_dw.seconds,
        "steady_s": t_steady,
        "engine_qps": n / t_steady,
        "latency_ms": latency_ms,
        "program_cache": {k: cache1[k] - cache0[k] for k in cache1},
        "direct_s": t_d.seconds,
        "direct_qps": n / t_d.seconds,
        "legacy_s": t_legacy,
        "legacy_qps": n / t_legacy,
        "speedup": t_legacy / t_steady,
        "speedup_vs_jitted": t_d.seconds / t_steady,
        "programs": engine.num_programs,
        "compiles": engine.stats["compiles"],
        "scheduler_steps": steps_per_pass,
        "padded_rows": padded_per_pass,
        # high-watermark, not last-write: the queue drains before the report
        # is assembled, so the plain gauge value always reads ~0 here
        "queue_depth_max": METRICS.gauge("serve.queue.depth").max,
        "parity_max_abs_diff": parity,
    }


def format_report(r: Dict[str, Any]) -> str:
    lines = [
        f"batched exact-inference engine: {r['num_requests']} requests, "
        f"kinds={','.join(r['kinds'])}, max_batch={r['max_batch']}",
        f"warm-up   : engine {r['warmup_s']*1e3:.0f} ms "
        f"({r['programs']} programs, compile {r['compile_s']*1e3:.0f} ms); "
        f"direct path {r['direct_warmup_s']*1e3:.0f} ms",
        f"steady    : engine {r['steady_s']*1e3:.1f} ms "
        f"({r['engine_qps']:.0f} req/s)",
    ]
    for kind, lm in sorted(r.get("latency_ms", {}).items()):
        lines.append(
            f"latency   : {kind:<24s} p50 {lm['p50']:8.3f} ms   "
            f"p95 {lm['p95']:8.3f} ms   p99 {lm['p99']:8.3f} ms"
        )
    pc = r.get("program_cache")
    if pc:
        lines.append(
            f"prog cache: {pc['hits']} hits / {pc['misses']} misses "
            f"({pc['registry_compiles']} registry compiles)"
        )
    lines += [
        f"baselines : current one-call-at-a-time (unjitted sampling) "
        f"{r['legacy_s']*1e3:.1f} ms ({r['legacy_qps']:.0f} req/s) -> "
        f"{r['speedup']:.1f}x; fully-jitted per-request "
        f"{r['direct_s']*1e3:.1f} ms ({r['direct_qps']:.0f} req/s) -> "
        f"{r['speedup_vs_jitted']:.1f}x",
        f"parity    : max|engine - direct| = {r['parity_max_abs_diff']:.2e}",
        f"programs  : {r['programs']} cached / {r['compiles']} compiles "
        f"({r['scheduler_steps']} scheduler steps, "
        f"{r['padded_rows']} padded filler rows per stream)",
    ]
    return "\n".join(lines)
