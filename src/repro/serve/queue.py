"""Request queue + slot manager for the exact-inference serving engine.

Mirrors the LM path's continuous-batching design (``launch.serve.serve_lm``:
one shared cache, slot = row).  Requests enter a FIFO; each scheduling step
the engine leases up to ``capacity`` slots, builds one micro-batch, and
releases the slots when the micro-batch retires.  The EiNet has no
persistent per-request state (no KV cache), so a slot is an admission token
rather than a cache row -- it bounds the number of in-flight rows per step,
which keeps every padded micro-batch inside the compiled bucket range.
"""

from __future__ import annotations

import collections
from typing import Any, Callable, Deque, List, Optional


class SlotManager:
    """Fixed pool of admission slots (continuous-batching row leases)."""

    def __init__(self, capacity: int):
        if capacity <= 0:
            raise ValueError(f"capacity must be positive, got {capacity}")
        self.capacity = int(capacity)
        self._free: List[int] = list(range(capacity - 1, -1, -1))
        self._held = set()

    @property
    def free(self) -> int:
        return len(self._free)

    @property
    def held(self) -> int:
        return len(self._held)

    def acquire(self) -> Optional[int]:
        """Lease one slot; None when the pool is exhausted."""
        if not self._free:
            return None
        slot = self._free.pop()
        self._held.add(slot)
        return slot

    def release(self, slot: int) -> None:
        if slot not in self._held:
            raise ValueError(f"slot {slot} is not held")
        self._held.remove(slot)
        self._free.append(slot)


class RequestQueue:
    """FIFO of heterogeneous requests with per-group draining.

    ``pop_kind`` removes up to ``limit`` requests of one coalescing group
    while preserving the arrival order of everything else -- the coalescing
    primitive: the engine always serves the oldest request's group first, and
    rides along every queued request of the same group that fits the batch.

    The group of a request defaults to its query ``kind``; ``key_fn`` lets
    the engine refine it (the mixture path groups by ``(kind, component)`` so
    component-pinned queries to different components never share a
    micro-batch -- the component index is folded into the program key).
    """

    def __init__(self, key_fn: Optional[Callable[[Any], Any]] = None):
        self._q: Deque = collections.deque()
        self._key = key_fn or (lambda r: r.kind)

    def __len__(self) -> int:
        return len(self._q)

    def submit(self, request) -> None:
        self._q.append(request)

    def oldest_kind(self) -> Optional[Any]:
        return self._key(self._q[0]) if self._q else None

    def pending_kinds(self) -> List[Any]:
        """Distinct groups in arrival order of their oldest request."""
        seen: List[Any] = []
        for r in self._q:
            k = self._key(r)
            if k not in seen:
                seen.append(k)
        return seen

    def pop_kind(self, kind: Any, limit: int) -> List:
        """Remove and return up to ``limit`` requests of group ``kind``
        (FIFO)."""
        taken: List = []
        rest: List = []
        for r in self._q:
            if self._key(r) == kind and len(taken) < limit:
                taken.append(r)
            else:
                rest.append(r)
        self._q = collections.deque(rest)
        return taken
