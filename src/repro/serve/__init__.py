"""Batched exact-inference serving for Einsum Networks.

``ServeEngine`` coalesces heterogeneous exact-inference requests (likelihoods,
marginals, conditionals, sampling, MPE) into padded per-kind micro-batches and
executes them through a bounded compiled-program cache -- the systems layer
that makes the paper's "fast exact inference" claim hold under mixed traffic.
"""

from repro.serve.engine import (
    Request,
    Result,
    ServeEngine,
    request_key,
)
from repro.serve.benchmark import format_report, run_benchmark
from repro.serve.queue import RequestQueue, SlotManager
from repro.serve.workload import (
    DEFAULT_MIX,
    direct_call,
    legacy_call,
    mixed_requests,
)

__all__ = [
    "Request",
    "Result",
    "ServeEngine",
    "RequestQueue",
    "SlotManager",
    "request_key",
    "DEFAULT_MIX",
    "direct_call",
    "legacy_call",
    "mixed_requests",
    "run_benchmark",
    "format_report",
]
