"""Continuous-batching exact-inference engine for Einsum Networks.

The serving analogue of the LM path's prefill/decode slot loop: heterogeneous
requests (joint LL, marginal LL, conditional LL, conditional/unconditional
sampling, MPE decode) enter one FIFO, are coalesced into micro-batches per
query kind, padded up to a *batch bucket*, and executed through an
ahead-of-time compiled-program cache keyed by ``(kind, bucket)`` -- so the
number of XLA programs is bounded by ``len(kinds) * len(buckets)`` regardless
of the traffic mix, and steady-state dispatch never retraces.

Design points:

  * Bucket padding uses filler rows (zeros, empty masks, key 0) that are
    sliced off before results are returned.  LL kinds are row-independent by
    construction; sampling kinds go through
    ``EiNet.conditional_sample_per_key`` (vmap with one PRNG key per row), so
    a request's draw is a pure function of its own (seed, x, evidence) and
    can never depend on its micro-batch neighbours or on the bucket size.
  * Per-request determinism: a request with ``seed`` samples exactly as a
    direct ``model.conditional_sample(params, request_key(seed), ...)`` call.
  * Optional sharded execution: pass a ``repro.dist.sharding`` rule table
    (e.g. ``sharding.serve_rules()``) and programs are lowered under it --
    batch over the data axes, layer-nodes over "model".  Per the dist
    degradation contract this is a no-op without an ambient multi-device
    mesh, so the engine is mesh-agnostic.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import compile as compile_lib
from repro import obs
from repro.obs import METRICS
from repro.core.einet import QUERY_KINDS, EiNet
from repro.dist import sharding as shlib
from repro.serve.queue import RequestQueue, SlotManager

_LL_KINDS = ("joint_ll", "marginal_ll", "conditional_ll")
_SAMPLE_KINDS = ("sample", "conditional_sample", "mpe")


def _key_data(seed: int) -> np.ndarray:
    """Host-side per-request PRNG key data: the exact uint32 pair
    ``jax.random.PRNGKey(seed)`` would hold (threefry: [hi, lo] words), built
    with numpy so micro-batch assembly never touches the device."""
    seed = int(seed)
    if not jax.config.jax_enable_x64:
        seed &= 0xFFFFFFFF  # PRNGKey truncates seeds to 32 bits without x64
    return np.array([(seed >> 32) & 0xFFFFFFFF, seed & 0xFFFFFFFF], np.uint32)


def request_key(seed: int) -> jax.Array:
    """The per-request PRNG key, identical to ``jax.random.PRNGKey(seed)``:
    the key a direct ``model.conditional_sample`` call must use to reproduce
    the engine's draw for a request with this ``seed``."""
    return jnp.asarray(_key_data(seed))


@dataclasses.dataclass
class Request:
    """One exact-inference query.  ``x``/masks are per-variable vectors (D,);
    kinds that do not need a field may leave it None (zero-filled).

    ``component`` pins a mixture request to one mixture component (required
    by the model's ``component_kinds``, rejected for every other kind).  It
    is a *static* index: the engine folds it into the coalescing group and
    the compiled-program key, so per-component programs stay specialized and
    the cache stays bounded by ``kinds x buckets x components``.
    """

    req_id: int
    kind: str
    x: Optional[np.ndarray] = None
    evidence_mask: Optional[np.ndarray] = None
    query_mask: Optional[np.ndarray] = None
    seed: int = 0
    component: Optional[int] = None


@dataclasses.dataclass
class Result:
    req_id: int
    kind: str
    value: np.ndarray  # () log-likelihood, or (D,) sample / decode


class ServeEngine:
    """Batched exact-inference serving engine over one EiNet + params."""

    def __init__(
        self,
        model: EiNet,
        params: Dict[str, Any],
        max_batch: int = 64,
        buckets: Optional[Sequence[int]] = None,
        rules: Optional[shlib.Rules] = None,
        registry: Optional[compile_lib.ProgramRegistry] = None,
    ):
        self.model = model
        self.params = params
        if buckets is None:
            buckets = []
            b = 1
            while b < max_batch:
                buckets.append(b)
                b *= 2
            buckets.append(max_batch)
        self.buckets: Tuple[int, ...] = tuple(sorted(set(int(b) for b in buckets)))
        if self.buckets[-1] != max_batch:
            raise ValueError(
                f"largest bucket {self.buckets[-1]} must equal max_batch {max_batch}"
            )
        if jax.random.PRNGKey(0).shape != (2,):
            raise NotImplementedError(
                "ServeEngine per-request keys assume the threefry PRNG "
                "(2-word keys); got a different default PRNG impl"
            )
        self.rules = rules
        # the engine serves whatever query surface the model declares:
        # EiNet's six kinds, or EiNetMixture's mixture_* kinds
        self.query_kinds: Tuple[str, ...] = tuple(
            getattr(model, "query_kinds", QUERY_KINDS)
        )
        self.component_kinds: Tuple[str, ...] = tuple(
            getattr(model, "component_kinds", ())
        )
        # coalescing group = (kind, component): component-pinned requests to
        # different components never share a micro-batch (their programs are
        # distinct -- the component is baked into the compiled program)
        self.queue = RequestQueue(key_fn=lambda r: (r.kind, r.component))
        self.slots = SlotManager(max_batch)
        # compiled programs live in the shared registry (anchored to the
        # model); this dict is the engine's own view of the keys it serves,
        # so num_programs / stats stay per-engine even under a shared cache
        self.registry = registry if registry is not None else compile_lib.REGISTRY
        self._programs: Dict[Tuple, Any] = {}
        self.stats = {
            "compiles": 0,  # programs materialized into THIS engine's view
            "compile_s": 0.0,  # compile seconds actually paid by this engine
            "registry_hits": 0,  # programs another engine already compiled
            "steps": 0,
            "requests": 0,
            "padded_rows": 0,
        }
        # req_id -> enqueue wall clock, for per-request queue-wait and
        # end-to-end latency metrics (popped in _execute)
        self._submit_t: Dict[int, float] = {}

    # ----------------------------------------------------------- submission
    def submit(self, request: Request) -> None:
        if request.kind not in self.query_kinds:
            raise ValueError(
                f"unknown query kind {request.kind!r}; one of "
                f"{self.query_kinds}"
            )
        if request.kind in self.component_kinds:
            c = request.component
            num = getattr(self.model, "num_components", 0)
            if c is None or not 0 <= int(c) < num:
                raise ValueError(
                    f"kind {request.kind!r} needs component in [0, {num}); "
                    f"got {c!r}"
                )
        elif request.component is not None:
            raise ValueError(
                f"kind {request.kind!r} does not take a component "
                f"(got {request.component!r})"
            )
        self.queue.submit(request)
        self._submit_t[request.req_id] = obs.now()
        METRICS.gauge("serve.queue.depth").set(len(self.queue))

    def submit_many(self, requests: Sequence[Request]) -> None:
        for r in requests:
            self.submit(r)

    # ------------------------------------------------------- program cache
    @property
    def num_programs(self) -> int:
        return len(self._programs)

    def _bucket_for(self, n: int) -> int:
        for b in self.buckets:
            if n <= b:
                return b
        return self.buckets[-1]

    def _rules_key(self):
        if self.rules is None:
            return None
        return tuple(
            sorted(
                (k, tuple(v) if isinstance(v, (list, tuple)) else v)
                for k, v in self.rules.items()
            )
        )

    def _program(self, kind: str, bucket: int, component: Optional[int] = None):
        key = (kind, bucket) if component is None else (kind, bucket, component)
        prog = self._programs.get(key)
        if prog is not None:
            # engine-local fast path; misses fall through to the shared
            # registry, which does its own (compile.cache.*) accounting
            METRICS.counter("serve.program_cache.hits", kind=kind).inc()
            return prog
        METRICS.counter("serve.program_cache.misses", kind=kind).inc()
        d = self.model.num_vars
        batch_struct = {
            "x": jax.ShapeDtypeStruct((bucket, d), jnp.float32),
            "evidence_mask": jax.ShapeDtypeStruct((bucket, d), jnp.bool_),
            "query_mask": jax.ShapeDtypeStruct((bucket, d), jnp.bool_),
            "keys": jax.ShapeDtypeStruct((bucket, 2), jnp.uint32),
        }
        if component is None:
            fn = functools.partial(self.model.query, kind=kind)
        else:
            fn = functools.partial(
                self.model.query, kind=kind, component=int(component)
            )
        before = (
            self.registry.stats["compiles"], self.registry.stats["compile_s"]
        )
        prog = self.registry.aot(
            self.model, key + (self._rules_key(),), fn,
            (self.params, batch_struct), rules=self.rules,
        )
        if self.registry.stats["compiles"] > before[0]:
            self.stats["compile_s"] += (
                self.registry.stats["compile_s"] - before[1]
            )
        else:
            self.stats["registry_hits"] += 1
        self.stats["compiles"] += 1
        self._programs[key] = prog
        return prog

    def warmup(
        self,
        kinds: Optional[Sequence[str]] = None,
        buckets: Optional[Sequence[int]] = None,
        components: Optional[Sequence[int]] = None,
    ) -> float:
        """Pre-compile programs for a kind/bucket cross product; returns the
        wall-clock seconds the warm-up took (the cost a deployment pays once,
        reported separately from steady-state latency).  Component-pinned
        kinds warm one program per component (all of them by default; pass
        ``components`` to narrow)."""
        with obs.timed("serve.warmup") as t:
            for kind in kinds or self.query_kinds:
                if kind in self.component_kinds:
                    comps: Sequence[Optional[int]] = (
                        components
                        if components is not None
                        else range(getattr(self.model, "num_components", 0))
                    )
                else:
                    comps = (None,)
                for c in comps:
                    for bucket in buckets or self.buckets:
                        self._program(kind, bucket, c)
        return t.seconds

    # ------------------------------------------------------------ execution
    def _assemble(self, kind: str, reqs: List[Request], bucket: int):
        d = self.model.num_vars
        x = np.zeros((bucket, d), np.float32)
        ev = np.zeros((bucket, d), bool)
        qm = np.zeros((bucket, d), bool)
        keys = np.zeros((bucket, 2), np.uint32)
        for i, r in enumerate(reqs):
            if r.x is not None:
                x[i] = r.x
            if r.evidence_mask is not None:
                ev[i] = r.evidence_mask
            if r.query_mask is not None:
                qm[i] = r.query_mask
            keys[i] = _key_data(r.seed)
        return {"x": x, "evidence_mask": ev, "query_mask": qm, "keys": keys}

    def _execute(
        self, kind: str, component: Optional[int], reqs: List[Request]
    ) -> List[Result]:
        bucket = self._bucket_for(len(reqs))
        t_pop = obs.now()
        wait_hist = METRICS.histogram("serve.queue_wait.seconds", kind=kind)
        for r in reqs:
            t_sub = self._submit_t.get(r.req_id)
            if t_sub is not None:
                wait_hist.record(t_pop - t_sub)
        with obs.timed("serve.coalesce", metric="serve.coalesce.seconds",
                       kind=kind, bucket=bucket):
            batch = self._assemble(kind, reqs, bucket)
        with obs.timed("serve.execute", metric="serve.execute.seconds",
                       kind=kind, bucket=bucket):
            prog = self._program(kind, bucket, component)
            out = np.asarray(prog(self.params, batch))[: len(reqs)]
        self.stats["padded_rows"] += bucket - len(reqs)
        self.stats["requests"] += len(reqs)
        t_done = obs.now()
        req_hist = METRICS.histogram(
            "serve.request.seconds", kind=kind, bucket=bucket
        )
        results = []
        for i, r in enumerate(reqs):
            t_sub = self._submit_t.pop(r.req_id, None)
            if t_sub is not None:
                req_hist.record(t_done - t_sub)
            results.append(Result(r.req_id, kind, out[i]))
        return results

    def step(self) -> List[Result]:
        """One scheduling step: serve the oldest pending request's coalescing
        group -- (kind, component) -- riding along every queued request of
        that group that fits the free slots.  Returns the retired results
        (empty when idle/saturated)."""
        group = self.queue.oldest_kind()
        if group is None:
            return []
        kind, component = group
        limit = min(self.slots.free, self.buckets[-1])
        if limit == 0:
            return []
        reqs = self.queue.pop_kind(group, limit)
        METRICS.gauge("serve.queue.depth").set(len(self.queue))
        # limit <= slots.free, so every acquire succeeds; the leases bound
        # in-flight rows for drivers that overlap steps (async serving)
        leases = [self.slots.acquire() for _ in reqs]
        try:
            with obs.span("serve.step", kind=kind, n=len(reqs)):
                results = self._execute(kind, component, reqs)
        finally:
            for s in leases:
                if s is not None:
                    self.slots.release(s)
        self.stats["steps"] += 1
        return results

    def run(
        self, requests: Optional[Sequence[Request]] = None
    ) -> Dict[int, Result]:
        """Drain the queue (plus ``requests``, if given): step until empty.
        Returns {req_id: Result}."""
        if requests is not None:
            self.submit_many(requests)
        out: Dict[int, Result] = {}
        while len(self.queue):
            for res in self.step():
                out[res.req_id] = res
        return out
