"""Einsum Networks: layered, vectorized probabilistic circuits (paper §3).

An ``EiNet`` compiles a region graph into a bottom-up list of (einsum-layer,
mixing-layer) pairs with *static* integer gather tables (built once, on host,
in numpy).  The jitted forward pass is then nothing but:

    leaf EF tensor  ->  segment-sum into leaf rows  ->  for each pair:
    gather(left rows), gather(right rows), one monolithic log-einsum-exp,
    optional mixing logsumexp  ->  append to the row buffer.

This is exactly the paper's design: all product/sum operations of one
topological layer collapse into a single einsum (Eq. 5), products are never
materialized, probabilities stay in the log-domain, weights stay linear.

Also implemented here: exact marginalization (evidence masks), ancestral /
conditional sampling (the induced-tree top-down pass used for Fig. 4
inpainting), and MPE-style argmax decoding.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import plan as plan_lib
from repro.core import region_graph as rg_lib
from repro.dist.sharding import constraint as _cst
from repro.core.exponential_family import ExponentialFamily, Normal
from repro.core.layers import (
    NEG_INF,
    gather_grouped_log_einsum_exp,
    grouped_log_einsum_exp,
    log_einsum_exp,
    log_mix_exp,
    normalize_einsum_weights,
    normalize_mixing_weights,
)
from repro.obs import health as health_lib

# execution planning lives in core.plan; re-exported here for callers (and
# tests) that reach the planner types through the model module
ExecSegment = plan_lib.ExecSegment
VMEM_BUDGET_BYTES = plan_lib.VMEM_BUDGET_BYTES
_GROUP_BLOCK_B = plan_lib._GROUP_BLOCK_B


# query kinds understood by EiNet.query / the serving engine
QUERY_KINDS = (
    "joint_ll",
    "marginal_ll",
    "conditional_ll",
    "sample",
    "conditional_sample",
    "mpe",
)


@dataclasses.dataclass
class PairSpec:
    """Static gather tables for one (product-layer, sum-layer) pair."""

    left: np.ndarray  # (L,) global buffer rows of left children
    right: np.ndarray  # (L,) global buffer rows of right children
    einsum_global: np.ndarray  # (L,) global row id of each simple-sum output
    k_in: int
    k_out: int
    # mixing (None when every sum in this layer has a single child)
    mix_child_local: Optional[np.ndarray]  # (M, C) local partition idx, 0-padded
    mix_mask: Optional[np.ndarray]  # (M, C) 1/0
    mix_global: Optional[np.ndarray]  # (M,) global row ids
    is_final: bool
    # canonical layout (beyond-paper layout optimization, DESIGN.md/§Perf):
    # when the pair's children are exactly the previous layer's outputs, the
    # previous layer is reordered at build time so left = rows [0, L) and
    # right = rows [L, 2L) -- the gather becomes a static slice (zero copy,
    # zero collectives under layer-node sharding).
    canonical: bool = False

    @property
    def num_partitions(self) -> int:
        return len(self.left)

    @property
    def num_mixed(self) -> int:
        return 0 if self.mix_global is None else len(self.mix_global)


@dataclasses.dataclass
class LeafSpec:
    pair_var: np.ndarray  # (P,) variable ids, concatenated leaf scopes
    pair_rep: np.ndarray  # (P,) replica id of the owning leaf
    pair_leaf: np.ndarray  # (P,) owning leaf row (= segment id)
    num_leaves: int
    num_replica: int
    leaf_scopes: List[Tuple[int, ...]]
    leaf_replica: np.ndarray  # (num_leaves,)


class EiNet:
    """A compiled Einsum Network over a region graph.

    Static structure lives on the instance; learnable state is a pytree
    ``params`` produced by :meth:`init` and consumed by the pure functions
    :meth:`log_likelihood`, :meth:`forward`, :meth:`sample`, ... so the model
    composes with jit / grad / pjit.
    """

    # the query surface the serving engine compiles programs for (the
    # mixture model declares its own mixture_* kinds the same way)
    query_kinds = QUERY_KINDS

    def __init__(
        self,
        graph: rg_lib.RegionGraph,
        num_sums: int = 10,
        num_classes: int = 1,
        exponential_family: Optional[ExponentialFamily] = None,
        impl: str = "xla",
        grouped: bool = True,
        vmem_budget: Optional[int] = None,
        verify: Optional[str] = None,
        health: Optional[bool] = None,
    ):
        self.graph = graph
        self.K = int(num_sums)
        self.num_classes = int(num_classes)
        self.ef = exponential_family or Normal()
        self.num_vars = graph.num_vars
        self.impl = impl
        self.grouped = bool(grouped)
        self.vmem_budget = plan_lib.resolve_vmem_budget(vmem_budget)
        self._build()
        self.plan = plan_lib.plan_circuit(
            self.pair_specs, grouped=self.grouped,
            vmem_budget=self.vmem_budget,
        )
        self.exec_plan = self.plan.segments
        # numerical-health telemetry (repro.obs.health): ctor knob wins, else
        # the REPRO_HEALTH env var; the spec is fixed by the execution plan
        self.health = health_lib.resolve_health(health)
        self.health_spec = health_lib.spec_for(self)
        # static verification (repro.analysis.verify): the ctor knob wins,
        # else the REPRO_VERIFY env var ("off" | "report" | "raise")
        self.verify_report = None
        mode = verify if verify is not None else os.environ.get(
            "REPRO_VERIFY", "off").strip().lower()
        if mode in ("off", "", "0"):
            return
        if mode not in ("report", "raise"):
            raise ValueError(
                f"verify={mode!r}; expected 'off', 'report' or 'raise'")
        from repro.analysis.verify import VerifyError, verify_einet

        self.verify_report = verify_einet(self)
        if not self.verify_report.ok:
            if mode == "raise":
                raise VerifyError(self.verify_report)
            print(self.verify_report.format_report())

    # ------------------------------------------------------------------ build
    def _build(self) -> None:
        graph = self.graph
        leaves, pairs = rg_lib.topological_layers(graph)
        leaf_scopes = [graph.regions[i] for i in leaves]
        leaf_replica, num_replica = rg_lib.assign_replicas(leaf_scopes)

        pair_var = np.concatenate(
            [np.asarray(s, dtype=np.int32) for s in leaf_scopes]
        )
        pair_rep = np.concatenate(
            [
                np.full(len(s), leaf_replica[i], dtype=np.int32)
                for i, s in enumerate(leaf_scopes)
            ]
        )
        pair_leaf = np.concatenate(
            [np.full(len(s), i, dtype=np.int32) for i, s in enumerate(leaf_scopes)]
        )
        self.leaf_spec = LeafSpec(
            pair_var=pair_var,
            pair_rep=pair_rep,
            pair_leaf=pair_leaf,
            num_leaves=len(leaves),
            num_replica=int(num_replica),
            leaf_scopes=leaf_scopes,
            leaf_replica=leaf_replica,
        )

        region_row: Dict[int, int] = {r: i for i, r in enumerate(leaves)}
        next_row = len(leaves)
        self.pair_specs: List[PairSpec] = []
        for t, (l_p, l_s) in enumerate(pairs):
            is_final = t == len(pairs) - 1
            if is_final:
                assert l_s == [graph.root], "final sum layer must be the root"
            k_out = self.num_classes if is_final else self.K
            part_local = {p: i for i, p in enumerate(l_p)}
            left = np.array(
                [region_row[graph.partitions[p][1]] for p in l_p], dtype=np.int32
            )
            right = np.array(
                [region_row[graph.partitions[p][2]] for p in l_p], dtype=np.int32
            )
            einsum_global = np.arange(next_row, next_row + len(l_p), dtype=np.int32)
            next_row += len(l_p)

            mixed_regions = [s for s in l_s if len(graph.region_children[s]) > 1]
            mix_child_local = mix_mask = mix_global = None
            if mixed_regions:
                c_max = max(len(graph.region_children[s]) for s in mixed_regions)
                mix_child_local = np.zeros((len(mixed_regions), c_max), np.int32)
                mix_mask = np.zeros((len(mixed_regions), c_max), np.float32)
                for m, s in enumerate(mixed_regions):
                    kids = [part_local[p] for p in graph.region_children[s]]
                    mix_child_local[m, : len(kids)] = kids
                    mix_mask[m, : len(kids)] = 1.0
                mix_global = np.arange(
                    next_row, next_row + len(mixed_regions), dtype=np.int32
                )
                next_row += len(mixed_regions)
                for m, s in enumerate(mixed_regions):
                    region_row[s] = int(mix_global[m])
            for s in l_s:
                if len(graph.region_children[s]) == 1:
                    p = graph.region_children[s][0]
                    region_row[s] = int(einsum_global[part_local[p]])

            self.pair_specs.append(
                PairSpec(
                    left=left,
                    right=right,
                    einsum_global=einsum_global,
                    k_in=self.K,
                    k_out=k_out,
                    mix_child_local=mix_child_local,
                    mix_mask=mix_mask,
                    mix_global=mix_global,
                    is_final=is_final,
                )
            )
        self.total_rows = next_row  # includes final-layer rows (never buffered)
        self.root_row = region_row[graph.root]
        # rows that live in the value buffer (everything below the final pair)
        final = self.pair_specs[-1]
        self.buffer_rows = final.einsum_global[0]
        self._canonicalize()
        self.needs_buffer = any(not p.canonical for p in self.pair_specs)

    def _canonicalize(self) -> None:
        """Beyond-paper layout optimization: reorder each layer so children
        are contiguous -- the paper's §3.3 'extracting and concatenating
        slices ... bookkeeping overhead' becomes two static slices, which
        also shard with zero collectives (left/right halves of the L-sharded
        output below).  Applies whenever a pair's children are exactly the
        previous layer's outputs, each consumed once (true for every pair of
        the RAT structure); other pairs keep the general gather path."""
        specs = self.pair_specs
        for i in range(len(specs) - 1, -1, -1):
            cur = specs[i]
            child = np.concatenate([cur.left, cur.right])
            if i == 0:
                n = self.leaf_spec.num_leaves
                if len(child) != n or sorted(child.tolist()) != list(range(n)):
                    continue
                # reorder the leaf layer itself
                order = child.tolist()
                ls = self.leaf_spec
                scopes = [ls.leaf_scopes[j] for j in order]
                replica = ls.leaf_replica[order]
                ls.leaf_scopes = scopes
                ls.leaf_replica = replica
                ls.pair_var = np.concatenate(
                    [np.asarray(s, np.int32) for s in scopes])
                ls.pair_rep = np.concatenate([
                    np.full(len(s), replica[j], np.int32)
                    for j, s in enumerate(scopes)])
                ls.pair_leaf = np.concatenate([
                    np.full(len(s), j, np.int32)
                    for j, s in enumerate(scopes)])
                half = len(cur.left)
                cur.left = np.arange(half, dtype=np.int32)
                cur.right = np.arange(half, 2 * half, dtype=np.int32)
                cur.canonical = True
                continue
            prev = specs[i - 1]
            if prev.mix_global is not None:
                continue
            base = int(prev.einsum_global[0])
            rows = prev.einsum_global.tolist()
            if sorted(child.tolist()) != rows:
                continue
            order = [int(r) - base for r in child]  # new local -> old local
            prev.left = prev.left[order]
            prev.right = prev.right[order]
            half = len(cur.left)
            cur.left = prev.einsum_global[:half]
            cur.right = prev.einsum_global[half:]
            cur.canonical = True

    # ------------------------------------------------------------------- plan
    # (the planner itself lives in core.plan: ``plan_circuit`` compiles the
    # pair list into ``self.plan`` at construction time)
    @property
    def grouped_active(self) -> bool:
        """True when the forward/backward hot path runs fused segments."""
        return self.plan.grouped_active

    def grouping_summary(self) -> Dict[str, Any]:
        """Kernel-launch accounting for one forward pass: the per-layer
        schedule vs the grouped plan (benchmarks record this as the
        ``grouping`` field next to wall-clock)."""
        return self.plan.summary()

    # ------------------------------------------------------------- parameters
    def init(self, key: jax.Array) -> Dict[str, Any]:
        keys = jax.random.split(key, len(self.pair_specs) + 2)
        phi = self.ef.init_phi(
            keys[0], (self.num_vars, self.K, self.leaf_spec.num_replica)
        )
        einsum_w = []
        mixing_v = []
        for i, spec in enumerate(self.pair_specs):
            w = jax.random.uniform(
                keys[i + 1],
                (spec.num_partitions, spec.k_out, spec.k_in, spec.k_in),
                minval=0.1,
                maxval=1.0,
            )
            einsum_w.append(normalize_einsum_weights(w))
            if spec.mix_global is not None:
                kv = jax.random.fold_in(keys[i + 1], 1)
                v = jax.random.uniform(
                    kv,
                    (spec.num_mixed, spec.mix_child_local.shape[1], spec.k_out),
                    minval=0.1,
                    maxval=1.0,
                )
                mixing_v.append(
                    normalize_mixing_weights(v, jnp.asarray(spec.mix_mask))
                )
            else:
                mixing_v.append(jnp.zeros((0, 0, spec.k_out)))
        # strong float32: a weak-typed prior changes aval after the first EM
        # update and forces a silent recompile of every jitted training step
        class_prior = jnp.full(
            (self.num_classes,), 1.0 / self.num_classes, dtype=jnp.float32
        )
        return {
            "phi": phi,
            "einsum": einsum_w,
            "mixing": mixing_v,
            "class_prior": class_prior,
        }

    def num_params(self, params: Dict[str, Any]) -> int:
        return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(params))

    # ---------------------------------------------------------------- forward
    def leaf_log_prob(
        self, params: Dict[str, Any], x: jax.Array, marg_mask: Optional[jax.Array]
    ) -> jax.Array:
        """EF tensor E (B, D, K, R), with marginalized variables set to log 1 = 0."""
        e = self.ef.log_prob(x, params["phi"])
        if marg_mask is not None:
            e = jnp.where(marg_mask[:, :, None, None], e, 0.0)
        return e

    def _leaf_rows(self, e: jax.Array) -> jax.Array:
        """Factorize E into leaf-region rows: (B, num_leaves, K)."""
        ls = self.leaf_spec
        b, d, k, r = e.shape
        e_flat = jnp.transpose(e, (1, 3, 0, 2)).reshape(d * r, b, k)
        gathered = e_flat[ls.pair_var * r + ls.pair_rep]  # (P, B, K)
        summed = jax.ops.segment_sum(
            gathered, ls.pair_leaf, num_segments=ls.num_leaves
        )  # (num_leaves, B, K)
        return jnp.transpose(summed, (1, 0, 2))

    def forward_from_e(
        self,
        einsum_w: List[jax.Array],
        mixing_v: List[jax.Array],
        e: Optional[jax.Array],
        return_cache: bool = False,
        leaf_rows: Optional[jax.Array] = None,
    ):
        """Bottom-up pass from the leaf EF tensor (or precomputed leaf rows).
        Returns (B, num_classes) root log-densities (and the per-pair cache
        when ``return_cache``).

        Canonical pairs read their children as two static slices of the layer
        below (zero-gather fast path); the global row buffer is materialized
        only for non-canonical pairs or when the sampling cache is requested.

        When the execution plan has fused segments (``grouped_active``) and
        no cache is requested, the pass walks the plan instead of the pair
        list: each fused segment is one grouped log-einsum-exp (a single
        kernel launch under ``impl="pallas"``), per-layer segments keep the
        existing op.  The sampling path (``return_cache``) needs every
        depth's activations by definition, so it always runs per-layer.
        """
        if leaf_rows is None:
            leaf_rows = self._leaf_rows(e)
        leaf_out = _cst(leaf_rows, ("batch", "einet_nodes", None))
        if self.grouped_active and not return_cache:
            return self._forward_planned(einsum_w, mixing_v, leaf_out)
        buffer = leaf_out
        build_buffer = self.needs_buffer or return_cache
        cache = {"S": []}
        prev_out = leaf_out
        root_out = None
        for i, spec in enumerate(self.pair_specs):
            if spec.canonical:
                half = spec.num_partitions
                n_l = prev_out[:, :half, :]
                n_r = prev_out[:, half: 2 * half, :]
            else:
                n_l = buffer[:, spec.left, :]
                n_r = buffer[:, spec.right, :]
            s = log_einsum_exp(einsum_w[i], n_l, n_r, impl=self.impl)  # (B,L,k)
            s = _cst(s, ("batch", "einet_nodes", None))
            health_lib.tap_segment(s)
            new_rows = [s]
            mix_out = None
            if spec.mix_global is not None:
                ln = s[:, spec.mix_child_local, :]  # (B, M, C, k_out)
                mix_out = log_mix_exp(mixing_v[i], ln, jnp.asarray(spec.mix_mask))
                new_rows.append(mix_out)
            if return_cache:
                cache["S"].append(s)
            if spec.is_final:
                root_out = mix_out if spec.mix_global is not None else s[:, 0, :]
            else:
                prev_out = s if mix_out is None else jnp.concatenate(
                    [s, mix_out], axis=1)
                if build_buffer:
                    buffer = jnp.concatenate([buffer] + new_rows, axis=1)
        if root_out.ndim == 3:  # root was a mixing row: (B, 1, num_classes)
            root_out = root_out[:, 0, :]
        if return_cache:
            cache["buffer"] = buffer
            return root_out, cache
        return root_out

    def _forward_planned(
        self,
        einsum_w: List[jax.Array],
        mixing_v: List[jax.Array],
        leaf_out: jax.Array,
    ) -> jax.Array:
        """The depth-grouped bottom-up pass (``self.plan`` walk).

        All-canonical structures (``needs_buffer`` is False, the RAT family)
        walk "fused"/"layer" segments over the previous layer's outputs --
        no row buffer exists, every pair reads two static slices, and fused
        segments are exactly the canonical chains the grouped kernel
        implements.  Structures with gather topology (PD) walk
        "gather"/"layer" segments over the materialized global row buffer:
        a gather segment is one table-driven kernel covering a run of
        depths (mixing in-kernel), a layer segment is the per-pair op on
        buffer-gathered children.  Either way every segment computes the
        identical per-pair math in the identical order, making this path
        bit-exact against the per-layer loop under ``impl="xla"`` by
        construction.
        """
        if self.needs_buffer:
            return self._forward_planned_buffer(einsum_w, mixing_v, leaf_out)
        prev_out = leaf_out
        root_out = None
        for seg in self.exec_plan:
            last = self.pair_specs[seg.stop - 1]
            # spans fire at TRACE time (this loop runs under jit/AOT
            # lowering): the counter tallies segment lowerings, and an
            # eager profiler (obs.set_sync + jax.disable_jit) reads real
            # per-segment device time through obs.sync
            obs.METRICS.counter("plan.segment.traces", kind=seg.kind).inc()
            with obs.span("plan.segment", kind=seg.kind,
                          start=seg.start, stop=seg.stop):
                if seg.fused:
                    ws = [einsum_w[t] for t in range(seg.start, seg.stop)]
                    s = grouped_log_einsum_exp(
                        ws, prev_out, seg.out_block, seg.block_b,
                        impl=self.impl
                    )
                else:
                    half = last.num_partitions
                    s = log_einsum_exp(
                        einsum_w[seg.start],
                        prev_out[:, :half, :],
                        prev_out[:, half: 2 * half, :],
                        impl=self.impl,
                    )
                s = _cst(s, ("batch", "einet_nodes", None))
                health_lib.tap_segment(s)
                mix_out = None
                if last.mix_global is not None:
                    ln = s[:, last.mix_child_local, :]
                    mix_out = log_mix_exp(
                        mixing_v[seg.stop - 1], ln, jnp.asarray(last.mix_mask)
                    )
                obs.sync(s if mix_out is None else mix_out)
            if last.is_final:
                root_out = mix_out if last.mix_global is not None else s[:, 0, :]
            else:
                prev_out = s if mix_out is None else jnp.concatenate(
                    [s, mix_out], axis=1)
        if root_out.ndim == 3:
            root_out = root_out[:, 0, :]
        return root_out

    def _forward_planned_buffer(
        self,
        einsum_w: List[jax.Array],
        mixing_v: List[jax.Array],
        leaf_out: jax.Array,
    ) -> jax.Array:
        """Row-buffer plan walk for gather-topology structures.

        The buffer is indexed by GLOBAL row id (leaves first, then each
        pair's einsum rows followed by its mixing rows -- the allocation
        order of ``_build``), so a gather segment's output rows append in
        exactly global order and layer segments read ``spec.left`` /
        ``spec.right`` directly.  The planner never emits "fused"
        (slice-tiled) segments here: they skip materializing interior rows,
        which would leave holes in the buffer.
        """
        buffer = leaf_out
        root_out = None
        for seg in self.exec_plan:
            obs.METRICS.counter("plan.segment.traces", kind=seg.kind).inc()
            if seg.kind == "gather":
                with obs.span("plan.segment", kind=seg.kind,
                              start=seg.start, stop=seg.stop):
                    ws = tuple(
                        einsum_w[t] for t in range(seg.start, seg.stop)
                    )
                    vs = tuple(
                        mixing_v[t]
                        for t in range(seg.start, seg.stop)
                        if self.pair_specs[t].mix_global is not None
                    )
                    w0 = buffer.shape[1]
                    buffer = gather_grouped_log_einsum_exp(
                        seg.tables, ws, vs, buffer,
                        block_b=seg.block_b, impl=self.impl,
                    )
                    buffer = _cst(buffer, ("batch", "einet_nodes", None))
                    health_lib.tap_segment(buffer[:, w0:, :])
                    obs.sync(buffer)
                continue
            with obs.span("plan.segment", kind=seg.kind,
                          start=seg.start, stop=seg.stop):
                spec = self.pair_specs[seg.start]
                n_l = buffer[:, spec.left, :]
                n_r = buffer[:, spec.right, :]
                s = log_einsum_exp(
                    einsum_w[seg.start], n_l, n_r, impl=self.impl
                )
                s = _cst(s, ("batch", "einet_nodes", None))
                health_lib.tap_segment(s)
                mix_out = None
                if spec.mix_global is not None:
                    ln = s[:, spec.mix_child_local, :]
                    mix_out = log_mix_exp(
                        mixing_v[seg.start], ln, jnp.asarray(spec.mix_mask)
                    )
                obs.sync(s if mix_out is None else mix_out)
            if spec.is_final:
                root_out = (
                    mix_out if spec.mix_global is not None else s[:, 0, :]
                )
            else:
                new = s if mix_out is None else jnp.concatenate(
                    [s, mix_out], axis=1)
                buffer = jnp.concatenate([buffer, new], axis=1)
        if root_out.ndim == 3:
            root_out = root_out[:, 0, :]
        return root_out

    def forward(
        self,
        params: Dict[str, Any],
        x: jax.Array,
        marg_mask: Optional[jax.Array] = None,
        return_cache: bool = False,
    ):
        e = self.leaf_log_prob(params, x, marg_mask)
        return self.forward_from_e(
            params["einsum"], params["mixing"], e, return_cache=return_cache
        )

    def log_likelihood(
        self,
        params: Dict[str, Any],
        x: jax.Array,
        marg_mask: Optional[jax.Array] = None,
    ) -> jax.Array:
        """log P(x) = logsumexp_c [log prior_c + log P(x | c)], shape (B,)."""
        root = self.forward(params, x, marg_mask)
        return jax.scipy.special.logsumexp(
            root + jnp.log(params["class_prior"])[None, :], axis=-1
        )

    def conditional_log_likelihood(
        self,
        params: Dict[str, Any],
        x: jax.Array,
        query_mask: jax.Array,
        evidence_mask: jax.Array,
    ) -> jax.Array:
        """log p(x_q | x_e) = log p(x_q, x_e) - log p(x_e)  (Eq. 1, exact)."""
        joint = self.log_likelihood(params, x, query_mask | evidence_mask)
        ev = self.log_likelihood(params, x, evidence_mask)
        return joint - ev

    # --------------------------------------------------------------- sampling
    def sample(
        self,
        params: Dict[str, Any],
        key: jax.Array,
        num_samples: int,
        mode: str = "sample",
    ) -> jax.Array:
        """Unconditional ancestral sampling: (num_samples, D)."""
        x = jnp.zeros((num_samples, self.num_vars))
        marg = jnp.zeros((num_samples, self.num_vars), dtype=bool)
        return self.conditional_sample(params, key, x, marg, mode=mode)

    def conditional_sample(
        self,
        params: Dict[str, Any],
        key: jax.Array,
        x: jax.Array,
        evidence_mask: jax.Array,
        mode: str = "sample",
    ) -> jax.Array:
        """Sample X_m ~ p(. | x_e): the Fig. 4 inpainting operation.

        Bottom-up pass with the evidence marginalized out of the complement,
        then a top-down induced-tree pass where every categorical choice is
        re-weighted by the children's (evidence-conditioned) log-likelihoods.
        ``mode='argmax'`` gives a greedy MPE-style decoding instead.
        """
        b = x.shape[0]
        root, cache = self.forward(params, x, evidence_mask, return_cache=True)
        buffer = cache["buffer"]
        dummy = self.total_rows
        comp = jnp.full((b, self.total_rows + 1), -1, dtype=jnp.int32)
        # root class choice
        logits = root + jnp.log(params["class_prior"])[None, :]
        if mode == "argmax":
            c0 = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            key, sub = jax.random.split(key)
            c0 = jax.random.categorical(sub, logits, axis=-1).astype(jnp.int32)
        comp = comp.at[:, self.root_row].set(c0)
        rows_b = jnp.arange(b)[:, None]

        for i in reversed(range(len(self.pair_specs))):
            spec = self.pair_specs[i]
            s_cache = cache["S"][i]  # (B, L, k_out)
            # -- mixing rows first: they activate einsum rows
            if spec.mix_global is not None:
                k = comp[:, spec.mix_global]  # (B, M)
                active = k >= 0
                kk = jnp.maximum(k, 0)
                v = params["mixing"][i]  # (M, C, k_out)
                logv = jnp.log(jnp.maximum(v, 1e-38))  # (M, C, k_out)
                lv = jnp.take_along_axis(
                    logv[None].repeat(b, 0), kk[:, :, None, None], axis=3
                )[..., 0]  # (B, M, C)
                child_ll = s_cache[:, spec.mix_child_local, :]  # (B, M, C, k_out)
                cll = jnp.take_along_axis(child_ll, kk[:, :, None, None], axis=3)[
                    ..., 0
                ]  # (B, M, C)
                logits = jnp.where(
                    jnp.asarray(spec.mix_mask)[None] > 0, lv + cll, NEG_INF
                )
                if mode == "argmax":
                    cidx = jnp.argmax(logits, axis=-1)
                else:
                    key, sub = jax.random.split(key)
                    cidx = jax.random.categorical(sub, logits, axis=-1)
                child_local = jnp.take_along_axis(
                    jnp.asarray(spec.mix_child_local)[None].repeat(b, 0),
                    cidx[:, :, None],
                    axis=2,
                )[..., 0]  # (B, M)
                child_global = jnp.asarray(spec.einsum_global)[child_local]
                rows = jnp.where(active, child_global, dummy)
                comp = comp.at[rows_b, rows].set(kk)
            # -- einsum rows: choose (i, j) and activate the two children
            k = comp[:, spec.einsum_global]  # (B, L)
            active = k >= 0
            kk = jnp.maximum(k, 0)
            w = params["einsum"][i]  # (L, k_out, K, K)
            wk = w[jnp.arange(spec.num_partitions)[None], kk]  # (B, L, K, K)
            n_l = buffer[:, spec.left, :]  # (B, L, K)
            n_r = buffer[:, spec.right, :]
            logits = (
                jnp.log(jnp.maximum(wk, 1e-38))
                + n_l[:, :, :, None]
                + n_r[:, :, None, :]
            ).reshape(b, spec.num_partitions, -1)
            if mode == "argmax":
                flat = jnp.argmax(logits, axis=-1)
            else:
                key, sub = jax.random.split(key)
                flat = jax.random.categorical(sub, logits, axis=-1)
            ii = (flat // self.K).astype(jnp.int32)
            jj = (flat % self.K).astype(jnp.int32)
            lrows = jnp.where(active, jnp.asarray(spec.left)[None], dummy)
            rrows = jnp.where(active, jnp.asarray(spec.right)[None], dummy)
            comp = comp.at[rows_b, lrows].set(ii)
            comp = comp.at[rows_b, rrows].set(jj)

        # -- leaves: sample every variable of every active leaf
        ls = self.leaf_spec
        k_leaf = comp[:, : ls.num_leaves]  # (B, num_leaves)
        k_p = k_leaf[:, ls.pair_leaf]  # (B, P)
        act_p = k_p >= 0
        kk = jnp.maximum(k_p, 0)
        phi = params["phi"][ls.pair_var, :, ls.pair_rep]  # (P, K, T)
        phi_sel = jnp.take_along_axis(
            phi[None].repeat(b, 0), kk[:, :, None, None], axis=2
        )[:, :, 0, :]  # (B, P, T)
        key, sub = jax.random.split(key)
        if mode == "argmax":
            draws = self.ef.mode(phi_sel)  # deterministic MPE-style decode
        else:
            draws = self.ef.sample(sub, phi_sel)  # (B, P)
        cols = jnp.where(act_p, jnp.asarray(ls.pair_var)[None], self.num_vars)
        out = jnp.zeros((b, self.num_vars + 1))
        out = out.at[rows_b, cols].set(draws)[:, : self.num_vars]
        return jnp.where(evidence_mask, x, out)

    def conditional_sample_per_key(
        self,
        params: Dict[str, Any],
        keys: jax.Array,
        x: jax.Array,
        evidence_mask: jax.Array,
        mode: str = "sample",
    ) -> jax.Array:
        """Row-independent conditional sampling: one PRNG key per batch row.

        vmap over the batch makes every row's draw a pure function of its own
        (key, x, evidence) triple -- results are invariant to how requests
        are coalesced into micro-batches, which is what lets the serving
        engine pad buckets with filler rows without perturbing real rows.
        """

        def one(k, xi, ei):
            return self.conditional_sample(
                params, k, xi[None], ei[None], mode=mode
            )[0]

        return jax.vmap(one)(keys, x, evidence_mask)

    # ----------------------------------------------------------------- query
    def query(self, params: Dict[str, Any], batch: Dict[str, Any],
              kind: str) -> jax.Array:
        """Uniform exact-inference entry point (the serving-engine surface).

        ``batch`` carries "x" (B, D) float32, "evidence_mask" / "query_mask"
        (B, D) bool, and "keys" (B, 2) uint32 per-row PRNG keys; each kind
        ignores the fields it does not need, so one input signature covers
        every program in the serving cache.

        Kinds: "joint_ll" -> (B,) log p(x); "marginal_ll" -> (B,) log p(x_e);
        "conditional_ll" -> (B,) log p(x_q | x_e); "sample" -> (B, D)
        unconditional draws; "conditional_sample" -> (B, D) draws of the
        evidence complement; "mpe" -> (B, D) greedy argmax decode.
        """
        x = batch["x"]
        if kind == "joint_ll":
            return self.log_likelihood(params, x)
        if kind == "marginal_ll":
            return self.log_likelihood(params, x, batch["evidence_mask"])
        if kind == "conditional_ll":
            return self.conditional_log_likelihood(
                params, x, batch["query_mask"], batch["evidence_mask"]
            )
        if kind == "sample":
            return self.conditional_sample_per_key(
                params, batch["keys"], jnp.zeros_like(x),
                jnp.zeros_like(batch["evidence_mask"]),
            )
        if kind == "conditional_sample":
            return self.conditional_sample_per_key(
                params, batch["keys"], x, batch["evidence_mask"]
            )
        if kind == "mpe":
            return self.conditional_sample_per_key(
                params, batch["keys"], x, batch["evidence_mask"], mode="argmax"
            )
        raise ValueError(f"unknown query kind {kind!r}; one of {QUERY_KINDS}")

    # ------------------------------------------------------------- projection
    def project_params(self, params: Dict[str, Any]) -> Dict[str, Any]:
        """Re-normalize all weights + clamp EF parameters to valid domains."""
        out = dict(params)
        out["phi"] = self.ef.project_phi(params["phi"])
        out["einsum"] = [normalize_einsum_weights(w) for w in params["einsum"]]
        out["mixing"] = [
            normalize_mixing_weights(v, jnp.asarray(spec.mix_mask))
            if spec.mix_global is not None
            else v
            for v, spec in zip(params["mixing"], self.pair_specs)
        ]
        out["class_prior"] = jnp.maximum(params["class_prior"], 1e-12)
        out["class_prior"] = out["class_prior"] / jnp.sum(out["class_prior"])
        return out
