"""Paper core: Einsum Networks (Peharz et al., ICML 2020) in JAX."""

from repro.core.baseline import NaiveEiNet
from repro.core.einet import QUERY_KINDS, EiNet
from repro.core.em import (
    EMConfig,
    accumulate_statistics,
    blend_params,
    em_statistics,
    em_update,
    m_step,
    stochastic_em_update,
    zeros_like_statistics,
)
from repro.core.exponential_family import (
    Bernoulli,
    Binomial,
    Categorical,
    Normal,
    make_exponential_family,
)
from repro.core.region_graph import (
    RegionGraph,
    assign_replicas,
    poon_domingos,
    random_binary_trees,
    topological_layers,
)

__all__ = [
    "EiNet",
    "QUERY_KINDS",
    "NaiveEiNet",
    "EMConfig",
    "em_statistics",
    "em_update",
    "m_step",
    "stochastic_em_update",
    "blend_params",
    "accumulate_statistics",
    "zeros_like_statistics",
    "Normal",
    "Bernoulli",
    "Binomial",
    "Categorical",
    "make_exponential_family",
    "RegionGraph",
    "random_binary_trees",
    "poon_domingos",
    "topological_layers",
    "assign_replicas",
]
