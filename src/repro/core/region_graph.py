"""Region graphs: the structural skeleton of vectorized PCs (§3.1).

A region graph is a bipartite DAG of *regions* (variable scopes -> vectorized
sum/leaf nodes) and *partitions* (binary scope splits -> vectorized product
nodes).  Two constructions from the paper:

  * ``random_binary_trees``  -- the RAT-SPN structure (Peharz et al., 2019)
    used in the efficiency study (Fig. 3/6) and Table 1: R replica of randomized
    balanced binary splits down to depth D, mixed at the root.
  * ``poon_domingos``        -- the image-tailored PD structure (Poon &
    Domingos, 2011) used for SVHN/CelebA (§4.2): recursive axis-aligned
    rectangle splits at absolute multiples of a step size Delta.

``topological_layers`` implements Algorithm 1 of the paper verbatim: a
top-down sweep that emits alternating (product-layer, sum-layer) pairs such
that every node's parents live in strictly higher layers.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Sequence, Tuple

import numpy as np

Scope = Tuple[int, ...]  # sorted variable indices


@dataclasses.dataclass
class RegionGraph:
    num_vars: int
    regions: List[Scope]  # region id -> scope
    partitions: List[Tuple[int, int, int]]  # partition id -> (parent, left, right)
    root: int

    # derived
    def __post_init__(self):
        self.region_children: Dict[int, List[int]] = {
            i: [] for i in range(len(self.regions))
        }
        for pid, (parent, _, _) in enumerate(self.partitions):
            self.region_children[parent].append(pid)
        # parents of a region = partitions that reference it as a child
        self.region_parents: Dict[int, List[int]] = {
            i: [] for i in range(len(self.regions))
        }
        for pid, (_, left, right) in enumerate(self.partitions):
            self.region_parents[left].append(pid)
            self.region_parents[right].append(pid)

    @property
    def leaf_ids(self) -> List[int]:
        return [i for i in range(len(self.regions)) if not self.region_children[i]]

    @property
    def internal_ids(self) -> List[int]:
        return [i for i in range(len(self.regions)) if self.region_children[i]]

    def validate(self) -> None:
        """Check smoothness + decomposability structurally (Definition 1)."""
        for parent, left, right in self.partitions:
            sl, sr, sp = (
                set(self.regions[left]),
                set(self.regions[right]),
                set(self.regions[parent]),
            )
            assert sl and sr, "empty child scope"
            assert not (sl & sr), f"decomposability violated: {sl & sr}"
            assert sl | sr == sp, "partition children must cover the parent scope"
        assert set(self.regions[self.root]) == set(range(self.num_vars))


class _Builder:
    def __init__(self, num_vars: int):
        self.num_vars = num_vars
        self._scope_to_id: Dict[Scope, int] = {}
        self.regions: List[Scope] = []
        self.partitions: List[Tuple[int, int, int]] = []
        self._seen_partitions = set()

    def region(self, scope: Sequence[int]) -> int:
        scope = tuple(sorted(scope))
        if scope not in self._scope_to_id:
            self._scope_to_id[scope] = len(self.regions)
            self.regions.append(scope)
        return self._scope_to_id[scope]

    def partition(self, parent: int, left: int, right: int) -> None:
        key = (parent, left, right)
        if key in self._seen_partitions or (parent, right, left) in self._seen_partitions:
            return
        self._seen_partitions.add(key)
        self.partitions.append(key)

    def build(self) -> RegionGraph:
        root = self.region(tuple(range(self.num_vars)))
        rg = RegionGraph(self.num_vars, self.regions, self.partitions, root)
        rg.validate()
        return rg


def random_binary_trees(
    num_vars: int, depth: int, num_repetitions: int, seed: int = 0
) -> RegionGraph:
    """RAT-SPN structure: R randomized balanced binary trees mixed at the root."""
    if 2**depth > num_vars:
        raise ValueError(f"depth {depth} too large for {num_vars} variables")
    rng = np.random.RandomState(seed)
    b = _Builder(num_vars)
    root = b.region(range(num_vars))

    def split(region_id: int, scope: Scope, d: int) -> None:
        if d == 0 or len(scope) <= 1:
            return
        perm = rng.permutation(len(scope))
        half = len(scope) // 2
        left_scope = tuple(sorted(scope[i] for i in perm[:half]))
        right_scope = tuple(sorted(scope[i] for i in perm[half:]))
        left, right = b.region(left_scope), b.region(right_scope)
        b.partition(region_id, left, right)
        split(left, left_scope, d - 1)
        split(right, right_scope, d - 1)

    for _ in range(num_repetitions):
        split(root, tuple(range(num_vars)), depth)
    return b.build()


def poon_domingos(
    height: int,
    width: int,
    delta: float | Sequence[float],
    num_channels: int = 1,
    axes: Sequence[str] = ("h", "w"),
    max_cuts_per_rect: int | None = None,
) -> RegionGraph:
    """Poon-Domingos image structure.

    Variables are pixels x channels, id = (r * width + c) * num_channels + ch.
    A rectangle's scope contains all channel variables of its pixels.  Cuts are
    placed at absolute coordinates that are multiples of any value in ``delta``;
    the recursion stops when a rectangle admits no cut (the paper's stopping
    rule).  ``axes=('w',)`` reproduces the paper's vertical-splits-only choice
    for SVHN/CelebA.
    """
    deltas = [delta] if np.isscalar(delta) else list(delta)
    b = _Builder(height * width * num_channels)

    def rect_scope(r0, r1, c0, c1) -> Scope:
        return tuple(
            (r * width + c) * num_channels + ch
            for r in range(r0, r1)
            for c in range(c0, c1)
            for ch in range(num_channels)
        )

    def cut_positions(lo: int, hi: int) -> List[int]:
        pos = set()
        for d in deltas:
            k = int(np.ceil(lo / d)) * d
            # absolute multiples of d strictly inside (lo, hi)
            vals = np.arange(k if k > lo else k + d, hi, d)
            pos.update(int(v) for v in vals if lo < v < hi)
        return sorted(pos)

    root_rect = (0, height, 0, width)
    rect_ids: Dict[Tuple[int, int, int, int], int] = {}
    stack = [root_rect]
    while stack:
        rect = stack.pop()
        if rect in rect_ids:
            continue
        r0, r1, c0, c1 = rect
        rid = b.region(rect_scope(*rect))
        rect_ids[rect] = rid
        cuts = []
        if "h" in axes:
            cuts += [("h", p) for p in cut_positions(r0, r1)]
        if "w" in axes:
            cuts += [("w", p) for p in cut_positions(c0, c1)]
        if max_cuts_per_rect is not None:
            cuts = cuts[:max_cuts_per_rect]
        for axis, p in cuts:
            if axis == "h":
                top, bot = (r0, p, c0, c1), (p, r1, c0, c1)
            else:
                top, bot = (r0, r1, c0, p), (r0, r1, p, c1)
            lid = b.region(rect_scope(*top))
            rid2 = b.region(rect_scope(*bot))
            b.partition(rid, lid, rid2)
            stack.append(top)
            stack.append(bot)
    return b.build()


def topological_layers(
    rg: RegionGraph,
) -> Tuple[List[int], List[Tuple[List[int], List[int]]]]:
    """Algorithm 1: layer the graph top-down, return it bottom-up.

    Returns ``(leaf_region_ids, pairs)`` where ``pairs`` is a bottom-up list of
    (partition_layer, sum_region_layer): the partition layer contains exactly
    the product inputs of the sum layer above it (paper §3.3 / Appendix A).
    """
    leaf_set = set(rg.leaf_ids)
    sums = [r for r in rg.internal_ids]
    visited = set()
    pairs_top_down: List[Tuple[List[int], List[int]]] = []
    remaining_sums = set(sums)
    remaining_parts = set(range(len(rg.partitions)))
    guard = 0
    while remaining_sums or remaining_parts:
        guard += 1
        if guard > len(rg.regions) + len(rg.partitions) + 2:
            raise RuntimeError("topological layering did not converge (cycle?)")
        l_s = [
            s
            for s in sorted(remaining_sums)
            if all(("P", p) in visited for p in rg.region_parents[s])
        ]
        for s in l_s:
            visited.add(("S", s))
        remaining_sums -= set(l_s)
        l_p = [
            p
            for p in sorted(remaining_parts)
            if ("S", rg.partitions[p][0]) in visited
        ]
        for p in l_p:
            visited.add(("P", p))
        remaining_parts -= set(l_p)
        if not l_s and not l_p:
            raise RuntimeError("stuck: graph is not layerable")
        pairs_top_down.append((l_p, l_s))
    pairs = list(reversed(pairs_top_down))
    leaves = sorted(leaf_set)
    return leaves, pairs


def assign_replicas(leaf_scopes: Sequence[Scope]) -> Tuple[np.ndarray, int]:
    """Greedy colouring: leaves sharing a replica must have disjoint scopes (§3.4)."""
    replica_vars: List[set] = []
    out = np.zeros(len(leaf_scopes), dtype=np.int32)
    for i, scope in enumerate(leaf_scopes):
        s = set(scope)
        for r, used in enumerate(replica_vars):
            if not (s & used):
                used |= s
                out[i] = r
                break
        else:
            replica_vars.append(set(s))
            out[i] = len(replica_vars) - 1
    return out, len(replica_vars)
