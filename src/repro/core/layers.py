"""The einsum layer and mixing layer (paper §3.2, §3.3, Appendix B).

Everything probabilistic lives in the log-domain; the weight tensors live in
the *linear* domain.  Numerical stability comes from the paper's
log-einsum-exp trick (Eq. 4): subtract per-row maxes before ``exp`` so the
einsum contracts numbers in (0, 1], then add the maxes back after the ``log``.

``log_einsum_exp`` dispatches between a pure-XLA einsum path (used on CPU and
as the autodiff path for EM) and the fused Pallas TPU kernel in
``repro.kernels`` (used for the forward hot loop on TPU).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# Large-negative stand-in for log(0): keeps gradients finite where jnp.inf
# would produce NaNs through max/exp.
NEG_INF = -1e30


def log_einsum_exp(w: jax.Array, ln_left: jax.Array, ln_right: jax.Array,
                   impl: str = "xla") -> jax.Array:
    """Eq. (5) with the log-einsum-exp trick of Eq. (4).

    Args:
      w:        (L, K_out, K, K) linear-domain weights, normalized over (i, j).
      ln_left:  (B, L, K) log-densities of the "left" product children.
      ln_right: (B, L, K) log-densities of the "right" product children.
      impl:     "xla" | "pallas".

    Returns:
      (B, L, K_out) log-densities  log S[b,l,k] = log sum_ij W[l,k,i,j]
                                                  exp(ln_left[b,l,i])
                                                  exp(ln_right[b,l,j]).
    """
    if impl == "pallas":
        from repro.kernels import ops as _kops

        return _kops.log_einsum_exp(w, ln_left, ln_right)
    if impl == "naive":
        from repro.core.baseline import log_einsum_exp_naive

        return log_einsum_exp_naive(w, ln_left, ln_right)
    a = jnp.max(ln_left, axis=-1, keepdims=True)  # (B, L, 1)
    ap = jnp.max(ln_right, axis=-1, keepdims=True)
    # Guard fully-marginalized / degenerate rows where the max itself is -inf.
    a = jnp.maximum(a, NEG_INF)
    ap = jnp.maximum(ap, NEG_INF)
    el = jnp.exp(ln_left - a)  # in (0, 1]
    er = jnp.exp(ln_right - ap)
    s = jnp.einsum("lkij,bli,blj->blk", w, el, er)
    return a + ap + jnp.log(s)


def grouped_log_einsum_exp(ws, x, out_block: int, block_b: int = 128,
                           impl: str = "xla"):
    """One fused execution segment: a run of consecutive CANONICAL einsum
    layers (left = rows [0, L), right = rows [L, 2L) of the layer below),
    applied bottom-up to ``x`` (B, 2 * L_first, K).

    With ``impl == "pallas"`` the whole run is ONE kernel launch
    (``repro.kernels.grouped``): intermediate log-activations live in VMEM
    and never round-trip HBM.  Other impls execute the run as the chained
    per-depth op -- computationally identical to the per-layer loop (same
    einsum per depth, same order), so grouped XLA execution is bit-exact
    against the per-layer path by construction.

    Returns (B, L_last, K_out_last).
    """
    if impl == "pallas":
        from repro.kernels import ops as _kops

        return _kops.grouped_log_einsum_exp(out_block, block_b, tuple(ws), x)
    cur = x
    for w in ws:
        half = w.shape[0]
        cur = log_einsum_exp(w, cur[:, :half], cur[:, half: 2 * half],
                             impl=impl)
    return cur


def gather_grouped_log_einsum_exp(tables, ws, vs, x, block_b: int = 128,
                                  impl: str = "xla"):
    """One fused GATHER execution segment: a run of consecutive pairs whose
    child access is a static row lookup (Poon-Domingos topologies), applied
    bottom-up to the global row buffer ``x`` (B, r_in, K).

    ``tables`` is a ``core.plan.GatherTables``: per-depth left/right child
    row ids (into the growing buffer, global numbering) plus per-depth
    mixing tables (local indices into that depth's einsum outputs).

    With ``impl == "pallas"`` the whole run is ONE kernel launch
    (``repro.kernels.grouped``): the row buffer lives in VMEM, child
    lookups are static stacks baked at trace time, and mixing layers run
    in-kernel.  Other impls execute the run as chained take-along-axis +
    per-depth ops -- the same ``log_einsum_exp`` / ``log_mix_exp`` on the
    same gathered rows, with the buffer concatenated incrementally per
    depth exactly as the per-layer loop does, so grouped XLA execution is
    bit-exact against the per-layer path FORWARD AND BACKWARD by
    construction (an identical graph accumulates identically; returning
    only the new rows and concatenating outside would re-associate the
    cross-depth cotangent sums by ulps).

    Returns (B, r_in + r_new, K): the EXTENDED row buffer -- the input rows
    followed by every new row the run emits (einsum rows then mixing rows
    per depth, in global row order).
    """
    if impl == "pallas":
        from repro.kernels import ops as _kops

        new = _kops.gather_grouped_log_einsum_exp(
            tables, block_b, tuple(ws), tuple(vs), x
        )
        return jnp.concatenate([x, new], axis=1)
    buf = x
    vi = 0
    for t in range(tables.num_depths):
        left = np.asarray(tables.left[t])
        right = np.asarray(tables.right[t])
        s = log_einsum_exp(ws[t], buf[:, left, :], buf[:, right, :],
                           impl=impl)
        piece = s
        if tables.mix_child[t] is not None:
            child = np.asarray(tables.mix_child[t])
            mask = jnp.asarray(tables.mix_mask[t], jnp.float32)
            m = log_mix_exp(vs[vi], s[:, child, :], mask)
            vi += 1
            piece = jnp.concatenate([s, m], axis=1)
        buf = jnp.concatenate([buf, piece], axis=1)
    return buf


# Floor for the stabilized sum when dividing the backward cotangent: must be
# a NORMAL float32 (XLA flushes subnormals to zero -- a 1e-38 floor becomes
# g / 0 = inf on fully-saturated rows).  Same contract as the fused
# log-einsum-exp backward kernel (kernels/log_einsum_exp.py).
_S_FLOOR = 1e-30


def _log_mix_exp_frame(v, ln, mask):
    """The mixing layer's stabilized frame: (masked ln, clamped max, exp'd
    inputs, stabilized sum).  Shared bit-exactly by the forward and the
    custom backward, which recomputes it from the residuals instead of
    letting XLA autodiff save/reconstruct intermediates."""
    lnm = jnp.where(mask[None, :, :, None] > 0, ln, NEG_INF)
    a = jnp.maximum(jnp.max(lnm, axis=2, keepdims=True), NEG_INF)  # (B,M,1,K)
    e = jnp.exp(lnm - a)  # (B, M, C, K)
    s = jnp.sum(v[None] * e, axis=2)  # (B, M, K)
    return a, e, s


@jax.custom_vjp
def log_mix_exp(v: jax.Array, ln: jax.Array, mask: jax.Array) -> jax.Array:
    """Mixing layer (Appendix B): element-wise mixtures over C children.

    Args:
      v:    (M, C, K) linear-domain mixing weights, normalized over C;
            padded children carry zero weight.
      ln:   (B, M, C, K) log-densities of the C simple-sum children.
      mask: (M, C) 1.0 for real children, 0.0 for padding.

    Returns:
      (B, M, K) log-densities  log sum_c v[m,c,k] exp(ln[b,m,c,k]).

    Carries a fused custom VJP (the last op of the EM update off the XLA
    autodiff path): the backward recomputes the forward's stabilized frame
    from the (v, ln, mask) residuals -- same residual-recompute contract as
    the fused ``log_einsum_exp`` backward -- and emits both gradients

        dv[m,c,k]    = sum_b g[b,m,k] exp(ln[b,m,c,k] - a) / s
        dln[b,m,c,k] = g[b,m,k] v[m,c,k] exp(ln[b,m,c,k] - a) / s

    in one pass, with padded children explicitly zeroed (on fully
    marginalized NEG_INF rows ``exp(ln - a) = 1`` even where mask == 0, so
    masking the gradient is load-bearing, not cosmetic).
    """
    a, _, s = _log_mix_exp_frame(v, ln, mask)
    return a[:, :, 0, :] + jnp.log(s)


def log_mix_exp_ref(v: jax.Array, ln: jax.Array, mask: jax.Array) -> jax.Array:
    """The pure-XLA-autodiff reference (identical forward values): the grad
    parity oracle for the fused VJP (tests/test_kernels.py)."""
    a, _, s = _log_mix_exp_frame(v, ln, mask)
    return a[:, :, 0, :] + jnp.log(s)


def _lme_fwd(v, ln, mask):
    # residuals are the unpadded primals; the backward re-derives the frame
    # bit-exactly (cheap: one max + one exp sweep) so no forward
    # intermediate -- and no log -- needs to live in residual memory
    return log_mix_exp(v, ln, mask), (v, ln, mask)


def _lme_bwd(res, g):
    v, ln, mask = res
    _, e, s = _log_mix_exp_frame(v, ln, mask)
    ginv = g / jnp.maximum(s, _S_FLOOR)  # (B, M, K)
    gmask = mask[None, :, :, None]
    ge = ginv[:, :, None, :] * e * gmask  # (B, M, C, K), padding zeroed
    gv = jnp.sum(ge, axis=0)  # (M, C, K)
    gln = ge * v[None]
    return gv, gln, jnp.zeros_like(mask)


log_mix_exp.defvjp(_lme_fwd, _lme_bwd)


def normalize_einsum_weights(w: jax.Array, floor: float = 1e-12) -> jax.Array:
    """Project W onto the simplex over its last two axes (sum-weight constraint)."""
    w = jnp.maximum(w, floor)
    return w / jnp.sum(w, axis=(-2, -1), keepdims=True)


def normalize_mixing_weights(v: jax.Array, mask: jax.Array,
                             floor: float = 1e-12) -> jax.Array:
    """Project V onto the simplex over the child axis, respecting padding."""
    v = jnp.maximum(v, floor) * mask[:, :, None]
    return v / jnp.sum(v, axis=1, keepdims=True)
