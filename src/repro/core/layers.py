"""The einsum layer and mixing layer (paper §3.2, §3.3, Appendix B).

Everything probabilistic lives in the log-domain; the weight tensors live in
the *linear* domain.  Numerical stability comes from the paper's
log-einsum-exp trick (Eq. 4): subtract per-row maxes before ``exp`` so the
einsum contracts numbers in (0, 1], then add the maxes back after the ``log``.

``log_einsum_exp`` dispatches between a pure-XLA einsum path (used on CPU and
as the autodiff path for EM) and the fused Pallas TPU kernel in
``repro.kernels`` (used for the forward hot loop on TPU).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# Large-negative stand-in for log(0): keeps gradients finite where jnp.inf
# would produce NaNs through max/exp.
NEG_INF = -1e30


def log_einsum_exp(w: jax.Array, ln_left: jax.Array, ln_right: jax.Array,
                   impl: str = "xla") -> jax.Array:
    """Eq. (5) with the log-einsum-exp trick of Eq. (4).

    Args:
      w:        (L, K_out, K, K) linear-domain weights, normalized over (i, j).
      ln_left:  (B, L, K) log-densities of the "left" product children.
      ln_right: (B, L, K) log-densities of the "right" product children.
      impl:     "xla" | "pallas".

    Returns:
      (B, L, K_out) log-densities  log S[b,l,k] = log sum_ij W[l,k,i,j]
                                                  exp(ln_left[b,l,i])
                                                  exp(ln_right[b,l,j]).
    """
    if impl == "pallas":
        from repro.kernels import ops as _kops

        return _kops.log_einsum_exp(w, ln_left, ln_right)
    if impl == "naive":
        from repro.core.baseline import log_einsum_exp_naive

        return log_einsum_exp_naive(w, ln_left, ln_right)
    a = jnp.max(ln_left, axis=-1, keepdims=True)  # (B, L, 1)
    ap = jnp.max(ln_right, axis=-1, keepdims=True)
    # Guard fully-marginalized / degenerate rows where the max itself is -inf.
    a = jnp.maximum(a, NEG_INF)
    ap = jnp.maximum(ap, NEG_INF)
    el = jnp.exp(ln_left - a)  # in (0, 1]
    er = jnp.exp(ln_right - ap)
    s = jnp.einsum("lkij,bli,blj->blk", w, el, er)
    return a + ap + jnp.log(s)


def log_mix_exp(v: jax.Array, ln: jax.Array, mask: jax.Array) -> jax.Array:
    """Mixing layer (Appendix B): element-wise mixtures over C children.

    Args:
      v:    (M, C, K) linear-domain mixing weights, normalized over C;
            padded children carry zero weight.
      ln:   (B, M, C, K) log-densities of the C simple-sum children.
      mask: (M, C) 1.0 for real children, 0.0 for padding.

    Returns:
      (B, M, K) log-densities  log sum_c v[m,c,k] exp(ln[b,m,c,k]).
    """
    ln = jnp.where(mask[None, :, :, None] > 0, ln, NEG_INF)
    a = jnp.max(ln, axis=2, keepdims=True)  # (B, M, 1, K)
    a = jnp.maximum(a, NEG_INF)
    s = jnp.sum(v[None] * jnp.exp(ln - a), axis=2)
    return a[:, :, 0, :] + jnp.log(s)


def normalize_einsum_weights(w: jax.Array, floor: float = 1e-12) -> jax.Array:
    """Project W onto the simplex over its last two axes (sum-weight constraint)."""
    w = jnp.maximum(w, floor)
    return w / jnp.sum(w, axis=(-2, -1), keepdims=True)


def normalize_mixing_weights(v: jax.Array, mask: jax.Array,
                             floor: float = 1e-12) -> jax.Array:
    """Project V onto the simplex over the child axis, respecting padding."""
    v = jnp.maximum(v, floor) * mask[:, :, None]
    return v / jnp.sum(v, axis=1, keepdims=True)
