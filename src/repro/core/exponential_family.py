"""Exponential-family input distributions for Einsum Networks.

The paper (§3.4) computes the whole input layer as one ``D x K x R`` tensor of
exponential-family (EF) log-densities

    log L = log h(x) + T(x)^T theta - A(theta),

with parameters kept in *expectation form* ``phi`` (Sato, 1999) so that the EM
M-step is a simple moment average:  phi <- (sum_x p_L(x) T(x)) / (sum_x p_L(x)).

Each EF below provides:
  * ``num_stats``                      -- |T|, dimensionality of T(x)
  * ``sufficient_statistics(x)``       -- (...,) -> (..., |T|)
  * ``log_h(x)``                       -- base measure, (...,) -> (...,)
  * ``expectation_to_natural(phi)``    -- theta(phi), (..., |T|) -> (..., |T|)
  * ``log_normalizer(theta)``          -- A(theta), (..., |T|) -> (...,)
  * ``sample(key, phi, shape)``        -- ancestral sampling at the leaves
  * ``init_phi(key, shape)``           -- random valid initialization
  * ``project_phi(phi)``               -- clamp to the valid domain (e.g. the
                                          paper projects Gaussian variances to
                                          [1e-6, 1e-2] after each EM update)

Parameter tensors have shape ``(D, K, R, |T|)``: D variables, K densities per
leaf vector, R replica (paper notation).  ``log_prob`` evaluates all D*K*R
densities in a handful of parallel primitives (inner product + A(theta)),
exactly the layout of Eq. "E" in §3.4.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ExponentialFamily:
    """Abstract EF over a single scalar variable (vectorized over leading dims)."""

    name: str = "abstract"

    # --- interface -----------------------------------------------------------
    @property
    def num_stats(self) -> int:
        raise NotImplementedError

    def sufficient_statistics(self, x: jax.Array) -> jax.Array:
        raise NotImplementedError

    def log_h(self, x: jax.Array) -> jax.Array:
        raise NotImplementedError

    def expectation_to_natural(self, phi: jax.Array) -> jax.Array:
        raise NotImplementedError

    def log_normalizer(self, theta: jax.Array) -> jax.Array:
        raise NotImplementedError

    def sample(self, key: jax.Array, phi: jax.Array) -> jax.Array:
        raise NotImplementedError

    def init_phi(self, key: jax.Array, shape: Tuple[int, ...]) -> jax.Array:
        raise NotImplementedError

    def project_phi(self, phi: jax.Array) -> jax.Array:
        return phi

    def mode(self, phi: jax.Array) -> jax.Array:
        """Distribution mode (deterministic decode for argmax sampling)."""
        raise NotImplementedError

    def clamp_fraction(self, phi: jax.Array) -> jax.Array:
        """Fraction of leaf parameters pinned at their projection bounds
        (scalar float32) -- the health telemetry's leak detector for EM
        updates that keep slamming into ``project_phi``'s clamps.  Families
        without hard bounds report 0."""
        return jnp.zeros((), jnp.float32)

    # --- shared machinery ----------------------------------------------------
    def log_prob(self, x: jax.Array, phi: jax.Array) -> jax.Array:
        """All-leaves log density tensor (the paper's ``E``).

        Args:
          x:   (B, D) observations.
          phi: (D, K, R, |T|) expectation parameters.

        Returns:
          (B, D, K, R) log-densities.
        """
        theta = self.expectation_to_natural(phi)  # (D, K, R, T)
        t = self.sufficient_statistics(x)  # (B, D, T)
        # inner product T(x)^T theta, broadcast over (K, R)
        dot = jnp.einsum("bdt,dkrt->bdkr", t, theta)
        a = self.log_normalizer(theta)  # (D, K, R)
        return self.log_h(x)[:, :, None, None] + dot - a[None]


class Normal(ExponentialFamily):
    """Univariate Gaussian.  T(x) = [x, x^2], phi = [mu, mu^2 + sigma^2]."""

    def __init__(self, min_var: float = 1e-6, max_var: float = 10.0):
        object.__setattr__(self, "name", "normal")
        object.__setattr__(self, "min_var", float(min_var))
        object.__setattr__(self, "max_var", float(max_var))

    @property
    def num_stats(self) -> int:
        return 2

    def sufficient_statistics(self, x):
        return jnp.stack([x, x * x], axis=-1)

    def log_h(self, x):
        return jnp.full(x.shape, -0.5 * jnp.log(2.0 * jnp.pi), x.dtype)

    def _moments(self, phi):
        mu = phi[..., 0]
        var = phi[..., 1] - mu * mu
        var = jnp.clip(var, self.min_var, self.max_var)
        return mu, var

    def expectation_to_natural(self, phi):
        mu, var = self._moments(phi)
        return jnp.stack([mu / var, -0.5 / var], axis=-1)

    def log_normalizer(self, theta):
        # A(theta) = -theta1^2 / (4 theta2) - 0.5 log(-2 theta2)
        return -(theta[..., 0] ** 2) / (4.0 * theta[..., 1]) - 0.5 * jnp.log(
            -2.0 * theta[..., 1]
        )

    def sample(self, key, phi):
        mu, var = self._moments(phi)
        return mu + jnp.sqrt(var) * jax.random.normal(key, mu.shape, mu.dtype)

    def init_phi(self, key, shape):
        k1, _ = jax.random.split(key)
        mu = jax.random.normal(k1, shape) * 0.5
        var = jnp.ones(shape)
        return jnp.stack([mu, mu * mu + var], axis=-1)

    def mode(self, phi):
        return phi[..., 0]

    def project_phi(self, phi):
        mu, var = self._moments(phi)
        return jnp.stack([mu, mu * mu + var], axis=-1)

    def clamp_fraction(self, phi):
        mu = phi[..., 0]
        raw_var = phi[..., 1] - mu * mu
        pinned = (raw_var <= self.min_var) | (raw_var >= self.max_var)
        return jnp.mean(pinned.astype(jnp.float32))


class Bernoulli(ExponentialFamily):
    """x in {0,1}.  T(x) = [x], phi = [p]."""

    def __init__(self, min_p: float = 1e-6):
        object.__setattr__(self, "name", "bernoulli")
        object.__setattr__(self, "min_p", float(min_p))

    @property
    def num_stats(self) -> int:
        return 1

    def sufficient_statistics(self, x):
        return x[..., None]

    def log_h(self, x):
        return jnp.zeros(x.shape, x.dtype)

    def _p(self, phi):
        return jnp.clip(phi[..., 0], self.min_p, 1.0 - self.min_p)

    def expectation_to_natural(self, phi):
        p = self._p(phi)
        return jnp.log(p / (1.0 - p))[..., None]

    def log_normalizer(self, theta):
        return jnp.logaddexp(0.0, theta[..., 0])

    def sample(self, key, phi):
        return jax.random.bernoulli(key, self._p(phi)).astype(jnp.float32)

    def init_phi(self, key, shape):
        return jax.random.uniform(key, shape + (1,), minval=0.3, maxval=0.7)

    def mode(self, phi):
        return (self._p(phi) > 0.5).astype(jnp.float32)

    def project_phi(self, phi):
        return jnp.clip(phi, self.min_p, 1.0 - self.min_p)

    def clamp_fraction(self, phi):
        p = phi[..., 0]
        pinned = (p <= self.min_p) | (p >= 1.0 - self.min_p)
        return jnp.mean(pinned.astype(jnp.float32))


class Binomial(ExponentialFamily):
    """x in {0..N}.  Used by the paper for 8-bit image data (N=255).

    T(x) = [x], phi = [N p].  log h(x) = log C(N, x).
    """

    def __init__(self, n_trials: int, min_p: float = 1e-6):
        object.__setattr__(self, "name", "binomial")
        object.__setattr__(self, "n_trials", int(n_trials))
        object.__setattr__(self, "min_p", float(min_p))

    @property
    def num_stats(self) -> int:
        return 1

    def sufficient_statistics(self, x):
        return x[..., None]

    def log_h(self, x):
        n = self.n_trials
        return (
            jax.lax.lgamma(jnp.float32(n + 1))
            - jax.lax.lgamma(x + 1.0)
            - jax.lax.lgamma(n - x + 1.0)
        )

    def _p(self, phi):
        return jnp.clip(phi[..., 0] / self.n_trials, self.min_p, 1.0 - self.min_p)

    def expectation_to_natural(self, phi):
        p = self._p(phi)
        return jnp.log(p / (1.0 - p))[..., None]

    def log_normalizer(self, theta):
        return self.n_trials * jnp.logaddexp(0.0, theta[..., 0])

    def sample(self, key, phi):
        p = self._p(phi)
        u = jax.random.uniform(key, p.shape + (self.n_trials,))
        return jnp.sum(u < p[..., None], axis=-1).astype(jnp.float32)

    def init_phi(self, key, shape):
        p = jax.random.uniform(key, shape + (1,), minval=0.3, maxval=0.7)
        return p * self.n_trials

    def mode(self, phi):
        return jnp.round(jnp.clip(phi[..., 0], 0, self.n_trials))

    def project_phi(self, phi):
        return jnp.clip(
            phi, self.min_p * self.n_trials, (1.0 - self.min_p) * self.n_trials
        )

    def clamp_fraction(self, phi):
        p = phi[..., 0] / self.n_trials
        pinned = (p <= self.min_p) | (p >= 1.0 - self.min_p)
        return jnp.mean(pinned.astype(jnp.float32))


class Categorical(ExponentialFamily):
    """x in {0..C-1}.  T(x) = onehot(x), phi = probs (C,)."""

    def __init__(self, num_categories: int, min_p: float = 1e-6):
        object.__setattr__(self, "name", "categorical")
        object.__setattr__(self, "num_categories", int(num_categories))
        object.__setattr__(self, "min_p", float(min_p))

    @property
    def num_stats(self) -> int:
        return self.num_categories

    def sufficient_statistics(self, x):
        return jax.nn.one_hot(x.astype(jnp.int32), self.num_categories, dtype=jnp.float32)

    def log_h(self, x):
        return jnp.zeros(x.shape, jnp.float32)

    def _p(self, phi):
        p = jnp.clip(phi, self.min_p, 1.0)
        return p / jnp.sum(p, axis=-1, keepdims=True)

    def expectation_to_natural(self, phi):
        return jnp.log(self._p(phi))

    def log_normalizer(self, theta):
        # theta already normalized log-probs -> A = 0
        return jnp.zeros(theta.shape[:-1], theta.dtype)

    def sample(self, key, phi):
        logits = jnp.log(self._p(phi))
        return jax.random.categorical(key, logits, axis=-1).astype(jnp.float32)

    def init_phi(self, key, shape):
        p = jax.random.uniform(
            key, shape + (self.num_categories,), minval=0.5, maxval=1.5
        )
        return p / jnp.sum(p, axis=-1, keepdims=True)

    def mode(self, phi):
        return jnp.argmax(phi, axis=-1).astype(jnp.float32)

    def project_phi(self, phi):
        return self._p(phi)

    def clamp_fraction(self, phi):
        return jnp.mean((phi <= self.min_p).astype(jnp.float32))


EF_REGISTRY = {
    "normal": Normal,
    "bernoulli": Bernoulli,
    "binomial": Binomial,
    "categorical": Categorical,
}


def make_exponential_family(name: str, **kwargs) -> ExponentialFamily:
    return EF_REGISTRY[name](**kwargs)
