"""The naive PC implementation the paper benchmarks against (§3.2, §4.1).

LibSPN (Pronobis et al., 2017) and SPFlow (Molina et al., 2019) compute the
core sum-product unit entirely in the log-domain:

  1. materialize the outer *sum* of log-densities
     ``P[b,l,i,j] = logN[b,l,i] + logN'[b,l,j]``            (K^2 products, stored)
  2. broadcast-add ``log W[l,k,i,j]``                        (K^3 terms, stored)
  3. ``log-sum-exp`` over (i, j)                             (K^3 exp ops)

versus EiNets' 2K exp / K log / K^3 *multiply* ops with nothing materialized.
Both paths compute the identical function, so Table-1-style log-likelihood
parity is exact up to float error -- which is what ``benchmarks/bench_table1``
checks -- while Fig. 3/6 measure the time/memory gap.

``NaiveEiNet`` shares all structure/parameters with ``EiNet``; only the layer
computation differs, making the comparison apples-to-apples.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.einet import EiNet


def log_einsum_exp_naive(w: jax.Array, ln_left: jax.Array,
                         ln_right: jax.Array) -> jax.Array:
    """Steps 1-3 above: explicit products + K^3-exp log-sum-exp."""
    prod = ln_left[:, :, :, None] + ln_right[:, :, None, :]  # (B, L, K, K) stored
    logw = jnp.log(jnp.maximum(w, 1e-38))  # (L, K_out, K, K)
    t = logw[None] + prod[:, :, None, :, :]  # (B, L, K_out, K, K) stored
    b, l, k_out = t.shape[:3]
    return jax.scipy.special.logsumexp(t.reshape(b, l, k_out, -1), axis=-1)


class NaiveEiNet(EiNet):
    """EiNet structure evaluated with the naive LibSPN/SPFlow-style layers."""

    def __init__(self, *args, **kwargs):
        kwargs["impl"] = "naive"
        super().__init__(*args, **kwargs)
