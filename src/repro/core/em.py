"""Expectation-Maximization via automatic differentiation (paper §3.5).

The paper's key algorithmic observation: for a log-output circuit,

    dlogP/dw_{S,N} * w_{S,N}  =  (1/P) dP/dS N  =  n_{S,N}(x)      (Eq. 6)
    dlogP/dlogL               =  (1/P) dP/dL L  =  p_L(x)

so the *entire* E-step is one ``jax.grad`` call on the batch log-likelihood,
with the sum-over-data accumulation done by autodiff itself.  The M-step is a
renormalization (sums) resp. a weighted moment average (EF leaves, Eq. 7).

Two training modes:
  * ``em_update``         -- full/minibatch statistics, exact M-step.
  * ``stochastic_em_update`` -- Sato (1999) online EM:  p <- (1-l) p + l p_mini
    (Eqs. 8/9); the paper shows this is natural-gradient SGD under the
    complete-data Fisher.

Distribution: the sufficient statistics are *sums over data*, so the
distributed E-step is a ``psum`` over the data axes -- structurally identical
to gradient all-reduce (see ``repro.dist``).  ``em_update`` takes an optional
``axis_names`` for exactly that.

This module holds the *algorithm*; the compiled training pipeline --
microbatch statistic accumulation under ``lax.scan``, donated-buffer jitted
update steps -- lives in ``repro.train`` (EXPERIMENTS.md §Perf, "compiled EM
step").
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.einet import EiNet
from repro.dist import sharding as sharding_lib
from repro.core.layers import normalize_einsum_weights, normalize_mixing_weights


@dataclasses.dataclass(frozen=True)
class EMConfig:
    laplace_alpha: float = 1e-4  # Laplace smoothing on sum-weight statistics
    stat_floor: float = 1e-12
    step_size: float = 0.5  # lambda for stochastic EM (paper uses 0.5)


def _psum(x, axis_names):
    return jax.lax.psum(x, axis_names) if axis_names else x


def leaf_scatter(model: EiNet, s_phi_pairs: jax.Array,
                 s_den_pairs: jax.Array):
    """Fan per-pair leaf statistics out to parameter layout: (P, K, |T|) ->
    (D, K, R, |T|) and (P, K) -> (D, K, R).

    Every (variable, replica) pair belongs to exactly one leaf, so this is a
    unique-index scatter with zero cross-shard traffic under node sharding
    (§Perf einet it.3).  THE one definition of the fan-out: the single-model
    E-step, the vmapped mixture E-step (``repro.mixture.train``) and the
    fuse-or-not microbenchmark (``benchmarks/bench_train.py``) all time and
    run this exact op.
    """
    ls = model.leaf_spec
    d, k, r = model.num_vars, model.K, ls.num_replica
    tdim = model.ef.num_stats
    flat = ls.pair_var * r + ls.pair_rep  # unique per pair entry
    s_phi = (
        jnp.zeros((d * r, k, tdim)).at[flat].set(s_phi_pairs)
        .reshape(d, r, k, tdim).swapaxes(1, 2)
    )  # (D, K, R, |T|)
    s_den = (
        jnp.zeros((d * r, k)).at[flat].set(s_den_pairs)
        .reshape(d, r, k).swapaxes(1, 2)
    )  # (D, K, R)
    return s_phi, s_den


def em_statistics(
    model: EiNet,
    params: Dict[str, Any],
    x: jax.Array,
    axis_names: Optional[Sequence[str]] = None,
) -> Dict[str, Any]:
    """E-step: expected statistics for every parameter block, via one grad call.

    Returns a dict with:
      n_einsum: list of (L, k_out, K, K)    -- sum-node statistics n_{S,N}
      n_mixing: list of (M, C, k_out)
      s_phi:    (D, K, R, |T|)              -- sum_x p_L(x) T(x)
      s_den:    (D, K, R)                   -- sum_x p_L(x)
      n_class:  (num_classes,)
      ll:       scalar mean log-likelihood (for monitoring)
    """
    e = model.leaf_log_prob(params, x, None)
    leaf_rows = model._leaf_rows(e)  # (B, num_leaves, K)
    prior = params["class_prior"]

    def batch_ll(einsum_w, mixing_v, lr, logprior):
        root = model.forward_from_e(einsum_w, mixing_v, None, leaf_rows=lr)
        ll = jax.scipy.special.logsumexp(root + logprior[None, :], axis=-1)
        return jnp.sum(ll)

    logprior = jnp.log(prior)
    val, grads = jax.value_and_grad(batch_ll, argnums=(0, 1, 2, 3))(
        params["einsum"], params["mixing"], leaf_rows, logprior
    )
    g_einsum, g_mixing, g_leaf, g_prior = grads
    # pin the statistic tensors to the weight sharding (layer-node axis over
    # the model mesh axis): otherwise the psum over data moves the FULL
    # 2 GB-scale stat tensors per device (EXPERIMENTS.md §Perf, einet cell)
    pinned = sharding_lib.constrain_like_params(
        {"einsum": g_einsum, "mixing": g_mixing}
    )
    g_einsum, g_mixing = pinned["einsum"], pinned["mixing"]

    # sum-node statistics: n = W * dlogP/dW  (accumulated over the batch by AD)
    n_einsum = [w * g for w, g in zip(params["einsum"], g_einsum)]
    n_mixing = [v * g for v, g in zip(params["mixing"], g_mixing)]
    # leaf statistics.  We differentiate wrt the LEAF ROWS (node-sharded, no
    # cross-shard scatter in the transpose -- §Perf einet it.3) and fan the
    # leaf posteriors out to (d, k, r): every (variable, replica) pair belongs
    # to exactly one leaf, so the fan-out is a unique-index scatter.
    ls = model.leaf_spec
    t = model.ef.sufficient_statistics(x)  # (B, D, |T|)
    cst = sharding_lib.constraint
    g_pairs = cst(g_leaf[:, ls.pair_leaf, :], ("batch", "einet_nodes", None))
    t_pairs = cst(t[:, ls.pair_var, :], ("batch", "einet_nodes", None))
    s_phi_pairs = cst(jnp.einsum("bpk,bpt->pkt", g_pairs, t_pairs),
                      ("einet_nodes", None, None))
    s_den_pairs = cst(jnp.sum(g_pairs, axis=0), ("einet_nodes", None))
    s_phi, s_den = leaf_scatter(model, s_phi_pairs, s_den_pairs)
    # dlogP/dlog(prior_c) = sum_x posterior(c | x): the expected class counts
    n_class = g_prior

    stats = {
        "n_einsum": n_einsum,
        "n_mixing": n_mixing,
        "s_phi": s_phi,
        "s_den": s_den,
        "n_class": n_class,
        "ll": val,
        "count": jnp.asarray(x.shape[0], jnp.float32),
    }
    if axis_names:
        stats = jax.tree_util.tree_map(lambda a: _psum(a, axis_names), stats)
    return stats


def m_step(
    model: EiNet,
    stats: Dict[str, Any],
    cfg: EMConfig,
) -> Dict[str, Any]:
    """Exact M-step from accumulated statistics."""
    alpha = cfg.laplace_alpha
    einsum_w = [
        normalize_einsum_weights(n + alpha, floor=cfg.stat_floor)
        for n in stats["n_einsum"]
    ]
    mixing_v = []
    for n, spec in zip(stats["n_mixing"], model.pair_specs):
        if spec.mix_global is None:
            mixing_v.append(n)
        else:
            mask = jnp.asarray(spec.mix_mask)
            mixing_v.append(
                normalize_mixing_weights(
                    n + alpha * mask[:, :, None], mask, floor=cfg.stat_floor
                )
            )
    den = jnp.maximum(stats["s_den"], cfg.stat_floor)
    phi = stats["s_phi"] / den[..., None]
    phi = model.ef.project_phi(phi)
    prior = stats["n_class"] + alpha
    prior = prior / jnp.sum(prior)
    return {
        "phi": phi,
        "einsum": einsum_w,
        "mixing": mixing_v,
        "class_prior": prior,
    }


def em_update(
    model: EiNet,
    params: Dict[str, Any],
    x: jax.Array,
    cfg: EMConfig = EMConfig(),
    axis_names: Optional[Sequence[str]] = None,
):
    """One full EM update on a batch (monotone on that batch). Returns
    (new_params, mean_ll)."""
    stats = em_statistics(model, params, x, axis_names)
    new = m_step(model, stats, cfg)
    return new, stats["ll"] / stats["count"]


def blend_params(
    model: EiNet,
    params: Dict[str, Any],
    mini: Dict[str, Any],
    step_size: float,
) -> Dict[str, Any]:
    """Sato online-EM interpolation (Eqs. 8/9):  p <- (1-l) p + l p_mini.

    Shared by ``stochastic_em_update`` and the compiled training pipeline
    (``repro.train``), so both paths apply the identical update -- including
    the phi re-projection that keeps EF parameters in their valid domain
    after interpolation.
    """
    lam = step_size

    def blend(old, new):
        return (1.0 - lam) * old + lam * new

    return {
        "phi": model.ef.project_phi(blend(params["phi"], mini["phi"])),
        "einsum": [blend(o, n) for o, n in zip(params["einsum"], mini["einsum"])],
        "mixing": [blend(o, n) for o, n in zip(params["mixing"], mini["mixing"])],
        "class_prior": blend(params["class_prior"], mini["class_prior"]),
    }


def stochastic_em_update(
    model: EiNet,
    params: Dict[str, Any],
    x: jax.Array,
    cfg: EMConfig = EMConfig(),
    axis_names: Optional[Sequence[str]] = None,
):
    """Sato-style online EM (Eqs. 8/9): blend minibatch M-step with step lambda."""
    mini, ll = em_update(model, params, x, cfg, axis_names)
    return blend_params(model, params, mini, cfg.step_size), ll


def accumulate_statistics(acc: Dict[str, Any], new: Dict[str, Any]) -> Dict[str, Any]:
    """Running sum of E-step statistics across minibatches (full-batch EM on
    datasets that do not fit in one device batch)."""
    return jax.tree_util.tree_map(lambda a, b: a + b, acc, new)


def zeros_like_statistics(model: EiNet, params: Dict[str, Any]) -> Dict[str, Any]:
    tdim = model.ef.num_stats
    d, k, r = params["phi"].shape[:3]
    return {
        "n_einsum": [jnp.zeros_like(w) for w in params["einsum"]],
        "n_mixing": [jnp.zeros_like(v) for v in params["mixing"]],
        "s_phi": jnp.zeros((d, k, r, tdim)),
        "s_den": jnp.zeros((d, k, r)),
        "n_class": jnp.zeros_like(params["class_prior"]),
        "ll": jnp.zeros(()),
        "count": jnp.zeros(()),
    }
