"""Circuit execution planning: compile a pair-spec list into a typed plan.

``EiNet._build`` turns a region graph into a bottom-up list of
(product-layer, sum-layer) ``PairSpec``s; THIS module decides how that list
executes.  The output is a :class:`CircuitPlan` -- a sequence of
:class:`ExecSegment`s, each one of three kinds:

  * ``"fused"``  -- a run of consecutive CANONICAL pairs (left = rows
    [0, L), right = [L, 2L) of the layer below, sizes halving exactly: the
    RAT layout ``EiNet._canonicalize`` produces).  Runs as ONE subtree-tiled
    grouped kernel (``kernels.grouped.grouped_log_einsum_exp_pallas``) with
    a static (out_block, block_b) tiling chosen here against the VMEM
    budget.
  * ``"gather"`` -- a run of consecutive NON-FINAL pairs of ARBITRARY
    topology (PD's cross-depth gathers, interior mixing layers included),
    carrying per-depth permutation tables (:class:`GatherTables`) built once
    on host.  Runs as ONE gather-grouped kernel whose row buffer lives in
    VMEM and whose child access is a static table lookup -- the
    PyJuice-style "compile the DAG into index tables + a few block-parallel
    kernels" execution model.
  * ``"layer"``  -- a single pair on the per-layer path, with the reason it
    could not join a group recorded in ``CircuitPlan.fallback_reasons``.

Planning is pure host-side numpy/python over static structure: no jax
arrays, no tracing.  The planner never changes WHAT a cell computes -- only
how many kernel launches the schedule takes -- so every plan is bitwise
equivalent to the per-layer loop (pinned by tests/test_grouped.py and
tests/test_gather_grouped.py).

The VMEM budget resolves in priority order: the ``vmem_budget=`` ctor knob,
the ``REPRO_VMEM_BUDGET`` env var (bytes; TPU calibration runs record the
effective value in the BENCH JSON ``grouping`` field), then the
conservative :data:`VMEM_BUDGET_BYTES` default.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Any, Dict, List, Optional, Sequence, Tuple

# VMEM working-set budget for one fused-kernel program: a conservative slice
# of the ~16 MiB/core so weights + recomputed activations + the K^2 product
# scratch of the BACKWARD pass (the larger of the two) co-reside
VMEM_BUDGET_BYTES = 12 * 2 ** 20
_GROUP_BLOCK_B = (128, 64, 32)  # planner's batch-tile candidates, best first

VMEM_BUDGET_ENV = "REPRO_VMEM_BUDGET"


def resolve_vmem_budget(ctor_value: Optional[int] = None) -> int:
    """Effective VMEM budget in bytes: ctor knob > env var > default."""
    if ctor_value is not None:
        return int(ctor_value)
    env = os.environ.get(VMEM_BUDGET_ENV, "").strip()
    if env:
        return int(env)
    return VMEM_BUDGET_BYTES


@dataclasses.dataclass(frozen=True)
class GatherTables:
    """Static per-depth permutation tables for one gather-grouped segment.

    Built once on host from the segment's ``PairSpec``s and baked into the
    kernel as compile-time constants (and into the ``custom_vjp``'s static
    args -- everything here is hashable nested int tuples).

    Row ids are GLOBAL buffer rows: ``EiNet._build`` allocates rows
    sequentially (leaves first, then each pair's einsum rows followed by its
    mixing rows), so the kernel's local row list -- input rows [0, r_in)
    followed by each depth's new rows in emission order -- coincides with
    the global numbering with no translation.
    """

    num_in_rows: int  # rows below the segment (= specs[start].einsum_global[0])
    k: int  # K of every depth (interior pairs: k_in == k_out == K)
    left: Tuple[Tuple[int, ...], ...]  # per depth: global rows of left children
    right: Tuple[Tuple[int, ...], ...]
    # per depth: (M, C) LOCAL indices into that depth's einsum outputs and the
    # matching 0/1 mask -- exactly PairSpec.mix_child_local / mix_mask, so the
    # in-kernel mixing replicates log_mix_exp bit-for-bit.  None = no mixing.
    mix_child: Tuple[Optional[Tuple[Tuple[int, ...], ...]], ...]
    mix_mask: Tuple[Optional[Tuple[Tuple[int, ...], ...]], ...]

    @property
    def num_depths(self) -> int:
        return len(self.left)

    @property
    def num_mix_depths(self) -> int:
        return sum(1 for m in self.mix_child if m is not None)

    @property
    def num_new_rows(self) -> int:
        return sum(
            len(l) + (len(m) if m is not None else 0)
            for l, m in zip(self.left, self.mix_child)
        )


@dataclasses.dataclass(frozen=True)
class ExecSegment:
    """One entry of the kernel schedule ``plan_circuit`` emits.

    ``kind == "fused"``: pairs [start, stop) as one canonical grouped kernel
    tiled over ``out_block`` final-depth cells x ``block_b`` batch rows.
    ``kind == "gather"``: pairs [start, stop) as one gather-grouped kernel
    (``tables`` carries the permutation tables, ``block_b`` the batch tile).
    ``kind == "layer"``: a single pair on the per-layer path.
    """

    start: int
    stop: int  # exclusive
    kind: str  # "layer" | "fused" | "gather"
    out_block: int = 0
    block_b: int = 0
    tables: Optional[GatherTables] = None

    @property
    def fused(self) -> bool:
        """Grouped execution of any flavour (not the per-layer path)."""
        return self.kind != "layer"


@dataclasses.dataclass(frozen=True)
class CircuitPlan:
    """The compiled execution schedule for one circuit's pair list."""

    segments: Tuple[ExecSegment, ...]
    num_pairs: int
    mix_flags: Tuple[bool, ...]  # per pair: has a mixing layer
    fallback_reasons: Tuple[Tuple[int, str], ...]  # (pair idx, reason)
    vmem_budget: int

    @property
    def grouped_active(self) -> bool:
        return any(seg.fused for seg in self.segments)

    def launches(self) -> Tuple[int, int]:
        """(per-layer launches, planned launches) for one forward pass.

        Per-layer: one einsum launch per pair plus one mixing launch per
        mixing pair.  Planned: a gather segment is ONE launch (mixing runs
        in-kernel); a fused segment is one launch plus the terminating
        pair's mixing (canonical runs keep mixing outside the kernel); a
        layer segment counts like the per-layer path.
        """
        per_layer = self.num_pairs + sum(self.mix_flags)
        planned = 0
        for seg in self.segments:
            if seg.kind == "gather":
                planned += 1
            elif seg.kind == "fused":
                planned += 1 + (1 if self.mix_flags[seg.stop - 1] else 0)
            else:
                planned += 1 + (1 if self.mix_flags[seg.start] else 0)
        return per_layer, planned

    def summary(self) -> Dict[str, Any]:
        """Kernel-launch accounting (benchmarks record this as the
        ``grouping`` field next to wall-clock)."""
        per_layer, planned = self.launches()
        return {
            "num_pairs": self.num_pairs,
            "launches_per_layer": per_layer,
            "launches_grouped": planned,
            "fused_groups": sum(
                1 for s in self.segments if s.kind == "fused"
            ),
            "gather_groups": sum(
                1 for s in self.segments if s.kind == "gather"
            ),
            "fused_pairs": sum(
                s.stop - s.start for s in self.segments if s.fused
            ),
            "segments": [
                [s.start, s.stop, s.kind, s.out_block, s.block_b]
                for s in self.segments
            ],
            "fallbacks": [[p, r] for p, r in self.fallback_reasons],
            "vmem_budget": self.vmem_budget,
        }


def format_summary(s: Dict[str, Any]) -> str:
    """One startup log line per arch (launch/dryrun.py, launch/train.py)."""
    segs = " ".join(
        f"{kind}[{a},{b})" for a, b, kind, _, _ in s["segments"]
    )
    line = (
        f"launches {s['launches_per_layer']}->{s['launches_grouped']} "
        f"({s['fused_groups']} fused + {s['gather_groups']} gather group(s) "
        f"over {s['fused_pairs']}/{s['num_pairs']} pairs; "
        f"vmem budget {s['vmem_budget']} B): {segs}"
    )
    if s["fallbacks"]:
        falls = "; ".join(f"pair {p}: {r}" for p, r in s["fallbacks"])
        line += f" | per-layer: {falls}"
    return line


# ------------------------------------------------------------- cost models
def fused_cost_bytes(specs: Sequence, i: int, j: int, s: int, bb: int) -> int:
    """Estimated VMEM working set of ONE backward-pass program for the
    canonical run [i, j) at out_block ``s``, batch tile ``bb`` (padded
    shapes).  The backward dominates: weights + dW blocks + every depth's
    recomputed activations + the K^2 product/contraction scratch."""
    g = j - i
    k = specs[i].k_in
    k_p = -(-k // 16) * 16
    ko_fp = -(-specs[j - 1].k_out // 128) * 128
    f = 4  # float32
    w_bytes = 0
    for d in range(g):
        m = 2 ** (g - 1 - d)
        ko = k_p if d < g - 1 else ko_fp
        w_bytes += m * s * ko * k_p * k_p * f
    act = bb * s * k_p * f * sum(2 ** (g - d) for d in range(g + 1))
    scratch = bb * k_p * k_p * f * 4
    io = bb * s * ko_fp * f * 2
    return 2 * w_bytes + act + scratch + io


def gather_cost_bytes(specs: Sequence, i: int, j: int, bb: int) -> int:
    """Estimated VMEM working set of ONE backward-pass program for the
    gather run [i, j) at batch tile ``bb`` (padded shapes).  The gather
    kernel holds the WHOLE segment per program (no cell tiling -- rows are
    irregular), so the budget bounds run length instead of out_block:
    weights + dW + the full row buffer (forward rows AND cotangents) + the
    K^2 product scratch."""
    k = specs[i].k_in
    k_p = -(-k // 16) * 16
    f = 4
    w_bytes = sum(
        specs[t].num_partitions * k_p * k_p * k_p * f for t in range(i, j)
    )
    v_bytes = sum(
        specs[t].num_mixed * specs[t].mix_child_local.shape[1] * k_p * f
        for t in range(i, j)
        if specs[t].mix_global is not None
    )
    r_in = int(specs[i].einsum_global[0])
    r_new = sum(
        specs[t].num_partitions + specs[t].num_mixed for t in range(i, j)
    )
    rows = bb * (r_in + r_new) * k_p * f
    scratch = bb * k_p * k_p * f * 4
    io = bb * (r_in + 2 * r_new) * k_p * f
    return 2 * (w_bytes + v_bytes) + 2 * rows + scratch + io


# ------------------------------------------------------------ run pickers
def pick_tiling(
    specs: Sequence, i: int, j: int, vmem_budget: int
) -> Optional[Tuple[int, int]]:
    """(out_block, block_b) fitting the canonical run [i, j) in the VMEM
    budget, or None when the run cannot be fused (structure or budget)."""
    if any(not specs[t].canonical for t in range(i, j)):
        return None
    # a mixing pair may only TERMINATE a run: its mixture outputs join the
    # einsum outputs outside the kernel
    if any(specs[t].mix_global is not None for t in range(i, j - 1)):
        return None
    l_out = specs[j - 1].num_partitions
    for d, t in enumerate(range(i, j)):
        if specs[t].num_partitions != l_out * 2 ** (j - i - 1 - d):
            return None  # not an exact canonical halving chain
        if t < j - 1 and specs[t].k_out != specs[t + 1].k_in:
            return None
    for bb in _GROUP_BLOCK_B:
        for s in range(l_out, 0, -1):
            if l_out % s:
                continue
            if fused_cost_bytes(specs, i, j, s, bb) <= vmem_budget:
                return s, bb
    return None


def pick_gather_batch(
    specs: Sequence, i: int, j: int, vmem_budget: int
) -> Optional[int]:
    """Largest batch tile fitting the gather run [i, j) in the VMEM budget,
    or None.  Structure constraints: every pair non-final (the root layer
    changes K_out and is cheap -- it stays per-layer) with a uniform K;
    arbitrary gathers and interior mixing are fine (that is the point)."""
    if any(specs[t].is_final for t in range(i, j)):
        return None
    k = specs[i].k_in
    if any(
        specs[t].k_in != k or specs[t].k_out != k for t in range(i, j)
    ):
        return None
    for bb in _GROUP_BLOCK_B:
        if gather_cost_bytes(specs, i, j, bb) <= vmem_budget:
            return bb
    return None


def build_gather_tables(specs: Sequence, start: int, stop: int) -> GatherTables:
    """Freeze the per-depth permutation tables for pairs [start, stop)."""
    left: List[Tuple[int, ...]] = []
    right: List[Tuple[int, ...]] = []
    mix_child: List[Optional[Tuple[Tuple[int, ...], ...]]] = []
    mix_mask: List[Optional[Tuple[Tuple[int, ...], ...]]] = []
    r_in = int(specs[start].einsum_global[0])
    for t in range(start, stop):
        sp = specs[t]
        assert not sp.is_final, "gather segments cover non-final pairs only"
        left.append(tuple(int(v) for v in sp.left))
        right.append(tuple(int(v) for v in sp.right))
        if sp.mix_global is not None:
            mix_child.append(
                tuple(
                    tuple(int(c) for c in row) for row in sp.mix_child_local
                )
            )
            mix_mask.append(
                tuple(tuple(int(m) for m in row) for row in sp.mix_mask)
            )
        else:
            mix_child.append(None)
            mix_mask.append(None)
    return GatherTables(
        num_in_rows=r_in,
        k=int(specs[start].k_in),
        left=tuple(left),
        right=tuple(right),
        mix_child=tuple(mix_child),
        mix_mask=tuple(mix_mask),
    )


# ---------------------------------------------------------------- planner
def _why_not_canonical(specs: Sequence, i: int, vmem_budget: int) -> str:
    n = len(specs)
    if i + 2 > n:
        return "run shorter than 2 pairs"
    if not specs[i].canonical or not specs[i + 1].canonical:
        return "non-canonical pair in every candidate run"
    if specs[i].mix_global is not None:
        return "interior mixing terminates runs"
    return "2-depth working set exceeds the vmem budget"


def _why_not_gather(specs: Sequence, i: int, vmem_budget: int) -> str:
    n = len(specs)
    if specs[i].is_final:
        return "final (root) pair runs per-layer"
    if i + 2 > n or specs[i + 1].is_final:
        return "no 2-pair run available before the root"
    if pick_gather_batch(specs, i, i + 2, vmem_budget) is None:
        return "2-pair gather working set exceeds the vmem budget"
    return "unfusable run"


def plan_circuit(
    specs: Sequence,
    grouped: bool = True,
    vmem_budget: Optional[int] = None,
) -> CircuitPlan:
    """Compile the pair list into the execution plan.

    All-canonical structures (RAT: ``needs_buffer`` is False) get exactly
    the canonical greedy plan of the original ``EiNet._plan_groups`` --
    maximal fused runs, split on the VMEM budget -- preserving those plans
    (and their benchmarks) bit-for-bit.  Structures with ANY non-canonical
    pair run in row-buffer mode, where fused (slice-tiled) segments are
    forbidden -- they skip materializing interior rows, which would leave
    holes in the global-row-indexed buffer -- and maximal gather runs take
    their place.  Pairs joining no run become layer segments with the
    reason recorded.
    """
    budget = resolve_vmem_budget(vmem_budget)
    n = len(specs)
    mix_flags = tuple(sp.mix_global is not None for sp in specs)

    def _finish(segments, reasons):
        return CircuitPlan(
            segments=tuple(segments),
            num_pairs=n,
            mix_flags=mix_flags,
            fallback_reasons=tuple(reasons),
            vmem_budget=budget,
        )

    if not grouped or n < 2:
        reason = "grouped execution disabled" if not grouped else (
            "circuit has fewer than 2 pairs"
        )
        return _finish(
            [ExecSegment(i, i + 1, "layer") for i in range(n)],
            [(i, reason) for i in range(n)],
        )

    needs_buffer = any(not sp.canonical for sp in specs)
    segments: List[ExecSegment] = []
    reasons: List[Tuple[int, str]] = []
    i = 0
    if not needs_buffer:
        while i < n:
            best = None
            j = i + 2
            while j <= n:
                tiling = pick_tiling(specs, i, j, budget)
                if tiling is None:
                    break
                best = (j, tiling)
                j += 1
            if best is not None:
                j, (s, bb) = best
                segments.append(
                    ExecSegment(i, j, "fused", out_block=s, block_b=bb)
                )
                i = j
            else:
                segments.append(ExecSegment(i, i + 1, "layer"))
                reasons.append((i, _why_not_canonical(specs, i, budget)))
                i += 1
        return _finish(segments, reasons)

    while i < n:
        best = None
        j = i + 2
        while j <= n:
            bb = pick_gather_batch(specs, i, j, budget)
            if bb is None:
                break
            best = (j, bb)
            j += 1
        if best is not None:
            j, bb = best
            segments.append(
                ExecSegment(
                    i, j, "gather", block_b=bb,
                    tables=build_gather_tables(specs, i, j),
                )
            )
            i = j
        else:
            segments.append(ExecSegment(i, i + 1, "layer"))
            reasons.append((i, _why_not_gather(specs, i, budget)))
            i += 1
    return _finish(segments, reasons)
