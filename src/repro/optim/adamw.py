"""AdamW in pure JAX, with optionally block-quantized (int8) moment state.

At kimi-k2 scale (1T params) full f32 Adam moments are 8 TB -- more than the
512-chip pod's HBM.  ``state_dtype='int8'`` stores m and v block-quantized
(256-value blocks, per-block f32 absmax scales, symmetric for m / asymmetric
for v), cutting optimizer state to ~2 TB and making the 1T cells fit.  This
is the standard 8-bit-Adam trick (Dettmers et al.) adapted to a pytree/pjit
world: quantization is elementwise per shard, so it composes with any
sharding and needs no extra collectives.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    learning_rate: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "float32"  # float32 | bfloat16 | int8
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1


def _quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Blockwise symmetric int8 quantization. Returns (q, scales)."""
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    q = jnp.round(blocks / jnp.maximum(scale, 1e-20)).astype(jnp.int8)
    return q, scale[:, 0]


def _dequantize(q: jax.Array, scale: jax.Array, shape, size) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)[:size]
    return flat.reshape(shape)


def _encode(x: jax.Array, dtype: str):
    if dtype == "float32":
        return x
    if dtype == "bfloat16":
        return x.astype(jnp.bfloat16)
    if dtype == "int8":
        return _quantize(x)
    raise ValueError(dtype)


def _decode(enc, shape, dtype: str) -> jax.Array:
    if dtype == "float32":
        return enc
    if dtype == "bfloat16":
        return enc.astype(jnp.float32)
    q, scale = enc
    size = 1
    for s in shape:
        size *= s
    return _dequantize(q, scale, shape, size)


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.learning_rate * jnp.minimum(warm, 1.0) * cos


def init_state(cfg: AdamWConfig, params: Any) -> Any:
    def one(p):
        z = jnp.zeros_like(p, jnp.float32)
        return {"m": _encode(z, cfg.state_dtype), "v": _encode(z, cfg.state_dtype)}

    return {
        "step": jnp.zeros((), jnp.int32),
        "moments": jax.tree_util.tree_map(one, params),
    }


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(
        sum(
            jnp.sum(jnp.square(x.astype(jnp.float32)))
            for x in jax.tree_util.tree_leaves(tree)
        )
    )


def apply_updates(
    cfg: AdamWConfig, params: Any, grads: Any, state: Any
) -> Tuple[Any, Any, jax.Array]:
    """One AdamW step.  Returns (new_params, new_state, grad_norm)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    lr = lr_schedule(cfg, step)
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)

    def one(p, g, mom):
        g = g.astype(jnp.float32) * clip
        m = _decode(mom["m"], p.shape, cfg.state_dtype)
        v = _decode(mom["v"], p.shape, cfg.state_dtype)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        upd = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        newp = p.astype(jnp.float32) - lr * (upd + cfg.weight_decay * p.astype(jnp.float32))
        return newp.astype(p.dtype), {
            "m": _encode(m, cfg.state_dtype),
            "v": _encode(v, cfg.state_dtype),
        }

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["moments"])
    out = [one(p, g, m) for p, g, m in zip(flat_p, flat_g, flat_m)]
    new_params = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_moments = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    return new_params, {"step": step, "moments": new_moments}, gnorm
