"""Gradient compression for the slow (inter-pod / DCN) all-reduce axis.

Two codecs, both with error feedback (the residual is carried to the next
step so compression error does not bias the optimizer):

  * int8 blockwise quantization (32x vs f32 counting scales; 4x vs bf16) --
    cheap, dense, the default for the 'pod' axis where DCN bandwidth is
    ~10-20x scarcer than ICI.
  * top-k sparsification (magnitude) -- for very sparse updates (EiNet EM
    statistics are extremely peaked after a few epochs).

``compressed_psum`` composes with shard_map: quantize -> psum the int8 (as
int32 accumulators to avoid overflow) -> dequantize; EM statistics use the
same path (they are sums over data, like gradients -- DESIGN.md §2).
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    q = jnp.clip(jnp.round(blocks / jnp.maximum(scale, 1e-20)), -127, 127)
    return q.astype(jnp.int8), scale[:, 0]


def dequantize_int8(q: jax.Array, scale: jax.Array, shape) -> jax.Array:
    size = 1
    for s in shape:
        size *= s
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)[:size]
    return flat.reshape(shape)


def compress_with_feedback(
    g: jax.Array, residual: Optional[jax.Array]
) -> Tuple[Tuple[jax.Array, jax.Array], jax.Array]:
    """Returns ((q, scale), new_residual)."""
    if residual is not None:
        g = g + residual
    q, scale = quantize_int8(g)
    approx = dequantize_int8(q, scale, g.shape)
    return (q, scale), g - approx


def topk_sparsify(
    g: jax.Array, k: int, residual: Optional[jax.Array]
) -> Tuple[Tuple[jax.Array, jax.Array], jax.Array]:
    """Magnitude top-k with error feedback.  Returns ((values, indices), res)."""
    if residual is not None:
        g = g + residual
    flat = g.reshape(-1)
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    vals = flat[idx]
    approx = jnp.zeros_like(flat).at[idx].set(vals)
    return (vals, idx), (flat - approx).reshape(g.shape)


def densify_topk(vals: jax.Array, idx: jax.Array, shape) -> jax.Array:
    size = 1
    for s in shape:
        size *= s
    return jnp.zeros((size,), vals.dtype).at[idx].add(vals).reshape(shape)


def compressed_psum(
    g: jax.Array, axis_name: str, residual: Optional[jax.Array]
) -> Tuple[jax.Array, jax.Array]:
    """int8 all-reduce with error feedback, for use inside shard_map.

    Per-block scales must be SHARED across the axis before quantizing (the
    sum of int8 payloads is only decodable against a common codebook), so one
    small f32 pmax of the scales precedes the int32 psum of the payloads.
    Error feedback carries each shard's local quantization error to the next
    step, so the compression is unbiased over time.
    """
    if residual is not None:
        g = g + residual
    flat = g.reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    local_scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jax.lax.pmax(local_scale, axis_name)  # shared codebook
    q = jnp.clip(jnp.round(blocks / jnp.maximum(scale, 1e-20)), -127, 127)
    qsum = jax.lax.psum(q.astype(jnp.int32), axis_name)
    out = (qsum.astype(jnp.float32) * scale).reshape(-1)[: g.size].reshape(g.shape)
    approx_local = (q * scale).reshape(-1)[: g.size].reshape(g.shape)
    return out, g - approx_local
