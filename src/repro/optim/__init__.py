"""Optimizers: pure-JAX AdamW (f32/bf16/int8 moment state) + gradient
compression for the DP/DCN axes."""

from repro.optim import adamw, compression

__all__ = ["adamw", "compression"]
