"""Structured evidence masks for the Fig. 4 inpainting experiment.

A mask is a flat ``(D,)`` boolean *evidence* mask in the EiNet's variable
order (pixel-major, channels innermost -- the ``poon_domingos`` id layout
``(r * width + c) * num_channels + ch``): ``True`` marks observed pixels,
``False`` the occluded region to inpaint.  Mask *names* describe the occluded
region, matching the paper's figures (``left_half`` = left half covered).

All masks occlude whole pixels (every channel of a pixel together), which is
what "inpainting" means for RGB data; ``random_pixel`` is the paper's
doodle-mask stand-in -- an unstructured scatter of missing pixels.
"""

from __future__ import annotations

from typing import Tuple

import numpy as np

MASK_KINDS: Tuple[str, ...] = (
    "left_half",
    "bottom_half",
    "center_square",
    "random_pixel",
)


def make_mask(
    kind: str,
    height: int,
    width: int,
    channels: int = 1,
    seed: int = 0,
    missing_fraction: float = 0.5,
) -> np.ndarray:
    """Build the flat (D,) evidence mask for one occlusion pattern.

    Args:
      kind: one of ``MASK_KINDS`` (names the OCCLUDED region).
      seed: only ``random_pixel`` uses it (deterministic scatter).
      missing_fraction: only ``random_pixel`` uses it.

    Returns: (height * width * channels,) bool; True = observed evidence.
    """
    occluded = np.zeros((height, width), bool)
    if kind == "left_half":
        occluded[:, : width // 2] = True
    elif kind == "bottom_half":
        occluded[height // 2:, :] = True
    elif kind == "center_square":
        h0, w0 = height // 4, width // 4
        occluded[h0: h0 + height // 2, w0: w0 + width // 2] = True
    elif kind == "random_pixel":
        rng = np.random.RandomState(seed)
        occluded = rng.rand(height, width) < missing_fraction
    else:
        raise KeyError(f"unknown mask kind {kind!r}; one of {MASK_KINDS}")
    evidence = ~occluded
    return np.repeat(evidence.reshape(-1), channels)
