"""Generative-image evaluation workbench (the paper's §4.2 / Fig. 4 surface).

Everything the repo needs to *measure* an EiNet as an image model, served
through the batched exact-inference engine (``repro.serve``):

  * ``metrics``    -- held-out log-likelihood / bits-per-dim, streamed through
                      the engine (kinds ``joint_ll`` / ``marginal_ll``) with
                      parity counted against direct ``EiNet.query`` calls.
  * ``masks``      -- the Fig. 4 structured evidence masks (left-half,
                      bottom-half, center-square, random-pixel).
  * ``inpainting`` -- the Fig. 4 harness: ``conditional_sample`` + ``mpe``
                      per-request through the engine, parity vs direct calls,
                      reconstruction metrics.
  * ``grids``      -- PNG sample/inpainting grid artifacts + per-run metrics
                      JSON (picked up by ``benchmarks/make_experiments_md.py``).
  * ``workbench``  -- the end-to-end run behind ``repro.launch.eval``.
"""

from repro.eval.masks import MASK_KINDS, make_mask
from repro.eval.metrics import (
    EngineLLResult,
    bits_per_dim,
    engine_log_likelihoods,
    evaluate_bpd,
)
from repro.eval.inpainting import InpaintingReport, run_inpainting
from repro.eval.grids import save_image_grid, save_metrics_json
from repro.eval.workbench import EvalConfig, run_eval

__all__ = [
    "MASK_KINDS",
    "make_mask",
    "EngineLLResult",
    "bits_per_dim",
    "engine_log_likelihoods",
    "evaluate_bpd",
    "InpaintingReport",
    "run_inpainting",
    "save_image_grid",
    "save_metrics_json",
    "EvalConfig",
    "run_eval",
]
