"""End-to-end image-evaluation runs: the engine behind ``repro.launch.eval``.

One :func:`run_eval` call is the repo's Fig. 4 / §4.2 protocol in miniature:

  1. resolve the dataset (synthetic / MNIST / SVHN / CelebA; ``--smoke`` and
     offline hosts use the deterministic procedural fallback),
  2. build a PD-structure EiNet matched to the image grid and leaf family --
     or, with ``mixture=C``, the paper's §4.2 mixture-of-EiNets: k-means
     clusters the train split (``repro.mixture.cluster``) and a single
     vmapped EM step trains all C components over their clusters,
  3. train with the compiled EM pipeline (``repro.train`` / the vmapped
     ``repro.mixture.train`` step),
  4. stream the test split through the serving engine for bits-per-dim
     (joint + marginal), run the Fig. 4 inpainting harness and a sample
     grid -- every query through ``repro.serve`` (mixture runs use the
     ``mixture_*`` kinds), parity-audited against direct query calls,
  5. write PNG grids + a metrics JSON under ``artifacts/eval/<run>/``.

The returned record is flat JSON; ``parity_mismatches_total`` is the
acceptance gate (must be exactly 0).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np

from repro import obs
from repro.configs import EinetConfig
from repro.core.einet import EiNet
from repro.data import datasets as ds_lib
from repro.eval import grids as grids_lib
from repro.eval.inpainting import run_inpainting
from repro.eval.masks import MASK_KINDS
from repro.eval.metrics import (
    bits_per_dim,
    engine_log_likelihoods,
    evaluate_bpd,
    parity_report,
)
from repro.serve import Request, ServeEngine
from repro.train import TrainConfig, fit

EVAL_DATASETS = ("synthetic", "mnist", "svhn", "celeba")


@dataclasses.dataclass(frozen=True)
class EvalConfig:
    """One evaluation run.  ``smoke`` shrinks every knob to CI size and
    forces the offline procedural dataset source."""

    dataset: str = "synthetic"
    family: str = "normal"  # leaf EF: normal | binomial | categorical
    smoke: bool = False
    steps: int = 80  # stochastic-EM training steps before eval
    batch: int = 128
    num_sums: int = 16
    delta: Optional[int] = None  # PD grid coarseness (None = per-dataset)
    data_dir: str = ds_lib.DEFAULT_DATA_DIR
    source: str = "auto"  # auto | download | procedural
    out_dir: str = "artifacts/eval"
    run_name: Optional[str] = None
    max_batch: int = 32  # engine micro-batch cap
    eval_rows: int = 256  # test rows streamed for bpd
    inpaint_rows: int = 8  # images per mask kind in the Fig. 4 harness
    num_samples: int = 16
    mask_kinds: Sequence[str] = MASK_KINDS
    marginal_mask: str = "left_half"  # mask for the marginal-bpd record
    seed: int = 0
    # §4.2 mixture-of-EiNets: number of k-means-clustered components
    # (0 / 1 = a single EiNet, the pre-mixture behaviour)
    mixture: int = 0


def resolve_dataset(cfg: EvalConfig) -> ds_lib.ImageDataset:
    if cfg.dataset == "synthetic":
        if cfg.smoke:
            return ds_lib.synthetic_image_dataset(
                8, 8, 1, num_train=512, num_test=96, seed=cfg.seed
            )
        return ds_lib.synthetic_image_dataset(16, 16, 3, seed=cfg.seed)
    source = "procedural" if cfg.smoke else cfg.source
    return ds_lib.load_image_dataset(
        cfg.dataset, data_dir=cfg.data_dir, source=source,
        size_cap=1024 if cfg.smoke else None,
    )


def pd_config_for(cfg: EvalConfig, spec: ds_lib.ImageSpec) -> EinetConfig:
    """The PD image-grid config for this dataset's geometry (28x28 MNIST,
    32x32 SVHN/CelebA, or the synthetic grid), shrunk under ``--smoke``."""
    delta = cfg.delta
    if delta is None:
        delta = {"mnist": 7, "svhn": 8, "celeba": 8}.get(
            spec.name, max(spec.height // 4, 2)
        )
    if cfg.smoke:
        delta = max(delta, spec.height // 2)
    return EinetConfig(
        name=f"einet-pd-{spec.name}-eval",
        structure="pd",
        height=spec.height,
        width=spec.width,
        num_channels=spec.channels,
        delta=delta,
        pd_axes=("w",),
        num_sums=4 if cfg.smoke else cfg.num_sums,
        exponential_family=cfg.family,
        min_var=1e-6,
        max_var=1e-2,  # the paper's image-leaf variance clamp
        batch_size=cfg.batch,
    )


def _train(
    model: EiNet, cfg: EvalConfig, train_x: np.ndarray
) -> Tuple[Dict[str, Any], list]:
    params = model.init(jax.random.PRNGKey(cfg.seed))
    steps = min(cfg.steps, 25) if cfg.smoke else cfg.steps
    batch = min(cfg.batch, len(train_x))
    loader = ds_lib.array_loader(train_x, batch)
    return fit(model, params, loader, TrainConfig(donate=False),
               num_steps=steps)


def _train_mixture(
    mix, cfg: EvalConfig, train_x: np.ndarray
) -> Tuple[Dict[str, Any], list, Any]:
    """The §4.2 protocol: k-means the train split, seed the mixture weights
    with the cluster proportions, and run the single vmapped hard-EM step
    over stacked per-cluster batches.  Returns (params, lls, KMeansResult).
    """
    from repro.mixture import (
        MixtureTrainConfig,
        fit_mixture,
        prepare_mixture_training,
    )

    params, loader, km = prepare_mixture_training(
        mix, train_x, seed=cfg.seed, global_batch=cfg.batch,
        kmeans_iters=10 if cfg.smoke else 25,
    )
    steps = min(cfg.steps, 25) if cfg.smoke else cfg.steps
    params, lls = fit_mixture(
        mix, params, loader, MixtureTrainConfig(donate=False),
        num_steps=steps,
    )
    return params, lls, km


def _sample_grid(
    model: EiNet,
    params: Dict[str, Any],
    engine: ServeEngine,
    cfg: EvalConfig,
    kind: str = "sample",
) -> Tuple[np.ndarray, Dict[str, Any]]:
    """Unconditional samples through the engine + parity record."""
    reqs = [
        Request(req_id=i, kind=kind, seed=7_000_000 + cfg.seed * 10_007 + i)
        for i in range(cfg.num_samples)
    ]
    engine.warmup(kinds=[kind])
    results = engine.run(reqs)
    samples = np.stack([results[i].value for i in range(cfg.num_samples)])
    par = parity_report(model, params, reqs, results, rows=None)
    return samples, par


def run_eval(cfg: EvalConfig, model: Optional[EiNet] = None,
             params: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    """The full workbench run; pass (model, params) to skip training and
    evaluate an existing net or EiNetMixture (matching the dataset
    geometry)."""
    if cfg.dataset not in EVAL_DATASETS:
        raise KeyError(
            f"unknown eval dataset {cfg.dataset!r}; one of {EVAL_DATASETS}"
        )
    t_start = obs.now()
    dataset = resolve_dataset(cfg)
    spec = dataset.spec
    train_x, _ = ds_lib.to_domain(dataset.train_x, cfg.family)
    test_x, offset_bits = ds_lib.to_domain(dataset.test_x, cfg.family)
    vmax = 1.0 if cfg.family == "normal" else 255.0

    lls: list = []
    km = None
    if model is None:
        from repro.launch.cells import build_einet

        base = build_einet(pd_config_for(cfg, spec))
        if int(cfg.mixture) >= 2:
            from repro.mixture import EiNetMixture

            model = EiNetMixture(base, int(cfg.mixture))
            params, lls, km = _train_mixture(model, cfg, train_x)
        else:
            model = base
            params, lls = _train(model, cfg, train_x)
    assert model.num_vars == spec.num_dims, (
        f"model covers {model.num_vars} vars, dataset has {spec.num_dims}"
    )
    is_mixture = hasattr(model, "num_components")
    prefix = "mixture_" if is_mixture else ""

    engine = ServeEngine(model, params, max_batch=cfg.max_batch)

    # -- bits per dim: joint on the test split, marginal under one mask ----
    eval_x = test_x[: cfg.eval_rows]
    bpd_joint = evaluate_bpd(
        model, params, eval_x, offset_bits=offset_bits, engine=engine,
        parity_rows=None if cfg.smoke else 64, kind=prefix + "joint_ll",
    )
    from repro.eval.masks import make_mask

    marg_ev = make_mask(cfg.marginal_mask, spec.height, spec.width,
                        spec.channels, seed=cfg.seed)
    marg = engine_log_likelihoods(
        model, params, eval_x, kind=prefix + "marginal_ll",
        evidence_mask=marg_ev,
        engine=engine, parity_rows=None if cfg.smoke else 64,
    )
    n_ev = int(np.sum(marg_ev))
    bpd_marginal = bits_per_dim(float(np.mean(marg.ll)), n_ev, offset_bits)

    # -- Fig. 4 inpainting + sample grid (exhaustively parity-audited) ----
    inp = run_inpainting(
        model, params, test_x[: cfg.inpaint_rows], spec.height, spec.width,
        spec.channels, mask_kinds=cfg.mask_kinds,
        mean_fill=train_x.mean(axis=0), engine=engine, seed=cfg.seed,
        parity_rows=None,
        kinds=(prefix + "conditional_sample", prefix + "mpe"),
    )
    samples, sample_par = _sample_grid(
        model, params, engine, cfg, kind=prefix + "sample"
    )

    # -- artifacts --------------------------------------------------------
    run_name = cfg.run_name or (
        f"{spec.name}_{cfg.family}"
        # from the model, not cfg.mixture: prebuilt mixtures passed in with
        # the default cfg still label their artifacts correctly
        + (f"_mix{int(model.num_components)}" if is_mixture else "")
        + ("_smoke" if cfg.smoke else "")
    )
    out = f"{cfg.out_dir}/{run_name}"
    pngs = {
        "samples": grids_lib.save_image_grid(
            f"{out}/samples.png",
            samples.reshape(-1, spec.height, spec.width, spec.channels),
            vmax=vmax,
        )
    }
    for mk in cfg.mask_kinds:
        pngs[f"inpaint_{mk}"] = grids_lib.save_inpainting_grid(
            f"{out}/inpaint_{mk}.png",
            test_x[: cfg.inpaint_rows], inp.evidence_masks[mk],
            inp.recon(mk, "conditional_sample"), inp.recon(mk, "mpe"),
            spec.height, spec.width, spec.channels, vmax=vmax,
        )

    mismatches = (
        bpd_joint["parity_mismatches"] + marg.parity_mismatches
        + inp.metrics["parity_mismatches"] + sample_par["parity_mismatches"]
    )
    record = {
        "run_name": run_name,
        "dataset": spec.name,
        "dataset_source": dataset.source,
        "family": cfg.family,
        "smoke": cfg.smoke,
        "mixture_components": (
            int(model.num_components) if is_mixture else 0
        ),
        "cluster_sizes": (
            km.counts.tolist() if km is not None else None
        ),
        "cluster_inertia": (
            float(km.inertia) if km is not None else None
        ),
        "height": spec.height,
        "width": spec.width,
        "channels": spec.channels,
        "num_dims": spec.num_dims,
        "num_params": model.num_params(params),
        "train_steps": len(lls),
        "train_ll_first": float(lls[0]) if lls else None,
        "train_ll_last": float(lls[-1]) if lls else None,
        "bpd_joint": bpd_joint,
        "bpd_marginal": {
            "mask": cfg.marginal_mask,
            "evidence_dims": n_ev,
            "mean_ll": float(np.mean(marg.ll)),
            "bpd": bpd_marginal,
            "parity_mismatches": marg.parity_mismatches,
        },
        "inpainting": inp.metrics,
        "samples_parity_mismatches": sample_par["parity_mismatches"],
        "parity_mismatches_total": int(mismatches),
        "engine_programs": engine.num_programs,
        "engine_stats": dict(engine.stats),
        "artifacts": pngs,
        "wall_seconds": obs.now() - t_start,
    }
    grids_lib.save_metrics_json(f"{out}/metrics.json", record)
    return record
