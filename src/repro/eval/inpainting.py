"""The Fig. 4 inpainting harness, served through the batched engine.

For each test image and each structured mask the harness issues TWO requests
-- a ``conditional_sample`` (posterior draw of the occluded region, the
paper's Fig. 4 middle rows) and an ``mpe`` decode (greedy argmax
reconstruction) -- each with its own per-request PRNG seed, through the same
``ServeEngine`` that serves production traffic.  Engine results are parity-
checked (bit-identical) against direct ``EiNet.query`` calls, and scored as
occluded-region MSE against the original image, with the train-mean fill as
the baseline any generative claim must beat.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from repro import obs
from repro.core.einet import EiNet
from repro.eval.masks import MASK_KINDS, make_mask
from repro.eval.metrics import parity_report
from repro.serve import Request, ServeEngine

INPAINT_KINDS = ("conditional_sample", "mpe")
MIXTURE_INPAINT_KINDS = ("mixture_conditional_sample", "mixture_mpe")


def _short(kind: str) -> str:
    """Canonical metric/recon key: mixture kinds score under the same names
    as the single-EiNet kinds ("conditional_sample", "mpe"), so downstream
    consumers (launch printing, EXPERIMENTS.md) read one schema."""
    return kind[len("mixture_"):] if kind.startswith("mixture_") else kind


@dataclasses.dataclass
class InpaintingReport:
    """Everything one Fig. 4 run produced.

    ``reconstructions[mask_kind][query_kind]`` is the (N, D) float array of
    inpainted images (evidence rows pass through untouched, per the
    ``conditional_sample`` contract).
    """

    mask_kinds: Sequence[str]
    evidence_masks: Dict[str, np.ndarray]  # (D,) bool per mask kind
    reconstructions: Dict[str, Dict[str, np.ndarray]]
    metrics: Dict[str, Any]  # flat JSON-able record

    def recon(self, mask_kind: str, query_kind: str = "mpe") -> np.ndarray:
        return self.reconstructions[mask_kind][query_kind]


def run_inpainting(
    model: EiNet,
    params: Dict[str, Any],
    images: np.ndarray,  # (N, D) in the leaf-EF domain
    height: int,
    width: int,
    channels: int,
    mask_kinds: Sequence[str] = MASK_KINDS,
    mean_fill: Optional[np.ndarray] = None,  # (D,) train mean for the baseline
    engine: Optional[ServeEngine] = None,
    max_batch: int = 32,
    seed: int = 0,
    parity_rows: Optional[int] = None,
    kinds: Sequence[str] = INPAINT_KINDS,
) -> InpaintingReport:
    """Run every (image, mask, kind) cell through the engine; score + verify.

    ``parity_rows=None`` verifies EVERY request against the direct call --
    the Fig. 4 harness is also the engine's correctness audit, so default to
    exhaustive.  ``kinds`` selects the query pair (``MIXTURE_INPAINT_KINDS``
    drives a mixture model; reconstructions and metrics keep the canonical
    short names either way).  Returns an :class:`InpaintingReport`.
    """
    n, d = images.shape
    assert d == height * width * channels, (d, height, width, channels)
    if engine is None:
        engine = ServeEngine(model, params, max_batch=min(max_batch, max(n, 1)))
    engine.warmup(kinds=kinds)

    evidence = {k: make_mask(k, height, width, channels, seed=seed)
                for k in mask_kinds}
    # requests run one engine drain per mask (for per-mask timing), but ids
    # and seeds are allocated in the same global order as ever -- engine
    # results are a pure function of each request's own (seed, x, evidence)
    # (the micro-batch invariant), so the reconstructions are unchanged
    requests: List[Request] = []
    index: Dict[int, tuple] = {}
    results: Dict[int, Any] = {}
    mask_seconds: Dict[str, float] = {}
    rid = 0
    for mk in mask_kinds:
        ev = evidence[mk]
        mask_requests: List[Request] = []
        for qk in kinds:
            for i in range(n):
                mask_requests.append(Request(
                    req_id=rid, kind=qk, x=np.asarray(images[i], np.float32),
                    evidence_mask=ev,
                    seed=seed * 1_000_003 + rid,
                ))
                index[rid] = (mk, _short(qk), i)
                rid += 1
        with obs.timed("eval.inpaint", metric="eval.inpaint.seconds",
                       mask=mk) as t:
            results.update(engine.run(mask_requests))
        mask_seconds[mk] = t.seconds
        requests.extend(mask_requests)
    engine_s = sum(mask_seconds.values())

    short_kinds = [_short(qk) for qk in kinds]
    recon: Dict[str, Dict[str, np.ndarray]] = {
        mk: {qk: np.empty((n, d), np.float32) for qk in short_kinds}
        for mk in mask_kinds
    }
    for r_id, (mk, qk, i) in index.items():
        recon[mk][qk][i] = results[r_id].value

    par = parity_report(model, params, requests, results, rows=parity_rows)

    per_mask: Dict[str, Any] = {}
    for mk in mask_kinds:
        missing = ~evidence[mk]
        row: Dict[str, float] = {
            "missing_fraction": float(np.mean(missing)),
        }
        row["engine_seconds"] = mask_seconds[mk]
        for qk in short_kinds:
            err = recon[mk][qk][:, missing] - images[:, missing]
            row[f"{qk}_mse"] = float(np.mean(err ** 2))
        if mean_fill is not None:
            base = np.broadcast_to(mean_fill, images.shape)[:, missing] \
                - images[:, missing]
            row["mean_fill_mse"] = float(np.mean(base ** 2))
        per_mask[mk] = row

    metrics = {
        "num_images": int(n),
        "num_requests": len(requests),
        "engine_seconds": engine_s,
        "requests_per_s": len(requests) / max(engine_s, 1e-9),
        "per_mask": per_mask,
        **par,
    }
    return InpaintingReport(
        mask_kinds=tuple(mask_kinds),
        evidence_masks=evidence,
        reconstructions=recon,
        metrics=metrics,
    )
