"""Held-out likelihood / bits-per-dim evaluation through the serving engine.

The test split is streamed through ``repro.serve.ServeEngine`` as ordinary
``joint_ll`` / ``marginal_ll`` requests -- evaluation is deliberately NOT a
separate batched code path, it is *traffic*: the same coalescing, bucket
padding and compiled-program cache that serve production queries also serve
the benchmark, so the numbers in EXPERIMENTS.md measure the deployed path.

Parity is counted against direct per-request ``EiNet.query`` calls (batch-1
jitted programs): a *mismatch* is any request whose engine result is not
bit-identical to the direct result.  Row-independent LL math and per-row
PRNG keys make bit-identity the engine's contract (PR 2), so the eval
harness inherits "exactly 0 mismatches" as its acceptance gate.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional

import numpy as np

from repro import obs
from repro.compile import REGISTRY
from repro.core.einet import EiNet
from repro.serve import Request, ServeEngine

LN2 = float(np.log(2.0))


def bits_per_dim(mean_ll: float, num_dims: int, offset_bits: float = 0.0) -> float:
    """nats of model log-likelihood -> bits-per-dim of the original data.

    ``offset_bits`` is the per-dim change-of-variables term from the domain
    transform (``repro.data.datasets.to_domain``): log2(256) for uint8 data
    modelled as x/255 on [0, 1] by continuous leaves, 0 for discrete leaves.
    """
    return -float(mean_ll) / (num_dims * LN2) + float(offset_bits)


@dataclasses.dataclass
class EngineLLResult:
    """Per-row log-likelihoods + parity/throughput accounting."""

    ll: np.ndarray  # (N,) float32
    kind: str
    engine_seconds: float  # steady-state drain time (post warm-up)
    warmup_seconds: float  # compile time paid once
    parity_rows: int  # rows checked against direct EiNet.query
    parity_mismatches: int  # rows NOT bit-identical to the direct call
    parity_max_abs_diff: float

    @property
    def rows_per_second(self) -> float:
        return len(self.ll) / max(self.engine_seconds, 1e-9)


def _request_batch(model: EiNet, req: Request) -> Dict[str, Any]:
    """The batch-1 ``EiNet.query`` input reproducing one engine request."""
    from repro.serve.engine import _key_data

    d = model.num_vars
    zeros = np.zeros((1, d), np.float32)
    fmask = np.zeros((1, d), bool)
    return {
        "x": zeros if req.x is None else np.asarray(req.x, np.float32)[None],
        "evidence_mask": fmask if req.evidence_mask is None
        else np.asarray(req.evidence_mask, bool)[None],
        "query_mask": fmask if req.query_mask is None
        else np.asarray(req.query_mask, bool)[None],
        "keys": _key_data(req.seed)[None],
    }


def _direct_fn(model: EiNet, kind: str, component=None):
    """One jitted batch-1 query program per (model, kind[, component]): a
    fresh jit(partial(...)) per call would retrace/recompile for EVERY
    audited request (exhaustive parity passes issue hundreds).  Cached in
    the shared ``ProgramRegistry`` anchored to the model (weakref -- dead
    models release their programs), because jax's own jit cache is keyed on
    the partial object identity and would never hit."""
    if component is None:
        fn = functools.partial(model.query, kind=kind)
        key = ("direct_query", kind)
    else:
        # mixture component-pinned kinds: the component is static, same
        # as the engine's per-component compiled programs
        fn = functools.partial(model.query, kind=kind, component=int(component))
        key = ("direct_query", kind, int(component))
    return REGISTRY.jit(model, key, fn)


def direct_query(model: EiNet, params: Dict[str, Any], req: Request):
    """Direct (engine-free) result for one request: the parity oracle."""
    fn = _direct_fn(model, req.kind, getattr(req, "component", None))
    return np.asarray(fn(params, _request_batch(model, req)))[0]


def parity_report(
    model: EiNet,
    params: Dict[str, Any],
    requests,
    results: Dict[int, Any],
    rows: Optional[int] = None,
) -> Dict[str, Any]:
    """Count engine-vs-direct mismatches (bitwise) over ``rows`` requests."""
    checked = mismatches = 0
    max_diff = 0.0
    for req in requests:
        if rows is not None and checked >= rows:
            break
        ref = direct_query(model, params, req)
        got = np.asarray(results[req.req_id].value)
        checked += 1
        if not np.array_equal(got, ref):
            mismatches += 1
            max_diff = max(max_diff, float(np.max(np.abs(got - ref))))
    return {
        "parity_rows": checked,
        "parity_mismatches": mismatches,
        "parity_max_abs_diff": max_diff,
    }


def engine_log_likelihoods(
    model: EiNet,
    params: Dict[str, Any],
    x: np.ndarray,
    kind: str = "joint_ll",
    evidence_mask: Optional[np.ndarray] = None,
    engine: Optional[ServeEngine] = None,
    max_batch: int = 64,
    parity_rows: Optional[int] = 64,
) -> EngineLLResult:
    """Stream ``x`` (N, D) through the engine as LL requests, in order.

    ``evidence_mask`` (broadcastable to (N, D)) switches ``marginal_ll`` on a
    shared or per-row mask.  ``parity_rows=None`` checks every row;
    ``0`` skips the parity pass (pure-throughput benchmarking).
    """
    if kind not in ("joint_ll", "marginal_ll",
                    "mixture_joint_ll", "mixture_marginal_ll"):
        raise ValueError(f"LL streaming supports joint/marginal, got {kind!r}")
    n = len(x)
    if engine is None:
        engine = ServeEngine(model, params, max_batch=min(max_batch, max(n, 1)))
    ev = None
    if evidence_mask is not None:
        ev = np.broadcast_to(np.asarray(evidence_mask, bool), x.shape)
    reqs = [
        Request(
            req_id=i,
            kind=kind,
            x=np.asarray(x[i], np.float32),
            evidence_mask=None if ev is None else ev[i],
        )
        for i in range(n)
    ]
    warmup = engine.warmup(kinds=[kind])
    with obs.timed("eval.ll_stream", kind=kind) as t:
        results = engine.run(reqs)
    engine_s = t.seconds
    ll = np.array([float(results[i].value) for i in range(n)], np.float32)
    par = {"parity_rows": 0, "parity_mismatches": 0, "parity_max_abs_diff": 0.0}
    if parity_rows is None or parity_rows > 0:
        par = parity_report(model, params, reqs, results, rows=parity_rows)
    return EngineLLResult(
        ll=ll, kind=kind, engine_seconds=engine_s, warmup_seconds=warmup, **par
    )


def direct_log_likelihoods(
    model: EiNet,
    params: Dict[str, Any],
    x: np.ndarray,
    kind: str = "joint_ll",
    evidence_mask: Optional[np.ndarray] = None,
    chunk: int = 256,
) -> np.ndarray:
    """The engine-free dense baseline: fixed-size jitted chunks of
    ``EiNet.query`` (zero-padded tail), for throughput comparison in
    ``benchmarks/bench_eval.py``."""
    n, d = x.shape
    chunk = min(chunk, n)
    fn = _direct_fn(model, kind)  # cached: repeat calls must not recompile
    ev = np.zeros((n, d), bool) if evidence_mask is None else \
        np.broadcast_to(np.asarray(evidence_mask, bool), x.shape)
    out = np.empty(n, np.float32)
    fmask = np.zeros((chunk, d), bool)
    keys = np.zeros((chunk, 2), np.uint32)
    for i in range(0, n, chunk):
        xs = np.zeros((chunk, d), np.float32)
        es = np.zeros((chunk, d), bool)
        m = min(chunk, n - i)
        xs[:m] = x[i: i + m]
        es[:m] = ev[i: i + m]
        batch = {"x": xs, "evidence_mask": es, "query_mask": fmask,
                 "keys": keys}
        out[i: i + m] = np.asarray(fn(params, batch))[:m]
    return out


def evaluate_bpd(
    model: EiNet,
    params: Dict[str, Any],
    x: np.ndarray,
    offset_bits: float = 0.0,
    engine: Optional[ServeEngine] = None,
    max_batch: int = 64,
    parity_rows: Optional[int] = 64,
    kind: str = "joint_ll",
) -> Dict[str, Any]:
    """Test-split bits-per-dim through the engine; returns a flat JSON-able
    record (the EXPERIMENTS.md ingestion format).  ``kind="mixture_joint_ll"``
    evaluates a mixture model through the identical traffic path."""
    res = engine_log_likelihoods(
        model, params, x, kind=kind, engine=engine, max_batch=max_batch,
        parity_rows=parity_rows,
    )
    mean_ll = float(np.mean(res.ll))
    return {
        "num_rows": int(len(x)),
        "num_dims": int(x.shape[1]),
        "mean_ll": mean_ll,
        "bpd": bits_per_dim(mean_ll, x.shape[1], offset_bits),
        "bpd_offset_bits": float(offset_bits),
        "engine_rows_per_s": res.rows_per_second,
        "engine_seconds": res.engine_seconds,
        "warmup_seconds": res.warmup_seconds,
        "parity_rows": res.parity_rows,
        "parity_mismatches": res.parity_mismatches,
        "parity_max_abs_diff": res.parity_max_abs_diff,
    }
