"""Artifact writers: PNG image grids + per-run metrics JSON.

Grids are plain row-major tilings (PIL, no matplotlib dependency at
runtime): samples render as one grid, inpainting renders one grid per mask
kind with rows [original / masked / conditional sample / MPE decode] -- the
layout of the paper's Fig. 4.  Metrics JSONs land next to the PNGs under
``artifacts/eval/<run>/`` and are ingested by
``benchmarks/make_experiments_md.py`` into the EXPERIMENTS.md Fig. 4 section.
"""

from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional, Sequence

import numpy as np


def _to_uint8(images: np.ndarray, vmax: float) -> np.ndarray:
    """(N, H, W, C) floats in [0, vmax] -> uint8, clipped."""
    return np.clip(images / vmax * 255.0, 0.0, 255.0).astype(np.uint8)


def save_image_grid(
    path: str,
    images: np.ndarray,  # (N, H, W, C) float, domain [0, vmax]
    columns: int = 8,
    vmax: float = 1.0,
    pad: int = 2,
) -> str:
    """Tile images into one PNG; returns the written path."""
    from PIL import Image  # container ships Pillow

    n, h, w, c = images.shape
    cols = max(1, min(columns, n))
    rows = -(-n // cols)
    canvas = np.full(
        (rows * (h + pad) + pad, cols * (w + pad) + pad, c), 32, np.uint8
    )
    tiles = _to_uint8(images, vmax)
    for i in range(n):
        r, col = divmod(i, cols)
        y, x = pad + r * (h + pad), pad + col * (w + pad)
        canvas[y: y + h, x: x + w] = tiles[i]
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    mode = "L" if c == 1 else "RGB"
    Image.fromarray(canvas[..., 0] if c == 1 else canvas, mode).save(path)
    return path


def save_inpainting_grid(
    path: str,
    originals: np.ndarray,  # (N, D) domain floats
    evidence_mask: np.ndarray,  # (D,) bool
    conditional: np.ndarray,  # (N, D)
    mpe: np.ndarray,  # (N, D)
    height: int,
    width: int,
    channels: int,
    vmax: float = 1.0,
    columns: Optional[int] = None,
) -> str:
    """Fig. 4 layout: four rows per column block -- original, masked
    (occluded pixels zeroed), conditional sample, MPE decode."""
    n = len(originals)
    masked = np.where(evidence_mask[None, :], originals, 0.0)
    stack = np.concatenate([originals, masked, conditional, mpe])
    imgs = stack.reshape(-1, height, width, channels)
    return save_image_grid(path, imgs, columns=columns or n, vmax=vmax)


def save_metrics_json(path: str, record: Dict[str, Any]) -> str:
    """Atomic JSON write (tmp + rename), sorted keys for stable diffs."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(record, f, indent=2, sort_keys=True, default=float)
    os.replace(tmp, path)
    return path


def load_eval_records(root: str = "artifacts/eval") -> Sequence[Dict[str, Any]]:
    """All per-run metrics JSONs under ``root`` (for EXPERIMENTS.md)."""
    records = []
    if not os.path.isdir(root):
        return records
    for run in sorted(os.listdir(root)):
        p = os.path.join(root, run, "metrics.json")
        if os.path.isfile(p):
            with open(p) as f:
                records.append(json.load(f))
    return records
