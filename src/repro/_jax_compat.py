"""Compatibility shims for the mesh/sharding API this codebase targets.

The source tree is written against the modern ambient-mesh API
(``jax.set_mesh``, ``jax.make_mesh(..., axis_types=...)``, ``jax.shard_map``,
``jax.sharding.get_abstract_mesh``).  The container pins an older jax that
predates those entry points but has the same machinery under different names
(``with mesh:`` resource envs, ``jax.experimental.shard_map``).  This module
bridges the gap: :func:`install` adds ONLY the missing attributes -- on a
modern jax it is a no-op, so nothing ever shadows a real implementation.

Installed from ``repro/__init__.py`` so every entry point (tests, drivers,
the dry-run subprocesses) sees a uniform API after ``import repro``.
"""

from __future__ import annotations

import contextlib
import functools
import inspect

import jax


def ambient_mesh():
    """The mesh currently in scope, or None.

    Checks the modern abstract-mesh context first, then the legacy
    ``with mesh:`` resource env.  Returns a mesh whose ``.shape`` maps axis
    name -> size, or None when no mesh is active (the single-device path).
    """
    get_abstract = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_abstract is not None and not getattr(get_abstract, "_repro_shim", False):
        try:
            m = get_abstract()
            if m is not None and not getattr(m, "empty", False):
                return m
        except Exception:
            pass
    try:
        from jax._src import mesh as _mesh_lib

        m = _mesh_lib.thread_resources.env.physical_mesh
        if m is not None and not m.empty:
            return m
    except Exception:
        pass
    return None


def install() -> None:
    shd = jax.sharding

    if not hasattr(shd, "AxisType"):
        class AxisType:
            """Stand-in for jax.sharding.AxisType (Auto/Explicit/Manual)."""

            Auto = "auto"
            Explicit = "explicit"
            Manual = "manual"

        shd.AxisType = AxisType

    if (not getattr(jax.make_mesh, "_repro_shim", False)
            and "axis_types" not in inspect.signature(jax.make_mesh).parameters):
        _orig_make_mesh = jax.make_mesh

        @functools.wraps(_orig_make_mesh)
        def make_mesh(axis_shapes, axis_names, *, devices=None, axis_types=None):
            # axis_types only matters for the Explicit-sharding type system,
            # which this codebase never relies on (everything is Auto/GSPMD).
            del axis_types
            return _orig_make_mesh(axis_shapes, axis_names, devices=devices)

        # explicit marker: functools.wraps copies __wrapped__, which makes
        # inspect.signature see the ORIGINAL signature -- the check above
        # alone would re-wrap on a second install()
        make_mesh._repro_shim = True
        jax.make_mesh = make_mesh

    if not hasattr(jax, "set_mesh"):
        @contextlib.contextmanager
        def set_mesh(mesh):
            """Context manager form of jax.set_mesh over the legacy env."""
            with mesh:
                yield mesh

        jax.set_mesh = set_mesh

    if not hasattr(shd, "get_abstract_mesh"):
        def get_abstract_mesh():
            try:
                from jax._src import mesh as _mesh_lib

                m = _mesh_lib.thread_resources.env.physical_mesh
                if m is not None and not m.empty:
                    return m
            except Exception:
                pass
            raise RuntimeError(
                "no mesh in scope; wrap the call in jax.set_mesh(mesh)"
            )

        get_abstract_mesh._repro_shim = True
        shd.get_abstract_mesh = get_abstract_mesh

    # Compiled.cost_analysis: jax >= 0.5 returns one flat dict; 0.4.x returns
    # a one-element list of dicts.  On old jax only, normalize to the dict
    # form the codebase (launch/dryrun.py, tests/test_roofline.py) targets.
    _old_jax = tuple(int(p) for p in jax.__version__.split(".")[:2]) < (0, 5)
    _compiled = jax.stages.Compiled
    if _old_jax and not getattr(_compiled.cost_analysis, "_repro_shim", False):
        _orig_cost_analysis = _compiled.cost_analysis

        def cost_analysis(self):
            r = _orig_cost_analysis(self)
            if isinstance(r, list) and r and isinstance(r[0], dict):
                return r[0]
            return r

        cost_analysis._repro_shim = True
        _compiled.cost_analysis = cost_analysis

    if not hasattr(jax, "shard_map"):
        from jax.experimental.shard_map import shard_map as _shard_map

        def shard_map(f, mesh=None, in_specs=None, out_specs=None,
                      check_vma=None, check_rep=None, **kwargs):
            if mesh is None:
                mesh = ambient_mesh()
                if mesh is None:
                    raise RuntimeError(
                        "jax.shard_map without an explicit mesh needs an "
                        "ambient mesh; wrap the call in jax.set_mesh(mesh)"
                    )
            if check_rep is None:
                check_rep = True if check_vma is None else bool(check_vma)
            return _shard_map(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=check_rep,
                              **kwargs)

        jax.shard_map = shard_map
