"""Rule-based logical-axis -> PartitionSpec resolution.

Model code never names mesh axes.  It annotates tensors with *logical* axes
(``"batch"``, ``"heads"``, ``"mlp"``, ``"expert"``, ``"einet_nodes"``, ...)
via :func:`constraint`, and parameter/batch placement is derived from the
leaf's *tree path* via :func:`tree_shardings` / :func:`batch_shardings`.  A
rule table -- installed with :func:`use_rules` -- maps each logical axis to a
mesh axis (or a tuple of mesh axes, or None for replicated).  Swapping the
table re-targets the whole model: single-pod vs multi-pod DP, FSDP on or
off, sequence parallelism on or off, with zero changes to model code.

Degradation contract (load-bearing for the tier-1 suite): every entry point
is a no-op when there are no rules in scope, no ambient mesh, or a 1-device
mesh -- so the single-device path has no distribution dependencies and jit
traces are byte-identical to an annotation-free model.

Resolution of one tensor dim:
  logical name -> rules[name] -> mesh axes; the axes are kept only if they
  all exist in the mesh, none was already used by an earlier dim of the same
  tensor, and the dim size divides evenly -- otherwise that dim degrades to
  replicated (never an error: rules are preferences, not requirements).
"""

from __future__ import annotations

import contextlib
import threading
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro._jax_compat import ambient_mesh as _ambient_mesh

Rules = Dict[str, Any]  # logical axis -> mesh axis | tuple of axes | None

_state = threading.local()


# ===========================================================================
# rule tables
# ===========================================================================
def default_rules(multi_pod: bool, fsdp: bool) -> Rules:
    """The production rule table.

    * ``batch``  -- data parallelism over ("pod", "data") / ("data",); the
      "pod" axis is the slow DCN axis, only DP reductions cross it.
    * ``seq`` / ``heads`` / ``mlp`` / ``vocab`` -- megatron-style tensor
      parallelism: activations carry the "model" axis on different dims at
      different points of the layer.
    * ``expert`` -- expert parallelism for MoE (a single axis name: the
      all-to-all needs one contiguous axis).
    * ``einet_nodes`` -- the EiNet layer-node axis (paper Eq. 5's L dim):
      einsum weights, EM statistics and leaf rows all shard over "model"
      along it, which is what makes the E-step psum move K x K blocks
      instead of full layers.
    * ``fsdp`` -- parameter sharding over the fast DP axis (ZeRO-3 style);
      None keeps parameters fully replicated over DP.
    """
    dp: Tuple[str, ...] = ("pod", "data") if multi_pod else ("data",)
    return {
        "batch": dp,
        "seq": "model",
        "heads": "model",
        "mlp": "model",
        "vocab": "model",
        "expert": "model",
        "expert_mlp": None,
        "einet_nodes": "model",
        "fsdp": ("data",) if fsdp else None,
    }


def serve_rules(multi_pod: bool = False) -> Rules:
    """Rule table for the batched inference engine (``repro.serve``): data
    parallelism over the micro-batch, layer-node sharding over "model", no
    FSDP (serving keeps parameters resident).  Degrades to a no-op on a
    single device like every other table."""
    return default_rules(multi_pod, fsdp=False)


@contextlib.contextmanager
def use_rules(rules: Rules):
    """Install ``rules`` for the dynamic extent of the block (re-entrant:
    the innermost table wins, the outer one is restored on exit)."""
    stack = getattr(_state, "stack", None)
    if stack is None:
        stack = _state.stack = []
    stack.append(dict(rules))
    try:
        yield rules
    finally:
        stack.pop()


def get_rules() -> Optional[Rules]:
    """The innermost active rule table, or None outside any use_rules."""
    stack = getattr(_state, "stack", None)
    return stack[-1] if stack else None


# ===========================================================================
# resolution
# ===========================================================================
def _mesh_axis_sizes(mesh) -> Dict[str, int]:
    return dict(mesh.shape)


def resolve_spec(
    axes: Sequence[Optional[str]],
    shape: Sequence[int],
    axis_sizes: Dict[str, int],
    rules: Rules,
) -> Optional[P]:
    """Pure resolution: logical axes + rules + mesh axis sizes -> spec.

    Returns None when nothing ended up sharded (caller skips the constraint).
    """
    used = set()
    entries = []
    for i, name in enumerate(axes):
        mesh_axes = rules.get(name) if name is not None else None
        if mesh_axes is None:
            entries.append(None)
            continue
        if isinstance(mesh_axes, str):
            mesh_axes = (mesh_axes,)
        else:
            mesh_axes = tuple(mesh_axes)
        prod = 1
        ok = True
        for ax in mesh_axes:
            if ax not in axis_sizes or ax in used:
                ok = False
                break
            prod *= axis_sizes[ax]
        dim = shape[i] if i < len(shape) else 0
        if not ok or prod <= 1 or dim <= 0 or dim % prod != 0:
            entries.append(None)
            continue
        used.update(mesh_axes)
        entries.append(mesh_axes if len(mesh_axes) > 1 else mesh_axes[0])
    if not used:
        return None
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def _mesh_in_scope():
    mesh = _ambient_mesh()
    if mesh is None:
        return None
    sizes = _mesh_axis_sizes(mesh)
    total = 1
    for s in sizes.values():
        total *= s
    if total <= 1:
        return None
    return mesh


def constraint(x, axes: Sequence[Optional[str]]):
    """Pin ``x``'s layout to the resolved logical ``axes``.

    A no-op (returns ``x`` unchanged) without rules, without an ambient
    mesh, or on a 1-device mesh -- single-device callers pay nothing.
    """
    rules = get_rules()
    if rules is None:
        return x
    mesh = _mesh_in_scope()
    if mesh is None:
        return x
    spec = resolve_spec(axes, x.shape, _mesh_axis_sizes(mesh), rules)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ===========================================================================
# tree placement
# ===========================================================================
def _path_str(path) -> str:
    """jax key path -> "/nested/list/0/leaf" (stable across key types)."""
    parts = []
    for k in path:
        for attr in ("key", "idx", "name"):
            v = getattr(k, attr, None)
            if v is not None:
                parts.append(str(v))
                break
        else:
            parts.append(str(k))
    return "/" + "/".join(parts)


# (path suffix -> logical axes per dim), first match wins.  Matched with
# str.endswith / containment on the `_path_str` form, so the same table
# covers params, grads, EM statistics, and AdamW moment trees (whose leaves
# live under the same suffixes).
_PARAM_AXES: Tuple[Tuple[str, Tuple[Optional[str], ...]], ...] = (
    # -- EiNet (phi: (D, K, R, |T|); einsum: (L, k_out, K, K); mixing: (M, C, k))
    ("/phi", ("einet_nodes", None, None, None)),
    ("/einsum/*", ("einet_nodes", None, None, None)),
    ("/mixing/*", ("einet_nodes", None, None)),
    ("/n_einsum/*", ("einet_nodes", None, None, None)),
    ("/n_mixing/*", ("einet_nodes", None, None)),
    ("/s_phi", ("einet_nodes", None, None, None)),
    ("/s_den", ("einet_nodes", None, None)),
    ("/class_prior", (None,)),
    # -- attention (stacked over periods: leading np dim)
    ("/wq", (None, "fsdp", "heads")),
    ("/wk", (None, "fsdp", "heads")),
    ("/wv", (None, "fsdp", "heads")),
    ("/wo", (None, "heads", "fsdp")),
    ("/bq", (None, "heads")),
    ("/bk", (None, "heads")),
    ("/bv", (None, "heads")),
    # -- MoE (router replicated: every token needs every expert's logit)
    ("/moe/router", (None, None, None)),
    ("/moe/wg", (None, "expert", "fsdp", None)),
    ("/moe/wu", (None, "expert", "fsdp", None)),
    ("/moe/wd", (None, "expert", None, "fsdp")),
    # -- dense FFN
    ("/mlp/wg", (None, "fsdp", "mlp")),
    ("/mlp/wu", (None, "fsdp", "mlp")),
    ("/mlp/wd", (None, "mlp", "fsdp")),
    # -- mamba
    ("/in_proj", (None, "fsdp", "mlp")),
    ("/conv_w", (None, None, "mlp")),
    ("/x_proj", (None, "mlp", None)),
    ("/dt_proj", (None, None, "mlp")),
    ("/dt_bias", (None, "mlp")),
    ("/a_log", (None, "mlp", None)),
    ("/d_skip", (None, "mlp")),
    ("/out_proj", (None, "mlp", "fsdp")),
    # -- xLSTM
    ("/up", (None, "fsdp", "mlp")),
    ("/wq_l", (None, None, "mlp")),
    ("/wk_l", (None, None, "mlp")),
    ("/wi", (None, "mlp", None)),
    ("/wf", (None, "mlp", None)),
    ("/down", (None, "mlp", "fsdp")),
    ("/wx", (None, "fsdp", "mlp")),
    ("/bx", (None, "mlp")),
    # -- embedding / unembedding
    ("/embed", ("vocab", "fsdp")),
    ("/head", ("fsdp", "vocab")),
)


def _axes_for_path(p: str, ndim: int) -> Optional[Tuple[Optional[str], ...]]:
    for suffix, axes in _PARAM_AXES:
        if suffix.endswith("/*"):
            stem = suffix[:-2]
            i = p.rfind("/")
            hit = i > 0 and p[:i].endswith(stem) and p[i + 1:].isdigit()
        else:
            hit = p.endswith(suffix)
        if hit:
            return axes if len(axes) == ndim else None
    return None


def _leaf_spec(path, x, axis_sizes: Dict[str, int], rules: Rules) -> P:
    shape = getattr(x, "shape", ())
    axes = _axes_for_path(_path_str(path), len(shape))
    if axes is None:
        return P()
    return resolve_spec(axes, shape, axis_sizes, rules) or P()


def _rules_for(mesh) -> Rules:
    rules = get_rules()
    if rules is None:
        rules = default_rules("pod" in _mesh_axis_sizes(mesh), fsdp=False)
    return rules


def tree_shardings(mesh, tree) -> Any:
    """NamedSharding per leaf, derived from the leaf's tree path.

    Covers parameter trees (LM and EiNet), gradient/EM-statistic trees, and
    optimizer-state trees (same path suffixes); unmatched leaves -- or
    leaves whose shape no longer lines up with the pattern, e.g. int8-
    quantized moments -- replicate.
    """
    rules = _rules_for(mesh)
    sizes = _mesh_axis_sizes(mesh)
    return jax.tree_util.tree_map_with_path(
        lambda path, x: NamedSharding(mesh, _leaf_spec(path, x, sizes, rules)),
        tree,
    )


def batch_shardings(mesh, batch) -> Any:
    """Shard every batch leaf's leading dim over the DP axes (replicate
    leaves whose leading dim does not divide)."""
    rules = _rules_for(mesh)
    sizes = _mesh_axis_sizes(mesh)

    def leaf(x):
        shape = getattr(x, "shape", ())
        axes = ("batch",) + (None,) * (len(shape) - 1) if shape else (None,)
        return NamedSharding(mesh, resolve_spec(axes, shape, sizes, rules) or P())

    return jax.tree_util.tree_map(leaf, batch)


def constrain_like_params(tree) -> Any:
    """Pin each leaf of ``tree`` to the layout its path would give a
    parameter: gradients and EM statistics realign to the weight sharding
    *before* the DP reduction, turning it into a reduce-scatter-shaped psum
    instead of moving replicated full tensors.  Identity without rules or
    a multi-device mesh."""
    rules = get_rules()
    if rules is None:
        return tree
    mesh = _mesh_in_scope()
    if mesh is None:
        return tree
    sizes = _mesh_axis_sizes(mesh)

    def leaf(path, x):
        spec = _leaf_spec(path, x, sizes, rules)
        if spec == P():
            return x
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return jax.tree_util.tree_map_with_path(leaf, tree)
