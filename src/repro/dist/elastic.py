"""Elastic resharding: re-place a pytree on a grown or shrunk device mesh.

Elasticity story: the mesh is a *function of the currently alive devices*
(``repro.launch.mesh.make_mesh_for``), parameter placement is a *function of
the tree and the rules* (``repro.dist.sharding.tree_shardings``), and the
data pipeline is stateless.  So surviving a lost (or gained) device is just:
build a new mesh over the live devices, :func:`reshard` the state onto it,
continue -- no parameter surgery, no renumbering, values bit-identical.

``reshard`` accepts host (numpy) arrays or jax Arrays from *any* previous
mesh; cross-mesh moves that the runtime cannot express as a direct transfer
fall back to a host round-trip (gather -> place), which is exactly the
DCN-bandwidth path a real elastic-training system takes on a topology
change.
"""

from __future__ import annotations

from typing import Any

import jax
import numpy as np

from repro.dist import sharding as sharding_lib


def reshard(tree: Any, mesh) -> Any:
    """Place ``tree``'s leaves on ``mesh`` under the active sharding rules.

    Values are preserved exactly (this is data movement, not math); layouts
    come from :func:`repro.dist.sharding.tree_shardings`, so the result is
    immediately consumable by a jit compiled against that mesh.
    """
    shardings = sharding_lib.tree_shardings(mesh, tree)

    def move(x, sh):
        try:
            return jax.device_put(x, sh)
        except Exception:
            # cross-mesh move the runtime can't express directly (e.g. the
            # source mesh no longer exists): gather to host, then place.
            return jax.device_put(np.asarray(jax.device_get(x)), sh)

    return jax.tree_util.tree_map(move, tree, shardings)
