"""Fault-tolerant training loop: deterministic checkpoint-restart + straggler
mitigation.

The recovery contract is *exact state reproduction*, not best-effort: because
the data pipeline is stateless (``batch_at(step)`` is a pure function of the
step -- repro.data.pipeline) and the step function is deterministic, a run
with N injected failures produces bit-identical final state to a run with
none.  Restart = restore the newest committed checkpoint, replay from its
step.  That property is what the tier-1 test pins
(tests/test_checkpoint_ft.py::test_run_training_with_failures).

A restart *budget* bounds crash loops: a persistent fault (bad node, corrupt
input) must surface as an error, not an infinite replay cycle.

``StragglerMonitor`` is the detection half of slow-node mitigation: per-shard
step-time windows, median-based outlier detection (robust when *most* of the
fleet is slow -- a global slowdown is not a straggler), and a spare-remapping
plan consumed by the launch layer (data shards are re-assignable for free:
``batch_at(step, shard)`` makes shard identity a parameter, not state).
"""

from __future__ import annotations

import collections
import dataclasses
import statistics
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro import obs


@dataclasses.dataclass(frozen=True)
class LoopConfig:
    checkpoint_every: int = 100       # steps between committed checkpoints
    max_restarts: int = 3             # total failures tolerated per run
    straggler_factor: float = 2.0     # mean step time > factor * fleet median
    straggler_window: int = 16        # samples per shard before judging


def run_training(
    step_fn: Callable[[Any, Any], Any],
    init: Any,
    batch_at: Callable[[int], Any],
    mgr,
    num_steps: int,
    cfg: LoopConfig = LoopConfig(),
    fail_injector: Optional[Callable[[int], None]] = None,
    on_step: Optional[Callable[[int, Any], None]] = None,
) -> Tuple[Any, Dict[str, Any]]:
    """Run ``num_steps`` deterministic steps with checkpoint-restart recovery.

    Args:
      step_fn: (state, batch) -> state.  Deterministic.
      init: initial state pytree (also the restore template).
      batch_at: step -> batch.  Pure function of the step index.
      mgr: a repro.checkpoint.CheckpointManager.
      fail_injector: test hook, called with the step index before each step;
        raising simulates a node failure at that step.
      on_step: observer called with (completed_step_count, state).

    Returns (final_state, stats) where stats["restarts"] counts recoveries.
    Raises RuntimeError once failures exceed ``cfg.max_restarts``.
    """
    state = init
    step = 0
    if mgr.latest_step() is not None:  # resume a preempted run
        step, state = mgr.restore(init)
    restarts = 0
    failures: List[str] = []
    t_start = obs.now()
    while step < num_steps:
        try:
            if fail_injector is not None:
                fail_injector(step)
            state = step_fn(state, batch_at(step))
            step += 1
            if on_step is not None:
                on_step(step, state)
            if cfg.checkpoint_every > 0 and step % cfg.checkpoint_every == 0:
                mgr.save(step, state)
        except Exception as e:  # noqa: BLE001 -- any step failure is a "node loss"
            restarts += 1
            failures.append(f"step {step}: {e!r}")
            if restarts > cfg.max_restarts:
                raise RuntimeError(
                    f"restart budget exhausted ({cfg.max_restarts} allowed, "
                    f"{restarts} failures): {failures}"
                ) from e
            try:
                mgr.wait()  # let an in-flight async commit land before looking
            except Exception as we:  # noqa: BLE001 -- a failed write just means
                failures.append(f"checkpoint writer: {we!r}")  # an older restore
            if mgr.latest_step() is None:
                step, state = 0, init  # nothing committed yet: replay all
            else:
                step, state = mgr.restore(init)
    mgr.wait()
    stats = {
        "restarts": restarts,
        "failures": failures,
        "final_step": step,
        "wall_time_s": obs.now() - t_start,
    }
    return state, stats


class StragglerMonitor:
    """Detect persistently slow data shards and plan spare remappings.

    ``record(shard, step_time)`` feeds per-shard timings; a shard is a
    straggler once its windowed mean exceeds ``straggler_factor`` times the
    fleet *median* of windowed means (median, not mean: robust to one huge
    outlier inflating the baseline, and a uniformly slow fleet -- e.g. a
    bigger batch -- flags nobody).  ``mitigate()`` consumes spares in order,
    returning {straggler_shard: spare_id}; the caller re-points
    ``batch_at(step, shard)`` at the spare.  Shards are only judged on full
    windows, so a cold-start blip cannot trigger a remap.
    """

    def __init__(self, num_shards: int, cfg: LoopConfig = LoopConfig(),
                 spares: Optional[Sequence[int]] = None):
        self.cfg = cfg
        self.num_shards = num_shards
        self.times: Dict[int, collections.deque] = {
            s: collections.deque(maxlen=cfg.straggler_window)
            for s in range(num_shards)
        }
        self.spares: List[int] = list(spares) if spares else []
        self.remapped: Dict[int, int] = {}

    def record(self, shard: int, step_time: float) -> None:
        self.times[shard].append(float(step_time))

    def _windowed_means(self) -> Dict[int, float]:
        return {
            s: sum(d) / len(d)
            for s, d in self.times.items()
            if len(d) >= self.cfg.straggler_window
        }

    def stragglers(self) -> List[int]:
        means = self._windowed_means()
        if len(means) < 2:  # nothing to compare against
            return []
        out = []
        for s, m in means.items():
            # leave-one-out median: a shard must not dilute its own baseline
            # (with 2 shards and factor>=2, a self-inclusive median could
            # never flag anything)
            others = [v for t, v in means.items() if t != s]
            med = statistics.median(others)
            if med > 0.0 and m > self.cfg.straggler_factor * med:
                out.append(s)
        return sorted(out)

    def mitigate(self) -> Dict[int, int]:
        """Assign spares to stragglers (first detected, first served).
        Returns this round's {straggler: spare}; empty when no spares are
        left or nobody qualifies.  A remapped shard's window resets so the
        spare is judged on its own timings."""
        remap: Dict[int, int] = {}
        for s in self.stragglers():
            if not self.spares:
                break
            spare = self.spares.pop(0)
            remap[s] = spare
            self.remapped[s] = spare
            self.times[s].clear()
        return remap
