"""Distribution subsystem: sharding rules, fault tolerance, elasticity.

  sharding         logical-axis rules -> PartitionSpecs (no-op on 1 device)
  fault_tolerance  checkpoint-restart training loop + straggler mitigation
  elastic          re-place state on a grown/shrunk mesh
"""

from repro.dist import elastic, fault_tolerance, sharding

__all__ = ["elastic", "fault_tolerance", "sharding"]
