"""Distribution tests that need >1 device: run in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (the main pytest process
must keep seeing 1 device, per the dry-run isolation rule)."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(body: str) -> dict:
    script = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json, sys
        import jax, jax.numpy as jnp
        import numpy as np
        """
    ) + textwrap.dedent(body)
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        env=env, timeout=600,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_distributed_em_matches_single_device():
    """One pjit stochastic-EM step on a (4, 2) mesh == the single-device
    update: the E-step statistics psum is exact (DESIGN.md §2)."""
    r = _run("""
    from jax.sharding import PartitionSpec as P, NamedSharding
    from repro.core import EiNet, Normal, random_binary_trees
    from repro.core.em import EMConfig, stochastic_em_update
    from repro.dist import sharding as shlib

    g = random_binary_trees(12, 2, 2, seed=0)
    net = EiNet(g, num_sums=4, exponential_family=Normal())
    params = net.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (32, 12))
    ref, ll_ref = stochastic_em_update(net, params, x, EMConfig())

    mesh = jax.make_mesh((4, 2), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    rules = shlib.default_rules(multi_pod=False, fsdp=False)
    with shlib.use_rules(rules), jax.set_mesh(mesh):
        psh = shlib.tree_shardings(mesh, params)
        xsh = NamedSharding(mesh, P("data", None))
        xd = jax.device_put(x, xsh)
        pd = jax.tree_util.tree_map(jax.device_put, params, psh)
        out, ll = jax.jit(
            lambda p, b: stochastic_em_update(net, p, b, EMConfig()),
            in_shardings=(psh, xsh), out_shardings=(psh, None),
        )(pd, xd)
    errs = [float(jnp.max(jnp.abs(a - b))) for a, b in zip(
        jax.tree_util.tree_leaves(ref), jax.tree_util.tree_leaves(out))
        if a.size]
    print(json.dumps({"max_err": max(errs), "ll": float(ll),
                      "ll_ref": float(ll_ref)}))
    """)
    assert r["max_err"] < 1e-4, r
    assert abs(r["ll"] - r["ll_ref"]) < 1e-4


@pytest.mark.slow
def test_elastic_reshard_roundtrip():
    """Params placed on an 8-device mesh, 'shrunk' to 4 devices, keep values."""
    r = _run("""
    from repro.dist import elastic, sharding as shlib
    from repro.launch.mesh import make_mesh_for

    rules = shlib.default_rules(multi_pod=False, fsdp=False)
    tree = {"blocks": ({"mlp": {"wu": jax.random.normal(jax.random.PRNGKey(0),
                                                        (2, 8, 32))}},),
            "head": jax.random.normal(jax.random.PRNGKey(1), (8, 128))}
    with shlib.use_rules(rules):
        m8 = make_mesh_for(jax.devices(), model_parallel=4)
        placed = elastic.reshard(tree, m8)
        m4 = make_mesh_for(jax.devices()[:4], model_parallel=2)
        moved = elastic.reshard(jax.tree_util.tree_map(np.asarray, placed), m4)
    err = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(
        jax.tree_util.tree_leaves(tree), jax.tree_util.tree_leaves(moved)))
    ndev = len({d for l in jax.tree_util.tree_leaves(moved)
                for d in l.sharding.device_set})
    print(json.dumps({"err": err, "ndev": ndev}))
    """)
    assert r["err"] == 0.0
    assert r["ndev"] == 4


@pytest.mark.slow
def test_compressed_psum_shard_map():
    """int8 all-reduce inside shard_map approximates the exact psum."""
    r = _run("""
    from jax.sharding import PartitionSpec as P
    from repro.optim.compression import compressed_psum

    mesh = jax.make_mesh((8,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    g = jax.random.normal(jax.random.PRNGKey(0), (8, 256))

    def body(g_loc, r_loc):
        out, new_res = compressed_psum(g_loc[0], "data", r_loc[0])
        return out[None], new_res[None]

    with jax.set_mesh(mesh):
        fn = jax.shard_map(body,
                           in_specs=(P("data", None), P("data", None)),
                           out_specs=(P("data", None), P("data", None)))
        out, res = jax.jit(fn)(g, jnp.zeros_like(g))
    exact = jnp.sum(g, axis=0)
    rel = float(jnp.max(jnp.abs(out[0] - exact)) / (jnp.max(jnp.abs(exact)) + 1e-9))
    print(json.dumps({"rel": rel}))
    """)
    assert r["rel"] < 0.05, r


@pytest.mark.slow
def test_sharded_em_step_matches_single_device():
    """The shard_map psum-EM step (make_sharded_em_step, the multi-host
    launch path) on an 8-way data mesh == the single-shard compiled step on
    the same batch: the explicit statistics psum is exact, microbatch
    accumulation included.  Closes the ROADMAP 'Distributed compiled EM'
    item."""
    r = _run("""
    from repro.core import EiNet, Normal, random_binary_trees
    from repro.dist import sharding as shlib
    from repro.train import TrainConfig, make_em_step, make_sharded_em_step

    g = random_binary_trees(12, 2, 2, seed=0)
    net = EiNet(g, num_sums=4, exponential_family=Normal())
    params = net.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 12))

    cfg = TrainConfig(mode="stochastic", num_microbatches=2, donate=False)
    ref, ll_ref = make_em_step(net, cfg)(params, x)

    mesh = jax.make_mesh((8,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    rules = shlib.default_rules(multi_pod=False, fsdp=False)
    with shlib.use_rules(rules), jax.set_mesh(mesh):
        step = make_sharded_em_step(net, cfg, mesh)
        out, ll = step(params, x)
        out2, ll2 = step(out, x)  # second step: no retrace surprises
    errs = [float(jnp.max(jnp.abs(a - b))) for a, b in zip(
        jax.tree_util.tree_leaves(ref), jax.tree_util.tree_leaves(out))
        if a.size]
    # replication check: every shard ran the identical M-step on psum'd
    # totals, so every device's buffer must hold the same values.  Compare
    # the actual per-device data (check_rep=False means nothing else
    # guarantees this; sharding metadata alone would be vacuous here).
    def shards_agree(a):
        datas = [np.asarray(s.data) for s in a.addressable_shards]
        return all(np.array_equal(datas[0], d) for d in datas[1:])
    reps = [shards_agree(a)
            for a in jax.tree_util.tree_leaves(out) if a.size]
    print(json.dumps({"max_err": max(errs), "ll": float(ll),
                      "ll_ref": float(ll_ref), "ll2": float(ll2),
                      "replicated": all(reps)}))
    """)
    assert r["max_err"] < 1e-4, r
    assert abs(r["ll"] - r["ll_ref"]) < 1e-4
    assert r["replicated"], r
