"""EiNet behaviour tests: normalization, parity, marginals, sampling."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    Bernoulli,
    EiNet,
    NaiveEiNet,
    Normal,
    poon_domingos,
    random_binary_trees,
)


@pytest.fixture(scope="module")
def rat_net():
    g = random_binary_trees(12, 2, 3, seed=0)
    net = EiNet(g, num_sums=5, exponential_family=Normal())
    params = net.init(jax.random.PRNGKey(0))
    return net, params


def test_full_marginalization_is_normalized(rat_net):
    """Integrating everything out must give exactly 1 (log 1 = 0): the
    self-normalization property of smooth+decomposable PCs (paper §2)."""
    net, params = rat_net
    x = jnp.zeros((4, net.num_vars))
    mask = jnp.zeros((4, net.num_vars), dtype=bool)
    ll = net.log_likelihood(params, x, mask)
    np.testing.assert_allclose(np.asarray(ll), 0.0, atol=1e-5)


def test_naive_baseline_parity(rat_net):
    """EiNet einsum layers == LibSPN-style log-sum-exp layers (Table 1 logic)."""
    net, params = rat_net
    naive = NaiveEiNet(net.graph, num_sums=5, exponential_family=Normal())
    x = jax.random.normal(jax.random.PRNGKey(1), (16, net.num_vars))
    a = net.log_likelihood(params, x)
    b = naive.log_likelihood(params, x)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


def test_pallas_kernel_parity(rat_net):
    net, params = rat_net
    kern = EiNet(net.graph, num_sums=5, exponential_family=Normal(),
                 impl="pallas")
    x = jax.random.normal(jax.random.PRNGKey(2), (8, net.num_vars))
    a = net.log_likelihood(params, x)
    b = kern.log_likelihood(params, x)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


def test_bernoulli_exact_marginalization():
    """Brute-force check of exact inference: sum_x P(x) = 1 and
    marginal p(x_A) = sum_{x_B} p(x_A, x_B) on a small Bernoulli EiNet."""
    g = random_binary_trees(6, 1, 2, seed=3)
    net = EiNet(g, num_sums=3, exponential_family=Bernoulli())
    params = net.init(jax.random.PRNGKey(3))
    # all 64 assignments
    grid = np.array(
        [[(i >> d) & 1 for d in range(6)] for i in range(64)], np.float32
    )
    ll = np.asarray(net.log_likelihood(params, jnp.asarray(grid)))
    total = np.exp(ll).sum()
    np.testing.assert_allclose(total, 1.0, rtol=1e-4)
    # marginal over last 3 vars via evidence mask == explicit sum
    mask = jnp.asarray([[True] * 3 + [False] * 3] * 8)
    x_a = grid[:8].copy()
    marg = np.exp(np.asarray(net.log_likelihood(params, jnp.asarray(x_a), mask)))
    brute = np.zeros(8)
    for i in range(8):
        sel = (grid[:, :3] == grid[i, :3]).all(axis=1)
        brute[i] = np.exp(ll[sel]).sum()
    np.testing.assert_allclose(marg, brute, rtol=1e-4)


def test_conditional_log_likelihood_consistency(rat_net):
    """log p(q|e) + log p(e) == log p(q, e) (Eq. 1, exactly)."""
    net, params = rat_net
    x = jax.random.normal(jax.random.PRNGKey(4), (5, net.num_vars))
    qmask = jnp.zeros((5, net.num_vars), bool).at[:, :6].set(True)
    emask = jnp.zeros((5, net.num_vars), bool).at[:, 6:].set(True)
    cond = net.conditional_log_likelihood(params, x, qmask, emask)
    joint = net.log_likelihood(params, x, qmask | emask)
    ev = net.log_likelihood(params, x, emask)
    np.testing.assert_allclose(np.asarray(cond), np.asarray(joint - ev), atol=1e-5)


def test_sampling_shapes_and_evidence(rat_net):
    net, params = rat_net
    s = net.sample(params, jax.random.PRNGKey(5), 7)
    assert s.shape == (7, net.num_vars)
    assert np.isfinite(np.asarray(s)).all()
    x = jax.random.normal(jax.random.PRNGKey(6), (7, net.num_vars))
    ev = jnp.zeros((7, net.num_vars), bool).at[:, ::2].set(True)
    cs = net.conditional_sample(params, jax.random.PRNGKey(7), x, ev)
    np.testing.assert_array_equal(
        np.asarray(cs)[:, ::2], np.asarray(x)[:, ::2]
    )
    # argmax mode is deterministic
    a1 = net.conditional_sample(params, jax.random.PRNGKey(8), x, ev, mode="argmax")
    a2 = net.conditional_sample(params, jax.random.PRNGKey(9), x, ev, mode="argmax")
    np.testing.assert_allclose(np.asarray(a1), np.asarray(a2))


def test_sampling_distribution_matches_density():
    """Samples from a Bernoulli EiNet should have empirical frequencies
    close to the exact per-assignment probabilities."""
    g = random_binary_trees(4, 1, 2, seed=10)
    net = EiNet(g, num_sums=3, exponential_family=Bernoulli())
    params = net.init(jax.random.PRNGKey(10))
    n = 20_000
    s = np.asarray(net.sample(params, jax.random.PRNGKey(11), n))
    codes = (s * (2 ** np.arange(4))).sum(axis=1).astype(int)
    emp = np.bincount(codes, minlength=16) / n
    grid = np.array([[(i >> d) & 1 for d in range(4)] for i in range(16)], np.float32)
    exact = np.exp(np.asarray(net.log_likelihood(params, jnp.asarray(grid))))
    np.testing.assert_allclose(emp, exact, atol=0.02)


def test_pd_einet_forward():
    g = poon_domingos(4, 4, delta=2, num_channels=3, axes=("w",))
    net = EiNet(g, num_sums=4, exponential_family=Normal())
    params = net.init(jax.random.PRNGKey(12))
    x = jax.random.normal(jax.random.PRNGKey(13), (3, g.num_vars))
    ll = net.log_likelihood(params, x)
    assert ll.shape == (3,)
    assert np.isfinite(np.asarray(ll)).all()
    mask = jnp.zeros((3, g.num_vars), bool)
    np.testing.assert_allclose(
        np.asarray(net.log_likelihood(params, x, mask)), 0.0, atol=1e-4
    )


def test_num_classes_root():
    g = random_binary_trees(8, 2, 2, seed=1)
    net = EiNet(g, num_sums=4, num_classes=3, exponential_family=Normal())
    params = net.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (5, 8))
    root = net.forward(params, x)
    assert root.shape == (5, 3)
    ll = net.log_likelihood(params, x)
    assert ll.shape == (5,)
