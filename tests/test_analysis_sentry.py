"""Recompile-sentry tests: the PR 3 weak-type regression class, the
bounded serve compile count, and the leak detectors themselves.

"One compile per (kind, bucket)" is only an invariant if something can
measure compiles; these tests pin both directions -- the healthy paths
compile exactly once per program, and the seeded leaks (weak-typed prior,
dtype drift) are detected and attributed.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.sentry import CompileSentry
from repro.compile import ProgramRegistry
from repro.core import EiNet, Normal, random_binary_trees
from repro.serve import ServeEngine, mixed_requests
from repro.train import TrainConfig, make_em_step


@pytest.fixture()
def small_net():
    g = random_binary_trees(8, 2, 2, seed=0)
    net = EiNet(g, num_sums=3, exponential_family=Normal())
    return net, net.init(jax.random.PRNGKey(0))


# ---------------------------------------------------- weak-type regression
def test_em_step_compiles_exactly_once(small_net, compile_sentry):
    """The PR 3 regression: params built by ``init`` (strong float32
    class_prior) run 3 compiled EM steps with EXACTLY one compile."""
    net, params = small_net
    x = jnp.asarray(np.random.RandomState(0).randn(16, net.num_vars),
                    jnp.float32)
    raw = make_em_step(net, TrainConfig(), registry=ProgramRegistry())
    step = compile_sentry.wrap(raw, name="em_step")
    for _ in range(3):
        params, ll = step(params, x)
    compile_sentry.assert_max_compiles(1, name="em_step")
    assert len(compile_sentry.signatures("em_step")) == 1
    compile_sentry.assert_no_leaks()
    assert np.isfinite(float(ll))


def test_weak_typed_prior_detected(small_net, compile_sentry):
    """Seed the bug: a weak-typed class_prior splits the jit cache after
    the first update (the update emits a strong-typed prior), and the
    sentry both counts the second compile and names the leak."""
    net, params = small_net
    params = dict(params)
    # the pre-PR-3 construction: no dtype= -> weak_type=True
    params["class_prior"] = jnp.full(
        (net.num_classes,), 1.0 / net.num_classes)
    assert jax.core.get_aval(params["class_prior"]).weak_type
    x = jnp.asarray(np.random.RandomState(0).randn(16, net.num_vars),
                    jnp.float32)
    raw = make_em_step(net, TrainConfig(), registry=ProgramRegistry())
    step = compile_sentry.wrap(raw, name="em_step")
    for _ in range(3):
        params, _ = step(params, x)
    assert compile_sentry.compiles("em_step") == 2  # the silent recompile
    kinds = {f.kind for f in compile_sentry.findings}
    assert "weak-type-arg" in kinds  # flagged already at the first call
    assert "weak-type-leak" in kinds  # and attributed after the second
    with pytest.raises(AssertionError, match="recompile sentry"):
        compile_sentry.assert_max_compiles(1, name="em_step")
    with pytest.raises(AssertionError, match="weak"):
        compile_sentry.assert_no_leaks()


def test_dtype_promotion_leak_detected(compile_sentry):
    f = compile_sentry.wrap(lambda v: v + 1, name="f")
    f(jnp.zeros((4,), jnp.float32))
    f(jnp.zeros((4,), jnp.int32))
    assert compile_sentry.compiles("f") == 2
    assert any(f_.kind == "dtype-promotion-leak"
               for f_ in compile_sentry.findings)


def test_shape_polymorphism_is_not_a_leak(compile_sentry):
    """Different shapes (bucketing) are legitimate distinct programs."""
    f = compile_sentry.wrap(lambda v: v, name="f")
    f(jnp.zeros((4,), jnp.float32))
    f(jnp.zeros((8,), jnp.float32))
    assert compile_sentry.compiles("f") == 2
    assert compile_sentry.findings == []


# ------------------------------------------------------------ serve stream
def test_mixed_serve_stream_bounded_compiles(small_net):
    """64 mixed-kind requests compile at most kinds x buckets programs --
    the bounded-AOT-cache claim as a sentry invariant, not a cache-size
    check."""
    net, params = small_net
    engine = ServeEngine(net, params, max_batch=8,
                         registry=ProgramRegistry())
    reqs = mixed_requests(net.num_vars, 64, seed=7)
    kinds = {r.kind for r in reqs}
    with CompileSentry(registry=engine.registry) as sentry:
        results = engine.run(reqs)
    assert len(results) == 64
    bound = len(kinds) * len(engine.buckets)
    assert 0 < sentry.registry_compiles() <= bound
    # a second identical wave reuses every program: zero new compiles
    with CompileSentry(registry=engine.registry) as sentry2:
        engine.run(mixed_requests(net.num_vars, 64, seed=8))
    assert sentry2.registry_compiles() == 0


def test_registry_required_for_registry_compiles():
    with CompileSentry() as sentry:
        pass
    with pytest.raises(ValueError, match="registry"):
        sentry.registry_compiles()
