"""Optimizer tests: AdamW variants, quantized state, gradient compression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.optim import adamw, compression


def _quadratic_losses(cfg, steps=120):
    """Minimize ||x - t||^2 with AdamW; return loss trajectory."""
    target = jnp.asarray([1.5, -2.0, 0.5, 3.0])
    params = {"w": jnp.zeros(4)}
    state = adamw.init_state(cfg, params)

    @jax.jit
    def step(p, s):
        g = jax.grad(lambda q: jnp.sum((q["w"] - target) ** 2))(p)
        p2, s2, _ = adamw.apply_updates(cfg, p, g, s)
        return p2, s2

    losses = []
    for _ in range(steps):
        params, state = step(params, state)
        losses.append(float(jnp.sum((params["w"] - target) ** 2)))
    return losses


@pytest.mark.parametrize("dtype", ["float32", "bfloat16", "int8"])
def test_adamw_converges(dtype):
    cfg = adamw.AdamWConfig(learning_rate=0.1, weight_decay=0.0,
                            warmup_steps=5, decay_steps=1000,
                            state_dtype=dtype)
    losses = _quadratic_losses(cfg)
    assert losses[-1] < 0.05 * losses[0], f"{dtype}: {losses[-1]}"


def test_int8_state_tracks_f32():
    """Blockwise-int8 moments should track the f32 trajectory closely enough
    for the 1T-parameter memory trick to be safe (DESIGN.md §4)."""
    base = adamw.AdamWConfig(learning_rate=0.05, weight_decay=0.0,
                             warmup_steps=1, decay_steps=10_000)
    l32 = _quadratic_losses(base)
    l8 = _quadratic_losses(
        adamw.AdamWConfig(**{**base.__dict__, "state_dtype": "int8"})
    )
    assert abs(l8[-1] - l32[-1]) < 0.1


def test_lr_schedule_shape():
    cfg = adamw.AdamWConfig(learning_rate=1.0, warmup_steps=10, decay_steps=100,
                            min_lr_ratio=0.1)
    lrs = [float(adamw.lr_schedule(cfg, jnp.asarray(s))) for s in range(120)]
    assert lrs[0] < 0.2  # warmup starts low
    assert abs(max(lrs) - 1.0) < 1e-5
    assert np.argmax(lrs) <= 12
    assert abs(lrs[-1] - 0.1) < 0.02  # decays to min ratio


def test_grad_clip():
    cfg = adamw.AdamWConfig(learning_rate=1e-3, grad_clip=1.0)
    params = {"w": jnp.zeros(3)}
    state = adamw.init_state(cfg, params)
    huge = {"w": jnp.asarray([1e6, -1e6, 1e6])}
    p2, _, gnorm = adamw.apply_updates(cfg, params, huge, state)
    assert float(gnorm) > 1e5
    # post-clip update magnitude is bounded by ~lr
    assert np.abs(np.asarray(p2["w"])).max() < 5e-3


# ----------------------------------------------------------------- compression
@given(st.integers(0, 1000), st.integers(10, 5000))
@settings(max_examples=20, deadline=None)
def test_int8_roundtrip_error_bound(seed, n):
    x = jax.random.normal(jax.random.PRNGKey(seed), (n,)) * 10
    q, s = compression.quantize_int8(x)
    back = compression.dequantize_int8(q, s, x.shape)
    # error bounded by half a quantization step per block
    err = np.abs(np.asarray(x - back))
    bound = np.repeat(np.asarray(s), compression.BLOCK)[: n] * 0.5 + 1e-6
    assert (err <= bound + 1e-5).all()


def test_error_feedback_removes_bias():
    """With error feedback, the *running sum* of compressed grads converges
    to the running sum of true grads (no systematic bias)."""
    key = jax.random.PRNGKey(0)
    g_true = jax.random.normal(key, (512,)) * 0.1
    res = None
    acc = jnp.zeros((512,))
    for i in range(50):
        (q, s), res = compression.compress_with_feedback(g_true, res)
        acc = acc + compression.dequantize_int8(q, s, g_true.shape)
    total_err = np.abs(np.asarray(acc - 50 * g_true)).max()
    # residual carries at most one step's quantization error
    assert total_err < float(np.abs(np.asarray(g_true)).max()) * 0.02 + 1e-3


def test_topk_sparsify():
    x = jnp.asarray([0.1, -5.0, 0.2, 3.0, -0.05])
    (vals, idx), res = compression.topk_sparsify(x, 2, None)
    dense = compression.densify_topk(vals, idx, x.shape)
    np.testing.assert_allclose(
        np.asarray(dense), [0, -5.0, 0, 3.0, 0], atol=1e-6
    )
    np.testing.assert_allclose(np.asarray(res), [0.1, 0, 0.2, 0, -0.05],
                               atol=1e-6)
