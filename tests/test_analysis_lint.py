"""Repo-lint tests: each rule catches its seeded violation snippet, the
allowlists hold, waivers suppress (and only with a reason), and -- the
satellite acceptance -- the actual tree lints clean with ZERO waivers.
"""

import json
import pathlib
import subprocess
import sys
import textwrap

import pytest

from repro.analysis.lint import (
    RULES,
    Violation,
    lint_source,
    load_waivers,
    run_lint,
)

SRC = pathlib.Path(__file__).resolve().parents[1] / "src" / "repro"


def rules_of(violations):
    return {v.rule for v in violations}


def lint(src, path="src/repro/serve/somefile.py"):
    return lint_source(textwrap.dedent(src), path)


# ------------------------------------------------------------ rule negatives
def test_neg_inf_literal_caught():
    found = lint("LOG_ZERO = -1e30\n")
    assert rules_of(found) == {"neg-inf-literal"}
    # the canonical home is exempt
    assert lint("NEG_INF = -1e30\n", "src/repro/core/layers.py") == []
    # ordinary floats are not
    assert lint("x = -1e6\n") == []


def test_interpret_default_caught():
    bad = "def kernel(x, interpret=True):\n    return x\n"
    found = lint(bad, "src/repro/kernels/foo.py")
    assert rules_of(found) == {"interpret-default"}
    # None default inside kernels is the contract
    ok = "def kernel(x, interpret=None):\n    return x\n"
    assert lint(ok, "src/repro/kernels/foo.py") == []
    # outside kernels the knob must not exist at all, even defaulted to None
    found = lint(ok, "src/repro/serve/foo.py")
    assert rules_of(found) == {"interpret-default"}
    # no-default (the resolver itself) is fine inside kernels
    res = "def resolve_interpret(interpret):\n    return bool(interpret)\n"
    assert lint(res, "src/repro/kernels/dispatch.py") == []


def test_pallas_contract_caught():
    found = lint("out = pl.pallas_call(kern, out_shape=shape)(x)\n")
    assert rules_of(found) == {"pallas-contract"}
    found = lint("out = log_einsum_exp_pallas(w, l, r)\n")
    assert rules_of(found) == {"pallas-contract"}
    # inside the kernels package both are the implementation itself
    assert lint("out = pl.pallas_call(kern, out_shape=s)(x)\n",
                "src/repro/kernels/grouped.py") == []


def test_bare_jit_caught():
    assert rules_of(lint("f = jax.jit(g)\n")) == {"bare-jit"}
    assert rules_of(lint(
        "@jax.jit\ndef f(x):\n    return x\n")) == {"bare-jit"}
    assert rules_of(lint("p = jax.pmap(g)\n")) == {"bare-jit"}
    # the allowlist: registry, train step builders, kernel ABI wrappers
    for path in ("src/repro/compile.py", "src/repro/train/pipeline.py",
                 "src/repro/kernels/grouped.py"):
        assert lint("f = jax.jit(g)\n", path) == []


def test_donated_read_caught():
    bad = """
    def fit(model, params, x):
        step = make_em_step(model)
        step(params, x)
        return params
    """
    assert rules_of(lint(bad)) == {"donated-read"}


def test_donated_read_rebinding_is_clean():
    ok = """
    def fit(model, params, x):
        step = make_em_step(model)
        for _ in range(3):
            params, ll = step(params, x)
        return params, ll
    """
    assert lint(ok) == []


def test_donated_read_in_loop_without_rebinding_caught():
    bad = """
    def fit(model, params, x):
        step = make_sharded_em_step(model)
        for _ in range(3):
            ll = step(params, x)
        return params
    """
    assert "donated-read" in rules_of(lint(bad))


def test_timing_outside_obs_caught():
    # raw clock reads are obs's job (obs.now / obs.timed)
    bad = "import time\nt0 = time.perf_counter()\n"
    assert rules_of(lint(bad)) == {"timing-outside-obs"}
    assert rules_of(lint("import time\nt = time.time()\n")) == \
        {"timing-outside-obs"}
    assert rules_of(lint("from time import perf_counter\n")) == \
        {"timing-outside-obs"}
    assert rules_of(lint("import time\nt = time.monotonic_ns()\n")) == \
        {"timing-outside-obs"}
    # the obs package itself and standalone benchmark drivers are the allow
    assert lint(bad, "src/repro/obs/trace.py") == []
    assert lint(bad, "benchmarks/bench_serve.py") == []
    # non-clock uses of the time module are not timing
    assert lint("import time\ntime.sleep(0.1)\n") == []
    assert lint("from time import sleep\n") == []


@pytest.mark.parametrize("rule", sorted(RULES))
def test_every_rule_has_a_negative(rule):
    """Each rule id above is exercised by a seeded-violation test; pin the
    rule list so adding a rule forces adding its negative test."""
    seeded = {
        "neg-inf-literal": "x = 1e30\n",
        "interpret-default": "def k(x, interpret=False):\n    return x\n",
        "pallas-contract": "pl.pallas_call(k)\n",
        "bare-jit": "jax.jit(f)\n",
        "donated-read": (
            "def f(m, p, x):\n"
            "    s = make_em_step(m)\n"
            "    s(p, x)\n"
            "    print(p)\n"
        ),
        "timing-outside-obs": "import time\nt = time.perf_counter()\n",
    }
    assert rule in seeded
    assert rule in rules_of(lint(seeded[rule]))


# ----------------------------------------------------------------- waivers
def test_waiver_suppresses_with_reason(tmp_path):
    f = tmp_path / "bad.py"
    f.write_text("x = -1e30\n")
    waivers = tmp_path / "waivers.json"
    waivers.write_text(json.dumps([{
        "rule": "neg-inf-literal", "path": "bad.py",
        "reason": "test fixture"}]))
    violations, waived = run_lint([str(f)], str(waivers))
    assert violations == [] and len(waived) == 1


def test_waiver_requires_reason(tmp_path):
    waivers = tmp_path / "waivers.json"
    waivers.write_text(json.dumps([{"rule": "bare-jit", "path": "x.py"}]))
    with pytest.raises(ValueError, match="reason"):
        load_waivers(str(waivers))


def test_waiver_line_mismatch_does_not_suppress(tmp_path):
    f = tmp_path / "bad.py"
    f.write_text("x = -1e30\n")
    waivers = tmp_path / "waivers.json"
    waivers.write_text(json.dumps([{
        "rule": "neg-inf-literal", "path": "bad.py", "line": 999,
        "reason": "wrong line"}]))
    violations, waived = run_lint([str(f)], str(waivers))
    assert len(violations) == 1 and waived == []


# ------------------------------------------------------------- tree is clean
def test_tree_lints_clean_with_zero_waivers():
    violations, waived = run_lint([str(SRC)])
    assert violations == [], "\n".join(str(v) for v in violations)
    assert waived == []
    assert load_waivers() == []  # the shipped waiver file is empty


def test_cli_exit_codes(tmp_path):
    env_src = str(SRC.parents[0])
    ok = subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint", str(SRC / "core")],
        capture_output=True, text=True, env={"PYTHONPATH": env_src,
                                             "PATH": "/usr/bin:/bin"},
    )
    assert ok.returncode == 0, ok.stdout + ok.stderr
    bad = tmp_path / "bad.py"
    bad.write_text("f = jax.jit(g)\n")
    fail = subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint", str(bad)],
        capture_output=True, text=True, env={"PYTHONPATH": env_src,
                                             "PATH": "/usr/bin:/bin"},
    )
    assert fail.returncode == 1
    assert "bare-jit" in fail.stdout


def test_violation_str_is_clickable():
    v = Violation("bare-jit", "repro/serve/x.py", 12, "msg")
    assert str(v) == "repro/serve/x.py:12: bare-jit: msg"
