"""repro.data.datasets: procedural fallback, caching, domain transforms,
and the ShardedLoader contract for image data."""

import numpy as np
import pytest

from repro.data import datasets as ds


def test_procedural_is_deterministic_and_shaped():
    spec = ds.SPECS["mnist"]
    x1, y1 = ds.procedural_images(spec, 32, seed=0)
    x2, y2 = ds.procedural_images(spec, 32, seed=0)
    np.testing.assert_array_equal(x1, x2)
    np.testing.assert_array_equal(y1, y2)
    assert x1.shape == (32, 28, 28, 1) and x1.dtype == np.uint8
    assert y1.shape == (32,) and y1.dtype == np.int32
    assert y1.min() >= 0 and y1.max() < spec.num_classes
    x3, _ = ds.procedural_images(spec, 32, seed=1)
    assert not np.array_equal(x1, x3)  # seeds differ


def test_procedural_dataset_splits_and_api():
    d = ds.load_image_dataset("mnist", source="procedural", size_cap=200)
    assert d.source == "procedural"
    assert d.spec.name == "mnist"
    n_valid = len(d.valid_x)
    assert n_valid == max(1, int(200 * ds.VALID_FRACTION))
    assert len(d.train_x) + n_valid == 200
    assert d.test_x.shape[1:] == (28, 28, 1)
    for split in ("train", "valid", "test"):
        x, y = d.split(split)
        assert len(x) == len(y) and x.dtype == np.uint8
    with pytest.raises(KeyError):
        d.split("nope")


def test_svhn_procedural_shapes():
    d = ds.load_image_dataset("svhn", source="procedural", size_cap=64)
    assert d.train_x.shape[1:] == (32, 32, 3)
    assert d.spec.num_dims == 32 * 32 * 3


def test_unknown_dataset_and_source():
    with pytest.raises(KeyError):
        ds.load_image_dataset("imagenet")
    with pytest.raises(ValueError):
        ds.load_image_dataset("mnist", source="torrent")


def test_celeba_procedural_shapes_and_splits():
    d = ds.load_image_dataset("celeba", source="procedural", size_cap=64)
    assert d.source == "procedural"
    assert d.train_x.shape[1:] == (32, 32, 3) and d.train_x.dtype == np.uint8
    assert d.spec.num_dims == 32 * 32 * 3
    assert d.spec.num_classes == 1  # unlabeled: density estimation only
    assert len(d.train_x) + len(d.valid_x) == 64
    x, off = ds.to_domain(d.test_x, "normal")
    assert x.dtype == np.float32 and off == pytest.approx(8.0)


def test_celeba_raw_build_and_cache(tmp_path):
    """The "download" source builds the npz cache from a locally provided
    raw copy (CelebA has no anonymous mirror): jpgs are center-cropped,
    resized to the 32x32 spec, and split by list_eval_partition.txt."""
    PIL = pytest.importorskip("PIL")
    from PIL import Image

    raw = tmp_path / "celeba_raw" / "img_align_celeba"
    raw.mkdir(parents=True)
    rng = np.random.RandomState(0)
    names = [f"{i:06d}.jpg" for i in range(1, 7)]
    for name in names:
        Image.fromarray(
            rng.randint(0, 256, (218, 178, 3), dtype=np.uint8)
        ).save(raw / name)
    with open(tmp_path / "celeba_raw" / "list_eval_partition.txt", "w") as f:
        for i, name in enumerate(names):
            f.write(f"{name} {0 if i < 4 else 2}\n")
    d = ds.load_image_dataset("celeba", data_dir=str(tmp_path))
    assert d.source == "download"
    assert len(d.train_x) + len(d.valid_x) == 4 and len(d.test_x) == 2
    assert d.train_x.shape[1:] == (32, 32, 3)
    assert (tmp_path / "celeba.npz").is_file()
    # second load resolves from the npz cache, not the raw files
    d2 = ds.load_image_dataset("celeba", data_dir=str(tmp_path))
    assert d2.source == "cache"
    np.testing.assert_array_equal(d.test_x, d2.test_x)


def test_celeba_without_raw_copy_is_unavailable(tmp_path):
    with pytest.raises(ds.DatasetUnavailable):
        ds.load_image_dataset("celeba", data_dir=str(tmp_path))


def test_to_domain_per_family():
    x = np.arange(2 * 4, dtype=np.uint8).reshape(2, 2, 2, 1) * 30
    unit, off = ds.to_domain(x, "normal")
    assert unit.shape == (2, 4) and unit.dtype == np.float32
    assert unit.max() <= 1.0 and off == pytest.approx(8.0)
    counts, off0 = ds.to_domain(x, "binomial")
    np.testing.assert_array_equal(counts, x.reshape(2, 4).astype(np.float32))
    assert off0 == 0.0
    with pytest.raises(ValueError):
        ds.to_domain(x, "poisson")


def test_cache_roundtrip_and_size_cap(tmp_path):
    spec = ds.SPECS["mnist"]
    tx, ty = ds.procedural_images(spec, 64, seed=0)
    ex, ey = ds.procedural_images(spec, 32, seed=1)
    np.savez_compressed(tmp_path / "mnist.npz", train_x=tx, train_y=ty,
                        test_x=ex, test_y=ey)
    d = ds.load_image_dataset("mnist", data_dir=str(tmp_path))
    assert d.source == "cache"
    assert len(d.train_x) + len(d.valid_x) == 64
    capped = ds.load_image_dataset("mnist", data_dir=str(tmp_path),
                                   size_cap=16)
    assert len(capped.train_x) + len(capped.valid_x) == 16
    assert len(capped.test_x) <= 64


def test_offline_download_raises_dataset_unavailable(tmp_path, monkeypatch):
    def no_net(url, path, timeout=60.0):
        raise OSError("network unreachable")

    monkeypatch.setattr(ds, "_download", no_net)
    with pytest.raises(ds.DatasetUnavailable):
        ds.load_image_dataset("mnist", data_dir=str(tmp_path))


def test_array_loader_shards_disjoint_and_tile():
    data = np.arange(64, dtype=np.float32)[:, None].repeat(3, 1)
    loaders = [
        ds.array_loader(data, global_batch=16, num_shards=4, shard_id=s)
        for s in range(4)
    ]
    step0 = [l.batch_at(0)["x"][:, 0] for l in loaders]
    seen = np.concatenate(step0)
    assert len(np.unique(seen)) == 16  # disjoint shards
    # steps tile the dataset contiguously
    np.testing.assert_array_equal(
        np.sort(np.concatenate([l.batch_at(s)["x"][:, 0]
                                for l in loaders for s in range(4)])),
        np.arange(64, dtype=np.float32),
    )


def test_image_loader_domain_and_contract():
    d = ds.load_image_dataset("mnist", source="procedural", size_cap=96)
    loader = ds.image_loader(d, "train", global_batch=8, family="normal")
    b = next(loader)
    assert b["x"].shape == (8, 784) and b["x"].dtype == np.float32
    assert b["x"].max() <= 1.0
    # stateless: batch_at(step) is reproducible
    np.testing.assert_array_equal(loader.batch_at(0)["x"],
                                  ds.image_loader(d, "train", 8).batch_at(0)["x"])


def test_synthetic_image_dataset_wrapping():
    d = ds.synthetic_image_dataset(8, 8, 1, num_train=48, num_test=16, seed=3)
    assert d.spec.num_dims == 64
    assert d.train_x.dtype == np.uint8
    assert len(d.train_x) + len(d.valid_x) == 48
    x, off = ds.to_domain(d.test_x, "normal")
    assert x.shape == (16, 64) and off == pytest.approx(8.0)
