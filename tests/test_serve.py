"""Serving-engine tests: queue/slot mechanics, parity of batched results with
direct model calls, bucket-padding isolation, bounded program cache."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import EiNet, Normal, random_binary_trees
from repro.dist import sharding as shlib
from repro.serve import (
    Request,
    RequestQueue,
    ServeEngine,
    SlotManager,
    direct_call,
    mixed_requests,
    request_key,
)


@pytest.fixture(scope="module")
def small_net():
    g = random_binary_trees(8, 2, 2, seed=0)
    net = EiNet(g, num_sums=3, exponential_family=Normal())
    params = net.init(jax.random.PRNGKey(0))
    return net, params


# ---------------------------------------------------------------- queue/slots
def test_request_queue_fifo_and_pop_kind():
    q = RequestQueue()
    for i, kind in enumerate(["joint_ll", "mpe", "joint_ll", "sample", "mpe"]):
        q.submit(Request(i, kind))
    assert len(q) == 5
    assert q.oldest_kind() == "joint_ll"
    assert q.pending_kinds() == ["joint_ll", "mpe", "sample"]
    taken = q.pop_kind("joint_ll", limit=10)
    assert [r.req_id for r in taken] == [0, 2]
    # remaining order preserved
    assert q.oldest_kind() == "mpe"
    taken = q.pop_kind("mpe", limit=1)
    assert [r.req_id for r in taken] == [1]
    assert [r.req_id for r in q.pop_kind("sample", 5)] == [3]
    assert [r.req_id for r in q.pop_kind("mpe", 5)] == [4]
    assert len(q) == 0 and q.oldest_kind() is None


def test_slot_manager_bounds_and_release():
    s = SlotManager(3)
    leases = [s.acquire() for _ in range(3)]
    assert sorted(leases) == [0, 1, 2] and s.free == 0
    assert s.acquire() is None
    s.release(leases[0])
    assert s.free == 1
    with pytest.raises(ValueError):
        s.release(leases[0])  # double release
    assert s.acquire() == leases[0]


def test_request_key_matches_prngkey():
    for seed in (0, 1, 12345, 2**40 + 17):
        np.testing.assert_array_equal(
            np.asarray(request_key(seed)), np.asarray(jax.random.PRNGKey(seed))
        )


# -------------------------------------------------------------------- parity
def test_query_entry_point_matches_model_calls(small_net):
    net, params = small_net
    d = net.num_vars
    rng = np.random.RandomState(1)
    x = jnp.asarray(rng.randn(5, d), jnp.float32)
    ev = jnp.asarray(rng.rand(5, d) < 0.5)
    keys = jnp.stack([jax.random.PRNGKey(i) for i in range(5)])
    batch = {"x": x, "evidence_mask": ev, "query_mask": ~ev, "keys": keys}
    np.testing.assert_array_equal(
        np.asarray(net.query(params, batch, "joint_ll")),
        np.asarray(net.log_likelihood(params, x)),
    )
    np.testing.assert_array_equal(
        np.asarray(net.query(params, batch, "marginal_ll")),
        np.asarray(net.log_likelihood(params, x, ev)),
    )
    np.testing.assert_array_equal(
        np.asarray(net.query(params, batch, "conditional_ll")),
        np.asarray(net.conditional_log_likelihood(params, x, ~ev, ev)),
    )
    # per-key sampling: row i == direct batch-1 call with key i
    cs = np.asarray(net.query(params, batch, "conditional_sample"))
    for i in range(5):
        ref = net.conditional_sample(
            params, jax.random.PRNGKey(i), x[i: i + 1], ev[i: i + 1]
        )[0]
        np.testing.assert_allclose(cs[i], np.asarray(ref), atol=1e-5)
    with pytest.raises(ValueError):
        net.query(params, batch, "nope")


def test_mixed_stream_parity_with_direct_calls(small_net):
    """A shuffled heterogeneous stream through the engine must reproduce the
    direct jitted per-request calls (the acceptance contract: <= 1e-5; LL
    kinds and the discrete structure land bit-identical in practice)."""
    net, params = small_net
    reqs = mixed_requests(net.num_vars, 13, seed=2)
    engine = ServeEngine(net, params, max_batch=8)
    results = engine.run(reqs)
    assert sorted(results) == list(range(13))
    call = direct_call(net, params)
    for r in reqs:
        ref = np.asarray(call(r))
        np.testing.assert_allclose(results[r.req_id].value, ref, atol=1e-5)
        if r.kind in ("conditional_sample", "mpe"):
            # evidence rows pass through untouched
            np.testing.assert_array_equal(
                results[r.req_id].value[r.evidence_mask],
                r.x[r.evidence_mask],
            )


def test_bucket_padding_never_leaks(small_net):
    """Identical streams through engines with different bucket layouts must
    return identical results: filler rows and micro-batch composition cannot
    perturb real rows (row-independent LL math + per-row sampling keys)."""
    net, params = small_net
    mix = ("joint_ll", "conditional_sample", "marginal_ll")
    reqs = mixed_requests(net.num_vars, 10, seed=3, mix=mix)
    out_small = ServeEngine(net, params, max_batch=4).run(reqs)
    out_large = ServeEngine(net, params, max_batch=16).run(reqs)
    assert ServeEngine(net, params, max_batch=16)._bucket_for(4) == 4
    for i in out_small:
        np.testing.assert_array_equal(out_small[i].value, out_large[i].value)


def test_program_cache_bounded_under_random_mix(small_net):
    """Randomized traffic must never grow the program cache beyond
    len(kinds) * len(buckets), and replaying traffic must add no compiles."""
    net, params = small_net
    kinds = ("joint_ll", "marginal_ll", "conditional_sample")
    engine = ServeEngine(net, params, max_batch=4)  # buckets (1, 2, 4)
    rng = np.random.RandomState(4)
    rid = 0
    for _ in range(12):
        wave = mixed_requests(
            net.num_vars, int(rng.randint(1, 7)), seed=rid,
            mix=tuple(rng.permutation(kinds)),
        )
        for r in wave:
            r.req_id = rid
            rid += 1
        engine.run(wave)
    bound = len(kinds) * len(engine.buckets)
    assert engine.num_programs <= bound
    assert engine.stats["compiles"] == engine.num_programs  # no retraces
    before = engine.num_programs
    engine.run(mixed_requests(net.num_vars, 12, seed=99, mix=kinds))
    assert engine.num_programs <= bound
    assert engine.num_programs == engine.stats["compiles"]
    assert engine.num_programs <= before + len(kinds)  # only new buckets


def test_engine_with_serve_rules_is_noop_on_single_device(small_net):
    """The dist degradation contract: compiling under serve_rules() on a
    single device must not change results."""
    net, params = small_net
    reqs = mixed_requests(net.num_vars, 4, seed=5, mix=("joint_ll",))
    plain = ServeEngine(net, params, max_batch=4).run(reqs)
    ruled = ServeEngine(
        net, params, max_batch=4, rules=shlib.serve_rules()
    ).run(reqs)
    for i in plain:
        np.testing.assert_array_equal(plain[i].value, ruled[i].value)
