"""Depth-grouped (whole-circuit fused) execution: parity vs the per-layer
path, plan/fallback behaviour, and the kernels' dispatch contract.

The tentpole contract this file pins:

  * grouped execution is the DEFAULT forward/backward for canonical (RAT)
    structures, and its outputs are BITWISE identical to the per-layer
    loop -- per segment, per depth, the same per-cell op in the same order;
  * gradients through the grouped custom VJP match the per-layer VJP to
    <= 1e-8 (measured 0.0 on the XLA path);
  * gather/mixing (needs_buffer) structures compile to GATHER-grouped
    segments (core.plan.GatherTables) instead of falling back -- only the
    final (root) pair stays per-layer (tests/test_gather_grouped.py pins
    the numerics; this file pins the planner integration);
  * the VMEM budget splits fused segments without changing a single bit;
  * the Pallas entry points take ``interpret=None`` and resolve it through
    ``kernels.dispatch`` (never ``interpret=True`` in a public signature).
"""

import inspect
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import plan as plan_lib
from repro.core.einet import _GROUP_BLOCK_B, EiNet
from repro.core.layers import NEG_INF
from repro.core.exponential_family import Normal
from repro.core.region_graph import random_binary_trees
from repro.kernels import dispatch, grouped
from repro.launch.cells import build_einet
from repro.configs import get_config

# fully-canonical small RAT shapes (scope collisions at smaller var counts
# break the canonical layout -- see random_binary_trees region dedup)
CANONICAL_SHAPES = [
    # (num_vars, depth, repetitions, K, num_classes)
    (64, 3, 3, 10, 1),   # odd K: 10 -> 16 lane padding inside the kernel
    (64, 4, 2, 4, 3),    # deeper chain, multi-class root
    (32, 2, 2, 6, 1),    # the smallest groupable shape (smoke-config twin)
]


def _pair_models(num_vars, depth, reps, k, nc, impl="xla", **kw):
    graph = random_binary_trees(num_vars, depth, reps, seed=0)
    ef = Normal()
    m_g = EiNet(graph, num_sums=k, num_classes=nc, exponential_family=ef,
                impl=impl, grouped=True, **kw)
    m_p = EiNet(graph, num_sums=k, num_classes=nc, exponential_family=ef,
                impl=impl, grouped=False)
    params = m_g.init(jax.random.PRNGKey(0))
    x = jnp.asarray(
        np.random.RandomState(1).randn(8, num_vars).astype(np.float32)
    )
    return m_g, m_p, params, x


def _max_tree_diff(a, b):
    return max(
        float(jnp.max(jnp.abs(la - lb))) if la.size else 0.0
        for la, lb in zip(jax.tree_util.tree_leaves(a),
                          jax.tree_util.tree_leaves(b))
    )


@pytest.mark.parametrize("shape", CANONICAL_SHAPES, ids=str)
def test_grouped_forward_bitwise_xla(shape):
    m_g, m_p, params, x = _pair_models(*shape)
    assert m_g.grouped_active
    assert not m_p.grouped_active
    out_g = m_g.forward(params, x)
    out_p = m_p.forward(params, x)
    assert float(jnp.max(jnp.abs(out_g - out_p))) == 0.0


@pytest.mark.parametrize("shape", CANONICAL_SHAPES, ids=str)
def test_grouped_forward_bitwise_pallas(shape):
    # interpret resolves via kernels.dispatch (None -> interpret off-TPU)
    m_g, m_p, params, x = _pair_models(*shape, impl="pallas")
    assert m_g.grouped_active
    out_g = m_g.forward(params, x)
    out_p = m_p.forward(params, x)
    assert float(jnp.max(jnp.abs(out_g - out_p))) == 0.0


@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_grouped_grad_parity(impl):
    m_g, m_p, params, x = _pair_models(64, 3, 3, 10, 1, impl=impl)

    def nll(m):
        return lambda p: -jnp.sum(m.log_likelihood(p, x))

    g_g = jax.grad(nll(m_g))(params)
    g_p = jax.grad(nll(m_p))(params)
    assert _max_tree_diff(g_g, g_p) <= 1e-8


def test_grouped_neg_inf_saturated_rows():
    """NEG_INF-saturated leaf rows (fully-marginalized scopes) flow through
    the fused kernel's -inf padding contract: bitwise forward parity and
    finite gradients on both paths."""
    m_g, m_p, params, x = _pair_models(64, 3, 3, 10, 1, impl="pallas")
    lr = m_g._leaf_rows(m_g.leaf_log_prob(params, x, None))
    lr = lr.at[:, ::3, :].set(NEG_INF)  # saturate every third leaf row

    def root(m, rows):
        out = m.forward_from_e(params["einsum"], params["mixing"], None,
                               leaf_rows=rows)
        return out

    out_g = root(m_g, lr)
    out_p = root(m_p, lr)
    assert float(jnp.max(jnp.abs(out_g - out_p))) == 0.0

    def loss(m):
        return lambda rows: jnp.sum(root(m, rows))

    gr_g = jax.grad(loss(m_g))(lr)
    gr_p = jax.grad(loss(m_p))(lr)
    assert bool(jnp.all(jnp.isfinite(gr_g)))
    assert _max_tree_diff(gr_g, gr_p) <= 1e-8


def test_needs_buffer_structures_gather_group_and_match():
    """Scope collisions at small var counts produce shared leaves ->
    non-canonical pairs -> needs_buffer: the planner now compiles these to
    gather-grouped segments (no warning, no fallback) with bitwise-identical
    results vs the per-layer loop."""
    graph = random_binary_trees(16, 3, 3, seed=0)
    ef = Normal()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        m_g = EiNet(graph, num_sums=4, exponential_family=ef, grouped=True)
    assert not any("needs_buffer" in str(w.message) for w in rec)
    assert m_g.needs_buffer
    assert m_g.grouped_active
    s = m_g.grouping_summary()
    assert s["gather_groups"] >= 1, s
    assert s["launches_grouped"] < s["launches_per_layer"], s
    m_p = EiNet(graph, num_sums=4, exponential_family=ef, grouped=False)
    params = m_g.init(jax.random.PRNGKey(0))
    x = jnp.asarray(np.random.RandomState(2).randn(4, 16).astype(np.float32))
    assert float(jnp.max(jnp.abs(
        m_g.forward(params, x) - m_p.forward(params, x)
    ))) == 0.0


def test_vmem_budget_forces_segment_split_bitwise():
    """A VMEM budget below the 3-depth working set splits the canonical
    chain into >= 2 fused groups; the split must not change a single bit."""
    graph = random_binary_trees(64, 4, 2, seed=0)
    ef = Normal()
    whole = EiNet(graph, num_sums=4, exponential_family=ef, grouped=True)
    assert whole.grouping_summary()["fused_groups"] == 1  # whole circuit
    # largest budget that cannot fit 3 depths at the smallest tiling:
    # 2-depth groups still fit, so the greedy planner must split
    budget = plan_lib.fused_cost_bytes(
        whole.pair_specs, 0, 3, 1, min(_GROUP_BLOCK_B)
    ) - 1
    split = EiNet(graph, num_sums=4, exponential_family=ef, grouped=True,
                  vmem_budget=budget)
    summary = split.grouping_summary()
    assert summary["fused_groups"] >= 2, summary
    per_layer = EiNet(graph, num_sums=4, exponential_family=ef, grouped=False)
    params = whole.init(jax.random.PRNGKey(0))
    x = jnp.asarray(np.random.RandomState(3).randn(8, 64).astype(np.float32))
    out_w = whole.forward(params, x)
    out_s = split.forward(params, x)
    out_p = per_layer.forward(params, x)
    assert float(jnp.max(jnp.abs(out_w - out_s))) == 0.0
    assert float(jnp.max(jnp.abs(out_s - out_p))) == 0.0
    # gradients agree across the split boundary too
    g_s = jax.grad(lambda p: -jnp.sum(split.log_likelihood(p, x)))(params)
    g_p = jax.grad(lambda p: -jnp.sum(per_layer.log_likelihood(p, x)))(params)
    assert _max_tree_diff(g_s, g_p) <= 1e-8


def test_mixture_stacked_components_bitwise():
    """The mixture trainer vmaps forward_from_e over stacked component
    params (repro.mixture); the grouped op must be vmap-transparent."""
    m_g, m_p, _, x = _pair_models(64, 3, 3, 6, 1)
    keys = jax.random.split(jax.random.PRNGKey(7), 3)
    stacked = jax.vmap(m_g.init)(keys)

    def comp_root(m):
        def one(p):
            e = m.leaf_log_prob(p, x, None)
            return m.forward_from_e(p["einsum"], p["mixing"], e)
        return jax.vmap(one)(stacked)

    out_g = comp_root(m_g)
    out_p = comp_root(m_p)
    assert out_g.shape[0] == 3
    assert float(jnp.max(jnp.abs(out_g - out_p))) == 0.0


def test_registered_archs_grouped_parity():
    """Registered RAT archs group by default and match their per-layer
    twins bitwise (einet_rat_large is covered by BENCH_train.json -- its
    ~0.5B-weight init is too heavy for a unit test)."""
    cfg = get_config("einet_rat")
    m_g = build_einet(cfg)
    assert m_g.grouped_active
    graph = random_binary_trees(cfg.num_vars, cfg.depth, cfg.num_repetitions)
    m_p = EiNet(graph, num_sums=cfg.num_sums, num_classes=cfg.num_classes,
                exponential_family=Normal(min_var=cfg.min_var,
                                          max_var=cfg.max_var),
                grouped=False)
    params = m_g.init(jax.random.PRNGKey(0))
    x = jnp.asarray(
        np.random.RandomState(4).randn(4, cfg.num_vars).astype(np.float32)
    )
    assert float(jnp.max(jnp.abs(
        m_g.log_likelihood(params, x) - m_p.log_likelihood(params, x)
    ))) == 0.0


def test_registered_pd_arch_builds_gather_plan():
    """PD (gather topology) archs now compile to gather-grouped segments:
    strictly fewer launches than the per-layer loop, with only the final
    (root) pair left per-layer."""
    cfg = get_config("einet_pd_mnist")
    m = build_einet(cfg)
    assert m.grouped_active
    s = m.grouping_summary()
    assert s["gather_groups"] >= 1, s
    assert s["launches_grouped"] < s["launches_per_layer"], s
    # the only per-layer remainder is the root pair (non-uniform K_out)
    kinds = [seg[2] for seg in s["segments"]]
    assert kinds[-1] == "layer" and all(k == "gather" for k in kinds[:-1]), s
    assert any("final (root) pair" in r for _, r in s["fallbacks"]), s


def test_sampling_cache_path_stays_per_layer():
    """return_cache (sampling) needs every depth's activations, so it runs
    the per-layer loop even on a grouped model -- and still agrees with the
    cacheless grouped forward."""
    m_g, _, params, x = _pair_models(64, 3, 3, 6, 1)
    root_plain = m_g.forward(params, x)
    root_cached, cache = m_g.forward(params, x, return_cache=True)
    assert len(cache["S"]) == len(m_g.pair_specs)
    assert float(jnp.max(jnp.abs(root_plain - root_cached))) == 0.0


def test_kernel_signatures_resolve_interpret_via_dispatch():
    """The PR-3 bug class: no public Pallas entry point may default
    ``interpret=True`` -- the backend decision belongs to kernels.dispatch."""
    for fn in (grouped.grouped_log_einsum_exp_pallas,
               grouped.grouped_log_einsum_exp_bwd_pallas):
        sig = inspect.signature(fn)
        assert sig.parameters["interpret"].default is None, fn.__name__
    # and dispatch's resolution is the documented one: interpret off-TPU
    assert dispatch.resolve_interpret(None) == (not dispatch.on_tpu())
    assert dispatch.resolve_interpret(True) is True
    assert dispatch.resolve_interpret(False) is False


def test_grouping_summary_launch_accounting():
    """Launches drop from O(pairs) to O(segments) and the summary's segment
    list tiles the pair list exactly."""
    m_g, _, _, _ = _pair_models(64, 4, 2, 4, 1)
    s = m_g.grouping_summary()
    assert s["launches_grouped"] < s["launches_per_layer"]
    covered = []
    for start, stop, kind, _, _ in s["segments"]:
        assert kind in ("fused", "gather", "layer")
        covered.extend(range(start, stop))
    assert covered == list(range(s["num_pairs"]))
