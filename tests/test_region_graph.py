"""Region-graph structure tests: smoothness/decomposability invariants."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import region_graph as rg


@given(
    num_vars=st.integers(8, 64),
    depth=st.integers(1, 4),
    reps=st.integers(1, 5),
    seed=st.integers(0, 10_000),
)
@settings(max_examples=25, deadline=None)
def test_rat_structure_valid(num_vars, depth, reps, seed):
    if 2**depth > num_vars:
        depth = int(np.log2(num_vars))
    g = rg.random_binary_trees(num_vars, depth, reps, seed)
    g.validate()  # asserts decomposability + scope unions
    assert set(g.regions[g.root]) == set(range(num_vars))


def test_rat_leaf_count():
    g = rg.random_binary_trees(16, 3, 2, 0)
    for leaf in g.leaf_ids:
        assert 1 <= len(g.regions[leaf]) <= 4  # 16 / 2^3 = 2 +/- imbalance


@pytest.mark.parametrize("axes", [("w",), ("h", "w")])
def test_pd_structure_valid(axes):
    g = rg.poon_domingos(8, 8, delta=2, num_channels=1, axes=axes)
    g.validate()


def test_pd_channels_fold_into_leaf_scopes():
    g = rg.poon_domingos(2, 4, delta=2, num_channels=3, axes=("w",))
    g.validate()
    assert g.num_vars == 2 * 4 * 3
    for leaf in g.leaf_ids:
        assert len(g.regions[leaf]) % 3 == 0  # channels always travel together


def test_topological_layers_order():
    g = rg.random_binary_trees(32, 3, 4, 1)
    leaves, pairs = rg.topological_layers(g)
    seen = set(leaves)
    for l_p, l_s in pairs:
        for p in l_p:
            _, left, right = g.partitions[p]
            assert left in seen and right in seen, "child computed after parent"
        seen.update(g.partitions[p][0] for p in l_p)
        for s in l_s:
            assert all(p in l_p or ("x", p) for p in g.region_children[s])
    # final layer is exactly the root
    assert pairs[-1][1] == [g.root]


def test_topological_layers_pd():
    g = rg.poon_domingos(4, 8, delta=2, num_channels=1, axes=("w", "h"))
    leaves, pairs = rg.topological_layers(g)
    assert pairs[-1][1] == [g.root]
    # every partition appears exactly once
    all_parts = [p for l_p, _ in pairs for p in l_p]
    assert sorted(all_parts) == list(range(len(g.partitions)))


def test_replica_assignment_disjoint():
    g = rg.random_binary_trees(32, 3, 5, 2)
    scopes = [g.regions[i] for i in g.leaf_ids]
    assign, num = rg.assign_replicas(scopes)
    for r in range(num):
        used = set()
        for i, s in enumerate(scopes):
            if assign[i] == r:
                assert not (used & set(s)), "overlapping scopes share a replica"
                used |= set(s)
