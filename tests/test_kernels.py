"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.log_einsum_exp import log_einsum_exp_pallas
from repro.kernels.ref import log_einsum_exp_ref, mha_ref


def _random_lee(key, b, l, k, ko, scale=30.0):
    k1, k2, k3 = jax.random.split(key, 3)
    w = jax.nn.softmax(
        jax.random.normal(k1, (l, ko, k, k)).reshape(l, ko, -1), -1
    ).reshape(l, ko, k, k)
    lnl = -jnp.abs(jax.random.normal(k2, (b, l, k))) * scale
    lnr = -jnp.abs(jax.random.normal(k3, (b, l, k))) * scale
    return w, lnl, lnr


@pytest.mark.parametrize(
    "b,l,k,ko",
    [(1, 1, 1, 1), (4, 3, 5, 5), (7, 2, 8, 1), (130, 4, 16, 16),
     (16, 1, 40, 40), (33, 7, 13, 9)],
)
def test_log_einsum_exp_shapes(b, l, k, ko):
    w, lnl, lnr = _random_lee(jax.random.PRNGKey(b * 100 + l), b, l, k, ko)
    out = log_einsum_exp_pallas(w, lnl, lnr, interpret=True)
    ref = log_einsum_exp_ref(w, lnl, lnr)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


def test_log_einsum_exp_extreme_underflow():
    """Values around -1000 in the log-domain: naive exp would underflow to 0,
    the log-einsum-exp trick must stay exact (paper Eq. 4)."""
    w, lnl, lnr = _random_lee(jax.random.PRNGKey(0), 8, 2, 6, 6, scale=1000.0)
    out = np.asarray(log_einsum_exp_pallas(w, lnl, lnr, interpret=True))
    ref = np.asarray(log_einsum_exp_ref(w, lnl, lnr))
    assert np.isfinite(out).all()
    np.testing.assert_allclose(out, ref, atol=1e-3)


@pytest.mark.parametrize("b,l,k,ko", [(5, 3, 5, 3), (4, 2, 7, 10), (9, 1, 17, 1)])
def test_log_einsum_exp_wrapper_pads_odd_k(b, l, k, ko):
    """Non-lane-multiple K / K_out must round-trip exactly through the ops
    wrapper padding (regression: the kernel docstring promised padding that
    ``ops.py`` never implemented -- odd K would fail to compile on real TPU)."""
    w, lnl, lnr = _random_lee(jax.random.PRNGKey(10 * k + ko), b, l, k, ko)
    wp, lp, rp = ops._pad_for_lanes(w, lnl, lnr)
    assert (wp.shape[2] ** 2) % 128 == 0, "K^2 must land on a 128 lane multiple"
    assert wp.shape[1] % 128 == 0, "K_out must land on a 128 lane multiple"
    assert lp.shape == rp.shape == (b, l, wp.shape[2])
    out = ops.log_einsum_exp(w, lnl, lnr)
    assert out.shape == (b, l, ko)
    ref = log_einsum_exp_ref(w, lnl, lnr)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


def test_log_einsum_exp_custom_vjp():
    w, lnl, lnr = _random_lee(jax.random.PRNGKey(1), 12, 3, 10, 10)
    gk = jax.grad(lambda *a: ops.log_einsum_exp(*a).sum(), argnums=(0, 1, 2))(
        w, lnl, lnr
    )
    gr = jax.grad(lambda *a: log_einsum_exp_ref(*a).sum(), argnums=(0, 1, 2))(
        w, lnl, lnr
    )
    for a, b in zip(gk, gr):
        rel = np.abs(np.asarray(a) - np.asarray(b)) / (np.abs(np.asarray(b)) + 1e-2)
        assert rel.max() < 1e-3


@given(
    b=st.integers(1, 32),
    l=st.integers(1, 6),
    k=st.integers(1, 24),
    ko=st.integers(1, 24),
    seed=st.integers(0, 1000),
)
@settings(max_examples=15, deadline=None)
def test_log_einsum_exp_property(b, l, k, ko, seed):
    w, lnl, lnr = _random_lee(jax.random.PRNGKey(seed), b, l, k, ko)
    out = log_einsum_exp_pallas(w, lnl, lnr, interpret=True)
    ref = log_einsum_exp_ref(w, lnl, lnr)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)
    # shift invariance: log S(ln + c) == log S(ln) + c
    c = 7.25
    out2 = log_einsum_exp_pallas(w, lnl + c, lnr, interpret=True)
    np.testing.assert_allclose(np.asarray(out2), np.asarray(out) + c, atol=1e-3)


@pytest.mark.parametrize(
    "b,hq,hkv,sq,sk,dh,causal",
    [
        (2, 4, 2, 64, 64, 32, True),
        (1, 8, 8, 100, 100, 16, True),
        (2, 4, 1, 1, 300, 64, True),
        (1, 2, 2, 48, 48, 8, False),
        (3, 6, 3, 130, 130, 32, True),
    ],
)
def test_flash_attention_vs_ref(b, hq, hkv, sq, sk, dh, causal):
    key = jax.random.PRNGKey(b + sq)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (b, hq, sq, dh))
    k = jax.random.normal(kk, (b, hkv, sk, dh))
    v = jax.random.normal(kv, (b, hkv, sk, dh))
    out = ops.flash_attention(q, k, v, causal=causal, block_q=32, block_k=32)
    ref = mha_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)


@given(
    sq=st.integers(1, 96),
    sk=st.integers(8, 160),
    dh=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 100),
)
@settings(max_examples=10, deadline=None)
def test_flash_attention_property(sq, sk, dh, seed):
    if sq > sk:
        sq = sk
    key = jax.random.PRNGKey(seed)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (1, 2, sq, dh))
    k = jax.random.normal(kk, (1, 2, sk, dh))
    v = jax.random.normal(kv, (1, 2, sk, dh))
    out = flash_attention_pallas(
        q.reshape(2, sq, dh), k.reshape(2, sk, dh), v.reshape(2, sk, dh),
        causal=True, block_q=32, block_k=32, interpret=True,
    ).reshape(1, 2, sq, dh)
    ref = mha_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=5e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (1, 2, 64, 32), dtype)
    k = jax.random.normal(key, (1, 2, 64, 32), dtype)
    v = jax.random.normal(key, (1, 2, 64, 32), dtype)
    out = ops.flash_attention(q, k, v)
    ref = mha_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                  v.astype(jnp.float32))
    tol = 3e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref), atol=tol
    )
