"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.layers import NEG_INF
from repro.kernels import ops
from repro.kernels.log_einsum_exp import (
    log_einsum_exp_bwd_pallas,
    log_einsum_exp_pallas,
)
from repro.kernels.ref import log_einsum_exp_ref


def _random_lee(key, b, l, k, ko, scale=30.0):
    k1, k2, k3 = jax.random.split(key, 3)
    w = jax.nn.softmax(
        jax.random.normal(k1, (l, ko, k, k)).reshape(l, ko, -1), -1
    ).reshape(l, ko, k, k)
    lnl = -jnp.abs(jax.random.normal(k2, (b, l, k))) * scale
    lnr = -jnp.abs(jax.random.normal(k3, (b, l, k))) * scale
    return w, lnl, lnr


@pytest.mark.parametrize(
    "b,l,k,ko",
    [(1, 1, 1, 1), (4, 3, 5, 5), (7, 2, 8, 1), (130, 4, 16, 16),
     (16, 1, 40, 40), (33, 7, 13, 9)],
)
def test_log_einsum_exp_shapes(b, l, k, ko):
    w, lnl, lnr = _random_lee(jax.random.PRNGKey(b * 100 + l), b, l, k, ko)
    out = log_einsum_exp_pallas(w, lnl, lnr, interpret=True)
    ref = log_einsum_exp_ref(w, lnl, lnr)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


def test_log_einsum_exp_extreme_underflow():
    """Values around -1000 in the log-domain: naive exp would underflow to 0,
    the log-einsum-exp trick must stay exact (paper Eq. 4)."""
    w, lnl, lnr = _random_lee(jax.random.PRNGKey(0), 8, 2, 6, 6, scale=1000.0)
    out = np.asarray(log_einsum_exp_pallas(w, lnl, lnr, interpret=True))
    ref = np.asarray(log_einsum_exp_ref(w, lnl, lnr))
    assert np.isfinite(out).all()
    np.testing.assert_allclose(out, ref, atol=1e-3)


@pytest.mark.parametrize("b,l,k,ko", [(5, 3, 5, 3), (4, 2, 7, 10), (9, 1, 17, 1)])
def test_log_einsum_exp_wrapper_pads_odd_k(b, l, k, ko):
    """Non-lane-multiple K / K_out must round-trip exactly through the ops
    wrapper padding (regression: the kernel docstring promised padding that
    ``ops.py`` never implemented -- odd K would fail to compile on real TPU)."""
    w, lnl, lnr = _random_lee(jax.random.PRNGKey(10 * k + ko), b, l, k, ko)
    # the unified entry point: every padding view (per-layer, canonical
    # group, gather group) is a thin wrapper over pad_to_lanes
    (wp,), (lp, rp), () = ops.pad_to_lanes((w,), logs=(lnl, lnr))
    assert (wp.shape[2] ** 2) % 128 == 0, "K^2 must land on a 128 lane multiple"
    assert wp.shape[1] % 128 == 0, "K_out must land on a 128 lane multiple"
    assert lp.shape == rp.shape == (b, l, wp.shape[2])
    out = ops.log_einsum_exp(w, lnl, lnr)
    assert out.shape == (b, l, ko)
    ref = log_einsum_exp_ref(w, lnl, lnr)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


@pytest.mark.parametrize("k", [3, 5, 10, 13, 17])
def test_pad_to_lanes_unified_contract(k):
    """One padding contract behind every view: per-layer (final 128-lane
    output), canonical group, and gather group (all-interior 16-pad,
    including the (M, C, K) mixing tables) agree with pad_to_lanes."""
    b, l, ko, m, c = 4, 3, 7, 2, 3
    key = jax.random.PRNGKey(k)
    w, lnl, lnr = _random_lee(key, b, l, k, ko)
    wi = jax.nn.softmax(
        jax.random.normal(key, (l, k, k, k)).reshape(l, k, -1), -1
    ).reshape(l, k, k, k)
    v = jax.nn.softmax(jax.random.normal(key, (m, c, k)), 1)
    x = -jnp.abs(jax.random.normal(key, (b, 2 * l, k)))
    k_p = -(-k // 16) * 16
    # per-layer view: final output pads to 128 lanes
    wp, lp, rp = ops.pad_for_lanes(w, lnl, lnr)
    (wp2,), (lp2, rp2), () = ops.pad_to_lanes((w,), logs=(lnl, lnr))
    assert wp.shape == wp2.shape and wp.shape[1] % 128 == 0
    np.testing.assert_array_equal(np.asarray(lp), np.asarray(lp2))
    assert np.asarray(lp)[..., k:].min() == -np.inf or k == k_p
    # gather view: everything interior (k_p), mixing tables zero-padded
    (wip,), vsp, xp = ops.pad_gather_for_lanes((wi,), (v,), x)
    assert wip.shape == (l, k_p, k_p, k_p)
    assert vsp[0].shape == (m, c, k_p)
    assert np.asarray(vsp[0])[..., k:].max(initial=0.0) == 0.0
    assert xp.shape == (b, 2 * l, k_p)
    # canonical group view agrees on the shared interior contract
    wgp, xgp = ops.pad_group_for_lanes((wi,), x)
    np.testing.assert_array_equal(np.asarray(xgp), np.asarray(xp))
    np.testing.assert_array_equal(np.asarray(wgp[0])[:, :k_p],
                                  np.asarray(wip))


def test_log_einsum_exp_custom_vjp():
    w, lnl, lnr = _random_lee(jax.random.PRNGKey(1), 12, 3, 10, 10)
    gk = jax.grad(lambda *a: ops.log_einsum_exp(*a).sum(), argnums=(0, 1, 2))(
        w, lnl, lnr
    )
    gr = jax.grad(lambda *a: log_einsum_exp_ref(*a).sum(), argnums=(0, 1, 2))(
        w, lnl, lnr
    )
    for a, b in zip(gk, gr):
        rel = np.abs(np.asarray(a) - np.asarray(b)) / (np.abs(np.asarray(b)) + 1e-2)
        assert rel.max() < 1e-3


def test_em_statistics_through_pallas_impl_match_xla():
    """Paper §3.5 end-to-end: the E-step is one grad over the circuit, so the
    fused backward kernel must reproduce the XLA impl's EM statistics."""
    from repro.core import EiNet, Normal, em_statistics, random_binary_trees

    g = random_binary_trees(8, 2, 2, seed=0)
    net_p = EiNet(g, num_sums=3, exponential_family=Normal(), impl="pallas")
    net_x = EiNet(g, num_sums=3, exponential_family=Normal(), impl="xla")
    params = net_p.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (12, 8))
    sp = em_statistics(net_p, params, x)
    sx = em_statistics(net_x, params, x)
    for a, b in zip(
        jax.tree_util.tree_leaves(sp), jax.tree_util.tree_leaves(sx)
    ):
        a, b = np.asarray(a), np.asarray(b)
        assert np.isfinite(a).all()
        if a.size:
            np.testing.assert_allclose(a, b, atol=1e-4)


# ------------------------------------------------------- fused backward kernel
@pytest.mark.parametrize(
    "b,l,k,ko",
    [(1, 1, 1, 1), (4, 3, 5, 5), (7, 2, 8, 1), (130, 4, 16, 16),
     (16, 1, 40, 40), (33, 7, 13, 9), (5, 3, 5, 3), (9, 1, 17, 1)],
)
def test_log_einsum_exp_grad_parity(b, l, k, ko):
    """Fused-backward Pallas VJP vs the pure-XLA autodiff path, across the
    shape sweep INCLUDING odd-K lane-padded cases (the padding path used to be
    forward-only tested).  Acceptance bound: <= 1e-4 max abs error on the
    EM-normalized (mean) loss."""
    w, lnl, lnr = _random_lee(jax.random.PRNGKey(b * 100 + l + ko), b, l, k, ko)
    gk = jax.grad(lambda *a: ops.log_einsum_exp(*a).mean(), argnums=(0, 1, 2))(
        w, lnl, lnr
    )
    gr = jax.grad(lambda *a: log_einsum_exp_ref(*a).mean(), argnums=(0, 1, 2))(
        w, lnl, lnr
    )
    for a, ref in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(ref), atol=1e-4)


def test_log_einsum_exp_bwd_pallas_accumulates_batch_tiles():
    """dW is accumulated by revisiting the same output block across batch
    tiles; force several tiles (plus a ragged final tile) and check against
    the einsum oracle."""
    b, l, k, ko = 70, 2, 8, 4
    w, lnl, lnr = _random_lee(jax.random.PRNGKey(3), b, l, k, ko, scale=5.0)
    wp, lp, rp, gp = ops.pad_for_lanes(
        w, lnl, lnr, jnp.ones((b, l, ko)) / (b * l * ko)
    )
    gw, gl, gr = log_einsum_exp_bwd_pallas(wp, lp, rp, gp, block_b=32,
                                           interpret=True)
    ref = jax.grad(
        lambda *a: log_einsum_exp_ref(*a).mean(), argnums=(0, 1, 2)
    )(w, lnl, lnr)
    np.testing.assert_allclose(np.asarray(gw[:, :ko, :k, :k]),
                               np.asarray(ref[0]), atol=1e-5)
    np.testing.assert_allclose(np.asarray(gl[..., :k]), np.asarray(ref[1]),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(gr[..., :k]), np.asarray(ref[2]),
                               atol=1e-5)


def test_grad_zero_on_padded_lanes():
    """The padding contract must hold in the backward too: -inf padded ln
    lanes and zero padded weights get identically-zero gradients."""
    b, l, k, ko = 6, 2, 5, 3  # pads K 5 -> 16, K_out 3 -> 128
    w, lnl, lnr = _random_lee(jax.random.PRNGKey(9), b, l, k, ko, scale=3.0)
    wp, lp, rp, gp = ops.pad_for_lanes(
        w, lnl, lnr, jnp.ones((b, l, ko)) / (b * l * ko)
    )
    gw, gl, gr = log_einsum_exp_bwd_pallas(wp, lp, rp, gp, interpret=True)
    gw, gl, gr = map(np.asarray, (gw, gl, gr))
    assert (gw[:, ko:, :, :] == 0).all() and (gw[:, :, k:, :] == 0).all()
    assert (gw[:, :, :, k:] == 0).all()
    assert (gl[..., k:] == 0).all() and (gr[..., k:] == 0).all()
    assert np.isfinite(gw).all() and np.isfinite(gl).all()


def test_grad_neg_inf_vs_minus_inf_padding_conventions():
    """Entries at NEG_INF (the masked-row convention, exp -> 1 in the clamped
    frame) and at -inf (the lane-padding convention, exp -> 0) must both give
    finite gradients that match the XLA autodiff path."""
    b, l, k, ko = 8, 2, 6, 4
    w, lnl, lnr = _random_lee(jax.random.PRNGKey(5), b, l, k, ko, scale=2.0)
    lnl = lnl.at[0, 0, :].set(NEG_INF)        # fully-masked row
    lnl = lnl.at[1, 0, :3].set(-jnp.inf)      # partially -inf row
    lnr = lnr.at[2, 1, :].set(NEG_INF)
    gk = jax.grad(lambda *a: ops.log_einsum_exp(*a).mean(), argnums=(0, 1, 2))(
        w, lnl, lnr
    )
    gr = jax.grad(lambda *a: log_einsum_exp_ref(*a).mean(), argnums=(0, 1, 2))(
        w, lnl, lnr
    )
    for a, ref in zip(gk, gr):
        a, ref = np.asarray(a), np.asarray(ref)
        assert np.isfinite(a).all()
        mask = np.isfinite(ref)  # ref autodiff may NaN where it divides 0/0
        np.testing.assert_allclose(a[mask], ref[mask], atol=1e-4)


def test_grad_finite_on_rows_saturated_below_neg_inf():
    """Regression (PR 3 bugfix): the old einsum backward reconstructed
    ``s = exp(out - a - a')`` WITHOUT the forward's NEG_INF clamp on the row
    maxes, so rows saturated below NEG_INF were rebuilt in a different
    stabilized frame -> inf/NaN gradients.  The fused backward clamps
    identically and recomputes s in the forward's exact frame: gradients of
    saturated rows must come out finite (and exactly zero -- the row
    contributes log 0 regardless of any parameter)."""
    b, l, k, ko = 6, 2, 4, 4
    w, lnl, lnr = _random_lee(jax.random.PRNGKey(11), b, l, k, ko, scale=1.0)
    lnl = lnl.at[1, 0, :].set(2.0 * NEG_INF)   # saturated BELOW the clamp
    lnl = lnl.at[3, 1, :].set(4.0 * NEG_INF)
    gk = jax.grad(lambda *a: ops.log_einsum_exp(*a).mean(), argnums=(0, 1, 2))(
        w, lnl, lnr
    )
    for a in gk:
        assert np.isfinite(np.asarray(a)).all()
    # the saturated rows' input-gradients are exactly zero
    assert (np.asarray(gk[1])[1, 0] == 0).all()
    assert (np.asarray(gk[1])[3, 1] == 0).all()
    # unaffected rows still match the XLA path
    gr = jax.grad(lambda *a: log_einsum_exp_ref(*a).mean(), argnums=(0, 1, 2))(
        w, lnl, lnr
    )
    ref1 = np.asarray(gr[1])
    ok = np.ones((b, l), dtype=bool)
    ok[1, 0] = ok[3, 1] = False
    np.testing.assert_allclose(np.asarray(gk[1])[ok], ref1[ok], atol=1e-4)


@given(
    b=st.integers(1, 32),
    l=st.integers(1, 6),
    k=st.integers(1, 24),
    ko=st.integers(1, 24),
    seed=st.integers(0, 1000),
)
@settings(max_examples=15, deadline=None)
def test_log_einsum_exp_property(b, l, k, ko, seed):
    w, lnl, lnr = _random_lee(jax.random.PRNGKey(seed), b, l, k, ko)
    out = log_einsum_exp_pallas(w, lnl, lnr, interpret=True)
    ref = log_einsum_exp_ref(w, lnl, lnr)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)
    # shift invariance: log S(ln + c) == log S(ln) + c
    c = 7.25
    out2 = log_einsum_exp_pallas(w, lnl + c, lnr, interpret=True)
    np.testing.assert_allclose(np.asarray(out2), np.asarray(out) + c, atol=1e-3)


# --------------------------------------------------------------------------
# log_mix_exp fused custom VJP (core/layers.py)
# --------------------------------------------------------------------------
def _random_lme(key, b, m, c, k, scale=30.0, pad_last=True):
    """Mixing-layer operands: normalized weights, log-domain inputs, and a
    padding mask with the last child of the last node padded out."""
    from repro.core.layers import normalize_mixing_weights

    k1, k2 = jax.random.split(key)
    mask = np.ones((m, c), np.float32)
    if pad_last and c > 1:
        mask[-1, -1] = 0.0
    mask = jnp.asarray(mask)
    v = normalize_mixing_weights(
        jax.random.uniform(k1, (m, c, k), minval=0.1, maxval=1.0), mask
    )
    ln = -jnp.abs(jax.random.normal(k2, (b, m, c, k))) * scale
    return v, ln, mask


@pytest.mark.parametrize("b,m,c,k", [(4, 3, 2, 5), (9, 1, 4, 3), (2, 5, 3, 8)])
def test_log_mix_exp_custom_vjp_matches_autodiff(b, m, c, k):
    from repro.core.layers import log_mix_exp, log_mix_exp_ref

    v, ln, mask = _random_lme(jax.random.PRNGKey(0), b, m, c, k)
    out = log_mix_exp(v, ln, mask)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(log_mix_exp_ref(v, ln, mask)), atol=1e-6
    )
    gk = jax.grad(lambda *a: log_mix_exp(*a).sum(), argnums=(0, 1))(v, ln, mask)
    gr = jax.grad(lambda *a: log_mix_exp_ref(*a).sum(), argnums=(0, 1))(
        v, ln, mask
    )
    for a_, b_ in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a_), np.asarray(b_), atol=1e-5)


def test_log_mix_exp_grad_finite_and_masked_on_neg_inf_rows():
    """Fully marginalized rows (every child at NEG_INF): exp(ln - a) == 1
    everywhere, so only the explicit mask multiply keeps padded children's
    gradients at zero -- and nothing may go inf/NaN through the s division."""
    from repro.core.layers import log_mix_exp, log_mix_exp_ref

    v, ln, mask = _random_lme(jax.random.PRNGKey(3), 5, 2, 3, 4, pad_last=True)
    ln = ln.at[0].set(NEG_INF)  # one fully saturated batch row
    ln = ln.at[2, 1].set(-jnp.inf)  # and one genuinely -inf node row
    gk = jax.grad(lambda *a: log_mix_exp(*a).sum(), argnums=(0, 1))(v, ln, mask)
    gr = jax.grad(lambda *a: log_mix_exp_ref(*a).sum(), argnums=(0, 1))(
        v, ln, mask
    )
    for a_, b_ in zip(gk, gr):
        a_, b_ = np.asarray(a_), np.asarray(b_)
        # the fused VJP must stay finite even where the autodiff reference
        # NaNs out (the -inf row drives its s to exactly 0: g / 0)...
        assert np.all(np.isfinite(a_))
        assert not np.all(np.isfinite(b_))
        # ...and must agree wherever the reference is well-defined
        fin = np.isfinite(b_)
        np.testing.assert_allclose(a_[fin], b_[fin], atol=1e-5)
    # padded child gradients are identically zero
    gv, gln = gk
    assert np.all(np.asarray(gv)[-1, -1] == 0.0)
    assert np.all(np.asarray(gln)[:, -1, -1, :] == 0.0)


def test_log_mix_exp_vjp_composes_with_vmap_and_jit():
    from repro.core.layers import log_mix_exp

    v, ln, mask = _random_lme(jax.random.PRNGKey(4), 6, 2, 3, 4)
    g = jax.jit(jax.grad(lambda lv: log_mix_exp(v, lv, mask).sum()))
    gv = jax.vmap(lambda row: g(row[None]))(ln)
    np.testing.assert_allclose(
        np.asarray(gv)[:, 0], np.asarray(g(ln)), atol=1e-5
    )
