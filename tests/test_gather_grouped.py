"""Gather-grouped execution (Poon-Domingos topologies): parity vs the
per-layer path on both impls, lane padding, saturated rows, and vmap.

The numerics contract this file pins:

  * XLA: the chained gather reference (``layers.gather_grouped_log_einsum_exp``
    with ``impl="xla"``) builds a graph IDENTICAL to the per-layer loop --
    same per-depth op on the same gathered rows, buffer concatenated
    incrementally -- so forward AND gradients are BITWISE equal (0.0).
  * Pallas (interpret on CPU): forward is bitwise equal; gradients match to
    float32 ulp level.  The fused kernel keeps interior lanes at the 16-pad
    (k_p) while the per-layer ops pad every K_out to 128 lanes, and gemm
    reductions over different padded lengths associate partial sums
    differently -- a platform-level ulp effect, not an algorithmic one (all
    the kernel's per-depth math replicates the per-layer kernels exactly,
    and mixing-weight gradients ARE bitwise).  The bound used here is
    ``5e-7 * (1 + max|g_ref|)`` per tensor: ~4 float32 ulps of the largest
    gradient entry, orders of magnitude below EM step noise.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.einet import EiNet
from repro.core.exponential_family import Normal
from repro.core.layers import NEG_INF
from repro.core.region_graph import poon_domingos

# (height, width, delta, K): all produce needs_buffer PD structures whose
# plan is one gather run + the per-layer root pair.  (4, 4, 1, 3) is a
# 5-depth gather run with odd K = 3 (16-lane padding inside the kernel).
PD_SMOKE_SHAPES = [
    (4, 8, 2, 4),
    (2, 8, 2, 6),
    (4, 4, 1, 3),
]


def _pair_models(h, w, delta, k, impl="xla", **kw):
    graph = poon_domingos(h, w, delta)
    ef = Normal()
    m_g = EiNet(graph, num_sums=k, exponential_family=ef, impl=impl,
                grouped=True, **kw)
    m_p = EiNet(graph, num_sums=k, exponential_family=ef, impl=impl,
                grouped=False)
    params = m_g.init(jax.random.PRNGKey(0))
    x = jnp.asarray(
        np.random.RandomState(1).randn(8, h * w).astype(np.float32)
    )
    return m_g, m_p, params, x


def _assert_grad_parity(g_a, g_b, impl):
    """XLA: bitwise.  Pallas: <= ~4 ulps of the largest entry per tensor."""
    for la, lb in zip(jax.tree_util.tree_leaves(g_a),
                      jax.tree_util.tree_leaves(g_b)):
        if not la.size:
            continue
        diff = float(jnp.max(jnp.abs(la - lb)))
        if impl == "xla":
            assert diff == 0.0
        else:
            mag = float(jnp.max(jnp.abs(lb)))
            assert diff <= 5e-7 * (1.0 + mag), (diff, mag)


@pytest.mark.parametrize("impl", ["xla", "pallas"])
@pytest.mark.parametrize("shape", PD_SMOKE_SHAPES, ids=str)
def test_gather_forward_bitwise(shape, impl):
    m_g, m_p, params, x = _pair_models(*shape, impl=impl)
    assert m_g.grouped_active
    assert not m_p.grouped_active
    assert m_g.grouping_summary()["gather_groups"] >= 1
    out_g = m_g.forward(params, x)
    out_p = m_p.forward(params, x)
    assert float(jnp.max(jnp.abs(out_g - out_p))) == 0.0


@pytest.mark.parametrize("impl", ["xla", "pallas"])
@pytest.mark.parametrize("shape", PD_SMOKE_SHAPES, ids=str)
def test_gather_grad_parity(shape, impl):
    m_g, m_p, params, x = _pair_models(*shape, impl=impl)

    def nll(m):
        return lambda p: -jnp.sum(m.log_likelihood(p, x))

    g_g = jax.grad(nll(m_g))(params)
    g_p = jax.grad(nll(m_p))(params)
    _assert_grad_parity(g_g, g_p, impl)


@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_gather_neg_inf_saturated_rows(impl):
    """NEG_INF-saturated leaf rows (fully-marginalized scopes) flow through
    the gather kernel's -inf padding and stabilization clamps: bitwise
    forward parity and finite gradients on both paths."""
    m_g, m_p, params, x = _pair_models(4, 8, 2, 4, impl=impl)
    lr = m_g._leaf_rows(m_g.leaf_log_prob(params, x, None))
    # saturate one leaf rectangle: PD decompositions overlap, so siblings
    # keep the root finite while -inf rows flow through the kernel
    lr = lr.at[:, 0, :].set(NEG_INF)

    def root(m, rows):
        return m.forward_from_e(params["einsum"], params["mixing"], None,
                                leaf_rows=rows)

    out_g = root(m_g, lr)
    out_p = root(m_p, lr)
    assert bool(jnp.all(jnp.isfinite(out_g)))  # guard: root stayed finite
    assert float(jnp.max(jnp.abs(out_g - out_p))) == 0.0

    gr_g = jax.grad(lambda r: jnp.sum(root(m_g, r)))(lr)
    gr_p = jax.grad(lambda r: jnp.sum(root(m_p, r)))(lr)
    assert bool(jnp.all(jnp.isfinite(gr_g)))
    _assert_grad_parity(gr_g, gr_p, impl)


@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_gather_mixture_stacked_components(impl):
    """The mixture trainer vmaps forward_from_e over stacked component
    params (repro.mixture); the gather-grouped op must be vmap-transparent
    on both impls."""
    m_g, m_p, _, x = _pair_models(2, 8, 2, 6, impl=impl)
    keys = jax.random.split(jax.random.PRNGKey(7), 3)
    stacked = jax.vmap(m_g.init)(keys)

    def comp_root(m):
        def one(p):
            e = m.leaf_log_prob(p, x, None)
            return m.forward_from_e(p["einsum"], p["mixing"], e)
        return jax.vmap(one)(stacked)

    out_g = comp_root(m_g)
    out_p = comp_root(m_p)
    assert out_g.shape[0] == 3
    assert float(jnp.max(jnp.abs(out_g - out_p))) == 0.0


def test_gather_em_step_parity():
    """One full EM update through the gather plan matches the per-layer
    plan: the end-to-end path the trainers actually run."""
    from repro.core.em import em_update

    m_g, m_p, params, x = _pair_models(4, 8, 2, 4, impl="xla")
    p_g, _ = em_update(m_g, params, x)
    p_p, _ = em_update(m_p, params, x)
    for la, lb in zip(jax.tree_util.tree_leaves(p_g),
                      jax.tree_util.tree_leaves(p_p)):
        if la.size:
            assert float(jnp.max(jnp.abs(la - lb))) == 0.0


def test_gather_sampling_cache_path_stays_per_layer():
    """return_cache (sampling) needs every depth's activations, so it runs
    the per-layer loop even on a gather-planned model -- and still agrees
    with the cacheless gather forward."""
    m_g, _, params, x = _pair_models(4, 8, 2, 4)
    root_plain = m_g.forward(params, x)
    root_cached, cache = m_g.forward(params, x, return_cache=True)
    assert len(cache["S"]) == len(m_g.pair_specs)
    assert float(jnp.max(jnp.abs(root_plain - root_cached))) == 0.0
