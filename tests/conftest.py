"""Shared pytest configuration.

``@pytest.mark.slow`` marks subprocess tests that re-launch python with
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (the main pytest
process must keep seeing 1 device).  They take minutes, so the tier-1 loop
skips them; opt in with ``--runslow`` (CI runs them as a separate job).
"""

import importlib.util
import os
import sys

import pytest

try:  # the container may not ship hypothesis; tests fall back to a
    import hypothesis  # noqa: F401  deterministic mini-sampler (same API slice)
except ImportError:
    _spec = importlib.util.spec_from_file_location(
        "hypothesis",
        os.path.join(os.path.dirname(__file__), "_hypothesis_fallback.py"),
    )
    _mod = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_mod)
    sys.modules["hypothesis"] = _mod


@pytest.fixture
def compile_sentry():
    """Active :class:`repro.analysis.sentry.CompileSentry` for the test."""
    from repro.analysis.sentry import CompileSentry

    with CompileSentry() as sentry:
        yield sentry


def pytest_addoption(parser):
    parser.addoption(
        "--runslow",
        action="store_true",
        default=False,
        help="run @pytest.mark.slow multi-device subprocess tests",
    )


def pytest_collection_modifyitems(config, items):
    if config.getoption("--runslow"):
        return
    skip_slow = pytest.mark.skip(reason="slow multi-device test: use --runslow")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip_slow)
