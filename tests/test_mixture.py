"""repro.mixture: k-means determinism, mixture model semantics, vmapped EM
correctness, and mixture serving parity."""

import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.compile import ProgramRegistry
from repro.core import EiNet, Normal, random_binary_trees
from repro.core.em import EMConfig, em_update
from repro.core.layers import NEG_INF
from repro.eval.metrics import parity_report
from repro.mixture import (
    MIXTURE_QUERY_KINDS,
    EiNetMixture,
    MixtureTrainConfig,
    hard_mixture_em_update,
    kmeans,
    make_mixture_em_step,
    mixture_em_update,
    stacked_cluster_loader,
)
from repro.serve import Request, ServeEngine


@pytest.fixture(scope="module")
def small_mix():
    g = random_binary_trees(8, 2, 2, seed=0)
    net = EiNet(g, num_sums=3, exponential_family=Normal())
    mix = EiNetMixture(net, 3)
    params = mix.init(jax.random.PRNGKey(0))
    return mix, params


@pytest.fixture(scope="module")
def blobs():
    """Three well-separated Gaussian blobs, shuffled deterministically."""
    rng = np.random.RandomState(0)
    centers = np.array([[-6.0] * 8, [0.0] * 8, [6.0] * 8], np.float32)
    x = np.concatenate(
        [c + rng.randn(40, 8).astype(np.float32) * 0.3 for c in centers]
    )
    truth = np.repeat(np.arange(3), 40)
    order = rng.permutation(len(x))
    return x[order], truth[order]


# ------------------------------------------------------------------- k-means
def test_kmeans_recovers_separated_blobs(blobs):
    x, truth = blobs
    km = kmeans(x, 3, seed=0)
    assert km.num_clusters == 3
    assert sorted(km.counts.tolist()) == [40, 40, 40]
    # each k-means cluster is pure wrt the generating blob
    for c in range(3):
        assert len(set(truth[km.assignments == c])) == 1
    assert km.inertia < 2.0
    w = km.weights()
    np.testing.assert_allclose(w, [1 / 3] * 3, atol=1e-6)
    assert w.dtype == np.float32


def test_kmeans_minibatch_mode_and_validation(blobs):
    x, _ = blobs
    km = kmeans(x, 3, seed=0, batch=32, num_iters=30)
    assert km.inertia < 2.0  # minibatch converges on easy data too
    with pytest.raises(ValueError):
        kmeans(x, 0)
    with pytest.raises(ValueError):
        kmeans(x[:2], 3)


def test_kmeans_deterministic_across_processes(blobs, tmp_path):
    """The cross-process reproducibility contract (crc32 seeding, no
    PYTHONHASHSEED dependence, RNG-free iterations): a fresh interpreter
    must derive bit-identical centers and assignments."""
    import os

    x, _ = blobs
    km = kmeans(x, 3, seed=7, batch=32)
    np.save(tmp_path / "x.npy", x)
    code = (
        "import numpy as np; from repro.mixture import kmeans\n"
        f"km = kmeans(np.load(r'{tmp_path / 'x.npy'}'), 3, seed=7, batch=32)\n"
        f"np.save(r'{tmp_path / 'centers.npy'}', km.centers)\n"
        f"np.save(r'{tmp_path / 'assign.npy'}', km.assignments)\n"
    )
    # a DIFFERENT hash salt is the whole point; everything else inherits
    env = dict(os.environ,
               PYTHONPATH="src" + os.pathsep + os.environ.get("PYTHONPATH", ""),
               PYTHONHASHSEED="12345")
    subprocess.run([sys.executable, "-c", code], check=True, env=env)
    np.testing.assert_array_equal(
        km.centers, np.load(tmp_path / "centers.npy")
    )
    np.testing.assert_array_equal(
        km.assignments, np.load(tmp_path / "assign.npy")
    )


def test_stacked_cluster_loader_contract(blobs):
    x, _ = blobs
    km = kmeans(x, 3, seed=0)
    loader = stacked_cluster_loader(x, km.assignments, 3,
                                    per_component_batch=8)
    b = loader.batch_at(0)["x"]
    assert b.shape == (3, 8, 8) and b.dtype == np.float32
    # every row of slice c really belongs to cluster c
    for c in range(3):
        for row in b[c]:
            idx = np.where((x == row).all(axis=1))[0]
            assert km.assignments[idx[0]] == c
    # deterministic + steps tile each cluster
    np.testing.assert_array_equal(
        loader.batch_at(0)["x"],
        stacked_cluster_loader(x, km.assignments, 3, 8).batch_at(0)["x"],
    )
    seen = np.concatenate([loader.batch_at(s)["x"][0] for s in range(5)])
    assert len(np.unique(seen, axis=0)) == 40  # cluster 0 fully covered


# -------------------------------------------------------------------- model
def test_mixture_init_and_log_prob_reference(small_mix):
    mix, params = small_mix
    assert params["components"]["phi"].shape[0] == 3
    np.testing.assert_allclose(params["mixture_weights"], [1 / 3] * 3)
    # stacked init == per-key single inits
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    single = mix.component.init(keys[1])
    for a, b in zip(
        jax.tree_util.tree_leaves(mix.component_params(params, 1)),
        jax.tree_util.tree_leaves(single),
    ):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-7)

    x = jnp.asarray(np.random.RandomState(1).randn(9, 8), jnp.float32)
    comp_ll = mix.component_log_likelihoods(params, x)
    assert comp_ll.shape == (9, 3)
    ref = jax.scipy.special.logsumexp(
        comp_ll + jnp.log(params["mixture_weights"])[None, :], axis=-1
    )
    np.testing.assert_allclose(
        np.asarray(mix.log_likelihood(params, x)), np.asarray(ref), atol=1e-5
    )
    # a mixture with all mass on component 1 degenerates to that component
    p1 = dict(params)
    p1["mixture_weights"] = jnp.asarray([0.0, 1.0, 0.0])
    np.testing.assert_allclose(
        np.asarray(mix.log_likelihood(p1, x)),
        np.asarray(mix.component.log_likelihood(
            mix.component_params(params, 1), x)),
        atol=1e-5,
    )


def test_responsibilities_sum_to_one_under_saturation(small_mix):
    mix, params = small_mix
    x = jnp.asarray(np.random.RandomState(2).randn(4, 8), jnp.float32)
    r = mix.responsibilities(params, x)
    assert r.shape == (4, 3)
    np.testing.assert_allclose(np.asarray(r.sum(axis=1)), 1.0, atol=1e-6)
    # rows so far in the tails that every component underflows: the clamped
    # logits resolve to the uniform posterior, not NaN
    x_sat = jnp.full((2, 8), 1e8, jnp.float32)
    r_sat = np.asarray(mix.responsibilities(params, x_sat))
    assert np.all(np.isfinite(r_sat))
    np.testing.assert_allclose(r_sat.sum(axis=1), 1.0, atol=1e-6)
    np.testing.assert_allclose(r_sat, 1.0 / 3.0, atol=1e-6)
    # an explicitly -inf/NEG_INF weight row behaves the same way
    p0 = dict(params)
    p0["mixture_weights"] = jnp.asarray([0.0, 0.0, 0.0])
    r0 = np.asarray(mix.responsibilities(p0, x))
    assert np.all(np.isfinite(r0))
    np.testing.assert_allclose(r0.sum(axis=1), 1.0, atol=1e-6)


def test_mixture_sampling_row_independent(small_mix):
    mix, params = small_mix
    d = mix.num_vars
    keys = jnp.stack([jax.random.PRNGKey(i) for i in range(6)])
    x = jnp.asarray(np.random.RandomState(3).randn(6, d), jnp.float32)
    ev = jnp.asarray(np.random.RandomState(4).rand(6, d) < 0.5)
    full = mix.conditional_sample_per_key(params, keys, x, ev)
    # evidence passthrough
    np.testing.assert_array_equal(np.asarray(full)[np.asarray(ev)],
                                  np.asarray(x)[np.asarray(ev)])
    # row 2 alone == row 2 of the batch (micro-batch invariance)
    solo = mix.conditional_sample_per_key(
        params, keys[2:3], x[2:3], ev[2:3]
    )
    np.testing.assert_array_equal(np.asarray(solo[0]), np.asarray(full[2]))
    # component-pinned sampling equals the single component's path
    pinned = mix.component_conditional_sample_per_key(
        params, keys, x, ev, component=1
    )
    direct = mix.component.conditional_sample_per_key(
        mix.component_params(params, 1), keys, x, ev
    )
    np.testing.assert_array_equal(np.asarray(pinned), np.asarray(direct))


# ----------------------------------------------------------------- training
def test_soft_full_em_is_monotone(small_mix):
    mix, params = small_mix
    x = jnp.asarray(np.random.RandomState(5).randn(24, 8), jnp.float32)
    cfg = MixtureTrainConfig(assign="soft", mode="full")
    lls = []
    p = params
    for _ in range(6):
        p, ll = mixture_em_update(mix, p, x, cfg)
        lls.append(float(ll))
    assert all(b >= a - 1e-4 for a, b in zip(lls, lls[1:])), lls
    assert lls[-1] > lls[0]


def test_single_component_soft_em_matches_single_model(small_mix):
    """C=1 soft mixture EM must reduce exactly to single-model EM."""
    mix, _ = small_mix
    one = EiNetMixture(mix.component, 1)
    params = one.init(jax.random.PRNGKey(3))
    x = jnp.asarray(np.random.RandomState(6).randn(16, 8), jnp.float32)
    newp, ll = mixture_em_update(
        one, params, x, MixtureTrainConfig(assign="soft", mode="full")
    )
    ref, ll_ref = em_update(
        mix.component, one.component_params(params, 0), x, EMConfig()
    )
    np.testing.assert_allclose(float(ll), float(ll_ref), atol=1e-5)
    for a, b in zip(
        jax.tree_util.tree_leaves(one.component_params(newp, 0)),
        jax.tree_util.tree_leaves(ref),
    ):
        if np.asarray(a).size:
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), atol=2e-6
            )


@pytest.mark.parametrize("num_sums", [4, 3])  # incl. odd K (lane-padded)
def test_vmapped_hard_em_matches_looped_components(num_sums):
    g = random_binary_trees(8, 2, 2, seed=1)
    net = EiNet(g, num_sums=num_sums, exponential_family=Normal())
    mix = EiNetMixture(net, 4)
    params = mix.init(jax.random.PRNGKey(1))
    x = jnp.asarray(
        np.random.RandomState(7).randn(4, 8, 8).astype(np.float32)
    )
    cfg = MixtureTrainConfig(assign="hard", mode="stochastic")
    new, _ll = hard_mixture_em_update(mix, params, x, cfg)
    from repro.core.em import stochastic_em_update

    for c in range(4):
        ref, _ = stochastic_em_update(
            net, mix.component_params(params, c), x[c], cfg.em
        )
        for a, b in zip(
            jax.tree_util.tree_leaves(mix.component_params(new, c)),
            jax.tree_util.tree_leaves(ref),
        ):
            if np.asarray(a).size:
                np.testing.assert_allclose(
                    np.asarray(a), np.asarray(b), atol=2e-6
                )


def test_hard_em_shape_validation_and_step_cache(small_mix):
    mix, params = small_mix
    with pytest.raises(ValueError):
        hard_mixture_em_update(
            mix, params, jnp.zeros((2, 4, 8)), MixtureTrainConfig()
        )
    with pytest.raises(ValueError):
        make_mixture_em_step(mix, MixtureTrainConfig(assign="fuzzy"))
    with pytest.raises(ValueError):
        make_mixture_em_step(mix, MixtureTrainConfig(mode="sgd"))
    # the shared registry returns the SAME compiled step for the same
    # (model, config) -- the serve/train unification contract
    reg = ProgramRegistry()
    cfg = MixtureTrainConfig(donate=False)
    s1 = make_mixture_em_step(mix, cfg, registry=reg)
    s2 = make_mixture_em_step(mix, cfg, registry=reg)
    assert s1 is s2
    assert reg.stats["hits"] == 1 and reg.stats["compiles"] == 1


def test_mixture_learns_clustered_data(blobs):
    """End-to-end: k-means + hard vmapped EM on separable blobs raises the
    mixture LL far above the init."""
    x, _ = blobs
    g = random_binary_trees(8, 2, 2, seed=2)
    net = EiNet(g, num_sums=3, exponential_family=Normal())
    mix = EiNetMixture(net, 3)
    km = kmeans(x, 3, seed=0)
    params = mix.init(jax.random.PRNGKey(2))
    params["mixture_weights"] = jnp.asarray(km.weights(alpha=1.0))
    loader = stacked_cluster_loader(x, km.assignments, 3,
                                    per_component_batch=16)
    step = make_mixture_em_step(mix, MixtureTrainConfig(donate=False))
    ll0 = float(jnp.mean(mix.log_likelihood(params, jnp.asarray(x))))
    p = params
    for s in range(15):
        p, _ = step(p, jnp.asarray(loader.batch_at(s)["x"]))
    ll1 = float(jnp.mean(mix.log_likelihood(p, jnp.asarray(x))))
    assert ll1 > ll0 + 5.0, (ll0, ll1)


# ------------------------------------------------------------------ serving
def test_engine_bitwise_parity_for_every_mixture_kind(small_mix):
    mix, params = small_mix
    engine = ServeEngine(mix, params, max_batch=4)
    rng = np.random.RandomState(11)
    reqs, rid = [], 0
    for kind in MIXTURE_QUERY_KINDS:
        comps = range(mix.num_components) \
            if kind in mix.component_kinds else [None]
        for c in comps:
            for _ in range(2):
                x = rng.randn(8).astype(np.float32)
                ev = rng.rand(8) < 0.5
                reqs.append(Request(
                    rid, kind, x=x, evidence_mask=ev, query_mask=~ev,
                    seed=500 + rid, component=c,
                ))
                rid += 1
    results = engine.run(reqs)
    par = parity_report(mix, params, reqs, results, rows=None)
    assert par["parity_rows"] == len(reqs)
    assert par["parity_mismatches"] == 0, par
    # responsibilities rows come back (C,) and sum to 1
    resp = [results[r.req_id].value for r in reqs
            if r.kind == "mixture_responsibility"]
    for v in resp:
        assert v.shape == (3,)
        np.testing.assert_allclose(v.sum(), 1.0, atol=1e-6)


def test_engine_component_folding_and_validation(small_mix):
    mix, params = small_mix
    engine = ServeEngine(mix, params, max_batch=4,
                         registry=ProgramRegistry())
    with pytest.raises(ValueError):
        engine.submit(Request(0, "joint_ll"))  # single-EiNet kind
    with pytest.raises(ValueError):
        engine.submit(Request(0, "mixture_component_sample"))  # no component
    with pytest.raises(ValueError):
        engine.submit(Request(0, "mixture_component_sample", component=9))
    with pytest.raises(ValueError):
        engine.submit(Request(0, "mixture_joint_ll", component=1))
    # same kind, different components -> distinct programs, never coalesced
    d = mix.num_vars
    rng = np.random.RandomState(12)
    reqs = [
        Request(i, "mixture_component_mpe",
                x=rng.randn(d).astype(np.float32),
                evidence_mask=rng.rand(d) < 0.5, seed=i, component=i % 3)
        for i in range(9)
    ]
    engine.run(reqs)
    comp_keys = {k for k in engine._programs if len(k) == 3}
    assert {k[2] for k in comp_keys} == {0, 1, 2}
    # cache stays bounded: replaying the same traffic shape adds no programs
    before = engine.num_programs
    engine.run([Request(100 + i, "mixture_component_mpe",
                        x=rng.randn(d).astype(np.float32),
                        evidence_mask=rng.rand(d) < 0.5,
                        seed=i, component=i % 3) for i in range(9)])
    assert engine.num_programs == before
    assert engine.stats["compiles"] == engine.num_programs


def test_engine_shared_registry_across_engines(small_mix):
    """Two engines over the same model share compiled programs through one
    registry: the second engine pays zero compile seconds."""
    mix, params = small_mix
    reg = ProgramRegistry()
    e1 = ServeEngine(mix, params, max_batch=2, registry=reg)
    e1.warmup(kinds=["mixture_joint_ll"])
    compiled = reg.stats["compiles"]
    assert compiled == len(e1.buckets)
    e2 = ServeEngine(mix, params, max_batch=2, registry=reg)
    e2.warmup(kinds=["mixture_joint_ll"])
    assert reg.stats["compiles"] == compiled  # all hits
    assert e2.stats["registry_hits"] == len(e2.buckets)
    assert e2.stats["compile_s"] == 0.0
