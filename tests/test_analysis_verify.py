"""Circuit/plan verifier tests: every registered arch verifies clean, and
every invariant class catches its seeded corruption.

The corruption tests are the contract: a verifier that cannot reject a
mutated gather table / scope / plan is checking nothing.  Each test builds
a fresh small model (RAT for fused plans, 6x6 Poon-Domingos for gather
plans, or a hand-built synthetic circuit for surgical scope corruptions),
mutates exactly one structure, and asserts the named invariant fires.
"""

import dataclasses
from types import SimpleNamespace

import numpy as np
import pytest

from repro.analysis.verify import (
    INVARIANTS,
    VerifyError,
    verify_circuit,
    verify_config,
    verify_einet,
    verify_plan,
    verify_region_graph,
)
from repro.configs import REGISTRY as CONFIGS
from repro.core import EiNet, poon_domingos, random_binary_trees
from repro.core.einet import PairSpec
from repro.core.region_graph import RegionGraph


def rat_net(**kw):
    return EiNet(random_binary_trees(8, 2, 2, seed=0), num_sums=4, **kw)


def pd_net(**kw):
    return EiNet(poon_domingos(6, 6, 2), num_sums=4, **kw)


def invariants_of(findings):
    return {f.invariant for f in findings}


# ------------------------------------------------------------- clean passes
def test_small_models_verify_clean():
    for net in (rat_net(), pd_net()):
        report = verify_einet(net)
        assert report.ok, report.format_report()
        assert report.invariants == INVARIANTS


@pytest.mark.parametrize("arch", sorted(CONFIGS))
def test_all_registered_archs_verify_clean(arch):
    report = verify_config(CONFIGS[arch])
    assert report.ok, report.format_report()


def test_einet_verify_knob_raise_and_report():
    net = rat_net(verify="raise")  # clean model: must not raise
    assert net.verify_report is not None and net.verify_report.ok
    with pytest.raises(ValueError, match="verify"):
        rat_net(verify="bogus")


def test_einet_verify_env(monkeypatch):
    monkeypatch.setenv("REPRO_VERIFY", "raise")
    net = rat_net()
    assert net.verify_report is not None and net.verify_report.ok
    monkeypatch.setenv("REPRO_VERIFY", "off")
    assert rat_net().verify_report is None


# ------------------------------------------------------------- region graph
def _graph(num_vars, regions, partitions, root=0):
    return RegionGraph(num_vars=num_vars, regions=regions,
                       partitions=partitions, root=root)


def test_graph_decomposability_overlap_caught():
    g = _graph(2, [(0, 1), (0,), (0,)], [(0, 1, 2)])  # children share var 0
    assert "graph/decomposability" in invariants_of(verify_region_graph(g))


def test_graph_smoothness_cover_caught():
    g = _graph(3, [(0, 1, 2), (0,), (1,)], [(0, 1, 2)])  # var 2 uncovered
    assert "graph/smoothness" in invariants_of(verify_region_graph(g))


def test_graph_empty_scope_caught():
    g = _graph(2, [(0, 1), (), (0, 1)], [(0, 1, 2)])
    assert "graph/nonempty-scope" in invariants_of(verify_region_graph(g))


def test_graph_root_scope_caught():
    g = _graph(3, [(0, 1), (0,), (1,)], [(0, 1, 2)], root=0)
    assert "graph/root-scope" in invariants_of(verify_region_graph(g))


def test_graph_clean_pass():
    g = _graph(2, [(0, 1), (0,), (1,)], [(0, 1, 2)])
    assert verify_region_graph(g) == []


# ---------------------------------------------------- synthetic circuit walk
def _synthetic():
    """Hand-built valid circuit: 4 vars, leaves rows 0-3, pair 0 emits
    einsum rows 4-6 (two partitions of {0,1} plus one of {2,3}) and mixing
    row 7 (mixes the two {0,1} partitions), final pair emits root row 8."""
    def spec(**kw):
        return PairSpec(**{
            "mix_child_local": None, "mix_mask": None, "mix_global": None,
            "is_final": False, **kw})

    pair0 = spec(
        left=np.array([0, 0, 2]), right=np.array([1, 1, 3]),
        einsum_global=np.arange(4, 7), k_in=2, k_out=2,
        mix_child_local=np.array([[0, 1]]),
        mix_mask=np.array([[1.0, 1.0]], np.float32),
        mix_global=np.array([7]),
    )
    pair1 = spec(
        left=np.array([7]), right=np.array([6]),
        einsum_global=np.array([8]), k_in=2, k_out=1, is_final=True,
    )
    return SimpleNamespace(
        leaf_spec=SimpleNamespace(leaf_scopes=[(0,), (1,), (2,), (3,)]),
        pair_specs=[pair0, pair1], num_vars=4, K=2, num_classes=1,
    )


def test_synthetic_circuit_clean():
    assert verify_circuit(_synthetic()) == []


def test_circuit_scope_overlap_caught():
    m = _synthetic()
    m.pair_specs[0].right = np.array([0, 1, 3])  # partition 0 = (row0, row0)
    assert "circuit/scope-decomposability" in invariants_of(verify_circuit(m))


def test_circuit_row_out_of_range_caught():
    m = _synthetic()
    m.pair_specs[0].left = np.array([0, 0, 99])
    assert "circuit/row-range" in invariants_of(verify_circuit(m))


def test_circuit_allocation_order_caught():
    m = _synthetic()
    m.pair_specs[0].einsum_global = np.arange(5, 8)
    assert "circuit/allocation-order" in invariants_of(verify_circuit(m))


def test_circuit_k_chain_caught():
    m = _synthetic()
    m.pair_specs[1].k_out = 3  # final pair must emit num_classes
    assert "circuit/k-chain" in invariants_of(verify_circuit(m))


def test_circuit_mix_mask_caught():
    m = _synthetic()
    m.pair_specs[0].mix_mask = np.zeros((1, 2), np.float32)  # no children
    assert "circuit/mix-tables" in invariants_of(verify_circuit(m))


def test_circuit_mix_child_range_caught():
    m = _synthetic()
    m.pair_specs[0].mix_child_local = np.array([[0, 9]])
    assert "circuit/mix-tables" in invariants_of(verify_circuit(m))


def test_circuit_smoothness_caught():
    m = _synthetic()
    # mix partitions 0 ({0,1}) and 2 ({2,3}): differing scopes under one sum
    m.pair_specs[0].mix_child_local = np.array([[0, 2]])
    assert "circuit/scope-smoothness" in invariants_of(verify_circuit(m))


def test_circuit_root_coverage_caught():
    m = _synthetic()
    m.num_vars = 5  # root scope {0..3} no longer covers every variable
    assert "circuit/root-coverage" in invariants_of(verify_circuit(m))


def test_corrupt_real_model_scope_swap():
    """Swapping gather rows between partitions of a REAL PD circuit breaks
    decomposability and is caught end-to-end through verify_einet."""
    net = pd_net()
    sp = net.pair_specs[0]
    sp.right = sp.right.copy()
    sp.right[0] = int(sp.left[0])  # product of a row with itself
    report = verify_einet(net)
    assert not report.ok
    assert "circuit/scope-decomposability" in invariants_of(report.findings)


# --------------------------------------------------------------------- plan
def _gather_seg_index(net):
    return next(i for i, s in enumerate(net.plan.segments)
                if s.kind == "gather")


def _replace_segment(net, idx, **kw):
    segs = list(net.plan.segments)
    segs[idx] = dataclasses.replace(segs[idx], **kw)
    net.plan = dataclasses.replace(net.plan, segments=tuple(segs))


def test_plan_coverage_gap_caught():
    net = pd_net()
    net.plan = dataclasses.replace(net.plan, segments=net.plan.segments[1:])
    assert "plan/coverage" in invariants_of(verify_plan(net))


def test_plan_mix_flags_caught():
    net = pd_net()
    flags = list(net.plan.mix_flags)
    flags[0] = not flags[0]
    net.plan = dataclasses.replace(net.plan, mix_flags=tuple(flags))
    assert "plan/mix-flags" in invariants_of(verify_plan(net))


def test_plan_gather_row_out_of_range_caught():
    net = pd_net()
    i = _gather_seg_index(net)
    tb = net.plan.segments[i].tables
    left = list(tb.left)
    left[0] = (10 ** 6,) + left[0][1:]
    _replace_segment(net, i, tables=dataclasses.replace(
        tb, left=tuple(left)))
    found = invariants_of(verify_plan(net))
    assert "plan/gather-row-range" in found
    assert "plan/gather-tables" in found  # no longer the spec's permutation


def test_plan_gather_swapped_rows_caught():
    net = pd_net()
    i = _gather_seg_index(net)
    tb = net.plan.segments[i].tables
    row = tb.left[0]
    assert len(row) >= 2
    left = (row[::-1],) + tb.left[1:]  # in-range but permuted vs the spec
    _replace_segment(net, i, tables=dataclasses.replace(
        tb, left=tuple(left)))
    assert "plan/gather-tables" in invariants_of(verify_plan(net))


def test_plan_gather_mix_table_caught():
    net = pd_net()
    i = _gather_seg_index(net)
    tb = net.plan.segments[i].tables
    d = next(d for d, m in enumerate(tb.mix_child) if m is not None)
    mix_child = list(tb.mix_child)
    mix_child[d] = None  # drop the mixing depth from the frozen tables
    _replace_segment(net, i, tables=dataclasses.replace(
        tb, mix_child=tuple(mix_child)))
    assert "plan/mix-flags" in invariants_of(verify_plan(net))


def test_plan_vmem_budget_exceeded_caught():
    for net in (rat_net(), pd_net()):
        net.plan = dataclasses.replace(net.plan, vmem_budget=1)
        assert "plan/vmem-budget" in invariants_of(verify_plan(net))


def test_plan_fused_tiling_caught():
    net = rat_net()
    i = next(i for i, s in enumerate(net.plan.segments) if s.kind == "fused")
    _replace_segment(net, i, out_block=0)
    assert "plan/fused-tiling" in invariants_of(verify_plan(net))


def test_plan_fused_structure_caught():
    net = rat_net()
    seg = next(s for s in net.plan.segments if s.kind == "fused")
    net.pair_specs[seg.start].canonical = False
    assert "plan/fused-structure" in invariants_of(verify_plan(net))


def test_plan_lanes_contract_caught():
    net = rat_net()
    i = next(i for i, s in enumerate(net.plan.segments) if s.fused)
    _replace_segment(net, i, block_b=12)  # not a multiple of 8 sublanes
    assert "plan/lanes-contract" in invariants_of(verify_plan(net))


def test_plan_segment_kind_caught():
    net = pd_net()
    _replace_segment(net, 0, kind="bogus")
    assert "plan/segment-kind" in invariants_of(verify_plan(net))


def test_verify_error_carries_report():
    net = pd_net()
    sp = net.pair_specs[0]
    sp.left = sp.left.copy()
    sp.left[0] = 10 ** 6
    report = verify_einet(net)
    with pytest.raises(VerifyError) as exc:
        raise VerifyError(report)
    assert not exc.value.report.ok
    assert "circuit/row-range" in invariants_of(exc.value.report.findings)


def test_every_invariant_has_coverage():
    """Pin the invariant id list: a new invariant must add its id here AND
    a corruption test above."""
    assert len(INVARIANTS) == 20
    assert len(set(INVARIANTS)) == 20
