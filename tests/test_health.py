"""Health-telemetry tests: the three contracts the tentpole stands on.

1. **Zero-recompile**: enabling the health vector selects a different cached
   program (new registry key) but never splits the jit cache of a running
   step -- 3 health-on steps compile exactly once under the CompileSentry.
2. **Bitwise-off**: with health off, params and LL are byte-identical to a
   run of the same step built before the health code ever executed -- the
   tap sites leave the disabled graph untouched.
3. **Flight recorder**: a seeded-NaN batch produces exactly ONE incident
   bundle (metrics snapshot, schema-valid trace, health history, params)
   and aborts or continues per policy.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.sentry import CompileSentry  # noqa: F401 (fixture dep)
from repro.compile import ProgramRegistry
from repro.core import EiNet, Normal, random_binary_trees
from repro.core.region_graph import poon_domingos
from repro.obs import health as health_lib
from repro.obs.check import validate_events, validate_metrics
from repro.train import TrainConfig, make_em_step
from repro.train.pipeline import fit


def _rat_net(health=None, **kwargs):
    g = random_binary_trees(8, 2, 2, seed=0)
    net = EiNet(g, num_sums=3, exponential_family=Normal(), health=health,
                **kwargs)
    return net, net.init(jax.random.PRNGKey(0))


def _x(net, b=16, seed=0):
    return jnp.asarray(
        np.random.RandomState(seed).randn(b, net.num_vars), jnp.float32)


# ----------------------------------------------------------------- resolve
def test_resolve_health_env(monkeypatch):
    monkeypatch.delenv("REPRO_HEALTH", raising=False)
    assert health_lib.resolve_health(None) is False
    assert health_lib.resolve_health(True) is True
    monkeypatch.setenv("REPRO_HEALTH", "1")
    assert health_lib.resolve_health(None) is True
    assert health_lib.resolve_health(False) is False  # ctor wins
    monkeypatch.setenv("REPRO_HEALTH", "off")
    assert health_lib.resolve_health(None) is False


def test_spec_matches_plan():
    net, _ = _rat_net()
    spec = net.health_spec
    assert spec.num_segments == len(net.exec_plan)
    assert spec.names[: len(health_lib.BASE_SLOTS)] == health_lib.BASE_SLOTS
    assert spec.index("ll.mean") == 0
    d = spec.to_dict(np.zeros(spec.size))
    assert set(d) == set(spec.names)


# ----------------------------------------------------- contract 1: sentry
def test_health_on_zero_extra_compiles(compile_sentry):
    """3 health-on steps = exactly 1 compile; the vector is a fused extra
    output, not a second program or a cache split."""
    net, params = _rat_net(health=True)
    x = _x(net)
    raw = make_em_step(net, TrainConfig(donate=False),
                       registry=ProgramRegistry())
    step = compile_sentry.wrap(raw, name="em_step_health")
    for _ in range(3):
        params, ll, hv = step(params, x)
    compile_sentry.assert_max_compiles(1, name="em_step_health")
    compile_sentry.assert_no_leaks()
    assert hv.shape == (net.health_spec.size,)
    assert hv.dtype == jnp.float32
    vals = net.health_spec.to_dict(np.asarray(hv))
    assert np.isfinite(vals["ll.mean"])
    assert vals["ll.nonfinite"] == 0
    assert vals["stat.nonfinite"] == 0
    assert 0.0 <= vals["seg0.sat_frac"] <= 1.0


def test_health_toggle_is_distinct_cached_program():
    """health on/off are DIFFERENT registry keys: toggling selects a cached
    program instead of recompiling the other variant."""
    net, _ = _rat_net()
    reg = ProgramRegistry()
    a = make_em_step(net, TrainConfig(health=True), registry=reg)
    b = make_em_step(net, TrainConfig(health=False), registry=reg)
    assert a is not b
    assert make_em_step(net, TrainConfig(health=True), registry=reg) is a


# -------------------------------------------------- contract 2: bitwise-off
@pytest.mark.parametrize("microbatches", [1, 4])
def test_health_off_bitwise_identical(microbatches):
    """Same step, health on vs off: the off run's params/LL are bitwise
    equal to the on run's (the extra output is computed, never fed back)."""
    net, params = _rat_net()
    x = _x(net, b=16)
    cfg = dict(donate=False, num_microbatches=microbatches)
    on = make_em_step(net, TrainConfig(health=True, **cfg),
                      registry=ProgramRegistry())
    off = make_em_step(net, TrainConfig(health=False, **cfg),
                       registry=ProgramRegistry())
    p_on, ll_on, _ = on(params, x)
    p_off, ll_off = off(params, x)
    assert np.asarray(ll_on).tobytes() == np.asarray(ll_off).tobytes()
    for a, b in zip(jax.tree_util.tree_leaves(p_on),
                    jax.tree_util.tree_leaves(p_off)):
        assert np.asarray(a).tobytes() == np.asarray(b).tobytes()


def test_tap_disabled_outside_collect():
    """tap_segment without a collector is a no-op -- a forward outside
    ``collect()`` must not accumulate anything."""
    net, params = _rat_net()
    net.log_likelihood(params, _x(net))  # runs the tap sites
    with health_lib.collect() as taps:
        pass
    assert taps == []


def test_pd_gather_taps():
    """Gather-topology (PD) walk: one tap per plan segment, all finite."""
    g = poon_domingos(4, 4, delta=2)
    net = EiNet(g, num_sums=3, health=True)
    params = net.init(jax.random.PRNGKey(0))
    x = _x(net, b=8)
    e = net.leaf_log_prob(params, x, None)
    rows = net._leaf_rows(e)
    with health_lib.collect() as taps:
        net.forward_from_e(params["einsum"], params["mixing"], None,
                           leaf_rows=rows)
    assert len(taps) == net.health_spec.num_segments
    assert all(np.isfinite(float(t)) for t in taps)


# --------------------------------------------- contract 3: flight recorder
def _nan_batches(net, n=6, nan_from=3):
    """Finite batches, then batches with NaN rows (seeded divergence)."""
    out = []
    for i in range(n):
        x = np.random.RandomState(i).randn(16, net.num_vars).astype(
            np.float32)
        if i >= nan_from:
            x[0, 0] = np.nan
        out.append(x)
    return out


def test_incident_bundle_once_and_schema(tmp_path):
    """Seeded NaN under continue-policy: training survives, exactly one
    bundle is dumped, and every artifact in it is schema-valid."""
    net, params = _rat_net(health=True)
    policy = health_lib.HealthPolicy(
        on_incident="continue", incident_dir=str(tmp_path / "incidents"))
    _, lls = fit(net, params, _nan_batches(net),
                 TrainConfig(donate=False), health_policy=policy)
    assert len(lls) == 6  # continue-policy: the loop ran to completion
    root = tmp_path / "incidents"
    bundles = sorted(os.listdir(root))
    assert len(bundles) == 1  # max_incidents=1: one bundle, not one per step
    bundle = root / bundles[0]
    with open(bundle / "incident.json") as f:
        inc = json.load(f)
    assert inc["step"] == 3 and "non-finite" in inc["reason"]
    assert inc["health_slots"] == list(net.health_spec.names)
    with open(bundle / "trace.json") as f:
        trace = json.load(f)
    assert validate_events(trace) == []
    assert any(ev["name"] == "train.incident"
               for ev in trace["traceEvents"])
    with open(bundle / "metrics.json") as f:
        snap = json.load(f)
    # the snapshot is schema-valid EXCEPT the non-finite train gauges
    # (health slots + last-LL) -- those NaNs ARE the incident being recorded
    assert all("'train.health." in p or "'train.ll." in p
               for p in validate_metrics(snap))
    assert any(k.startswith("train.health.") for k in snap)
    with open(bundle / "health_history.json") as f:
        hist = json.load(f)
    assert hist[-1]["step"] == 3
    with np.load(bundle / "params.npz") as npz:
        assert len(npz.files) > 0


def test_abort_policy_raises(tmp_path):
    net, params = _rat_net(health=True)
    policy = health_lib.HealthPolicy(
        on_incident="abort", incident_dir=str(tmp_path / "incidents"))
    with pytest.raises(health_lib.DivergenceError, match="non-finite"):
        fit(net, params, _nan_batches(net), TrainConfig(donate=False),
            health_policy=policy)
    assert len(os.listdir(tmp_path / "incidents")) == 1


def test_watcher_relative_triggers():
    """stat-norm explosion and saturation spikes trip against the running
    median, not absolute thresholds."""
    net, _ = _rat_net()
    spec = net.health_spec
    policy = health_lib.HealthPolicy(on_incident="continue", max_incidents=0)
    w = health_lib.HealthWatcher(net, policy)
    base = {n: 0.0 for n in spec.names}
    base.update({"ll.mean": -10.0, "stat.norm.max": 1.0,
                 "stat.norm.mean": 1.0, "weight.entropy": 1.0})

    def vec(**over):
        d = dict(base, **over)
        return np.array([d[n] for n in spec.names], np.float32)

    for i in range(4):
        assert w.observe(i, vec()) is None
    assert w._check(dict(base, **{"stat.norm.max": 100.0})) is not None
    assert w._check(dict(base, **{"seg0.sat_frac": 0.9})) is not None
    assert w._check(dict(base)) is None


def test_ef_clamp_fraction_families():
    from repro.core.exponential_family import (
        Bernoulli, Binomial, Categorical, Normal)

    n = Normal(min_var=1e-6, max_var=10.0)
    phi = np.zeros((4, 1, 1, 2), np.float32)
    phi[..., 1] = 1.0  # var 1: inside bounds
    phi[0, ..., 1] = 0.0  # var 0: pinned at min_var
    assert float(n.clamp_fraction(jnp.asarray(phi))) == pytest.approx(0.25)
    b = Bernoulli()
    pb = np.full((4, 1, 1, 1), 0.5, np.float32)
    pb[0] = 0.0
    assert float(b.clamp_fraction(jnp.asarray(pb))) == pytest.approx(0.25)
    bi = Binomial(n_trials=255)
    pbi = np.full((4, 1, 1, 1), 128.0, np.float32)
    pbi[0] = 0.0
    assert float(bi.clamp_fraction(jnp.asarray(pbi))) == pytest.approx(0.25)
    c = Categorical(num_categories=4)
    pc = np.full((2, 1, 1, 4), 0.25, np.float32)
    pc[0, ..., 0] = 0.0
    assert float(c.clamp_fraction(jnp.asarray(pc))) == pytest.approx(0.125)
