"""Fast single-device unit tests for repro.dist.

The subprocess tests in test_dist.py cover the 8-device semantics; these
cover the pure logic (rule resolution, precedence, degradation to no-ops on
one device) that must hold everywhere, including in jit traces with no mesh
in scope at all.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import EiNet, Normal, random_binary_trees
from repro.dist import elastic, fault_tolerance as ft, sharding as shlib


# ===================================================================== rules
def test_default_rules_tables():
    r = shlib.default_rules(multi_pod=False, fsdp=False)
    assert r["batch"] == ("data",)
    assert r["expert"] == "model"  # single axis name: all_to_all needs one
    assert r["fsdp"] is None
    r = shlib.default_rules(multi_pod=True, fsdp=True)
    assert r["batch"] == ("pod", "data")
    assert r["fsdp"] == ("data",)


def test_use_rules_nesting_precedence():
    assert shlib.get_rules() is None
    outer = shlib.default_rules(False, False)
    with shlib.use_rules(outer):
        assert shlib.get_rules()["seq"] == "model"
        inner = dict(outer, seq=None)
        with shlib.use_rules(inner):
            assert shlib.get_rules()["seq"] is None  # innermost wins
        assert shlib.get_rules()["seq"] == "model"  # outer restored
    assert shlib.get_rules() is None


def test_use_rules_copies_table():
    rules = shlib.default_rules(False, False)
    with shlib.use_rules(rules):
        rules["batch"] = None  # caller mutation after install is invisible
        assert shlib.get_rules()["batch"] == ("data",)


# ================================================================ resolution
def test_resolve_spec_divisibility_fallback():
    rules = shlib.default_rules(False, False)
    sizes = {"data": 2, "model": 4}
    # 7 % 4 != 0: the dim degrades to replicated instead of erroring
    assert shlib.resolve_spec(("heads",), (7,), sizes, rules) is None
    assert shlib.resolve_spec(("heads",), (8,), sizes, rules) == P("model")


def test_resolve_spec_no_double_use_of_axis():
    rules = shlib.default_rules(False, False)
    sizes = {"data": 2, "model": 4}
    # "seq" and "heads" both map to "model": only the first dim gets it
    spec = shlib.resolve_spec(("seq", "heads"), (8, 8), sizes, rules)
    assert spec == P("model")


def test_resolve_spec_missing_axis_and_zero_dim():
    rules = shlib.default_rules(multi_pod=True, fsdp=False)
    sizes = {"data": 2, "model": 4}  # no "pod" axis in this mesh
    assert shlib.resolve_spec(("batch",), (8,), sizes, rules) is None
    assert shlib.resolve_spec(("heads",), (0,), sizes, rules) is None


def test_path_str():
    tree = {"blocks": ({"mlp": {"wu": 1}},), "head": 2}
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    paths = sorted(shlib._path_str(p) for p, _ in flat)
    assert paths == ["/blocks/0/mlp/wu", "/head"]


# ================================================================ constraint
def test_constraint_noop_without_rules():
    x = jnp.ones((4, 4))
    assert shlib.constraint(x, ("batch", "mlp")) is x


def test_constraint_noop_without_mesh():
    x = jnp.ones((4, 4))
    with shlib.use_rules(shlib.default_rules(False, False)):
        assert shlib.constraint(x, ("batch", "mlp")) is x


def test_constraint_noop_on_one_device_mesh():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    x = jnp.ones((4, 4))
    with shlib.use_rules(shlib.default_rules(False, False)), jax.set_mesh(mesh):
        y = jax.jit(lambda a: shlib.constraint(a * 2, ("batch", "mlp")))(x)
    np.testing.assert_allclose(np.asarray(y), 2 * np.ones((4, 4)))


def test_constrain_like_params_identity_without_rules():
    tree = {"mlp": {"wu": jnp.ones((2, 3, 4))}}
    out = shlib.constrain_like_params(tree)
    assert out["mlp"]["wu"] is tree["mlp"]["wu"]


# ============================================================ tree placement
def test_tree_shardings_covers_lm_and_einet_paths():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    tree = {
        "blocks": ({"mlp": {"wu": jnp.ones((2, 8, 32))}},),
        "head": jnp.ones((8, 128)),
        "phi": jnp.ones((12, 4, 2, 2)),
        "einsum": [jnp.ones((4, 4, 4, 4))],
        "mixing": [jnp.zeros((0, 0, 4))],
        "class_prior": jnp.ones((1,)),
    }
    with shlib.use_rules(shlib.default_rules(False, False)):
        sh = shlib.tree_shardings(mesh, tree)
    leaves = jax.tree_util.tree_leaves(sh)
    assert len(leaves) == len(jax.tree_util.tree_leaves(tree))
    assert all(l.mesh is mesh for l in leaves)


def test_batch_shardings_leading_dim():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    batch = {"x": jnp.ones((8, 16)), "scalar": jnp.ones(())}
    with shlib.use_rules(shlib.default_rules(False, False)):
        sh = shlib.batch_shardings(mesh, batch)
    assert sh["x"].mesh is mesh and sh["scalar"].mesh is mesh


def test_reshard_one_device_mesh_roundtrip():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    tree = {
        "blocks": ({"mlp": {
            "wu": np.random.RandomState(0).randn(2, 8, 32).astype(np.float32)
        }},),
        "head": np.random.RandomState(1).randn(8, 128).astype(np.float32),
    }
    with shlib.use_rules(shlib.default_rules(False, False)):
        placed = elastic.reshard(tree, mesh)
        moved = elastic.reshard(
            jax.tree_util.tree_map(np.asarray, placed), mesh
        )
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(moved)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ========================================================= straggler monitor
def _full_window(mon, pattern, rounds=None):
    rounds = rounds or mon.cfg.straggler_window
    for _ in range(rounds):
        for shard, t in enumerate(pattern):
            mon.record(shard, t)


def test_straggler_no_spares_gives_empty_remap():
    cfg = ft.LoopConfig(straggler_factor=2.0, straggler_window=4)
    mon = ft.StragglerMonitor(num_shards=3, cfg=cfg)
    _full_window(mon, [1.0, 1.0, 10.0])
    assert mon.stragglers() == [2]
    assert mon.mitigate() == {}  # no spares: detection without a plan


def test_straggler_all_slow_flags_nobody():
    cfg = ft.LoopConfig(straggler_factor=2.0, straggler_window=4)
    mon = ft.StragglerMonitor(num_shards=4, cfg=cfg, spares=[9])
    _full_window(mon, [10.0, 10.0, 10.0, 10.0])  # uniform slowdown
    assert mon.stragglers() == []
    assert mon.mitigate() == {}


def test_straggler_needs_full_window():
    cfg = ft.LoopConfig(straggler_factor=2.0, straggler_window=8)
    mon = ft.StragglerMonitor(num_shards=2, cfg=cfg)
    _full_window(mon, [1.0, 10.0], rounds=3)  # window not filled yet
    assert mon.stragglers() == []


def test_straggler_fewer_spares_than_stragglers():
    cfg = ft.LoopConfig(straggler_factor=2.0, straggler_window=2)
    mon = ft.StragglerMonitor(num_shards=5, cfg=cfg, spares=[50])
    _full_window(mon, [1.0, 1.0, 1.0, 30.0, 40.0])
    assert mon.stragglers() == [3, 4]
    assert mon.mitigate() == {3: 50}  # one spare: first straggler served
    assert mon.spares == []


def test_straggler_two_shard_fleet():
    """Leave-one-out baseline: a 10x-slow node in a 2-shard fleet must be
    flagged (a self-inclusive median could never exceed its own threshold)."""
    cfg = ft.LoopConfig(straggler_factor=2.0, straggler_window=4)
    mon = ft.StragglerMonitor(num_shards=2, cfg=cfg, spares=[7])
    _full_window(mon, [1.0, 10.0])
    assert mon.stragglers() == [1]
    assert mon.mitigate() == {1: 7}


def test_straggler_single_shard_never_flags():
    mon = ft.StragglerMonitor(
        num_shards=1, cfg=ft.LoopConfig(straggler_window=2))
    _full_window(mon, [100.0])
    assert mon.stragglers() == []


# ============================================== EiNet without rules (satellite)
def test_einet_forward_and_sample_with_rules_unset():
    """Regression: EiNet must run with repro.dist rules unset (the module-
    level constraint import must not require a mesh or rules)."""
    assert shlib.get_rules() is None
    g = random_binary_trees(8, 2, 2, seed=0)
    net = EiNet(g, num_sums=3, exponential_family=Normal())
    params = net.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (5, 8))
    ll = net.log_likelihood(params, x)
    assert ll.shape == (5,)
    assert bool(jnp.all(jnp.isfinite(ll)))
    s = net.sample(params, jax.random.PRNGKey(2), 4)
    assert s.shape == (4, 8)
    assert bool(jnp.all(jnp.isfinite(s)))
