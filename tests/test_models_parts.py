"""Unit tests for model substrates: attention, MoE, mamba, xLSTM, losses."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.ref import mha_ref
from repro.models import attention, mamba, moe, xlstm
from repro.models.common import cross_entropy_loss


# ------------------------------------------------------------------ attention
@pytest.mark.parametrize("qc,kc", [(16, 16), (32, 64), (1000, 1000)])
def test_chunked_attention_matches_ref(qc, kc):
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (2, 8, 100, 16))
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 2, 100, 16))
    v = jax.random.normal(jax.random.PRNGKey(2), (2, 2, 100, 16))
    out = attention.chunked_attention(q, k, v, q_chunk=qc, kv_chunk=kc)
    ref = mha_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_decode_attention_matches_ref():
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (2, 8, 1, 16))
    k = jax.random.normal(jax.random.PRNGKey(4), (2, 2, 64, 16))
    v = jax.random.normal(jax.random.PRNGKey(5), (2, 2, 64, 16))
    # cache valid up to 40 entries
    out = attention.decode_attention(q, k, v, kv_len=jnp.asarray(40))
    ref = mha_ref(q, k[:, :, :40], v[:, :, :40], causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


# ------------------------------------------------------------------------ moe
def _moe_weights(key, e, d, f):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return (
        jax.random.normal(k1, (d, e)) * 0.1,
        jax.random.normal(k2, (e, d, f)) * 0.1,
        jax.random.normal(k3, (e, d, f)) * 0.1,
        jax.random.normal(k4, (e, f, d)) * 0.1,
    )


def test_moe_gather_matches_dense():
    """Sort-based dispatch == GShard one-hot dispatch (same drops by rank)."""
    e, d, f, t, k = 4, 8, 16, 64, 2
    router, wg, wu, wd = _moe_weights(jax.random.PRNGKey(0), e, d, f)
    x = jax.random.normal(jax.random.PRNGKey(1), (t, d))
    # generous capacity -> no token dropping -> exactly equal
    out_g, aux_g = moe.moe_ffn_gather(x, router, wg, wu, wd, k, 8.0)
    out_d, aux_d = moe.moe_ffn_dense(x, router, wg, wu, wd, k, 8.0)
    np.testing.assert_allclose(np.asarray(out_g), np.asarray(out_d), atol=1e-5)
    np.testing.assert_allclose(float(aux_g), float(aux_d), rtol=1e-5)


def test_moe_capacity_drops_tokens():
    e, d, f, t, k = 2, 4, 8, 32, 1
    router, wg, wu, wd = _moe_weights(jax.random.PRNGKey(2), e, d, f)
    x = jax.random.normal(jax.random.PRNGKey(3), (t, d))
    out_full, _ = moe.moe_ffn_gather(x, router, wg, wu, wd, k, 8.0)
    out_tight, _ = moe.moe_ffn_gather(x, router, wg, wu, wd, k, 0.25)
    # with tight capacity some token outputs must be zero (dropped)
    dropped = np.where(np.abs(np.asarray(out_tight)).sum(-1) == 0)[0]
    assert len(dropped) > 0
    kept = np.where(np.abs(np.asarray(out_tight)).sum(-1) > 0)[0]
    np.testing.assert_allclose(
        np.asarray(out_tight)[kept], np.asarray(out_full)[kept], atol=1e-5
    )


def test_moe_grad_flows():
    e, d, f, t, k = 4, 8, 16, 32, 2
    router, wg, wu, wd = _moe_weights(jax.random.PRNGKey(4), e, d, f)
    x = jax.random.normal(jax.random.PRNGKey(5), (t, d))

    def loss(wg_):
        out, aux = moe.moe_ffn_gather(x, router, wg_, wu, wd, k, 2.0)
        return jnp.sum(out**2) + aux

    g = jax.grad(loss)(wg)
    assert np.isfinite(np.asarray(g)).all()
    assert np.abs(np.asarray(g)).sum() > 0


# ---------------------------------------------------------------------- mamba
def _naive_selective_scan(x, dt, a_log, b, c, d_skip):
    bsz, l, e = x.shape
    n = a_log.shape[1]
    a = -np.exp(np.asarray(a_log))
    h = np.zeros((bsz, e, n))
    ys = []
    for t in range(l):
        a_bar = np.exp(np.asarray(dt[:, t])[..., None] * a)
        bx = (np.asarray(dt[:, t] * x[:, t]))[..., None] * np.asarray(b[:, t])[:, None, :]
        h = a_bar * h + bx
        ys.append((h * np.asarray(c[:, t])[:, None, :]).sum(-1))
    y = np.stack(ys, 1) + np.asarray(d_skip) * np.asarray(x)
    return y


@pytest.mark.parametrize("chunk", [4, 16, 64])
def test_selective_scan_matches_naive(chunk):
    bsz, l, e, n = 2, 24, 6, 4
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (bsz, l, e))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bsz, l, e)))
    a_log = jax.random.normal(ks[2], (e, n)) * 0.3
    b = jax.random.normal(ks[3], (bsz, l, n))
    c = jax.random.normal(ks[4], (bsz, l, n))
    d_skip = jnp.ones((e,))
    y, h = mamba.selective_scan(x, dt, a_log, b, c, d_skip, chunk=chunk)
    ref = _naive_selective_scan(x, dt, a_log, b, c, d_skip)
    np.testing.assert_allclose(np.asarray(y), ref, rtol=2e-4, atol=2e-4)


def test_selective_step_matches_scan():
    bsz, l, e, n = 2, 8, 4, 3
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    x = jax.random.normal(ks[0], (bsz, l, e))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bsz, l, e)))
    a_log = jax.random.normal(ks[2], (e, n)) * 0.3
    b = jax.random.normal(ks[3], (bsz, l, n))
    c = jax.random.normal(ks[4], (bsz, l, n))
    d_skip = jnp.zeros((e,))
    y_seq, h_seq = mamba.selective_scan(x, dt, a_log, b, c, d_skip, chunk=4)
    h = jnp.zeros((bsz, e, n))
    for t in range(l):
        y_t, h = mamba.selective_step(
            x[:, t], dt[:, t], a_log, b[:, t], c[:, t], d_skip, h
        )
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_seq), rtol=1e-4,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(y_t), np.asarray(y_seq[:, -1]),
                               rtol=1e-4, atol=1e-5)


def test_causal_conv1d_decode_matches_train():
    bsz, l, e, kw = 2, 10, 4, 4
    x = jax.random.normal(jax.random.PRNGKey(2), (bsz, l, e))
    w = jax.random.normal(jax.random.PRNGKey(3), (kw, e))
    y_full, _ = mamba.causal_conv1d(x, w)
    state = jnp.zeros((bsz, kw - 1, e))
    ys = []
    for t in range(l):
        y_t, state = mamba.causal_conv1d(x[:, t : t + 1], w, state)
        ys.append(y_t)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate(ys, 1)), np.asarray(y_full), atol=1e-5
    )


# ---------------------------------------------------------------------- xlstm
def test_mlstm_chunks_equal_steps():
    bsz, l, h, dh = 2, 12, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    q = jax.random.normal(ks[0], (bsz, l, h, dh))
    k = jax.random.normal(ks[1], (bsz, l, h, dh))
    v = jax.random.normal(ks[2], (bsz, l, h, dh))
    li = jax.random.normal(ks[3], (bsz, l, h))
    lf = jax.nn.log_sigmoid(jax.random.normal(ks[4], (bsz, l, h)) + 2.0)
    y_seq, carry_seq = xlstm.mlstm_sequence(q, k, v, li, lf, chunk=5)
    carry = (
        jnp.zeros((bsz, h, dh, dh)),
        jnp.zeros((bsz, h, dh)),
        jnp.full((bsz, h), -1e30),
    )
    ys = []
    for t in range(l):
        carry, y = xlstm.mlstm_step(
            carry, {"q": q[:, t], "k": k[:, t], "v": v[:, t],
                    "li": li[:, t], "lf": lf[:, t]}
        )
        ys.append(y)
    np.testing.assert_allclose(
        np.asarray(jnp.stack(ys, 1)), np.asarray(y_seq), rtol=1e-5, atol=1e-5
    )
    for a, b in zip(carry, carry_seq):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                                   atol=1e-5)


def test_slstm_stability_long_sequence():
    """Exponential gating with the m-stabilizer must not overflow over 200
    steps (the xLSTM stabilization claim)."""
    bsz, l, h, dh = 1, 200, 2, 4
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    wx = {
        n: jax.random.normal(k, (bsz, l, h, dh)) * 3.0
        for n, k in zip("ifzo", ks)
    }
    r = {n: jnp.eye(dh)[None].repeat(h, 0) * 0.1 for n in "ifzo"}
    y, carry = xlstm.slstm_sequence(wx, r, chunk=16)
    assert np.isfinite(np.asarray(y)).all()


# ----------------------------------------------------------------------- loss
def test_cross_entropy_matches_manual():
    logits = jax.random.normal(jax.random.PRNGKey(0), (2, 5, 11))
    labels = jax.random.randint(jax.random.PRNGKey(1), (2, 5), 0, 11)
    loss, denom = cross_entropy_loss(logits, labels)
    p = jax.nn.log_softmax(logits, -1)
    manual = -np.take_along_axis(
        np.asarray(p), np.asarray(labels)[..., None], -1
    ).mean()
    np.testing.assert_allclose(float(loss), manual, rtol=1e-5)
    assert float(denom) == 10.0


def test_cross_entropy_ignores_masked():
    logits = jax.random.normal(jax.random.PRNGKey(0), (1, 4, 7))
    labels = jnp.asarray([[1, -1, 2, -1]])
    loss, denom = cross_entropy_loss(logits, labels)
    assert float(denom) == 2.0
    assert np.isfinite(float(loss))
