"""SLO budget + bench-history tests: the perf gate is a *contract*.

The committed ``slo.json`` must admit the committed ``BENCH_*.json`` (else
the gate is red at HEAD), synthetic breaches must be caught with the
declared noise tolerance applied, smoke reports must be checked for
correctness flags only, and every history row must be commit-stamped and
round-trip through the JSONL store.
"""

import json
import os

import pytest

from repro.obs import slo as slo_lib

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SLO = {
    "tolerance": 0.10,
    "serve": {
        "max_parity_abs_diff": 1e-5,
        "min_speedup_vs_jitted": 2.0,
        "p99_ms": {"joint_ll": 10.0},
    },
    "train": {"min_speedup": 1.0, "max_step_ms": {"einet_rat": 100.0}},
    "mixture": {"min_speedup": 1.2},
    "eval": {"min_engine_vs_direct": 0.2},
}


def _serve_report(**over):
    r = {
        "parity_ok": True,
        "grouped_ok": True,
        "parity_max_abs_diff": 1e-7,
        "speedup_vs_jitted": 3.0,
        "latency_ms": {"joint_ll": {"p50": 1.0, "p95": 5.0, "p99": 8.0}},
    }
    r.update(over)
    return r


# ------------------------------------------------------------------ budgets
def test_serve_within_budget():
    assert slo_lib.check_report("serve", _serve_report(), SLO) == []


def test_serve_p99_breach_uses_tolerance():
    # budget 10 ms, tolerance 10% -> limit 11 ms: 10.5 passes, 11.5 fails
    ok = _serve_report(latency_ms={"joint_ll": {"p99": 10.5}})
    assert slo_lib.check_report("serve", ok, SLO) == []
    bad = _serve_report(latency_ms={"joint_ll": {"p99": 11.5}})
    probs = slo_lib.check_report("serve", bad, SLO)
    assert len(probs) == 1 and "p99" in probs[0] and "tolerance" in probs[0]


def test_serve_flags_checked_even_on_smoke():
    bad = _serve_report(smoke=True, parity_ok=False,
                        parity_max_abs_diff=1.0)
    probs = slo_lib.check_report("serve", bad, SLO)
    assert any("parity_ok" in p for p in probs)
    assert any("parity_max_abs_diff" in p for p in probs)
    # but no timing problems: smoke wall-clock carries no signal
    slow_smoke = _serve_report(
        smoke=True, latency_ms={"joint_ll": {"p99": 9999.0}},
        speedup_vs_jitted=0.01)
    assert slo_lib.check_report("serve", slow_smoke, SLO) == []


def test_serve_pd_smoke_subreport_flags():
    r = _serve_report(pd_smoke={"parity_ok": True, "grouped_ok": False,
                                "parity_max_abs_diff": 0.0})
    probs = slo_lib.check_report("serve", r, SLO)
    assert probs == ["serve.pd_smoke: grouped_ok is not true"]


def test_serve_missing_latency_kind_is_a_problem():
    r = _serve_report(latency_ms={})
    assert any("no latency for kind 'joint_ll'" in p
               for p in slo_lib.check_report("serve", r, SLO))


def test_train_budgets_and_waiver():
    base = {"parity_ok": True, "grouped_ok": True}
    rows = [{"arch_id": "einet_rat", "grad_parity_ok": True,
             "fused_ms_per_step": 50.0, "speedup": 2.0}]
    assert slo_lib.check_report(
        "train", dict(base, results=rows), SLO) == []
    slow = [dict(rows[0], fused_ms_per_step=150.0)]
    assert any("fused step" in p for p in slo_lib.check_report(
        "train", dict(base, results=slow), SLO))
    # below the speedup floor trips -- unless the row carries a waiver
    regressed = [dict(rows[0], speedup=0.5)]
    assert any("speedup" in p for p in slo_lib.check_report(
        "train", dict(base, results=regressed), SLO))
    waived = [dict(rows[0], speedup=0.5, speedup_waiver="tiny arch")]
    assert slo_lib.check_report(
        "train", dict(base, results=waived), SLO) == []


def test_mixture_and_eval_budgets():
    mix = {"parity_ok": True,
           "results": [{"cell": "a", "speedup": 2.0},
                       {"cell": "b", "speedup": 0.9}]}
    probs = slo_lib.check_report("mixture", mix, SLO)
    assert len(probs) == 1 and "mixture[b]" in probs[0]
    ev = {"parity_ok": True, "engine_vs_direct": 0.3}
    assert slo_lib.check_report("eval", ev, SLO) == []
    ev_bad = {"parity_ok": True, "engine_vs_direct": 0.1}
    assert any("engine_vs_direct" in p
               for p in slo_lib.check_report("eval", ev_bad, SLO))


def test_unknown_kind_rejected():
    assert slo_lib.check_report("nope", {}, SLO) != []


def test_check_all_empty_dir_is_not_a_pass(tmp_path):
    out = slo_lib.check_all(bench_dir=str(tmp_path), slo=SLO)
    assert out == {"(none)": [f"no BENCH_*.json found in {str(tmp_path)!r}"]}


def test_check_all_malformed_bench_file(tmp_path):
    (tmp_path / "BENCH_serve.json").write_text("{not json")
    out = slo_lib.check_all(bench_dir=str(tmp_path), slo=SLO)
    assert any("cannot load" in p for p in out["serve"])


# -------------------------------------------- the committed contract at HEAD
def test_committed_slo_admits_committed_benches():
    """The repo's own slo.json must pass against the repo's own BENCH
    files -- a red gate at HEAD means either the budget or the committed
    numbers are wrong, and this test catches it before CI does."""
    slo = slo_lib.load_slo(os.path.join(REPO_ROOT, "slo.json"))
    out = slo_lib.check_all(bench_dir=REPO_ROOT, slo=slo)
    assert "(none)" not in out, "no committed BENCH files found"
    for kind, problems in sorted(out.items()):
        assert problems == [], f"{kind}: {problems}"


# ------------------------------------------------------------------ history
def test_history_row_is_commit_stamped():
    row = slo_lib.history_row(
        "eval", {"timestamp": "2026-08-08T00:00:00+00:00", "smoke": True,
                 "engine_vs_direct": 0.3, "parity_ok": True})
    assert row["bench"] == "eval"
    assert row["ts"] == "2026-08-08T00:00:00+00:00"  # report ts wins
    assert row["smoke"] is True
    assert isinstance(row["commit"], str) and row["commit"]
    assert row["engine_vs_direct"] == 0.3
    # without a report timestamp the row stamps itself (UTC ISO)
    assert "T" in slo_lib.history_row("eval", {})["ts"]


def test_append_and_load_history_roundtrip(tmp_path):
    root = str(tmp_path / "hist")
    r1 = {"parity_ok": True, "results": [
        {"arch_id": "einet_rat", "fused_ms_per_step": 50.0, "speedup": 2.0}]}
    r2 = {"parity_ok": True, "smoke": True, "results": []}
    p1 = slo_lib.append_history("train", r1, root=root)
    p2 = slo_lib.append_history("train", r2, root=root)
    assert p1 == p2 == os.path.join(root, "train.jsonl")
    hist = slo_lib.load_history(root)
    assert list(hist) == ["train"]
    assert len(hist["train"]) == 2  # appends, never truncates
    assert hist["train"][0]["cells"]["einet_rat"]["fused_ms"] == 50.0
    assert hist["train"][1]["smoke"] is True
    # every line is self-contained JSON (greppable / tail-able)
    with open(p1) as f:
        for line in f:
            json.loads(line)


def test_load_history_skips_malformed_lines(tmp_path):
    root = tmp_path / "hist"
    root.mkdir()
    (root / "serve.jsonl").write_text(
        json.dumps({"bench": "serve", "commit": "abc"}) + "\n"
        + "not json at all\n"
        + json.dumps({"bench": "serve", "commit": "def"}) + "\n")
    hist = slo_lib.load_history(str(root))
    assert [r["commit"] for r in hist["serve"]] == ["abc", "def"]


def test_load_history_missing_dir(tmp_path):
    assert slo_lib.load_history(str(tmp_path / "nowhere")) == {}


# ------------------------------------------------------------------ CLI
def test_cli_check_passes_on_committed_contract(capsys):
    cwd = os.getcwd()
    os.chdir(REPO_ROOT)
    try:
        status = slo_lib.main(["--check"])
    finally:
        os.chdir(cwd)
    out = capsys.readouterr().out
    assert status == 0
    assert "within budget" in out


def test_cli_check_fails_on_breach(tmp_path, capsys):
    (tmp_path / "slo.json").write_text(json.dumps(SLO))
    (tmp_path / "BENCH_eval.json").write_text(json.dumps(
        {"parity_ok": True, "engine_vs_direct": 0.01}))
    status = slo_lib.main(["--check", "--dir", str(tmp_path),
                           "--slo", str(tmp_path / "slo.json")])
    assert status == 1
    assert "engine_vs_direct" in capsys.readouterr().out


def test_cli_requires_an_action():
    with pytest.raises(SystemExit):
        slo_lib.main([])
