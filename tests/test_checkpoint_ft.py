"""Checkpointing + fault-tolerance loop tests."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.data.pipeline import ShardedLoader
from repro.data import synthetic
from repro.dist import fault_tolerance as ft


def _tree(step):
    return {
        "a": jnp.arange(6, dtype=jnp.float32) + step,
        "nested": {"b": jnp.ones((3, 2)) * step, "c": jnp.asarray(step)},
    }


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_write=False)
    mgr.save(7, _tree(7))
    step, restored = mgr.restore(_tree(0))
    assert step == 7
    for a, b in zip(
        jax.tree_util.tree_leaves(restored), jax.tree_util.tree_leaves(_tree(7))
    ):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_write=True)
    for s in (1, 2, 3):
        mgr.save(s, _tree(s))
    mgr.wait()
    assert mgr.latest_step() == 3


def test_gc_keeps_last_n(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_write=False)
    for s in range(5):
        mgr.save(s, _tree(s))
    assert mgr.all_steps() == [3, 4]


def test_tmp_debris_ignored(tmp_path):
    """A crashed (uncommitted) write must never be restored."""
    mgr = CheckpointManager(str(tmp_path), async_write=False)
    mgr.save(1, _tree(1))
    os.makedirs(tmp_path / "step_00000009.tmp")  # simulated crash debris
    assert mgr.latest_step() == 1
    step, _ = mgr.restore(_tree(0))
    assert step == 1


def test_tree_mismatch_rejected(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_write=False)
    mgr.save(1, _tree(1))
    with pytest.raises(AssertionError):
        mgr.restore({"different": jnp.zeros(3)})


# ------------------------------------------------------------ fault tolerance
def test_run_training_with_failures(tmp_path):
    """Injected crashes at steps 7 and 13 must not change the final result:
    restart from the last checkpoint reproduces the exact state (stateless
    data + deterministic step)."""
    mgr = CheckpointManager(str(tmp_path / "a"), async_write=False)

    def step_fn(state, batch):
        return {"x": state["x"] + batch["v"].sum(), "step": state["step"] + 1}

    def batch_at(step):
        return {"v": np.asarray([step, step], np.float32)}

    crashed = set()

    def injector(step):
        if step in (7, 13) and step not in crashed:
            crashed.add(step)
            raise RuntimeError(f"simulated node failure at {step}")

    init = {"x": jnp.zeros(()), "step": jnp.zeros((), jnp.int32)}
    cfg = ft.LoopConfig(checkpoint_every=5, max_restarts=5)
    final, stats = ft.run_training(
        step_fn, init, batch_at, mgr, num_steps=20, cfg=cfg,
        fail_injector=injector,
    )
    assert stats["restarts"] == 2
    # reference run without failures
    mgr2 = CheckpointManager(str(tmp_path / "b"), async_write=False)
    ref, _ = ft.run_training(step_fn, init, batch_at, mgr2, num_steps=20,
                             cfg=cfg)
    np.testing.assert_allclose(float(final["x"]), float(ref["x"]))
    assert int(final["step"]) == int(ref["step"]) == 20


def test_restart_budget_exceeded(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_write=False)

    def step_fn(state, batch):
        return state

    def injector(step):
        raise RuntimeError("always down")

    with pytest.raises(RuntimeError, match="restart budget"):
        ft.run_training(
            step_fn, {"x": jnp.zeros(())}, lambda s: {}, mgr, 5,
            ft.LoopConfig(max_restarts=2), fail_injector=injector,
        )


def test_straggler_monitor_remaps():
    cfg = ft.LoopConfig(straggler_factor=2.0, straggler_window=8)
    mon = ft.StragglerMonitor(num_shards=4, cfg=cfg)
    mon.spares = [99]
    for _ in range(8):
        for shard in range(4):
            mon.record(shard, 10.0 if shard == 2 else 1.0)
    assert mon.stragglers() == [2]
    remap = mon.mitigate()
    assert remap == {2: 99}


# ----------------------------------------------------------------------- data
def test_loader_deterministic_skip_ahead():
    """batch(step) must be derivable from (step, shard) alone -- the property
    the restart logic relies on."""
    mk = lambda step, shard, n: synthetic.token_batch(step, shard, n, 8, 100)
    a = ShardedLoader(mk, global_batch=8, num_shards=2, shard_id=0)
    b = ShardedLoader(mk, global_batch=8, num_shards=2, shard_id=0,
                      start_step=5)
    for _ in range(5):
        next(a)
    np.testing.assert_array_equal(next(a)["tokens"], next(b)["tokens"])


def test_loader_shards_differ():
    mk = lambda step, shard, n: synthetic.token_batch(step, shard, n, 8, 100)
    a = ShardedLoader(mk, 8, 2, 0)
    b = ShardedLoader(mk, 8, 2, 1)
    assert not np.array_equal(next(a)["tokens"], next(b)["tokens"])


def test_loader_prefetch():
    mk = lambda step, shard, n: synthetic.token_batch(step, shard, n, 4, 50)
    ld = ShardedLoader(mk, 4, 1, 0).start_prefetch()
    b0 = ld.next_prefetched()
    b1 = ld.next_prefetched()
    ld.stop()
    ref = synthetic.token_batch(0, 0, 4, 4, 50)
    np.testing.assert_array_equal(b0["tokens"], ref["tokens"])
    assert not np.array_equal(b0["tokens"], b1["tokens"])


def test_binary_dataset_shapes():
    for name, d in synthetic.TWENTY_DATASETS[:5]:
        x = synthetic.binary_dataset(name, 100)
        assert x.shape == (100, d)
        assert set(np.unique(x)) <= {0.0, 1.0}


def test_image_proxy_range():
    x = synthetic.gaussian_mixture_images(16, 8, 8, 3)
    assert x.shape == (16, 192)
    assert x.min() >= 0 and x.max() <= 1
