"""EM tests: autodiff-EM correctness, monotonicity, stochastic EM."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    Bernoulli,
    EMConfig,
    EiNet,
    Normal,
    accumulate_statistics,
    em_statistics,
    em_update,
    m_step,
    random_binary_trees,
    stochastic_em_update,
    zeros_like_statistics,
)


@pytest.fixture(scope="module")
def setup():
    g = random_binary_trees(10, 2, 2, seed=0)
    net = EiNet(g, num_sums=4, exponential_family=Normal())
    params = net.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 10)) * 1.5 + 0.3
    return net, params, x


def test_em_statistics_shapes_and_counts(setup):
    net, params, x = setup
    stats = em_statistics(net, params, x)
    # expected sum-node counts: for each root-layer entry, statistics sum to
    # the total expected number of uses == batch size (root is used once per x)
    top = stats["n_einsum"][-1]
    if net.pair_specs[-1].mix_global is None:
        np.testing.assert_allclose(float(jnp.sum(top)), x.shape[0], rtol=1e-4)
    # leaf responsibilities: for each variable, total leaf posterior == batch
    per_var = np.asarray(jnp.sum(stats["s_den"], axis=(1, 2)))
    np.testing.assert_allclose(per_var, x.shape[0], rtol=1e-4)


def test_full_batch_em_is_monotone(setup):
    """Full-batch EM must not decrease the training likelihood (§3.5)."""
    net, params, x = setup
    prev = -np.inf
    p = params
    for _ in range(8):
        p, ll = em_update(net, p, x)
        ll = float(ll)
        assert ll >= prev - 1e-3, f"EM decreased LL: {prev} -> {ll}"
        prev = ll


def test_em_improves_over_init(setup):
    net, params, x = setup
    _, ll0 = em_update(net, params, x)
    p = params
    for _ in range(10):
        p, ll = em_update(net, p, x)
    assert float(ll) > float(ll0) + 1.0


def test_stochastic_em_learns(setup):
    net, params, _ = setup
    key = jax.random.PRNGKey(7)
    data = jax.random.normal(key, (512, 10)) * 0.7 - 0.5
    cfg = EMConfig(step_size=0.4)
    p = params
    step = jax.jit(lambda p, b: stochastic_em_update(net, p, b, cfg))
    lls = []
    for i in range(30):
        batch = data[(i * 64) % 512: (i * 64) % 512 + 64]
        p, ll = step(p, batch)
        lls.append(float(ll))
    assert np.mean(lls[-5:]) > np.mean(lls[:5]) + 1.0


def test_minibatch_statistics_accumulate_to_full_batch(setup):
    """E-step stats are sums over data: two half-batches == one full batch.
    (This additivity is what makes the distributed psum-EM exact.)"""
    net, params, x = setup
    full = em_statistics(net, params, x)
    acc = zeros_like_statistics(net, params)
    acc = accumulate_statistics(acc, em_statistics(net, params, x[:32]))
    acc = accumulate_statistics(acc, em_statistics(net, params, x[32:]))
    for a, b in zip(
        jax.tree_util.tree_leaves(full), jax.tree_util.tree_leaves(acc)
    ):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3,
                                   atol=1e-4)


def test_m_step_respects_constraints(setup):
    net, params, x = setup
    stats = em_statistics(net, params, x)
    new = m_step(net, stats, EMConfig())
    for w in new["einsum"]:
        np.testing.assert_allclose(
            np.asarray(jnp.sum(w, axis=(-2, -1))), 1.0, rtol=1e-5
        )
        assert (np.asarray(w) > 0).all()
    mu = np.asarray(new["phi"][..., 0])
    second = np.asarray(new["phi"][..., 1])
    assert ((second - mu**2) > 0).all(), "variances must stay positive"


def test_em_recovers_bernoulli_mixture():
    """EiNet EM on data from a 2-cluster Bernoulli source should beat the
    independent-Bernoulli baseline in held-out LL."""
    rng = np.random.RandomState(0)
    z = rng.randint(2, size=600)
    protos = np.array([[0.9] * 4 + [0.1] * 4, [0.1] * 4 + [0.9] * 4])
    data = (rng.rand(600, 8) < protos[z]).astype(np.float32)
    train, test = jnp.asarray(data[:500]), jnp.asarray(data[500:])
    g = random_binary_trees(8, 1, 2, seed=5)
    net = EiNet(g, num_sums=4, exponential_family=Bernoulli())
    p = net.init(jax.random.PRNGKey(5))
    for _ in range(15):
        p, _ = em_update(net, p, train)
    ll = float(jnp.mean(net.log_likelihood(p, test)))
    # independent Bernoulli baseline
    q = np.clip(data[:500].mean(0), 1e-3, 1 - 1e-3)
    base = float(
        np.mean(
            (data[500:] * np.log(q) + (1 - data[500:]) * np.log(1 - q)).sum(1)
        )
    )
    assert ll > base + 0.3, f"EiNet {ll} should beat indep baseline {base}"
