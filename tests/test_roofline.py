"""HLO analyzer validation: scan-aware FLOP/collective counting on programs
with known analytic costs."""

import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_analysis import analyze_hlo


def test_plain_matmul_flops():
    n, m, k = 64, 128, 256

    def f(a, b):
        return a @ b

    compiled = jax.jit(f).lower(
        jax.ShapeDtypeStruct((n, k), jnp.float32),
        jax.ShapeDtypeStruct((k, m), jnp.float32),
    ).compile()
    r = analyze_hlo(compiled.as_text())
    assert abs(r["flops"] - 2 * n * m * k) / (2 * n * m * k) < 0.01


def test_scan_multiplies_by_trip_count():
    """The whole point: a matmul inside lax.scan must count trips x flops,
    which XLA's own cost_analysis misses."""
    n, trips = 128, 17

    def f(x, ws):
        def body(c, w):
            return jnp.tanh(c @ w), None

        out, _ = jax.lax.scan(body, x, ws)
        return out

    compiled = jax.jit(f).lower(
        jax.ShapeDtypeStruct((n, n), jnp.float32),
        jax.ShapeDtypeStruct((trips, n, n), jnp.float32),
    ).compile()
    r = analyze_hlo(compiled.as_text())
    expect = 2 * n * n * n * trips
    assert abs(r["flops"] - expect) / expect < 0.05, r["flops"]
    # XLA raw analysis counts the body once -- document the gap
    raw = compiled.cost_analysis()["flops"]
    assert raw < r["flops"] / 2


def test_nested_scan_trip_products():
    n, outer, inner = 32, 5, 7

    def f(x, ws):
        def outer_body(c, wouter):
            def inner_body(ci, wi):
                return ci @ wi, None

            c2, _ = jax.lax.scan(inner_body, c, wouter)
            return c2, None

        out, _ = jax.lax.scan(outer_body, x, ws)
        return out

    compiled = jax.jit(f).lower(
        jax.ShapeDtypeStruct((n, n), jnp.float32),
        jax.ShapeDtypeStruct((outer, inner, n, n), jnp.float32),
    ).compile()
    r = analyze_hlo(compiled.as_text())
    expect = 2 * n**3 * outer * inner
    assert abs(r["flops"] - expect) / expect < 0.05


def test_collective_bytes_counted():
    """all-reduce result bytes on an SPMD module (subprocess: needs 8 dev)."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.hlo_analysis import analyze_hlo

        mesh = jax.make_mesh((8,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        def f(x):
            return jnp.sum(x, axis=0)
        sh = NamedSharding(mesh, P("data", None))
        compiled = jax.jit(f, in_shardings=(sh,),
                           out_shardings=NamedSharding(mesh, P())).lower(
            jax.ShapeDtypeStruct((8, 1024), jnp.float32)).compile()
        r = analyze_hlo(compiled.as_text())
        print(json.dumps({"ar": r["collectives"]["all-reduce"]["bytes"],
                          "total": r["collective_bytes"]}))
    """)
    env = dict(os.environ, PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, env=env, timeout=300)
    assert out.returncode == 0, out.stderr
    r = json.loads(out.stdout.strip().splitlines()[-1])
    assert r["ar"] == 1024 * 4  # one f32[1024] all-reduce result per device


def test_dryrun_artifacts_valid_if_present():
    """Every committed dry-run artifact must parse and carry the roofline
    inputs (guards against schema drift)."""
    d = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")
    if not os.path.isdir(d):
        pytest.skip("no dry-run artifacts yet")
    files = [f for f in os.listdir(d) if f.endswith(".json")]
    assert files, "artifact dir exists but is empty"
    for f in files:
        with open(os.path.join(d, f)) as fh:
            rec = json.load(fh)
        if "skipped" in rec or "error" in rec:
            continue
        for key in ("flops_per_device", "collective_bytes_per_device",
                    "memory", "num_devices"):
            assert key in rec, (f, key)
        # batch-1 decode steps lower their matvecs as fusions (no HLO dot
        # ops); the roofline uses the analytic 2*N_active flops there
        if not (rec["kind"] == "decode" and rec["flops_per_device"] == 0):
            assert rec["flops_per_device"] > 0, f
