"""Observability tests: span nesting + Chrome-trace export schema, log-bucket
histogram percentiles against numpy, steady-state baseline subtraction,
counter thread-safety, the disabled path costing nothing AND changing
nothing (bitwise-identical serve results traced vs untraced), and the
single-source compile-event accounting shared with ``analysis.sentry``."""

import json
import threading

import numpy as np
import pytest

import jax

from repro import obs
from repro.obs import METRICS
from repro.obs.check import validate_events
from repro.obs.metrics import NUM_BUCKETS, percentile_from_counts


@pytest.fixture(autouse=True)
def obs_clean_slate():
    """Every test starts with tracing off and an empty buffer; the global
    METRICS registry is process-wide, so tests read *deltas*, not totals."""
    obs.configure(trace=False)
    obs.reset()
    yield
    obs.configure(trace=False)
    obs.reset()
    obs.set_sync(None)


# ------------------------------------------------------------------- tracing
def test_span_nesting_and_export_schema(tmp_path):
    obs.configure(trace=True)
    with obs.span("outer.op", kind="a"):
        with obs.span("inner.op", idx=0):
            pass
        with obs.span("inner.op", idx=1):
            pass
    obs.event("marker.point", note="x")
    assert obs.num_events() == 4

    path = tmp_path / "trace.json"
    out = obs.export_trace(str(path))
    assert out == str(path)
    doc = json.loads(path.read_text())  # round-trips through real JSON
    assert validate_events(doc, ("outer.", "inner.")) == []
    assert doc["displayTimeUnit"] == "ms"

    evs = {e["name"]: e for e in doc["traceEvents"] if e["ph"] == "X"}
    outer = evs["outer.op"]
    inners = [e for e in doc["traceEvents"] if e["name"] == "inner.op"]
    assert len(inners) == 2 and [e["args"]["idx"] for e in inners] == [0, 1]
    # Perfetto reconstructs nesting from ts/dur containment: both inner
    # spans must lie inside the outer span's [ts, ts + dur] window
    for e in inners:
        assert outer["ts"] <= e["ts"]
        assert e["ts"] + e["dur"] <= outer["ts"] + outer["dur"] + 1e-6
    assert outer["args"] == {"kind": "a"}


def test_validate_events_catches_bad_traces():
    assert validate_events([]) != []
    assert validate_events({"traceEvents": "nope"}) != []
    bad_dur = {"traceEvents": [
        {"ph": "X", "ts": 0.0, "name": "a", "args": {}}]}  # missing dur
    assert any("dur" in p for p in validate_events(bad_dur))
    ok = {"traceEvents": [
        {"ph": "X", "ts": 0.0, "dur": 1.0, "name": "serve.step", "args": {}}]}
    assert validate_events(ok, ("serve.",)) == []
    assert any("required subsystem" in p
               for p in validate_events(ok, ("train.",)))


def test_disabled_span_is_shared_noop_and_buffers_nothing():
    assert not obs.enabled()
    s1, s2 = obs.span("a"), obs.span("b", k=1)
    assert s1 is s2  # the no-op singleton: no per-call allocation
    with s1:
        pass
    obs.event("nope")
    assert obs.num_events() == 0


def test_timed_measures_even_when_disabled_and_feeds_metric():
    before = METRICS.sum_histogram("test.obs.seconds")
    with obs.timed("test.obs", metric="test.obs.seconds", tag="t") as t:
        x = sum(range(1000))
    assert x == 499500 and t.seconds > 0.0
    assert obs.num_events() == 0  # tracing off: no event, but measured
    delta = [a - b for a, b in
             zip(METRICS.sum_histogram("test.obs.seconds"), before)]
    assert sum(delta) == 1
    labels = [d for d, _ in METRICS.find("test.obs.seconds", tag="t")]
    assert labels and labels[0] == {"tag": "t"}


# ------------------------------------------------------------------- metrics
def test_histogram_percentile_matches_numpy():
    rng = np.random.RandomState(0)
    samples = rng.lognormal(mean=-6.0, sigma=1.5, size=5000)
    h = METRICS.histogram("test.obs.hist.seconds")
    base = h.counts()
    for v in samples:
        h.record(float(v))
    counts = [a - b for a, b in zip(h.counts(), base)]
    for q in (50, 90, 95, 99):
        got = percentile_from_counts(counts, q)
        want = float(np.percentile(samples, q))
        # log buckets at 24/decade -> half-bucket relative error ~5%
        assert got == pytest.approx(want, rel=0.08), f"p{q}"


def test_percentile_baseline_reads_only_the_interval():
    h = METRICS.histogram("test.obs.base.seconds")
    for _ in range(50):
        h.record(1e-3)  # "warm-up": slow
    mark = h.counts()
    for _ in range(50):
        h.record(1e-5)  # steady state: fast
    p95_all = h.percentile(95)
    p95_steady = h.percentile(95, baseline=mark)
    assert p95_steady == pytest.approx(1e-5, rel=0.08)
    assert p95_all > p95_steady * 5  # mixed window drags the tail upward


def test_counter_thread_safety_exact():
    c = METRICS.counter("test.obs.threads.count")
    start = c.value
    n_threads, per_thread = 8, 5000

    def work():
        for _ in range(per_thread):
            c.inc()

    threads = [threading.Thread(target=work) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.value - start == n_threads * per_thread  # no lost updates


def test_snapshot_is_plain_json(tmp_path):
    METRICS.counter("test.obs.snap.count", kind="a").inc(3)
    METRICS.gauge("test.obs.snap.depth").set(7)
    METRICS.histogram("test.obs.snap.seconds").record(0.01)
    snap = METRICS.snapshot()
    text = json.dumps(snap)  # must be JSON-able as-is (BENCH files)
    back = json.loads(text)
    assert back["test.obs.snap.count{kind=a}"] == 3
    # gauges snapshot value + high-watermark (bursty gauges like queue depth
    # read ~0 at end-of-run without the max)
    assert back["test.obs.snap.depth"]["value"] == 7.0
    assert back["test.obs.snap.depth"]["max"] == 7.0
    h = back["test.obs.snap.seconds"]
    assert h["count"] >= 1 and h["p50"] > 0


def test_registry_rejects_type_confusion():
    METRICS.counter("test.obs.typed")
    with pytest.raises(TypeError, match="already registered"):
        METRICS.gauge("test.obs.typed")


# ------------------------------------------- compile events (single source)
def test_compile_events_single_source_with_listener():
    """ProgramRegistry is the only emitter: one miss + one hit produce
    exactly one compile event and one cache hit, and the subscribed
    listener (the sentry mechanism) sees exactly the one compile."""
    from repro.compile import ProgramRegistry

    miss0 = METRICS.value("compile.cache.misses", kind="aot")
    hit0 = METRICS.value("compile.cache.hits", kind="aot")
    seen = []
    token = obs.on_compile(seen.append)
    try:
        reg = ProgramRegistry()

        class Anchor:  # plain object() is not weakref-able
            pass

        anchor = Anchor()

        def f(a):
            return a * 2.0

        args = (np.ones((2,), np.float32),)
        p1 = reg.aot(anchor, ("k", 2), f, args)
        p2 = reg.aot(anchor, ("k", 2), f, args)  # cache hit
        assert p1 is p2
        assert reg.stats["compiles"] == 1 and reg.stats["hits"] == 1
    finally:
        obs.remove_compile_listener(token)
    assert METRICS.value("compile.cache.misses", kind="aot") - miss0 == 1
    assert METRICS.value("compile.cache.hits", kind="aot") - hit0 == 1
    assert len(seen) == 1
    assert seen[0]["kind"] == "aot" and "('k', 2)" in seen[0]["key"]
    assert seen[0]["seconds"] >= 0.0
    # removed listener hears nothing further
    obs.compile_event("aot", ("k", 3), 0.0)
    assert len(seen) == 1


# --------------------------------------------------- disabled-mode identity
def test_serve_results_bitwise_identical_traced_vs_untraced():
    """Tracing must be observational only: the same request stream through
    fresh engines, traced and untraced, yields bitwise-identical bytes."""
    from repro.core import EiNet, Normal, random_binary_trees
    from repro.serve import ServeEngine, mixed_requests

    g = random_binary_trees(8, 2, 2, seed=0)
    net = EiNet(g, num_sums=3, exponential_family=Normal())
    params = net.init(jax.random.PRNGKey(0))
    reqs = mixed_requests(net.num_vars, 12, seed=0)

    obs.configure(trace=False)
    plain = ServeEngine(net, params, max_batch=4).run(reqs)

    obs.configure(trace=True)
    traced = ServeEngine(net, params, max_batch=4).run(reqs)
    assert obs.num_events() > 0  # tracing actually collected spans

    assert sorted(plain) == sorted(traced)
    for rid in plain:
        a, b = plain[rid], traced[rid]
        assert a.kind == b.kind
        va, vb = np.asarray(a.value), np.asarray(b.value)
        assert va.dtype == vb.dtype and va.shape == vb.shape
        assert va.tobytes() == vb.tobytes()  # bitwise, not approx


def test_summary_rolls_up_serve_and_plan():
    req0 = sum(METRICS.sum_histogram("serve.request.seconds"))
    METRICS.histogram("serve.request.seconds",
                      kind="joint_ll", bucket=4).record(2e-3)
    s = obs.summary()
    assert s["serve_requests"] >= req0 + 1
    assert set(s["serve_latency_ms"]) == {"p50", "p95", "p99"}
    assert isinstance(obs.format_summary(), str)


# --------------------------------------------------------- buffer mechanics
def test_buffer_cap_counts_dropped(monkeypatch):
    from repro.obs import trace as trace_mod

    monkeypatch.setattr(trace_mod, "_MAX_EVENTS", 3)
    obs.configure(trace=True)
    for i in range(5):
        obs.event("e", i=i)
    assert obs.num_events() == 3
    assert trace_mod._STATE.dropped == 2  # counted, not silently lost
    obs.reset()
    assert obs.num_events() == 0 and trace_mod._STATE.dropped == 0
