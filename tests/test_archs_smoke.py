"""Registry smoke tests: the --arch surface is EiNet-only and every
registered config builds a working model.

The repo scaffold originally shipped a set of template LM architectures
(transformer/SSM/MoE configs + model code) alongside the paper's EiNets;
those were removed from the registry, packaging, and test collection.
These tests pin both halves: the EiNet cells keep their exact paper
numbers, and the LM surface stays gone.
"""

import importlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALIASES, REGISTRY, EinetConfig, get_config
from repro.launch.cells import build_einet

EINET_ARCHS = sorted(REGISTRY)


def test_registry_is_einet_only():
    assert REGISTRY, "registry must not be empty"
    for name, cfg in REGISTRY.items():
        assert isinstance(cfg, EinetConfig), (name, type(cfg))
        assert cfg.name == name
    # the short ids --arch accepts all resolve to registered configs
    for alias, name in ALIASES.items():
        assert get_config(alias) is REGISTRY[name]


def test_unknown_arch_lists_available():
    with pytest.raises(KeyError) as e:
        get_config("qwen1.5-0.5b")  # a removed LM arch id
    assert "einet-rat" in str(e.value)


@pytest.mark.parametrize(
    "arch,expect",
    [
        # Fig. 3/6 efficiency-study RAT: D=4, R=10, K=10 at 512 vars
        ("einet_rat", dict(structure="rat", num_vars=512, depth=4,
                           num_repetitions=10, num_sums=10)),
        ("einet_rat_large", dict(structure="rat", num_vars=1024, depth=7,
                                 num_repetitions=16, num_sums=64)),
        # §4.2 SVHN PD: 32x32x3, Delta=8, K=40
        ("einet_pd", dict(structure="pd", height=32, width=32,
                          num_channels=3, delta=8, num_sums=40)),
        ("einet_pd_mnist", dict(structure="pd", height=28, width=28,
                                num_channels=1, delta=7, num_sums=32)),
        ("einet_celeba", dict(structure="pd", height=32, width=32,
                              num_channels=3, delta=8, num_sums=40)),
    ],
)
def test_exact_config_numbers(arch, expect):
    cfg = get_config(arch)
    for field, val in expect.items():
        assert getattr(cfg, field) == val, (arch, field)


def test_lm_surface_is_gone():
    for mod in ("repro.models", "repro.kernels.flash_attention"):
        with pytest.raises(ImportError):
            importlib.import_module(mod)
    import repro.configs as configs
    import repro.kernels as kernels
    assert not hasattr(kernels, "flash_attention")
    assert not hasattr(configs, "LM_ARCHS")
    assert not hasattr(configs, "ModelConfig")


def test_registered_arch_builds_and_forwards():
    # the cheapest registered cell end-to-end: build, init, LL forward
    cfg = get_config("einet_rat")
    model = build_einet(cfg)
    params = model.init(jax.random.PRNGKey(0))
    x = jnp.asarray(np.random.RandomState(0).randn(4, cfg.num_vars),
                    jnp.float32)
    ll = model.log_likelihood(params, x)
    assert ll.shape == (4,)
    assert bool(jnp.all(jnp.isfinite(ll)))
    # the registered RAT archs run depth-grouped by default (this PR)
    assert model.grouped_active
    assert model.grouping_summary()["fused_groups"] >= 1


@pytest.mark.parametrize("arch", ["einet_pd_mnist", "einet_pd",
                                  "einet_celeba"])
def test_registered_pd_archs_build_gather_plans(arch):
    """Every registered PD arch compiles to a gather-grouped plan with
    strictly fewer launches than the per-layer loop (the gather-fusion
    tentpole); only the root pair stays per-layer."""
    model = build_einet(get_config(arch))
    assert model.grouped_active, arch
    s = model.grouping_summary()
    assert s["gather_groups"] >= 1, (arch, s)
    assert s["launches_grouped"] < s["launches_per_layer"], (arch, s)
    kinds = [seg[2] for seg in s["segments"]]
    assert all(k in ("gather", "layer") for k in kinds), (arch, kinds)
    assert kinds[-1] == "layer"  # the root pair (K_out != K)
