"""Deliverable (f): per-architecture smoke tests.

Every assigned architecture instantiates a REDUCED config of the same family
(same block pattern / MoE layout / flags, small dims) and runs one forward and
one train step on CPU, asserting output shapes and finiteness.  The serve
(prefill + decode) path is additionally checked for exact consistency with
the training forward.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import LM_ARCHS, get_config, smoke_variant
from repro.models import lm
from repro.optim import adamw

KEY = jax.random.PRNGKey(0)
B, S = 2, 32


def _batch(cfg, s=S, with_labels=True):
    out = {}
    if cfg.embedding_input:
        out["inputs_embeds"] = (
            jax.random.normal(KEY, (B, s, cfg.d_model), jnp.float32) * 0.1
        )
    else:
        out["tokens"] = jax.random.randint(KEY, (B, s), 0, cfg.vocab_size)
    if with_labels:
        out["labels"] = jax.random.randint(KEY, (B, s), 0, cfg.vocab_size)
    return out


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = smoke_variant(get_config(arch))
    params = lm.init_params(cfg, KEY)
    logits, aux = jax.jit(lambda p, b: lm.forward(cfg, p, b))(
        params, _batch(cfg, with_labels=False)
    )
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_train_step(arch):
    cfg = smoke_variant(get_config(arch))
    params = lm.init_params(cfg, KEY)
    ocfg = adamw.AdamWConfig()
    ostate = adamw.init_state(ocfg, params)
    p2, o2, m = jax.jit(
        lambda p, o, b: lm.train_step(cfg, ocfg, p, o, b)
    )(params, ostate, _batch(cfg))
    assert np.isfinite(float(m["loss"]))
    assert np.isfinite(float(m["grad_norm"]))
    # parameters actually moved
    moved = any(
        not np.allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))
        for a, b in zip(
            jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(p2)
        )
    )
    assert moved


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_serve_consistency(arch):
    """prefill(x[:t]) + decode(x[t]) logits == forward(x) logits at t."""
    cfg = smoke_variant(get_config(arch))
    if cfg.num_experts:  # no-drop capacity so routing is batch-independent
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params = lm.init_params(cfg, KEY)
    if cfg.embedding_input:
        emb = jax.random.normal(KEY, (B, 16, cfg.d_model), jnp.float32) * 0.1
        full, _ = lm.forward(cfg, params, {"inputs_embeds": emb}, remat=False)
        lgp, cache, pos = lm.prefill(
            cfg, params, {"inputs_embeds": emb[:, :15]}, max_len=16
        )
        lgd, _ = lm.decode_step(
            cfg, params, {"inputs_embeds": emb[:, 15:16]}, cache, pos
        )
    else:
        toks = jax.random.randint(jax.random.PRNGKey(1), (B, 16), 0, cfg.vocab_size)
        full, _ = lm.forward(cfg, params, {"tokens": toks}, remat=False)
        lgp, cache, pos = lm.prefill(cfg, params, {"tokens": toks[:, :15]},
                                     max_len=16)
        lgd, _ = lm.decode_step(cfg, params, {"tokens": toks[:, 15:16]},
                                cache, pos)
    np.testing.assert_allclose(
        np.asarray(lgp[:, 0]), np.asarray(full[:, 14], np.float32), atol=5e-3
    )
    np.testing.assert_allclose(
        np.asarray(lgd[:, 0]), np.asarray(full[:, 15], np.float32), atol=5e-3
    )


def test_exact_config_numbers():
    """The full (non-smoke) configs carry exactly the assigned numbers."""
    expect = {
        "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
        "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 0, 163840),
        "moonshot-v1-16b-a3b": (48, 2048, 16, 16, 0, 163840),
        "granite-8b": (36, 4096, 32, 8, 14336, 49152),
        "llama3.2-3b": (28, 3072, 24, 8, 8192, 128256),
        "nemotron-4-15b": (32, 6144, 48, 8, 24576, 256000),
        "qwen1.5-0.5b": (24, 1024, 16, 16, 2816, 151936),
        "internvl2-26b": (48, 6144, 48, 8, 16384, 92553),
    }
    for arch, (nl, d, h, kv, ff, v) in expect.items():
        c = get_config(arch)
        assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
                c.d_ff, c.vocab_size) == (nl, d, h, kv, ff, v), arch
    # MoE details
    kimi = get_config("kimi-k2-1t-a32b")
    assert (kimi.num_experts, kimi.num_experts_per_tok, kimi.d_ff_expert) == (384, 8, 2048)
    moon = get_config("moonshot-v1-16b-a3b")
    assert (moon.num_experts, moon.num_experts_per_tok, moon.d_ff_expert) == (64, 6, 1408)
    jamba = get_config("jamba-v0.1-52b")
    assert (jamba.num_experts, jamba.num_experts_per_tok) == (16, 2)
    assert jamba.block_pattern.count("attn") * 8 == len(jamba.block_pattern)
    assert get_config("qwen1.5-0.5b").qkv_bias
    assert get_config("nemotron-4-15b").activation == "squared_relu"
    assert get_config("kimi-k2-1t-a32b").head_dim == 112


def test_param_counts_sane():
    """Analytic parameter counts match the advertised model sizes."""
    approx = {
        "kimi-k2-1t-a32b": (1.0e12, 0.25),
        "jamba-v0.1-52b": (52e9, 0.35),
        "granite-8b": (8e9, 0.3),
        "llama3.2-3b": (3.2e9, 0.4),
        "nemotron-4-15b": (15e9, 0.35),
        "qwen1.5-0.5b": (0.5e9, 0.5),
        # backbone only: the assignment stubs the 6B InternViT frontend
        "internvl2-26b": (20e9, 0.35),
        # the assignment's table numbers (48L x 64e x 1408) imply ~28B total;
        # the advertised 16B corresponds to a sparser MoE placement --
        # we implement the table numbers verbatim (active ~4B checks out)
        "moonshot-v1-16b-a3b": (28e9, 0.3),
        "xlstm-350m": (350e6, 0.6),
    }
    for arch, (target, tol) in approx.items():
        n = get_config(arch).param_count()
        assert abs(n - target) / target < tol, f"{arch}: {n:.3e} vs {target:.1e}"
    kimi = get_config("kimi-k2-1t-a32b")
    a = kimi.active_param_count()
    assert 20e9 < a < 45e9, f"kimi active {a:.2e} should be ~32B"
    moon = get_config("moonshot-v1-16b-a3b").active_param_count()
    assert 2e9 < moon < 6e9, f"moonshot active {moon:.2e} should be ~3B"
