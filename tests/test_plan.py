"""Unit suite for the circuit execution planner (``repro.core.plan``).

Exercises the planner as a pure host-side function over ``PairSpec`` lists:
segment boundaries under tight VMEM budgets, mixed canonical/gather region
graphs (buffer mode forbids slice-tiled fusion), fallback-reason reporting,
launch accounting, gather-table construction, and the budget-resolution
priority (ctor > env > default).
"""

import numpy as np
import pytest

from repro.core import plan as plan_lib
from repro.core.einet import EiNet, PairSpec
from repro.core.exponential_family import Normal
from repro.core.region_graph import poon_domingos, random_binary_trees


def _canonical_spec(rows_below, num_partitions, k, is_final=False,
                    k_out=None):
    left = np.arange(num_partitions)
    return PairSpec(
        left=rows_below - 2 * num_partitions + left,
        right=rows_below - num_partitions + left,
        einsum_global=rows_below + left,
        k_in=k,
        k_out=k if k_out is None else k_out,
        mix_child_local=None,
        mix_mask=None,
        mix_global=None,
        is_final=is_final,
        canonical=True,
    )


def _canonical_chain(depths, k, leaves=None):
    """An exact halving chain: 2**depths leaf rows down to one root pair."""
    specs = []
    rows = leaves if leaves is not None else 2 ** depths
    for d in range(depths):
        n = 2 ** (depths - 1 - d)
        specs.append(
            _canonical_spec(rows, n, k, is_final=(d == depths - 1))
        )
        rows += n
    return specs


def test_resolve_vmem_budget_priority(monkeypatch):
    monkeypatch.delenv(plan_lib.VMEM_BUDGET_ENV, raising=False)
    assert plan_lib.resolve_vmem_budget() == plan_lib.VMEM_BUDGET_BYTES
    monkeypatch.setenv(plan_lib.VMEM_BUDGET_ENV, "123456")
    assert plan_lib.resolve_vmem_budget() == 123456
    assert plan_lib.resolve_vmem_budget(777) == 777  # ctor wins over env


def test_vmem_env_reaches_model_plan(monkeypatch):
    monkeypatch.setenv(plan_lib.VMEM_BUDGET_ENV, str(4 * 2 ** 20))
    graph = random_binary_trees(64, 3, 2, seed=0)
    m = EiNet(graph, num_sums=4, exponential_family=Normal(), grouped=True)
    assert m.vmem_budget == 4 * 2 ** 20
    assert m.grouping_summary()["vmem_budget"] == 4 * 2 ** 20


def test_disabled_plan_is_all_layer_segments():
    specs = _canonical_chain(3, 4)
    p = plan_lib.plan_circuit(specs, grouped=False)
    assert [s.kind for s in p.segments] == ["layer"] * 3
    assert not p.grouped_active
    assert all(r == "grouped execution disabled"
               for _, r in p.fallback_reasons)
    per_layer, planned = p.launches()
    assert per_layer == planned == 3


def test_canonical_chain_single_fused_segment():
    specs = _canonical_chain(4, 4)
    p = plan_lib.plan_circuit(specs)
    assert [s.kind for s in p.segments] == ["fused"]
    assert (p.segments[0].start, p.segments[0].stop) == (0, 4)
    assert p.launches() == (4, 1)


def test_canonical_tight_budget_splits_segments():
    """The greedy planner splits exactly where the budget stops admitting a
    longer run, and the segments tile the pair list."""
    specs = _canonical_chain(4, 4)
    full_cost = plan_lib.fused_cost_bytes(
        specs, 0, 3, 1, min(plan_lib._GROUP_BLOCK_B)
    )
    p = plan_lib.plan_circuit(specs, vmem_budget=full_cost - 1)
    kinds = [s.kind for s in p.segments]
    assert kinds.count("fused") >= 2, kinds
    covered = [i for s in p.segments for i in range(s.start, s.stop)]
    assert covered == list(range(4))


def test_canonical_budget_below_two_depths_goes_per_layer():
    specs = _canonical_chain(3, 4)
    p = plan_lib.plan_circuit(specs, vmem_budget=1)
    assert [s.kind for s in p.segments] == ["layer"] * 3
    # every pair with a 2-run candidate reports the budget as the blocker
    # (the last pair has no candidate run at all)
    reasons = dict(p.fallback_reasons)
    assert "vmem budget" in reasons[0] and "vmem budget" in reasons[1]


def test_buffer_mode_forbids_fused_segments():
    """A single non-canonical pair anywhere forces row-buffer mode: even
    perfectly canonical runs execute as gather segments (slice-tiled fusion
    would skip materializing rows the buffer needs)."""
    graph = random_binary_trees(16, 3, 3, seed=0)
    m = EiNet(graph, num_sums=4, exponential_family=Normal(), grouped=True)
    assert m.needs_buffer
    assert any(sp.canonical for sp in m.pair_specs)  # genuinely mixed graph
    s = m.grouping_summary()
    assert s["fused_groups"] == 0
    assert s["gather_groups"] >= 1


def test_gather_tight_budget_splits_runs():
    """PD chain under a budget that fits 2-pair gather runs but not the
    whole run: >= 2 gather groups, still covering every non-final pair."""
    graph = poon_domingos(4, 4, 1)
    m = EiNet(graph, num_sums=3, exponential_family=Normal(), grouped=True)
    specs = m.pair_specs
    whole = plan_lib.plan_circuit(specs)
    assert whole.summary()["gather_groups"] == 1
    stop = whole.segments[0].stop
    assert stop >= 4  # need a >= 4-pair run for a two-group split
    # largest budget that cannot fit the first (stop - 1) pairs: the greedy
    # first run shrinks and the tail still fits a second gather run
    budget = plan_lib.gather_cost_bytes(
        specs, 0, stop - 1, min(plan_lib._GROUP_BLOCK_B)
    ) - 1
    split = plan_lib.plan_circuit(specs, vmem_budget=budget)
    s = split.summary()
    assert s["gather_groups"] >= 2, s
    covered = [i for seg in split.segments for i in range(seg.start, seg.stop)]
    assert covered == list(range(len(specs)))


def test_gather_final_pair_stays_per_layer_with_reason():
    graph = poon_domingos(2, 8, 2)
    m = EiNet(graph, num_sums=6, exponential_family=Normal(), grouped=True)
    p = m.plan
    assert p.segments[-1].kind == "layer"
    assert any("final (root) pair" in r for _, r in p.fallback_reasons)


def test_gather_tables_match_specs():
    graph = poon_domingos(2, 8, 2)
    m = EiNet(graph, num_sums=6, exponential_family=Normal(), grouped=True)
    seg = next(s for s in m.plan.segments if s.kind == "gather")
    t = seg.tables
    hash(t)  # static kernel/custom_vjp arg: must be hashable
    assert t.num_in_rows == int(m.pair_specs[seg.start].einsum_global[0])
    assert t.num_depths == seg.stop - seg.start
    assert t.num_new_rows == sum(
        sp.num_partitions + sp.num_mixed
        for sp in m.pair_specs[seg.start: seg.stop]
    )
    for d, sp in enumerate(m.pair_specs[seg.start: seg.stop]):
        assert t.left[d] == tuple(int(v) for v in sp.left)
        assert t.right[d] == tuple(int(v) for v in sp.right)
        if sp.mix_global is None:
            assert t.mix_child[d] is None
        else:
            assert np.array_equal(np.asarray(t.mix_child[d]),
                                  sp.mix_child_local)
            assert np.array_equal(np.asarray(t.mix_mask[d]), sp.mix_mask)


def test_launch_accounting_per_kind():
    """A gather segment is ONE launch (in-kernel mixing); fused and layer
    segments pay for the terminating/own pair's mixing launch."""
    graph = poon_domingos(4, 8, 2)
    m = EiNet(graph, num_sums=4, exponential_family=Normal(), grouped=True)
    p = m.plan
    per_layer, planned = p.launches()
    assert per_layer == p.num_pairs + sum(p.mix_flags)
    expect = 0
    for seg in p.segments:
        if seg.kind == "gather":
            expect += 1
        elif seg.kind == "fused":
            expect += 1 + int(p.mix_flags[seg.stop - 1])
        else:
            expect += 1 + int(p.mix_flags[seg.start])
    assert planned == expect
    assert planned < per_layer


def test_format_summary_mentions_every_segment_and_fallback():
    graph = poon_domingos(2, 8, 2)
    m = EiNet(graph, num_sums=6, exponential_family=Normal(), grouped=True)
    line = plan_lib.format_summary(m.grouping_summary())
    assert "gather[" in line
    assert "final (root) pair" in line
    assert f"vmem budget {m.vmem_budget} B" in line
