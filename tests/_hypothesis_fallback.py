"""Minimal stand-in for the slice of `hypothesis` these tests use.

The container image does not ship hypothesis and nothing may be installed,
so conftest.py registers this module as ``sys.modules["hypothesis"]`` when
the real package is missing.  It implements ``given`` / ``settings`` /
``strategies.integers`` / ``strategies.sampled_from`` as a deterministic
sampler: boundary values first, then seeded-random draws, ``max_examples``
honored.  No shrinking, no database -- failures report the drawn arguments
in the assertion traceback instead.
"""

from __future__ import annotations

import inspect
import random
from types import SimpleNamespace


class _Strategy:
    def __init__(self, boundary, draw):
        self.boundary = list(boundary)  # always-tested edge cases
        self.draw = draw  # rng -> value


def integers(min_value, max_value):
    return _Strategy(
        boundary=[min_value, max_value],
        draw=lambda rng: rng.randint(min_value, max_value),
    )


def sampled_from(elements):
    elements = list(elements)
    return _Strategy(
        boundary=elements[:1],
        draw=lambda rng: rng.choice(elements),
    )


def settings(max_examples=10, deadline=None, **_ignored):
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn

    return deco


def given(*arg_strategies, **kw_strategies):
    def deco(fn):
        def wrapper():
            # read lazily so @settings works in either decorator order
            # (above @given it lands on wrapper, below it lands on fn)
            max_examples = getattr(
                wrapper, "_fallback_max_examples",
                getattr(fn, "_fallback_max_examples", 10),
            )
            rng = random.Random(fn.__name__)  # deterministic per test
            strategies = list(arg_strategies) + list(kw_strategies.values())
            names = list(kw_strategies.keys())
            n_boundary = max((len(s.boundary) for s in strategies), default=0)
            for example in range(max_examples):
                drawn = []
                for s in strategies:
                    if example < n_boundary and s.boundary:
                        drawn.append(s.boundary[example % len(s.boundary)])
                    else:
                        drawn.append(s.draw(rng))
                args = drawn[: len(arg_strategies)]
                kwargs = dict(zip(names, drawn[len(arg_strategies):]))
                fn(*args, **kwargs)

        # keep the name/doc for reporting but present a zero-arg signature,
        # so pytest does not mistake the drawn parameters for fixtures
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        wrapper.__signature__ = inspect.Signature()
        return wrapper

    return deco


strategies = SimpleNamespace(integers=integers, sampled_from=sampled_from)
