"""repro.eval: masks, bpd metrics, engine-vs-direct parity, inpainting
determinism (the Fig. 4 harness contract), and artifact writers."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import EinetConfig
from repro.data import datasets as ds
from repro.eval import grids as grids_lib
from repro.eval.inpainting import INPAINT_KINDS, run_inpainting
from repro.eval.masks import MASK_KINDS, make_mask
from repro.eval.metrics import (
    bits_per_dim,
    direct_log_likelihoods,
    engine_log_likelihoods,
    evaluate_bpd,
)
from repro.launch.cells import build_einet
from repro.serve import ServeEngine

H = W = 8
C = 1
D = H * W * C


@pytest.fixture(scope="module")
def pd_net():
    cfg = EinetConfig(
        name="einet-pd-test", structure="pd", height=H, width=W,
        num_channels=C, delta=4, pd_axes=("w",), num_sums=4,
        exponential_family="normal", min_var=1e-6, max_var=1e-2,
    )
    model = build_einet(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


@pytest.fixture(scope="module")
def images():
    d = ds.synthetic_image_dataset(H, W, C, num_train=64, num_test=24, seed=0)
    x, _ = ds.to_domain(d.test_x, "normal")
    return x


# ----------------------------------------------------------------- masks
def test_mask_kinds_shapes_and_regions():
    for kind in MASK_KINDS:
        m = make_mask(kind, H, W, C)
        assert m.shape == (D,) and m.dtype == bool
        assert 0 < m.sum() < D  # something observed, something occluded
    left = make_mask("left_half", H, W, C).reshape(H, W, C)
    assert not left[:, : W // 2].any() and left[:, W // 2:].all()
    bottom = make_mask("bottom_half", H, W, C).reshape(H, W, C)
    assert not bottom[H // 2:].any() and bottom[: H // 2].all()
    center = make_mask("center_square", H, W, C).reshape(H, W, C)
    assert not center[H // 4: H // 4 + H // 2, W // 4: W // 4 + W // 2].any()
    assert center[0, 0] and center[-1, -1]


def test_random_mask_deterministic_and_channel_coupled():
    a = make_mask("random_pixel", H, W, 3, seed=5)
    b = make_mask("random_pixel", H, W, 3, seed=5)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, make_mask("random_pixel", H, W, 3, seed=6))
    # whole pixels are occluded together: channels agree
    pix = a.reshape(H * W, 3)
    assert (pix.all(1) | (~pix).any(1)).all()
    assert (pix[:, 0] == pix[:, 1]).all() and (pix[:, 1] == pix[:, 2]).all()
    with pytest.raises(KeyError):
        make_mask("diagonal", H, W, C)


# --------------------------------------------------------------- metrics
def test_bits_per_dim_formula():
    # uniform density on [0,1]^D has ll = 0 -> bpd equals the uint8 offset
    assert bits_per_dim(0.0, 64, offset_bits=8.0) == pytest.approx(8.0)
    # one nat per dim = 1/ln2 bits per dim
    assert bits_per_dim(-64.0, 64, 0.0) == pytest.approx(1.0 / np.log(2.0))


def test_engine_ll_matches_direct_with_zero_mismatches(pd_net, images):
    model, params = pd_net
    res = engine_log_likelihoods(
        model, params, images, engine=None, max_batch=8, parity_rows=None
    )
    assert res.parity_mismatches == 0
    assert res.parity_rows == len(images)
    assert np.all(np.isfinite(res.ll))
    dense = direct_log_likelihoods(model, params, images, chunk=8)
    np.testing.assert_allclose(res.ll, dense, atol=1e-5)


def test_marginal_ll_streaming_and_bpd_record(pd_net, images):
    model, params = pd_net
    ev = make_mask("left_half", H, W, C)
    res = engine_log_likelihoods(
        model, params, images[:8], kind="marginal_ll", evidence_mask=ev,
        max_batch=4, parity_rows=None,
    )
    assert res.parity_mismatches == 0
    # marginal LL over fewer dims is higher than the joint
    joint = engine_log_likelihoods(
        model, params, images[:8], max_batch=4, parity_rows=0
    )
    assert np.all(res.ll >= joint.ll)
    rec = evaluate_bpd(model, params, images[:8], offset_bits=8.0,
                       max_batch=4, parity_rows=None)
    assert rec["parity_mismatches"] == 0
    assert rec["bpd"] == pytest.approx(
        bits_per_dim(rec["mean_ll"], D, 8.0))
    with pytest.raises(ValueError):
        engine_log_likelihoods(model, params, images, kind="mpe")


def test_marginal_ll_ignores_occluded_values(pd_net, images):
    """Marginalized-LL on masked images == the dense marginal-mask path:
    values under the occlusion cannot affect log p(x_evidence)."""
    model, params = pd_net
    ev = jnp.asarray(np.tile(make_mask("center_square", H, W, C), (8, 1)))
    x = jnp.asarray(images[:8])
    zeroed = jnp.where(ev, x, 0.0)
    scrambled = jnp.where(ev, x, 17.3)
    ll = model.log_likelihood(params, x, ev)
    np.testing.assert_array_equal(np.asarray(ll),
                                  np.asarray(model.log_likelihood(params, zeroed, ev)))
    np.testing.assert_array_equal(np.asarray(ll),
                                  np.asarray(model.log_likelihood(params, scrambled, ev)))


# ------------------------------------------------------------ inpainting
def test_inpainting_engine_bit_identical_to_direct_under_every_mask(
    pd_net, images
):
    """The determinism contract: engine-batched conditional_sample / mpe
    with per-request keys reproduces direct EiNet.query calls bit-for-bit
    under every structured mask (parity_rows=None checks all requests)."""
    model, params = pd_net
    rep = run_inpainting(
        model, params, images[:3], H, W, C, max_batch=8, seed=11,
        parity_rows=None,
    )
    assert rep.metrics["parity_mismatches"] == 0
    assert rep.metrics["parity_rows"] == rep.metrics["num_requests"]
    assert rep.metrics["num_requests"] == len(MASK_KINDS) * len(INPAINT_KINDS) * 3
    for mk in MASK_KINDS:
        ev = rep.evidence_masks[mk]
        for qk in INPAINT_KINDS:
            recon = rep.recon(mk, qk)
            # evidence passes through untouched; occlusion is filled
            np.testing.assert_array_equal(recon[:, ev], images[:3][:, ev])
            assert np.all(np.isfinite(recon))
        assert f"{qk}_mse" in rep.metrics["per_mask"][mk]


def test_inpainting_invariant_to_engine_batching(pd_net, images):
    """Different micro-batch caps (hence different coalescing/padding) must
    give byte-identical reconstructions: per-request keys decouple a draw
    from its neighbours."""
    model, params = pd_net
    a = run_inpainting(model, params, images[:4], H, W, C, max_batch=2,
                       seed=3, parity_rows=0)
    b = run_inpainting(model, params, images[:4], H, W, C, max_batch=16,
                       seed=3, parity_rows=0)
    for mk in MASK_KINDS:
        for qk in INPAINT_KINDS:
            np.testing.assert_array_equal(a.recon(mk, qk), b.recon(mk, qk))


def test_inpainting_mean_fill_baseline(pd_net, images):
    model, params = pd_net
    rep = run_inpainting(
        model, params, images[:2], H, W, C, mask_kinds=("left_half",),
        mean_fill=images.mean(0), parity_rows=0,
    )
    m = rep.metrics["per_mask"]["left_half"]
    assert "mean_fill_mse" in m and m["mean_fill_mse"] >= 0
    assert m["missing_fraction"] == pytest.approx(0.5)


# -------------------------------------------------------------- artifacts
def test_save_image_grid_and_metrics_json(tmp_path):
    imgs = np.random.RandomState(0).rand(5, H, W, C).astype(np.float32)
    p = grids_lib.save_image_grid(str(tmp_path / "g.png"), imgs, columns=3)
    from PIL import Image

    im = Image.open(p)
    assert im.size[0] > W and im.size[1] > H
    rgb = np.random.RandomState(0).rand(4, H, W, 3).astype(np.float32)
    grids_lib.save_image_grid(str(tmp_path / "rgb.png"), rgb)
    assert Image.open(tmp_path / "rgb.png").mode == "RGB"

    rec = {"bpd": np.float32(1.5), "n": 3}
    jp = grids_lib.save_metrics_json(str(tmp_path / "run" / "metrics.json"),
                                     rec)
    assert json.load(open(jp))["bpd"] == pytest.approx(1.5)
    loaded = grids_lib.load_eval_records(str(tmp_path))
    assert len(loaded) == 1 and loaded[0]["n"] == 3


def test_save_inpainting_grid(tmp_path, images):
    ev = make_mask("bottom_half", H, W, C)
    p = grids_lib.save_inpainting_grid(
        str(tmp_path / "fig4.png"), images[:4], ev, images[:4], images[:4],
        H, W, C,
    )
    assert os.path.isfile(p)


# -------------------------------------------------------------- workbench
def test_run_eval_smoke_record(tmp_path):
    from repro.eval.workbench import EvalConfig, run_eval

    cfg = EvalConfig(
        dataset="synthetic", smoke=True, steps=2, eval_rows=12,
        inpaint_rows=2, num_samples=4, max_batch=4,
        mask_kinds=("left_half", "random_pixel"),
        out_dir=str(tmp_path), run_name="t",
    )
    rec = run_eval(cfg)
    assert rec["parity_mismatches_total"] == 0
    assert rec["bpd_joint"]["num_rows"] == 12
    assert os.path.isfile(tmp_path / "t" / "metrics.json")
    assert os.path.isfile(tmp_path / "t" / "samples.png")
    assert os.path.isfile(tmp_path / "t" / "inpaint_left_half.png")
    # the record is what make_experiments_md ingests
    assert json.load(open(tmp_path / "t" / "metrics.json"))["run_name"] == "t"
