"""Compiled EM training pipeline (repro.train) + sharded-loader regression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    EMConfig,
    EiNet,
    Normal,
    accumulate_statistics,
    em_statistics,
    em_update,
    random_binary_trees,
    stochastic_em_update,
    zeros_like_statistics,
)
from repro.launch.train import einet_loader
from repro.train import (
    TrainConfig,
    em_update_microbatched,
    fit,
    make_em_step,
    microbatched_em_statistics,
    stochastic_em_update_microbatched,
)


@pytest.fixture(scope="module")
def setup():
    g = random_binary_trees(10, 2, 2, seed=0)
    net = EiNet(g, num_sums=4, exponential_family=Normal())
    params = net.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 10)) * 1.5 + 0.3
    return net, params, x


# ---------------------------------------------------------------- pipeline
def test_scan_statistics_match_python_loop(setup):
    """The lax.scan accumulation must total exactly what the Python-loop
    ``accumulate_statistics`` pattern totals (statistics are sums over data)."""
    net, params, x = setup
    scanned = microbatched_em_statistics(net, params, x, num_microbatches=4)
    acc = zeros_like_statistics(net, params)
    for i in range(4):
        acc = accumulate_statistics(
            acc, em_statistics(net, params, x[i * 16: (i + 1) * 16])
        )
    for a, b in zip(
        jax.tree_util.tree_leaves(scanned), jax.tree_util.tree_leaves(acc)
    ):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6,
                                   atol=1e-6)


def test_microbatched_update_matches_single_batch(setup):
    """Microbatching is an implementation detail: the EM update from 4
    microbatches must match the one-shot full-batch update."""
    net, params, x = setup
    one, ll1 = em_update(net, params, x)
    four, ll4 = em_update_microbatched(net, params, x, num_microbatches=4)
    np.testing.assert_allclose(float(ll1), float(ll4), rtol=1e-5)
    for a, b in zip(
        jax.tree_util.tree_leaves(one), jax.tree_util.tree_leaves(four)
    ):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4,
                                   atol=1e-6)


def test_compiled_step_matches_reference_update(setup):
    """The jitted donated-buffer step must produce the same parameters as the
    plain stochastic_em_update it compiles."""
    net, params, x = setup
    cfg = EMConfig(step_size=0.4)
    ref, ll_ref = stochastic_em_update(net, params, x, cfg)
    step = make_em_step(net, TrainConfig(em=cfg, mode="stochastic"))
    got, ll_got = step(params, x)
    np.testing.assert_allclose(float(ll_ref), float(ll_got), rtol=1e-5)
    for a, b in zip(
        jax.tree_util.tree_leaves(ref), jax.tree_util.tree_leaves(got)
    ):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                                   atol=1e-6)


def test_stochastic_microbatched_blend(setup):
    net, params, x = setup
    cfg = EMConfig(step_size=0.3)
    ref, _ = stochastic_em_update(net, params, x, cfg)
    got, _ = stochastic_em_update_microbatched(
        net, params, x, cfg, num_microbatches=2
    )
    for a, b in zip(
        jax.tree_util.tree_leaves(ref), jax.tree_util.tree_leaves(got)
    ):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4,
                                   atol=1e-6)


def test_full_mode_step_is_monotone(setup):
    net, params, x = setup
    step = make_em_step(net, TrainConfig(mode="full", num_microbatches=2))
    p, prev = params, -np.inf
    for _ in range(6):
        p, ll = step(p, x)
        assert float(ll) >= prev - 1e-3
        prev = float(ll)


def test_fit_learns(setup):
    net, params, _ = setup
    data = jax.random.normal(jax.random.PRNGKey(7), (256, 10)) * 0.7 - 0.5
    batches = [data[i * 64: (i + 1) * 64] for i in range(4)] * 5
    p, lls = fit(net, params, batches,
                 TrainConfig(em=EMConfig(step_size=0.4)))
    assert np.mean(lls[-4:]) > np.mean(lls[:4]) + 0.5


def test_make_em_step_rejects_unknown_mode(setup):
    net, _, _ = setup
    with pytest.raises(ValueError):
        make_em_step(net, TrainConfig(mode="adam"))


def test_microbatch_divisibility_error(setup):
    net, params, x = setup
    with pytest.raises(ValueError):
        em_update_microbatched(net, params, x, num_microbatches=7)


# ------------------------------------------------------------------ loader
def test_einet_loader_shards_are_disjoint_and_cover_batch():
    """Regression: the pre-PR-3 loader ignored its shard argument, so every
    data-parallel shard trained on IDENTICAL rows."""
    data = np.arange(64, dtype=np.float32)[:, None].repeat(3, axis=1)
    num_shards, global_batch = 4, 16
    loaders = [
        einet_loader(data, global_batch, num_shards=num_shards, shard_id=sh)
        for sh in range(num_shards)
    ]
    step0 = [ld.batch_at(0)["x"] for ld in loaders]
    ids = [set(b[:, 0].astype(int).tolist()) for b in step0]
    for i in range(num_shards):
        assert len(ids[i]) == global_batch // num_shards
        for j in range(i + 1, num_shards):
            assert not ids[i] & ids[j], f"shards {i},{j} overlap: {ids[i] & ids[j]}"
    union = set().union(*ids)
    assert union == set(range(global_batch)), "step 0 must cover rows [0, 16)"
    # consecutive steps keep tiling the dataset
    step1 = set(loaders[0].batch_at(1)["x"][:, 0].astype(int).tolist())
    assert step1 == set(range(16, 20))


def test_einet_loader_explicit_shard_override():
    """batch_at(step, shard) re-points a shard (straggler remap contract)."""
    data = np.arange(32, dtype=np.float32)[:, None]
    ld = einet_loader(data, 8, num_shards=2, shard_id=0)
    own = ld.batch_at(0)["x"][:, 0]
    other = ld.batch_at(0, shard=1)["x"][:, 0]
    assert not set(own.astype(int)) & set(other.astype(int))
